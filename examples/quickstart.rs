//! Quickstart: quantize one linear layer with COMQ in ~40 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! No artifacts needed — this exercises the pure-algorithm API on
//! synthetic calibration data, comparing COMQ against round-to-nearest
//! exactly as Sec. 3 of the paper describes.

use comq::quant::grid::Scheme;
use comq::quant::{comq_gram, make_quantizer, GramSet, OrderKind, QuantConfig};
use comq::tensor::{matmul_at_a, Tensor};
use comq::util::Rng;

fn main() {
    // A "layer": weights W [m, n] and calibration features X [b, m].
    let (b, m, n) = (512, 64, 32);
    let mut rng = Rng::new(7);
    let x = Tensor::new(&[b, m], rng.normal_vec(b * m));
    let w = Tensor::new(&[m, n], rng.normal_vec(m * n)).scale(0.5);

    // The entire calibration interface is the Gram matrix G = XᵀX:
    // the layer-wise objective ‖XW_q − XW‖² depends on X only through G.
    let gram = GramSet::Shared(matmul_at_a(&x));

    println!(
        "{:<22} {:>6} {:>14} {:>14} {:>8}",
        "method", "bits", "err", "rtn err", "ratio"
    );
    for bits in [4u32, 3, 2] {
        let cfg = QuantConfig {
            bits,
            scheme: Scheme::PerChannel,
            order: OrderKind::GreedyPerColumn, // Sec. 3.3 greedy rule
            iters: 3,                          // K (Tab. 7: 3–4 optimal)
            lam: 1.0,
        };
        // COMQ: backprop-free coordinate descent (Alg. 2)
        let lq = comq_gram(&gram, &w, &cfg);
        assert!(lq.codes_feasible(bits));
        let err = gram.recon_error(&w, &lq.dequant());

        // Baseline: round-to-nearest on the same grid
        let rtn = make_quantizer("rtn").unwrap().quantize(&gram, &w, &cfg);
        let err_rtn = gram.recon_error(&w, &rtn.dequant());

        println!(
            "{:<22} {:>6} {:>14.4} {:>14.4} {:>7.2}x",
            "comq (greedy, K=3)",
            bits,
            err,
            err_rtn,
            err_rtn / err
        );
    }

    // Deployment: pack the 4-bit codes into a real bitstream.
    let cfg = QuantConfig::default();
    let lq = comq_gram(&gram, &w, &cfg);
    let packed = lq.pack_codes(4);
    println!(
        "\npacked {} weights into {} bytes ({}x smaller than f32)",
        m * n,
        packed.len(),
        (m * n * 4) / packed.len()
    );
}
