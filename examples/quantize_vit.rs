//! End-to-end driver: quantize a real trained ViT with every method and
//! evaluate on the real validation workload through the PJRT artifacts.
//!
//! This composes the full three-layer system: the L3 coordinator
//! calibrates through the AOT L2 `calib_stats` graph, quantizes every
//! linear layer (optionally through the L1 Pallas sweep kernel), and
//! evaluates the quantized checkpoint through the AOT `forward` graph.
//!
//! ```bash
//! make artifacts && cargo run --release --example quantize_vit [model]
//! ```

use anyhow::Result;

use comq::calib::{Dataset, EngineKind};
use comq::coordinator::{quantize_model, PipelineOptions, QuantEngine};
use comq::manifest::Manifest;
use comq::model::Model;
use comq::quant::QuantConfig;

fn main() -> Result<()> {
    let model_name = std::env::args().nth(1).unwrap_or_else(|| "vit_s".into());
    let manifest = Manifest::load("artifacts")?;
    let model = Model::load(&manifest, &model_name)?;
    let dataset = Dataset::load(&manifest)?;
    println!(
        "model {model_name}: {} params, {} quantizable weights in {} layers (fp top1 {:.2}%)",
        model.num_params(),
        model.num_quant_weights(),
        model.info.quant_layers.len(),
        model.info.fp_top1 * 100.0
    );

    println!("\n-- weight-only, per-channel, 4/3/2 bits, all methods --");
    for bits in [4u32, 3, 2] {
        for method in ["comq", "comq-cyclic", "obq", "gpfq", "adaround-lite", "rtn"] {
            let opts = PipelineOptions {
                method: method.into(),
                engine: EngineKind::Pjrt,
                calib_size: 1024,
                qcfg: QuantConfig {
                    bits,
                    lam: if bits == 2 { 0.8 } else { 1.0 },
                    ..Default::default()
                },
                ..Default::default()
            };
            let (_qm, report) = quantize_model(&manifest, &model, &dataset, &opts)?;
            println!("{}", report.summary());
        }
    }

    println!("\n-- the same quantization through the L1 Pallas sweep kernel (PJRT) --");
    let opts = PipelineOptions {
        engine: EngineKind::Pjrt,
        quant_engine: QuantEngine::PjrtKernel,
        calib_size: 1024,
        qcfg: QuantConfig { bits: 4, ..Default::default() },
        ..Default::default()
    };
    let (_qm, report) = quantize_model(&manifest, &model, &dataset, &opts)?;
    println!("{}", report.summary());

    println!("\n-- full quantization: W4A4 / W4A8 --");
    for act_bits in [4u32, 8] {
        let opts = PipelineOptions {
            engine: EngineKind::Pjrt,
            calib_size: 1024,
            act_bits: Some(act_bits),
            ..Default::default()
        };
        let (_qm, report) = quantize_model(&manifest, &model, &dataset, &opts)?;
        println!("{}", report.summary());
    }
    Ok(())
}
