//! The TCP serving tier in one file: quantize a synthetic model, put
//! [`NetServer`] in front of the micro-batcher, and drive it with
//! [`NetClient`] — plain inference, a deadline-budgeted request, what an
//! overload shed looks like to a client, the Prometheus text endpoint
//! over the same socket, and a graceful drain.
//!
//! Runs entirely on the synthetic fixture — no AOT artifacts needed:
//!
//! ```bash
//! cargo run --release --example net_quickstart
//! ```
//!
//! For a real checkpoint, `comq serve --packed model.cqm --addr
//! 0.0.0.0:7943` serves the same protocol from the CLI.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use comq::proptest::{quantize_all_layers, tiny_plain_cnn};
use comq::serve::net::{AdmissionConfig, ClientError, NetClient, NetConfig, NetServer};
use comq::serve::{ActSource, BatchConfig, QuantizedModel};
use comq::tensor::Tensor;
use comq::util::Rng;

fn main() -> Result<()> {
    // 1. quantize: the same W4A8 synthetic CNN the serving tests use.
    let (manifest, model) = tiny_plain_cnn(7);
    let mut rng = Rng::new(42);
    let calib = Tensor::new(&[64, 8, 8, 3], rng.normal_vec(64 * 8 * 8 * 3));
    let (packed, act, qmodel) = quantize_all_layers(&manifest, &model, 4, 8, &calib)?;
    let qm = Arc::new(QuantizedModel::from_parts(
        model.info.clone(),
        qmodel.params.clone(),
        &packed,
        ActSource::Static { bits: act.bits, by_layer: act.by_layer },
    )?);
    let elems = 8 * 8 * 3;

    // 2. serve: one listener, an event loop (epoll on Linux), a
    //    micro-batcher + admission gate per model. Port 0 = ephemeral.
    let server = NetServer::bind(
        "127.0.0.1:0",
        vec![("tiny_plain".to_string(), qm.clone())],
        NetConfig {
            batch: BatchConfig { max_batch: 8, max_delay: Duration::from_millis(2), executors: 1 },
            admission: AdmissionConfig { max_inflight: 64, max_queue: 128 },
            ..NetConfig::default()
        },
    )?;
    println!("serving tiny_plain on {}", server.local_addr());

    // 3. infer over the wire — bit-identical to the in-process forward.
    let mut client = NetClient::connect(server.local_addr()).map_err(anyhow::Error::msg)?;
    let img = rng.normal_vec(elems);
    let logits = client.infer("tiny_plain", &img).map_err(anyhow::Error::msg)?;
    let direct = qm.forward(&Tensor::new(&[1, 8, 8, 3], img.clone()));
    assert_eq!(
        logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        direct.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
    );
    println!("wire logits match the direct forward bit for bit ({} classes)", logits.len());

    // 4. a latency budget rides the frame into the batcher: it tightens
    //    the coalesce window, and a request that cannot make it is shed
    //    with a typed error instead of burning a GEMM slot.
    let logits = client
        .infer_deadline("tiny_plain", &img, Some(Duration::from_millis(50)))
        .map_err(anyhow::Error::msg)?;
    println!("deadline-budgeted request served ({} classes)", logits.len());

    // 5. what a shed looks like: typed, per-request, connection intact.
    //    (Clients should back off on Overloaded; DeadlineExceeded means
    //    the budget was too tight for the current queue.)
    match client.infer_deadline("tiny_plain", &img, Some(Duration::from_micros(1))) {
        Ok(_) => println!("1 µs budget served anyway (fast machine!)"),
        Err(ClientError::Server { reason, message }) => {
            println!("1 µs budget shed as expected: {} ({message})", reason.name())
        }
        Err(e) => return Err(anyhow::Error::msg(e)),
    }

    // 6. the Prometheus exposition travels over the same transport
    //    (set COMQ_OBS=on to populate it; the net tier's always-on
    //    counters are in `server.stats()` either way).
    let text = client.metrics().map_err(anyhow::Error::msg)?;
    match text.lines().find(|l| l.starts_with("comq_net_frames_total")) {
        Some(line) => println!("metrics over the wire: {line}"),
        None => println!("metrics empty (COMQ_OBS=off) — stats: {:?}", server.stats()),
    }

    // 7. graceful drain: stop accepting, answer everything admitted,
    //    flush, join the loop and every executor.
    server.shutdown();
    let st = server.model_server("tiny_plain").expect("model").stats();
    println!("drained: {} served in {} batches, queue depth {}", st.served, st.batches, server.model_server("tiny_plain").unwrap().queue_depth());
    Ok(())
}
