//! Serving scenario: quantize once, then serve batched classification
//! requests three ways from one binary —
//!
//!  * `pjrt-sim`    — the compiled forward artifact with dequantized f32
//!    weights fed as inputs (simulated quantization: same graph, same
//!    FLOPs as fp32);
//!  * `fp32-native` — the in-crate f32 mirror forward;
//!  * `int8-serve`  — the integer runtime: packed codes expanded once to
//!    i8 panels, i8 GEMM with fused dequant, requests coalesced by the
//!    dynamic micro-batcher.
//!
//! One latency-percentile row per path, accuracy parity of the integer
//! path against the simulated reference, and the packed footprint.
//! At exit it prints the process-wide telemetry snapshot in Prometheus
//! text form — per-stage request latencies, queue depth, batch sizes,
//! per-layer exec counters, kernel dispatch counts (see
//! EXPERIMENTS.md §Observability for the metric catalogue; gate with
//! `COMQ_OBS=off|on|trace`, JSON twin via `obs::registry().to_json()`).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_quantized [model]
//! ```

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use comq::bench::pct;
use comq::calib::{Dataset, EngineKind};
use comq::coordinator::{quantize_model_packed, PipelineOptions};
use comq::eval::{evaluate, evaluate_int8, ActMode};
use comq::manifest::Manifest;
use comq::model::{Model, Tap};
use comq::runtime::Engine;
use comq::serve::{ActSource, BatchConfig, QuantizedModel, Server};
use comq::tensor::Tensor;
use comq::util::{stats, Rng, Timer};

fn main() -> Result<()> {
    let model_name = std::env::args().nth(1).unwrap_or_else(|| "vit_b".into());
    let manifest = Manifest::load("artifacts")?;
    let model = Model::load(&manifest, &model_name)?;
    let dataset = Dataset::load(&manifest)?;

    // 1. offline: quantize (the whole PTQ pass is part of the story —
    //    COMQ's pitch is that this step is seconds, not an hour),
    //    keeping the packed codes + calibrated activation grid around.
    let t = Timer::start();
    let opts = PipelineOptions {
        engine: EngineKind::Pjrt,
        calib_size: 1024,
        act_bits: Some(8),
        skip_eval: true,
        ..Default::default()
    };
    let out = quantize_model_packed(&manifest, &model, &dataset, &opts)?;
    println!(
        "quantized {model_name} to {}-bit (W{}A8) in {:.2}s (calib {:.2}s + quant {:.2}s)",
        opts.qcfg.bits,
        opts.qcfg.bits,
        t.secs(),
        out.report.calib_secs,
        out.report.quant_secs
    );

    // 2. online: one latency table, three serving paths.
    let b = manifest.batch;
    let elems = manifest.img * manifest.img * 3;
    let mut rng = Rng::new(1);
    let make_batch = |rng: &mut Rng| {
        Tensor::new(&[b, manifest.img, manifest.img, 3], rng.normal_vec(b * elems))
    };
    let row = |label: &str, lat: &[f64]| {
        // sort once; all three percentiles read the sorted copy
        let mut s = lat.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "{label:<12} batch={b}: p50={:.2}ms p95={:.2}ms p99={:.2}ms throughput={:.0} img/s",
            stats::quantile_sorted(&s, 0.5) * 1e3,
            stats::quantile_sorted(&s, 0.95) * 1e3,
            stats::quantile_sorted(&s, 0.99) * 1e3,
            b as f64 / stats::mean(&s)
        );
    };

    // 2a. PJRT simulated quantization (dequantized weights as inputs)
    {
        let engine = Engine::global()?;
        let art = manifest.path(&model.info.artifacts["forward"]);
        let exe = engine.load(&art)?;
        let params = out.model.params_in_order();
        let batch = make_batch(&mut rng);
        let mut inputs: Vec<&Tensor> = params.clone();
        inputs.push(&batch);
        let mut lat = Vec::new();
        for _ in 0..50 {
            let t = Timer::start();
            std::hint::black_box(engine.run_exe(&exe, &inputs)?);
            lat.push(t.secs());
        }
        row("pjrt-sim", &lat);
    }

    // 2b. fp32 native mirror forward
    {
        let batch = make_batch(&mut rng);
        let mut lat = Vec::new();
        for _ in 0..50 {
            let t = Timer::start();
            std::hint::black_box(model.forward(&batch, &mut Tap::None));
            lat.push(t.secs());
        }
        row("fp32-native", &lat);
    }

    // 2c. integer runtime behind the micro-batcher: b concurrent singles
    //     per wave, coalesced back into full batches by the queue.
    println!(
        "int8 GEMM kernel: {} (runtime-detected; force with COMQ_KERNEL=scalar|avx2|vnni)",
        comq::serve::Kernel::active().name()
    );
    let act_src = match &out.act {
        Some(a) => ActSource::Static { bits: a.bits, by_layer: a.by_layer.clone() },
        None => ActSource::Dynamic { bits: comq::serve::DEFAULT_ACT_BITS },
    };
    let qm = Arc::new(QuantizedModel::from_parts(
        model.info.clone(),
        out.model.params.clone(),
        &out.packed,
        act_src,
    )?);
    {
        let server = Server::start(
            qm.clone(),
            BatchConfig { max_batch: b, max_delay: Duration::from_millis(2), executors: 1 },
        );
        let mut lat = Vec::new();
        for _ in 0..50 {
            let wave: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(elems)).collect();
            let t = Timer::start();
            let rxs: Vec<_> = wave.into_iter().map(|im| server.submit(im)).collect();
            for rx in rxs {
                rx.recv()??;
            }
            lat.push(t.secs());
        }
        row("int8-serve", &lat);
        let st = server.stats();
        println!(
            "  micro-batcher: {} requests coalesced into {} batches (mean {:.1})",
            st.served,
            st.batches,
            st.served as f64 / st.batches.max(1) as f64
        );
    }

    // 3. quality: fp32 baseline, simulated quantization reference, and
    //    the integer path — the last two must agree.
    let acc_fp = evaluate(
        &manifest,
        &model,
        &dataset.val_images,
        &dataset.val_labels,
        EngineKind::Pjrt,
        &ActMode::Fp,
    )?;
    let act_mode = match &out.act {
        Some(a) => ActMode::Quant {
            bits: a.bits,
            params: model.info.quant_layers.iter().map(|l| a.by_layer[&l.name]).collect(),
        },
        None => ActMode::Fp,
    };
    let acc_sim = evaluate(
        &manifest,
        &out.model,
        &dataset.val_images,
        &dataset.val_labels,
        EngineKind::Native,
        &act_mode,
    )?;
    let acc_i8 = evaluate_int8(&qm, &dataset.val_images, &dataset.val_labels, manifest.batch)?;
    println!("fp32         top1={}% top5={}%", pct(acc_fp.top1), pct(acc_fp.top5));
    println!("sim-quant    top1={}% top5={}%", pct(acc_sim.top1), pct(acc_sim.top5));
    println!("int8-serve   top1={}% top5={}%  (parity with sim-quant expected)", pct(acc_i8.top1), pct(acc_i8.top5));

    // 4. memory story: packed deployment size vs serving-resident panels.
    let (packed_b, fp32_b) = comq::deploy::footprint(&out.packed);
    println!(
        "\nweights: {:.1} KiB fp32 -> {:.1} KiB packed codes on disk, {:.1} KiB i8 panels resident ({} layers served integer, {} grouped, W{})",
        fp32_b as f64 / 1024.0,
        packed_b as f64 / 1024.0,
        qm.resident_bytes() as f64 / 1024.0,
        qm.int8_layers(),
        qm.grouped_layers(),
        qm.weight_bits_label(),
    );

    // 5. everything the runtime recorded along the way, in the exact
    //    text a Prometheus scrape of this process would return (the JSON
    //    twin is `registry().to_json()`).
    println!(
        "\n--- telemetry snapshot (COMQ_OBS={}) ---",
        comq::obs::level().name()
    );
    let snap = comq::obs::registry().snapshot();
    if snap.is_empty() {
        println!("(empty — set COMQ_OBS=on for metrics, =trace for sweep trajectories)");
    } else {
        print!("{}", snap.to_prometheus());
    }
    Ok(())
}
