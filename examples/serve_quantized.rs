//! Serving scenario: quantize once, then serve batched classification
//! requests from the self-contained Rust binary via the PJRT forward
//! artifact — python is nowhere on this path. Reports per-batch latency
//! percentiles and end-to-end throughput for the FP and the 4-bit
//! checkpoints (simulated-quantization inference: same graph, quantized
//! weights fed as inputs).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_quantized [model]
//! ```

use anyhow::Result;

use comq::bench::{pct, time_it};
use comq::calib::{Dataset, EngineKind};
use comq::coordinator::{quantize_model, PipelineOptions};
use comq::eval::{evaluate, ActMode};
use comq::manifest::Manifest;
use comq::model::Model;
use comq::runtime::Engine;
use comq::tensor::Tensor;
use comq::util::{stats, Rng, Timer};

fn main() -> Result<()> {
    let model_name = std::env::args().nth(1).unwrap_or_else(|| "vit_b".into());
    let manifest = Manifest::load("artifacts")?;
    let model = Model::load(&manifest, &model_name)?;
    let dataset = Dataset::load(&manifest)?;

    // 1. offline: quantize (the whole PTQ pass is part of the story —
    //    COMQ's pitch is that this step is seconds, not an hour).
    let t = Timer::start();
    let opts = PipelineOptions {
        engine: EngineKind::Pjrt,
        calib_size: 1024,
        skip_eval: true,
        ..Default::default()
    };
    let (qmodel, report) = quantize_model(&manifest, &model, &dataset, &opts)?;
    println!(
        "quantized {model_name} to 4-bit in {:.2}s (calib {:.2}s + quant {:.2}s)",
        t.secs(),
        report.calib_secs,
        report.quant_secs
    );

    // 2. online: serve batches through the compiled forward executable.
    let engine = Engine::global()?;
    let art = manifest.path(&model.info.artifacts["forward"]);
    let exe = engine.load(&art)?;
    let b = manifest.batch;
    let mut rng = Rng::new(1);
    let make_batch = |rng: &mut Rng| {
        Tensor::new(
            &[b, manifest.img, manifest.img, 3],
            rng.normal_vec(b * manifest.img * manifest.img * 3),
        )
    };

    for (label, m) in [("fp32", &model), ("comq-4bit", &qmodel)] {
        let params = m.params_in_order();
        let batch = make_batch(&mut rng);
        let mut inputs: Vec<&Tensor> = params.clone();
        inputs.push(&batch);
        // latency distribution over 50 request batches
        let mut lat = Vec::new();
        for _ in 0..50 {
            let t = Timer::start();
            let out = engine.run_exe(&exe, &inputs)?;
            std::hint::black_box(&out);
            lat.push(t.secs());
        }
        let throughput = b as f64 / stats::mean(&lat);
        println!(
            "{label:<10} batch={b}: p50={:.2}ms p95={:.2}ms p99={:.2}ms throughput={:.0} img/s",
            stats::quantile(&lat, 0.5) * 1e3,
            stats::quantile(&lat, 0.95) * 1e3,
            stats::quantile(&lat, 0.99) * 1e3,
            throughput
        );
    }

    // 3. quality check on the real val set.
    for (label, m) in [("fp32", &model), ("comq-4bit", &qmodel)] {
        let acc = evaluate(
            &manifest,
            m,
            &dataset.val_images,
            &dataset.val_labels,
            EngineKind::Pjrt,
            &ActMode::Fp,
        )?;
        println!("{label:<10} top1={}% top5={}%", pct(acc.top1), pct(acc.top5));
    }

    // 4. memory story: packed deployment size of the quantized weights.
    let total_w: usize = model.info.quant_layers.iter().map(|l| l.m * l.n).sum();
    println!(
        "\nweights: {:.1} KiB fp32 -> {:.1} KiB packed 4-bit codes (+ {:.2} KiB scales)",
        total_w as f64 * 4.0 / 1024.0,
        total_w as f64 * 0.5 / 1024.0,
        model.info.quant_layers.iter().map(|l| l.n * 8).sum::<usize>() as f64 / 1024.0,
    );
    let _ = time_it(0, 1, || {}); // keep bench API exercised in docs builds
    Ok(())
}
