//! CNN scenario: per-layer vs per-channel PTQ on the CNN family,
//! including the depthwise (grouped-Gram) path of mobilenet_lite —
//! the paper's Tab. 3 / Tab. 4 workloads on our trained stand-ins.
//!
//! ```bash
//! make artifacts && cargo run --release --example quantize_cnn
//! ```

use anyhow::Result;

use comq::calib::{Dataset, EngineKind};
use comq::coordinator::{quantize_model, PipelineOptions};
use comq::manifest::Manifest;
use comq::model::Model;
use comq::quant::grid::Scheme;
use comq::quant::{OrderKind, QuantConfig};

fn main() -> Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let dataset = Dataset::load(&manifest)?;

    for model_name in ["resnet_lite", "cnn_s", "mobilenet_lite"] {
        let model = Model::load(&manifest, model_name)?;
        println!(
            "\n== {model_name} (fp top1 {:.2}%) ==",
            model.info.fp_top1 * 100.0
        );

        // Per-layer quantization (Tab. 3): one shared scale per layer,
        // cyclic (the paper's COMQ†) vs greedy.
        for bits in [4u32, 3] {
            for order in [OrderKind::Cyclic, OrderKind::GreedyPerColumn] {
                let opts = PipelineOptions {
                    engine: EngineKind::Pjrt,
                    calib_size: 1024,
                    qcfg: QuantConfig {
                        bits,
                        scheme: Scheme::PerLayer,
                        order,
                        ..Default::default()
                    },
                    ..Default::default()
                };
                let (_qm, report) = quantize_model(&manifest, &model, &dataset, &opts)?;
                println!("{}", report.summary());
            }
        }

        // Per-channel (Tab. 4), 4/3/2-bit.
        for bits in [4u32, 3, 2] {
            let opts = PipelineOptions {
                engine: EngineKind::Pjrt,
                calib_size: 1024,
                qcfg: QuantConfig {
                    bits,
                    lam: if bits == 2 { 0.8 } else { 1.0 },
                    ..Default::default()
                },
                ..Default::default()
            };
            let (_qm, report) = quantize_model(&manifest, &model, &dataset, &opts)?;
            println!("{}", report.summary());
        }
    }
    Ok(())
}
