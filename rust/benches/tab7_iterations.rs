//! Paper Table 7: accuracy vs COMQ iteration count K (4W32A per-layer).
//! The claim: K = 3–4 is where the coordinate descent converges; more
//! sweeps do not keep helping.

use comq::bench::suite::Suite;
use comq::bench::{pct, Table};
use comq::calib::EngineKind;
use comq::coordinator::{quantize_model, PipelineOptions};
use comq::quant::grid::Scheme;
use comq::quant::{OrderKind, QuantConfig};

const MODELS: &[&str] = &["resnet_lite", "cnn_s"];
const KS: &[usize] = &[1, 2, 3, 4, 5];

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    let mut headers = vec!["Model".to_string()];
    headers.extend(KS.iter().map(|k| format!("K={k}")));
    headers.push("FP".into());
    let mut table = Table::new(
        "Tab.7 — top-1 (%) vs iteration count K (4W32A per-layer COMQ)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    for mname in MODELS {
        let model = suite.model(mname)?;
        let mut row = vec![mname.to_string()];
        for &k in KS {
            let opts = PipelineOptions {
                engine: EngineKind::Pjrt,
                calib_size: 2048,
                qcfg: QuantConfig {
                    bits: 4,
                    scheme: Scheme::PerLayer,
                    order: OrderKind::GreedyPerColumn,
                    iters: k,
                    lam: 1.0,
                },
                ..Default::default()
            };
            let (_qm, rep) = quantize_model(&suite.manifest, &model, &suite.dataset, &opts)?;
            row.push(pct(rep.top1));
        }
        row.push(pct(model.info.fp_top1));
        table.row(row);
    }
    table.print();
    table.save_json("tab7_iterations");
    Ok(())
}
