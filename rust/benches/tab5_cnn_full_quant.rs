//! Paper Table 5: CNNs under full per-channel quantization (W4A4):
//! weights by each method + shared 4-bit activation quantization.

use comq::bench::suite::Suite;
use comq::bench::{pct, Table};
use comq::quant::grid::Scheme;
use comq::quant::OrderKind;

const MODELS: &[&str] = &["resnet_lite", "cnn_s", "mobilenet_lite"];
const METHODS: &[&str] = &["rtn", "adaround-lite", "gpfq", "obq", "comq"];

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    let mut headers = vec!["Method".to_string(), "Bit (W/A)".to_string()];
    headers.extend(MODELS.iter().map(|m| m.to_string()));
    let mut table = Table::new(
        "Tab.5 — CNNs, per-channel full quantization top-1 (%)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    let mut row = vec!["Baseline".into(), "32/32".into()];
    for m in MODELS {
        row.push(pct(suite.manifest.model(m)?.fp_top1));
    }
    table.row(row);

    for method in METHODS {
        let mut row = vec![method.to_string(), "4/4".into()];
        for mname in MODELS {
            let model = suite.model(mname)?;
            let rep = suite.run(
                &model,
                method,
                4,
                Scheme::PerChannel,
                OrderKind::GreedyPerColumn,
                1.0,
                2048,
                Some(4),
            )?;
            row.push(pct(rep.top1));
        }
        table.row(row);
    }
    table.print();
    table.save_json("tab5_cnn_full_quant");
    Ok(())
}
