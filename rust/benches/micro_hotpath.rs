//! Hot-path microbenchmarks (the §Perf instrument):
//!
//!  * COMQ sweep ns/coordinate — residual-domain vs Gram-domain vs
//!    column-major workspace engine at the paper's layer shapes and
//!    calibration sizes (the Gram reformulation removes the batch
//!    dimension from the hot loop; the workspace packing removes the
//!    stride-`n` gathers and per-sweep argsorts from the Gram loop);
//!  * Gram build (XᵀX) throughput;
//!  * threading scaling of the column-parallel sweep (persistent pool);
//!  * PJRT sweep-kernel dispatch overhead vs native.
//!
//! Every table is also collected into `BENCH_micro_hotpath.json` at the
//! repo root (see `bench::Report`) — the machine-readable perf
//! trajectory that EXPERIMENTS.md §Perf quotes.

use comq::bench::{time_budget, Report, Table};
use comq::quant::grid::Scheme;
use comq::quant::{comq_gram, comq_residual, comq_workspace, GramSet, OrderKind, QuantConfig};
use comq::tensor::{matmul, matmul_at_a, Tensor};
use comq::util::simd::Kernel;
use comq::util::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = QuantConfig {
        bits: 4,
        scheme: Scheme::PerChannel,
        order: OrderKind::GreedyPerColumn,
        iters: 3,
        lam: 1.0,
    };
    let mut report = Report::new("micro_hotpath");

    // -- engine comparison across (b, m, n) ------------------------------
    let mut table = Table::new(
        "micro — COMQ engines, ns per coordinate-update (K=3)",
        &["shape (b,m,n)", "kernel", "residual ns/coord", "gram ns/coord", "workspace ns/coord", "ws vs gram"],
    );
    for &(b, m, n) in &[
        (256usize, 48usize, 96usize),
        (1024, 96, 288),
        (4096, 96, 288),
        (4096, 192, 384),
        (16384, 144, 32),
    ] {
        let mut rng = Rng::new(1);
        let x = Tensor::new(&[b, m], rng.normal_vec(b * m));
        let w = Tensor::new(&[m, n], rng.normal_vec(m * n)).scale(0.4);
        let gram = GramSet::Shared(matmul_at_a(&x));
        let coords = (cfg.iters * m * n) as f64;

        let t_res = time_budget(0.5, 20, || {
            std::hint::black_box(comq_residual(&x, &w, &cfg));
        });
        let t_gram = time_budget(0.5, 50, || {
            std::hint::black_box(comq_gram(&gram, &w, &cfg));
        });
        let t_ws = time_budget(0.5, 50, || {
            std::hint::black_box(comq_workspace(&gram, &w, &cfg));
        });
        table.row(vec![
            format!("({b},{m},{n})"),
            Kernel::active().name().to_string(),
            format!("{:.1}", t_res.mean * 1e9 / coords),
            format!("{:.1}", t_gram.mean * 1e9 / coords),
            format!("{:.1}", t_ws.mean * 1e9 / coords),
            format!("{:.2}x", t_gram.mean / t_ws.mean),
        ]);
    }
    table.print();
    table.save_json("micro_engines");
    report.add(&table);

    // -- f32 matmul kernel sweep -----------------------------------------
    // the packed matmul is the calibration + fake-quant workhorse; time
    // it per dispatched kernel via the COMQ_KERNEL override (same knob
    // CI pins), skipping kernels the host lacks
    let mut table = Table::new(
        "micro — f32 packed matmul kernel sweep (forced dispatch)",
        &["shape (m,k,n)", "kernel", "ms", "GFLOP/s"],
    );
    // preserve any caller pin (e.g. `COMQ_KERNEL=scalar cargo bench`) so
    // the tables after this sweep still run on the kernel the user chose
    let pinned = std::env::var("COMQ_KERNEL").ok();
    for &(m, k, n) in &[(256usize, 192usize, 384usize), (512, 768, 768)] {
        let mut rng = Rng::new(5);
        let a = Tensor::new(&[m, k], rng.normal_vec(m * k));
        let b = Tensor::new(&[k, n], rng.normal_vec(k * n));
        // Vnni is skipped: the f32 path has no separate AVX-512 kernel
        // (it shares AVX2/FMA), so its row would duplicate avx2
        for kern in [Kernel::Scalar, Kernel::Avx2] {
            if !kern.supported() {
                println!("[f32 kernel sweep: {} unsupported, skipped]", kern.name());
                continue;
            }
            std::env::set_var("COMQ_KERNEL", kern.name());
            let t = time_budget(0.3, 200, || {
                std::hint::black_box(matmul(&a, &b));
            });
            let flops = 2.0 * m as f64 * k as f64 * n as f64;
            table.row(vec![
                format!("({m},{k},{n})"),
                kern.name().to_string(),
                format!("{:.3}", t.mean * 1e3),
                format!("{:.2}", flops / t.mean / 1e9),
            ]);
        }
    }
    match &pinned {
        Some(v) => std::env::set_var("COMQ_KERNEL", v),
        None => std::env::remove_var("COMQ_KERNEL"),
    }
    table.print();
    table.save_json("micro_f32_kernels");
    report.add(&table);

    // -- grouped depthwise i8 kernel sweep -------------------------------
    // the per-lane grouped kernel (util::simd::dot_i8_grouped) driven
    // through the serving entry point, dispatch forced per kernel; raw
    // kernel throughput at a 3×3-depthwise shape, epilogue included
    let mut table = Table::new(
        "micro — grouped depthwise i8 kernel sweep (W8A8, forced dispatch)",
        &["shape (rows,kk,c)", "kernel", "ms", "GIOP/s"],
    );
    {
        use comq::serve::{dwconv_i8_fused_with, EpilogueCoeffs, GroupedQuantizedActs};
        let (rows, kk, c) = (4096usize, 9usize, 256usize);
        let mut rng = Rng::new(6);
        let x3 = Tensor::new(&[rows, c, kk], rng.normal_vec(rows * c * kk));
        let aq = comq::quant::actq::ActQuant::from_range(x3.min(), x3.max(), 8, 1.0);
        let acts = GroupedQuantizedActs::quantize(&x3, aq);
        let s: Vec<i8> = (0..kk * c).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
        let panel = comq::serve::gemm::pack_panel_k4(&s, kk, c);
        let co = EpilogueCoeffs {
            scale: vec![1e-3; c],
            zc: vec![128.0; c],
            fixed: vec![0.0; c],
            bias: vec![0.0; c],
        };
        let mut out = vec![0.0f32; rows * c];
        for kern in Kernel::ALL {
            if !kern.supported() {
                println!("[grouped kernel sweep: {} unsupported, skipped]", kern.name());
                continue;
            }
            let t = time_budget(0.3, 200, || {
                dwconv_i8_fused_with(kern, &acts, &panel, c, 8, &co, &mut out);
                std::hint::black_box(&mut out);
            });
            let ops = 2.0 * rows as f64 * kk as f64 * c as f64;
            table.row(vec![
                format!("({rows},{kk},{c})"),
                kern.name().to_string(),
                format!("{:.3}", t.mean * 1e3),
                format!("{:.2}", ops / t.mean / 1e9),
            ]);
        }
    }
    table.print();
    table.save_json("micro_grouped_kernels");
    report.add(&table);

    // -- Gram build throughput -------------------------------------------
    let mut table = Table::new(
        "micro — calibration Gram build G = XᵀX",
        &["shape (b,m)", "ms", "GFLOP/s"],
    );
    for &(b, m) in &[(2048usize, 96usize), (8192, 144), (16384, 288), (65536, 144)] {
        let mut rng = Rng::new(2);
        let x = Tensor::new(&[b, m], rng.normal_vec(b * m));
        let t = time_budget(0.5, 30, || {
            std::hint::black_box(matmul_at_a(&x));
        });
        let flops = b as f64 * m as f64 * m as f64; // symmetric: ~b·m²
        table.row(vec![
            format!("({b},{m})"),
            format!("{:.2}", t.mean * 1e3),
            format!("{:.2}", flops / t.mean / 1e9),
        ]);
    }
    table.print();
    table.save_json("micro_gram");
    report.add(&table);

    // -- thread scaling (production workspace engine) ----------------------
    let mut table = Table::new(
        "micro — workspace sweep thread scaling (m=192, n=384)",
        &["threads", "ms/quantize", "speedup"],
    );
    {
        let (b, m, n) = (4096usize, 192usize, 384usize);
        let mut rng = Rng::new(3);
        let x = Tensor::new(&[b, m], rng.normal_vec(b * m));
        let w = Tensor::new(&[m, n], rng.normal_vec(m * n)).scale(0.4);
        let gram = GramSet::Shared(matmul_at_a(&x));
        let mut base = 0.0;
        for threads in [1usize, 2, 4, 8] {
            std::env::set_var("COMQ_THREADS", threads.to_string());
            let t = time_budget(0.5, 50, || {
                std::hint::black_box(comq_workspace(&gram, &w, &cfg));
            });
            if threads == 1 {
                base = t.mean;
            }
            table.row(vec![
                threads.to_string(),
                format!("{:.2}", t.mean * 1e3),
                format!("{:.2}x", base / t.mean),
            ]);
        }
        std::env::remove_var("COMQ_THREADS");
    }
    table.print();
    table.save_json("micro_threads");
    report.add(&table);

    // -- scheduler: work-stealing vs chunked fork-join ---------------------
    // An imbalanced task set — a cluster of heavy tasks at the front,
    // the straggler shape chunked fork-join is worst at: the chunk that
    // lands the heavy cluster serializes it while every other worker
    // idles. The work-stealing pool is measured; the fork-join column is
    // the analytic straggler bound of the old chunked partition on the
    // same measured single-thread time (the chunked scheduler no longer
    // exists to measure).
    let mut table = Table::new(
        "micro — scheduler, imbalanced tasks (4 heavy + 252 light), work-stealing vs fork-join bound",
        &["threads", "stealing ms", "speedup", "fork-join bound ms"],
    );
    {
        let (m_small, m_big, n_tasks, n_big) = (24usize, 96usize, 256usize, 4usize);
        let mut rng = Rng::new(5);
        let a_small = Tensor::new(&[m_small, m_small], rng.normal_vec(m_small * m_small));
        let a_big = Tensor::new(&[m_big, m_big], rng.normal_vec(m_big * m_big));
        // one task = one m×m GEMM; tasks 0..4 are 4× the dimension
        // (~64× the flops) of the rest
        let work = |i: usize| {
            let a = if i < n_big { &a_big } else { &a_small };
            std::hint::black_box(matmul(a, a));
        };
        // flop-weighted units for the analytic bound: heavy = 64 light
        let heavy_units = 64usize;
        let total_units = n_big * heavy_units + (n_tasks - n_big);
        let mut t1 = 0.0;
        for threads in [1usize, 2, 4, 8] {
            std::env::set_var("COMQ_THREADS", threads.to_string());
            let t = time_budget(0.5, 20, || {
                comq::util::pool::parallel_ranges(n_tasks, 1, |_, r| {
                    for i in r {
                        work(i);
                    }
                });
            });
            if threads == 1 {
                t1 = t.mean;
            }
            // chunked fork-join: chunk = ceil(n/threads); the first
            // chunk holds the heavy cluster and bounds the whole join
            let chunk = n_tasks.div_ceil(threads);
            let heavy_in_first = n_big.min(chunk);
            let straggler =
                heavy_in_first * heavy_units + (chunk - heavy_in_first);
            let bound = t1 * (straggler.max(chunk) as f64) / (total_units as f64);
            table.row(vec![
                threads.to_string(),
                format!("{:.2}", t.mean * 1e3),
                format!("{:.2}x", t1 / t.mean),
                format!("{:.2}", bound * 1e3),
            ]);
        }
        std::env::remove_var("COMQ_THREADS");
    }
    table.print();
    table.save_json("micro_scheduler");
    report.add(&table);

    // -- PJRT kernel dispatch vs native ------------------------------------
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if root.join("manifest.json").exists() {
        let manifest = comq::manifest::Manifest::load(&root)?;
        if let Some(sw) = manifest.sweeps.iter().find(|s| s.per_channel && s.m >= 96) {
            let mut table = Table::new(
                &format!("micro — COMQ full quantize, native vs PJRT Pallas kernel (m={}, n={})", sw.m, sw.n),
                &["engine", "ms/layer"],
            );
            let mut rng = Rng::new(4);
            let x = Tensor::new(&[1024, sw.m], rng.normal_vec(1024 * sw.m));
            let w = Tensor::new(&[sw.m, sw.n], rng.normal_vec(sw.m * sw.n)).scale(0.4);
            let gram = GramSet::Shared(matmul_at_a(&x));
            let t_gram = time_budget(0.5, 50, || {
                std::hint::black_box(comq_gram(&gram, &w, &cfg));
            });
            let t_ws = time_budget(0.5, 50, || {
                std::hint::black_box(comq_workspace(&gram, &w, &cfg));
            });
            let t_pjrt = time_budget(1.0, 20, || {
                std::hint::black_box(
                    comq::coordinator::pjrt_kernel::comq_pjrt(&manifest, &gram, &w, &cfg).unwrap(),
                );
            });
            table.row(vec!["native (gram)".into(), format!("{:.2}", t_gram.mean * 1e3)]);
            table.row(vec!["native (workspace)".into(), format!("{:.2}", t_ws.mean * 1e3)]);
            table.row(vec!["pjrt-kernel".into(), format!("{:.2}", t_pjrt.mean * 1e3)]);
            table.print();
            table.save_json("micro_pjrt_kernel");
            report.add(&table);
        }
    }

    report.write_repo_root()?;
    Ok(())
}
