//! Serving-path latency (the §Serving instrument):
//!
//!  * i8 GEMM + fused dequant vs the f32 native matmul at serving layer
//!    shapes, batch 1 (memory-bound — the panel is ¼ the bytes of f32 B)
//!    and batch 32 (compute-bound);
//!  * depthwise conv: the f32 per-channel loop vs the grouped i8 kernel
//!    (`GroupedPanel::conv_i8`), plus grouped rows in the forced-dispatch
//!    kernel sweep;
//!  * end-to-end model latency percentiles: fp32 native forward vs the
//!    integer runtime, batch 1 and batch N — on the plain CNN and on the
//!    depthwise `tiny_mobile` model (all layers integer, 3 grouped);
//!  * the micro-batcher serving N concurrent single requests vs N
//!    sequential batch-1 forwards.
//!
//! All tables land in `BENCH_serve_latency.json` at the repo root (see
//! `bench::Report`) — quoted by EXPERIMENTS.md §Serving. Runs entirely
//! on the synthetic model; no AOT artifacts needed.

use std::sync::Arc;
use std::time::Duration;

use comq::bench::{time_budget, Report, Table};
use comq::deploy::PackedLayer;
use comq::model::Tap;
use comq::proptest::{quantize_all_layers, tiny_mobile_cnn, tiny_plain_cnn};
use comq::quant::actq::ActQuant;
use comq::quant::grid::LayerQuant;
use comq::serve::{ActSource, BatchConfig, GroupedPanel, Int8Panel, Kernel, QuantizedModel, Server};
use comq::tensor::{matmul, Tensor};
use comq::util::topo::{self, NumaMode};
use comq::util::{stats, Rng, Timer};

/// f32 reference depthwise conv over grouped patches [rows, c, kk] —
/// the loop `model::dwconv2d` runs on the fallback path.
fn dwconv_f32(x3: &Tensor, w: &Tensor, bias: &[f32]) -> Tensor {
    let (rows, c, kk) = (x3.shape()[0], x3.shape()[1], x3.shape()[2]);
    let mut out = Tensor::zeros(&[rows, c]);
    for r in 0..rows {
        let xr = &x3.data()[r * c * kk..(r + 1) * c * kk];
        let orow = &mut out.data_mut()[r * c..(r + 1) * c];
        for ch in 0..c {
            let xc = &xr[ch * kk..(ch + 1) * kk];
            let mut s = 0.0f32;
            for p in 0..kk {
                s += xc[p] * w.at2(p, ch);
            }
            orow[ch] = s + bias[ch];
        }
    }
    out
}

fn random_packed(rng: &mut Rng, m: usize, n: usize, bits: u32) -> PackedLayer {
    let levels = (1u64 << bits) as usize;
    let zero = vec![-((1i64 << (bits - 1)) as f32); n];
    let delta: Vec<f32> = (0..n).map(|_| rng.range_f32(0.005, 0.05)).collect();
    let mut q = Tensor::zeros(&[m, n]);
    for idx in 0..m * n {
        q.data_mut()[idx] = zero[idx % n] + rng.below(levels) as f32;
    }
    PackedLayer::from_quant("bench", &LayerQuant { q, delta, zero }, bits)
}

fn main() -> anyhow::Result<()> {
    let mut report = Report::new("serve_latency");

    // -- i8 GEMM vs f32 matmul at serving shapes -------------------------
    let mut table = Table::new(
        "serve — layer GEMM, f32 native vs i8 fused-dequant",
        &["shape (m,n)", "batch", "kernel", "f32 ms", "int8 ms", "speedup", "B bytes f32", "B bytes i8"],
    );
    for &(m, n) in &[(192usize, 384usize), (768, 768), (768, 3072), (3072, 768)] {
        let mut rng = Rng::new(1);
        let pl = random_packed(&mut rng, m, n, 8);
        let panel = Int8Panel::from_packed(&pl)?;
        let w = pl.dequant();
        let bias = vec![0.0f32; n];
        for &rows in &[1usize, 32] {
            let x = Tensor::new(&[rows, m], rng.normal_vec(rows * m));
            let aq = ActQuant::from_range(x.min(), x.max(), 8, 1.0);
            let t_f32 = time_budget(0.3, 400, || {
                std::hint::black_box(matmul(&x, &w));
            });
            let t_i8 = time_budget(0.3, 400, || {
                std::hint::black_box(panel.matmul_i8(&x, aq, Some(&bias)));
            });
            table.row(vec![
                format!("({m},{n})"),
                rows.to_string(),
                Kernel::active().name().to_string(),
                format!("{:.3}", t_f32.mean * 1e3),
                format!("{:.3}", t_i8.mean * 1e3),
                format!("{:.2}x", t_f32.mean / t_i8.mean),
                (4 * m * n).to_string(),
                panel.resident_bytes().to_string(),
            ]);
        }
    }
    table.print();
    table.save_json("serve_gemm");
    report.add(&table);

    // -- NUMA: flat panel vs per-node shards ------------------------------
    // The sharded panel is built under a forced 2-node layout and keeps
    // its shards after the override is cleared, so this measures the
    // sharded dispatch itself. On a UMA host the interesting number is
    // the overhead (should be ~1.00x — same strips, same reductions);
    // the cross-socket bandwidth win only exists on a real multi-node
    // machine and is tagged projected in BENCH_serve_latency.json.
    let mut table = Table::new(
        "serve — dense GEMM, flat panel vs forced 2-node shards (nodes=1 vs N)",
        &["shape (m,n)", "batch", "kernel", "flat ms", "sharded ms", "sharded vs flat"],
    );
    for &(m, n) in &[(768usize, 768usize), (768, 3072)] {
        let mut rng = Rng::new(7);
        let pl = random_packed(&mut rng, m, n, 8);
        topo::set_mode_override(Some(NumaMode::Off));
        let flat = Int8Panel::from_packed(&pl)?;
        topo::set_mode_override(Some(NumaMode::Force(2)));
        let sharded = Int8Panel::from_packed(&pl)?;
        topo::set_mode_override(None);
        let bias = vec![0.0f32; n];
        for &rows in &[1usize, 16] {
            let x = Tensor::new(&[rows, m], rng.normal_vec(rows * m));
            let aq = ActQuant::from_range(x.min(), x.max(), 8, 1.0);
            let t_flat = time_budget(0.3, 400, || {
                std::hint::black_box(flat.matmul_i8(&x, aq, Some(&bias)));
            });
            let t_shard = time_budget(0.3, 400, || {
                std::hint::black_box(sharded.matmul_i8(&x, aq, Some(&bias)));
            });
            table.row(vec![
                format!("({m},{n})"),
                rows.to_string(),
                Kernel::active().name().to_string(),
                format!("{:.3}", t_flat.mean * 1e3),
                format!("{:.3}", t_shard.mean * 1e3),
                format!("{:.2}x", t_flat.mean / t_shard.mean),
            ]);
        }
    }
    table.print();
    table.save_json("serve_numa");
    report.add(&table);

    // -- depthwise conv, f32 loop vs grouped i8 kernel -------------------
    // rows = b·oh·ow of a mobile block; c spans a partial-strip and a
    // multi-strip channel count
    let mut table = Table::new(
        "serve — depthwise conv, f32 loop vs grouped i8 fused-dequant",
        &["shape (kk,c)", "rows", "kernel", "f32 ms", "int8 ms", "speedup", "W bytes f32", "W bytes i8"],
    );
    for &(kk, c) in &[(9usize, 64usize), (9, 256)] {
        let mut rng = Rng::new(3);
        let pl = random_packed(&mut rng, kk, c, 8);
        let panel = GroupedPanel::from_packed(&pl)?;
        let w = pl.dequant();
        let bias = vec![0.0f32; c];
        for &rows in &[196usize, 6272] {
            let x3 = Tensor::new(&[rows, c, kk], rng.normal_vec(rows * c * kk));
            let aq = ActQuant::from_range(x3.min(), x3.max(), 8, 1.0);
            let t_f32 = time_budget(0.3, 400, || {
                std::hint::black_box(dwconv_f32(&x3, &w, &bias));
            });
            let t_i8 = time_budget(0.3, 400, || {
                std::hint::black_box(panel.conv_i8(&x3, aq, Some(&bias)));
            });
            table.row(vec![
                format!("({kk},{c})"),
                rows.to_string(),
                Kernel::active().name().to_string(),
                format!("{:.3}", t_f32.mean * 1e3),
                format!("{:.3}", t_i8.mean * 1e3),
                format!("{:.2}x", t_f32.mean / t_i8.mean),
                (4 * kk * c).to_string(),
                panel.resident_bytes().to_string(),
            ]);
        }
    }
    table.print();
    table.save_json("serve_dwconv");
    report.add(&table);

    // -- i8 GEMM per-kernel sweep ----------------------------------------
    // dispatch forced through the COMQ_KERNEL override (the same knob
    // CI pins); unsupported kernels are reported and skipped
    let mut table = Table::new(
        "serve — i8 GEMM kernel sweep (W8A8, forced dispatch)",
        &["shape (m,n)", "batch", "kernel", "int8 ms", "GIOP/s"],
    );
    // preserve any caller pin (e.g. `COMQ_KERNEL=scalar cargo bench`) so
    // the end-to-end tables below still run on the kernel the user chose
    let pinned = std::env::var("COMQ_KERNEL").ok();
    for &(m, n) in &[(768usize, 768usize), (768, 3072)] {
        let mut rng = Rng::new(2);
        let pl = random_packed(&mut rng, m, n, 8);
        let panel = Int8Panel::from_packed(&pl)?;
        let bias = vec![0.0f32; n];
        for &rows in &[1usize, 32] {
            let x = Tensor::new(&[rows, m], rng.normal_vec(rows * m));
            let aq = ActQuant::from_range(x.min(), x.max(), 8, 1.0);
            for kern in Kernel::ALL {
                if !kern.supported() {
                    println!("[kernel sweep: {} unsupported on this host, skipped]", kern.name());
                    continue;
                }
                std::env::set_var("COMQ_KERNEL", kern.name());
                let t = time_budget(0.3, 400, || {
                    std::hint::black_box(panel.matmul_i8(&x, aq, Some(&bias)));
                });
                let ops = 2.0 * rows as f64 * m as f64 * n as f64;
                table.row(vec![
                    format!("({m},{n})"),
                    rows.to_string(),
                    kern.name().to_string(),
                    format!("{:.3}", t.mean * 1e3),
                    format!("{:.2}", ops / t.mean / 1e9),
                ]);
            }
        }
    }
    // grouped depthwise rows under the same forced dispatch: "batch" is
    // the grouped row count, ops = 2·rows·kk·c
    for &(kk, c) in &[(9usize, 256usize)] {
        let mut rng = Rng::new(4);
        let pl = random_packed(&mut rng, kk, c, 8);
        let panel = GroupedPanel::from_packed(&pl)?;
        let bias = vec![0.0f32; c];
        for &rows in &[196usize, 6272] {
            let x3 = Tensor::new(&[rows, c, kk], rng.normal_vec(rows * c * kk));
            let aq = ActQuant::from_range(x3.min(), x3.max(), 8, 1.0);
            for kern in Kernel::ALL {
                if !kern.supported() {
                    println!("[kernel sweep: {} unsupported on this host, skipped]", kern.name());
                    continue;
                }
                std::env::set_var("COMQ_KERNEL", kern.name());
                let t = time_budget(0.3, 400, || {
                    std::hint::black_box(panel.conv_i8(&x3, aq, Some(&bias)));
                });
                let ops = 2.0 * rows as f64 * kk as f64 * c as f64;
                table.row(vec![
                    format!("(dw {kk},{c})"),
                    rows.to_string(),
                    kern.name().to_string(),
                    format!("{:.3}", t.mean * 1e3),
                    format!("{:.2}", ops / t.mean / 1e9),
                ]);
            }
        }
    }
    match &pinned {
        Some(v) => std::env::set_var("COMQ_KERNEL", v),
        None => std::env::remove_var("COMQ_KERNEL"),
    }
    table.print();
    table.save_json("serve_kernels");
    report.add(&table);

    // -- end-to-end model latency percentiles ----------------------------
    let (manifest, model) = tiny_plain_cnn(7);
    let mut rng = Rng::new(8);
    let calib = Tensor::new(&[64, 8, 8, 3], rng.normal_vec(64 * 8 * 8 * 3));
    // same fixture the parity tests assert on (proptest::quantize_all_layers)
    let (packed, act, qmodel) = quantize_all_layers(&manifest, &model, 4, 8, &calib)?;
    let qm = Arc::new(QuantizedModel::from_parts(
        model.info.clone(),
        qmodel.params.clone(),
        &packed,
        ActSource::Static { bits: act.bits, by_layer: act.by_layer },
    )?);

    let mut table = Table::new(
        "serve — end-to-end forward latency (tiny_plain, W4A8)",
        &["path", "batch", "kernel", "p50 ms", "p95 ms", "p99 ms", "img/s"],
    );
    let percentile_row =
        |table: &mut Table, label: &str, batch: usize, lat: &[f64]| {
            // sort once; every percentile reads the same sorted copy
            let mut s = lat.to_vec();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            table.row(vec![
                label.to_string(),
                batch.to_string(),
                Kernel::active().name().to_string(),
                format!("{:.3}", stats::quantile_sorted(&s, 0.5) * 1e3),
                format!("{:.3}", stats::quantile_sorted(&s, 0.95) * 1e3),
                format!("{:.3}", stats::quantile_sorted(&s, 0.99) * 1e3),
                format!("{:.0}", batch as f64 / stats::mean(&s)),
            ]);
        };
    for &batch in &[1usize, 16] {
        let x = Tensor::new(&[batch, 8, 8, 3], rng.normal_vec(batch * 8 * 8 * 3));
        let mut lat_fp = Vec::new();
        let mut lat_i8 = Vec::new();
        for _ in 0..100 {
            let t = Timer::start();
            std::hint::black_box(model.forward(&x, &mut Tap::None));
            lat_fp.push(t.secs());
            let t = Timer::start();
            std::hint::black_box(qm.forward(&x));
            lat_i8.push(t.secs());
        }
        percentile_row(&mut table, "fp32-native", batch, &lat_fp);
        percentile_row(&mut table, "int8-serve", batch, &lat_i8);

        // the same forward with COMQ_TRACE=all and a traced batch pinned
        // on this thread (as the batcher pins it): every layer exec is
        // timed and recorded as a span, so this row is the per-layer
        // trace-recording overhead against the int8-serve baseline
        {
            use comq::obs::trace::{self, TraceMode};
            trace::set_mode(TraceMode::All);
            let mut lat_tr = Vec::new();
            for i in 0..100u64 {
                trace::set_batch(&[i + 1]);
                let t = Timer::start();
                std::hint::black_box(qm.forward(&x));
                lat_tr.push(t.secs());
            }
            trace::clear_batch();
            trace::set_mode(TraceMode::Off);
            trace::reset();
            percentile_row(&mut table, "int8-serve traced", batch, &lat_tr);
        }
    }

    // micro-batcher: 16 concurrent singles per wave, coalesced by the queue
    {
        let server = Arc::new(Server::start(
            qm.clone(),
            BatchConfig {
                max_batch: 16,
                max_delay: Duration::from_millis(1),
                executors: 1,
                pipeline: false,
            },
        ));
        let mut lat = Vec::new();
        for wave in 0..50 {
            let imgs: Vec<Vec<f32>> =
                (0..16).map(|_| rng.normal_vec(8 * 8 * 3)).collect();
            let t = Timer::start();
            let rxs: Vec<_> = imgs.into_iter().map(|im| server.submit(im)).collect();
            for rx in rxs {
                rx.recv().expect("server reply").expect("served");
            }
            if wave >= 5 {
                lat.push(t.secs()); // whole-wave latency, 16 requests
            }
        }
        percentile_row(&mut table, "int8 micro-batched (16 concurrent)", 16, &lat);
        let st = server.stats();
        println!(
            "micro-batcher: {} requests in {} batches (mean batch {:.1})",
            st.served,
            st.batches,
            st.served as f64 / st.batches.max(1) as f64
        );
        // per-stage breakdown from the runtime's own telemetry (the same
        // histograms `comq::obs::registry()` exports) — where each request
        // actually spent its time, not just the wave total measured above
        if let Some(obs) = server.obs() {
            let mut stages = Table::new(
                "serve — micro-batcher stage breakdown (runtime telemetry, per request)",
                &["stage", "count", "p50 us", "p95 us", "p99 us", "mean us"],
            );
            for stage in comq::obs::span::STAGES {
                let s = obs.spans.hist(stage).snapshot();
                stages.row(vec![
                    stage.name().to_string(),
                    s.count.to_string(),
                    format!("{:.1}", s.p50() as f64 / 1e3),
                    format!("{:.1}", s.p95() as f64 / 1e3),
                    format!("{:.1}", s.p99() as f64 / 1e3),
                    format!("{:.1}", s.mean() / 1e3),
                ]);
            }
            stages.print();
            stages.save_json("serve_stages");
            report.add(&stages);
            let bs = obs.batch_size.snapshot();
            println!(
                "batch size p50={} p95={} (deadline misses {}, queue depth now {})",
                bs.p50(),
                bs.p95(),
                obs.deadline_miss.get(),
                obs.queue_depth.get()
            );
        } else {
            println!("[COMQ_OBS=off: no runtime stage telemetry]");
        }
    }
    table.print();
    table.save_json("serve_e2e");
    report.add(&table);

    // -- end-to-end, depthwise model -------------------------------------
    // the grouped path's model-level instrument: every layer (3 of them
    // depthwise) serves integer, no f32 weights anywhere
    let (manifest_m, model_m) = tiny_mobile_cnn(9);
    let mut rng = Rng::new(10);
    let calib = Tensor::new(&[64, 8, 8, 3], rng.normal_vec(64 * 8 * 8 * 3));
    let (packed_m, act_m, qmodel_m) = quantize_all_layers(&manifest_m, &model_m, 4, 8, &calib)?;
    let qm_m = Arc::new(QuantizedModel::from_parts(
        model_m.info.clone(),
        qmodel_m.params.clone(),
        &packed_m,
        ActSource::Static { bits: act_m.bits, by_layer: act_m.by_layer },
    )?);
    assert_eq!(qm_m.grouped_layers(), 3);
    let mut table = Table::new(
        "serve — end-to-end forward latency (tiny_mobile depthwise, W4A8)",
        &["path", "batch", "kernel", "p50 ms", "p95 ms", "p99 ms", "img/s"],
    );
    for &batch in &[1usize, 16] {
        let x = Tensor::new(&[batch, 8, 8, 3], rng.normal_vec(batch * 8 * 8 * 3));
        let mut lat_fp = Vec::new();
        let mut lat_i8 = Vec::new();
        for _ in 0..100 {
            let t = Timer::start();
            std::hint::black_box(model_m.forward(&x, &mut Tap::None));
            lat_fp.push(t.secs());
            let t = Timer::start();
            std::hint::black_box(qm_m.forward(&x));
            lat_i8.push(t.secs());
        }
        percentile_row(&mut table, "fp32-native", batch, &lat_fp);
        percentile_row(&mut table, "int8-serve", batch, &lat_i8);
    }
    table.print();
    table.save_json("serve_e2e_mobile");
    report.add(&table);

    report.write_repo_root()?;
    Ok(())
}
