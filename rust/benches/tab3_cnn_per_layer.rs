//! Paper Table 3: CNNs with *per-layer* weight-only uniform quantization
//! at 4/3 bits. "Ours†" is cyclic COMQ, "Ours" greedy COMQ, compared to
//! the calibration-free (rtn) and Hessian-based (obq) baselines standing
//! in for Bit-split/AdaQuant.

use comq::bench::suite::Suite;
use comq::bench::{pct, Table};
use comq::quant::grid::Scheme;
use comq::quant::OrderKind;

const MODELS: &[&str] = &["resnet_lite", "cnn_s", "mobilenet_lite"];

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    let mut headers = vec!["Method".to_string(), "WBit".to_string()];
    headers.extend(MODELS.iter().map(|m| m.to_string()));
    let mut table = Table::new(
        "Tab.3 — CNNs, per-layer weight-only top-1 (%)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    let mut row = vec!["Baseline".into(), "32".into()];
    for m in MODELS {
        row.push(pct(suite.manifest.model(m)?.fp_top1));
    }
    table.row(row);

    for bits in [4u32, 3] {
        for (label, method, order) in [
            ("rtn", "rtn", OrderKind::Cyclic),
            ("bitsplit", "bitsplit", OrderKind::Cyclic),
            ("obq", "obq", OrderKind::Cyclic),
            ("Ours† (cyclic)", "comq", OrderKind::Cyclic),
            ("Ours (greedy)", "comq", OrderKind::GreedyPerColumn),
        ] {
            let mut row = vec![label.to_string(), bits.to_string()];
            for mname in MODELS {
                let model = suite.model(mname)?;
                let rep = suite.run(
                    &model,
                    method,
                    bits,
                    Scheme::PerLayer,
                    order,
                    1.0,
                    2048,
                    None,
                )?;
                row.push(pct(rep.top1));
            }
            table.row(row);
        }
    }
    table.print();
    table.save_json("tab3_cnn_per_layer");
    Ok(())
}
