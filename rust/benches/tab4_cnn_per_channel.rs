//! Paper Table 4: CNNs with *per-channel* weight-only quantization at
//! 4/3/2 bits — COMQ vs the full baseline set (stand-ins for Bit-split /
//! AdaRound / FlexRound / BRECQ / OBQ).

use comq::bench::suite::Suite;
use comq::bench::{pct, Table};
use comq::quant::grid::Scheme;
use comq::quant::OrderKind;

const MODELS: &[&str] = &["resnet_lite", "cnn_s", "mobilenet_lite"];
const METHODS: &[&str] = &["rtn", "bitsplit", "adaround-lite", "gpfq", "obq", "comq"];

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    let mut headers = vec!["Method".to_string(), "Bit (W/A)".to_string()];
    headers.extend(MODELS.iter().map(|m| m.to_string()));
    let mut table = Table::new(
        "Tab.4 — CNNs, per-channel weight-only top-1 (%)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    let mut row = vec!["Baseline".into(), "32/32".into()];
    for m in MODELS {
        row.push(pct(suite.manifest.model(m)?.fp_top1));
    }
    table.row(row);

    for bits in [4u32, 3, 2] {
        for method in METHODS {
            let mut row = vec![method.to_string(), format!("{bits}/32")];
            for mname in MODELS {
                let model = suite.model(mname)?;
                let rep = suite.run(
                    &model,
                    method,
                    bits,
                    Scheme::PerChannel,
                    OrderKind::GreedyPerColumn,
                    Suite::default_lam(bits),
                    2048,
                    None,
                )?;
                row.push(pct(rep.top1));
            }
            table.row(row);
        }
    }
    table.print();
    table.save_json("tab4_cnn_per_channel");
    Ok(())
}
