//! Open-loop load generator for the TCP serving tier (the §Robustness
//! instrument): Poisson arrivals — exponential inter-arrival gaps,
//! `-ln(u)/λ` — offered at a ramp of rates against a loopback
//! [`NetServer`], with a per-request deadline budget so overload turns
//! into *typed sheds* instead of an unbounded queue.
//!
//! Open-loop matters: a closed-loop client (send, wait, send) slows
//! its own arrival rate exactly when the server struggles, hiding the
//! latency cliff. Here arrivals keep coming on schedule whatever the
//! server does — the protocol is pipelined, replies are matched to
//! send timestamps by request id — so the p99/p999 columns show the
//! real queueing behavior and the shed column shows admission control
//! doing its job.
//!
//! Reported per offered rate: achieved QPS, p50/p99/p999 latency, shed
//! rate; plus the max sustainable QPS (highest offered rate with under
//! 1% shed). Lands in `BENCH_serve_loadgen.json` at the repo root —
//! quoted by EXPERIMENTS.md §Robustness. Synthetic model; no AOT
//! artifacts needed.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use comq::bench::{Report, Table};
use comq::proptest::{quantize_all_layers, tiny_plain_cnn};
use comq::serve::net::{ClientError, NetClient, NetConfig, NetServer, Response};
use comq::serve::{ActSource, BatchConfig, QuantizedModel};
use comq::tensor::Tensor;
use comq::util::{stats, Rng};

const MODEL: &str = "tiny_plain";
const ELEMS: usize = 8 * 8 * 3;
/// Per-request latency budget: past this the server sheds instead of
/// queueing work it will miss anyway.
const BUDGET: Duration = Duration::from_millis(25);

struct LevelResult {
    offered_qps: f64,
    requests: usize,
    achieved_qps: f64,
    /// Latencies of served requests, seconds, sorted.
    lat: Vec<f64>,
    shed: usize,
    /// Requests unanswered when the wall-clock guard tripped (should
    /// stay 0 — every admitted request is answered, sheds included).
    lost: usize,
}

/// One offered-rate level: a single pipelined connection, sends paced
/// by the Poisson schedule, replies drained between arrivals with a
/// read timeout sized to the gap (open-loop: a slow server never slows
/// the schedule).
fn run_level(
    addr: std::net::SocketAddr,
    qps: f64,
    n_req: usize,
    img: &[f32],
    rng: &mut Rng,
) -> anyhow::Result<LevelResult> {
    let mut c = NetClient::connect(addr).map_err(|e| anyhow::anyhow!("connect: {e}"))?;
    let mut pending: HashMap<u32, Instant> = HashMap::new();
    let mut lat: Vec<f64> = Vec::with_capacity(n_req);
    let mut shed = 0usize;
    let start = Instant::now();
    // everything should resolve within the offered span plus the drain
    let wall = start + Duration::from_secs_f64(n_req as f64 / qps) + Duration::from_secs(5);
    let mut next = Instant::now();
    let mut sent = 0usize;
    let mut last_send = start;
    while (sent < n_req || !pending.is_empty()) && Instant::now() < wall {
        let now = Instant::now();
        if sent < n_req && now >= next {
            let id = c
                .send_infer(MODEL, img, Some(BUDGET))
                .map_err(|e| anyhow::anyhow!("send: {e}"))?;
            last_send = Instant::now();
            pending.insert(id, last_send);
            sent += 1;
            // exponential inter-arrival gap: -ln(u)/λ, u ∈ (0, 1]
            let u = rng.range_f32(f32::EPSILON, 1.0) as f64;
            next += Duration::from_secs_f64(-u.ln() / qps);
            continue;
        }
        // drain replies until the next arrival is due (bounded reads so
        // the schedule never slips behind a slow reply)
        let until_next =
            if sent < n_req { next.saturating_duration_since(now) } else { Duration::from_millis(2) };
        let t = until_next.clamp(Duration::from_micros(100), Duration::from_millis(2));
        c.set_read_timeout(Some(t)).map_err(|e| anyhow::anyhow!("timeout: {e}"))?;
        match c.recv() {
            Ok(Response::Logits { request_id, .. }) => {
                if let Some(t0) = pending.remove(&request_id) {
                    lat.push(t0.elapsed().as_secs_f64());
                }
            }
            Ok(Response::Error { request_id, .. }) => {
                // typed shed (DeadlineExceeded / Overloaded): counted,
                // never waited on again
                pending.remove(&request_id);
                shed += 1;
            }
            Ok(_) => {}
            Err(ClientError::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(e) => return Err(anyhow::anyhow!("recv: {e}")),
        }
    }
    let lost = pending.len();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let span = last_send.saturating_duration_since(start).as_secs_f64().max(1e-9);
    Ok(LevelResult {
        offered_qps: qps,
        requests: sent,
        achieved_qps: sent as f64 / span,
        lat,
        shed,
        lost,
    })
}

fn main() -> anyhow::Result<()> {
    let mut report = Report::new("serve_loadgen");

    // the W4A8 synthetic-CNN fixture every serving test and bench drives
    let (manifest, model) = tiny_plain_cnn(7);
    let mut rng = Rng::new(0x10AD);
    let calib = Tensor::new(&[64, 8, 8, 3], rng.normal_vec(64 * ELEMS));
    let (packed, act, qmodel) = quantize_all_layers(&manifest, &model, 4, 8, &calib)?;
    let qm = Arc::new(QuantizedModel::from_parts(
        model.info.clone(),
        qmodel.params.clone(),
        &packed,
        ActSource::Static { bits: act.bits, by_layer: act.by_layer },
    )?);

    let server = NetServer::bind(
        "127.0.0.1:0",
        vec![(MODEL.to_string(), qm)],
        NetConfig {
            batch: BatchConfig {
                max_batch: 32,
                max_delay: Duration::from_millis(1),
                executors: 2,
                pipeline: false,
            },
            ..NetConfig::default()
        },
    )?;
    let addr = server.local_addr();
    let img = rng.normal_vec(ELEMS);

    let mut table = Table::new(
        "serve — open-loop Poisson loadgen over TCP loopback (tiny_plain W4A8, 25 ms budget)",
        &["offered qps", "requests", "achieved qps", "p50 ms", "p99 ms", "p999 ms", "shed %", "lost"],
    );
    let mut max_sustainable = 0.0f64;
    for &qps in &[250.0f64, 500.0, 1000.0, 2000.0, 4000.0, 8000.0] {
        // enough requests for a stable p99 at every level, capped so the
        // whole ramp stays a bench and not a soak test
        let n_req = ((qps * 2.0) as usize).clamp(500, 4000);
        let r = run_level(addr, qps, n_req, &img, &mut rng)?;
        let shed_rate = (r.shed + r.lost) as f64 / r.requests.max(1) as f64;
        if shed_rate < 0.01 && r.lost == 0 {
            max_sustainable = max_sustainable.max(r.achieved_qps);
        }
        let q = |p: f64| {
            if r.lat.is_empty() { f64::NAN } else { stats::quantile_sorted(&r.lat, p) * 1e3 }
        };
        table.row(vec![
            format!("{:.0}", r.offered_qps),
            r.requests.to_string(),
            format!("{:.0}", r.achieved_qps),
            format!("{:.3}", q(0.5)),
            format!("{:.3}", q(0.99)),
            format!("{:.3}", q(0.999)),
            format!("{:.2}", shed_rate * 100.0),
            r.lost.to_string(),
        ]);
    }
    table.print();
    table.save_json("serve_loadgen");
    report.add(&table);

    // the headline number, as its own table so it survives in the
    // committed BENCH_serve_loadgen.json (Report serializes tables only)
    let mut summary = Table::new("serve — max sustainable QPS", &["criterion", "qps"]);
    summary.row(vec!["shed < 1% and no lost replies".to_string(), format!("{max_sustainable:.0}")]);
    summary.print();
    report.add(&summary);

    // tracing overhead: the same mid-ramp level offered twice — untraced,
    // then with every request traced end to end (client-minted wire
    // contexts, span trees, tail retention) — so EXPERIMENTS.md §Tracing
    // can quote the cost of COMQ_TRACE=all against the off baseline
    {
        use comq::obs::trace::{self, TraceMode};
        let mut overhead = Table::new(
            "serve — tracing overhead at 1000 qps (COMQ_TRACE off vs all)",
            &["trace", "requests", "p50 ms", "p99 ms", "p999 ms", "shed %"],
        );
        for (label, mode) in [("off", TraceMode::Off), ("all", TraceMode::All)] {
            trace::reset();
            trace::set_mode(mode);
            let r = run_level(addr, 1000.0, 2000, &img, &mut rng)?;
            let q = |p: f64| {
                if r.lat.is_empty() { f64::NAN } else { stats::quantile_sorted(&r.lat, p) * 1e3 }
            };
            overhead.row(vec![
                label.to_string(),
                r.requests.to_string(),
                format!("{:.3}", q(0.5)),
                format!("{:.3}", q(0.99)),
                format!("{:.3}", q(0.999)),
                format!("{:.2}", (r.shed + r.lost) as f64 / r.requests.max(1) as f64 * 100.0),
            ]);
        }
        println!(
            "traced level: {} span events buffered, {} traces retained",
            trace::events_buffered(),
            trace::retained().len()
        );
        trace::set_mode(TraceMode::Off);
        trace::reset();
        overhead.print();
        overhead.save_json("serve_loadgen_trace_overhead");
        report.add(&overhead);
    }

    // the tier's own accounting, reconciled against what the client saw
    let st = server.stats();
    let bst = server.model_server(MODEL).expect("model").stats();
    println!(
        "net: {} frames, {} error frames, {} rx bytes, {} tx bytes; batcher: {} served in {} batches, {} deadline-shed, {} overload-shed",
        st.frames, st.error_frames, st.rx_bytes, st.tx_bytes,
        bst.served, bst.batches, bst.shed_deadline, bst.shed_overload
    );
    server.shutdown();

    report.write_repo_root()?;
    Ok(())
}
