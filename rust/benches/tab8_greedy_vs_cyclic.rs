//! Paper Table 8 (appendix): greedy vs cyclic update order across models
//! and bit-widths, per-channel weight-only. The claim: greedy wins
//! everywhere, with the gap growing at lower bits / larger models.

use comq::bench::suite::Suite;
use comq::bench::{pct, Table};
use comq::quant::grid::Scheme;
use comq::quant::OrderKind;

const MODELS: &[&str] = &["resnet_lite", "cnn_s", "vit_s", "deit_s", "swin_t"];

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    let mut headers = vec!["Method".to_string(), "Bits".to_string()];
    headers.extend(MODELS.iter().map(|m| m.to_string()));
    let mut table = Table::new(
        "Tab.8 — cyclic vs greedy COMQ, per-channel weight-only top-1 (%)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    let mut row = vec!["FP".into(), "32".into()];
    for m in MODELS {
        row.push(pct(suite.manifest.model(m)?.fp_top1));
    }
    table.row(row);

    for bits in [4u32, 3, 2] {
        for (label, order) in [
            ("Cyclic", OrderKind::Cyclic),
            ("Greedy", OrderKind::GreedyPerColumn),
        ] {
            let mut row = vec![label.to_string(), bits.to_string()];
            for mname in MODELS {
                let model = suite.model(mname)?;
                let rep = suite.run(
                    &model,
                    "comq",
                    bits,
                    Scheme::PerChannel,
                    order,
                    Suite::default_lam(bits),
                    1024,
                    None,
                )?;
                row.push(pct(rep.top1));
            }
            table.row(row);
        }
    }
    table.print();
    table.save_json("tab8_greedy_vs_cyclic");
    Ok(())
}
