//! Paper Table 2: ViTs under *full* per-channel quantization — weights
//! by each method plus 4-bit activations (shared activation quantizer,
//! calibrated min/max with RepQ-style toward-zero clipping), W4A4 and
//! W2A4 rows.

use comq::bench::suite::Suite;
use comq::bench::{pct, Table};
use comq::quant::grid::Scheme;
use comq::quant::OrderKind;

const MODELS: &[&str] = &["vit_s", "vit_b", "deit_s", "swin_s"];
const METHODS: &[&str] = &["rtn", "gpfq", "obq", "comq"];

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    let mut headers = vec!["Method".to_string(), "Bit (W/A)".to_string()];
    headers.extend(MODELS.iter().map(|m| m.to_string()));
    let mut table = Table::new(
        "Tab.2 — ViTs, per-channel full quantization top-1 (%)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    let mut row = vec!["Baseline".into(), "32/32".into()];
    for m in MODELS {
        row.push(pct(suite.manifest.model(m)?.fp_top1));
    }
    table.row(row);

    for (wbits, abits) in [(4u32, 4u32), (2, 4)] {
        for method in METHODS {
            // the paper's W2A4 row is "Ours" only
            if wbits == 2 && *method != "comq" {
                continue;
            }
            let mut row = vec![method.to_string(), format!("{wbits}/{abits}")];
            for mname in MODELS {
                let model = suite.model(mname)?;
                let rep = suite.run(
                    &model,
                    method,
                    wbits,
                    Scheme::PerChannel,
                    OrderKind::GreedyPerColumn,
                    Suite::default_lam(wbits),
                    1024,
                    Some(abits),
                )?;
                row.push(pct(rep.top1));
            }
            table.row(row);
        }
    }
    table.print();
    table.save_json("tab2_vit_full_quant");
    Ok(())
}
