//! Ablation: real vs data-free (Gaussian) calibration.
//!
//! DFQ/ZeroQ (paper Sec. 2.1) motivate data-free PTQ; COMQ assumes a
//! small real calibration set. This ablation quantifies what the real
//! data buys: Gram statistics from moment-matched Gaussian noise vs the
//! genuine calibration split, COMQ per-channel at 4/3/2 bits.

use std::collections::BTreeMap;

use comq::bench::suite::Suite;
use comq::bench::{pct, Table};
use comq::calib::{collect_stats, EngineKind};
use comq::coordinator::{quantize_model_with_stats, PipelineOptions};
use comq::model::LayerStats;
use comq::quant::QuantConfig;

const MODELS: &[&str] = &["vit_s", "resnet_lite"];

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    let mut table = Table::new(
        "ablation — real vs Gaussian (data-free) calibration, COMQ per-channel top-1 (%)",
        &["model", "bits", "real calib", "gaussian calib", "gap"],
    );
    for mname in MODELS {
        let model = suite.model(mname)?;
        let real_imgs = suite.dataset.calib_subset(1024);
        let noise_imgs = suite.dataset.gaussian_calib(1024, 0xDF);
        let real: BTreeMap<String, LayerStats> =
            collect_stats(&suite.manifest, &model, &real_imgs, EngineKind::Pjrt)?;
        let noise: BTreeMap<String, LayerStats> =
            collect_stats(&suite.manifest, &model, &noise_imgs, EngineKind::Pjrt)?;
        for bits in [4u32, 3, 2] {
            let opts = PipelineOptions {
                engine: EngineKind::Pjrt,
                calib_size: 1024,
                qcfg: QuantConfig {
                    bits,
                    lam: Suite::default_lam(bits),
                    ..Default::default()
                },
                ..Default::default()
            };
            let (_m1, r_real) = quantize_model_with_stats(
                &suite.manifest, &model, &suite.dataset, &opts, &real, 0.0,
            )?;
            let (_m2, r_noise) = quantize_model_with_stats(
                &suite.manifest, &model, &suite.dataset, &opts, &noise, 0.0,
            )?;
            table.row(vec![
                mname.to_string(),
                bits.to_string(),
                pct(r_real.top1),
                pct(r_noise.top1),
                format!("{:+.2}", (r_real.top1 - r_noise.top1) * 100.0),
            ]);
        }
    }
    table.print();
    table.save_json("ablation_datafree");
    Ok(())
}
