//! Ablation (paper's future-work extension): mixed-precision bit
//! allocation vs uniform bit-widths at matched average weight budgets.
//! The design-choice question from DESIGN.md: does the greedy
//! marginal-utility allocator beat uniform COMQ at the same footprint?

use comq::bench::suite::Suite;
use comq::bench::{pct, Table};
use comq::coordinator::mixed_precision_quantize;
use comq::eval::{evaluate, ActMode};
use comq::calib::EngineKind;
use comq::quant::QuantConfig;

const MODELS: &[&str] = &["vit_s", "resnet_lite"];
const BUDGETS: &[f64] = &[2.5, 3.0, 3.5, 4.0];

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    let mut table = Table::new(
        "ablation — mixed-precision allocation vs uniform COMQ (top-1 %)",
        &["model", "avg bits", "uniform", "mixed", "mixed err/uniform err"],
    );
    for mname in MODELS {
        let model = suite.model(mname)?;
        let (stats, _) = suite.stats(&model, 1024)?;
        for &budget in BUDGETS {
            // uniform at the nearest integer width
            let uni_bits = budget.round() as u32;
            let uni = suite.run(
                &model,
                "comq",
                uni_bits,
                comq::quant::grid::Scheme::PerChannel,
                comq::quant::OrderKind::GreedyPerColumn,
                Suite::default_lam(uni_bits),
                1024,
                None,
            )?;
            let base = QuantConfig { lam: if budget <= 2.5 { 0.8 } else { 1.0 }, ..Default::default() };
            let (qm, rep) =
                mixed_precision_quantize(&suite.manifest, &model, &stats, &base, budget)?;
            let acc = evaluate(
                &suite.manifest,
                &qm,
                &suite.dataset.val_images,
                &suite.dataset.val_labels,
                EngineKind::Pjrt,
                &ActMode::Fp,
            )?;
            table.row(vec![
                mname.to_string(),
                format!("{budget:.1} (uni {uni_bits})"),
                pct(uni.top1),
                pct(acc.top1),
                format!("{:.3}", rep.total_err / uni.total_err().max(1e-12)),
            ]);
        }
    }
    table.print();
    table.save_json("ablation_mixed");
    Ok(())
}
