//! Paper Table 10 (appendix): the λ initialization ablation at 2-bit on
//! the Swin stand-ins. The claim: λ < 1 (shrinking the per-channel grid
//! range) is decisively better than λ = 1 at ultra-low bit-widths.

use comq::bench::suite::Suite;
use comq::bench::{pct, Table};
use comq::quant::grid::Scheme;
use comq::quant::OrderKind;

const MODELS: &[&str] = &["swin_t", "swin_s"];
const LAMBDAS: &[f32] = &[0.5, 0.6, 0.71, 0.8, 0.9, 1.0];

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    let mut headers = vec!["lambda".to_string(), "Bits".to_string()];
    headers.extend(MODELS.iter().map(|m| m.to_string()));
    let mut table = Table::new(
        "Tab.10 — λ-initialization ablation, 2-bit per-channel COMQ top-1 (%)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    for &lam in LAMBDAS {
        let mut row = vec![format!("{lam}"), "2".into()];
        for mname in MODELS {
            let model = suite.model(mname)?;
            let rep = suite.run(
                &model,
                "comq",
                2,
                Scheme::PerChannel,
                OrderKind::GreedyPerColumn,
                lam,
                1024,
                None,
            )?;
            row.push(pct(rep.top1));
        }
        table.row(row);
    }
    let mut row = vec!["FP".into(), "32".into()];
    for m in MODELS {
        row.push(pct(suite.manifest.model(m)?.fp_top1));
    }
    table.row(row);
    table.print();
    table.save_json("tab10_lambda");
    Ok(())
}
