//! Paper Table 1: ImageNet top-1 on ViTs, *per-channel weight-only*
//! uniform quantization at 4/3/2 bits.
//!
//! Paper comparators FQ-ViT / PTQ4ViT are substituted by the in-tree
//! backprop-free baselines (rtn / gpfq / obq / adaround-lite) on our
//! trained ViT stand-ins; the reproduced quantity is the *ordering and
//! gap structure*: COMQ ≥ baselines everywhere, near-lossless at 4-bit,
//! usable 2-bit where RTN collapses.

use comq::bench::suite::Suite;
use comq::bench::{pct, Table};
use comq::quant::grid::Scheme;
use comq::quant::OrderKind;

const MODELS: &[&str] = &["vit_s", "vit_b", "deit_s", "swin_t", "swin_s"];
const METHODS: &[&str] = &["rtn", "bitsplit", "adaround-lite", "gpfq", "obq", "comq"];

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    let mut headers = vec!["Method".to_string(), "WBit".to_string()];
    headers.extend(MODELS.iter().map(|m| m.to_string()));
    let mut table = Table::new(
        "Tab.1 — ViTs, per-channel weight-only top-1 (%)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    // FP baseline row
    let mut row = vec!["Baseline".into(), "32".into()];
    for m in MODELS {
        row.push(pct(suite.manifest.model(m)?.fp_top1));
    }
    table.row(row);

    for bits in [4u32, 3, 2] {
        for method in METHODS {
            let mut row = vec![method.to_string(), bits.to_string()];
            for mname in MODELS {
                let model = suite.model(mname)?;
                let rep = suite.run(
                    &model,
                    method,
                    bits,
                    Scheme::PerChannel,
                    OrderKind::GreedyPerColumn,
                    Suite::default_lam(bits),
                    1024,
                    None,
                )?;
                row.push(pct(rep.top1));
            }
            table.row(row);
        }
    }
    table.print();
    table.save_json("tab1_vit_weight_only");
    Ok(())
}
