//! Paper Figure 3: layer-wise reconstruction errors ‖XW_q − XW‖ for
//! cyclic vs greedy COMQ across architectures. Emits the per-layer
//! series (one row per layer) so the figure is regenerable, plus the
//! geometric-mean improvement.

use comq::bench::suite::Suite;
use comq::bench::Table;
use comq::calib::EngineKind;
use comq::coordinator::{quantize_model, PipelineOptions};
use comq::quant::{OrderKind, QuantConfig};

const MODELS: &[&str] = &["vit_s", "resnet_lite", "swin_t"];
const BITS: u32 = 3;

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    for mname in MODELS {
        let model = suite.model(mname)?;
        let mut table = Table::new(
            &format!("Fig.3 — {mname}: layer-wise ‖XW_q − XW‖ ({BITS}-bit per-channel)"),
            &["layer", "cyclic", "greedy", "greedy/cyclic"],
        );
        let run = |order| -> anyhow::Result<_> {
            let opts = PipelineOptions {
                engine: EngineKind::Pjrt,
                calib_size: 1024,
                skip_eval: true,
                qcfg: QuantConfig { bits: BITS, order, ..Default::default() },
                ..Default::default()
            };
            let (_qm, rep) = quantize_model(&suite.manifest, &model, &suite.dataset, &opts)?;
            Ok(rep)
        };
        let cyc = run(OrderKind::Cyclic)?;
        let gre = run(OrderKind::GreedyPerColumn)?;
        let mut log_ratio_sum = 0.0f64;
        for (lc, lg) in cyc.layers.iter().zip(&gre.layers) {
            assert_eq!(lc.name, lg.name);
            let (ec, eg) = (lc.err.sqrt(), lg.err.sqrt()); // the paper plots the norm
            let ratio = eg / ec.max(1e-12);
            log_ratio_sum += ratio.max(1e-9).ln();
            table.row(vec![
                lc.name.clone(),
                format!("{ec:.4}"),
                format!("{eg:.4}"),
                format!("{ratio:.4}"),
            ]);
        }
        let geo = (log_ratio_sum / cyc.layers.len() as f64).exp();
        table.row(vec![
            "geomean".into(),
            "-".into(),
            "-".into(),
            format!("{geo:.4}"),
        ]);
        table.print();
        table.save_json(&format!("fig3_layer_errors_{mname}"));
    }
    Ok(())
}
