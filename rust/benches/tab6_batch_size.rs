//! Paper Table 6: accuracy vs calibration batch size, 4W32A per-channel
//! COMQ. The claim: COMQ is robust down to small calibration sets (its
//! per-coordinate updates only need well-conditioned Gram statistics).

use comq::bench::suite::Suite;
use comq::bench::{pct, Table};
use comq::quant::grid::Scheme;
use comq::quant::OrderKind;

const MODELS: &[&str] = &["resnet_lite", "cnn_s", "vit_b"];
const SIZES: &[usize] = &[128, 256, 512, 1024, 2048];

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    let mut headers = vec!["Model".to_string()];
    headers.extend(SIZES.iter().map(|s| s.to_string()));
    headers.push("FP".into());
    let mut table = Table::new(
        "Tab.6 — top-1 (%) vs calibration batch size (4W32A per-channel COMQ)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    for mname in MODELS {
        let model = suite.model(mname)?;
        let mut row = vec![mname.to_string()];
        for &sz in SIZES {
            let rep = suite.run(
                &model,
                "comq",
                4,
                Scheme::PerChannel,
                OrderKind::GreedyPerColumn,
                1.0,
                sz,
                None,
            )?;
            row.push(pct(rep.top1));
        }
        row.push(pct(model.info.fp_top1));
        table.row(row);
    }
    table.print();
    table.save_json("tab6_batch_size");
    Ok(())
}
