//! Paper Table 9 (appendix): wall-clock runtime of the quantization pass
//! per method on the ResNet stand-in (4W32A per-channel). The paper's
//! claim is a ~5x gap (COMQ 12 min vs OBQ 65 min / AdaRound 55 min on
//! their testbed); here every method runs on identical calibration
//! statistics so the ratio isolates algorithmic cost.
//!
//! Also reports the COMQ sweep through the PJRT Pallas kernel path.

use comq::bench::suite::Suite;
use comq::bench::Table;
use comq::calib::EngineKind;
use comq::coordinator::{quantize_model, PipelineOptions, QuantEngine};
use comq::quant::QuantConfig;
use comq::util::stats;

const METHODS: &[&str] = &["adaround-lite", "gpfq", "obq", "comq", "comq-cyclic"];
const REPS: usize = 5;

fn main() -> anyhow::Result<()> {
    let suite = Suite::load()?;
    let model = suite.model("resnet_lite")?;
    let mut table = Table::new(
        "Tab.9 — quantization runtime, resnet_lite 4W32A per-channel",
        &["Method", "quant secs (median)", "± std", "vs comq"],
    );

    let run = |method: &str, qe: QuantEngine| -> anyhow::Result<Vec<f64>> {
        let mut secs = Vec::new();
        for _ in 0..REPS {
            let opts = PipelineOptions {
                method: method.into(),
                engine: EngineKind::Pjrt,
                quant_engine: qe,
                calib_size: 2048,
                skip_eval: true,
                qcfg: QuantConfig { bits: 4, ..Default::default() },
                ..Default::default()
            };
            let (_qm, rep) = quantize_model(&suite.manifest, &model, &suite.dataset, &opts)?;
            secs.push(rep.quant_secs);
        }
        Ok(secs)
    };

    let comq_med = stats::quantile(&run("comq", QuantEngine::Native)?, 0.5);
    for method in METHODS {
        let secs = run(method, QuantEngine::Native)?;
        let med = stats::quantile(&secs, 0.5);
        table.row(vec![
            method.to_string(),
            format!("{med:.3}"),
            format!("{:.3}", stats::std_dev(&secs)),
            format!("{:.2}x", med / comq_med),
        ]);
    }
    let secs = run("comq", QuantEngine::PjrtKernel)?;
    let med = stats::quantile(&secs, 0.5);
    table.row(vec![
        "comq (pjrt-kernel)".into(),
        format!("{med:.3}"),
        format!("{:.3}", stats::std_dev(&secs)),
        format!("{:.2}x", med / comq_med),
    ]);

    table.print();
    table.save_json("tab9_runtime");
    Ok(())
}
