//! Probe the toolchain for AVX-512 intrinsics support.
//!
//! The VNNI serving kernel (`util/simd.rs`) uses `vpdpbusd` through the
//! `std::arch` AVX-512 intrinsics, which are stable only from rustc
//! 1.89. Compiling them unconditionally would break older toolchains,
//! so the kernel is gated behind a `comq_avx512` cfg emitted here; when
//! the cfg is absent the dispatcher reports the kernel as unsupported
//! and runtime dispatch falls through to AVX2/scalar.

use std::process::Command;

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    // Older cargos treat unknown `cargo:` keys as inert metadata, so
    // declaring the custom cfg unconditionally is safe everywhere.
    println!("cargo:rustc-check-cfg=cfg(comq_avx512)");
    if std::env::var("CARGO_CFG_TARGET_ARCH").as_deref() != Ok("x86_64") {
        return;
    }
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".into());
    let out = match Command::new(&rustc).arg("--version").output() {
        Ok(o) if o.status.success() => String::from_utf8_lossy(&o.stdout).into_owned(),
        _ => return,
    };
    // "rustc 1.89.0 (...)" — parse major.minor, tolerate -nightly tails
    let Some(ver) = out.split_whitespace().nth(1) else { return };
    let mut parts = ver.split(|c: char| !c.is_ascii_digit());
    let major: u32 = parts.next().and_then(|s| s.parse().ok()).unwrap_or(0);
    let minor: u32 = parts.next().and_then(|s| s.parse().ok()).unwrap_or(0);
    if major > 1 || (major == 1 && minor >= 89) {
        println!("cargo:rustc-cfg=comq_avx512");
    }
}
