#!/usr/bin/env bash
# Tier-1 gate for the rust/ crate: build, tests, formatting, lints.
# Perf refactors (ISSUE 2 and onward) must keep this green — run it
# before every PR. Usage: ./ci.sh [--no-clippy]
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
# examples and benches are binaries too — keep them compiling even when
# nothing runs them (they bit-rotted silently before PR 3)
cargo build --release --examples
cargo bench --no-run
# env passes: runtime-detected SIMD kernels (the default), dispatch
# pinned to the portable reference — the parity tests compare kernels
# directly, but the whole suite must also pass when every GEMM runs
# scalar (what a non-AVX host sees) — single-threaded, so the pool's
# inline fallback path (never touches or creates workers) is exercised
# on every run, and with telemetry off, so the obs no-op path keeps the
# suite green and tests/serve_obs.rs asserts the empty-registry /
# bit-identical-logits contract (lib unit tests that exercise recording
# force the gate on themselves via obs::set_level)
cargo test -q
COMQ_KERNEL=scalar cargo test -q
COMQ_THREADS=1 cargo test -q
COMQ_OBS=off cargo test -q
# NUMA pinned off: panels stay flat (no per-node shards), workers stay
# unpinned — the suite's bit-identity asserts must hold against the
# same logits the auto-probed layout produces (PR 10)
COMQ_NUMA=off cargo test -q
# fifth env pass: every request traced end to end — the whole suite must
# stay green (and bit-exact where it asserts parity) while span trees,
# tail retention and the flight recorder record everything; clients
# auto-mint wire contexts so the v2 frame path is exercised everywhere
COMQ_TRACE=all cargo test -q
# fault-injection pass: the env-driven COMQ_FAULT path, run against the
# one test that expects it (the rest of tests/serve_net.rs arms faults
# via fault::set_spec and must never see an env spec — a full-suite run
# under COMQ_FAULT would fire injected faults inside unrelated tests)
COMQ_FAULT=panic:conn:1 cargo test -q --test serve_net env_spec_smoke
# lifecycle passes (PR 9): the env-driven io_err spec must kill the
# first atomic save and leave nothing behind, and the env-driven model
# budget must reach the registry's eviction machinery — each runs alone
# in a fresh process so the one-shot env parse is what's under test
COMQ_FAULT=io_err:1 cargo test -q --test serve_net env_spec_smoke
COMQ_MODEL_BUDGET=1 cargo test -q --test registry_lifecycle env_budget_smoke
# the intrinsics paths must not bit-rot uncompiled: a target-cpu=native
# build exercises the target_feature functions plus whatever the
# autovectorizer now assumes, in a separate target dir so the cache of
# the portable build survives
RUSTFLAGS="-C target-cpu=native" cargo build --release --target-dir target/native

if command -v rustfmt >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "ci.sh: rustfmt not installed, skipping format check" >&2
fi

if [[ "${1:-}" != "--no-clippy" ]] && cargo clippy --version >/dev/null 2>&1; then
    cargo clippy -- -D warnings
else
    echo "ci.sh: clippy unavailable or disabled, skipping lints" >&2
fi

echo "ci.sh: all checks passed"
