#!/usr/bin/env bash
# Tier-1 gate for the rust/ crate: build, tests, formatting, lints.
# Perf refactors (ISSUE 2 and onward) must keep this green — run it
# before every PR. Usage: ./ci.sh [--no-clippy]
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
# examples and benches are binaries too — keep them compiling even when
# nothing runs them (they bit-rotted silently before PR 3)
cargo build --release --examples
cargo bench --no-run
cargo test -q

if command -v rustfmt >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "ci.sh: rustfmt not installed, skipping format check" >&2
fi

if [[ "${1:-}" != "--no-clippy" ]] && cargo clippy --version >/dev/null 2>&1; then
    cargo clippy -- -D warnings
else
    echo "ci.sh: clippy unavailable or disabled, skipping lints" >&2
fi

echo "ci.sh: all checks passed"
