//! End-to-end integration over the real artifacts: pipeline runs,
//! engine parity (native ↔ PJRT), and accuracy guardrails mirroring the
//! paper's headline claims. All tests skip gracefully when artifacts are
//! missing (run `make artifacts` first).

use comq::calib::{collect_stats, Dataset, EngineKind};
use comq::coordinator::{quantize_model, PipelineOptions, QuantEngine};
use comq::eval::ActMode;
use comq::manifest::Manifest;
use comq::model::Model;
use comq::quant::grid::Scheme;
use comq::quant::{OrderKind, QuantConfig};

fn setup() -> Option<(Manifest, Dataset)> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let manifest = Manifest::load(&root).unwrap();
    let dataset = Dataset::load(&manifest).unwrap();
    Some((manifest, dataset))
}

#[test]
fn native_and_pjrt_eval_agree() {
    let Some((manifest, dataset)) = setup() else { return };
    for name in ["vit_s", "resnet_lite", "mobilenet_lite"] {
        let model = Model::load(&manifest, name).unwrap();
        // small val slice for speed
        let n = 256;
        let elems: usize = dataset.val_images.shape()[1..].iter().product();
        let imgs = comq::tensor::Tensor::new(
            &[n, manifest.img, manifest.img, 3],
            dataset.val_images.data()[..n * elems].to_vec(),
        );
        let labels = &dataset.val_labels[..n];
        let a = comq::eval::evaluate(&manifest, &model, &imgs, labels, EngineKind::Native, &ActMode::Fp)
            .unwrap();
        let b = comq::eval::evaluate(&manifest, &model, &imgs, labels, EngineKind::Pjrt, &ActMode::Fp)
            .unwrap();
        assert!(
            (a.top1 - b.top1).abs() < 0.01,
            "{name}: native {} vs pjrt {}",
            a.top1,
            b.top1
        );
    }
}

#[test]
fn native_and_pjrt_calibration_agree() {
    let Some((manifest, dataset)) = setup() else { return };
    let model = Model::load(&manifest, "vit_s").unwrap();
    let imgs = dataset.calib_subset(128);
    let sa = collect_stats(&manifest, &model, &imgs, EngineKind::Native).unwrap();
    let sb = collect_stats(&manifest, &model, &imgs, EngineKind::Pjrt).unwrap();
    for (name, a) in &sa {
        let b = &sb[name];
        let (ga, gb) = match (&a.gram, &b.gram) {
            (comq::quant::GramSet::Shared(x), comq::quant::GramSet::Shared(y)) => (x, y),
            _ => continue,
        };
        // relative Frobenius difference
        let diff = ga.sub(gb).frob_norm_sq().sqrt();
        let norm = ga.frob_norm_sq().sqrt().max(1e-9);
        assert!(diff / norm < 1e-3, "{name}: relative gram diff {}", diff / norm);
        assert!((a.min - b.min).abs() < 1e-2, "{name} min");
        assert!((a.max - b.max).abs() < 1e-2, "{name} max");
    }
}

#[test]
fn comq_4bit_near_lossless_on_vit() {
    // Paper: 4-bit ViT within ~1% of FP.
    let Some((manifest, dataset)) = setup() else { return };
    let model = Model::load(&manifest, "vit_s").unwrap();
    let opts = PipelineOptions {
        engine: EngineKind::Pjrt,
        calib_size: 512,
        ..Default::default()
    };
    let (_qm, report) = quantize_model(&manifest, &model, &dataset, &opts).unwrap();
    let drop = report.fp_top1 - report.top1;
    assert!(drop < 0.02, "4-bit drop too large: {drop}");
    assert!(report.top5 > 0.95);
}

#[test]
fn comq_beats_rtn_at_2bit() {
    // Paper: RTN collapses at 2-bit, COMQ stays usable.
    let Some((manifest, dataset)) = setup() else { return };
    let model = Model::load(&manifest, "vit_s").unwrap();
    let base = PipelineOptions {
        engine: EngineKind::Pjrt,
        calib_size: 512,
        qcfg: QuantConfig { bits: 2, lam: 0.8, ..Default::default() },
        ..Default::default()
    };
    let (_q1, comq) = quantize_model(&manifest, &model, &dataset, &base).unwrap();
    let rtn_opts = PipelineOptions { method: "rtn".into(), ..base };
    let (_q2, rtn) = quantize_model(&manifest, &model, &dataset, &rtn_opts).unwrap();
    assert!(
        comq.top1 > rtn.top1 + 0.10,
        "2-bit: comq {} vs rtn {} — gap should be large",
        comq.top1,
        rtn.top1
    );
    assert!(comq.total_err() < rtn.total_err());
}

#[test]
fn pjrt_kernel_engine_end_to_end() {
    // The L1 Pallas path must produce the same accuracy as the native
    // engine (same algorithm, different executor).
    let Some((manifest, dataset)) = setup() else { return };
    let model = Model::load(&manifest, "vit_s").unwrap();
    let mk = |qe| PipelineOptions {
        engine: EngineKind::Pjrt,
        quant_engine: qe,
        calib_size: 256,
        qcfg: QuantConfig { bits: 3, order: OrderKind::GreedyShared, ..Default::default() },
        ..Default::default()
    };
    let (_a, ra) = quantize_model(&manifest, &model, &dataset, &mk(QuantEngine::Native)).unwrap();
    let (_b, rb) =
        quantize_model(&manifest, &model, &dataset, &mk(QuantEngine::PjrtKernel)).unwrap();
    assert!(
        (ra.top1 - rb.top1).abs() < 0.01,
        "native {} vs pjrt-kernel {}",
        ra.top1,
        rb.top1
    );
    let (ea, eb) = (ra.total_err(), rb.total_err());
    assert!((ea - eb).abs() <= 0.02 * ea.max(eb), "err {ea} vs {eb}");
}

#[test]
fn full_quant_w4a4_works() {
    let Some((manifest, dataset)) = setup() else { return };
    let model = Model::load(&manifest, "resnet_lite").unwrap();
    let opts = PipelineOptions {
        engine: EngineKind::Pjrt,
        calib_size: 256,
        act_bits: Some(4),
        ..Default::default()
    };
    let (_qm, report) = quantize_model(&manifest, &model, &dataset, &opts).unwrap();
    // A4 hurts but must stay far above chance (1/16)
    assert!(report.top1 > 0.5, "W4A4 top1 {}", report.top1);
    // and A8 should be better than A4
    let opts8 = PipelineOptions { act_bits: Some(8), ..opts };
    let (_qm8, r8) = quantize_model(&manifest, &model, &dataset, &opts8).unwrap();
    assert!(r8.top1 >= report.top1 - 0.01, "A8 {} < A4 {}", r8.top1, report.top1);
}

#[test]
fn parallel_workers_match_sequential() {
    let Some((manifest, dataset)) = setup() else { return };
    let model = Model::load(&manifest, "cnn_s").unwrap();
    let mk = |workers| PipelineOptions {
        engine: EngineKind::Native,
        calib_size: 128,
        workers,
        skip_eval: true,
        ..Default::default()
    };
    let (qa, ra) = quantize_model(&manifest, &model, &dataset, &mk(1)).unwrap();
    let (qb, rb) = quantize_model(&manifest, &model, &dataset, &mk(4)).unwrap();
    assert_eq!(ra.layers.len(), rb.layers.len());
    for l in &model.info.quant_layers {
        let wa = qa.weight(&l.name);
        let wb = qb.weight(&l.name);
        assert_eq!(wa, wb, "layer {} differs across worker counts", l.name);
    }
}

#[test]
fn skip_layers_respected() {
    let Some((manifest, dataset)) = setup() else { return };
    let model = Model::load(&manifest, "cnn_s").unwrap();
    let opts = PipelineOptions {
        engine: EngineKind::Native,
        calib_size: 128,
        skip_layers: vec!["head".into()],
        skip_eval: true,
        ..Default::default()
    };
    let (qm, report) = quantize_model(&manifest, &model, &dataset, &opts).unwrap();
    assert_eq!(qm.weight("head"), model.weight("head"), "head must stay FP");
    assert!(report.layers.iter().all(|l| l.name != "head"));
}

#[test]
fn per_channel_beats_per_layer() {
    // Sec. 3.2's motivation: per-channel scales -> smaller error.
    let Some((manifest, dataset)) = setup() else { return };
    let model = Model::load(&manifest, "resnet_lite").unwrap();
    let mk = |scheme| PipelineOptions {
        engine: EngineKind::Native,
        calib_size: 256,
        skip_eval: true,
        qcfg: QuantConfig { bits: 3, scheme, ..Default::default() },
        ..Default::default()
    };
    let (_a, pc) = quantize_model(&manifest, &model, &dataset, &mk(Scheme::PerChannel)).unwrap();
    let (_b, pl) = quantize_model(&manifest, &model, &dataset, &mk(Scheme::PerLayer)).unwrap();
    assert!(
        pc.total_err() < pl.total_err(),
        "per-channel {} vs per-layer {}",
        pc.total_err(),
        pl.total_err()
    );
}
