//! SIMD kernel parity: every runtime-dispatched kernel must produce
//! i32 accumulators **bit-identical** to the scalar reference — not
//! within-tolerance — across all code widths, unaligned shapes, k not
//! divisible by the K4 group, and the k=1 edge; the grouped
//! (depthwise) kernel under the same exactness contract; and the
//! `COMQ_KERNEL` override must force dispatch (skipping cleanly where
//! the host lacks the feature).
//!
//! Everything here except `comq_kernel_env_forces_dispatch` uses the
//! explicit-kernel entry points (`dot_i8`, `gemm_i8_fused_with`), so
//! the env-mutating test cannot race the others inside this binary.

use comq::quant::actq::ActQuant;
use comq::serve::gemm::{
    dwconv_i8_fused_with, gemm_i8_fused_with, pack_panel_k4, EpilogueCoeffs, GroupedQuantizedActs,
    QuantizedActs,
};
use comq::tensor::{Tensor, MR, NR};
use comq::util::simd::{dot_f32, dot_i8, dot_i8_grouped, maddubs_safe, Kernel, K4};
use comq::util::Rng;

/// SIMD kernels available on this host; absent ones are reported and
/// skipped (the suite must pass on a scalar-only machine).
fn simd_kernels() -> Vec<Kernel> {
    let mut ks = Vec::new();
    for k in [Kernel::Avx2, Kernel::Vnni] {
        if k.supported() {
            ks.push(k);
        } else {
            eprintln!("kernel_parity: {} unsupported on this host, skipping", k.name());
        }
    }
    ks
}

/// Random centered weight codes for `wbits`, K4-packed, plus the raw
/// matrix.
fn random_panel(rng: &mut Rng, k: usize, n: usize, wbits: u32) -> (Vec<i8>, Vec<i8>) {
    let levels = 1usize << wbits;
    let center = (levels / 2) as i32;
    let s: Vec<i8> = (0..k * n).map(|_| (rng.below(levels) as i32 - center) as i8).collect();
    let panel = pack_panel_k4(&s, k, n);
    (s, panel)
}

/// Quantized activations spanning the full code range for `abits`.
fn random_acts(rng: &mut Rng, rows: usize, k: usize, abits: u32) -> QuantizedActs {
    let x = Tensor::new(&[rows, k], rng.normal_vec(rows * k));
    // a tight range clamps the tails to code 0 and 2^ab − 1, so the
    // extreme codes (the saturation-prone ones) actually occur
    let aq = ActQuant::from_range(-0.5, 0.5, abits, 1.0);
    QuantizedActs::quantize(&x, aq)
}

/// The shapes that historically break tiling code: k=1, k % 4 ≠ 0,
/// rows % MR ≠ 0, n % NR ≠ 0, single-element, and one full-tile case.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (3, 5, 7),
    (4, 16, 16),
    (5, 33, 21),
    (2, 31, 17),
    (7, 64, 48),
    (1, 129, 3),
    (6, 4, 64),
];

#[test]
fn dot_i8_bit_identical_to_scalar() {
    for kern in simd_kernels() {
        for &wbits in &[2u32, 3, 4, 8] {
            for &abits in &[4u32, 8] {
                let wide = !maddubs_safe(abits, wbits);
                let mut rng = Rng::new(0xD07 + wbits as u64 * 31 + abits as u64);
                for &(rows, k, n) in SHAPES {
                    let (_, panel) = random_panel(&mut rng, k, n, wbits);
                    let acts = random_acts(&mut rng, rows, k, abits);
                    let kg = k.div_ceil(K4);
                    let strip_len = kg * NR * K4;
                    for s in 0..n.div_ceil(NR) {
                        let strip = &panel[s * strip_len..(s + 1) * strip_len];
                        for blk in 0..rows.div_ceil(MR) {
                            let i0 = blk * MR;
                            let rmax = MR.min(rows - i0);
                            let a = &acts.codes[i0 * acts.stride..];
                            let mut want = [[0i32; NR]; MR];
                            let mut got = [[0i32; NR]; MR];
                            dot_i8(Kernel::Scalar, a, acts.stride, rmax, strip, kg, wide, &mut want);
                            dot_i8(kern, a, acts.stride, rmax, strip, kg, wide, &mut got);
                            assert_eq!(
                                got,
                                want,
                                "{} W{wbits}A{abits} shape ({rows},{k},{n}) strip {s} block {blk}",
                                kern.name()
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Grouped activation patches spanning the full code range for `abits`,
/// packed into the strip layout (the depthwise analogue of
/// [`random_acts`]).
fn random_grouped_acts(
    rng: &mut Rng,
    rows: usize,
    c: usize,
    kk: usize,
    abits: u32,
) -> GroupedQuantizedActs {
    let x3 = Tensor::new(&[rows, c, kk], rng.normal_vec(rows * c * kk));
    let aq = ActQuant::from_range(-0.5, 0.5, abits, 1.0);
    GroupedQuantizedActs::quantize(&x3, aq)
}

/// Grouped shapes (rows, kk, c) hitting the same tiling edges: kk=1,
/// kk % 4 ≠ 0, rows % MR ≠ 0, c % NR ≠ 0, single-element, full-strip,
/// and the 3×3 depthwise patch (kk=9) that serving actually runs.
const GSHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (3, 9, 5),
    (4, 9, 16),
    (5, 4, 21),
    (2, 7, 17),
    (7, 9, 48),
    (1, 25, 3),
    (6, 3, 64),
];

#[test]
fn dot_i8_grouped_bit_identical_to_scalar() {
    for kern in simd_kernels() {
        for &wbits in &[2u32, 3, 4, 8] {
            for &abits in &[4u32, 8] {
                let wide = !maddubs_safe(abits, wbits);
                let mut rng = Rng::new(0xDD7 + wbits as u64 * 31 + abits as u64);
                for &(rows, kk, c) in GSHAPES {
                    let (_, panel) = random_panel(&mut rng, kk, c, wbits);
                    let acts = random_grouped_acts(&mut rng, rows, c, kk, abits);
                    let kg = kk.div_ceil(K4);
                    let strip_len = kg * NR * K4;
                    for s in 0..c.div_ceil(NR) {
                        let strip = &panel[s * strip_len..(s + 1) * strip_len];
                        for blk in 0..rows.div_ceil(MR) {
                            let i0 = blk * MR;
                            let rmax = MR.min(rows - i0);
                            let a = &acts.codes[i0 * acts.stride + s * strip_len..];
                            let mut want = [[0i32; NR]; MR];
                            let mut got = [[0i32; NR]; MR];
                            let (st, k) = (acts.stride, kg);
                            dot_i8_grouped(Kernel::Scalar, a, st, rmax, strip, k, wide, &mut want);
                            dot_i8_grouped(kern, a, st, rmax, strip, k, wide, &mut got);
                            assert_eq!(
                                got,
                                want,
                                "{} W{wbits}A{abits} shape ({rows},{kk},{c}) strip {s} block {blk}",
                                kern.name()
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Full grouped-conv parity: identical accumulators through the
/// identical f64 epilogue must give bit-identical f32 outputs across
/// kernels, pooled row split included.
#[test]
fn dwconv_outputs_bit_identical_across_kernels() {
    let kernels = simd_kernels();
    for &wbits in &[2u32, 4, 8] {
        for &abits in &[4u32, 8] {
            let mut rng = Rng::new(0x6E55 + wbits as u64 + 100 * abits as u64);
            for &(rows, kk, c) in GSHAPES {
                let (s, panel) = random_panel(&mut rng, kk, c, wbits);
                let acts = random_grouped_acts(&mut rng, rows, c, kk, abits);
                let cw = (1i64 << (wbits - 1)) as f64;
                let mut csum = vec![0i64; c];
                for (idx, &v) in s.iter().enumerate() {
                    csum[idx % c] += v as i64;
                }
                let zero: Vec<f64> = (0..c).map(|_| rng.below(9) as f64 - 4.0).collect();
                let za = acts.aq.zero as f64;
                let co = EpilogueCoeffs {
                    scale: (0..c).map(|_| rng.range_f32(0.01, 0.2) as f64).collect(),
                    zc: zero.iter().map(|&z| cw + z).collect(),
                    fixed: (0..c).map(|j| za * (csum[j] as f64 + kk as f64 * (cw + zero[j]))).collect(),
                    bias: (0..c).map(|_| rng.range_f32(-1.0, 1.0) as f64).collect(),
                };
                let mut want = vec![0.0f32; rows * c];
                dwconv_i8_fused_with(Kernel::Scalar, &acts, &panel, c, wbits, &co, &mut want);
                for &kern in &kernels {
                    let mut got = vec![0.0f32; rows * c];
                    dwconv_i8_fused_with(kern, &acts, &panel, c, wbits, &co, &mut got);
                    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{} W{wbits}A{abits} shape ({rows},{kk},{c}) flat {i}: {a} vs {b}",
                            kern.name()
                        );
                    }
                }
            }
        }
    }
}

/// Full-GEMM parity: identical accumulators through the identical f64
/// epilogue must give bit-identical f32 outputs, including the
/// batch-1 column-split parallel path.
#[test]
fn gemm_outputs_bit_identical_across_kernels() {
    let kernels = simd_kernels();
    for &wbits in &[2u32, 4, 8] {
        for &abits in &[4u32, 8] {
            let mut rng = Rng::new(0x6E44 + wbits as u64 + 100 * abits as u64);
            for &(rows, k, n) in SHAPES {
                let (s, panel) = random_panel(&mut rng, k, n, wbits);
                let acts = random_acts(&mut rng, rows, k, abits);
                let cw = (1i64 << (wbits - 1)) as f64;
                let mut csum = vec![0i64; n];
                for (idx, &v) in s.iter().enumerate() {
                    csum[idx % n] += v as i64;
                }
                let zero: Vec<f64> = (0..n).map(|_| rng.below(9) as f64 - 4.0).collect();
                let za = acts.aq.zero as f64;
                let co = EpilogueCoeffs {
                    scale: (0..n).map(|_| rng.range_f32(0.01, 0.2) as f64).collect(),
                    zc: zero.iter().map(|&z| cw + z).collect(),
                    fixed: (0..n).map(|j| za * (csum[j] as f64 + k as f64 * (cw + zero[j]))).collect(),
                    bias: (0..n).map(|_| rng.range_f32(-1.0, 1.0) as f64).collect(),
                };
                let mut want = vec![0.0f32; rows * n];
                gemm_i8_fused_with(Kernel::Scalar, &acts, &panel, n, wbits, &co, &mut want);
                for &kern in &kernels {
                    let mut got = vec![0.0f32; rows * n];
                    gemm_i8_fused_with(kern, &acts, &panel, n, wbits, &co, &mut got);
                    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{} W{wbits}A{abits} shape ({rows},{k},{n}) flat {i}: {a} vs {b}",
                            kern.name()
                        );
                    }
                }
            }
        }
    }
}

/// The f32 FMA kernel is *not* required to match scalar bitwise (fused
/// rounding) — it must match a f64 reference within tolerance and be
/// deterministic for a fixed kernel.
#[test]
fn dot_f32_simd_accurate_and_deterministic() {
    for kern in simd_kernels() {
        let mut rng = Rng::new(0xF32);
        for &(rows, k) in &[(1usize, 1usize), (3, 7), (4, 33), (2, 300)] {
            let a = rng.normal_vec(rows * k);
            let strip = rng.normal_vec(k * NR);
            let mut acc = [[0.0f32; NR]; MR];
            dot_f32(kern, &a, k, rows, &strip, k, &mut acc);
            let mut again = [[0.0f32; NR]; MR];
            dot_f32(kern, &a, k, rows, &strip, k, &mut again);
            for r in 0..rows {
                for l in 0..NR {
                    assert_eq!(
                        acc[r][l].to_bits(),
                        again[r][l].to_bits(),
                        "{} nondeterministic at ({r},{l})",
                        kern.name()
                    );
                    let want: f64 = (0..k)
                        .map(|kk| a[r * k + kk] as f64 * strip[kk * NR + l] as f64)
                        .sum();
                    let tol = 1e-4 * (k as f64).sqrt().max(1.0);
                    assert!(
                        (acc[r][l] as f64 - want).abs() <= tol,
                        "{} ({rows},{k}) at ({r},{l}): {} vs {want}",
                        kern.name(),
                        acc[r][l]
                    );
                }
            }
        }
    }
}

/// `COMQ_KERNEL` must force dispatch when the kernel is supported and
/// fall back to detection (never fault) when it isn't. The only test
/// in this binary that touches the env var — everything else uses the
/// explicit-kernel entry points.
#[test]
fn comq_kernel_env_forces_dispatch() {
    // ci.sh runs this suite once with COMQ_KERNEL=scalar pinned —
    // restore whatever pin the caller set rather than deleting it
    let pinned = std::env::var("COMQ_KERNEL").ok();
    for kern in Kernel::ALL {
        std::env::set_var("COMQ_KERNEL", kern.name());
        if kern.supported() {
            assert_eq!(Kernel::active(), kern, "override {} must win", kern.name());
        } else {
            eprintln!("kernel_parity: {} absent, checking clean fallback", kern.name());
            assert_eq!(Kernel::active(), Kernel::detect());
        }
    }
    // unknown names also fall back instead of panicking mid-serve
    std::env::set_var("COMQ_KERNEL", "quantum");
    assert_eq!(Kernel::active(), Kernel::detect());
    std::env::remove_var("COMQ_KERNEL");
    assert_eq!(Kernel::active(), Kernel::detect());
    if let Some(v) = pinned {
        std::env::set_var("COMQ_KERNEL", v);
    }
}
