//! Loopback integration tests for end-to-end request tracing (PR 8):
//! wire-level trace-context propagation on both transports, tail-based
//! retention of the slowest K, flight-recorder reconciliation against
//! injected panics, the `COMQ_TRACE=off` bit-identity contract, and the
//! telescoping acceptance check (span tree sums to wire latency).
//!
//! Trace mode, retention and the flight recorder are process-global, so
//! every test serializes on one lock, resets the global state on entry
//! and pins `COMQ_TRACE` back to `Off` on exit.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use comq::deploy::save_packed_with_act;
use comq::manifest::Manifest;
use comq::obs::recorder::{self, RecKind};
use comq::obs::trace::{self, TraceMode, Why};
use comq::proptest::{quantize_all_layers, tiny_plain_cnn};
use comq::serve::net::fault::{self, Site};
use comq::serve::net::{ClientError, ErrorReason, NetClient, NetConfig, NetServer, Response};
use comq::serve::{load_cached, BatchConfig, QuantizedModel};
use comq::tensor::Tensor;
use comq::util::json::Json;
use comq::util::Rng;

const MODEL: &str = "tiny_plain";
const ELEMS: usize = 8 * 8 * 3;
const RECV_TIMEOUT: Duration = Duration::from_secs(10);

static LOCK: Mutex<()> = Mutex::new(());

fn guard() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Reset every piece of process-global trace state this binary mutates.
fn fresh(mode: TraceMode) {
    fault::clear();
    trace::reset();
    recorder::reset();
    trace::set_slow_k(trace::DEFAULT_SLOW_K);
    trace::set_mode(mode);
}

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("comq_serve_trace_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_string_lossy().to_string()
}

/// The W4A8 synthetic-CNN fixture the other serving tests drive.
fn fixture(tag: &str) -> (Manifest, Arc<QuantizedModel>) {
    let (manifest, model) = tiny_plain_cnn(7);
    let mut rng = Rng::new(0xF00D);
    let calib = Tensor::new(&[64, 8, 8, 3], rng.normal_vec(64 * ELEMS));
    let (packed, act, qmodel) = quantize_all_layers(&manifest, &model, 4, 8, &calib).unwrap();
    let path = tmp(&format!("{tag}.cqm"));
    save_packed_with_act(&path, &qmodel, &packed, 4, Some(&act)).unwrap();
    let qm = load_cached(&manifest, MODEL, &path).unwrap();
    (manifest, qm)
}

fn client(server: &NetServer) -> NetClient {
    let mut c = NetClient::connect(server.local_addr()).expect("connect");
    c.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    c
}

fn net_config() -> NetConfig {
    NetConfig {
        batch: BatchConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(2),
            executors: 1,
            pipeline: false,
        },
        ..NetConfig::default()
    }
}

/// One-at-a-time batcher so injected faults map to known requests.
fn serial_config() -> NetConfig {
    NetConfig {
        batch: BatchConfig {
            max_batch: 1,
            max_delay: Duration::from_millis(0),
            executors: 1,
            pipeline: false,
        },
        ..NetConfig::default()
    }
}

/// A client-minted trace context round-trips through the wire, the
/// server and back onto the reply frame on both transports; an untraced
/// (v1) request gets a server-minted id and never sees a v2 reply.
#[test]
fn trace_id_round_trips_on_both_transports() {
    let _g = guard();
    let (_manifest, qm) = fixture("roundtrip");
    for force_fallback in [false, true] {
        fresh(TraceMode::All);
        let server = NetServer::bind(
            "127.0.0.1:0",
            vec![(MODEL.to_string(), qm.clone())],
            NetConfig { force_fallback, ..net_config() },
        )
        .unwrap();
        let mut c = client(&server);
        let mut rng = Rng::new(0x7121D + force_fallback as u64);
        let img = rng.normal_vec(ELEMS);

        // traced request: the reply echoes the exact context
        let ctx = trace::mint_client();
        let id = c.send_infer_traced(MODEL, &img, None, Some(ctx)).unwrap();
        let (resp, echoed) = c.recv_with_trace().expect("traced reply");
        match resp {
            Response::Logits { request_id, .. } => assert_eq!(request_id, id),
            other => panic!("expected logits, got {other:?}"),
        }
        assert_eq!(echoed, Some(ctx), "reply must echo the request's trace context");
        assert!(
            trace::retained().iter().any(|(t, m)| *t == ctx.id && m.outcome == "ok"),
            "the traced request must be retained under its client-minted id"
        );
        assert!(!trace::events_of(ctx.id).is_empty(), "span tree recorded under the wire id");

        // explicit None forces an untraced v1 frame: the reply is v1
        // (no echo) and the server minted its own id for the trace
        let before: Vec<u64> = trace::retained().iter().map(|(t, _)| *t).collect();
        let id2 = c.send_infer_traced(MODEL, &img, None, None).unwrap();
        let (resp2, echoed2) = c.recv_with_trace().expect("untraced reply");
        match resp2 {
            Response::Logits { request_id, .. } => assert_eq!(request_id, id2),
            other => panic!("expected logits, got {other:?}"),
        }
        assert_eq!(echoed2, None, "a v1 request must never be answered with a v2 frame");
        let minted: Vec<u64> = trace::retained()
            .iter()
            .map(|(t, _)| *t)
            .filter(|t| !before.contains(t))
            .collect();
        assert_eq!(minted.len(), 1, "exactly one new retained trace");
        assert_ne!(
            minted[0] & trace::SERVER_MINTED,
            0,
            "v1 requests get server-minted ids (high bit set)"
        );
        server.shutdown();
    }
    fresh(TraceMode::Off);
}

/// Under `sample:0` only tail retention keeps traces: exactly the K
/// slowest requests of the window survive (the injected-slow ones), and
/// they are marked `Why::Slow`.
#[test]
fn tail_retention_keeps_exactly_k_slow_requests() {
    let _g = guard();
    let (_manifest, qm) = fixture("tailk");
    fresh(TraceMode::Sample(0.0));
    const K: usize = 3;
    trace::set_slow_k(K);
    fault::set_spec("slow:40:3").unwrap(); // first 3 single-request batches stall 40 ms
    let server =
        NetServer::bind("127.0.0.1:0", vec![(MODEL.to_string(), qm.clone())], serial_config())
            .unwrap();
    let mut c = client(&server);
    let mut rng = Rng::new(0x51_0E);
    let img = rng.normal_vec(ELEMS);
    let mut ids = Vec::new();
    for _ in 0..13 {
        let ctx = trace::mint_client();
        ids.push(ctx.id);
        let rid = c.send_infer_traced(MODEL, &img, None, Some(ctx)).unwrap();
        loop {
            match c.recv().expect("reply") {
                Response::Logits { request_id, .. } if request_id == rid => break,
                Response::Logits { .. } => continue,
                other => panic!("unexpected {other:?}"),
            }
        }
    }
    assert_eq!(fault::fired_slow(), 3, "the slow fault must have hit the first {K} requests");
    let retained = trace::retained();
    assert_eq!(
        retained.len(),
        K,
        "sample:0 + no errors leaves exactly the slowest-{K}: {retained:?}"
    );
    for (id, meta) in &retained {
        assert!(ids[..K].contains(id), "retained id {id:#x} must be one of the slow three");
        assert_eq!(meta.why, Why::Slow);
        assert!(
            meta.total_ns >= 30_000_000,
            "a retained-slow request carries its 40 ms stall, got {} ns",
            meta.total_ns
        );
    }
    fresh(TraceMode::Off);
}

/// The flight recorder is the crash black box: injected executor panics
/// land in it with counts that reconcile exactly against both the fault
/// layer and `NetStats` — `Shed + Panic + ErrorFrame == error_frames`.
#[test]
fn flight_recorder_reconciles_injected_panics() {
    let _g = guard();
    let (_manifest, qm) = fixture("blackbox");
    fresh(TraceMode::All);
    const STORM: usize = 2;
    fault::set_spec(&format!("panic:exec:{STORM}")).unwrap();
    let server =
        NetServer::bind("127.0.0.1:0", vec![(MODEL.to_string(), qm.clone())], serial_config())
            .unwrap();
    let mut c = client(&server);
    let mut rng = Rng::new(0xB1AC);
    for i in 0..STORM {
        match c.infer(MODEL, &rng.normal_vec(ELEMS)).unwrap_err() {
            ClientError::Server { reason, .. } => {
                assert_eq!(reason, ErrorReason::ExecutorPanicked, "storm request {i}")
            }
            other => panic!("expected ExecutorPanicked, got {other:?}"),
        }
    }
    const OK: usize = 3;
    for _ in 0..OK {
        c.infer(MODEL, &rng.normal_vec(ELEMS)).expect("recovered after the storm");
    }
    server.shutdown();

    assert_eq!(fault::fired_panics(Site::Exec), STORM as u64);
    let st = server.model_server(MODEL).unwrap().stats();
    assert_eq!(st.respawns, STORM);
    // recorder vs supervisor: one Respawn note per injected panic
    assert_eq!(recorder::count(RecKind::Respawn), STORM as u64);
    // recorder vs wire: the error-frame partition is total
    let net = server.stats();
    assert_eq!(
        recorder::count(RecKind::Shed)
            + recorder::count(RecKind::Panic)
            + recorder::count(RecKind::ErrorFrame),
        net.error_frames as u64,
        "flight-recorder counts must reconcile counter-for-counter against NetStats"
    );
    assert_eq!(recorder::count(RecKind::Panic), STORM as u64);
    // every admitted request (errored or served) left an Admit note
    assert_eq!(recorder::count(RecKind::Admit), (STORM + OK) as u64);
    assert_eq!(recorder::count(RecKind::Drain), 1, "shutdown notes the drain once");
    // the ring still holds the panic events for the post-mortem
    let tail = recorder::last(recorder::CAP);
    assert!(tail.iter().any(|e| e.kind == RecKind::Panic));
    fresh(TraceMode::Off);
}

/// `COMQ_TRACE=off` is the bit-identity contract: logits match the
/// direct in-process forward exactly and every trace/recorder buffer
/// stays empty — even when the client sends a v2 traced frame.
#[test]
fn trace_off_is_bit_identical_with_empty_buffers() {
    let _g = guard();
    let (_manifest, qm) = fixture("off");
    fresh(TraceMode::Off);
    let server =
        NetServer::bind("127.0.0.1:0", vec![(MODEL.to_string(), qm.clone())], net_config())
            .unwrap();
    let mut c = client(&server);
    let mut rng = Rng::new(0x0FF);
    for _ in 0..4 {
        let img = rng.normal_vec(ELEMS);
        let direct = qm.forward(&Tensor::new(&[1, 8, 8, 3], img.clone()));
        // hand-built context: even an explicitly traced wire frame must
        // not make the server record anything while tracing is off
        let ctx = comq::obs::TraceCtx { id: 0xDEAD_BEEF, flags: trace::FLAG_SAMPLED };
        let rid = c.send_infer_traced(MODEL, &img, None, Some(ctx)).unwrap();
        let (resp, echoed) = c.recv_with_trace().expect("reply");
        match resp {
            Response::Logits { request_id, logits, .. } => {
                assert_eq!(request_id, rid);
                assert_eq!(logits.len(), direct.data().len());
                for (a, b) in logits.iter().zip(direct.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "COMQ_TRACE=off must be bit-identical");
                }
            }
            other => panic!("expected logits, got {other:?}"),
        }
        assert_eq!(echoed, None, "tracing off: the server ignores wire contexts entirely");
    }
    assert_eq!(trace::events_buffered(), 0, "no span events under COMQ_TRACE=off");
    assert!(trace::retained().is_empty(), "nothing retained under COMQ_TRACE=off");
    assert_eq!(recorder::len(), 0, "flight-recorder ring stays empty");
    assert_eq!(recorder::count(RecKind::Admit), 0);
    server.shutdown();
    assert_eq!(recorder::count(RecKind::Drain), 0, "recorder off: even the drain is unrecorded");
}

/// The acceptance check: one traced request's span tree telescopes —
/// batcher stages are exactly contiguous (cut from shared instants),
/// contained in the root `request` span, which is itself bounded by the
/// client-observed wire latency; the Chrome export parses and carries
/// the tree.
#[test]
fn span_tree_telescopes_to_wire_latency() {
    let _g = guard();
    let (_manifest, qm) = fixture("telescope");
    fresh(TraceMode::All);
    let server =
        NetServer::bind("127.0.0.1:0", vec![(MODEL.to_string(), qm.clone())], net_config())
            .unwrap();
    let mut c = client(&server);
    let mut rng = Rng::new(0x7E1E);
    let img = rng.normal_vec(ELEMS);
    let ctx = trace::mint_client();
    let t0 = Instant::now();
    let rid = c.send_infer_traced(MODEL, &img, None, Some(ctx)).unwrap();
    loop {
        match c.recv().expect("reply") {
            Response::Logits { request_id, .. } if request_id == rid => break,
            other => panic!("unexpected {other:?}"),
        }
    }
    let wall_ns = t0.elapsed().as_nanos() as u64;

    let evs = trace::events_of(ctx.id);
    let span = |name: &str| -> (u64, u64) {
        let e = evs
            .iter()
            .find(|e| e.name == name)
            .unwrap_or_else(|| panic!("span '{name}' missing from {evs:?}"));
        (e.start_ns, e.dur_ns)
    };
    let (req_s, req_d) = span("request");
    let (adm_s, adm_d) = span("admission");
    let (qw_s, qw_d) = span("queue_wait");
    let (co_s, co_d) = span("coalesce");
    let (ex_s, ex_d) = span("exec");
    let (ep_s, ep_d) = span("epilogue");

    // batcher stages are cut from shared instants: exactly contiguous,
    // no gaps and no overlap (the telescoping identity, in nanoseconds)
    assert_eq!(qw_s + qw_d, co_s, "queue_wait must end where coalesce starts");
    assert_eq!(co_s + co_d, ex_s, "coalesce must end where exec starts");
    assert_eq!(ex_s + ex_d, ep_s, "exec must end where epilogue starts");

    // tree containment: admission and the batcher pipeline live inside
    // the root request span; write-back ends the tree with the root
    assert!(adm_s >= req_s && adm_s + adm_d <= req_s + req_d);
    assert!(qw_s >= req_s, "queue wait starts after dispatch");
    assert!(ex_s + ex_d <= req_s + req_d, "exec finishes before the reply is written back");
    let (wb_s, wb_d) = span("write_back");
    assert_eq!(wb_s + wb_d, req_s + req_d, "write_back and request close together");

    // per-layer exec breakdown rode along, attributed with its kernel
    let layers: Vec<_> = evs.iter().filter(|e| e.name.starts_with("layer:")).collect();
    assert!(!layers.is_empty(), "per-layer spans must be recorded under the traced id");
    for l in &layers {
        assert!(l.attrs.iter().any(|(k, _)| *k == "kernel"));
        assert!(l.attrs.iter().any(|(k, v)| *k == "batch" && v.parse::<u64>().unwrap() >= 1));
        assert!(l.start_ns >= ex_s && l.start_ns + l.dur_ns <= ex_s + ex_d);
    }

    // ...and the whole tree is bounded by what the client measured on
    // the wire (the µs-level slack of the acceptance criterion is free
    // here: the client timestamps *surround* the server's)
    assert!(
        req_d <= wall_ns,
        "server-side request span ({req_d} ns) cannot exceed wire latency ({wall_ns} ns)"
    );

    // the export is valid Chrome trace-event JSON carrying this tree
    let doc = Json::parse(&trace::export_chrome()).expect("export parses");
    let events = doc.get("traceEvents").unwrap().arr().unwrap();
    let field = |e: &Json, k: &str| e.get(k).and_then(|v| v.str()).ok().map(str::to_string);
    let lanes = events.iter().filter(|e| field(e, "ph").as_deref() == Some("M")).count();
    assert!(lanes >= 1, "one metadata lane per retained trace");
    assert!(events.iter().any(|e| field(e, "name").as_deref() == Some("request")));
    server.shutdown();
    fresh(TraceMode::Off);
}
