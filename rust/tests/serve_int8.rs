//! Integer serving runtime: `.cqm` round-trips at every bit width,
//! integer-path vs dequantized-f32 parity, the micro-batcher, and the
//! model registry. Everything runs on the synthetic `tiny_plain_cnn`
//! model, so — unlike the `integration_*` suites — none of these tests
//! need the AOT artifact set.

use std::sync::Arc;
use std::time::Duration;

use comq::deploy::{load_packed, read_packed, save_packed, save_packed_with_act, PackedAct, PackedLayer};
use comq::manifest::Manifest;
use comq::model::{Model, Tap};
use comq::proptest::{forall, quantize_all_layers, tiny_mobile_cnn, tiny_plain_cnn};
use comq::serve::{load_cached, ActSource, BatchConfig, QuantizedModel, Server};
use comq::tensor::Tensor;
use comq::util::Rng;

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("comq_serve_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_string_lossy().to_string()
}

fn images(rng: &mut Rng, n: usize) -> Tensor {
    Tensor::new(&[n, 8, 8, 3], rng.normal_vec(n * 8 * 8 * 3))
}

/// The shared fixture (`proptest::quantize_all_layers`), unwrapped.
fn quantize_synthetic(
    manifest: &Manifest,
    model: &Model,
    bits: u32,
    act_bits: u32,
    calib: &Tensor,
) -> (Vec<PackedLayer>, PackedAct, Model) {
    quantize_all_layers(manifest, model, bits, act_bits, calib).unwrap()
}

#[test]
fn cqm_roundtrip_all_bit_widths() {
    let (manifest, model) = tiny_plain_cnn(40);
    let mut rng = Rng::new(41);
    let calib = images(&mut rng, 32);
    for bits in [2u32, 3, 4, 8] {
        // the bitstream edge: at least one layer's code count must not
        // pack to whole 32-bit words at this width
        assert!(
            model.info.quant_layers.iter().any(|l| (l.m * l.n * bits as usize) % 32 != 0),
            "bits={bits}: synthetic model no longer covers the packing edge"
        );
        let (packed, act, qmodel) = quantize_synthetic(&manifest, &model, bits, 8, &calib);
        let path = tmp(&format!("tiny_{bits}bit.cqm"));
        save_packed_with_act(&path, &qmodel, &packed, bits, Some(&act)).unwrap();

        // raw view round-trips codes, grids and the activation entries
        let ck = read_packed(&path).unwrap();
        assert_eq!(ck.bits, bits);
        assert_eq!(ck.layers.len(), packed.len());
        for pl in &packed {
            let got = ck.layers.iter().find(|l| l.name == pl.name).unwrap();
            assert_eq!(got.codes, pl.codes, "bits={bits} layer {}", pl.name);
            assert_eq!(got.delta, pl.delta, "bits={bits} layer {}", pl.name);
            assert_eq!(got.zero, pl.zero, "bits={bits} layer {}", pl.name);
            assert_eq!((got.m, got.n, got.bits), (pl.m, pl.n, pl.bits));
        }
        let ck_act = ck.act.expect("activation grid must round-trip");
        assert_eq!(ck_act.bits, 8);
        for (name, aq) in &act.by_layer {
            let got = ck_act.by_layer[name];
            assert_eq!((got.scale, got.zero, got.bits), (aq.scale, aq.zero, aq.bits), "{name}");
        }
        // the f32 loader reproduces the dequantized weights byte-exactly
        let loaded = load_packed(&manifest, "tiny_plain", &path).unwrap();
        for l in &model.info.quant_layers {
            assert_eq!(loaded.weight(&l.name), qmodel.weight(&l.name), "bits={bits} {}", l.name);
        }
    }
}

/// The acceptance property: integer-path logits match the
/// dequantized-f32 fake-quant reference within 1e-3 relative tolerance,
/// with identical argmax (whenever the reference's top-2 margin exceeds
/// the tolerance — below that the "right" argmax is itself a rounding
/// accident).
#[test]
fn int8_logits_match_f32_reference() {
    forall(8, 0xC0_301, |g| {
        let seed = 1000 + g.case as u64;
        let (manifest, model) = tiny_plain_cnn(seed);
        let bits = *g.choice(&[3u32, 4, 8]);
        let act_bits = *g.choice(&[4u32, 8]);
        let mut rng = Rng::new(seed ^ 0x55);
        let calib = images(&mut rng, 24);
        let (packed, act, qmodel) = quantize_synthetic(&manifest, &model, bits, act_bits, &calib);

        let test_x = images(&mut rng, 5);
        // reference: dequantized f32 weights + fake-quant activations
        let reference = qmodel.forward(&test_x, &mut Tap::ActQ(&act.by_layer));
        // integer path: same codes, same grid, i8 GEMMs
        let qm = QuantizedModel::from_parts(
            model.info.clone(),
            qmodel.params.clone(),
            &packed,
            ActSource::Static { bits: act_bits, by_layer: act.by_layer.clone() },
        )
        .unwrap();
        assert_eq!(qm.int8_layers(), model.info.quant_layers.len());
        let got = qm.forward(&test_x);
        assert_eq!(got.shape(), reference.shape());

        let argmax = |row: &[f32]| {
            row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
        };
        for r in 0..reference.rows() {
            let (rr, gr) = (reference.row(r), got.row(r));
            let mx = rr.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-3);
            let tol = 1e-3 * mx;
            for (j, (a, b)) in gr.iter().zip(rr).enumerate() {
                assert!(
                    (a - b).abs() <= tol,
                    "case {} (W{bits}A{act_bits}) row {r} col {j}: int8 {a} vs f32 {b}",
                    g.case
                );
            }
            let (ai, ri) = (argmax(gr), argmax(rr));
            if ai != ri {
                // only excusable as a genuine near-tie in the reference
                let margin = (rr[ri] - rr[ai]).abs();
                assert!(
                    margin <= tol,
                    "case {} row {r}: argmax {ai} vs {ri} with margin {margin}",
                    g.case
                );
            }
        }
    });
}

/// The ISSUE-5 acceptance property: a depthwise CNN served entirely on
/// the integer path — grouped layers included, no f32 `{l}/W` anywhere
/// — matches the fake-quant f32 reference within 1e-3 relative, argmax
/// included (same excusable-near-tie rule as the dense test).
#[test]
fn int8_serves_depthwise_model_with_no_f32_weights() {
    forall(8, 0xC0_501, |g| {
        let seed = 2000 + g.case as u64;
        let (manifest, model) = tiny_mobile_cnn(seed);
        let bits = *g.choice(&[3u32, 4, 8]);
        let act_bits = *g.choice(&[4u32, 8]);
        let mut rng = Rng::new(seed ^ 0xAA);
        let calib = images(&mut rng, 24);
        let (packed, act, qmodel) = quantize_synthetic(&manifest, &model, bits, act_bits, &calib);

        let test_x = images(&mut rng, 5);
        let reference = qmodel.forward(&test_x, &mut Tap::ActQ(&act.by_layer));
        let qm = QuantizedModel::from_parts(
            model.info.clone(),
            qmodel.params.clone(),
            &packed,
            ActSource::Static { bits: act_bits, by_layer: act.by_layer.clone() },
        )
        .unwrap();
        // every quantizable layer is integer-served; the three depthwise
        // blocks run the grouped kernel and materialize no f32 weight
        assert_eq!(qm.int8_layers(), model.info.quant_layers.len());
        assert_eq!(qm.grouped_layers(), 3);
        for l in model.info.quant_layers.iter() {
            assert!(
                !qm.fp_weight_materialized(&l.name),
                "layer '{}' still holds an f32 weight",
                l.name
            );
        }
        let got = qm.forward(&test_x);
        assert_eq!(got.shape(), reference.shape());

        let argmax = |row: &[f32]| {
            row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
        };
        for r in 0..reference.rows() {
            let (rr, gr) = (reference.row(r), got.row(r));
            let mx = rr.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-3);
            let tol = 1e-3 * mx;
            for (j, (a, b)) in gr.iter().zip(rr).enumerate() {
                assert!(
                    (a - b).abs() <= tol,
                    "case {} (W{bits}A{act_bits}) row {r} col {j}: int8 {a} vs f32 {b}",
                    g.case
                );
            }
            let (ai, ri) = (argmax(gr), argmax(rr));
            if ai != ri {
                let margin = (rr[ri] - rr[ai]).abs();
                assert!(
                    margin <= tol,
                    "case {} row {r}: argmax {ai} vs {ri} with margin {margin}",
                    g.case
                );
            }
        }
    });
}

/// ISSUE-5 regression: the packed codes are authoritative. A stale (or
/// corrupted) caller-supplied f32 `{l}/W` in the `params` map must
/// neither shadow the checkpoint's codes nor survive in the registry —
/// for grouped layers just like dense ones (grouped weights used to be
/// inserted with `or_insert_with`, letting the stale tensor win).
#[test]
fn packed_codes_beat_stale_params_weights() {
    let (manifest, model) = tiny_mobile_cnn(300);
    let mut rng = Rng::new(301);
    let calib = images(&mut rng, 16);
    let (packed, act, qmodel) = quantize_synthetic(&manifest, &model, 4, 8, &calib);
    let act_src = ActSource::Static { bits: 8, by_layer: act.by_layer.clone() };

    let clean = QuantizedModel::from_parts(
        model.info.clone(),
        qmodel.params.clone(),
        &packed,
        act_src.clone(),
    )
    .unwrap();

    // corrupt every quantizable layer's f32 weight (right shape, wrong
    // values) — dense and grouped alike
    let mut corrupted = qmodel.params.clone();
    for l in &model.info.quant_layers {
        corrupted.insert(
            format!("{}/W", l.name),
            Tensor::new(&[l.m, l.n], rng.normal_vec(l.m * l.n)),
        );
    }
    let dirty =
        QuantizedModel::from_parts(model.info.clone(), corrupted, &packed, act_src).unwrap();
    for l in &model.info.quant_layers {
        assert!(
            !dirty.fp_weight_materialized(&l.name),
            "corrupted '{}/W' survived the build",
            l.name
        );
    }
    let x = images(&mut rng, 4);
    let (a, b) = (clean.forward(&x), dirty.forward(&x));
    for (i, (u, v)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(
            u.to_bits(),
            v.to_bits(),
            "logit {i} diverged — stale params weight leaked into serving"
        );
    }
}

/// ISSUE-5: `weight_bits` must not flatten a mixed-precision checkpoint
/// to one number — the registry reports the min..max range across the
/// per-layer code widths.
#[test]
fn weight_bits_range_reports_mixed_precision() {
    use comq::model::collect_stats_native;
    use comq::quant::actq::ActQuant;
    use comq::quant::{comq_gram, QuantConfig};

    let (manifest, model) = tiny_mobile_cnn(400);
    let mut rng = Rng::new(401);
    let calib = images(&mut rng, 16);
    let stats = collect_stats_native(&model, &calib, manifest.batch).unwrap();
    // alternate 2- and 8-bit layers: a genuinely mixed checkpoint
    let mut packed = Vec::new();
    let mut by_layer = std::collections::BTreeMap::new();
    for (i, l) in model.info.quant_layers.iter().enumerate() {
        let bits = if i % 2 == 0 { 2u32 } else { 8 };
        let st = &stats[&l.name];
        let cfg = QuantConfig { bits, ..Default::default() };
        let lq = comq_gram(&st.gram, model.weight(&l.name), &cfg);
        packed.push(PackedLayer::from_quant(&l.name, &lq, bits));
        by_layer.insert(l.name.clone(), ActQuant::from_range(st.min, st.max, 8, 0.95));
    }
    let qm = QuantizedModel::from_parts(
        model.info.clone(),
        model.params.clone(),
        &packed,
        ActSource::Static { bits: 8, by_layer },
    )
    .unwrap();
    assert_eq!(qm.weight_bits_range(), (2, 8));
    assert_eq!(qm.weight_bits_label(), "2..8");
    // mixed widths still serve: the panel bits are per-layer
    let y = qm.forward(&images(&mut rng, 2));
    assert_eq!(y.shape(), &[2, manifest.classes]);
    assert!(y.data().iter().all(|v| v.is_finite()));
}

#[test]
fn micro_batcher_coalesces_and_matches_direct_forward() {
    let (manifest, model) = tiny_plain_cnn(77);
    let mut rng = Rng::new(78);
    let calib = images(&mut rng, 24);
    let (packed, act, qmodel) = quantize_synthetic(&manifest, &model, 4, 8, &calib);
    let qm = Arc::new(
        QuantizedModel::from_parts(
            model.info.clone(),
            qmodel.params.clone(),
            &packed,
            ActSource::Static { bits: 8, by_layer: act.by_layer },
        )
        .unwrap(),
    );
    let n_req = 24;
    let singles: Vec<Vec<f32>> = (0..n_req).map(|_| rng.normal_vec(8 * 8 * 3)).collect();
    // with a static grid every row is independent, so the batched
    // forward must reproduce each request bit-for-bit
    let mut flat = Vec::new();
    for im in &singles {
        flat.extend_from_slice(im);
    }
    let direct = qm.forward(&Tensor::new(&[n_req, 8, 8, 3], flat));

    let server = Server::start(
        qm.clone(),
        BatchConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(25),
            executors: 1,
            pipeline: false,
        },
    );
    let rxs: Vec<_> = singles.iter().map(|im| server.submit(im.clone())).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let logits = rx.recv().unwrap().expect("request must be served, not shed");
        assert_eq!(logits.len(), manifest.classes);
        for (a, b) in logits.iter().zip(direct.row(i)) {
            assert_eq!(a, b, "request {i} differs from direct forward");
        }
    }
    let st = server.stats();
    assert_eq!(st.served, n_req);
    assert!(
        st.batches < n_req,
        "queue never coalesced: {} batches for {n_req} requests",
        st.batches
    );
    drop(server); // joins executors; must not hang
}

/// A depthwise checkpoint round-trips through `.cqm` and serves from
/// disk identically to the in-memory build — the `run-packed --engine
/// int8` route for a MobileNet-style model.
#[test]
fn depthwise_cqm_loads_and_matches_in_memory_build() {
    let (manifest, model) = tiny_mobile_cnn(500);
    let mut rng = Rng::new(501);
    let calib = images(&mut rng, 16);
    let (packed, act, qmodel) = quantize_synthetic(&manifest, &model, 4, 8, &calib);
    let path = tmp("mobile.cqm");
    save_packed_with_act(&path, &qmodel, &packed, 4, Some(&act)).unwrap();

    let from_disk = QuantizedModel::load(&manifest, "tiny_mobile", &path).unwrap();
    let in_memory = QuantizedModel::from_parts(
        model.info.clone(),
        qmodel.params.clone(),
        &packed,
        ActSource::Static { bits: 8, by_layer: act.by_layer },
    )
    .unwrap();
    assert_eq!(from_disk.grouped_layers(), 3);
    assert_eq!(from_disk.int8_layers(), in_memory.int8_layers());
    let x = images(&mut rng, 3);
    let (a, b) = (from_disk.forward(&x), in_memory.forward(&x));
    for (u, v) in a.data().iter().zip(b.data()) {
        assert_eq!(u.to_bits(), v.to_bits(), "disk vs memory serving diverged");
    }
}

#[test]
fn registry_loads_each_checkpoint_once() {
    let (manifest, model) = tiny_plain_cnn(99);
    let mut rng = Rng::new(100);
    let calib = images(&mut rng, 16);
    let (packed, act, qmodel) = quantize_synthetic(&manifest, &model, 4, 8, &calib);
    let path = tmp("registry.cqm");
    save_packed_with_act(&path, &qmodel, &packed, 4, Some(&act)).unwrap();

    let a = load_cached(&manifest, "tiny_plain", &path).unwrap();
    let b = load_cached(&manifest, "tiny_plain", &path).unwrap();
    assert!(Arc::ptr_eq(&a, &b), "second load must hit the registry");
    assert!(comq::serve::registry_len() >= 1);
    assert_eq!(a.int8_layers(), model.info.quant_layers.len());
    assert_eq!(a.weight_bits_range(), (4, 4), "uniform checkpoint: degenerate range");
    assert_eq!(a.weight_bits_label(), "4");
    match a.act_source() {
        ActSource::Static { bits, .. } => assert_eq!(*bits, 8),
        other => panic!("expected static act source, got {other:?}"),
    }
    // the serving working set undercuts the f32 weights it replaces
    let fp32: usize = model.info.quant_layers.iter().map(|l| 4 * l.m * l.n).sum();
    assert!(a.resident_bytes() < fp32, "{} vs {fp32}", a.resident_bytes());
}

#[test]
fn dynamic_act_fallback_when_no_grid_stored() {
    let (manifest, model) = tiny_plain_cnn(123);
    let mut rng = Rng::new(124);
    let calib = images(&mut rng, 16);
    let (packed, _act, qmodel) = quantize_synthetic(&manifest, &model, 4, 8, &calib);
    let path = tmp("no_act.cqm");
    save_packed(&path, &qmodel, &packed, 4).unwrap();

    let ck = read_packed(&path).unwrap();
    assert!(ck.act.is_none(), "save_packed must not invent an act grid");
    let qm = QuantizedModel::load(&manifest, "tiny_plain", &path).unwrap();
    match qm.act_source() {
        ActSource::Dynamic { bits } => assert_eq!(*bits, comq::serve::DEFAULT_ACT_BITS),
        other => panic!("expected dynamic fallback, got {other:?}"),
    }
    let y = qm.forward(&images(&mut rng, 3));
    assert_eq!(y.shape(), &[3, manifest.classes]);
    assert!(y.data().iter().all(|v| v.is_finite()));
}
