//! Bit-identity contract of the work-stealing / NUMA / pipeline
//! executor rebuild (PR 10): parallel execution may redistribute whole
//! disjoint strips across workers and nodes and may slice the forward
//! across pipeline lanes, but it must never change a single reduction
//! order — so logits are equal *bit for bit* across `COMQ_NUMA=off`
//! vs a forced multi-node layout, across the stealing pool vs
//! `COMQ_THREADS=1`, and across the pipelined server vs the direct
//! forward.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use comq::proptest::{quantize_all_layers, tiny_plain_cnn};
use comq::serve::{ActSource, BatchConfig, QuantizedModel, Server};
use comq::tensor::Tensor;
use comq::util::topo::{self, NumaMode};
use comq::util::Rng;

/// Serializes the tests that rewire process-global knobs (the topo
/// override, `COMQ_THREADS`). A knob flipped mid-forward in a sibling
/// test would not break bit-identity — that is the point of the design
/// — but restoring one racily would leak state between tests.
fn knob_lock() -> MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|p| p.into_inner())
}

/// Restores the topology override even if an assertion unwinds.
struct RestoreTopo;
impl Drop for RestoreTopo {
    fn drop(&mut self) {
        topo::set_mode_override(None);
    }
}

/// W4A8-quantize the synthetic plain CNN end to end, in memory — the
/// same fixture the int8 parity tests drive. Panel prep happens inside,
/// so the NUMA layout active *now* decides whether panels are sharded.
fn build_model(seed: u64) -> (Arc<QuantizedModel>, usize) {
    let (manifest, model) = tiny_plain_cnn(seed);
    let mut rng = Rng::new(seed ^ 0xA5);
    let calib = Tensor::new(&[24, 8, 8, 3], rng.normal_vec(24 * 8 * 8 * 3));
    let (packed, act, qmodel) = quantize_all_layers(&manifest, &model, 4, 8, &calib).unwrap();
    let qm = QuantizedModel::from_parts(
        model.info.clone(),
        qmodel.params.clone(),
        &packed,
        ActSource::Static { bits: 8, by_layer: act.by_layer },
    )
    .unwrap();
    (Arc::new(qm), manifest.classes)
}

fn images(rng: &mut Rng, n: usize) -> Tensor {
    Tensor::new(&[n, 8, 8, 3], rng.normal_vec(n * 8 * 8 * 3))
}

fn assert_bits_equal(a: &Tensor, b: &Tensor, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape mismatch");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: logit {i} differs ({x} vs {y})"
        );
    }
}

/// NUMA sharding splits each panel into per-node strip ranges and
/// accumulates node-locally — rebuilding the model under a forced
/// 2-node layout must reproduce the single-node logits exactly, because
/// sharding only changes *where* a strip's reduction runs, never how
/// it reduces.
#[test]
fn numa_off_vs_forced_nodes_logits_bit_identical() {
    let _g = knob_lock();
    let _restore = RestoreTopo;
    let mut rng = Rng::new(0x91A);
    let x = images(&mut rng, 5);

    topo::set_mode_override(Some(NumaMode::Off));
    let (qm_off, classes) = build_model(910);
    let y_off = qm_off.forward(&x);
    assert_eq!(y_off.shape(), &[5, classes]);

    topo::set_mode_override(Some(NumaMode::Force(2)));
    let (qm_numa, _) = build_model(910);
    let y_numa = qm_numa.forward(&x);

    assert_bits_equal(&y_off, &y_numa, "COMQ_NUMA=off vs forced 2-node");
}

/// The stealing scheduler redistributes whole chunks between workers;
/// `COMQ_THREADS=1` bypasses the pool entirely and runs every chunk
/// inline. Same chunk partition, same per-chunk reduction order — same
/// bits.
#[test]
fn work_stealing_matches_single_thread_exec() {
    let _g = knob_lock();
    let (qm, _) = build_model(920);
    let mut rng = Rng::new(0x92B);
    let x = images(&mut rng, 6);
    // stealing path: whatever parallelism the environment grants
    let y_mt = qm.forward(&x);
    // pinned path: pure inline execution, no pool involvement at all
    let pinned = std::env::var("COMQ_THREADS").ok();
    std::env::set_var("COMQ_THREADS", "1");
    let y_st = qm.forward(&x);
    match pinned {
        Some(v) => std::env::set_var("COMQ_THREADS", v),
        None => std::env::remove_var("COMQ_THREADS"),
    }
    assert_bits_equal(&y_mt, &y_st, "work-stealing vs COMQ_THREADS=1");
}

/// The pipelined server slices the same stage plan across lane threads;
/// every request must get the logits the direct forward produces, bit
/// for bit (with fewer than two lanes available it falls back to the
/// classic executor, which this test then covers instead).
#[test]
fn pipelined_server_matches_direct_forward() {
    let (qm, classes) = build_model(930);
    let mut rng = Rng::new(0x93C);
    let n_req = 16;
    let singles: Vec<Vec<f32>> = (0..n_req).map(|_| rng.normal_vec(8 * 8 * 3)).collect();
    let mut flat = Vec::new();
    for im in &singles {
        flat.extend_from_slice(im);
    }
    let direct = qm.forward(&Tensor::new(&[n_req, 8, 8, 3], flat));

    let server = Server::start(
        qm.clone(),
        BatchConfig {
            max_batch: 4,
            max_delay: Duration::from_millis(25),
            executors: 1,
            pipeline: true,
        },
    );
    let rxs: Vec<_> = singles.iter().map(|im| server.submit(im.clone())).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let logits = rx.recv().unwrap().expect("request must be served, not shed");
        assert_eq!(logits.len(), classes);
        for (a, b) in logits.iter().zip(direct.row(i)) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "request {i}: pipelined logits differ from direct forward"
            );
        }
    }
    let st = server.stats();
    assert_eq!(st.served, n_req, "every request answered");
    // joins the head and every lane through the Quit cascade — a wedged
    // lane would hang right here
    drop(server);
}

/// Shutdown with work still queued drains through the lane chain: every
/// queued request is answered before the threads exit.
#[test]
fn pipelined_shutdown_drains_queued_requests() {
    let (qm, _) = build_model(940);
    let mut rng = Rng::new(0x94D);
    let server = Server::start(
        qm,
        BatchConfig {
            max_batch: 2,
            // a long window: requests are still queued when shutdown
            // lands, so the drain path (not the window close) answers
            max_delay: Duration::from_millis(250),
            executors: 1,
            pipeline: true,
        },
    );
    let rxs: Vec<_> = (0..6).map(|_| server.submit(rng.normal_vec(8 * 8 * 3))).collect();
    server.shutdown();
    for rx in rxs {
        // drained requests are answered with logits; a request that
        // raced the flag itself gets a typed Shutdown error — either
        // way the reply arrives
        let _ = rx.recv().expect("reply must arrive through the drain");
    }
}
