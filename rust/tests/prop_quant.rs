//! Property-based tests on quantizer invariants (in-tree harness;
//! see rust/src/proptest).

use comq::proptest::forall;
use comq::quant::grid::{LayerQuant, Scheme};
use comq::quant::{
    comq_gram, comq_residual, comq_workspace, make_quantizer, GramSet, OrderKind, QuantConfig,
    QUANTIZER_NAMES,
};
use comq::tensor::{matmul_at_a, Tensor};

fn random_case(g: &mut comq::proptest::Gen) -> (Tensor, Tensor, GramSet, QuantConfig) {
    let b = g.usize_in(4, 64);
    let m = g.usize_in(2, 32);
    let n = g.usize_in(1, 16);
    let x = g.tensor(&[b, m], 1.0);
    let w = g.tensor_with_outliers(&[m, n], 0.5, 0.05);
    let gram = GramSet::Shared(matmul_at_a(&x));
    let cfg = QuantConfig {
        bits: *g.choice(&[2u32, 3, 4, 8]),
        scheme: *g.choice(&[Scheme::PerChannel, Scheme::PerLayer]),
        order: *g.choice(&[OrderKind::Cyclic, OrderKind::GreedyShared, OrderKind::GreedyPerColumn]),
        iters: g.usize_in(1, 4),
        lam: g.f32_in(0.5, 1.0),
    };
    (x, w, gram, cfg)
}

#[test]
fn all_methods_always_feasible_and_finite() {
    forall(60, 0xC0301, |g| {
        let (_x, w, gram, cfg) = random_case(g);
        for name in QUANTIZER_NAMES {
            let lq = make_quantizer(name).unwrap().quantize(&gram, &w, &cfg);
            assert!(lq.codes_feasible(cfg.bits), "{name} cfg={cfg:?}");
            assert!(lq.q.data().iter().all(|v| v.is_finite()), "{name}");
            assert!(lq.delta.iter().all(|d| d.is_finite() && *d != 0.0), "{name}");
            assert_eq!(lq.q.shape(), w.shape(), "{name}");
        }
    });
}

#[test]
fn comq_never_worse_than_rtn() {
    forall(60, 0xC0302, |g| {
        let (_x, w, gram, cfg) = random_case(g);
        let comq = comq_gram(&gram, &w, &cfg);
        let rtn = make_quantizer("rtn").unwrap().quantize(&gram, &w, &cfg);
        let e_comq = gram.recon_error(&w, &comq.dequant());
        let e_rtn = gram.recon_error(&w, &rtn.dequant());
        // COMQ starts from the RTN-equivalent grid and coordinate descent
        // only ever reduces the objective within a sweep; the δ-update is
        // also monotone. Tiny float slack allowed.
        assert!(
            e_comq <= e_rtn * 1.001 + 1e-6,
            "comq {e_comq} > rtn {e_rtn} (cfg {cfg:?})"
        );
    });
}

#[test]
fn gram_equals_residual_engine() {
    forall(40, 0xC0303, |g| {
        let (x, w, gram, cfg) = random_case(g);
        let a = comq_gram(&gram, &w, &cfg);
        let b = comq_residual(&x, &w, &cfg);
        let agree = a
            .q
            .data()
            .iter()
            .zip(b.q.data())
            .filter(|(p, q)| p == q)
            .count() as f64
            / a.q.len() as f64;
        assert!(agree > 0.95, "only {agree:.3} agreement (cfg {cfg:?})");
        let ea = gram.recon_error(&w, &a.dequant());
        let eb = gram.recon_error(&w, &b.dequant());
        let tol = 0.05 * ea.max(eb).max(1e-6);
        assert!((ea - eb).abs() <= tol, "gram {ea} vs residual {eb}");
    });
}

/// The ISSUE-2 acceptance property: the column-major workspace engine is
/// *bit*-identical to the row-major Gram engine — codes, scales and zero
/// points — across random layers and the full bits × scheme × order
/// grid, on shared and grouped Grams alike.
#[test]
fn workspace_bit_identical_to_gram() {
    forall(40, 0xC0308, |g| {
        let grouped = g.case % 4 == 3; // every 4th case: depthwise layer
        let (w, gram) = if grouped {
            let rows = g.usize_in(4, 32);
            let c = g.usize_in(1, 8);
            let k = g.usize_in(1, 12);
            g.grouped_layer(rows, c, k)
        } else {
            let b = g.usize_in(4, 64);
            let m = g.usize_in(1, 32);
            let n = g.usize_in(1, 16);
            g.shared_layer(b, m, n)
        };
        let iters = g.usize_in(1, 4);
        let lam = g.f32_in(0.5, 1.0);
        for bits in [2u32, 3, 4] {
            for scheme in [Scheme::PerChannel, Scheme::PerLayer] {
                for order in
                    [OrderKind::Cyclic, OrderKind::GreedyShared, OrderKind::GreedyPerColumn]
                {
                    let cfg = QuantConfig { bits, scheme, order, iters, lam };
                    let a = comq_gram(&gram, &w, &cfg);
                    let b = comq_workspace(&gram, &w, &cfg);
                    let ctx = format!("grouped={grouped} cfg={cfg:?}");
                    assert_eq!(a.q.shape(), b.q.shape(), "{ctx}: shape");
                    for (i, (x, y)) in a.q.data().iter().zip(b.q.data()).enumerate() {
                        assert!(
                            x.to_bits() == y.to_bits(),
                            "{ctx}: code {i}: {x} vs {y}"
                        );
                    }
                    for (j, (x, y)) in a.delta.iter().zip(&b.delta).enumerate() {
                        assert!(
                            x.to_bits() == y.to_bits(),
                            "{ctx}: delta {j}: {x} vs {y}"
                        );
                    }
                    assert_eq!(a.zero, b.zero, "{ctx}: zero");
                }
            }
        }
    });
}

/// ISSUE-5: grouped im2col → `dwconv2d` parity across random k, stride
/// and pad — including pad ≥ k (patches that are entirely padding) and
/// the oh·ow = 1 edge. Two properties at once: the direct-fill
/// `im2col_grouped` equals the regrouped dense `im2col` (the old
/// implementation, kept here as the reference), and `dwconv2d` through
/// that layout equals a naive direct depthwise convolution bit-exactly
/// (identical f32 accumulation order).
#[test]
fn grouped_im2col_dwconv2d_parity() {
    use comq::model::{dwconv2d, Tap};
    use comq::tensor::{im2col, im2col_grouped};
    use std::collections::BTreeMap;

    forall(60, 0xC0501, |g| {
        let k = g.usize_in(1, 4);
        // pad up to k+1 so pad ≥ k occurs routinely
        let (pad, stride, h, b, c) = if g.case % 5 == 0 {
            // forced edge: h = k, pad = 0, stride 1 → oh = ow = 1
            (0, 1, k, g.usize_in(1, 2), g.usize_in(1, 5))
        } else {
            let pad = g.usize_in(0, k + 1);
            let hmin = k.saturating_sub(2 * pad).max(1);
            (pad, g.usize_in(1, 3), g.usize_in(hmin, hmin + 4), g.usize_in(1, 2), g.usize_in(1, 5))
        };
        let x = g.tensor(&[b, h, h, c], 1.0);
        let kk = k * k;

        // 1) direct-fill grouped layout == regrouped dense im2col
        let (x3, oh, ow) = im2col_grouped(&x, k, stride, pad);
        let (full, oh2, ow2) = im2col(&x, k, stride, pad);
        assert_eq!((oh, ow), (oh2, ow2));
        let rows = b * oh * ow;
        assert_eq!(x3.shape(), &[rows, c, kk]);
        if g.case % 5 == 0 {
            assert_eq!(oh * ow, 1, "forced 1×1 output edge");
        }
        for r in 0..rows {
            for ch in 0..c {
                for p in 0..kk {
                    let got = x3.data()[(r * c + ch) * kk + p];
                    let want = full.data()[r * kk * c + p * c + ch];
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "k={k} s={stride} p={pad} h={h} r={r} ch={ch} patch {p}"
                    );
                }
            }
        }

        // 2) dwconv2d == naive direct depthwise conv, bit-exactly
        let w = g.tensor(&[kk, c], 0.5);
        let bias = g.tensor(&[c], 0.1);
        let mut params = BTreeMap::new();
        params.insert("dw/W".to_string(), w.clone());
        params.insert("dw/b".to_string(), bias.clone());
        let y = dwconv2d(&params, "dw", &x, k, stride, pad, &mut Tap::None);
        assert_eq!(y.shape(), &[b, oh, ow, c]);
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ch in 0..c {
                        // same accumulation order as dwconv2d: patch
                        // index ascending, padded taps contributing an
                        // exact 0.0·w term
                        let mut s = 0.0f32;
                        for ki in 0..k {
                            for kj in 0..k {
                                let iy = (oy * stride + ki) as isize - pad as isize;
                                let ix = (ox * stride + kj) as isize - pad as isize;
                                let xv = if iy >= 0
                                    && (iy as usize) < h
                                    && ix >= 0
                                    && (ix as usize) < h
                                {
                                    x.data()[((bi * h + iy as usize) * h + ix as usize) * c + ch]
                                } else {
                                    0.0
                                };
                                s += xv * w.at2(ki * k + kj, ch);
                            }
                        }
                        s += bias.data()[ch];
                        let got = y.data()[(((bi * oh + oy) * ow) + ox) * c + ch];
                        assert_eq!(
                            got.to_bits(),
                            s.to_bits(),
                            "k={k} s={stride} p={pad} ({bi},{oy},{ox},{ch})"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn more_bits_never_hurt() {
    forall(40, 0xC0304, |g| {
        let (_x, w, gram, mut cfg) = random_case(g);
        cfg.lam = 1.0;
        let mut errs = Vec::new();
        for bits in [2u32, 4, 8] {
            cfg.bits = bits;
            let lq = comq_gram(&gram, &w, &cfg);
            errs.push(gram.recon_error(&w, &lq.dequant()));
        }
        assert!(
            errs[0] * 1.001 + 1e-9 >= errs[1] && errs[1] * 1.001 + 1e-9 >= errs[2],
            "errors not monotone in bits: {errs:?}"
        );
    });
}

#[test]
fn pack_unpack_identity_all_quantizers() {
    forall(30, 0xC0305, |g| {
        let (_x, w, gram, cfg) = random_case(g);
        let lq = comq_gram(&gram, &w, &cfg);
        if cfg.bits > 8 {
            return;
        }
        let packed = lq.pack_codes(cfg.bits);
        let un = LayerQuant::unpack_codes(&packed, cfg.bits, w.rows(), w.cols(), &lq.zero);
        assert_eq!(un, lq.q);
    });
}

#[test]
fn grid_points_are_rounding_fixed_points() {
    // Dequantized weights are exact fixed points of rounding *on the
    // same grid* (re-deriving the grid from W_q is NOT an invariant:
    // COMQ's optimal codes may not span the full code range, so the
    // re-initialized δ legitimately differs).
    forall(30, 0xC0306, |g| {
        let (_x, w, gram, cfg) = random_case(g);
        let lq = comq_gram(&gram, &w, &cfg);
        let wq = lq.dequant();
        let levels = (1u64 << cfg.bits) as f32 - 1.0;
        for i in 0..wq.rows() {
            for j in 0..wq.cols() {
                let q2 = comq::quant::grid::qround(
                    wq.at2(i, j) / lq.delta[j],
                    lq.zero[j],
                    levels,
                );
                assert_eq!(q2, lq.q.at2(i, j), "({i},{j}) cfg={cfg:?}");
            }
        }
    });
}

#[test]
fn scale_invariance_per_channel() {
    // scaling a column of W scales its quantization commensurately:
    // relative error is invariant
    forall(30, 0xC0307, |g| {
        let b = g.usize_in(8, 48);
        let m = g.usize_in(2, 24);
        let x = g.tensor(&[b, m], 1.0);
        let w = g.tensor(&[m, 1], 0.5);
        let gram = GramSet::Shared(matmul_at_a(&x));
        let cfg = QuantConfig {
            bits: 4,
            scheme: Scheme::PerChannel,
            order: OrderKind::Cyclic,
            iters: 2,
            lam: 1.0,
        };
        let e1 = gram.recon_error(&w, &comq_gram(&gram, &w, &cfg).dequant());
        let k = 16.0f32;
        let wk = w.clone().scale(k);
        let ek = gram.recon_error(&wk, &comq_gram(&gram, &wk, &cfg).dequant());
        // errors scale by k² (same codes, scaled delta)
        let expect = e1 * (k as f64) * (k as f64);
        assert!(
            (ek - expect).abs() <= 0.02 * expect.max(1e-9) + 1e-9,
            "e1={e1} ek={ek} expect={expect}"
        );
    });
}
