//! Crash-safety and integrity tests for the `.cqm` checkpoint
//! lifecycle (PR 9): kill-point injection at every stage of the atomic
//! save, a torn-bytes property sweep over the v2 container, and the
//! deploy-level v1-downgrade / corruption surface.
//!
//! The `COMQ_FAULT` state is process-global, so every test serializes
//! on one lock, and faults are armed via `fault::set_spec`, never the
//! environment.

use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use comq::deploy::{read_packed, save_packed_with_act};
use comq::proptest::{quantize_all_layers, tiny_plain_cnn};
use comq::serve::net::fault;
use comq::tensor::Tensor;
use comq::tensorstore::{
    parse_store_checked, read_store_checked, serialize_store, write_store, Entry, Integrity,
    Store,
};
use comq::util::Rng;

static LOCK: Mutex<()> = Mutex::new(());

fn guard() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("comq_ckpt_lifecycle_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_string_lossy().to_string()
}

/// A store small enough to byte-sweep but exercising both dtypes,
/// multi-dim shapes, and a scalar.
fn sample_store(marker: f32) -> Store {
    let mut s = Store::new();
    s.insert(
        "w0".into(),
        Entry::F32(Tensor::new(&[2, 3], vec![marker, -1.25, 3.0, 0.0, 9.5, -2.0])),
    );
    s.insert("codes".into(), Entry::I32 { shape: vec![4], data: vec![1, -7, 0, 42] });
    s.insert("z".into(), Entry::F32(Tensor::new(&[1], vec![0.125])));
    s
}

fn marker_of(path: &str) -> (f32, Integrity) {
    let loaded = read_store_checked(path).expect("store must load");
    let w0 = loaded.store.get("w0").unwrap().tensor().unwrap().data()[0];
    (w0, loaded.integrity)
}

/// No `.tmp.` litter next to `path` — a failed atomic save cleans up.
fn assert_no_tmp_litter(path: &str) {
    let p = std::path::Path::new(path);
    let dir = p.parent().unwrap();
    let stem = p.file_name().unwrap().to_string_lossy().to_string();
    for e in std::fs::read_dir(dir).unwrap() {
        let name = e.unwrap().file_name().to_string_lossy().to_string();
        assert!(
            !name.starts_with(&format!("{stem}.tmp.")),
            "temp file left behind: {name}"
        );
    }
}

/// Kill the save at every stage of the atomic write path. Whatever
/// stage dies, the previous checkpoint must still load bit-verified,
/// and no temp file may be left behind — the ISSUE's kill-point
/// guarantee.
#[test]
fn save_killed_at_every_stage_leaves_old_file_intact() {
    let _g = guard();
    fault::clear();
    let path = tmp("killpoint.cqm");
    // a previously killed *process* may have left temp litter behind;
    // start clean so the no-litter assertion checks this run only
    let dir = std::path::Path::new(&path).parent().unwrap().to_path_buf();
    for e in std::fs::read_dir(&dir).unwrap() {
        let e = e.unwrap();
        if e.file_name().to_string_lossy().contains(".tmp.") {
            let _ = std::fs::remove_file(e.path());
        }
    }
    let old = sample_store(1.0);
    let new = sample_store(2.0);
    write_store(&path, &old).unwrap();
    assert_eq!(marker_of(&path), (1.0, Integrity::Verified));

    for stage in ["create", "write", "sync", "rename"] {
        fault::set_spec(&format!("io_err:{stage}:1")).unwrap();
        let err = write_store(&path, &new)
            .expect_err("the armed stage must fail the save");
        let msg = format!("{err:#}");
        assert!(
            msg.contains(&format!("injected io_err at {stage}")),
            "error names the killed stage: {msg}"
        );
        assert_eq!(
            marker_of(&path),
            (1.0, Integrity::Verified),
            "old checkpoint intact after a {stage}-stage kill"
        );
        assert_no_tmp_litter(&path);
        fault::clear();
    }

    // budgets are exact: the stage-less spec fires once, then the
    // very next save goes through and the new bytes are live
    let io0 = fault::fired_io_errors();
    fault::set_spec("io_err:1").unwrap();
    write_store(&path, &new).expect_err("first save dies");
    write_store(&path, &new).expect("second save succeeds: budget spent");
    assert_eq!(fault::fired_io_errors() - io0, 1);
    assert_eq!(marker_of(&path), (2.0, Integrity::Verified));
    fault::clear();
}

/// Torn-bytes property sweep: truncate the v2 image at *every* byte
/// boundary and flip *every* byte. Each mutation must yield a typed
/// error — never a panic, never a silently-wrong store. The single
/// exception is documented: cutting exactly at the body/footer seam
/// leaves a structurally-valid v1 file, which loads flagged
/// `Unverified` (the v1-compat downgrade `read_packed` warns about).
#[test]
fn torn_bytes_never_parse_clean() {
    let _g = guard();
    fault::clear();
    let store = sample_store(3.5);
    let bytes = serialize_store(&store);
    // footer = magic(4) + n(4) + 4n entry CRCs + file CRC(4) + n(4) + magic(4)
    let body_len = bytes.len() - (20 + 4 * store.len());

    let full = parse_store_checked(&bytes).expect("pristine image parses");
    assert_eq!(full.integrity, Integrity::Verified);

    for cut in 0..bytes.len() {
        let r = parse_store_checked(&bytes[..cut]);
        if cut == body_len {
            let l = r.expect("footer torn off entirely = valid v1 file");
            assert_eq!(l.integrity, Integrity::Unverified, "v1 downgrade must be flagged");
        } else {
            assert!(r.is_err(), "truncation at byte {cut}/{} must fail", bytes.len());
        }
    }

    let mut work = bytes.clone();
    for i in 0..work.len() {
        work[i] ^= 0xFF;
        assert!(
            parse_store_checked(&work).is_err(),
            "flipped byte {i}/{} must fail the integrity check",
            work.len()
        );
        work[i] ^= 0xFF;
    }
}

/// The load-side fault sites fire inside `read_store_checked`, where
/// every checkpoint load funnels: `corrupt_load` flips a byte after
/// the disk read (caught by the footer), `slow_load` stretches the
/// read (caught by nothing — it must still verify).
#[test]
fn load_faults_fire_in_the_read_path() {
    let _g = guard();
    fault::clear();
    let path = tmp("loadfault.cqm");
    write_store(&path, &sample_store(4.0)).unwrap();

    let c0 = fault::fired_corrupt_loads();
    fault::set_spec("corrupt_load:37:1").unwrap();
    let err = read_store_checked(&path).expect_err("injected flip must be detected");
    assert!(format!("{err:#}").contains("integrity"), "typed integrity error: {err:#}");
    assert_eq!(fault::fired_corrupt_loads() - c0, 1);
    // budget spent: the same file now loads clean
    assert_eq!(marker_of(&path), (4.0, Integrity::Verified));
    fault::clear();

    let s0 = fault::fired_slow_loads();
    fault::set_spec("slow_load:30:1").unwrap();
    let t0 = Instant::now();
    assert_eq!(marker_of(&path), (4.0, Integrity::Verified));
    assert!(t0.elapsed() >= Duration::from_millis(30), "slow_load must actually stall");
    assert_eq!(fault::fired_slow_loads() - s0, 1);
    fault::clear();
}

/// Deploy-level surface on a real quantized checkpoint: a fresh save
/// is `verified`; stripping the footer downgrades the same bytes to a
/// loadable-but-`unverified` v1 file; corrupting one byte mid-file is
/// a typed load error, not a model with silently wrong weights.
#[test]
fn deploy_checkpoints_verify_downgrade_and_reject() {
    let _g = guard();
    fault::clear();
    let (manifest, model) = tiny_plain_cnn(7);
    let mut rng = Rng::new(0xF00D);
    let calib = Tensor::new(&[64, 8, 8, 3], rng.normal_vec(64 * 8 * 8 * 3));
    let (packed, act, qmodel) = quantize_all_layers(&manifest, &model, 4, 8, &calib).unwrap();
    let path = tmp("deploy_v2.cqm");
    save_packed_with_act(&path, &qmodel, &packed, 4, Some(&act)).unwrap();

    let ckpt = read_packed(&path).unwrap();
    assert_eq!(ckpt.integrity, Integrity::Verified);
    assert_eq!(ckpt.layers.len(), packed.len());

    // strip the footer: entry count sits 8 bytes from the end
    let bytes = std::fs::read(&path).unwrap();
    let n = u32::from_le_bytes(bytes[bytes.len() - 8..bytes.len() - 4].try_into().unwrap());
    let body_len = bytes.len() - (20 + 4 * n as usize);
    let v1_path = tmp("deploy_v1.cqm");
    std::fs::write(&v1_path, &bytes[..body_len]).unwrap();
    let v1 = read_packed(&v1_path).unwrap();
    assert_eq!(v1.integrity, Integrity::Unverified, "v1 files load, flagged");
    assert_eq!(v1.layers.len(), ckpt.layers.len(), "same payload either way");

    // one flipped byte in the middle of the body: typed refusal
    let mut evil = bytes.clone();
    let mid = body_len / 2;
    evil[mid] ^= 0x01;
    let evil_path = tmp("deploy_evil.cqm");
    std::fs::write(&evil_path, &evil).unwrap();
    let err = read_packed(&evil_path).expect_err("corrupt checkpoint must not load");
    assert!(format!("{err:#}").contains("integrity"), "typed integrity error: {err:#}");
}
