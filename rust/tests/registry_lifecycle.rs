//! Registry v2 lifecycle tests (PR 9): byte-budgeted eviction,
//! single-flight loading under racing first requests, shared load
//! failures, and counter-for-counter reconciliation between
//! `registry_stats()` and the flight recorder.
//!
//! The registry, its budget, and the fault state are process-global,
//! so every test serializes on one lock, sets the budget it needs, and
//! restores "unlimited" on the way out. The env-driven budget path is
//! covered by `env_budget_smoke`, which ci.sh runs alone under
//! `COMQ_MODEL_BUDGET=1`.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use comq::deploy::save_packed_with_act;
use comq::manifest::Manifest;
use comq::obs::recorder::{self, RecKind};
use comq::obs::trace::{self, TraceMode};
use comq::proptest::{quantize_all_layers, tiny_plain_cnn};
use comq::serve::net::fault;
use comq::serve::{
    load_cached, load_with_info, note_swap, registry_clear_idle, registry_len, registry_stats,
    set_budget,
};
use comq::tensor::Tensor;
use comq::util::Rng;

const MODEL: &str = "tiny_plain";
const ELEMS: usize = 8 * 8 * 3;

static LOCK: Mutex<()> = Mutex::new(());

fn guard() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("comq_registry_lifecycle_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_string_lossy().to_string()
}

/// Save the W4A8 fixture checkpoint under `tag` and hand back the
/// manifest + path (loading is each test's business).
fn checkpoint(tag: &str) -> (Manifest, String) {
    let (manifest, model) = tiny_plain_cnn(7);
    let mut rng = Rng::new(0xF00D);
    let calib = Tensor::new(&[64, 8, 8, 3], rng.normal_vec(64 * ELEMS));
    let (packed, act, qmodel) = quantize_all_layers(&manifest, &model, 4, 8, &calib).unwrap();
    let path = tmp(&format!("{tag}.cqm"));
    save_packed_with_act(&path, &qmodel, &packed, 4, Some(&act)).unwrap();
    (manifest, path)
}

/// Budget pressure evicts the least-recently-used *idle* entry and
/// never a model some holder still pins — and an unmeetable budget
/// degrades to a warning, not an eviction of live weights.
#[test]
fn budget_evicts_idle_lru_never_pinned() {
    let _g = guard();
    fault::clear();
    set_budget(None);
    registry_clear_idle();
    let (manifest, path_a) = checkpoint("budget_a");
    let (_, path_b) = checkpoint("budget_b");
    let (_, path_c) = checkpoint("budget_c");
    let st0 = registry_stats();
    let len0 = registry_len();

    let a = load_cached(&manifest, MODEL, &path_a).unwrap();
    set_budget(Some(a.resident_bytes() as u64)); // exactly one model fits

    // over budget, but A is pinned (we hold it) and B is the fresh
    // load: nothing is evictable, both must survive
    let b = load_cached(&manifest, MODEL, &path_b).unwrap();
    assert_eq!(registry_len() - len0, 2, "pinned entries never evicted");
    assert_eq!(registry_stats().evictions, st0.evictions);

    // A goes idle; the next load must reclaim it (LRU among idle) and
    // still keep pinned B resident
    drop(a);
    let _c = load_cached(&manifest, MODEL, &path_c).unwrap();
    let st = registry_stats();
    assert_eq!(st.evictions - st0.evictions, 1, "exactly the idle A evicted");
    assert_eq!(registry_len() - len0, 2, "B (pinned) + C (fresh)");
    assert!(Arc::strong_count(&b) >= 2, "B never left the registry");

    // A is really gone: loading it again is a fresh disk read
    let loads_before = registry_stats().loads;
    let _a2 = load_cached(&manifest, MODEL, &path_a).unwrap();
    assert_eq!(registry_stats().loads - loads_before, 1, "evicted entry reloads from disk");

    set_budget(None);
}

/// Racing first requests for one (model, path) key: exactly one
/// caller decodes + preps, everyone shares the same `Arc`. Proven two
/// ways — a barrier race (the loads counter can only move once) and a
/// `slow_load`-wedged loader with a waiter provably blocked on its
/// gate.
#[test]
fn double_load_race_is_single_flight() {
    let _g = guard();
    fault::clear();
    set_budget(None);
    registry_clear_idle();
    let (manifest, path) = checkpoint("race");
    let st0 = registry_stats();

    // Manifest isn't Clone; racing threads carry the (Clone) ModelInfo
    let info = manifest.model(MODEL).unwrap().clone();
    let barrier = Arc::new(std::sync::Barrier::new(8));
    let arcs: Vec<_> = (0..8)
        .map(|_| {
            let (i, p, bar) = (info.clone(), path.clone(), barrier.clone());
            std::thread::spawn(move || {
                bar.wait();
                load_with_info(i, &p).unwrap()
            })
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|t| t.join().unwrap())
        .collect();
    for w in &arcs[1..] {
        assert!(Arc::ptr_eq(&arcs[0], w), "all racers share one model");
    }
    assert_eq!(registry_stats().loads - st0.loads, 1, "one decode, 8 winners");

    // waiter path, deterministically: wedge the loader in the disk
    // read, start a second caller mid-wedge, require it to share
    drop(arcs);
    registry_clear_idle();
    let slow0 = fault::fired_slow_loads();
    fault::set_spec("slow_load:200:1").unwrap();
    let loader = {
        let (i, p) = (info.clone(), path.clone());
        std::thread::spawn(move || load_with_info(i, &p).unwrap())
    };
    std::thread::sleep(Duration::from_millis(60));
    let waited = load_cached(&manifest, MODEL, &path).unwrap();
    let loaded = loader.join().unwrap();
    assert!(Arc::ptr_eq(&waited, &loaded), "the waiter shares the wedged loader's result");
    assert_eq!(fault::fired_slow_loads() - slow0, 1, "only the loader touched the disk");
    assert_eq!(registry_stats().loads - st0.loads, 2, "barrier race + wedged load");
    fault::clear();
}

/// A failed load is shared with every waiter (one disk attempt, one
/// counted failure) and does not poison the key: once the file exists
/// the next call loads clean.
#[test]
fn load_failure_is_shared_then_retryable() {
    let _g = guard();
    fault::clear();
    set_budget(None);
    registry_clear_idle();
    let (manifest, good_path) = checkpoint("shared_fail");
    let missing = tmp("not_written_yet.cqm");
    let _ = std::fs::remove_file(&missing);
    let st0 = registry_stats();

    fault::set_spec("slow_load:200:1").unwrap();
    let loader = {
        let (i, p) = (manifest.model(MODEL).unwrap().clone(), missing.clone());
        std::thread::spawn(move || load_with_info(i, &p))
    };
    std::thread::sleep(Duration::from_millis(60));
    let waited = load_cached(&manifest, MODEL, &missing);
    let loaded = loader.join().unwrap();
    let e1 = format!("{:#}", loaded.expect_err("missing file must fail the loader"));
    let e2 = format!("{:#}", waited.expect_err("…and its waiter"));
    assert!(e1.contains("not_written_yet.cqm"), "error names the path: {e1}");
    assert!(e2.contains("not_written_yet.cqm"), "the waiter gets the same story: {e2}");
    let st = registry_stats();
    assert_eq!(st.load_failures - st0.load_failures, 1, "one failure, shared");
    assert_eq!(st.loads - st0.loads, 0);
    fault::clear();

    // the key is not poisoned: put real bytes there and load clean
    std::fs::copy(&good_path, &missing).unwrap();
    let qm = load_cached(&manifest, MODEL, &missing).expect("retry after the file appears");
    assert_eq!(qm.integrity().name(), "verified");
    assert_eq!(registry_stats().loads - st0.loads, 1);
}

/// The ISSUE's reconciliation clause: with the recorder on, every
/// loader/swap/evict counter movement has a matching flight-recorder
/// event — counter-for-counter, no silent paths.
#[test]
fn registry_counters_reconcile_with_recorder() {
    let _g = guard();
    fault::clear();
    set_budget(None);
    registry_clear_idle();
    let (manifest, path_a) = checkpoint("rec_a");
    let (_, path_b) = checkpoint("rec_b");

    trace::set_mode(TraceMode::All);
    recorder::reset();
    let st0 = registry_stats();

    let a = load_cached(&manifest, MODEL, &path_a).unwrap(); // Load
    set_budget(Some(a.resident_bytes() as u64));
    drop(a);
    let _b = load_cached(&manifest, MODEL, &path_b).unwrap(); // Load + Evict(a)
    note_swap(MODEL, "epoch 1 -> 2 (test)"); // Swap

    let st = registry_stats();
    assert_eq!(st.loads - st0.loads, 2);
    assert_eq!(st.evictions - st0.evictions, 1);
    assert_eq!(st.swaps - st0.swaps, 1);
    assert_eq!(recorder::count(RecKind::Load), st.loads - st0.loads);
    assert_eq!(recorder::count(RecKind::Evict), st.evictions - st0.evictions);
    assert_eq!(recorder::count(RecKind::Swap), st.swaps - st0.swaps);
    // and the ring carries the human-readable trail
    let tail = recorder::last(recorder::CAP);
    assert!(tail.iter().any(|e| e.kind == RecKind::Evict && e.detail.contains("budget")));
    assert!(tail.iter().any(|e| e.kind == RecKind::Swap && e.detail.contains("epoch 1 -> 2")));

    trace::set_mode(TraceMode::Off);
    recorder::reset();
    set_budget(None);
}

/// The env-driven `COMQ_MODEL_BUDGET` path. Under a plain `cargo
/// test` the variable is unset and the budget is armed via
/// `set_budget`; ci.sh runs this test alone as `COMQ_MODEL_BUDGET=1
/// cargo test --test registry_lifecycle env_budget_smoke`, proving
/// the one-shot env parse reaches the eviction machinery.
#[test]
fn env_budget_smoke() {
    let _g = guard();
    fault::clear();
    registry_clear_idle();
    match std::env::var("COMQ_MODEL_BUDGET").ok().filter(|s| !s.trim().is_empty()).as_deref() {
        Some("1") => {} // one byte: the env init armed it before any set_budget
        Some(other) => panic!("env_budget_smoke only understands a budget of 1, got '{other}'"),
        None => set_budget(Some(1)),
    }
    let (manifest, path_a) = checkpoint("env_a");
    let (_, path_b) = checkpoint("env_b");
    let st0 = registry_stats();
    let len0 = registry_len();

    // a pinned sole resident over budget survives (unmeetable budget
    // warns instead of ripping weights out from under a holder)...
    let a = load_cached(&manifest, MODEL, &path_a).unwrap();
    assert_eq!(registry_len() - len0, 1);
    // ...but once idle, the next load reclaims it immediately
    drop(a);
    let _b = load_cached(&manifest, MODEL, &path_b).unwrap();
    let st = registry_stats();
    assert_eq!(registry_len() - len0, 1, "one-byte budget keeps exactly the live model");
    assert_eq!(st.evictions - st0.evictions, 1);
    assert_eq!(st.loads - st0.loads, 2);

    set_budget(None);
}
