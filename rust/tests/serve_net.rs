//! Loopback integration tests for the TCP serving tier (PR 7): wire
//! parity with the in-process batcher, typed shed behavior under
//! overload, graceful drain, protocol edge cases, and fault-injection
//! containment with *exact* counter reconciliation.
//!
//! The `COMQ_FAULT` state is process-global, so every test here
//! serializes on one lock and arms faults through `fault::set_spec` /
//! `fault::clear` rather than the environment (the env-driven path is
//! covered by `env_spec_smoke`, which ci.sh runs alone under
//! `COMQ_FAULT=panic:conn:1` and again under `COMQ_FAULT=io_err:1`).
//!
//! No test blocks unboundedly: every client read carries a timeout, so
//! a server that wedges fails the assertion instead of hanging the
//! suite.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use comq::deploy::save_packed_with_act;
use comq::manifest::Manifest;
use comq::proptest::{quantize_all_layers, tiny_plain_cnn};
use comq::serve::net::fault::{self, Site};
use comq::serve::net::frame::{self, ErrorReason};
use comq::serve::net::{AdmissionConfig, ClientError, NetClient, NetConfig, NetServer, Response};
use comq::serve::{load_cached, BatchConfig, QuantizedModel, ServeError, Server};
use comq::tensor::Tensor;
use comq::util::Rng;

const MODEL: &str = "tiny_plain";
const ELEMS: usize = 8 * 8 * 3;
const RECV_TIMEOUT: Duration = Duration::from_secs(10);

/// Fault state is process-global: serialize every test in this binary.
static LOCK: Mutex<()> = Mutex::new(());

fn guard() -> MutexGuard<'static, ()> {
    // a poisoned lock just means an earlier test failed; don't cascade
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("comq_serve_net_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_string_lossy().to_string()
}

/// The W4A8 synthetic-CNN fixture the other serving tests drive.
fn fixture(tag: &str) -> (Manifest, Arc<QuantizedModel>) {
    let (manifest, model) = tiny_plain_cnn(7);
    let mut rng = Rng::new(0xF00D);
    let calib = Tensor::new(&[64, 8, 8, 3], rng.normal_vec(64 * ELEMS));
    let (packed, act, qmodel) = quantize_all_layers(&manifest, &model, 4, 8, &calib).unwrap();
    let path = tmp(&format!("{tag}.cqm"));
    save_packed_with_act(&path, &qmodel, &packed, 4, Some(&act)).unwrap();
    let qm = load_cached(&manifest, MODEL, &path).unwrap();
    (manifest, qm)
}

fn client(server: &NetServer) -> NetClient {
    let mut c = NetClient::connect(server.local_addr()).expect("connect");
    c.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    c
}

fn net_config() -> NetConfig {
    NetConfig {
        batch: BatchConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(2),
            executors: 1,
            pipeline: false,
        },
        ..NetConfig::default()
    }
}

/// Every wire reply must be bit-identical to the direct in-process
/// forward — across concurrent connections, pipelined requests, and
/// both transports (epoll and the portable fallback).
#[test]
fn loopback_parity_with_direct_forward() {
    let _g = guard();
    fault::clear();
    let (_manifest, qm) = fixture("parity");
    for force_fallback in [false, true] {
        let server = NetServer::bind(
            "127.0.0.1:0",
            vec![(MODEL.to_string(), qm.clone())],
            NetConfig { force_fallback, ..net_config() },
        )
        .unwrap();

        // concurrent connections, sequential requests on each
        let addr = server.local_addr();
        let handles: Vec<_> = (0..3)
            .map(|t| {
                let qm = qm.clone();
                std::thread::spawn(move || {
                    let mut c = NetClient::connect(addr).unwrap();
                    c.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
                    let mut rng = Rng::new(0xA11CE + t);
                    for _ in 0..6 {
                        let img = rng.normal_vec(ELEMS);
                        let direct = qm.forward(&Tensor::new(&[1, 8, 8, 3], img.clone()));
                        let logits = c.infer(MODEL, &img).expect("wire inference");
                        assert_eq!(logits.len(), direct.data().len());
                        for (a, b) in logits.iter().zip(direct.data()) {
                            assert_eq!(a.to_bits(), b.to_bits(), "wire logits must be bit-exact");
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("client thread");
        }

        // pipelining: many ids in flight on one connection, replies
        // matched by id whatever order the batcher completed them in
        let mut c = client(&server);
        let mut rng = Rng::new(0xBEEF);
        let imgs: Vec<Vec<f32>> = (0..10).map(|_| rng.normal_vec(ELEMS)).collect();
        let ids: Vec<u32> =
            imgs.iter().map(|im| c.send_infer(MODEL, im, None).unwrap()).collect();
        let mut got = 0;
        while got < ids.len() {
            match c.recv().expect("pipelined reply") {
                Response::Logits { request_id, logits, .. } => {
                    let idx = ids.iter().position(|&i| i == request_id).expect("known id");
                    let direct = qm.forward(&Tensor::new(&[1, 8, 8, 3], imgs[idx].clone()));
                    for (a, b) in logits.iter().zip(direct.data()) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                    got += 1;
                }
                other => panic!("unexpected reply {other:?}"),
            }
        }

        let stats = server.stats();
        assert_eq!(stats.inflight, 0, "all requests answered");
        assert_eq!(stats.error_frames, 0);
        assert!(stats.frames >= 28, "3*6 + 10 infer frames, got {}", stats.frames);
        server.shutdown();
        assert_eq!(server.model_server(MODEL).unwrap().queue_depth(), 0);
    }
}

/// Under overload (admission limit 1 + an injected slow executor) the
/// excess request gets a typed `Overloaded` frame on a healthy
/// connection; a request whose deadline passes while queued gets
/// `DeadlineExceeded`. Counters reconcile exactly.
#[test]
fn overload_and_deadline_shed_are_typed() {
    let _g = guard();
    fault::clear();
    let (_manifest, qm) = fixture("shed");
    let mut rng = Rng::new(0x5EED);

    // --- overload: one token, the second concurrent request is shed
    {
        fault::set_spec("slow:300:1").unwrap();
        let server = NetServer::bind(
            "127.0.0.1:0",
            vec![(MODEL.to_string(), qm.clone())],
            NetConfig {
                batch: BatchConfig {
                    max_batch: 1,
                    max_delay: Duration::from_millis(0),
                    executors: 1,
                    pipeline: false,
                },
                admission: AdmissionConfig { max_inflight: 1, max_queue: 64 },
                ..NetConfig::default()
            },
        )
        .unwrap();
        let mut c = client(&server);
        let img = rng.normal_vec(ELEMS);
        let id1 = c.send_infer(MODEL, &img, None).unwrap();
        // wait until the slow executor holds request 1's token
        let t0 = Instant::now();
        while fault::fired_slow() == 0 && t0.elapsed() < RECV_TIMEOUT {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(fault::fired_slow(), 1, "slow fault must have fired");
        let id2 = c.send_infer(MODEL, &img, None).unwrap();
        // the shed reply overtakes the slow one
        match c.recv().expect("shed reply") {
            Response::Error { request_id, reason, .. } => {
                assert_eq!(request_id, id2);
                assert_eq!(reason, ErrorReason::Overloaded);
            }
            other => panic!("expected Overloaded for request 2, got {other:?}"),
        }
        match c.recv().expect("slow reply") {
            Response::Logits { request_id, .. } => assert_eq!(request_id, id1),
            other => panic!("expected logits for request 1, got {other:?}"),
        }
        let st = server.model_server(MODEL).unwrap().stats();
        assert_eq!(st.shed_overload, 1, "exactly the one injected overload shed");
        assert_eq!(st.shed_deadline, 0);
        assert_eq!(server.stats().error_frames, 1);
        server.shutdown();
        assert_eq!(server.model_server(MODEL).unwrap().queue_depth(), 0);
        fault::clear();
    }

    // --- queue-depth shedding: max_queue 0 sheds before the batcher
    {
        let server = NetServer::bind(
            "127.0.0.1:0",
            vec![(MODEL.to_string(), qm.clone())],
            NetConfig {
                admission: AdmissionConfig { max_inflight: 8, max_queue: 0 },
                ..net_config()
            },
        )
        .unwrap();
        let mut c = client(&server);
        let err = c.infer(MODEL, &rng.normal_vec(ELEMS)).unwrap_err();
        match err {
            ClientError::Server { reason, .. } => assert_eq!(reason, ErrorReason::Overloaded),
            other => panic!("expected a typed Overloaded error, got {other:?}"),
        }
        let st = server.model_server(MODEL).unwrap().stats();
        assert_eq!(st.shed_overload, 1);
        assert_eq!(st.served, 0, "a queue-shed request must never reach the GEMM");
    }

    // --- deadline: the budget expires while the executor is busy
    {
        fault::set_spec("slow:300:1").unwrap();
        let server = NetServer::bind(
            "127.0.0.1:0",
            vec![(MODEL.to_string(), qm.clone())],
            NetConfig {
                batch: BatchConfig {
                    max_batch: 1,
                    max_delay: Duration::from_millis(0),
                    executors: 1,
                    pipeline: false,
                },
                ..NetConfig::default()
            },
        )
        .unwrap();
        let mut c = client(&server);
        let img = rng.normal_vec(ELEMS);
        let id1 = c.send_infer(MODEL, &img, None).unwrap();
        let t0 = Instant::now();
        while fault::fired_slow() == 0 && t0.elapsed() < RECV_TIMEOUT {
            std::thread::sleep(Duration::from_millis(5));
        }
        // budget far shorter than the 300 ms the executor is stuck
        let id2 = c.send_infer(MODEL, &img, Some(Duration::from_millis(30))).unwrap();
        let mut saw_logits = false;
        let mut saw_deadline = false;
        for _ in 0..2 {
            match c.recv().expect("reply") {
                Response::Logits { request_id, .. } => {
                    assert_eq!(request_id, id1);
                    saw_logits = true;
                }
                Response::Error { request_id, reason, .. } => {
                    assert_eq!(request_id, id2);
                    assert_eq!(reason, ErrorReason::DeadlineExceeded);
                    saw_deadline = true;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(saw_logits && saw_deadline);
        let st = server.model_server(MODEL).unwrap().stats();
        assert_eq!(st.shed_deadline, 1, "exactly the one expired request shed");
        assert_eq!(st.served, 1);
        fault::clear();
    }
}

/// Graceful drain: shutdown stops accepting but answers everything
/// already admitted, on both transports.
#[test]
fn graceful_drain_answers_inflight() {
    let _g = guard();
    fault::clear();
    let (_manifest, qm) = fixture("drain");
    for force_fallback in [false, true] {
        fault::set_spec("slow:250:1").unwrap();
        let server = NetServer::bind(
            "127.0.0.1:0",
            vec![(MODEL.to_string(), qm.clone())],
            NetConfig { force_fallback, ..net_config() },
        )
        .unwrap();
        let addr = server.local_addr();
        let qm2 = qm.clone();
        let h = std::thread::spawn(move || {
            let mut c = NetClient::connect(addr).unwrap();
            c.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
            let mut rng = Rng::new(0xD7A1);
            let img = rng.normal_vec(ELEMS);
            let direct = qm2.forward(&Tensor::new(&[1, 8, 8, 3], img.clone()));
            // in flight when the drain starts; must still be answered
            let logits = c.infer(MODEL, &img).expect("drained request must be answered");
            for (a, b) in logits.iter().zip(direct.data()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
        // wait until the request is in the slow executor, then drain
        let t0 = Instant::now();
        while fault::fired_slow() == 0 && t0.elapsed() < RECV_TIMEOUT {
            std::thread::sleep(Duration::from_millis(5));
        }
        server.shutdown();
        h.join().expect("client thread");
        let st = server.model_server(MODEL).unwrap().stats();
        assert_eq!(st.served, 1);
        assert_eq!(server.stats().inflight, 0, "drain must leave nothing in flight");
        assert_eq!(server.model_server(MODEL).unwrap().queue_depth(), 0);
        fault::clear();
    }
}

/// Raw-socket helper: write `bytes`, then read until EOF/timeout and
/// return the first decoded reply frame's error reason (if any) and
/// whether the server closed the connection.
fn raw_exchange(addr: std::net::SocketAddr, bytes: &[u8]) -> (Option<ErrorReason>, bool) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(3))).unwrap();
    s.write_all(bytes).unwrap();
    let _ = s.shutdown(std::net::Shutdown::Write);
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut closed = false;
    loop {
        match s.read(&mut chunk) {
            Ok(0) => {
                closed = true;
                break;
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) => break, // timeout: server kept the conn open
        }
    }
    let reason = match frame::decode(&buf) {
        Ok(Some((f, _))) => f.error_reason().ok().map(|(r, _)| r),
        _ => None,
    };
    (reason, closed)
}

/// Protocol damage answers a typed error frame and costs exactly that
/// connection; the server and other connections stay healthy.
#[test]
fn wire_edge_cases_are_typed_and_contained() {
    let _g = guard();
    fault::clear();
    let (_manifest, qm) = fixture("edges");
    let server =
        NetServer::bind("127.0.0.1:0", vec![(MODEL.to_string(), qm.clone())], net_config())
            .unwrap();
    let addr = server.local_addr();
    let mut rng = Rng::new(0xED6E);
    let img = rng.normal_vec(ELEMS);

    // not a COMQ frame at all
    let (reason, closed) = raw_exchange(addr, b"GET / HTTP/1.1\r\n\r\n");
    assert_eq!(reason, Some(ErrorReason::BadMagic));
    assert!(closed);

    // right magic, wrong version
    let mut bad_version = frame::encode_infer(1, MODEL, 0, &img);
    bad_version[4] = 99;
    let (reason, closed) = raw_exchange(addr, &bad_version);
    assert_eq!(reason, Some(ErrorReason::UnsupportedVersion));
    assert!(closed);

    // oversized declared payload, rejected before the bytes arrive
    let mut oversized = frame::encode_metrics_req(2);
    oversized[20..24].copy_from_slice(&((frame::MAX_PAYLOAD as u32) + 1).to_le_bytes());
    let (reason, closed) = raw_exchange(addr, &oversized);
    assert_eq!(reason, Some(ErrorReason::Oversized));
    assert!(closed);

    // truncated: a valid prefix, then the stream ends mid-frame
    let whole = frame::encode_infer(3, MODEL, 0, &img);
    let (reason, closed) = raw_exchange(addr, &whole[..whole.len() / 2]);
    assert_eq!(reason, Some(ErrorReason::Malformed));
    assert!(closed);

    // mid-stream hard drop (no write shutdown, connection just dies):
    // nothing to assert on this socket — the server must simply survive
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&whole[..10]).unwrap();
        drop(s);
    }

    // unknown model: well-formed frame, typed reply, connection-fatal
    let mut c = client(&server);
    match c.infer("no_such_model", &img).unwrap_err() {
        ClientError::Server { reason, .. } => assert_eq!(reason, ErrorReason::UnknownModel),
        other => panic!("expected UnknownModel, got {other:?}"),
    }

    // wrong payload geometry
    let mut c = client(&server);
    match c.infer(MODEL, &img[..ELEMS - 1]).unwrap_err() {
        ClientError::Server { reason, .. } => assert_eq!(reason, ErrorReason::BadPayload),
        other => panic!("expected BadPayload, got {other:?}"),
    }

    // after all of that damage, a fresh connection still serves with
    // bit-exact parity and the registry entry is untouched
    let direct = qm.forward(&Tensor::new(&[1, 8, 8, 3], img.clone()));
    let mut c = client(&server);
    let logits = c.infer(MODEL, &img).expect("healthy after protocol damage");
    for (a, b) in logits.iter().zip(direct.data()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let stats = server.stats();
    assert!(stats.error_frames >= 6, "each damaged exchange answered typed");
    assert_eq!(stats.inflight, 0);
}

/// An injected executor panic storm: every in-flight request is
/// answered with a typed error (no hangs), the executor respawns, and
/// throughput recovers. Counters match the injected count exactly.
#[test]
fn executor_panic_storm_recovers() {
    let _g = guard();
    fault::clear();
    let (_manifest, qm) = fixture("panics");
    const STORM: usize = 3;
    fault::set_spec(&format!("panic:exec:{STORM}")).unwrap();
    let server = NetServer::bind(
        "127.0.0.1:0",
        vec![(MODEL.to_string(), qm.clone())],
        NetConfig {
            batch: BatchConfig {
                max_batch: 1,
                max_delay: Duration::from_millis(0),
                executors: 1,
                pipeline: false,
            },
            ..NetConfig::default()
        },
    )
    .unwrap();
    let mut c = client(&server);
    let mut rng = Rng::new(0x9A71C);
    // one panic per single-request batch: the storm answers errors...
    for i in 0..STORM {
        match c.infer(MODEL, &rng.normal_vec(ELEMS)).unwrap_err() {
            ClientError::Server { reason, .. } => {
                assert_eq!(reason, ErrorReason::ExecutorPanicked, "storm request {i}")
            }
            other => panic!("expected ExecutorPanicked, got {other:?}"),
        }
    }
    // ...and once the budget is exhausted, the respawned executor serves
    for _ in 0..5 {
        let img = rng.normal_vec(ELEMS);
        let direct = qm.forward(&Tensor::new(&[1, 8, 8, 3], img.clone()));
        let logits = c.infer(MODEL, &img).expect("throughput must recover after the storm");
        for (a, b) in logits.iter().zip(direct.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    assert_eq!(fault::fired_panics(Site::Exec), STORM as u64);
    let st = server.model_server(MODEL).unwrap().stats();
    assert_eq!(st.respawns, STORM, "one respawn per injected panic, exactly");
    assert_eq!(st.served, 5);
    server.shutdown();
    assert_eq!(server.stats().inflight, 0);
    fault::clear();
}

/// A panic in the connection handler costs one connection (typed
/// `Internal` reply), never the process.
#[test]
fn conn_panic_is_contained() {
    let _g = guard();
    fault::clear();
    let (_manifest, qm) = fixture("connpanic");
    fault::set_spec("panic:conn:1").unwrap();
    let server =
        NetServer::bind("127.0.0.1:0", vec![(MODEL.to_string(), qm.clone())], net_config())
            .unwrap();
    let mut rng = Rng::new(0xC0117);
    let img = rng.normal_vec(ELEMS);
    let mut c = client(&server);
    match c.infer(MODEL, &img).unwrap_err() {
        ClientError::Server { reason, .. } => assert_eq!(reason, ErrorReason::Internal),
        ClientError::Io(_) => {} // reply raced the close — still contained
        other => panic!("expected Internal or a closed conn, got {other:?}"),
    }
    assert_eq!(fault::fired_panics(Site::Conn), 1);
    // a fresh connection is unaffected
    let mut c = client(&server);
    c.infer(MODEL, &img).expect("server must survive a conn-handler panic");
    fault::clear();
}

/// An injected reply corruption is detected by the client as a typed
/// frame error — and the server survives it.
#[test]
fn garbage_reply_detected_by_client() {
    let _g = guard();
    fault::clear();
    let (_manifest, qm) = fixture("garbage");
    fault::set_spec("garbage_frame:1").unwrap();
    let server =
        NetServer::bind("127.0.0.1:0", vec![(MODEL.to_string(), qm.clone())], net_config())
            .unwrap();
    let mut rng = Rng::new(0x6A6);
    let img = rng.normal_vec(ELEMS);
    let mut c = client(&server);
    match c.infer(MODEL, &img).unwrap_err() {
        ClientError::Frame(e) => {
            assert_eq!(e.reason(), ErrorReason::BadMagic, "corrupted magic detected")
        }
        other => panic!("expected a frame error, got {other:?}"),
    }
    // budget exhausted: the next reply is clean (new connection; the
    // old one has undecodable residue)
    let mut c = client(&server);
    c.infer(MODEL, &img).expect("only the one injected corruption");
    fault::clear();
}

/// `drop_conn` closes exactly its budgeted count of connections at
/// accept; later connections serve normally.
#[test]
fn drop_conn_fault_is_deterministic() {
    let _g = guard();
    fault::clear();
    let (_manifest, qm) = fixture("dropconn");
    fault::set_spec("drop_conn:1:2").unwrap(); // p=1 → every conn, budget 2
    let server =
        NetServer::bind("127.0.0.1:0", vec![(MODEL.to_string(), qm.clone())], net_config())
            .unwrap();
    let mut rng = Rng::new(0xD409);
    let img = rng.normal_vec(ELEMS);
    let mut failures = 0;
    for _ in 0..2 {
        let mut c = client(&server);
        match c.infer(MODEL, &img) {
            Err(ClientError::Io(_)) => failures += 1,
            other => panic!("dropped connection must surface as an IO error, got {other:?}"),
        }
    }
    assert_eq!(failures, 2);
    assert_eq!(fault::fired_drops(), 2);
    // budget exhausted: the third connection works
    let mut c = client(&server);
    c.infer(MODEL, &img).expect("third connection must be served");
    let stats = server.stats();
    assert_eq!(stats.dropped_conns, 2, "stats must match the injected count exactly");
    fault::clear();
}

/// The Prometheus exposition travels over the same transport.
#[test]
fn metrics_over_the_wire() {
    let _g = guard();
    fault::clear();
    let (_manifest, qm) = fixture("metrics");
    let server =
        NetServer::bind("127.0.0.1:0", vec![(MODEL.to_string(), qm.clone())], net_config())
            .unwrap();
    let mut rng = Rng::new(0x3E7);
    let mut c = client(&server);
    c.infer(MODEL, &rng.normal_vec(ELEMS)).unwrap();
    let text = c.metrics().expect("metrics frame");
    if comq::obs::enabled() {
        for needle in ["comq_serve_requests_total", "comq_net_frames_total"] {
            assert!(text.contains(needle), "metrics must carry {needle}:\n{text}");
        }
    } else {
        assert!(text.is_empty(), "COMQ_OBS=off keeps the registry empty");
    }
}

/// Batcher-level regressions that need no socket: shutdown wakes idle
/// executors immediately (the old code polled a 20 ms timeout to paper
/// over a lost-wakeup race), and an already-expired request is shed at
/// submit.
#[test]
fn batcher_shutdown_is_immediate_and_stale_requests_shed() {
    let _g = guard();
    fault::clear();
    let (_manifest, qm) = fixture("batcher");

    // idle shutdown: executors are parked on the condvar; the flag flips
    // under the queue lock so the wakeup cannot be lost. With the old
    // lost-wakeup bug this would hang forever, not just 20 ms — the
    // bound is generous to stay unflaky, the failure mode it catches is
    // a hang.
    let server = Server::start(
        qm.clone(),
        BatchConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(50),
            executors: 2,
            pipeline: false,
        },
    );
    std::thread::sleep(Duration::from_millis(30)); // let executors park
    let t = Instant::now();
    server.shutdown();
    assert!(
        t.elapsed() < Duration::from_secs(2),
        "idle shutdown must be immediate, took {:?}",
        t.elapsed()
    );

    // shutdown with work queued: drained and answered, not dropped
    let server = Server::start(
        qm.clone(),
        BatchConfig {
            max_batch: 8,
            max_delay: Duration::from_secs(5),
            executors: 1,
            pipeline: false,
        },
    );
    let mut rng = Rng::new(0x57A1E);
    let rx = server.submit(rng.normal_vec(ELEMS));
    server.shutdown();
    rx.recv().expect("drained reply").expect("queued request must be answered at shutdown");

    // stale at submit: shed before it ever takes a queue slot
    let server = Server::start(qm.clone(), BatchConfig::default());
    let rx = server.submit_deadline(rng.normal_vec(ELEMS), Some(Instant::now()));
    assert_eq!(rx.recv().unwrap(), Err(ServeError::DeadlineExceeded));
    let st = server.stats();
    assert_eq!(st.shed_deadline, 1);
    assert_eq!(server.queue_depth(), 0);
}

/// Hot-swap under live traffic: in-flight requests are answered from
/// the epoch that admitted them (zero drops), new requests ride the
/// new weights, pins to the retired epoch get a typed retryable
/// error, and the registry's swap/evict/load counters reconcile
/// exactly against the staged sequence.
#[test]
fn hot_swap_serves_both_epochs_without_drops() {
    let _g = guard();
    fault::clear();
    // two checkpoints of one architecture with different weights (4-
    // vs 2-bit quantization of the same float model)
    let (manifest, model) = tiny_plain_cnn(7);
    let mut rng = Rng::new(0xF00D);
    let calib = Tensor::new(&[64, 8, 8, 3], rng.normal_vec(64 * ELEMS));
    let (packed_a, act_a, qmodel_a) =
        quantize_all_layers(&manifest, &model, 4, 8, &calib).unwrap();
    let (packed_b, act_b, qmodel_b) =
        quantize_all_layers(&manifest, &model, 2, 8, &calib).unwrap();
    let path_a = tmp("swap_a.cqm");
    let path_b = tmp("swap_b.cqm");
    save_packed_with_act(&path_a, &qmodel_a, &packed_a, 4, Some(&act_a)).unwrap();
    save_packed_with_act(&path_b, &qmodel_b, &packed_b, 2, Some(&act_b)).unwrap();
    let qm_a = load_cached(&manifest, MODEL, &path_a).unwrap();
    let qm_b = load_cached(&manifest, MODEL, &path_b).unwrap();
    let img = rng.normal_vec(ELEMS);
    let direct_a = qm_a.forward(&Tensor::new(&[1, 8, 8, 3], img.clone())).data().to_vec();
    let direct_b = qm_b.forward(&Tensor::new(&[1, 8, 8, 3], img.clone())).data().to_vec();
    assert_ne!(direct_a, direct_b, "fixture must actually change the weights");
    let st0 = comq::serve::registry_stats();

    let server =
        NetServer::bind("127.0.0.1:0", vec![(MODEL.to_string(), qm_a.clone())], net_config())
            .unwrap();
    assert_eq!(server.model_server(MODEL).unwrap().epoch, 1);

    // a swap to a missing file is a typed error; epoch 1 keeps serving
    let mut c = client(&server);
    match c.swap(MODEL, &tmp("no_such.cqm")).unwrap_err() {
        ClientError::Server { reason, message } => {
            assert_eq!(reason, ErrorReason::ModelUnavailable);
            assert!(message.contains("no_such.cqm"), "error names the path: {message}");
        }
        other => panic!("expected a typed swap failure, got {other:?}"),
    }
    assert_eq!(c.infer(MODEL, &img).unwrap(), direct_a, "old weights keep serving");
    assert_eq!(server.model_server(MODEL).unwrap().epoch, 1);

    // wedge one request inside epoch 1's single executor so the swap
    // provably overlaps in-flight work...
    let slow0 = fault::fired_slow();
    fault::set_spec("slow:400:1").unwrap();
    let mut c_slow = client(&server);
    let slow_id = c_slow.send_infer(MODEL, &img, None).unwrap();
    let t0 = Instant::now();
    while fault::fired_slow() == slow0 {
        assert!(t0.elapsed() < RECV_TIMEOUT, "slow fault never fired");
        std::thread::sleep(Duration::from_millis(5));
    }
    // ...and hammer from a second connection while the flip happens.
    // Every reply must be bit-exact for the epoch that answered it —
    // never a blend, never an error, never a drop.
    let addr = server.local_addr();
    let (img2, da, db) = (img.clone(), direct_a.clone(), direct_b.clone());
    let hammer = std::thread::spawn(move || {
        let mut c = NetClient::connect(addr).unwrap();
        c.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
        let (mut n_a, mut n_b) = (0u32, 0u32);
        for i in 0..2000 {
            let id = c.send_infer(MODEL, &img2, None).unwrap();
            match c.recv().unwrap() {
                Response::Logits { request_id, logits, epoch } if request_id == id => {
                    match epoch {
                        Some(1) => {
                            assert_eq!(logits, da, "epoch-1 reply = old weights (iter {i})");
                            n_a += 1;
                        }
                        Some(2) => {
                            assert_eq!(logits, db, "epoch-2 reply = new weights (iter {i})");
                            n_b += 1;
                        }
                        e => panic!("reply from unknown epoch {e:?} (iter {i})"),
                    }
                }
                other => panic!("hammer got a non-logits reply: {other:?}"),
            }
            if n_b > 0 {
                break; // observed the new weights — overlap proven
            }
        }
        (n_a, n_b)
    });

    let mut c_swap = client(&server);
    let (old_e, new_e) = c_swap.swap(MODEL, &path_b).expect("swap succeeds");
    assert_eq!((old_e, new_e), (1, 2));
    // the wedged request was answered from the epoch that admitted it
    match c_slow.recv().unwrap() {
        Response::Logits { request_id, logits, epoch } => {
            assert_eq!(request_id, slow_id);
            assert_eq!(epoch, Some(1), "in-flight request answered by its admitting epoch");
            assert_eq!(logits, direct_a);
        }
        other => panic!("wedged request must be answered, got {other:?}"),
    }
    let (_n_a, n_b) = hammer.join().unwrap();
    assert!(n_b > 0, "hammer never saw the new weights");

    // pins: the retired epoch is a typed, non-fatal error on a still-
    // usable connection; the current epoch pin works
    match c.infer(&format!("{MODEL}@1"), &img).unwrap_err() {
        ClientError::Server { reason, message } => {
            assert_eq!(reason, ErrorReason::ModelUnavailable);
            assert!(message.contains("retired"), "says why: {message}");
        }
        other => panic!("expected ModelUnavailable, got {other:?}"),
    }
    assert_eq!(c.infer(&format!("{MODEL}@2"), &img).unwrap(), direct_b, "current-epoch pin");
    assert_eq!(c.infer(MODEL, &img).unwrap(), direct_b, "bare name takes the new weights");

    // the listing reflects the flip and carries the registry ledger
    let listing = c.models().unwrap();
    assert!(listing.contains("epoch=2"), "listing: {listing}");
    assert!(listing.contains("registry\t"), "listing: {listing}");

    // swap back: epoch 3 serves the original weights again
    assert_eq!(c_swap.swap(MODEL, &path_a).unwrap(), (2, 3));
    assert_eq!(c.infer(MODEL, &img).unwrap(), direct_a, "epoch 3 = original weights");

    // exact ledger: 2 flips, each a fresh disk read; 1 failed swap;
    // evictions = stale cached B before swap 1, stale cached A before
    // swap 2, then epoch 2's source B once it drained
    let st = comq::serve::registry_stats();
    assert_eq!(st.swaps - st0.swaps, 2);
    assert_eq!(st.loads - st0.loads, 2, "each swap re-reads its checkpoint from disk");
    assert_eq!(st.load_failures - st0.load_failures, 1, "the missing-file swap");
    assert_eq!(st.evictions - st0.evictions, 3);

    server.shutdown();
    assert_eq!(server.stats().inflight, 0, "zero dropped requests across both swaps");
    fault::clear();
}

/// The env-driven `COMQ_FAULT` path. Under a plain `cargo test` the
/// variable is unset and this only exercises the pure parser; ci.sh
/// runs it alone as `COMQ_FAULT=panic:conn:1 cargo test --test
/// serve_net env_spec_smoke` (and again under `COMQ_FAULT=io_err:1`)
/// and then it asserts the injected fault actually fires from the
/// environment spec.
#[test]
fn env_spec_smoke() {
    let _g = guard();
    let armed = std::env::var("COMQ_FAULT").ok().filter(|s| !s.trim().is_empty());
    match armed.as_deref() {
        Some("panic:conn:1") => {
            let (_manifest, qm) = fixture("envfault");
            let server = NetServer::bind(
                "127.0.0.1:0",
                vec![(MODEL.to_string(), qm.clone())],
                net_config(),
            )
            .unwrap();
            let mut rng = Rng::new(0xE27);
            let img = rng.normal_vec(ELEMS);
            let mut c = client(&server);
            match c.infer(MODEL, &img).unwrap_err() {
                ClientError::Server { reason, .. } => assert_eq!(reason, ErrorReason::Internal),
                ClientError::Io(_) => {}
                other => panic!("expected the env-armed fault to fire, got {other:?}"),
            }
            assert_eq!(fault::fired_panics(Site::Conn), 1, "env spec must arm exactly once");
            let mut c = client(&server);
            c.infer(MODEL, &img).expect("contained: fresh connections serve");
        }
        Some("io_err:1") => {
            // the first atomic save must fail with the injected io
            // error and leave nothing behind; the second (budget
            // exhausted) succeeds and loads back verified
            let (manifest, model) = tiny_plain_cnn(7);
            let mut rng = Rng::new(0xF00D);
            let calib = Tensor::new(&[64, 8, 8, 3], rng.normal_vec(64 * ELEMS));
            let (packed, act, qmodel) =
                quantize_all_layers(&manifest, &model, 4, 8, &calib).unwrap();
            let path = tmp("envfault_io.cqm");
            let _ = std::fs::remove_file(&path);
            let err = save_packed_with_act(&path, &qmodel, &packed, 4, Some(&act))
                .expect_err("env-armed io_err must fail the first save");
            assert!(
                format!("{err:#}").contains("injected io_err"),
                "typed injection, not a silent skip: {err:#}"
            );
            assert!(
                !std::path::Path::new(&path).exists(),
                "a failed save must leave no file behind"
            );
            assert_eq!(fault::fired_io_errors(), 1, "env spec must arm exactly once");
            save_packed_with_act(&path, &qmodel, &packed, 4, Some(&act))
                .expect("budget exhausted: the second save succeeds");
            let qm = load_cached(&manifest, MODEL, &path).expect("and loads back");
            assert_eq!(qm.integrity().name(), "verified");
        }
        Some(other) => {
            panic!("env_spec_smoke only understands panic:conn:1 or io_err:1, got '{other}'")
        }
        None => {
            // parser-only smoke: same grammar the env init uses
            assert!(fault::parse("panic:conn:1").is_ok());
            assert!(fault::parse("io_err:rename:2").is_ok());
            assert!(fault::parse("panic:gpu").is_err());
        }
    }
}
