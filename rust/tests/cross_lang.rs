//! Cross-language parity: the Rust COMQ engines vs the python oracle
//! (python/compile/kernels/ref.py), via the fixtures that `make
//! artifacts` exports to artifacts/data/fixtures.cts.
//!
//! This is the strongest evidence the two implementations are the *same
//! algorithm*: exact bit-code agreement on seeded inputs across bit-
//! widths, schemes and orders.

use comq::quant::grid::Scheme;
use comq::quant::{comq_gram, comq_residual, GramSet, OrderKind, QuantConfig};
use comq::tensor::{matmul_at_a, Tensor};
use comq::tensorstore;

fn fixtures() -> Option<tensorstore::Store> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/data/fixtures.cts");
    if !path.exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(tensorstore::read_store(&path.to_string_lossy()).unwrap())
}

struct Case {
    x: Tensor,
    w: Tensor,
    q_ref: Tensor,
    delta_ref: Vec<f32>,
    zero_ref: Vec<f32>,
    bits: u32,
    per_channel: bool,
    greedy: bool,
    lam: f32,
}

fn load_case(store: &tensorstore::Store, ci: usize) -> Case {
    let t = |suffix: &str| store[&format!("case{ci}/{suffix}")].tensor().unwrap().clone();
    let meta = t("meta");
    Case {
        x: t("x"),
        w: t("w"),
        q_ref: t("q"),
        delta_ref: t("delta").data().to_vec(),
        zero_ref: t("zero").data().to_vec(),
        bits: meta.data()[0] as u32,
        per_channel: meta.data()[1] != 0.0,
        greedy: meta.data()[2] != 0.0,
        lam: meta.data()[3],
    }
}

fn cfg_for(c: &Case) -> QuantConfig {
    QuantConfig {
        bits: c.bits,
        scheme: if c.per_channel { Scheme::PerChannel } else { Scheme::PerLayer },
        order: if c.greedy { OrderKind::GreedyPerColumn } else { OrderKind::Cyclic },
        iters: 3,
        lam: c.lam,
    }
}

#[test]
fn rust_gram_engine_matches_python_oracle() {
    let Some(store) = fixtures() else { return };
    let n_cases = store["num_cases"].ints().unwrap().len();
    assert!(n_cases >= 5);
    for ci in 0..n_cases {
        let c = load_case(&store, ci);
        let gram = GramSet::Shared(matmul_at_a(&c.x));
        let lq = comq_gram(&gram, &c.w, &cfg_for(&c));
        let agree = lq
            .q
            .data()
            .iter()
            .zip(c.q_ref.data())
            .filter(|(a, b)| a == b)
            .count() as f64
            / lq.q.len() as f64;
        assert!(
            agree > 0.995,
            "case {ci} (bits={}, pc={}, greedy={}): only {agree:.4} of codes agree",
            c.bits,
            c.per_channel,
            c.greedy
        );
        // scales agree to float tolerance
        for (a, b) in lq.delta.iter().zip(&c.delta_ref) {
            assert!((a - b).abs() <= 2e-3 * b.abs().max(1e-3), "case {ci}: delta {a} vs {b}");
        }
        for (a, b) in lq.zero.iter().zip(&c.zero_ref) {
            assert_eq!(a, b, "case {ci}: zero point");
        }
    }
}

#[test]
fn rust_residual_engine_matches_python_oracle() {
    let Some(store) = fixtures() else { return };
    for ci in 0..3 {
        let c = load_case(&store, ci);
        let lq = comq_residual(&c.x, &c.w, &cfg_for(&c));
        let agree = lq
            .q
            .data()
            .iter()
            .zip(c.q_ref.data())
            .filter(|(a, b)| a == b)
            .count() as f64
            / lq.q.len() as f64;
        assert!(agree > 0.99, "case {ci}: only {agree:.4} of codes agree");
    }
}

#[test]
fn pjrt_sweep_kernel_matches_rust_engine() {
    // Run the L1 Pallas sweep artifact against the native engine on a
    // real layer shape: init identically, K sweeps, compare codes.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = comq::manifest::Manifest::load(&root).unwrap();
    let Some(sw) = manifest.sweeps.iter().find(|s| s.per_channel) else { return };
    let mut rng = comq::util::Rng::new(99);
    let x = Tensor::new(&[96, sw.m], rng.normal_vec(96 * sw.m));
    let w = Tensor::new(&[sw.m, sw.n], rng.normal_vec(sw.m * sw.n)).scale(0.3);
    let gram = GramSet::Shared(matmul_at_a(&x));
    for order in [OrderKind::Cyclic, OrderKind::GreedyShared] {
        let cfg = QuantConfig { bits: 4, order, iters: 3, ..Default::default() };
        let native = comq_gram(&gram, &w, &cfg);
        let pjrt = comq::coordinator::pjrt_kernel::comq_pjrt(&manifest, &gram, &w, &cfg).unwrap();
        let agree = native
            .q
            .data()
            .iter()
            .zip(pjrt.q.data())
            .filter(|(a, b)| a == b)
            .count() as f64
            / native.q.len() as f64;
        // GreedyPerColumn (native default) differs from the kernel's
        // shared-order mode, so compare matching orders only.
        assert!(agree > 0.99, "{order:?}: only {agree:.4} of codes agree");
        assert!(pjrt.codes_feasible(4));
    }
}
