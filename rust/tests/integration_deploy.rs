//! Integration: packed-checkpoint deployment, mixed precision, and
//! data-free calibration — the extension features — over real artifacts.

use comq::calib::{collect_stats, Dataset, EngineKind};
use comq::coordinator::pipeline::quantize_model_full;
use comq::coordinator::{mixed_precision_quantize, PipelineOptions};
use comq::deploy::{footprint, load_packed, save_packed};
use comq::eval::{evaluate, ActMode};
use comq::manifest::Manifest;
use comq::model::Model;
use comq::quant::QuantConfig;

fn setup() -> Option<(Manifest, Dataset)> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some((Manifest::load(&root).unwrap(), Dataset::load(&Manifest::load(&root).unwrap()).unwrap()))
}

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("comq_deploy_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_string_lossy().to_string()
}

#[test]
fn packed_checkpoint_roundtrips_accuracy() {
    let Some((manifest, dataset)) = setup() else { return };
    let model = Model::load(&manifest, "cnn_s").unwrap();
    let imgs = dataset.calib_subset(256);
    let stats = collect_stats(&manifest, &model, &imgs, EngineKind::Native).unwrap();
    let opts = PipelineOptions {
        engine: EngineKind::Native,
        calib_size: 256,
        qcfg: QuantConfig { bits: 3, ..Default::default() },
        ..Default::default()
    };
    let out = quantize_model_full(&manifest, &model, &dataset, &opts, &stats, 0.0).unwrap();
    let path = tmp("cnn_s_3bit.cqm");
    save_packed(&path, &out.model, &out.packed, 3).unwrap();

    let loaded = load_packed(&manifest, "cnn_s", &path).unwrap();
    // weights byte-identical after pack -> unpack
    for l in &model.info.quant_layers {
        assert_eq!(
            loaded.weight(&l.name),
            out.model.weight(&l.name),
            "layer {} differs after packed roundtrip",
            l.name
        );
    }
    // non-quantized params preserved
    for p in &model.info.params {
        assert!(loaded.params.contains_key(p), "missing {p}");
    }
    // footprint really is ~3/32 of f32 (+ scale overhead)
    let (packed, fp32) = footprint(&out.packed);
    assert!(packed * 8 < fp32, "packed {packed} vs fp32 {fp32}");
    // identical accuracy
    let n = 512;
    let elems: usize = dataset.val_images.shape()[1..].iter().product();
    let imgs = comq::tensor::Tensor::new(
        &[n, manifest.img, manifest.img, 3],
        dataset.val_images.data()[..n * elems].to_vec(),
    );
    let a = evaluate(&manifest, &out.model, &imgs, &dataset.val_labels[..n], EngineKind::Native, &ActMode::Fp).unwrap();
    let b = evaluate(&manifest, &loaded, &imgs, &dataset.val_labels[..n], EngineKind::Native, &ActMode::Fp).unwrap();
    assert_eq!(a.top1, b.top1);
}

#[test]
fn packed_rejects_wrong_version() {
    let Some((manifest, _)) = setup() else { return };
    let path = tmp("bogus.cqm");
    let mut store = comq::tensorstore::Store::new();
    store.insert(
        "__meta__".into(),
        comq::tensorstore::Entry::I32 { shape: vec![3], data: vec![99, 4, 0] },
    );
    comq::tensorstore::write_store(&path, &store).unwrap();
    assert!(load_packed(&manifest, "cnn_s", &path).is_err());
}

#[test]
fn mixed_precision_beats_uniform_at_budget() {
    let Some((manifest, dataset)) = setup() else { return };
    let model = Model::load(&manifest, "vit_s").unwrap();
    let imgs = dataset.calib_subset(512);
    let stats = collect_stats(&manifest, &model, &imgs, EngineKind::Pjrt).unwrap();
    let base = QuantConfig::default();
    let (qm, rep) = mixed_precision_quantize(&manifest, &model, &stats, &base, 3.0).unwrap();
    assert!(rep.achieved_bits <= 3.0 + 1e-6, "budget exceeded: {}", rep.achieved_bits);
    assert!(rep.achieved_bits > 2.0, "allocator failed to spend budget");
    // every layer got one of the candidate widths
    for l in &rep.layers {
        assert!([2, 3, 4, 8].contains(&l.bits), "{l:?}");
    }
    // accuracy at least as good as uniform 3-bit on total error
    let uni_opts = PipelineOptions {
        engine: EngineKind::Pjrt,
        calib_size: 512,
        skip_eval: true,
        qcfg: QuantConfig { bits: 3, ..Default::default() },
        ..Default::default()
    };
    let uni = quantize_model_full(&manifest, &model, &dataset, &uni_opts, &stats, 0.0).unwrap();
    assert!(
        rep.total_err <= uni.report.total_err() * 1.05,
        "mixed err {} vs uniform {}",
        rep.total_err,
        uni.report.total_err()
    );
    let acc = evaluate(
        &manifest,
        &qm,
        &dataset.val_images,
        &dataset.val_labels,
        EngineKind::Pjrt,
        &ActMode::Fp,
    )
    .unwrap();
    assert!(acc.top1 > 0.85, "mixed 3-bit top1 {}", acc.top1);
}

#[test]
fn gaussian_calibration_usable_at_4bit() {
    let Some((manifest, dataset)) = setup() else { return };
    let model = Model::load(&manifest, "resnet_lite").unwrap();
    let noise = dataset.gaussian_calib(256, 7);
    assert_eq!(noise.shape()[0], 256);
    let stats = collect_stats(&manifest, &model, &noise, EngineKind::Native).unwrap();
    let opts = PipelineOptions {
        engine: EngineKind::Native,
        calib_size: 256,
        ..Default::default()
    };
    let (_m, rep) =
        comq::coordinator::quantize_model_with_stats(&manifest, &model, &dataset, &opts, &stats, 0.0)
            .unwrap();
    // moment-matched noise calibration stays within a few points at 4-bit
    assert!(rep.top1 > rep.fp_top1 - 0.05, "gaussian 4-bit top1 {}", rep.top1);
}
