//! Serving-path telemetry integration: drive real requests through the
//! checkpoint registry and the micro-batcher, then assert the global
//! snapshot is *coherent* — per-stage histogram sums telescope to the
//! end-to-end latency, the queue-depth gauge returns to zero after the
//! drain, per-layer exec counters equal layers × images, and the
//! deadline-miss counter ticks exactly once per late batch.
//!
//! Run with `COMQ_OBS=off` (ci.sh does) the same test instead asserts
//! the off-is-free contract: forwards still work, logits are identical
//! bit for bit, and the metrics registry stays empty.

use std::time::Duration;

use comq::deploy::save_packed_with_act;
use comq::obs::{self, ObsLevel, Stage};
use comq::proptest::{quantize_all_layers, tiny_plain_cnn};
use comq::serve::{load_cached, BatchConfig, Server};
use comq::tensor::Tensor;
use comq::util::Rng;

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("comq_serve_obs_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_string_lossy().to_string()
}

#[test]
fn telemetry_snapshot_is_coherent_end_to_end() {
    // the same fixture the int8 parity tests drive: synthetic CNN,
    // W4A8, every quantizable layer served integer
    let (manifest, model) = tiny_plain_cnn(7);
    let mut rng = Rng::new(0x0B5);
    let calib = Tensor::new(&[64, 8, 8, 3], rng.normal_vec(64 * 8 * 8 * 3));
    let (packed, act, qmodel) = quantize_all_layers(&manifest, &model, 4, 8, &calib).unwrap();

    if obs::level() == ObsLevel::Off {
        // off-is-free: the whole serving run must leave the registry
        // empty (every handle is detached) while serving works as usual
        let path = tmp("off.cqm");
        save_packed_with_act(&path, &qmodel, &packed, 4, Some(&act)).unwrap();
        let qm = load_cached(&manifest, "tiny_plain", &path).unwrap();
        assert!(qm.obs().is_none(), "model must not build telemetry when off");
        let x = Tensor::new(&[2, 8, 8, 3], rng.normal_vec(2 * 8 * 8 * 3));
        let _ = qm.forward(&x);
        let server = Server::start(qm.clone(), BatchConfig::default());
        assert!(server.obs().is_none(), "server must not build telemetry when off");
        server.infer(rng.normal_vec(8 * 8 * 3)).unwrap();
        drop(server);
        let snap = obs::registry().snapshot();
        assert!(
            snap.is_empty(),
            "COMQ_OBS=off must record nothing, got:\n{}",
            snap.to_prometheus()
        );
        return;
    }
    // pin the gate: from here the test owns the level, not the env
    obs::set_level(ObsLevel::On);

    let path = tmp("coherence.cqm");
    save_packed_with_act(&path, &qmodel, &packed, 4, Some(&act)).unwrap();
    let qm = load_cached(&manifest, "tiny_plain", &path).unwrap();
    let images0 = qm.obs().expect("model telemetry").images();

    let server = Server::start(
        qm.clone(),
        BatchConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(2),
            executors: 1,
            pipeline: false,
        },
    );
    // clone the handles out so the assertions can outlive the server
    // (dropping it joins the executors, making every count final)
    let sobs = server.obs().expect("server telemetry");
    let spans = sobs.spans.clone();
    let queue_depth = sobs.queue_depth.clone();
    let batch_size = sobs.batch_size.clone();
    let requests = sobs.requests.clone();
    let deadline_miss = sobs.deadline_miss.clone();
    let panics = sobs.panics.clone();

    // phase 1: waves of concurrent singles, coalesced by the queue (how
    // the queue happened to split them doesn't matter to the invariants)
    const WAVES: usize = 3;
    const WAVE: usize = 8;
    for _ in 0..WAVES {
        let imgs: Vec<Vec<f32>> = (0..WAVE).map(|_| rng.normal_vec(8 * 8 * 3)).collect();
        let rxs: Vec<_> = imgs.into_iter().map(|im| server.submit(im)).collect();
        for rx in rxs {
            rx.recv().expect("reply").expect("served");
        }
    }
    // misses are counted at drain time, and every wave batch has drained
    // (its replies arrived), so this baseline is final
    let misses_after_waves = deadline_miss.get();

    // phase 2: sequential singles — each sits alone in the queue until
    // the deadline fires, so each must count exactly one deadline miss
    const K: usize = 3;
    for _ in 0..K {
        server.infer(rng.normal_vec(8 * 8 * 3)).expect("single reply");
    }
    assert_eq!(
        deadline_miss.get() - misses_after_waves,
        K as u64,
        "a lone request must close its window on the deadline, exactly once"
    );

    let n = (WAVES * WAVE + K) as u64;
    drop(server); // joins the executors — all telemetry below is final

    assert_eq!(queue_depth.get(), 0, "queue depth must return to zero after the drain");
    assert_eq!(requests.get(), n);
    assert_eq!(panics.get(), 0);

    // every answered request is stamped in all five stages
    for stage in comq::obs::span::STAGES {
        assert_eq!(
            spans.hist(stage).count(),
            n,
            "stage {} must carry one sample per answered request",
            stage.name()
        );
    }

    // the stages telescope: queue_wait + coalesce + exec + epilogue was
    // computed from the same Instants as total, per request, so the
    // exact histogram sums agree (small slack for ns truncation)
    let sum = |st: Stage| spans.hist(st).sum();
    let parts =
        sum(Stage::QueueWait) + sum(Stage::Coalesce) + sum(Stage::Exec) + sum(Stage::Epilogue);
    let total = sum(Stage::Total);
    assert!(
        parts.abs_diff(total) <= 8 * n,
        "per-stage sums must add up to the end-to-end latency: {parts} vs {total}"
    );

    // batch accounting: sizes sum to the requests answered, and there
    // was at least one batch per wave plus one per sequential single
    assert_eq!(batch_size.sum(), n, "batch sizes must sum to answered requests");
    assert!(batch_size.count() >= (WAVES + K) as u64);

    // per-layer exec counters: each image crosses every integer layer once
    let mobs = qm.obs().expect("model telemetry");
    assert_eq!(mobs.images() - images0, n, "forward must count every request image");
    assert_eq!(mobs.fallbacks(), 0, "this fixture serves every layer integer");
    let layer_names: Vec<String> = mobs.layer_names().map(str::to_string).collect();
    assert_eq!(layer_names.len(), model.info.quant_layers.len());
    for name in &layer_names {
        assert_eq!(mobs.layer_execs(name), n, "layer {name} must execute once per image");
    }

    // both export formats carry the serving metrics
    let snap = obs::registry().snapshot();
    let prom = snap.to_prometheus();
    for needle in [
        "comq_serve_stage_seconds",
        "comq_serve_batch_size",
        "comq_serve_requests_total",
        "comq_serve_layer_exec_total",
        "comq_serve_gemm_calls_total",
        "comq_serve_resident_bytes",
    ] {
        assert!(prom.contains(needle), "prometheus export missing {needle}:\n{prom}");
    }
    assert!(snap.to_json().to_string_pretty(1).contains("comq_serve_requests_total"));

    // off-is-free bit-identity: flip the gate off, run the same forward,
    // get the same logits to the bit while not a single counter moves
    let x = Tensor::new(&[2, 8, 8, 3], rng.normal_vec(2 * 8 * 8 * 3));
    let y_on = qm.forward(&x);
    let execs_on: u64 = layer_names.iter().map(|l| mobs.layer_execs(l)).sum();
    let images_on = mobs.images();
    obs::set_level(ObsLevel::Off);
    let y_off = qm.forward(&x);
    obs::set_level(ObsLevel::On);
    assert_eq!(y_on.shape(), y_off.shape());
    for (a, b) in y_on.data().iter().zip(y_off.data()) {
        assert_eq!(a.to_bits(), b.to_bits(), "the COMQ_OBS gate must not change logits");
    }
    let execs_off: u64 = layer_names.iter().map(|l| mobs.layer_execs(l)).sum();
    assert_eq!(execs_off, execs_on, "counters must not move while off");
    assert_eq!(mobs.images(), images_on, "counters must not move while off");
}
