//! Layer-job scheduler: layer-wise PTQ jobs are mutually independent
//! (layer l's calibration features come from the *full-precision* model,
//! per the paper: "the matrix input X ... does not depend on the
//! quantized weights from the previous layer"), so they run concurrently
//! with work-stealing via an atomic cursor.
//!
//! The runners are tasks on the process-wide `util::pool` scheduler —
//! not dedicated threads — so a layer job that parallelizes internally
//! (every quantizer does) nests onto the same workers via the pool's
//! helping join, and panic propagation is the pool's single
//! latch-carried path instead of a second `thread::scope` copy of it.
//! Effective concurrency is therefore capped by the pool width;
//! `workers = 1` (or `COMQ_THREADS=1`) degenerates to a deterministic
//! sequential loop.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::util::pool::{self, SendPtr};

/// Run `job(i)` for i in 0..n on up to `workers` concurrent pool
/// runners; results returned in index order. Panics in jobs are
/// propagated.
///
/// Results land in a pre-allocated disjoint-write buffer (the pool's
/// `SendPtr` idiom): the cursor hands each index to exactly one runner,
/// which writes slot `i` through the raw base pointer — no per-item
/// `Mutex` traffic on the result path. The pool join publishes every
/// write before the buffer is read, and on a propagated panic the
/// `Vec<Option<T>>` drops whatever did complete.
pub fn run_jobs<T, F>(n: usize, workers: usize, job: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 {
        return (0..n).map(&job).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let base = SendPtr::new(results.as_mut_ptr());
    // one pool task per runner slot; each drains the shared cursor, so
    // the split of jobs across runners is load-balanced regardless of
    // how the pool schedules (or steals) the tasks themselves
    pool::parallel_ranges(workers, 1, |_, _runners| loop {
        let i = cursor.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        let r = job(i);
        // the cursor gave index i to this runner alone, so the
        // slot write is unaliased; overwritten None has no drop
        unsafe { *base.ptr().add(i) = Some(r) };
    });
    results
        .into_iter()
        .map(|m| m.expect("job did not complete"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = run_jobs(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_path() {
        let out = run_jobs(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty() {
        let out: Vec<usize> = run_jobs(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = run_jobs(2, 16, |i| i);
        assert_eq!(out, vec![0, 1]);
    }

    #[test]
    fn panic_propagates_from_worker() {
        let res = std::panic::catch_unwind(|| {
            run_jobs(64, 4, |i| {
                if i == 33 {
                    panic!("job 33 failed");
                }
                // results of completed jobs (heap-allocated, to exercise
                // the drop path of the disjoint-write buffer) are freed
                vec![i; 8]
            })
        });
        assert!(res.is_err(), "worker panic must reach the caller");
    }
}
