//! Layer-job scheduler: layer-wise PTQ jobs are mutually independent
//! (layer l's calibration features come from the *full-precision* model,
//! per the paper: "the matrix input X ... does not depend on the
//! quantized weights from the previous layer"), so they run concurrently
//! on a small worker pool with work-stealing via an atomic cursor.
//!
//! Each quantizer already parallelizes across output channels internally,
//! so the default worker count is deliberately small; `workers = 1`
//! degenerates to a deterministic sequential loop.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `job(i)` for i in 0..n on `workers` threads; results returned in
/// index order. Panics in jobs are propagated.
pub fn run_jobs<T, F>(n: usize, workers: usize, job: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 {
        return (0..n).map(&job).collect();
    }
    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = job(i);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not complete"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = run_jobs(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_path() {
        let out = run_jobs(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty() {
        let out: Vec<usize> = run_jobs(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = run_jobs(2, 16, |i| i);
        assert_eq!(out, vec![0, 1]);
    }
}
