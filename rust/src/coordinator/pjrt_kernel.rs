//! COMQ through the AOT Pallas sweep artifacts (the L1 kernel path).
//!
//! The Rust side owns the algorithm structure — grid init, the K-sweep
//! loop, greedy permutation, dequantization — and dispatches each row
//! sweep (+ scale update) to the PJRT executable lowered from
//! python/compile/kernels/comq_pallas.py for the exact layer shape.
//!
//! Clip bounds are runtime inputs, so one artifact per (shape, scheme)
//! serves every bit-width. Greedy (shared) order is realized exactly as
//! the paper describes: permute the rows of W and both axes of G, run the
//! cyclic kernel, inverse-permute the codes.

use anyhow::{anyhow, Result};

use crate::manifest::Manifest;
use crate::quant::grid::{init_grid, LayerQuant, QuantConfig, Scheme};
use crate::quant::order::{invert, shared_order, OrderKind};
use crate::quant::GramSet;
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// Quantize one (non-grouped) layer via the PJRT sweep artifact.
pub fn comq_pjrt(
    manifest: &Manifest,
    gram: &GramSet,
    w: &Tensor,
    cfg: &QuantConfig,
) -> Result<LayerQuant> {
    let g = gram.shared()?;
    let (m, n) = (w.rows(), w.cols());
    let per_channel = cfg.scheme == Scheme::PerChannel;
    let sweep = manifest
        .sweep_for(m, n, per_channel)
        .ok_or_else(|| anyhow!("no sweep artifact for shape ({m},{n},{})", cfg.scheme.name()))?;
    let engine = Engine::global()?;
    let path = manifest.path(&sweep.path);

    // greedy-shared: pre-permute; per-column greedy is not expressible in
    // the column-tiled kernel, so it maps to the shared variant here.
    let perm: Option<Vec<u32>> = match cfg.order {
        OrderKind::Cyclic => None,
        OrderKind::GreedyShared | OrderKind::GreedyPerColumn => {
            let diag: Vec<f32> = (0..m).map(|i| g.at2(i, i)).collect();
            Some(shared_order(&diag, w))
        }
    };
    let (gp, wp) = match &perm {
        None => (g.clone(), w.clone()),
        Some(p) => (permute_sym(g, p), permute_rows(w, p)),
    };

    let (delta0, zero) = init_grid(&wp, cfg);
    let levels = cfg.levels();
    // Q0 = W / δ (infeasible float start, same as the native engine)
    let mut q = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            q.data_mut()[i * n + j] = wp.at2(i, j) / delta0[j];
        }
    }
    let mut delta = Tensor::from_vec(delta0);
    let lo = Tensor::from_vec(zero.clone());
    let hi = Tensor::from_vec(zero.iter().map(|z| z + levels).collect());

    for _k in 0..cfg.iters {
        let outs = engine.run(&path, &[&gp, &wp, &q, &delta, &lo, &hi])?;
        let mut it = outs.into_iter();
        q = it.next().ok_or_else(|| anyhow!("sweep returned no Q"))?;
        delta = it.next().ok_or_else(|| anyhow!("sweep returned no delta"))?;
    }

    // undo the permutation on the codes
    let q = match &perm {
        None => q,
        Some(p) => permute_rows(&q, &invert(p)),
    };
    Ok(LayerQuant { q, delta: delta.data().to_vec(), zero })
}

/// Rows of `t` gathered by `perm`: out[i, :] = t[perm[i], :].
fn permute_rows(t: &Tensor, perm: &[u32]) -> Tensor {
    let (m, n) = (t.rows(), t.cols());
    assert_eq!(perm.len(), m);
    let mut out = Tensor::zeros(&[m, n]);
    for (i, &p) in perm.iter().enumerate() {
        out.data_mut()[i * n..(i + 1) * n].copy_from_slice(t.row(p as usize));
    }
    out
}

/// Symmetric permutation of a square matrix: out[i, j] = g[perm[i], perm[j]].
fn permute_sym(g: &Tensor, perm: &[u32]) -> Tensor {
    let m = g.rows();
    let mut out = Tensor::zeros(&[m, m]);
    for i in 0..m {
        let pi = perm[i] as usize;
        for j in 0..m {
            out.data_mut()[i * m + j] = g.at2(pi, perm[j] as usize);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn permutations() {
        let mut rng = Rng::new(1);
        let t = Tensor::new(&[4, 2], rng.normal_vec(8));
        let perm = vec![2u32, 0, 3, 1];
        let p = permute_rows(&t, &perm);
        assert_eq!(p.row(0), t.row(2));
        let back = permute_rows(&p, &invert(&perm));
        assert_eq!(back, t);

        let g0 = Tensor::new(&[4, 4], rng.normal_vec(16));
        // symmetrize
        let mut g = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            for j in 0..4 {
                let v = 0.5 * (g0.at2(i, j) + g0.at2(j, i));
                g.data_mut()[i * 4 + j] = v;
            }
        }
        let gp = permute_sym(&g, &perm);
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(gp.at2(i, j), g.at2(perm[i] as usize, perm[j] as usize));
                assert_eq!(gp.at2(i, j), gp.at2(j, i));
            }
        }
        let back = permute_sym(&gp, &invert(&perm));
        assert_eq!(back, g);
    }
}
