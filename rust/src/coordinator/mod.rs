//! The L3 coordinator: the PTQ pipeline that ties everything together.
//!
//! ```text
//! checkpoint ─┐
//! calib data ─┤→ calibration pass (G, min, max per layer; PJRT or native)
//!             │→ layer-job scheduler (independent layers on a worker pool)
//!             │     each job: quantizer (COMQ / baseline) on (G_l, W_l)
//!             │→ assemble quantized model (+ activation scales)
//!             └→ evaluation (top-1/top-5) + per-layer JSON report
//! ```

pub mod mixed;
pub mod pipeline;
pub mod pjrt_kernel;
pub mod report;
pub mod scheduler;

pub use mixed::{mixed_precision_quantize, MixedReport};
pub use pipeline::{
    quantize_model, quantize_model_packed, quantize_model_with_stats, PipelineOptions, QuantEngine,
};
pub use report::{LayerReport, QuantReport};
