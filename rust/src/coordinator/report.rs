//! Structured run reports: per-layer reconstruction errors, timings, and
//! end-to-end accuracy, serialized to JSON for the bench harness and
//! EXPERIMENTS.md.

use crate::obs::quant::SweepTelemetry;
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    pub m: usize,
    pub n: usize,
    /// ‖X W_q − X W‖² after quantization.
    pub err: f64,
    /// Same error for plain RTN on the same grid (context for Fig. 3).
    pub err_rtn: f64,
    pub secs: f64,
    /// Sweep-level telemetry stashed by the quantizer (present when
    /// `COMQ_OBS` is on and the method reports it; the per-pass error
    /// trajectory additionally needs `COMQ_OBS=trace`).
    pub sweep: Option<SweepTelemetry>,
}

#[derive(Debug, Clone)]
pub struct QuantReport {
    pub model: String,
    pub method: String,
    pub bits: u32,
    pub scheme: String,
    pub order: String,
    pub iters: usize,
    pub lam: f32,
    pub calib_size: usize,
    pub act_bits: Option<u32>,
    pub engine: String,
    pub quant_engine: String,
    pub fp_top1: f64,
    pub top1: f64,
    pub top5: f64,
    pub calib_secs: f64,
    pub quant_secs: f64,
    pub eval_secs: f64,
    pub layers: Vec<LayerReport>,
}

impl QuantReport {
    pub fn total_err(&self) -> f64 {
        self.layers.iter().map(|l| l.err).sum()
    }

    pub fn to_json(&self) -> Json {
        let layers = self
            .layers
            .iter()
            .map(|l| {
                let mut fields = vec![
                    ("name", Json::Str(l.name.clone())),
                    ("m", Json::Num(l.m as f64)),
                    ("n", Json::Num(l.n as f64)),
                    ("err", Json::Num(l.err)),
                    ("err_rtn", Json::Num(l.err_rtn)),
                    ("secs", Json::Num(l.secs)),
                ];
                if let Some(s) = &l.sweep {
                    fields.push((
                        "sweep",
                        Json::obj_from(vec![
                            ("passes", Json::from_f64s(&s.passes)),
                            ("updates", Json::Num(s.updates as f64)),
                            ("order_uniform", Json::Bool(s.order_uniform)),
                        ]),
                    ));
                }
                Json::obj_from(fields)
            })
            .collect();
        Json::obj_from(vec![
            ("model", Json::Str(self.model.clone())),
            ("method", Json::Str(self.method.clone())),
            ("bits", Json::Num(self.bits as f64)),
            ("scheme", Json::Str(self.scheme.clone())),
            ("order", Json::Str(self.order.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("lam", Json::Num(self.lam as f64)),
            ("calib_size", Json::Num(self.calib_size as f64)),
            (
                "act_bits",
                self.act_bits.map(|b| Json::Num(b as f64)).unwrap_or(Json::Null),
            ),
            ("engine", Json::Str(self.engine.clone())),
            ("quant_engine", Json::Str(self.quant_engine.clone())),
            ("fp_top1", Json::Num(self.fp_top1)),
            ("top1", Json::Num(self.top1)),
            ("top5", Json::Num(self.top5)),
            ("calib_secs", Json::Num(self.calib_secs)),
            ("quant_secs", Json::Num(self.quant_secs)),
            ("eval_secs", Json::Num(self.eval_secs)),
            ("total_err", Json::Num(self.total_err())),
            ("layers", Json::Arr(layers)),
        ])
    }

    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty(1))?;
        Ok(())
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<14} {:<13} {}W{} {:<11} {:<7} top1={:.2}% (fp {:.2}%, drop {:+.2}) err={:.4e} quant={:.2}s",
            self.model,
            self.method,
            self.bits,
            self.act_bits.map(|b| format!("A{b}")).unwrap_or_else(|| "A32".into()),
            self.scheme,
            self.order,
            self.top1 * 100.0,
            self.fp_top1 * 100.0,
            (self.top1 - self.fp_top1) * 100.0,
            self.total_err(),
            self.quant_secs,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> QuantReport {
        QuantReport {
            model: "vit_s".into(),
            method: "comq".into(),
            bits: 4,
            scheme: "per-channel".into(),
            order: "greedy".into(),
            iters: 3,
            lam: 1.0,
            calib_size: 1024,
            act_bits: None,
            engine: "native".into(),
            quant_engine: "native".into(),
            fp_top1: 0.92,
            top1: 0.91,
            top5: 0.99,
            calib_secs: 1.0,
            quant_secs: 0.5,
            eval_secs: 2.0,
            layers: vec![LayerReport {
                name: "head".into(),
                m: 96,
                n: 16,
                err: 0.125,
                err_rtn: 0.5,
                secs: 0.01,
                sweep: None,
            }],
        }
    }

    #[test]
    fn json_roundtrip() {
        let r = sample();
        let j = r.to_json();
        let txt = j.to_string_pretty(1);
        let back = Json::parse(&txt).unwrap();
        assert_eq!(back.get("model").unwrap().str().unwrap(), "vit_s");
        assert_eq!(back.get("top1").unwrap().num().unwrap(), 0.91);
        assert_eq!(back.get("act_bits").unwrap(), &Json::Null);
        assert_eq!(
            back.get("layers").unwrap().arr().unwrap()[0]
                .get("err")
                .unwrap()
                .num()
                .unwrap(),
            0.125
        );
    }

    #[test]
    fn json_carries_sweep_when_present() {
        let mut r = sample();
        // absent sweep ⇒ no key, so old readers see the old shape
        let layer0 = &r.to_json().get("layers").unwrap().arr().unwrap()[0];
        assert!(layer0.opt("sweep").is_none());
        r.layers[0].sweep = Some(SweepTelemetry {
            passes: vec![2.0, 1.0, 0.5],
            updates: 96 * 16 * 3,
            order_uniform: true,
        });
        let txt = r.to_json().to_string_pretty(1);
        let back = Json::parse(&txt).unwrap();
        let sweep = back.get("layers").unwrap().arr().unwrap()[0].get("sweep").unwrap();
        let passes = sweep.get("passes").unwrap().arr().unwrap();
        assert_eq!(passes.len(), 3);
        assert_eq!(passes[2].num().unwrap(), 0.5);
        assert_eq!(sweep.get("updates").unwrap().num().unwrap(), (96 * 16 * 3) as f64);
        assert!(sweep.get("order_uniform").unwrap().boolean().unwrap());
    }

    #[test]
    fn summary_readable() {
        let s = sample().summary();
        assert!(s.contains("vit_s"));
        assert!(s.contains("4W"));
        assert!(s.contains("top1=91.00%"));
    }
}
