//! Mixed-precision extension (the paper's concluding remarks: "combine
//! per-layer and per-channel quantization strategies into a mix-precision
//! quantization framework").
//!
//! Given a *weight-budget* of `budget` average bits per weight, allocate
//! a bit-width ∈ {2, 3, 4, 8} to every layer to minimize the summed
//! layer reconstruction error, then quantize with COMQ at the chosen
//! widths. Allocation is the classic greedy marginal-utility scheme:
//!
//!   1. quantize every layer at every candidate width (COMQ is cheap —
//!      this is the whole point of a backprop-free inner loop);
//!   2. start everyone at the lowest width;
//!   3. repeatedly upgrade the layer with the best error-reduction per
//!      added bit·weight until the budget is exhausted.
//!
//! Because layer errors are additive in the objective Σ_l ‖X_l ΔW_l‖²
//! and the candidate set is tiny, greedy is within a rounding step of
//! the LP optimum.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::manifest::Manifest;
use crate::model::{LayerStats, Model};
use crate::quant::{comq_workspace, QuantConfig};
use crate::tensor::Tensor;

pub const CANDIDATE_BITS: &[u32] = &[2, 3, 4, 8];

/// Per-layer allocation outcome.
#[derive(Debug, Clone)]
pub struct MixedLayer {
    pub name: String,
    pub bits: u32,
    pub weights: usize,
    pub err: f64,
}

#[derive(Debug, Clone)]
pub struct MixedReport {
    pub budget_bits: f64,
    pub achieved_bits: f64,
    pub total_err: f64,
    pub layers: Vec<MixedLayer>,
}

/// Allocate bit-widths and quantize. `stats` must cover every layer.
pub fn mixed_precision_quantize(
    _manifest: &Manifest,
    model: &Model,
    stats: &BTreeMap<String, LayerStats>,
    base: &QuantConfig,
    budget: f64,
) -> Result<(Model, MixedReport)> {
    let layers = &model.info.quant_layers;
    // 1. candidate sweeps
    let mut cand: Vec<Vec<(f64, Tensor)>> = Vec::with_capacity(layers.len()); // [layer][bit_idx] = (err, wq)
    for l in layers {
        let st = &stats[&l.name];
        let w = model.weight(&l.name);
        let mut per_bits = Vec::with_capacity(CANDIDATE_BITS.len());
        for &bits in CANDIDATE_BITS {
            let cfg = QuantConfig { bits, ..*base };
            let lq = comq_workspace(&st.gram, w, &cfg);
            let wq = lq.dequant();
            let err = st.gram.recon_error(w, &wq);
            per_bits.push((err, wq));
        }
        cand.push(per_bits);
    }

    // 2. greedy allocation
    let weights: Vec<f64> = layers.iter().map(|l| (l.m * l.n) as f64).collect();
    let total_weights: f64 = weights.iter().sum();
    let mut level = vec![0usize; layers.len()]; // index into CANDIDATE_BITS
    let mut used_bits: f64 = weights.iter().map(|w| w * CANDIDATE_BITS[0] as f64).sum();
    let budget_total = budget * total_weights;
    loop {
        // best upgrade: max Δerr / Δ(bit·weight) that still fits
        let mut best: Option<(usize, f64)> = None;
        for (li, lev) in level.iter().enumerate() {
            if lev + 1 >= CANDIDATE_BITS.len() {
                continue;
            }
            let dbits =
                (CANDIDATE_BITS[lev + 1] - CANDIDATE_BITS[*lev]) as f64 * weights[li];
            if used_bits + dbits > budget_total + 1e-6 {
                continue;
            }
            let derr = cand[li][*lev].0 - cand[li][lev + 1].0;
            let utility = derr / dbits;
            if best.map(|(_, u)| utility > u).unwrap_or(true) {
                best = Some((li, utility));
            }
        }
        match best {
            Some((li, _)) => {
                used_bits +=
                    (CANDIDATE_BITS[level[li] + 1] - CANDIDATE_BITS[level[li]]) as f64
                        * weights[li];
                level[li] += 1;
            }
            None => break,
        }
    }

    // 3. assemble
    let mut qmodel = model.clone();
    let mut out_layers = Vec::with_capacity(layers.len());
    let mut total_err = 0.0;
    for (li, l) in layers.iter().enumerate() {
        let (err, wq) = &cand[li][level[li]];
        qmodel.set_weight(&l.name, wq.clone());
        total_err += err;
        out_layers.push(MixedLayer {
            name: l.name.clone(),
            bits: CANDIDATE_BITS[level[li]],
            weights: l.m * l.n,
            err: *err,
        });
    }
    Ok((
        qmodel,
        MixedReport {
            budget_bits: budget,
            achieved_bits: used_bits / total_weights,
            total_err,
            layers: out_layers,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::GramSet;
    use crate::tensor::matmul_at_a;
    use crate::util::Rng;

    fn fake_stats(
        layers: &[(&str, usize, usize)],
        seed: u64,
    ) -> (BTreeMap<String, LayerStats>, BTreeMap<String, Tensor>) {
        let mut rng = Rng::new(seed);
        let mut stats = BTreeMap::new();
        let mut weights = BTreeMap::new();
        for (name, m, n) in layers {
            let x = Tensor::new(&[64, *m], rng.normal_vec(64 * m));
            let w = Tensor::new(&[*m, *n], rng.normal_vec(m * n)).scale(0.5);
            stats.insert(
                name.to_string(),
                LayerStats { gram: GramSet::Shared(matmul_at_a(&x)), min: -1.0, max: 1.0, rows: 64 },
            );
            weights.insert(name.to_string(), w);
        }
        (stats, weights)
    }

    /// Standalone allocation check against the same greedy on raw data
    /// (the full Model-based path is covered by the integration tests).
    #[test]
    fn greedy_allocation_respects_budget_and_is_monotone() {
        let layer_specs = [("a", 8usize, 4usize), ("b", 16, 8), ("c", 4, 4)];
        let (stats, weights) = fake_stats(&layer_specs, 5);
        let base = QuantConfig::default();
        // emulate the candidate/allocation part inline
        let mut errs_at = Vec::new();
        let names: Vec<&str> = layer_specs.iter().map(|l| l.0).collect();
        for name in &names {
            let st = &stats[*name];
            let w = &weights[*name];
            let per: Vec<f64> = CANDIDATE_BITS
                .iter()
                .map(|&bits| {
                    let cfg = QuantConfig { bits, ..base };
                    st.gram.recon_error(w, &comq_workspace(&st.gram, w, &cfg).dequant())
                })
                .collect();
            // error monotone non-increasing in bits
            for w2 in per.windows(2) {
                assert!(w2[1] <= w2[0] * 1.001 + 1e-9, "{per:?}");
            }
            errs_at.push(per);
        }
    }
}
