//! The end-to-end PTQ pipeline (calibrate → schedule layer jobs →
//! quantize → assemble → evaluate → report).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::calib::{self, Dataset, EngineKind};
use crate::eval::{self, ActMode};
use crate::manifest::Manifest;
use crate::model::{LayerStats, Model};
use crate::quant::actq::ActQuant;
use crate::quant::rtn::rtn;
use crate::quant::{make_quantizer, QuantConfig};
use crate::util::Timer;

use super::pjrt_kernel::comq_pjrt;
use super::report::{LayerReport, QuantReport};
use super::scheduler::run_jobs;

/// Which engine executes the COMQ coordinate sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantEngine {
    /// The in-crate Gram-domain engine (default; fastest).
    Native,
    /// The AOT Pallas sweep artifacts via PJRT (the L1 kernel path);
    /// layers without a matching artifact fall back to native.
    PjrtKernel,
}

impl QuantEngine {
    pub fn parse(s: &str) -> Option<QuantEngine> {
        match s {
            "native" => Some(QuantEngine::Native),
            "pjrt-kernel" | "pjrt" => Some(QuantEngine::PjrtKernel),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            QuantEngine::Native => "native",
            QuantEngine::PjrtKernel => "pjrt-kernel",
        }
    }
}

#[derive(Debug, Clone)]
pub struct PipelineOptions {
    /// Quantizer registry name ("comq", "rtn", "gpfq", "obq", ...).
    pub method: String,
    pub qcfg: QuantConfig,
    /// Engine for calibration capture and evaluation.
    pub engine: EngineKind,
    /// Engine for the COMQ sweeps themselves.
    pub quant_engine: QuantEngine,
    /// Number of calibration images (Tab. 6 sweeps this).
    pub calib_size: usize,
    /// Activation quantization bits (None = weight-only).
    pub act_bits: Option<u32>,
    /// Activation range clipping ratio (RepQ-style; 1.0 = full range).
    pub act_clip: f32,
    /// Layer names to keep in full precision.
    pub skip_layers: Vec<String>,
    /// Parallel layer jobs (1 = deterministic sequential).
    pub workers: usize,
    /// Skip the final evaluation (error-only runs in benches).
    pub skip_eval: bool,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions {
            method: "comq".into(),
            qcfg: QuantConfig::default(),
            engine: EngineKind::Native,
            quant_engine: QuantEngine::Native,
            calib_size: 1024,
            act_bits: None,
            act_clip: 0.95,
            skip_layers: Vec::new(),
            workers: 1,
            skip_eval: false,
        }
    }
}

/// Run the full pipeline; returns the quantized model and its report.
pub fn quantize_model(
    manifest: &Manifest,
    model: &Model,
    dataset: &Dataset,
    opts: &PipelineOptions,
) -> Result<(Model, QuantReport)> {
    let out = quantize_model_packed(manifest, model, dataset, opts)?;
    Ok((out.model, out.report))
}

/// [`quantize_model`] (calibration pass included) returning the full
/// deployment output — packed layers + activation grid — for callers
/// heading to `deploy::save_packed_with_act` or the serving runtime.
pub fn quantize_model_packed(
    manifest: &Manifest,
    model: &Model,
    dataset: &Dataset,
    opts: &PipelineOptions,
) -> Result<QuantOutput> {
    // 1. calibration statistics
    let t_calib = Timer::start();
    let calib_images = dataset.calib_subset(opts.calib_size);
    let stats = calib::collect_stats(manifest, model, &calib_images, opts.engine)?;
    let calib_secs = t_calib.secs();
    quantize_model_full(manifest, model, dataset, opts, &stats, calib_secs)
}

/// Full pipeline output (the packed layers feed `deploy::save_packed`;
/// `act` carries the calibrated activation grid so the checkpoint is
/// servable by the integer runtime with static scales).
pub struct QuantOutput {
    pub model: Model,
    pub report: QuantReport,
    pub packed: Vec<crate::deploy::PackedLayer>,
    pub act: Option<crate::deploy::PackedAct>,
}

/// Pipeline core with precomputed calibration statistics (bench sweeps
/// reuse one calibration pass across many method/bit configurations).
pub fn quantize_model_with_stats(
    manifest: &Manifest,
    model: &Model,
    dataset: &Dataset,
    opts: &PipelineOptions,
    stats: &BTreeMap<String, LayerStats>,
    calib_secs: f64,
) -> Result<(Model, QuantReport)> {
    let out = quantize_model_full(manifest, model, dataset, opts, stats, calib_secs)?;
    Ok((out.model, out.report))
}

/// Pipeline core returning the packed deployment layers as well.
pub fn quantize_model_full(
    manifest: &Manifest,
    model: &Model,
    dataset: &Dataset,
    opts: &PipelineOptions,
    stats: &BTreeMap<String, LayerStats>,
    calib_secs: f64,
) -> Result<QuantOutput> {
    let quantizer = make_quantizer(&opts.method)
        .ok_or_else(|| anyhow!("unknown method '{}' (have {:?})", opts.method, crate::quant::QUANTIZER_NAMES))?;

    // 2. layer jobs
    let t_quant = Timer::start();
    let jobs: Vec<_> = model
        .info
        .quant_layers
        .iter()
        .filter(|l| !opts.skip_layers.contains(&l.name))
        .collect();
    let results = run_jobs(jobs.len(), opts.workers, |i| {
        let layer = jobs[i];
        let t = Timer::start();
        let st = &stats[&layer.name];
        let w = model.weight(&layer.name);
        // discard any stale sweep stash on this worker thread so the
        // telemetry captured below can only come from this layer's run
        let _ = crate::obs::quant::take_sweep();
        let lq = match opts.quant_engine {
            QuantEngine::PjrtKernel if !layer.grouped && opts.method.starts_with("comq") => {
                match comq_pjrt(manifest, &st.gram, w, &opts.qcfg) {
                    Ok(lq) => lq,
                    Err(e) => {
                        crate::log_debug!("pjrt-kernel fallback for {}: {e}", layer.name);
                        quantizer.quantize(&st.gram, w, &opts.qcfg)
                    }
                }
            }
            _ => quantizer.quantize(&st.gram, w, &opts.qcfg),
        };
        let sweep = crate::obs::quant::take_sweep();
        let wq = lq.dequant();
        let err = st.gram.recon_error(w, &wq);
        let err_rtn = st.gram.recon_error(w, &rtn(w, &opts.qcfg).dequant());
        let packed = crate::deploy::PackedLayer::from_quant(&layer.name, &lq, opts.qcfg.bits);
        let secs = t.secs();
        if crate::obs::enabled() {
            crate::obs::quant::record_layer(secs);
        }
        (
            wq,
            packed,
            LayerReport {
                name: layer.name.clone(),
                m: layer.m,
                n: layer.n,
                err,
                err_rtn,
                secs,
                sweep,
            },
        )
    });
    let mut qmodel = model.clone();
    let mut layer_reports = Vec::with_capacity(results.len());
    let mut packed_layers = Vec::with_capacity(results.len());
    for (job, (wq, packed, rep)) in jobs.iter().zip(results) {
        qmodel.set_weight(&job.name, wq);
        layer_reports.push(rep);
        packed_layers.push(packed);
    }
    let quant_secs = t_quant.secs();

    // 3. activation quantization parameters (from the same calibration);
    //    the packed grid is the single source — eval mode derives from it
    let packed_act = opts.act_bits.map(|bits| crate::deploy::PackedAct {
        bits,
        by_layer: model
            .info
            .quant_layers
            .iter()
            .zip(act_params(stats, &model.info.quant_layers, bits, opts.act_clip))
            .map(|(l, a)| (l.name.clone(), a))
            .collect(),
    });
    let act_mode = match &packed_act {
        Some(a) => ActMode::Quant {
            bits: a.bits,
            params: model.info.quant_layers.iter().map(|l| a.by_layer[&l.name]).collect(),
        },
        None => ActMode::Fp,
    };

    // 4. evaluation
    let t_eval = Timer::start();
    let (top1, top5) = if opts.skip_eval {
        (f64::NAN, f64::NAN)
    } else if opts.engine == EngineKind::Int8 {
        // parity route: serve the packed codes through the i8 GEMM
        // runtime and score that, instead of the dequantized f32 model
        let act_src = match &packed_act {
            Some(a) => crate::serve::ActSource::Static {
                bits: a.bits,
                by_layer: a.by_layer.clone(),
            },
            None => crate::serve::ActSource::Dynamic { bits: crate::serve::DEFAULT_ACT_BITS },
        };
        let qm = crate::serve::QuantizedModel::from_parts(
            model.info.clone(),
            qmodel.params.clone(),
            &packed_layers,
            act_src,
        )?;
        let acc =
            eval::evaluate_int8(&qm, &dataset.val_images, &dataset.val_labels, manifest.batch)?;
        (acc.top1, acc.top5)
    } else {
        let acc = eval::evaluate(
            manifest,
            &qmodel,
            &dataset.val_images,
            &dataset.val_labels,
            opts.engine,
            &act_mode,
        )?;
        (acc.top1, acc.top5)
    };
    let eval_secs = t_eval.secs();

    let report = QuantReport {
        model: model.info.name.clone(),
        method: opts.method.clone(),
        bits: opts.qcfg.bits,
        scheme: opts.qcfg.scheme.name().into(),
        order: opts.qcfg.order.name().into(),
        iters: opts.qcfg.iters,
        lam: opts.qcfg.lam,
        calib_size: opts.calib_size,
        act_bits: opts.act_bits,
        engine: opts.engine.name().into(),
        quant_engine: opts.quant_engine.name().into(),
        fp_top1: model.info.fp_top1,
        top1,
        top5,
        calib_secs,
        quant_secs,
        eval_secs,
        layers: layer_reports,
    };
    Ok(QuantOutput { model: qmodel, report, packed: packed_layers, act: packed_act })
}

/// Derive per-layer activation fake-quant parameters (manifest order).
pub fn act_params(
    stats: &BTreeMap<String, LayerStats>,
    layers: &[crate::manifest::LayerInfo],
    bits: u32,
    clip: f32,
) -> Vec<ActQuant> {
    layers
        .iter()
        .map(|l| {
            let st = &stats[&l.name];
            ActQuant::from_range(st.min, st.max, bits, clip)
        })
        .collect()
}

/// Evaluate the unmodified FP model (baseline row of every table).
pub fn eval_fp(
    manifest: &Manifest,
    model: &Model,
    dataset: &Dataset,
    engine: EngineKind,
) -> Result<eval::Accuracy> {
    eval::evaluate(
        manifest,
        model,
        &dataset.val_images,
        &dataset.val_labels,
        engine,
        &ActMode::Fp,
    )
}
