//! NUMA topology probe for the work-stealing pool and panel sharding.
//!
//! The serving hot path wants two things from the machine layout: worker
//! threads grouped by memory domain (so steals prefer same-node victims
//! and a panel shard is consumed by the cores next to it), and a node
//! count for sharding each layer's packed panels with node-local i32
//! accumulation (`serve/packed.rs`). Both are answered here.
//!
//! ## Sources, in priority order
//!
//! 1. A test override installed via [`set_mode_override`] — dynamic, so
//!    bit-identity tests can flip between `off` and a synthetic node
//!    count without touching the process environment.
//! 2. `COMQ_NUMA` (read once, at first use):
//!    * `off`  — single node, no pinning. The compatibility setting:
//!      scheduling and sharding behave exactly like the pre-NUMA build.
//!    * `auto` (or unset) — probe `/sys/devices/system/node` on Linux;
//!      single-node fallback anywhere else or when the probe fails.
//!    * `<n>`  — force `n` synthetic nodes by splitting the detected
//!      CPUs round-robin. A test/bench knob: it exercises the sharded
//!      code paths on machines that are physically single-node.
//!    Invalid values warn once and fall back to `auto`, the same
//!    contract as `COMQ_THREADS` / `COMQ_KERNEL`.
//!
//! Nothing in the crate depends on the probe being *right* for
//! correctness: node ids only bias task placement and shard layout, and
//! the pool's find-work order always falls through to every queue in the
//! system. A wrong (or stale, under a test override) topology costs
//! locality, never results.

use std::sync::{Mutex, OnceLock};

/// Hard cap on distinguishable nodes. Keeps per-node arrays in the pool
/// fixed-size; machines with more domains than this fold the excess into
/// node `MAX_NODES - 1` (locality loss only).
pub const MAX_NODES: usize = 8;

/// Effective NUMA policy, after env parsing / override.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NumaMode {
    /// Single node, no pinning — bit-for-bit the pre-NUMA behavior.
    Off,
    /// Use the probed topology.
    Auto,
    /// Force a synthetic node count (testing / benching the sharded paths).
    Force(usize),
}

fn parse_mode(raw: Option<&str>) -> Result<NumaMode, String> {
    match raw.map(str::trim) {
        None | Some("") => Ok(NumaMode::Auto),
        Some(s) if s.eq_ignore_ascii_case("off") => Ok(NumaMode::Off),
        Some(s) if s.eq_ignore_ascii_case("auto") => Ok(NumaMode::Auto),
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(NumaMode::Force(n)),
            _ => Err(s.to_string()),
        },
    }
}

fn env_mode() -> NumaMode {
    static MODE: OnceLock<NumaMode> = OnceLock::new();
    *MODE.get_or_init(|| {
        let raw = std::env::var("COMQ_NUMA").ok();
        match parse_mode(raw.as_deref()) {
            Ok(m) => m,
            Err(bad) => {
                crate::warn_once!("COMQ_NUMA={bad}: expected off|auto|<nodes>, using auto");
                NumaMode::Auto
            }
        }
    })
}

/// Test hook: override the NUMA mode for the rest of the process (or
/// until cleared with `None`). Consulted before `COMQ_NUMA` on every
/// call to [`mode`] — dynamic so bit-identity tests can compare layouts
/// in a single process without env races.
pub fn set_mode_override(m: Option<NumaMode>) {
    *mode_override().lock().unwrap() = m;
}

fn mode_override() -> &'static Mutex<Option<NumaMode>> {
    static OV: OnceLock<Mutex<Option<NumaMode>>> = OnceLock::new();
    OV.get_or_init(|| Mutex::new(None))
}

/// The NUMA policy in effect right now.
pub fn mode() -> NumaMode {
    if let Some(m) = *mode_override().lock().unwrap() {
        return m;
    }
    env_mode()
}

// ---------------------------------------------------------------------------
// Probe
// ---------------------------------------------------------------------------

/// Physical topology as probed once at first use: one CPU list per node.
/// Empty node lists never appear; a failed or trivial probe yields one
/// node holding every detected CPU.
struct Probe {
    nodes: Vec<Vec<usize>>,
}

fn detected_cpus() -> Vec<usize> {
    let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    (0..n).collect()
}

/// Parse a sysfs cpulist like `0-3,8-11,17`.
fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                if a <= b && b - a < 4096 {
                    out.extend(a..=b);
                }
            }
        } else if let Ok(v) = part.parse::<usize>() {
            out.push(v);
        }
    }
    out
}

#[cfg(target_os = "linux")]
fn probe_sysfs() -> Option<Vec<Vec<usize>>> {
    let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
    for entry in std::fs::read_dir("/sys/devices/system/node").ok()? {
        let entry = entry.ok()?;
        let name = entry.file_name();
        let name = name.to_str()?;
        let Some(idx) = name.strip_prefix("node").and_then(|s| s.parse::<usize>().ok()) else {
            continue;
        };
        let cpulist = std::fs::read_to_string(entry.path().join("cpulist")).ok()?;
        let cpus = parse_cpulist(&cpulist);
        if !cpus.is_empty() {
            nodes.push((idx, cpus));
        }
    }
    if nodes.is_empty() {
        return None;
    }
    nodes.sort_by_key(|&(idx, _)| idx);
    Some(nodes.into_iter().map(|(_, cpus)| cpus).collect())
}

#[cfg(not(target_os = "linux"))]
fn probe_sysfs() -> Option<Vec<Vec<usize>>> {
    None
}

fn probe() -> &'static Probe {
    static PROBE: OnceLock<Probe> = OnceLock::new();
    PROBE.get_or_init(|| {
        let mut nodes = probe_sysfs().unwrap_or_else(|| vec![detected_cpus()]);
        if nodes.len() > MAX_NODES {
            // Fold the tail into the last kept node: locality loss only.
            let tail: Vec<usize> = nodes.drain(MAX_NODES..).flatten().collect();
            nodes[MAX_NODES - 1].extend(tail);
        }
        Probe { nodes }
    })
}

/// Split `cpus` into `n` synthetic round-robin groups (for
/// `COMQ_NUMA=<n>`). Never returns an empty group: `n` is clamped to the
/// CPU count.
fn synthetic_split(cpus: &[usize], n: usize) -> Vec<Vec<usize>> {
    let n = n.clamp(1, cpus.len().max(1));
    let mut groups = vec![Vec::new(); n];
    for (i, &c) in cpus.iter().enumerate() {
        groups[i % n].push(c);
    }
    groups
}

/// Effective node layout under the current mode: one CPU list per node,
/// `1..=MAX_NODES` entries, none empty.
fn layout() -> Vec<Vec<usize>> {
    match mode() {
        NumaMode::Off => vec![detected_cpus()],
        NumaMode::Auto => probe().nodes.clone(),
        NumaMode::Force(n) => {
            let all: Vec<usize> = probe().nodes.iter().flatten().copied().collect();
            synthetic_split(&all, n.min(MAX_NODES))
        }
    }
}

/// Number of NUMA nodes in effect (≥ 1, ≤ [`MAX_NODES`]). This is the
/// shard count for packed panels and the grouping factor for pool
/// workers. `COMQ_NUMA=off` always returns 1.
pub fn nodes() -> usize {
    layout().len().max(1)
}

/// CPUs belonging to `node` under the current mode (empty if the node id
/// is out of range, which callers treat as "don't pin").
pub fn node_cpus(node: usize) -> Vec<usize> {
    layout().get(node).cloned().unwrap_or_default()
}

/// Whether worker pinning should happen at all: only when a multi-node
/// layout is in effect. `off` and single-node machines never pin, so the
/// default path is identical to the pre-NUMA build.
pub fn pin_enabled() -> bool {
    mode() != NumaMode::Off && nodes() > 1
}

// ---------------------------------------------------------------------------
// Affinity
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod affinity {
    // Raw syscall wrapper against the C library std already links — the
    // same no-libc-crate idiom as `serve/net/epoll.rs`. The mask is a
    // 1024-bit cpu_set_t expressed as 16 u64 words.
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }

    pub fn pin_current_thread(cpus: &[usize]) -> bool {
        const WORDS: usize = 16; // 1024 CPUs
        let mut mask = [0u64; WORDS];
        let mut any = false;
        for &c in cpus {
            if c < WORDS * 64 {
                mask[c / 64] |= 1u64 << (c % 64);
                any = true;
            }
        }
        if !any {
            return false;
        }
        // pid 0 = calling thread.
        unsafe { sched_setaffinity(0, WORDS * 8, mask.as_ptr()) == 0 }
    }
}

#[cfg(not(target_os = "linux"))]
mod affinity {
    pub fn pin_current_thread(_cpus: &[usize]) -> bool {
        false
    }
}

/// Pin the calling thread to the CPUs of `node`. Best-effort: failure
/// (empty node, non-Linux, syscall error — e.g. a cpuset-restricted
/// container) warns once and leaves the thread unpinned; scheduling
/// correctness never depends on affinity.
pub fn pin_to_node(node: usize) -> bool {
    let cpus = node_cpus(node);
    if cpus.is_empty() {
        return false;
    }
    let ok = affinity::pin_current_thread(&cpus);
    if !ok {
        crate::warn_once!("NUMA: pinning to node {node} failed; continuing unpinned");
    }
    ok
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parsing_rules() {
        assert_eq!(parse_mode(None), Ok(NumaMode::Auto));
        assert_eq!(parse_mode(Some("")), Ok(NumaMode::Auto));
        assert_eq!(parse_mode(Some("  auto ")), Ok(NumaMode::Auto));
        assert_eq!(parse_mode(Some("off")), Ok(NumaMode::Off));
        assert_eq!(parse_mode(Some("OFF")), Ok(NumaMode::Off));
        assert_eq!(parse_mode(Some("2")), Ok(NumaMode::Force(2)));
        assert_eq!(parse_mode(Some("0")), Err("0".to_string()));
        assert_eq!(parse_mode(Some("lots")), Err("lots".to_string()));
        assert_eq!(parse_mode(Some("-1")), Err("-1".to_string()));
    }

    #[test]
    fn cpulist_parsing() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0-1,4-5"), vec![0, 1, 4, 5]);
        assert_eq!(parse_cpulist("7"), vec![7]);
        assert_eq!(parse_cpulist(" 0 , 2-3 \n"), vec![0, 2, 3]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("garbage"), Vec::<usize>::new());
        // inverted / absurd ranges are dropped, not expanded
        assert_eq!(parse_cpulist("5-2"), Vec::<usize>::new());
    }

    #[test]
    fn synthetic_split_covers_all_cpus() {
        let cpus: Vec<usize> = (0..8).collect();
        let groups = synthetic_split(&cpus, 2);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0], vec![0, 2, 4, 6]);
        assert_eq!(groups[1], vec![1, 3, 5, 7]);
        // n > cpu count clamps: never an empty group
        let groups = synthetic_split(&[0, 1], 5);
        assert_eq!(groups.len(), 2);
        assert!(groups.iter().all(|g| !g.is_empty()));
    }

    #[test]
    fn override_is_dynamic_and_off_is_single_node() {
        // Other tests in this binary may run concurrently; keep the
        // override window short and restore it before asserting on the
        // ambient mode.
        set_mode_override(Some(NumaMode::Off));
        assert_eq!(mode(), NumaMode::Off);
        assert_eq!(nodes(), 1);
        assert!(!pin_enabled());
        set_mode_override(Some(NumaMode::Force(2)));
        let n = nodes();
        assert!(n >= 1 && n <= 2, "forced split clamps to cpu count, got {n}");
        set_mode_override(None);
        assert!(nodes() >= 1);
    }
}
