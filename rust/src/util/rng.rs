//! Deterministic xoshiro256** RNG (no `rand` crate in the vendored set).
//!
//! Used by tests, the property-testing harness, and synthetic workload
//! generators in the benches. Seeded explicitly everywhere so every
//! number in EXPERIMENTS.md is reproducible.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the state
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-9);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let v = r.normal_vec(50_000);
        let mean = v.iter().sum::<f32>() / v.len() as f32;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
