//! Minimal JSON parser + writer (RFC 8259 subset sufficient for
//! `artifacts/manifest.json` and the coordinator's report files).
//!
//! Hand-rolled because serde is not in the vendored crate set.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Numbers are stored as f64 (manifest values fit exactly).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &str) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {path}: {e}"))?;
        Self::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.num()? as usize)
    }

    pub fn boolean(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    // -- builders -----------------------------------------------------------

    pub fn obj_from(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    /// Serialize. `indent = 0` emits compact JSON.
    pub fn to_string_pretty(&self, indent: usize) -> String {
        let mut out = String::new();
        self.write(&mut out, indent, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent > 0 {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: usize, depth: usize) {
    if indent > 0 {
        out.push('\n');
        for _ in 0..indent * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.s
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.s.len()
            && matches!(self.s[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number '{text}': {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.s.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs: accept but replace (manifest is ASCII)
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{} at byte {}", e as char, self.i),
                    }
                }
                _ => {
                    // continue multi-byte UTF-8 sequences verbatim
                    let len = utf8_len(c);
                    let bytes = &self.s[self.i - 1..self.i - 1 + len];
                    out.push_str(std::str::from_utf8(bytes)?);
                    self.i += len - 1;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().num().unwrap(), 1.0);
        assert_eq!(v.get("b").unwrap().arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().num().unwrap(), -2500.0);
        let re = Json::parse(&v.to_string_pretty(1)).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty(0)).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".to_string());
        let s = v.to_string_pretty(0);
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.str().unwrap(), "héllo ☃");
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse("{\"x\": 3}").unwrap();
        assert!(v.get("y").is_err());
        assert!(v.get("x").unwrap().str().is_err());
        assert!(v.get("x").unwrap().boolean().is_err());
        assert_eq!(v.get("x").unwrap().usize().unwrap(), 3);
    }
}
