//! Substrate utilities built in-tree (this build is fully offline; only the
//! `xla` crate's dependency closure is available, so JSON, RNG, stats,
//! timing and the worker pool are all implemented here).

pub mod json;
pub mod pool;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod timer;
pub mod topo;

pub use json::Json;
pub use rng::Rng;
pub use timer::Timer;

/// Read an environment variable as a trimmed string (None when unset or
/// blank). `COMQ_KERNEL` flows through here (see `util::simd`);
/// `COMQ_THREADS` has its own policy parser below (invalid values must
/// warn, not silently vanish).
pub fn env_str(key: &str) -> Option<String> {
    std::env::var(key)
        .ok()
        .map(|v| v.trim().to_string())
        .filter(|v| !v.is_empty())
}

/// Parsed `COMQ_THREADS` policy: `Ok(None)` = unset/blank → auto,
/// `Ok(Some(n))` = explicit count ≥ 1, `Err(raw)` = `0` or unparsable —
/// not a usable thread count, the caller warns once and falls back to
/// auto. Pure so the rules are unit-testable without touching the
/// process environment (tests in this crate run concurrently).
fn parse_threads(raw: Option<&str>) -> Result<Option<usize>, String> {
    match raw {
        None => Ok(None),
        Some(s) => {
            let t = s.trim();
            if t.is_empty() {
                return Ok(None); // blank = unset, like env_str
            }
            match t.parse::<usize>() {
                Ok(0) | Err(_) => Err(t.to_string()),
                Ok(n) => Ok(Some(n)),
            }
        }
    }
}

/// `COMQ_THREADS`, the crate-wide parallelism override. Re-read on every
/// call (the thread-scaling bench flips it between runs). `0` and
/// unparsable values mean "auto = use all detected cores" with a
/// one-time warning — the same warn-and-fall-back contract as the
/// `COMQ_KERNEL` override (`util::simd::Kernel::active`), instead of
/// the old silent clamp of 0 to a single thread.
pub fn comq_threads() -> Option<usize> {
    let raw = std::env::var("COMQ_THREADS").ok();
    match parse_threads(raw.as_deref()) {
        Ok(v) => v,
        Err(bad) => {
            crate::warn_once!(
                "COMQ_THREADS={bad}: not a positive thread count, using auto-detected parallelism"
            );
            None
        }
    }
}

/// Effective parallelism for the current call: `COMQ_THREADS` if set,
/// otherwise available hardware parallelism capped at 16. Used by the
/// worker pool sizing and the serve-queue executor sizing.
pub fn effective_threads() -> usize {
    comq_threads()
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16))
}

#[cfg(test)]
mod tests {
    use super::parse_threads;

    #[test]
    fn thread_parsing_rules() {
        // unset / blank → auto, silently
        assert_eq!(parse_threads(None), Ok(None));
        assert_eq!(parse_threads(Some("")), Ok(None));
        assert_eq!(parse_threads(Some("   ")), Ok(None));
        // explicit positive counts pass through (trimmed)
        assert_eq!(parse_threads(Some("1")), Ok(Some(1)));
        assert_eq!(parse_threads(Some(" 8 ")), Ok(Some(8)));
        // 0 and garbage are invalid → warn-and-auto, not clamp-to-1
        assert_eq!(parse_threads(Some("0")), Err("0".to_string()));
        assert_eq!(parse_threads(Some("lots")), Err("lots".to_string()));
        assert_eq!(parse_threads(Some("-2")), Err("-2".to_string()));
    }
}
