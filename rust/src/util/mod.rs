//! Substrate utilities built in-tree (this build is fully offline; only the
//! `xla` crate's dependency closure is available, so JSON, RNG, stats,
//! timing and the worker pool are all implemented here).

pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;

pub use json::Json;
pub use rng::Rng;
pub use timer::Timer;
