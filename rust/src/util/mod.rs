//! Substrate utilities built in-tree (this build is fully offline; only the
//! `xla` crate's dependency closure is available, so JSON, RNG, stats,
//! timing and the worker pool are all implemented here).

pub mod json;
pub mod pool;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod timer;

pub use json::Json;
pub use rng::Rng;
pub use timer::Timer;

/// Parse an environment variable as `usize` (None when unset or not a
/// number). The single place env-var parsing lives; callers that need a
/// specific knob wrap this so the parsing rules can't drift apart.
pub fn env_usize(key: &str) -> Option<usize> {
    std::env::var(key).ok().and_then(|v| v.parse::<usize>().ok())
}

/// Read an environment variable as a trimmed string (None when unset or
/// blank). `COMQ_KERNEL` flows through here (see `util::simd`), the
/// numeric knobs through [`env_usize`].
pub fn env_str(key: &str) -> Option<String> {
    std::env::var(key)
        .ok()
        .map(|v| v.trim().to_string())
        .filter(|v| !v.is_empty())
}

/// `COMQ_THREADS`, the crate-wide parallelism override. Re-read on every
/// call (the thread-scaling bench flips it between runs). Values are
/// clamped to ≥ 1.
pub fn comq_threads() -> Option<usize> {
    env_usize("COMQ_THREADS").map(|n| n.max(1))
}

/// Effective parallelism for the current call: `COMQ_THREADS` if set,
/// otherwise available hardware parallelism capped at 16. Used by the
/// worker pool sizing and the serve-queue executor sizing.
pub fn effective_threads() -> usize {
    comq_threads()
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16))
}
