//! Small statistics helpers shared by the bench harness and reports.

/// Mean of a slice (0.0 for empty input).
pub fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.iter().sum::<f64>() / v.len() as f64
}

/// Sample standard deviation.
pub fn std_dev(v: &[f64]) -> f64 {
    if v.len() < 2 {
        return 0.0;
    }
    let m = mean(v);
    (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (v.len() - 1) as f64).sqrt()
}

/// Quantile by linear interpolation on a sorted copy; q in [0, 1].
/// Callers reading many quantiles from the same sample should sort once
/// and use [`quantile_sorted`] instead.
pub fn quantile(v: &[f64], q: f64) -> f64 {
    let mut s = v.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&s, q)
}

/// [`quantile`] over an already ascending-sorted slice — no copy, no
/// re-sort, so percentile tables over large bench samples stay O(n log n)
/// once instead of per-row.
pub fn quantile_sorted(s: &[f64], q: f64) -> f64 {
    if s.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
    }
}

/// Smallest element (0.0 for empty input, like the other helpers here —
/// an empty sample must not leak ±inf into reports/JSON).
pub fn min(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Largest element (0.0 for empty input).
pub fn max(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&v), 2.5);
        assert!((std_dev(&v) - 1.2909944).abs() < 1e-6);
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 1.0), 4.0);
        assert_eq!(quantile(&v, 0.5), 2.5);
        assert_eq!(min(&v), 1.0);
        assert_eq!(max(&v), 4.0);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
        assert_eq!(quantile_sorted(&[], 0.5), 0.0);
        // min/max must be finite on empty input — ±inf is not JSON
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
    }

    #[test]
    fn quantile_sorted_matches_quantile() {
        let v = [5.0, 1.0, 4.0, 2.0, 3.0];
        let mut s = v.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 1.0] {
            assert_eq!(quantile_sorted(&s, q), quantile(&v, q));
        }
        assert_eq!(quantile_sorted(&s, 0.5), 3.0);
    }
}
