//! Small statistics helpers shared by the bench harness and reports.

/// Mean of a slice (0.0 for empty input).
pub fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.iter().sum::<f64>() / v.len() as f64
}

/// Sample standard deviation.
pub fn std_dev(v: &[f64]) -> f64 {
    if v.len() < 2 {
        return 0.0;
    }
    let m = mean(v);
    (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (v.len() - 1) as f64).sqrt()
}

/// Quantile by linear interpolation on the sorted copy; q in [0, 1].
pub fn quantile(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let mut s = v.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
    }
}

pub fn min(v: &[f64]) -> f64 {
    v.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(v: &[f64]) -> f64 {
    v.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&v), 2.5);
        assert!((std_dev(&v) - 1.2909944).abs() < 1e-6);
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 1.0), 4.0);
        assert_eq!(quantile(&v, 0.5), 2.5);
        assert_eq!(min(&v), 1.0);
        assert_eq!(max(&v), 4.0);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
    }
}
