//! Persistent work-stealing worker pool over std threads.
//!
//! No rayon in the vendored set, so every parallel region in the crate
//! (the COMQ sweeps, matmul, the serving GEMMs, the layer scheduler)
//! funnels through the helpers here. PR 2 replaced spawn-per-call
//! threading with a persistent pool behind one global FIFO; this PR
//! replaces that single queue with a work-stealing scheduler so the
//! serving hot path can keep every core busy across concurrent
//! submissions (pipeline stages, nested quantizer jobs) instead of
//! convoying behind one mutex.
//!
//! ## Scheduler shape
//!
//! * Every worker owns a bounded lock-free Chase–Lev deque. The owner
//!   pushes and pops at the bottom (LIFO — nested submissions run their
//!   own freshest work first, while it is still cache-hot); thieves take
//!   from the top (FIFO — they get the oldest, largest-remaining chunk,
//!   which amortizes the steal).
//! * Per-NUMA-node injector queues (`util/topo.rs` decides the node
//!   count) receive submissions from non-worker threads and node-hinted
//!   work ([`parallel_sharded`]); a worker looks for work in order: own
//!   deque → own node's injector → other injectors → steal same-node
//!   victims → steal cross-node. Hints and topology bias *placement
//!   only*; every queue is visible to every worker, so a wrong or stale
//!   topology costs locality, never correctness.
//! * Workers never exit; when no work is visible anywhere they park on a
//!   condvar with a timeout backstop, and publishers wake them only when
//!   an idle worker exists. Wakeups are a latency optimization, not a
//!   correctness dependency — see the helping join below.
//!
//! ## Determinism and bit-identity
//!
//! [`parallel_ranges`] computes the *same contiguous chunking* of
//! `0..n` as the fork-join pool did (`chunk = n.div_ceil(threads)`).
//! Stealing redistributes whole chunks across threads but never splits
//! one, so per-chunk iteration order — and therefore every in-chunk
//! reduction order — is unchanged. Which OS thread runs a chunk is the
//! only thing that varies, and no kernel in the crate keys on that.
//! `COMQ_THREADS=1` (or work below `min_per_thread`) still runs inline
//! on the calling thread as a single chunk and never touches — or
//! creates — the pool.
//!
//! ## Lifecycle and joining
//!
//! A call to [`parallel_ranges`] publishes one task per chunk and then
//! *helps*: the calling thread pops/steals alongside the workers until
//! its own completion latch opens. Helping makes correctness independent
//! of pool capacity (with zero spawnable threads the caller just runs
//! everything itself) and makes nested/concurrent calls deadlock-free:
//! no thread ever blocks while runnable work is visible, and when a
//! joiner does block, every one of its outstanding tasks is already in
//! flight on some other thread, which will open the latch.
//!
//! Closures are handed to workers by reference with the lifetime erased;
//! this is sound because the submitting call cannot return until its
//! completion latch opens, i.e. strictly after the last worker touching
//! the closure finished. A panic inside any task is caught on the
//! executing thread, stored in the latch, and re-thrown on the calling
//! thread once the remaining tasks finish; workers survive and keep
//! serving work.

use std::cell::{Cell, UnsafeCell};
use std::collections::VecDeque;
use std::mem::MaybeUninit;
use std::ops::Range;
use std::sync::atomic::{fence, AtomicIsize, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::obs::{self, Counter, Gauge, Histogram};
use crate::util::topo;

/// Hard cap on persistent workers, independent of `COMQ_THREADS`.
const MAX_WORKERS: usize = 64;

/// Per-worker deque capacity (power of two). Overflow is not loss: a
/// push that finds the ring full diverts to the owner's node injector.
const DEQUE_CAP: usize = 256;

/// How long a worker with no visible work sleeps before rescanning. A
/// backstop only — publishers notify the condvar when idle workers
/// exist, and joining callers never depend on worker wakeups at all.
const PARK_TIMEOUT: Duration = Duration::from_millis(50);

/// Number of worker threads to use for the *current* call: respects
/// COMQ_THREADS (re-read every call via [`crate::util::comq_threads`]),
/// defaults to available parallelism capped at 16.
pub fn num_threads() -> usize {
    crate::util::effective_threads()
}

// ---------------------------------------------------------------------------
// Latch + task
// ---------------------------------------------------------------------------

/// Completion latch shared by all tasks of one submission.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn std::any::Any + Send + 'static>>,
}

impl Latch {
    fn new(remaining: usize) -> Arc<Latch> {
        Arc::new(Latch {
            state: Mutex::new(LatchState { remaining, panic: None }),
            cv: Condvar::new(),
        })
    }
}

/// One published chunk of one submission. `func` is the submitting
/// call's closure with its lifetime erased; the latch-wait in the
/// submitter keeps it alive until every task referencing it has run.
/// `chunk` is the chunk index for [`parallel_ranges`] and the shard
/// index for [`parallel_sharded`].
struct Task {
    func: &'static (dyn Fn(usize, Range<usize>) + Sync),
    chunk: usize,
    lo: usize,
    hi: usize,
    latch: Arc<Latch>,
    /// Enqueue timestamp, taken only when telemetry is on — queue wait
    /// is the gap until a participant (worker or helping submitter)
    /// picks the task up.
    enqueued: Option<Instant>,
}

// ---------------------------------------------------------------------------
// Chase–Lev deque (bounded)
// ---------------------------------------------------------------------------

/// Bounded lock-free work-stealing deque (Chase & Lev, with the
/// C11-memory-model orderings of Lê et al.). The owner worker pushes
/// and pops at `bottom`; thieves CAS `top` upward. Bounded on purpose:
/// a thief's speculative `ptr::read` of slot `t` is safe because the
/// owner cannot wrap around and overwrite index `t` until `top` has
/// advanced past it (`push` refuses when `bottom - top == capacity`),
/// and a full deque simply diverts the push to an injector.
struct Deque {
    top: AtomicIsize,
    bottom: AtomicIsize,
    buf: Box<[UnsafeCell<MaybeUninit<Task>>]>,
}

// Slots are only read/written under the top/bottom index protocol below.
unsafe impl Sync for Deque {}
unsafe impl Send for Deque {}

enum Steal {
    Empty,
    /// Lost a race; the queue may still be non-empty. Callers must not
    /// treat this as proof of emptiness.
    Retry,
    Task(Task),
}

impl Deque {
    fn new() -> Deque {
        debug_assert!(DEQUE_CAP.is_power_of_two());
        Deque {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: (0..DEQUE_CAP).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect(),
        }
    }

    #[inline]
    fn slot(&self, i: isize) -> *mut MaybeUninit<Task> {
        self.buf[(i & (DEQUE_CAP as isize - 1)) as usize].get()
    }

    /// Owner only. Returns the task back when the ring is full.
    fn push(&self, t: Task) -> Result<(), Task> {
        let b = self.bottom.load(Ordering::Relaxed);
        let top = self.top.load(Ordering::Acquire);
        if b.wrapping_sub(top) >= DEQUE_CAP as isize {
            return Err(t);
        }
        unsafe { (*self.slot(b)).write(t) };
        self.bottom.store(b.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Owner only: LIFO pop from the bottom.
    fn pop(&self) -> Option<Task> {
        let b = self.bottom.load(Ordering::Relaxed).wrapping_sub(1);
        self.bottom.store(b, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            if t == b {
                // Last element: race the thieves for it via `top`.
                let won = self
                    .top
                    .compare_exchange(t, t.wrapping_add(1), Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                self.bottom.store(b.wrapping_add(1), Ordering::Relaxed);
                won.then(|| unsafe { (*self.slot(b)).assume_init_read() })
            } else {
                // More than one element: thieves can reach at most b-1
                // (they read `bottom` after their fence), slot b is ours.
                Some(unsafe { (*self.slot(b)).assume_init_read() })
            }
        } else {
            self.bottom.store(b.wrapping_add(1), Ordering::Relaxed);
            None
        }
    }

    /// Any thread: FIFO steal from the top.
    fn steal(&self) -> Steal {
        let t = self.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t >= b {
            return Steal::Empty;
        }
        // Speculative read; see the type-level comment for why the slot
        // cannot be overwritten before the CAS resolves.
        let task = unsafe { (*self.slot(t)).assume_init_read() };
        if self
            .top
            .compare_exchange(t, t.wrapping_add(1), Ordering::SeqCst, Ordering::Relaxed)
            .is_ok()
        {
            Steal::Task(task)
        } else {
            // Someone else owns the value now; forget our copy.
            std::mem::forget(task);
            Steal::Retry
        }
    }

    /// Approximate — used only for park heuristics, never correctness.
    fn maybe_nonempty(&self) -> bool {
        self.top.load(Ordering::Relaxed) < self.bottom.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Telemetry
// ---------------------------------------------------------------------------

/// Pool-wide telemetry handles, resolved once (the registry lock is too
/// slow for per-task lookups). Per-node counters/gauges are created
/// lazily so single-node processes don't export phantom node series.
struct PoolObs {
    wait: Arc<Histogram>,
    busy: Arc<Histogram>,
    jobs: Arc<Counter>,
    workers: Arc<Gauge>,
    steals: Arc<Counter>,
    /// `comq_pool_tasks_total{node=...}`; index MAX_NODES = "ext"
    /// (tasks run by helping non-worker threads).
    tasks_node: Vec<OnceLock<Arc<Counter>>>,
    /// `comq_pool_workers{node=...}` gauges.
    workers_node: Vec<OnceLock<Arc<Gauge>>>,
}

impl PoolObs {
    fn tasks(&self, node: Option<usize>) -> &Arc<Counter> {
        let idx = match node {
            Some(n) => n.min(topo::MAX_NODES - 1),
            None => topo::MAX_NODES,
        };
        self.tasks_node[idx].get_or_init(|| {
            let label = if idx == topo::MAX_NODES { "ext".to_string() } else { idx.to_string() };
            obs::registry()
                .counter(&obs::metrics::with_labels("comq_pool_tasks_total", &[("node", &label)]))
        })
    }

    fn node_workers(&self, node: usize) -> &Arc<Gauge> {
        let idx = node.min(topo::MAX_NODES - 1);
        self.workers_node[idx].get_or_init(|| {
            let node = idx.to_string();
            let name = obs::metrics::with_labels("comq_pool_workers", &[("node", &node)]);
            obs::registry().gauge(&name)
        })
    }
}

fn pool_obs() -> &'static PoolObs {
    static OBS: OnceLock<PoolObs> = OnceLock::new();
    OBS.get_or_init(|| PoolObs {
        wait: obs::registry().histogram("comq_pool_task_wait_seconds"),
        busy: obs::registry().histogram("comq_pool_job_seconds"),
        jobs: obs::registry().counter("comq_pool_jobs_total"),
        workers: obs::registry().gauge("comq_pool_workers"),
        steals: obs::registry().counter("comq_pool_steals_total"),
        tasks_node: (0..=topo::MAX_NODES).map(|_| OnceLock::new()).collect(),
        workers_node: (0..topo::MAX_NODES).map(|_| OnceLock::new()).collect(),
    })
}

// ---------------------------------------------------------------------------
// Pool
// ---------------------------------------------------------------------------

struct WorkerSlot {
    deque: Deque,
    /// NUMA node this worker was assigned at spawn (placement bias only).
    node: AtomicUsize,
}

/// One node-local FIFO for external and node-hinted submissions.
struct Injector {
    q: Mutex<VecDeque<Task>>,
    /// Fast non-empty check for scan/park paths.
    len: AtomicUsize,
}

impl Injector {
    fn push(&self, t: Task) {
        let mut q = self.q.lock().unwrap();
        q.push_back(t);
        self.len.store(q.len(), Ordering::Release);
    }

    fn pop(&self) -> Option<Task> {
        if self.len.load(Ordering::Acquire) == 0 {
            return None;
        }
        let mut q = self.q.lock().unwrap();
        let t = q.pop_front();
        self.len.store(q.len(), Ordering::Release);
        t
    }
}

struct Pool {
    workers: Vec<WorkerSlot>,
    /// Spawned worker count; slots `0..live` are active.
    live: AtomicUsize,
    injectors: Vec<Injector>,
    spawn_mx: Mutex<()>,
    sleep_mx: Mutex<()>,
    sleep_cv: Condvar,
    /// Workers currently parked (wake-throttling heuristic).
    idle: AtomicUsize,
    /// Round-robin cursor spreading unhinted external submissions
    /// across node injectors.
    rr: AtomicUsize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        workers: (0..MAX_WORKERS)
            .map(|_| WorkerSlot { deque: Deque::new(), node: AtomicUsize::new(0) })
            .collect(),
        live: AtomicUsize::new(0),
        injectors: (0..topo::MAX_NODES)
            .map(|_| Injector { q: Mutex::new(VecDeque::new()), len: AtomicUsize::new(0) })
            .collect(),
        spawn_mx: Mutex::new(()),
        sleep_mx: Mutex::new(()),
        sleep_cv: Condvar::new(),
        idle: AtomicUsize::new(0),
        rr: AtomicUsize::new(0),
    })
}

thread_local! {
    /// Set for the lifetime of a pool worker thread; `None` on every
    /// other thread. Distinguishes "push to own deque" (workers, nested
    /// submissions) from "push to an injector" (external submitters).
    static WORKER_ID: Cell<Option<usize>> = const { Cell::new(None) };
}

fn current_worker() -> Option<usize> {
    WORKER_ID.with(|c| c.get())
}

/// Persistent workers currently alive (diagnostics / tests). Zero until
/// the first out-of-line parallel call.
pub fn pool_workers() -> usize {
    POOL.get().map(|p| p.live.load(Ordering::Acquire)).unwrap_or(0)
}

impl Pool {
    /// Any task visible in an injector or a worker deque? Approximate;
    /// used only to decide whether a worker should park.
    fn maybe_work(&self) -> bool {
        if self.injectors.iter().any(|i| i.len.load(Ordering::Acquire) > 0) {
            return true;
        }
        let live = self.live.load(Ordering::Acquire);
        self.workers[..live].iter().any(|w| w.deque.maybe_nonempty())
    }

    /// Wake parked workers iff any exist. Publishers call this after
    /// every push; the lock closes the scan-then-park race and the
    /// park timeout backstops the rest.
    fn wake(&self) {
        if self.idle.load(Ordering::Relaxed) > 0 {
            let _g = self.sleep_mx.lock().unwrap();
            self.sleep_cv.notify_all();
        }
    }
}

enum Find {
    Task(Task, /* stolen: */ bool),
    Retry,
    None,
}

/// One scan for runnable work. `me` is the calling worker's id (None for
/// helping external threads); `home` is the preferred injector to drain
/// first. Scan order: own deque (LIFO) → home injector → remaining
/// injectors → steal same-node workers → steal the rest.
fn try_find(p: &Pool, me: Option<usize>, home: usize) -> Find {
    if let Some(w) = me {
        if let Some(t) = p.workers[w].deque.pop() {
            return Find::Task(t, false);
        }
    }
    let n_inj = p.injectors.len();
    for k in 0..n_inj {
        if let Some(t) = p.injectors[(home + k) % n_inj].pop() {
            return Find::Task(t, false);
        }
    }
    let live = p.live.load(Ordering::Acquire);
    if live == 0 {
        return Find::None;
    }
    let my_node = me.map(|w| p.workers[w].node.load(Ordering::Relaxed));
    let start = me.map(|w| w + 1).unwrap_or_else(|| p.rr.load(Ordering::Relaxed));
    let mut saw_retry = false;
    // Two passes: same-node victims first, then everyone else.
    for pass in 0..2 {
        for k in 0..live {
            let v = (start + k) % live;
            if Some(v) == me {
                continue;
            }
            let v_node = p.workers[v].node.load(Ordering::Relaxed);
            let same = my_node.map(|n| n == v_node).unwrap_or(true);
            if (pass == 0) != same {
                continue;
            }
            match p.workers[v].deque.steal() {
                Steal::Task(t) => return Find::Task(t, true),
                Steal::Retry => saw_retry = true,
                Steal::Empty => {}
            }
        }
        if my_node.is_none() {
            break; // helpers have no node: one pass covers everyone
        }
    }
    if saw_retry {
        Find::Retry
    } else {
        Find::None
    }
}

/// Run one task and report its outcome to the task's latch. Panics are
/// caught here so the executing thread survives and the submitter can
/// re-throw.
fn run_task(task: Task, me: Option<usize>, stolen: bool) {
    let started = task.enqueued.map(|t| {
        let now = Instant::now();
        let o = pool_obs();
        o.wait.record(now.saturating_duration_since(t).as_nanos() as u64);
        if stolen {
            o.steals.inc();
        }
        now
    });
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        (task.func)(task.chunk, task.lo..task.hi)
    }));
    if let Some(t) = started {
        let o = pool_obs();
        o.busy.record(t.elapsed().as_nanos() as u64);
        o.jobs.inc();
        let node = me.map(|w| pool().workers[w].node.load(Ordering::Relaxed));
        o.tasks(node).inc();
    }
    let mut st = task.latch.state.lock().unwrap();
    if let Err(payload) = result {
        if st.panic.is_none() {
            st.panic = Some(payload);
        }
    }
    st.remaining -= 1;
    if st.remaining == 0 {
        task.latch.cv.notify_all();
    }
}

fn worker_loop(p: &'static Pool, id: usize) {
    WORKER_ID.with(|c| c.set(Some(id)));
    let node = p.workers[id].node.load(Ordering::Relaxed);
    if topo::pin_enabled() {
        topo::pin_to_node(node);
    }
    loop {
        match try_find(p, Some(id), node) {
            Find::Task(t, stolen) => run_task(t, Some(id), stolen),
            Find::Retry => std::hint::spin_loop(),
            Find::None => {
                p.idle.fetch_add(1, Ordering::Relaxed);
                {
                    let g = p.sleep_mx.lock().unwrap();
                    // Re-check under the lock: a publisher that pushed
                    // after our scan but before we parked holds this
                    // lock in `wake()` and will notify.
                    if !p.maybe_work() {
                        let _ = p.sleep_cv.wait_timeout(g, PARK_TIMEOUT).unwrap();
                    }
                }
                p.idle.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }
}

/// Grow the pool to at least `wanted` workers (capped). Workers are
/// assigned to NUMA nodes round-robin at spawn and pinned to their
/// node's CPUs when a multi-node layout is in effect. Spawn failure is
/// tolerated: helping-join keeps submissions correct with any number of
/// workers, including zero.
fn ensure_workers(p: &'static Pool, wanted: usize) {
    let wanted = wanted.min(MAX_WORKERS);
    if p.live.load(Ordering::Acquire) >= wanted {
        return;
    }
    let _g = p.spawn_mx.lock().unwrap();
    let mut live = p.live.load(Ordering::Acquire);
    let n_nodes = topo::nodes().min(topo::MAX_NODES).max(1);
    while live < wanted {
        let id = live;
        let node = id % n_nodes;
        p.workers[id].node.store(node, Ordering::Relaxed);
        let spawned = std::thread::Builder::new()
            .name(format!("comq-pool-{id}"))
            .spawn(move || worker_loop(pool(), id))
            .is_ok();
        if !spawned {
            break;
        }
        live += 1;
        p.live.store(live, Ordering::Release);
        if obs::enabled() {
            pool_obs().node_workers(node).add(1);
        }
    }
    if obs::enabled() {
        pool_obs().workers.set(live as i64);
    }
}

/// Publish one task: workers push to their own deque (overflow diverts
/// to their node's injector); other threads push to `home`'s injector.
fn publish(p: &'static Pool, me: Option<usize>, home: usize, task: Task) {
    match me {
        Some(w) => {
            if let Err(t) = p.workers[w].deque.push(task) {
                let node = p.workers[w].node.load(Ordering::Relaxed);
                p.injectors[node.min(p.injectors.len() - 1)].push(t);
            }
        }
        None => p.injectors[home % p.injectors.len()].push(task),
    }
}

/// Helping join: pop/steal alongside the workers until `latch` opens,
/// then re-throw any stored panic on this thread.
fn join(p: &'static Pool, latch: &Arc<Latch>, me: Option<usize>, home: usize) {
    loop {
        {
            let mut st = latch.state.lock().unwrap();
            if st.remaining == 0 {
                if let Some(payload) = st.panic.take() {
                    drop(st);
                    std::panic::resume_unwind(payload);
                }
                return;
            }
        }
        match try_find(p, me, home) {
            Find::Task(t, stolen) => run_task(t, me, stolen),
            Find::Retry => std::hint::spin_loop(),
            Find::None => {
                // Nothing visible anywhere => every one of our
                // outstanding tasks is in flight on another thread;
                // those threads will notify the latch.
                let mut st = latch.state.lock().unwrap();
                while st.remaining != 0 {
                    st = latch.cv.wait(st).unwrap();
                }
                if let Some(payload) = st.panic.take() {
                    drop(st);
                    std::panic::resume_unwind(payload);
                }
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Public API (signatures unchanged from the fork-join era)
// ---------------------------------------------------------------------------

/// Run `f(chunk_index, item_range)` over `n` items split into contiguous
/// ranges across up to `num_threads()` participants (pool workers plus
/// the calling thread). Runs inline when the work is too small to
/// amortize handing off, or when `COMQ_THREADS=1`. The chunk partition
/// is a pure function of `(n, min_per_thread, num_threads())` — see the
/// module docs for why that preserves bit-identity under stealing.
pub fn parallel_ranges<F>(n: usize, min_per_thread: usize, f: F)
where
    F: Fn(usize, Range<usize>) + Sync,
{
    let threads = num_threads().min(n / min_per_thread.max(1)).max(1);
    if threads <= 1 || n == 0 {
        f(0, 0..n);
        return;
    }
    let p = pool();
    ensure_workers(p, threads - 1);

    // Erase the closure lifetime. Sound: this frame only returns after
    // the latch confirms every task referencing `f` has completed.
    let func: &(dyn Fn(usize, Range<usize>) + Sync) = &f;
    let func: &'static (dyn Fn(usize, Range<usize>) + Sync) =
        unsafe { std::mem::transmute(func) };

    let chunk = n.div_ceil(threads);
    let jobs = n.div_ceil(chunk); // number of non-empty chunks
    let latch = Latch::new(jobs);
    let enqueued = obs::enabled().then(Instant::now);
    let me = current_worker();
    let home = match me {
        Some(w) => p.workers[w].node.load(Ordering::Relaxed),
        None => p.rr.fetch_add(1, Ordering::Relaxed) % topo::nodes().min(topo::MAX_NODES).max(1),
    };
    for t in 0..jobs {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        publish(p, me, home, Task { func, chunk: t, lo, hi, latch: latch.clone(), enqueued });
    }
    p.wake();
    join(p, &latch, me, home);
}

/// Run `f(shard_index, item_subrange)` over node-affine shards: shard
/// `i`'s tasks are published to node `i`'s injector, so the workers
/// pinned to that node consume them first and any i32 accumulation
/// stays node-local. Each shard is split into whole contiguous
/// sub-ranges (never below `min_per_task` items except for the
/// remainder), so per-item reduction order is unchanged no matter who
/// executes — the same bit-identity argument as [`parallel_ranges`].
///
/// With `COMQ_THREADS=1` (or an empty shard set) the shards run inline,
/// sequentially, in index order — the exact pre-NUMA behavior. Unlike
/// [`parallel_ranges`] there is no small-work inline shortcut: placement
/// is the point (first-touch shard builds must run *on their node*), so
/// `min_per_task` only bounds how finely one shard is subdivided.
/// Hints bias placement only: any worker (or the helping caller) can
/// take any shard's tasks, so a stale topology never strands work.
pub fn parallel_sharded<F>(shards: &[Range<usize>], min_per_task: usize, f: F)
where
    F: Fn(usize, Range<usize>) + Sync,
{
    let total: usize = shards.iter().map(|s| s.len()).sum();
    let threads = num_threads();
    if threads <= 1 || total == 0 {
        for (i, s) in shards.iter().enumerate() {
            f(i, s.clone());
        }
        return;
    }
    let p = pool();
    ensure_workers(p, threads - 1);

    let func: &(dyn Fn(usize, Range<usize>) + Sync) = &f;
    let func: &'static (dyn Fn(usize, Range<usize>) + Sync) =
        unsafe { std::mem::transmute(func) };

    // Split each shard into at most its fair share of the thread budget.
    let nonempty = shards.iter().filter(|s| !s.is_empty()).count().max(1);
    let per_shard = (threads / nonempty).max(1);
    let mut pieces: Vec<(usize, usize, usize)> = Vec::new(); // (shard, lo, hi)
    for (i, s) in shards.iter().enumerate() {
        let len = s.len();
        if len == 0 {
            continue;
        }
        let tasks = per_shard.min(len / min_per_task.max(1)).max(1);
        let chunk = len.div_ceil(tasks);
        for c in 0..len.div_ceil(chunk) {
            let lo = s.start + c * chunk;
            let hi = (s.start + (c + 1) * chunk).min(s.end);
            pieces.push((i, lo, hi));
        }
    }
    let latch = Latch::new(pieces.len());
    let enqueued = obs::enabled().then(Instant::now);
    let n_inj = p.injectors.len();
    for (i, lo, hi) in pieces {
        // Node hint = shard index: the panels for shard i live on node i.
        p.injectors[i.min(n_inj - 1)]
            .push(Task { func, chunk: i, lo, hi, latch: latch.clone(), enqueued });
    }
    p.wake();
    let me = current_worker();
    let home = me.map(|w| p.workers[w].node.load(Ordering::Relaxed)).unwrap_or(0);
    join(p, &latch, me, home);
}

/// Shared mutable base pointer for disjoint-region writes across pool
/// threads. The one crate-wide copy of this unsafe pattern: every
/// parallel caller (matmul, the sweep engines, `parallel_chunks_mut`,
/// the layer scheduler) splits a buffer into ranges that each
/// participant owns exclusively, which is what makes the `Send + Sync`
/// promise sound. Keep that contract in mind at every use site.
pub(crate) struct SendPtr<T>(*mut T);

impl<T> SendPtr<T> {
    pub(crate) fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    #[inline]
    pub(crate) fn ptr(&self) -> *mut T {
        self.0
    }
}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> SendPtr<T> {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Map over mutable disjoint chunks of `data` (each `chunk_len` long) in
/// parallel: `f(chunk_index, chunk_slice)`. Built on [`parallel_ranges`],
/// so it shares the work-stealing pool, helping join and panic behaviour.
pub fn parallel_chunks_mut<T, F>(
    data: &mut [T],
    chunk_len: usize,
    min_chunks_per_thread: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0 && data.len() % chunk_len == 0, "data must divide into chunks");
    let n_chunks = data.len() / chunk_len;
    let base = SendPtr::new(data.as_mut_ptr());
    parallel_ranges(n_chunks, min_chunks_per_thread, |_, range| {
        for i in range {
            // Ranges are disjoint, hence so are the chunk slices.
            let p = unsafe { base.ptr().add(i * chunk_len) };
            let chunk = unsafe { std::slice::from_raw_parts_mut(p, chunk_len) };
            f(i, chunk);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ranges_cover_everything_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_ranges(1000, 10, |_, r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn ranges_small_runs_inline() {
        let hits = AtomicUsize::new(0);
        parallel_ranges(3, 100, |t, r| {
            assert_eq!(t, 0);
            hits.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn chunks_mut_disjoint() {
        let mut v = vec![0usize; 64 * 8];
        parallel_chunks_mut(&mut v, 8, 1, |i, c| {
            for x in c.iter_mut() {
                *x = i;
            }
        });
        for (i, c) in v.chunks(8).enumerate() {
            assert!(c.iter().all(|&x| x == i));
        }
    }

    #[test]
    fn zero_items() {
        parallel_ranges(0, 1, |_, r| assert!(r.is_empty()));
    }

    #[test]
    fn pool_is_reused_across_calls() {
        // Warm the pool with one full-demand call, then check that ten
        // more identical-demand calls don't grow it: reuse means worker
        // count is set by per-call demand, not call count.
        parallel_ranges(256, 1, |_, _| {});
        let before = pool_workers();
        for _ in 0..10 {
            let hits: Vec<AtomicUsize> = (0..256).map(|_| AtomicUsize::new(0)).collect();
            parallel_ranges(256, 1, |_, r| {
                for i in r {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
        let after = pool_workers();
        // Concurrent tests can legitimately grow the pool up to the
        // current demand (e.g. the COMQ_THREADS=1 test may have shrunk
        // our warm-up call to inline), hence the max() slack — but call
        // count must never be a growth factor.
        assert!(
            after <= before.max(num_threads().saturating_sub(1)),
            "pool grew with call count: {before} -> {after}"
        );
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let res = std::panic::catch_unwind(|| {
            parallel_ranges(100, 1, |_, r| {
                if r.contains(&57) {
                    panic!("boom in chunk");
                }
            });
        });
        assert!(res.is_err(), "worker panic must reach the caller");
        // the pool keeps working after a propagated panic
        let hits = AtomicUsize::new(0);
        parallel_ranges(100, 1, |_, r| {
            hits.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn comq_threads_one_runs_inline() {
        // ci.sh runs this suite once with COMQ_THREADS=1 pinned —
        // restore whatever pin the caller set rather than deleting it
        let pinned = std::env::var("COMQ_THREADS").ok();
        std::env::set_var("COMQ_THREADS", "1");
        let hits = AtomicUsize::new(0);
        parallel_ranges(1000, 1, |t, r| {
            assert_eq!(t, 0, "inline fallback must use a single chunk");
            assert_eq!(r, 0..1000);
            hits.fetch_add(r.len(), Ordering::Relaxed);
        });
        // the sharded entry must likewise run inline, sequentially, in
        // shard index order under COMQ_THREADS=1
        let order = Mutex::new(Vec::new());
        parallel_sharded(&[0..2, 2..4, 4..6], 1, |shard, r| {
            order.lock().unwrap().push((shard, r));
        });
        assert_eq!(*order.lock().unwrap(), vec![(0, 0..2), (1, 2..4), (2, 4..6)]);
        match pinned {
            Some(v) => std::env::set_var("COMQ_THREADS", v),
            None => std::env::remove_var("COMQ_THREADS"),
        }
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn sharded_covers_every_item_once_per_shard() {
        let hits: Vec<AtomicUsize> = (0..300).map(|_| AtomicUsize::new(0)).collect();
        let owner: Vec<AtomicUsize> = (0..300).map(|_| AtomicUsize::new(usize::MAX)).collect();
        let shards = vec![0..100, 100..200, 200..300];
        parallel_sharded(&shards, 1, |shard, r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
                owner[i].store(shard, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        for (i, o) in owner.iter().enumerate() {
            assert_eq!(o.load(Ordering::Relaxed), i / 100, "item {i} ran under the wrong shard");
        }
    }

    #[test]
    fn sharded_panic_propagates() {
        let res = std::panic::catch_unwind(|| {
            parallel_sharded(&[0..50, 50..100], 1, |_, r| {
                if r.contains(&73) {
                    panic!("boom in shard");
                }
            });
        });
        assert!(res.is_err(), "shard panic must reach the caller");
        let hits = AtomicUsize::new(0);
        parallel_sharded(&[0..50, 50..100], 1, |_, r| {
            hits.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn concurrent_submissions_stress() {
        // Many submitters × (many small + few huge tasks): every item
        // must run exactly once per submission while stealing is active.
        let submitters = 4;
        std::thread::scope(|s| {
            for _ in 0..submitters {
                s.spawn(|| {
                    for round in 0..20 {
                        let n = if round % 5 == 0 { 4096 } else { 64 };
                        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                        parallel_ranges(n, 1, |_, r| {
                            for i in r {
                                hits[i].fetch_add(1, Ordering::Relaxed);
                            }
                        });
                        assert!(
                            hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                            "lost or duplicated a chunk under concurrent stealing"
                        );
                    }
                });
            }
        });
    }
}
