//! Scoped data-parallel helpers over std threads.
//!
//! No rayon in the vendored set, so the coordinator and the tensor layer
//! parallelize with `std::thread::scope`. The helpers here keep that
//! boilerplate (chunking, fallback to inline execution for small work)
//! in one place.

/// Number of worker threads to use: respects COMQ_THREADS, defaults to
/// available parallelism capped at 16.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("COMQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Run `f(chunk_index, item_range)` over `n` items split into contiguous
/// ranges across up to `num_threads()` threads. Runs inline when the work
/// is too small to amortize thread spawn.
pub fn parallel_ranges<F>(n: usize, min_per_thread: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = num_threads().min(n / min_per_thread.max(1)).max(1);
    if threads <= 1 || n == 0 {
        f(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(t, lo..hi));
        }
    });
}

/// Map over mutable disjoint chunks of `data` (each `chunk_len` long) in
/// parallel: `f(chunk_index, chunk_slice)`.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, min_chunks_per_thread: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0 && data.len() % chunk_len == 0, "data must divide into chunks");
    let n_chunks = data.len() / chunk_len;
    let threads = num_threads().min(n_chunks / min_chunks_per_thread.max(1)).max(1);
    if threads <= 1 {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    let per = n_chunks.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, block) in data.chunks_mut(per * chunk_len).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (i, c) in block.chunks_mut(chunk_len).enumerate() {
                    f(t * per + i, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ranges_cover_everything_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_ranges(1000, 10, |_, r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn ranges_small_runs_inline() {
        let hits = AtomicUsize::new(0);
        parallel_ranges(3, 100, |t, r| {
            assert_eq!(t, 0);
            hits.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn chunks_mut_disjoint() {
        let mut v = vec![0usize; 64 * 8];
        parallel_chunks_mut(&mut v, 8, 1, |i, c| {
            for x in c.iter_mut() {
                *x = i;
            }
        });
        for (i, c) in v.chunks(8).enumerate() {
            assert!(c.iter().all(|&x| x == i));
        }
    }

    #[test]
    fn zero_items() {
        parallel_ranges(0, 1, |_, r| assert!(r.is_empty()));
    }
}
