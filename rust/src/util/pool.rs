//! Persistent data-parallel worker pool over std threads.
//!
//! No rayon in the vendored set, so every parallel region in the crate
//! (the COMQ sweeps, matmul, the baseline quantizers) funnels through the
//! two helpers here. Until PR 2 they spawned fresh OS threads per call;
//! at sweep granularity (three calls per quantized layer, plus two
//! matmuls) the ~50–100 µs spawn+join tax was a visible constant factor
//! on small and medium layers. The pool below is spawned lazily on first
//! use and then reused for the life of the process.
//!
//! ## Lifecycle
//!
//! * Workers are spawned on demand, the first time a call needs them,
//!   and never exit; they park on a condvar when the job queue is empty.
//!   The pool holds at most `MAX_WORKERS` threads, ever.
//! * `COMQ_THREADS` is re-read on **every** call (see [`num_threads`]),
//!   so callers (and the thread-scaling bench) can change the effective
//!   parallelism between calls without restarting the process. The pool
//!   never shrinks; a call that wants fewer threads than exist simply
//!   enqueues fewer chunks.
//! * `COMQ_THREADS=1` (or work below `min_per_thread`) runs inline on
//!   the calling thread and never touches — or creates — the pool.
//!
//! ## Execution model
//!
//! A call to [`parallel_ranges`] splits `0..n` into contiguous chunks,
//! enqueues one job per chunk, and then *helps*: the calling thread
//! drains the queue alongside the workers until its own jobs are done.
//! Helping makes correctness independent of pool capacity (with zero
//! spawnable threads the caller just runs everything itself) and makes
//! nested/concurrent calls — e.g. the layer scheduler running several
//! quantizers at once — deadlock-free: no thread ever blocks while
//! runnable work exists in the queue.
//!
//! Closures are handed to workers by reference with the lifetime erased;
//! this is sound because the submitting call cannot return until its
//! completion latch opens, i.e. strictly after the last worker touching
//! the closure finished. A panic inside any chunk is caught on the
//! worker, stored in the latch, and re-thrown on the calling thread once
//! the remaining chunks finish; the worker itself survives and keeps
//! serving jobs.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use crate::obs::{self, Counter, Gauge, Histogram};

/// Hard cap on persistent workers, independent of `COMQ_THREADS`.
const MAX_WORKERS: usize = 64;

/// Number of worker threads to use for the *current* call: respects
/// COMQ_THREADS (re-read every call via [`crate::util::comq_threads`]),
/// defaults to available parallelism capped at 16.
pub fn num_threads() -> usize {
    crate::util::effective_threads()
}

// ---------------------------------------------------------------------------
// Pool internals
// ---------------------------------------------------------------------------

/// Completion latch shared by all jobs of one submission.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn std::any::Any + Send + 'static>>,
}

/// One enqueued chunk. `func` is the submitting call's closure with its
/// lifetime erased; the latch-wait in `parallel_ranges` keeps it alive
/// until every job referencing it has run.
struct Job {
    func: &'static (dyn Fn(usize, Range<usize>) + Sync),
    chunk: usize,
    lo: usize,
    hi: usize,
    latch: Arc<Latch>,
    /// Enqueue timestamp, taken only when telemetry is on — queue wait
    /// is the gap until a participant (worker or helping submitter)
    /// picks the job up.
    enqueued: Option<Instant>,
}

/// Pool-wide telemetry handles, resolved once (the registry lock is too
/// slow for per-job lookups).
struct PoolObs {
    wait: Arc<Histogram>,
    busy: Arc<Histogram>,
    jobs: Arc<Counter>,
    workers: Arc<Gauge>,
}

fn pool_obs() -> &'static PoolObs {
    static OBS: OnceLock<PoolObs> = OnceLock::new();
    OBS.get_or_init(|| PoolObs {
        wait: obs::registry().histogram("comq_pool_task_wait_seconds"),
        busy: obs::registry().histogram("comq_pool_job_seconds"),
        jobs: obs::registry().counter("comq_pool_jobs_total"),
        workers: obs::registry().gauge("comq_pool_workers"),
    })
}

struct PoolState {
    queue: VecDeque<Job>,
    workers: usize,
}

struct Pool {
    state: Mutex<PoolState>,
    cv: Condvar,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        state: Mutex::new(PoolState { queue: VecDeque::new(), workers: 0 }),
        cv: Condvar::new(),
    })
}

/// Persistent workers currently alive (diagnostics / tests). Zero until
/// the first out-of-line parallel call.
pub fn pool_workers() -> usize {
    POOL.get().map(|p| p.state.lock().unwrap().workers).unwrap_or(0)
}

/// Run one job and report its outcome to the job's latch. Panics are
/// caught here so workers survive and the submitter can re-throw.
fn run_job(job: Job) {
    let started = job.enqueued.map(|t| {
        let now = Instant::now();
        pool_obs().wait.record(now.saturating_duration_since(t).as_nanos() as u64);
        now
    });
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        (job.func)(job.chunk, job.lo..job.hi)
    }));
    if let Some(t) = started {
        let o = pool_obs();
        o.busy.record(t.elapsed().as_nanos() as u64);
        o.jobs.inc();
    }
    let mut st = job.latch.state.lock().unwrap();
    if let Err(payload) = result {
        if st.panic.is_none() {
            st.panic = Some(payload);
        }
    }
    st.remaining -= 1;
    if st.remaining == 0 {
        job.latch.cv.notify_all();
    }
}

fn worker_loop(pool: &'static Pool) {
    loop {
        let job = {
            let mut st = pool.state.lock().unwrap();
            loop {
                if let Some(j) = st.queue.pop_front() {
                    break j;
                }
                st = pool.cv.wait(st).unwrap();
            }
        };
        run_job(job);
    }
}

/// Grow the pool to at least `wanted` workers (capped). Spawn failure is
/// tolerated: helping-join keeps submissions correct with any number of
/// workers, including zero.
fn ensure_workers(pool: &'static Pool, wanted: usize) {
    let wanted = wanted.min(MAX_WORKERS);
    let mut st = pool.state.lock().unwrap();
    while st.workers < wanted {
        let id = st.workers;
        let spawned = std::thread::Builder::new()
            .name(format!("comq-pool-{id}"))
            .spawn(move || worker_loop(pool))
            .is_ok();
        if !spawned {
            break;
        }
        st.workers += 1;
    }
    if obs::enabled() {
        pool_obs().workers.set(st.workers as i64);
    }
}

// ---------------------------------------------------------------------------
// Public API (unchanged signatures from the spawn-per-call era)
// ---------------------------------------------------------------------------

/// Run `f(chunk_index, item_range)` over `n` items split into contiguous
/// ranges across up to `num_threads()` participants (pool workers plus
/// the calling thread). Runs inline when the work is too small to
/// amortize handing off, or when `COMQ_THREADS=1`.
pub fn parallel_ranges<F>(n: usize, min_per_thread: usize, f: F)
where
    F: Fn(usize, Range<usize>) + Sync,
{
    let threads = num_threads().min(n / min_per_thread.max(1)).max(1);
    if threads <= 1 || n == 0 {
        f(0, 0..n);
        return;
    }
    let pool = pool();
    ensure_workers(pool, threads - 1);

    // Erase the closure lifetime. Sound: this frame only returns after
    // the latch confirms every job referencing `f` has completed.
    let func: &(dyn Fn(usize, Range<usize>) + Sync) = &f;
    let func: &'static (dyn Fn(usize, Range<usize>) + Sync) =
        unsafe { std::mem::transmute(func) };

    let chunk = n.div_ceil(threads);
    let jobs = n.div_ceil(chunk); // number of non-empty chunks
    let latch = Arc::new(Latch {
        state: Mutex::new(LatchState { remaining: jobs, panic: None }),
        cv: Condvar::new(),
    });
    let enqueued = obs::enabled().then(Instant::now);
    {
        let mut st = pool.state.lock().unwrap();
        for t in 0..jobs {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            st.queue.push_back(Job { func, chunk: t, lo, hi, latch: latch.clone(), enqueued });
        }
    }
    pool.cv.notify_all();

    // Helping join: drain the queue until our latch opens. Our own jobs
    // are at the front unless a concurrent call got there first; running
    // a stranger's job is still progress and prevents deadlock under
    // nested parallelism. We re-check our latch before every pop so a
    // call whose own jobs are already done never starts a (possibly
    // long) stranger chunk it doesn't have to.
    loop {
        {
            let mut st = latch.state.lock().unwrap();
            if st.remaining == 0 {
                if let Some(p) = st.panic.take() {
                    drop(st);
                    std::panic::resume_unwind(p);
                }
                return;
            }
        }
        let job = pool.state.lock().unwrap().queue.pop_front();
        match job {
            Some(j) => run_job(j),
            None => {
                // Queue empty => all our jobs are done or in flight on
                // workers; those workers will notify the latch.
                let mut st = latch.state.lock().unwrap();
                while st.remaining != 0 {
                    st = latch.cv.wait(st).unwrap();
                }
                if let Some(p) = st.panic.take() {
                    drop(st);
                    std::panic::resume_unwind(p);
                }
                return;
            }
        }
    }
}

/// Shared mutable base pointer for disjoint-region writes across pool
/// threads. The one crate-wide copy of this unsafe pattern: every
/// parallel caller (matmul, the sweep engines, `parallel_chunks_mut`)
/// splits a buffer into ranges that each participant owns exclusively,
/// which is what makes the `Send + Sync` promise sound. Keep that
/// contract in mind at every use site.
pub(crate) struct SendPtr<T>(*mut T);

impl<T> SendPtr<T> {
    pub(crate) fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    #[inline]
    pub(crate) fn ptr(&self) -> *mut T {
        self.0
    }
}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> SendPtr<T> {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Map over mutable disjoint chunks of `data` (each `chunk_len` long) in
/// parallel: `f(chunk_index, chunk_slice)`. Built on [`parallel_ranges`],
/// so it shares the persistent pool, helping join and panic behaviour.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, min_chunks_per_thread: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0 && data.len() % chunk_len == 0, "data must divide into chunks");
    let n_chunks = data.len() / chunk_len;
    let base = SendPtr::new(data.as_mut_ptr());
    parallel_ranges(n_chunks, min_chunks_per_thread, |_, range| {
        for i in range {
            // Ranges are disjoint, hence so are the chunk slices.
            let chunk = unsafe { std::slice::from_raw_parts_mut(base.ptr().add(i * chunk_len), chunk_len) };
            f(i, chunk);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ranges_cover_everything_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_ranges(1000, 10, |_, r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn ranges_small_runs_inline() {
        let hits = AtomicUsize::new(0);
        parallel_ranges(3, 100, |t, r| {
            assert_eq!(t, 0);
            hits.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn chunks_mut_disjoint() {
        let mut v = vec![0usize; 64 * 8];
        parallel_chunks_mut(&mut v, 8, 1, |i, c| {
            for x in c.iter_mut() {
                *x = i;
            }
        });
        for (i, c) in v.chunks(8).enumerate() {
            assert!(c.iter().all(|&x| x == i));
        }
    }

    #[test]
    fn zero_items() {
        parallel_ranges(0, 1, |_, r| assert!(r.is_empty()));
    }

    #[test]
    fn pool_is_reused_across_calls() {
        // Warm the pool with one full-demand call, then check that ten
        // more identical-demand calls don't grow it: reuse means worker
        // count is set by per-call demand, not call count.
        parallel_ranges(256, 1, |_, _| {});
        let before = pool_workers();
        for _ in 0..10 {
            let hits: Vec<AtomicUsize> = (0..256).map(|_| AtomicUsize::new(0)).collect();
            parallel_ranges(256, 1, |_, r| {
                for i in r {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
        let after = pool_workers();
        // Concurrent tests can legitimately grow the pool up to the
        // current demand (e.g. the COMQ_THREADS=1 test may have shrunk
        // our warm-up call to inline), hence the max() slack — but call
        // count must never be a growth factor.
        assert!(
            after <= before.max(num_threads().saturating_sub(1)),
            "pool grew with call count: {before} -> {after}"
        );
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let res = std::panic::catch_unwind(|| {
            parallel_ranges(100, 1, |_, r| {
                if r.contains(&57) {
                    panic!("boom in chunk");
                }
            });
        });
        assert!(res.is_err(), "worker panic must reach the caller");
        // the pool keeps working after a propagated panic
        let hits = AtomicUsize::new(0);
        parallel_ranges(100, 1, |_, r| {
            hits.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn comq_threads_one_runs_inline() {
        // ci.sh runs this suite once with COMQ_THREADS=1 pinned —
        // restore whatever pin the caller set rather than deleting it
        let pinned = std::env::var("COMQ_THREADS").ok();
        std::env::set_var("COMQ_THREADS", "1");
        let hits = AtomicUsize::new(0);
        parallel_ranges(1000, 1, |t, r| {
            assert_eq!(t, 0, "inline fallback must use a single chunk");
            assert_eq!(r, 0..1000);
            hits.fetch_add(r.len(), Ordering::Relaxed);
        });
        match pinned {
            Some(v) => std::env::set_var("COMQ_THREADS", v),
            None => std::env::remove_var("COMQ_THREADS"),
        }
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }
}
