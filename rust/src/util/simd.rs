//! Runtime-dispatched SIMD micro-kernels for the two GEMM hot paths:
//! the int8 serving GEMM (`serve/gemm.rs`) and the f32 packed matmul
//! (`tensor/matmul.rs`).
//!
//! ## Dispatch
//!
//! [`Kernel`] names the three implementations; [`Kernel::active`] picks
//! one per call from CPU feature detection (`is_x86_feature_detected!`,
//! cached by std) with a `COMQ_KERNEL=scalar|avx2|vnni` environment
//! override for benching and CI, parsed through `util::env_str` the same
//! way `COMQ_THREADS` flows through `util::comq_threads`. An override that
//! names a kernel the host cannot run falls back to detection with a
//! one-time warning — it never fault-dispatches an illegal instruction.
//!
//! ## The integer contract
//!
//! All three i8 kernels compute the *same* integer quantity — the dot
//! of **uncentered u8 activation codes** against **centered i8 weight
//! codes** — so their i32 accumulators are bit-identical by
//! construction (integer addition is associative; overflow is excluded
//! by the `MAX_K` bound in `serve/gemm.rs`). The operand signedness is
//! forced by the hardware: both `vpmaddubsw` (AVX2) and `vpdpbusd`
//! (AVX-512 VNNI) multiply an unsigned byte by a signed byte, so the
//! activation side carries the codes unsigned and the `2^(ab−1)`
//! centering that PR 3 applied at quantize time moves into the
//! epilogue's exact-integer correction (see `serve/gemm.rs`).
//!
//! Both instructions also want k in groups of 4 adjacent bytes, hence
//! the K4-interleaved panel layout (`serve::gemm::pack_panel_k4`):
//! one group row holds `NR × 4` weight bytes — 64 bytes, exactly one
//! cache line and one zmm load. The scalar kernel walks the same layout
//! so a panel packed once serves any later `COMQ_KERNEL` choice.
//!
//! The grouped (depthwise) kernel [`dot_i8_grouped`] is the per-lane
//! sibling of the dense [`dot_i8`]: every output column owns its own
//! k extent, so the activation side is packed into the *same*
//! K4-interleaved layout and loaded per lane instead of broadcast —
//! otherwise the contract (and the W8A8 split path) is identical.
//!
//! ### Exactness of the AVX2 path
//!
//! `vpmaddubsw` adds two adjacent u8×i8 products into an i16 **with
//! saturation**; the pair sum only fits when
//! `2 · (2^ab − 1) · 2^(b−1) ≤ 32767` (see [`maddubs_safe`]). That
//! holds for every bit pairing except W8A8. For that one case the
//! kernel takes a split path: the broadcast activation quad is masked
//! to even and odd k bytes separately, so each `vpmaddubsw` pair has a
//! zero term and the "pair sum" is a single product (|·| ≤ 32640 <
//! 32768) — two maddubs instead of one, still exact.
//!
//! ## The f32 kernel
//!
//! The AVX2/FMA f32 micro-kernel fuses the multiply-add (one rounding
//! instead of two), so its results differ from the scalar kernel's in
//! the last ulp — that is expected and allowed; the crate's f32
//! bit-identity contracts (workspace-vs-gram, transpose-commute) are
//! all *same-process, same-kernel* comparisons and hold for any single
//! dispatched kernel. Integer accumulators, by contrast, are
//! bit-identical across kernels and tested as such
//! (`rust/tests/kernel_parity.rs`).

use std::sync::OnceLock;

use crate::tensor::{MR, NR};

// The x86 kernels hard-code the tile: 4 rows × 16 columns (16 i32 = one
// zmm; 16 f32 = two ymm).
const _: () = assert!(MR == 4 && NR == 16, "SIMD kernels assume a 4x16 tile");

/// k-group width of the interleaved i8 panel layout (the quad both
/// `vpmaddubsw` and `vpdpbusd` consume per lane).
pub const K4: usize = 4;

/// One dot-product kernel implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Portable reference implementation — always available, and the
    /// ground truth the SIMD kernels are tested bit-exact against.
    Scalar,
    /// AVX2: `vpmaddubsw`+`vpmaddwd` for i8, FMA for f32.
    Avx2,
    /// AVX-512 VNNI: `vpdpbusd` for i8 (f32 shares the AVX2/FMA path).
    Vnni,
}

impl Kernel {
    pub const ALL: [Kernel; 3] = [Kernel::Scalar, Kernel::Avx2, Kernel::Vnni];

    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Vnni => "vnni",
        }
    }

    /// Parse a `COMQ_KERNEL` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Kernel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Kernel::Scalar),
            "avx2" => Some(Kernel::Avx2),
            "vnni" => Some(Kernel::Vnni),
            _ => None,
        }
    }

    /// Can this kernel run on the current host *and* toolchain? (The
    /// VNNI kernel additionally needs a rustc with stable AVX-512
    /// intrinsics — see `build.rs` and the `comq_avx512` cfg.)
    pub fn supported(self) -> bool {
        match self {
            Kernel::Scalar => true,
            Kernel::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                        && std::arch::is_x86_feature_detected!("fma")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Kernel::Vnni => {
                #[cfg(all(target_arch = "x86_64", comq_avx512))]
                {
                    std::arch::is_x86_feature_detected!("avx512f")
                        && std::arch::is_x86_feature_detected!("avx512vnni")
                        && Kernel::Avx2.supported()
                }
                #[cfg(not(all(target_arch = "x86_64", comq_avx512)))]
                {
                    false
                }
            }
        }
    }

    /// Best supported kernel for this host (VNNI > AVX2 > scalar),
    /// computed once per process.
    pub fn detect() -> Kernel {
        static DETECTED: OnceLock<Kernel> = OnceLock::new();
        *DETECTED.get_or_init(|| {
            if Kernel::Vnni.supported() {
                Kernel::Vnni
            } else if Kernel::Avx2.supported() {
                Kernel::Avx2
            } else {
                Kernel::Scalar
            }
        })
    }

    /// Kernel for the *current* call: `COMQ_KERNEL` if set (re-read
    /// every call, like `COMQ_THREADS`, so benches can flip it between
    /// runs), otherwise [`Kernel::detect`]. An unknown or unsupported
    /// override falls back to detection with a one-time warning.
    pub fn active() -> Kernel {
        match crate::util::env_str("COMQ_KERNEL") {
            None => Kernel::detect(),
            Some(s) => match Kernel::parse(&s) {
                Some(k) if k.supported() => k,
                _ => {
                    crate::warn_once!(
                        "COMQ_KERNEL={s}: unknown or unsupported on this host, using {}",
                        Kernel::detect().name()
                    );
                    Kernel::detect()
                }
            },
        }
    }
}

/// Whether the single-`vpmaddubsw` AVX2 path is exact for this bit
/// pairing: the worst-case adjacent pair sum `2·(2^ab − 1)·2^(b−1)`
/// must fit i16. False only for W8A8, which takes the split path.
pub fn maddubs_safe(act_bits: u32, w_bits: u32) -> bool {
    let amax = (1i64 << act_bits) - 1;
    let wmag = 1i64 << (w_bits.max(1) - 1);
    2 * amax * wmag <= i16::MAX as i64
}

// ---------------------------------------------------------------------------
// i8 × u8 → i32 micro-kernel
// ---------------------------------------------------------------------------

/// Exact integer tile product over one K4-interleaved panel strip:
///
/// ```text
/// acc[r][l] = Σ_{g < kg, t < 4} acts[r·stride + 4g + t] · strip[(g·NR + l)·4 + t]
/// ```
///
/// `acts` starts at the tile's first row; rows are `stride` bytes apart
/// (`stride ≥ 4·kg`, zero-padded past the true k extent — the matching
/// panel k-padding is also zero, so padded products vanish). Rows
/// `0..rows` of `acc` are overwritten; rows past `rows` are untouched.
/// Every kernel returns bit-identical accumulators; `wide` selects the
/// W8A8-exact AVX2 split path (see [`maddubs_safe`] — ignored by the
/// other kernels).
#[allow(clippy::too_many_arguments)]
// `wide` only steers the AVX2 path, so it is unread on non-x86 targets
#[cfg_attr(not(target_arch = "x86_64"), allow(unused_variables))]
pub fn dot_i8(
    kern: Kernel,
    acts: &[u8],
    stride: usize,
    rows: usize,
    strip: &[i8],
    kg: usize,
    wide: bool,
    acc: &mut [[i32; NR]; MR],
) {
    assert!(rows >= 1 && rows <= MR, "rows {rows} outside 1..={MR}");
    assert!(stride >= kg * K4, "stride {stride} < {} (k-groups {kg})", kg * K4);
    assert!(acts.len() >= (rows - 1) * stride + kg * K4, "acts too short");
    assert!(strip.len() >= kg * NR * K4, "strip too short for {kg} k-groups");
    match kern {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 if Kernel::Avx2.supported() => unsafe {
            x86::dot_i8_avx2(acts.as_ptr(), stride, rows, strip.as_ptr(), kg, wide, acc)
        },
        #[cfg(all(target_arch = "x86_64", comq_avx512))]
        Kernel::Vnni if Kernel::Vnni.supported() => unsafe {
            x86::dot_i8_vnni(acts.as_ptr(), stride, rows, strip.as_ptr(), kg, acc)
        },
        // Scalar, plus the defensive fallback for a force-dispatched
        // kernel the host can't run.
        _ => dot_i8_scalar(acts, stride, rows, strip, kg, acc),
    }
}

fn dot_i8_scalar(
    acts: &[u8],
    stride: usize,
    rows: usize,
    strip: &[i8],
    kg: usize,
    acc: &mut [[i32; NR]; MR],
) {
    for (r, accr) in acc.iter_mut().take(rows).enumerate() {
        let mut tile = [0i32; NR];
        for g in 0..kg {
            let a4 = &acts[r * stride + g * K4..r * stride + g * K4 + K4];
            let wrow = &strip[g * NR * K4..(g + 1) * NR * K4];
            for (t, w4) in tile.iter_mut().zip(wrow.chunks_exact(K4)) {
                *t += a4[0] as i32 * w4[0] as i32
                    + a4[1] as i32 * w4[1] as i32
                    + a4[2] as i32 * w4[2] as i32
                    + a4[3] as i32 * w4[3] as i32;
            }
        }
        *accr = tile;
    }
}

// ---------------------------------------------------------------------------
// grouped (depthwise) u8 × i8 → i32 micro-kernel
// ---------------------------------------------------------------------------

/// Exact integer tile product for grouped (depthwise) layers: every
/// output column `l` owns its *own* activation quad per k-group, so both
/// operands carry the **same** K4-interleaved strip layout and the tile
/// is a per-lane dot:
///
/// ```text
/// acc[r][l] = Σ_{g < kg, t < 4}
///     acts[r·stride + (g·NR + l)·4 + t] · strip[(g·NR + l)·4 + t]
/// ```
///
/// The dense kernel broadcasts one activation quad across all NR lanes;
/// here the quad is *loaded* per lane instead — the only difference, so
/// `vpdpbusd`/`vpmaddubsw` apply unchanged and the same `wide` split
/// path keeps W8A8 exact (the adjacent pair is still two k-neighbours
/// of one group, so [`maddubs_safe`] bounds it identically). `acts`
/// starts at the tile's first row's strip; rows are `stride` bytes
/// apart (`stride ≥ kg·NR·4`). Padded k positions and padded lanes are
/// zero in **both** operands, so their products vanish from every
/// kernel identically. Rows `0..rows` of `acc` are overwritten; all
/// kernels return bit-identical accumulators (exact integer sums,
/// overflow excluded by the serving-side `MAX_K` bound — kk is a
/// convolution patch, a few dozen at most).
#[allow(clippy::too_many_arguments)]
// `wide` only steers the AVX2 path, so it is unread on non-x86 targets
#[cfg_attr(not(target_arch = "x86_64"), allow(unused_variables))]
pub fn dot_i8_grouped(
    kern: Kernel,
    acts: &[u8],
    stride: usize,
    rows: usize,
    strip: &[i8],
    kg: usize,
    wide: bool,
    acc: &mut [[i32; NR]; MR],
) {
    let strip_len = kg * NR * K4;
    assert!(rows >= 1 && rows <= MR, "rows {rows} outside 1..={MR}");
    assert!(stride >= strip_len, "stride {stride} < strip {strip_len}");
    assert!(acts.len() >= (rows - 1) * stride + strip_len, "acts too short");
    assert!(strip.len() >= strip_len, "strip too short for {kg} k-groups");
    match kern {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 if Kernel::Avx2.supported() => unsafe {
            x86::dot_i8_grouped_avx2(acts.as_ptr(), stride, rows, strip.as_ptr(), kg, wide, acc)
        },
        #[cfg(all(target_arch = "x86_64", comq_avx512))]
        Kernel::Vnni if Kernel::Vnni.supported() => unsafe {
            x86::dot_i8_grouped_vnni(acts.as_ptr(), stride, rows, strip.as_ptr(), kg, acc)
        },
        // Scalar, plus the defensive fallback for a force-dispatched
        // kernel the host can't run.
        _ => dot_i8_grouped_scalar(acts, stride, rows, strip, kg, acc),
    }
}

fn dot_i8_grouped_scalar(
    acts: &[u8],
    stride: usize,
    rows: usize,
    strip: &[i8],
    kg: usize,
    acc: &mut [[i32; NR]; MR],
) {
    for (r, accr) in acc.iter_mut().take(rows).enumerate() {
        let mut tile = [0i32; NR];
        for g in 0..kg {
            let arow = &acts[r * stride + g * NR * K4..r * stride + (g + 1) * NR * K4];
            let wrow = &strip[g * NR * K4..(g + 1) * NR * K4];
            let quads = arow.chunks_exact(K4).zip(wrow.chunks_exact(K4));
            for (t, (a4, w4)) in tile.iter_mut().zip(quads) {
                *t += a4[0] as i32 * w4[0] as i32
                    + a4[1] as i32 * w4[1] as i32
                    + a4[2] as i32 * w4[2] as i32
                    + a4[3] as i32 * w4[3] as i32;
            }
        }
        *accr = tile;
    }
}

// ---------------------------------------------------------------------------
// f32 micro-kernel
// ---------------------------------------------------------------------------

/// f32 tile product over one NR-wide packed B strip (`tensor::pack_b`
/// layout, k-contiguous):
///
/// ```text
/// acc[r][l] = Σ_{kk < k} a[r·stride + kk] · strip[kk·NR + l]
/// ```
///
/// Rows `0..rows` of `acc` are overwritten. The AVX2 path uses FMA, so
/// it differs from scalar in the final ulp (see module docs); it is
/// deterministic for a fixed kernel choice. `Vnni` shares the AVX2/FMA
/// path — there is no separate f32 AVX-512 kernel.
pub fn dot_f32(
    kern: Kernel,
    a: &[f32],
    stride: usize,
    rows: usize,
    strip: &[f32],
    k: usize,
    acc: &mut [[f32; NR]; MR],
) {
    assert!(rows >= 1 && rows <= MR, "rows {rows} outside 1..={MR}");
    assert!(stride >= k, "stride {stride} < k {k}");
    assert!(a.len() >= (rows - 1) * stride + k, "a too short");
    assert!(strip.len() >= k * NR, "strip too short for k {k}");
    match kern {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 | Kernel::Vnni if Kernel::Avx2.supported() => unsafe {
            x86::dot_f32_avx2(a.as_ptr(), stride, rows, strip.as_ptr(), k, acc)
        },
        _ => dot_f32_scalar(a, stride, rows, strip, k, acc),
    }
}

fn dot_f32_scalar(
    a: &[f32],
    stride: usize,
    rows: usize,
    strip: &[f32],
    k: usize,
    acc: &mut [[f32; NR]; MR],
) {
    for (r, accr) in acc.iter_mut().take(rows).enumerate() {
        let mut tile = [0.0f32; NR];
        for kk in 0..k {
            let av = a[r * stride + kk];
            let brow = &strip[kk * NR..kk * NR + NR];
            for (t, &b) in tile.iter_mut().zip(brow) {
                *t += av * b;
            }
        }
        *accr = tile;
    }
}

// ---------------------------------------------------------------------------
// x86-64 intrinsics
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{K4, MR, NR};
    use std::arch::x86_64::*;

    /// Caller guarantees: avx2 detected; pointer extents as validated
    /// by [`super::dot_i8`]. Dispatches on `rows` to a const-generic
    /// body so the accumulators stay in registers.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_i8_avx2(
        acts: *const u8,
        stride: usize,
        rows: usize,
        strip: *const i8,
        kg: usize,
        wide: bool,
        acc: &mut [[i32; NR]; MR],
    ) {
        match rows {
            4 => dot_i8_avx2_r::<4>(acts, stride, strip, kg, wide, acc),
            3 => dot_i8_avx2_r::<3>(acts, stride, strip, kg, wide, acc),
            2 => dot_i8_avx2_r::<2>(acts, stride, strip, kg, wide, acc),
            _ => dot_i8_avx2_r::<1>(acts, stride, strip, kg, wide, acc),
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot_i8_avx2_r<const R: usize>(
        acts: *const u8,
        stride: usize,
        strip: *const i8,
        kg: usize,
        wide: bool,
        acc: &mut [[i32; NR]; MR],
    ) {
        let ones = _mm256_set1_epi16(1);
        let mut accv = [[_mm256_setzero_si256(); 2]; R];
        for g in 0..kg {
            // one K4 group row: NR columns × 4 k-bytes = 64 bytes
            let w0 = _mm256_loadu_si256(strip.add(g * NR * K4) as *const __m256i);
            let w1 = _mm256_loadu_si256(strip.add(g * NR * K4 + 32) as *const __m256i);
            for r in 0..R {
                let quad = (acts.add(r * stride + g * K4) as *const u32).read_unaligned();
                if !wide {
                    let av = _mm256_set1_epi32(quad as i32);
                    let p0 = _mm256_madd_epi16(_mm256_maddubs_epi16(av, w0), ones);
                    let p1 = _mm256_madd_epi16(_mm256_maddubs_epi16(av, w1), ones);
                    accv[r][0] = _mm256_add_epi32(accv[r][0], p0);
                    accv[r][1] = _mm256_add_epi32(accv[r][1], p1);
                } else {
                    // W8A8: mask even/odd k bytes so each maddubs pair
                    // has a zero term and cannot saturate i16
                    let lo = _mm256_set1_epi32((quad & 0x00FF_00FF) as i32);
                    let hi = _mm256_set1_epi32((quad & 0xFF00_FF00) as i32);
                    let p0 = _mm256_add_epi32(
                        _mm256_madd_epi16(_mm256_maddubs_epi16(lo, w0), ones),
                        _mm256_madd_epi16(_mm256_maddubs_epi16(hi, w0), ones),
                    );
                    let p1 = _mm256_add_epi32(
                        _mm256_madd_epi16(_mm256_maddubs_epi16(lo, w1), ones),
                        _mm256_madd_epi16(_mm256_maddubs_epi16(hi, w1), ones),
                    );
                    accv[r][0] = _mm256_add_epi32(accv[r][0], p0);
                    accv[r][1] = _mm256_add_epi32(accv[r][1], p1);
                }
            }
        }
        for (r, v) in accv.iter().enumerate() {
            _mm256_storeu_si256(acc[r].as_mut_ptr() as *mut __m256i, v[0]);
            _mm256_storeu_si256(acc[r].as_mut_ptr().add(8) as *mut __m256i, v[1]);
        }
    }

    /// Grouped variant of [`dot_i8_avx2`]: the activation quads are
    /// loaded per lane (same K4 strip layout as the weights) instead of
    /// broadcast. The `wide` split masks even/odd k bytes of the
    /// *loaded* activation vector, so each `vpmaddubsw` pair keeps a
    /// zero term — the same W8A8 exactness argument as the dense path.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_i8_grouped_avx2(
        acts: *const u8,
        stride: usize,
        rows: usize,
        strip: *const i8,
        kg: usize,
        wide: bool,
        acc: &mut [[i32; NR]; MR],
    ) {
        match rows {
            4 => dot_i8_grouped_avx2_r::<4>(acts, stride, strip, kg, wide, acc),
            3 => dot_i8_grouped_avx2_r::<3>(acts, stride, strip, kg, wide, acc),
            2 => dot_i8_grouped_avx2_r::<2>(acts, stride, strip, kg, wide, acc),
            _ => dot_i8_grouped_avx2_r::<1>(acts, stride, strip, kg, wide, acc),
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot_i8_grouped_avx2_r<const R: usize>(
        acts: *const u8,
        stride: usize,
        strip: *const i8,
        kg: usize,
        wide: bool,
        acc: &mut [[i32; NR]; MR],
    ) {
        let ones = _mm256_set1_epi16(1);
        let even = _mm256_set1_epi16(0x00FF);
        let mut accv = [[_mm256_setzero_si256(); 2]; R];
        for g in 0..kg {
            // one K4 group row of each operand: NR lanes × 4 bytes
            let w0 = _mm256_loadu_si256(strip.add(g * NR * K4) as *const __m256i);
            let w1 = _mm256_loadu_si256(strip.add(g * NR * K4 + 32) as *const __m256i);
            for r in 0..R {
                let abase = acts.add(r * stride + g * NR * K4);
                let a0 = _mm256_loadu_si256(abase as *const __m256i);
                let a1 = _mm256_loadu_si256(abase.add(32) as *const __m256i);
                if !wide {
                    let p0 = _mm256_madd_epi16(_mm256_maddubs_epi16(a0, w0), ones);
                    let p1 = _mm256_madd_epi16(_mm256_maddubs_epi16(a1, w1), ones);
                    accv[r][0] = _mm256_add_epi32(accv[r][0], p0);
                    accv[r][1] = _mm256_add_epi32(accv[r][1], p1);
                } else {
                    // W8A8: zero the odd (resp. even) activation bytes so
                    // each maddubs pair has a zero term and cannot
                    // saturate i16
                    let p0 = _mm256_add_epi32(
                        _mm256_madd_epi16(
                            _mm256_maddubs_epi16(_mm256_and_si256(a0, even), w0),
                            ones,
                        ),
                        _mm256_madd_epi16(
                            _mm256_maddubs_epi16(_mm256_andnot_si256(even, a0), w0),
                            ones,
                        ),
                    );
                    let p1 = _mm256_add_epi32(
                        _mm256_madd_epi16(
                            _mm256_maddubs_epi16(_mm256_and_si256(a1, even), w1),
                            ones,
                        ),
                        _mm256_madd_epi16(
                            _mm256_maddubs_epi16(_mm256_andnot_si256(even, a1), w1),
                            ones,
                        ),
                    );
                    accv[r][0] = _mm256_add_epi32(accv[r][0], p0);
                    accv[r][1] = _mm256_add_epi32(accv[r][1], p1);
                }
            }
        }
        for (r, v) in accv.iter().enumerate() {
            _mm256_storeu_si256(acc[r].as_mut_ptr() as *mut __m256i, v[0]);
            _mm256_storeu_si256(acc[r].as_mut_ptr().add(8) as *mut __m256i, v[1]);
        }
    }

    /// Grouped variant of [`dot_i8_vnni`]: one zmm of per-lane
    /// activation quads against one zmm of weight quads — `vpdpbusd`
    /// needs no broadcast and no split path at any width.
    #[cfg(comq_avx512)]
    #[target_feature(enable = "avx512f", enable = "avx512vnni")]
    pub(super) unsafe fn dot_i8_grouped_vnni(
        acts: *const u8,
        stride: usize,
        rows: usize,
        strip: *const i8,
        kg: usize,
        acc: &mut [[i32; NR]; MR],
    ) {
        match rows {
            4 => dot_i8_grouped_vnni_r::<4>(acts, stride, strip, kg, acc),
            3 => dot_i8_grouped_vnni_r::<3>(acts, stride, strip, kg, acc),
            2 => dot_i8_grouped_vnni_r::<2>(acts, stride, strip, kg, acc),
            _ => dot_i8_grouped_vnni_r::<1>(acts, stride, strip, kg, acc),
        }
    }

    #[cfg(comq_avx512)]
    #[target_feature(enable = "avx512f", enable = "avx512vnni")]
    unsafe fn dot_i8_grouped_vnni_r<const R: usize>(
        acts: *const u8,
        stride: usize,
        strip: *const i8,
        kg: usize,
        acc: &mut [[i32; NR]; MR],
    ) {
        let mut accv = [_mm512_setzero_si512(); R];
        for g in 0..kg {
            let w = (strip.add(g * NR * K4) as *const __m512i).read_unaligned();
            for (r, v) in accv.iter_mut().enumerate() {
                let a = (acts.add(r * stride + g * NR * K4) as *const __m512i).read_unaligned();
                *v = _mm512_dpbusd_epi32(*v, a, w);
            }
        }
        for (r, v) in accv.iter().enumerate() {
            (acc[r].as_mut_ptr() as *mut __m512i).write_unaligned(*v);
        }
    }

    /// `vpdpbusd`: u8×i8 quads into i32 lanes, exact (the intermediate
    /// i16 products are exact and the quad sum is added without
    /// saturation; accumulator overflow is excluded by `MAX_K`).
    #[cfg(comq_avx512)]
    #[target_feature(enable = "avx512f", enable = "avx512vnni")]
    pub(super) unsafe fn dot_i8_vnni(
        acts: *const u8,
        stride: usize,
        rows: usize,
        strip: *const i8,
        kg: usize,
        acc: &mut [[i32; NR]; MR],
    ) {
        match rows {
            4 => dot_i8_vnni_r::<4>(acts, stride, strip, kg, acc),
            3 => dot_i8_vnni_r::<3>(acts, stride, strip, kg, acc),
            2 => dot_i8_vnni_r::<2>(acts, stride, strip, kg, acc),
            _ => dot_i8_vnni_r::<1>(acts, stride, strip, kg, acc),
        }
    }

    #[cfg(comq_avx512)]
    #[target_feature(enable = "avx512f", enable = "avx512vnni")]
    unsafe fn dot_i8_vnni_r<const R: usize>(
        acts: *const u8,
        stride: usize,
        strip: *const i8,
        kg: usize,
        acc: &mut [[i32; NR]; MR],
    ) {
        let mut accv = [_mm512_setzero_si512(); R];
        for g in 0..kg {
            // one group row is exactly one zmm: 16 i32 lanes of 4 bytes
            let w = (strip.add(g * NR * K4) as *const __m512i).read_unaligned();
            for (r, v) in accv.iter_mut().enumerate() {
                let quad = (acts.add(r * stride + g * K4) as *const u32).read_unaligned();
                let av = _mm512_set1_epi32(quad as i32);
                *v = _mm512_dpbusd_epi32(*v, av, w);
            }
        }
        for (r, v) in accv.iter().enumerate() {
            (acc[r].as_mut_ptr() as *mut __m512i).write_unaligned(*v);
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn dot_f32_avx2(
        a: *const f32,
        stride: usize,
        rows: usize,
        strip: *const f32,
        k: usize,
        acc: &mut [[f32; NR]; MR],
    ) {
        match rows {
            4 => dot_f32_avx2_r::<4>(a, stride, strip, k, acc),
            3 => dot_f32_avx2_r::<3>(a, stride, strip, k, acc),
            2 => dot_f32_avx2_r::<2>(a, stride, strip, k, acc),
            _ => dot_f32_avx2_r::<1>(a, stride, strip, k, acc),
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot_f32_avx2_r<const R: usize>(
        a: *const f32,
        stride: usize,
        strip: *const f32,
        k: usize,
        acc: &mut [[f32; NR]; MR],
    ) {
        let mut accv = [[_mm256_setzero_ps(); 2]; R];
        for kk in 0..k {
            let b0 = _mm256_loadu_ps(strip.add(kk * NR));
            let b1 = _mm256_loadu_ps(strip.add(kk * NR + 8));
            for (r, v) in accv.iter_mut().enumerate() {
                let av = _mm256_set1_ps(*a.add(r * stride + kk));
                v[0] = _mm256_fmadd_ps(av, b0, v[0]);
                v[1] = _mm256_fmadd_ps(av, b1, v[1]);
            }
        }
        for (r, v) in accv.iter().enumerate() {
            _mm256_storeu_ps(acc[r].as_mut_ptr(), v[0]);
            _mm256_storeu_ps(acc[r].as_mut_ptr().add(8), v[1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Naive i64 reference for the K4 tile contract.
    fn naive_tile(
        acts: &[u8],
        stride: usize,
        rows: usize,
        strip: &[i8],
        kg: usize,
    ) -> Vec<Vec<i64>> {
        (0..rows)
            .map(|r| {
                (0..NR)
                    .map(|l| {
                        (0..kg * K4)
                            .map(|kk| {
                                let (g, t) = (kk / K4, kk % K4);
                                acts[r * stride + kk] as i64
                                    * strip[(g * NR + l) * K4 + t] as i64
                            })
                            .sum()
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn scalar_dot_i8_matches_naive() {
        let mut rng = Rng::new(31);
        for &(rows, kg) in &[(1usize, 1usize), (2, 3), (4, 7), (3, 16)] {
            let stride = kg * K4 + 4; // deliberately over-wide stride
            let acts: Vec<u8> = (0..rows * stride).map(|_| rng.below(256) as u8).collect();
            let strip: Vec<i8> =
                (0..kg * NR * K4).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
            let mut acc = [[0i32; NR]; MR];
            dot_i8(Kernel::Scalar, &acts, stride, rows, &strip, kg, false, &mut acc);
            let want = naive_tile(&acts, stride, rows, &strip, kg);
            for r in 0..rows {
                for l in 0..NR {
                    assert_eq!(acc[r][l] as i64, want[r][l], "({rows},{kg}) r={r} l={l}");
                }
            }
        }
    }

    /// Naive i64 reference for the grouped (per-lane) tile contract.
    fn naive_grouped_tile(
        acts: &[u8],
        stride: usize,
        rows: usize,
        strip: &[i8],
        kg: usize,
    ) -> Vec<Vec<i64>> {
        (0..rows)
            .map(|r| {
                (0..NR)
                    .map(|l| {
                        (0..kg * K4)
                            .map(|kk| {
                                let (g, t) = (kk / K4, kk % K4);
                                acts[r * stride + (g * NR + l) * K4 + t] as i64
                                    * strip[(g * NR + l) * K4 + t] as i64
                            })
                            .sum()
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn scalar_dot_i8_grouped_matches_naive() {
        let mut rng = Rng::new(34);
        for &(rows, kg) in &[(1usize, 1usize), (2, 3), (4, 7), (3, 16)] {
            let stride = kg * NR * K4 + 64; // deliberately over-wide stride
            let acts: Vec<u8> = (0..rows * stride).map(|_| rng.below(256) as u8).collect();
            let strip: Vec<i8> =
                (0..kg * NR * K4).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
            let mut acc = [[0i32; NR]; MR];
            dot_i8_grouped(Kernel::Scalar, &acts, stride, rows, &strip, kg, false, &mut acc);
            let want = naive_grouped_tile(&acts, stride, rows, &strip, kg);
            for r in 0..rows {
                for l in 0..NR {
                    assert_eq!(acc[r][l] as i64, want[r][l], "({rows},{kg}) r={r} l={l}");
                }
            }
        }
    }

    #[test]
    fn grouped_detection_smoke() {
        // every supported SIMD kernel agrees with scalar on a full-range
        // W8A8 tile through the wide path; the narrow path is covered
        // across all bit pairings in rust/tests/kernel_parity.rs
        let mut rng = Rng::new(35);
        let kg = 3;
        let stride = kg * NR * K4;
        let acts: Vec<u8> = (0..MR * stride).map(|_| rng.below(256) as u8).collect();
        let strip: Vec<i8> =
            (0..kg * NR * K4).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
        let mut want = [[0i32; NR]; MR];
        dot_i8_grouped(Kernel::Scalar, &acts, stride, MR, &strip, kg, true, &mut want);
        for k in [Kernel::Avx2, Kernel::Vnni] {
            if !k.supported() {
                continue;
            }
            let mut acc = [[0i32; NR]; MR];
            dot_i8_grouped(k, &acts, stride, MR, &strip, kg, true, &mut acc);
            assert_eq!(acc, want, "{}", k.name());
        }
    }

    #[test]
    fn scalar_dot_f32_matches_naive() {
        let mut rng = Rng::new(32);
        let (rows, k, stride) = (3usize, 11usize, 11usize);
        let a = rng.normal_vec(rows * stride);
        let strip = rng.normal_vec(k * NR);
        let mut acc = [[0.0f32; NR]; MR];
        dot_f32(Kernel::Scalar, &a, stride, rows, &strip, k, &mut acc);
        for r in 0..rows {
            for l in 0..NR {
                let want: f64 = (0..k)
                    .map(|kk| a[r * stride + kk] as f64 * strip[kk * NR + l] as f64)
                    .sum();
                assert!((acc[r][l] as f64 - want).abs() < 1e-3, "r={r} l={l}");
            }
        }
    }

    #[test]
    fn maddubs_safety_rule() {
        // only W8A8 needs the split path
        for ab in 1..=8u32 {
            for wb in 1..=8u32 {
                assert_eq!(maddubs_safe(ab, wb), !(ab == 8 && wb == 8), "A{ab} W{wb}");
            }
        }
    }

    #[test]
    fn parse_and_names_round_trip() {
        for k in Kernel::ALL {
            assert_eq!(Kernel::parse(k.name()), Some(k));
            assert_eq!(Kernel::parse(&k.name().to_ascii_uppercase()), Some(k));
        }
        assert_eq!(Kernel::parse("neon"), None);
    }

    #[test]
    fn detection_is_coherent() {
        assert!(Kernel::Scalar.supported());
        let best = Kernel::detect();
        assert!(best.supported());
        // detect() must prefer SIMD whenever any SIMD kernel works
        if Kernel::Avx2.supported() {
            assert_ne!(best, Kernel::Scalar);
        }
        // every supported SIMD kernel agrees with scalar on a smoke tile
        let mut rng = Rng::new(33);
        let kg = 5;
        let acts: Vec<u8> = (0..MR * kg * K4).map(|_| rng.below(256) as u8).collect();
        let strip: Vec<i8> =
            (0..kg * NR * K4).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
        let mut want = [[0i32; NR]; MR];
        dot_i8(Kernel::Scalar, &acts, kg * K4, MR, &strip, kg, true, &mut want);
        // these inputs are full-range W8A8, so only wide=true is exact
        // on AVX2; the narrow fast path is covered bit-by-bit across
        // all bit pairings in rust/tests/kernel_parity.rs
        for k in [Kernel::Avx2, Kernel::Vnni] {
            if !k.supported() {
                continue;
            }
            let mut acc = [[0i32; NR]; MR];
            dot_i8(k, &acts, kg * K4, MR, &strip, kg, true, &mut acc);
            assert_eq!(acc, want, "{}", k.name());
        }
    }
}
