//! Wall-clock timing helper.

use std::time::Instant;

/// Simple scope timer: `let t = Timer::start(); ...; t.secs()`.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    pub fn micros(&self) -> f64 {
        self.secs() * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.secs() >= 0.002);
        assert!(t.millis() >= 2.0);
    }
}
