//! COMQ: backpropagation-free post-training quantization.
//!
//! A three-layer reproduction of *COMQ: A Backpropagation-Free Algorithm
//! for Post-Training Quantization* (Zhang et al., 2024):
//!
//! * **L3 (this crate)** — the PTQ pipeline coordinator: checkpoint store,
//!   calibration manager, layer-job scheduler, quantizer registry (COMQ +
//!   baselines), PJRT runtime, evaluation harness, integer serving
//!   runtime (`serve`), CLI.
//! * **L2 (python/compile, build-time)** — JAX model zoo + AOT-lowered
//!   forward / calibration-statistics graphs.
//! * **L1 (python/compile/kernels, build-time)** — the COMQ coordinate-
//!   descent sweep as a Pallas kernel, lowered into the same HLO
//!   artifacts this crate executes via PJRT.
//!
//! See DESIGN.md for the system inventory and the per-experiment index.

pub mod bench;
pub mod calib;
pub mod config;
pub mod coordinator;
pub mod deploy;
pub mod eval;
pub mod manifest;
pub mod model;
pub mod obs;
pub mod proptest;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod tensorstore;
pub mod util;
