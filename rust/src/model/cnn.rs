//! Native CNN forward — operation-for-operation mirror of
//! python/compile/nets/cnn.py (resnet_lite, cnn_s, mobilenet_lite),
//! expressed as a stage plan (see [`super::Stage`]). `cnn_forward` is
//! the sequential fold of the plan; the pipelined serving executor runs
//! the same plan stage-by-stage across batches.

use std::collections::BTreeMap;

use crate::manifest::CnnConfig;
use crate::tensor::ops::{avg_pool2, global_avg_pool, relu_inplace, stride_slice};
use crate::tensor::Tensor;

use super::{conv2d, dwconv2d, linear, Stage, Tap};

/// x [b, img, img, 3] -> logits [b, classes].
pub fn cnn_forward(
    cfg: &CnnConfig,
    params: &BTreeMap<String, Tensor>,
    x: &Tensor,
    tap: &mut Tap,
) -> Tensor {
    let mut h = x.clone();
    for stage in cnn_stages(cfg) {
        h = stage.run(params, h, tap);
    }
    h
}

/// The CNN forward cut at its natural boundaries: stem, one stage per
/// (residual / conv / depthwise-separable) block, head. Stage order and
/// the ops inside each stage are exactly the pre-refactor statement
/// order, so the fold is operation-for-operation identical.
pub fn cnn_stages(cfg: &CnnConfig) -> Vec<Stage> {
    match cfg.kind.as_str() {
        "resnet" => resnet_stages(cfg),
        "plain" => plain_stages(),
        "mobile" => mobile_stages(cfg),
        k => panic!("unknown cnn kind '{k}'"),
    }
}

fn resnet_stages(cfg: &CnnConfig) -> Vec<Stage> {
    let mut stages = vec![Stage::new("stem", |params, x, tap| {
        let mut h = conv2d(params, "stem", &x, 3, 1, 1, tap);
        relu_inplace(&mut h);
        h
    })];
    let mut cin = cfg.width;
    for s in 0..3 {
        let cout = cfg.width * (1 << s);
        for b in 0..cfg.blocks {
            let nm = format!("s{s}/b{b}");
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let block_cin = cin;
            stages.push(Stage::new(nm.clone(), move |params, h, tap| {
                let mut y = conv2d(params, &format!("{nm}/conv1"), &h, 3, stride, 1, tap);
                relu_inplace(&mut y);
                let y2 = conv2d(params, &format!("{nm}/conv2"), &y, 3, 1, 1, tap);
                let sk = if block_cin != cout {
                    // 1x1 projection shortcut on the strided input
                    let skx = if stride > 1 { stride_slice(&h, stride) } else { h.clone() };
                    let (bsz, oh, ow) = (skx.shape()[0], skx.shape()[1], skx.shape()[2]);
                    let flat = skx.reshape(&[bsz * oh * ow, block_cin]);
                    linear(params, &format!("{nm}/skip"), flat, tap)
                        .reshape(&[bsz, oh, ow, cout])
                } else if stride > 1 {
                    stride_slice(&h, stride)
                } else {
                    h.clone()
                };
                let mut hn = y2;
                hn.add_assign(&sk);
                relu_inplace(&mut hn);
                hn
            }));
            cin = cout;
        }
    }
    stages.push(Stage::new("head", |params, h, tap| {
        let pooled = global_avg_pool(&h);
        linear(params, "head", pooled, tap)
    }));
    stages
}

fn plain_stages() -> Vec<Stage> {
    // Pool placement rides with the preceding conv so the op order of
    // the fold matches the old straight-line body exactly.
    let mut stages = Vec::new();
    for i in 0..5usize {
        let name = format!("conv{i}");
        let pooled_after = i == 1 || i == 3;
        stages.push(Stage::new(name.clone(), move |params, h, tap| {
            let mut h = conv2d(params, &name, &h, 3, 1, 1, tap);
            relu_inplace(&mut h);
            if pooled_after {
                h = avg_pool2(&h);
            }
            h
        }));
    }
    stages.push(Stage::new("fc", |params, h, tap| {
        let pooled = global_avg_pool(&h);
        let mut fc = linear(params, "fc", pooled, tap);
        relu_inplace(&mut fc);
        fc
    }));
    stages.push(Stage::new("head", |params, h, tap| linear(params, "head", h, tap)));
    stages
}

fn mobile_stages(cfg: &CnnConfig) -> Vec<Stage> {
    let mut stages = vec![Stage::new("stem", |params, x, tap| {
        let mut h = conv2d(params, "stem", &x, 3, 2, 1, tap);
        relu_inplace(&mut h);
        h
    })];
    let mut cin = cfg.width;
    for i in 0..3 {
        let cout = cfg.width * (1 << i);
        let nm = format!("dsb{i}");
        let stride = if i > 0 { 2 } else { 1 };
        let block_cin = cin;
        stages.push(Stage::new(nm.clone(), move |params, h, tap| {
            let mut h = dwconv2d(params, &format!("{nm}/dw"), &h, 3, stride, 1, tap);
            relu_inplace(&mut h);
            let (bsz, oh, ow) = (h.shape()[0], h.shape()[1], h.shape()[2]);
            let flat = h.reshape(&[bsz * oh * ow, block_cin]);
            let mut pw = linear(params, &format!("{nm}/pw"), flat, tap);
            relu_inplace(&mut pw);
            pw.reshape(&[bsz, oh, ow, cout])
        }));
        cin = cout;
    }
    stages.push(Stage::new("head", |params, h, tap| {
        let pooled = global_avg_pool(&h);
        linear(params, "head", pooled, tap)
    }));
    stages
}
