//! Native CNN forward — operation-for-operation mirror of
//! python/compile/nets/cnn.py (resnet_lite, cnn_s, mobilenet_lite).

use std::collections::BTreeMap;

use crate::manifest::CnnConfig;
use crate::tensor::ops::{avg_pool2, global_avg_pool, relu_inplace, stride_slice};
use crate::tensor::Tensor;

use super::{conv2d, dwconv2d, linear, Tap};

/// x [b, img, img, 3] -> logits [b, classes].
pub fn cnn_forward(
    cfg: &CnnConfig,
    params: &BTreeMap<String, Tensor>,
    x: &Tensor,
    tap: &mut Tap,
) -> Tensor {
    match cfg.kind.as_str() {
        "resnet" => resnet_forward(cfg, params, x, tap),
        "plain" => plain_forward(cfg, params, x, tap),
        "mobile" => mobile_forward(cfg, params, x, tap),
        k => panic!("unknown cnn kind '{k}'"),
    }
}

fn resnet_forward(
    cfg: &CnnConfig,
    params: &BTreeMap<String, Tensor>,
    x: &Tensor,
    tap: &mut Tap,
) -> Tensor {
    let mut h = conv2d(params, "stem", x, 3, 1, 1, tap);
    relu_inplace(&mut h);
    let mut cin = cfg.width;
    for s in 0..3 {
        let cout = cfg.width * (1 << s);
        for b in 0..cfg.blocks {
            let nm = format!("s{s}/b{b}");
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let mut y = conv2d(params, &format!("{nm}/conv1"), &h, 3, stride, 1, tap);
            relu_inplace(&mut y);
            let y2 = conv2d(params, &format!("{nm}/conv2"), &y, 3, 1, 1, tap);
            let sk = if cin != cout {
                // 1x1 projection shortcut on the strided input
                let skx = if stride > 1 { stride_slice(&h, stride) } else { h.clone() };
                let (bsz, oh, ow) = (skx.shape()[0], skx.shape()[1], skx.shape()[2]);
                let flat = skx.reshape(&[bsz * oh * ow, cin]);
                linear(params, &format!("{nm}/skip"), flat, tap)
                    .reshape(&[bsz, oh, ow, cout])
            } else if stride > 1 {
                stride_slice(&h, stride)
            } else {
                h.clone()
            };
            let mut hn = y2;
            hn.add_assign(&sk);
            relu_inplace(&mut hn);
            h = hn;
            cin = cout;
        }
    }
    let pooled = global_avg_pool(&h);
    linear(params, "head", pooled, tap)
}

fn plain_forward(
    _cfg: &CnnConfig,
    params: &BTreeMap<String, Tensor>,
    x: &Tensor,
    tap: &mut Tap,
) -> Tensor {
    let mut h = conv2d(params, "conv0", x, 3, 1, 1, tap);
    relu_inplace(&mut h);
    h = conv2d(params, "conv1", &h, 3, 1, 1, tap);
    relu_inplace(&mut h);
    h = avg_pool2(&h);
    h = conv2d(params, "conv2", &h, 3, 1, 1, tap);
    relu_inplace(&mut h);
    h = conv2d(params, "conv3", &h, 3, 1, 1, tap);
    relu_inplace(&mut h);
    h = avg_pool2(&h);
    h = conv2d(params, "conv4", &h, 3, 1, 1, tap);
    relu_inplace(&mut h);
    let pooled = global_avg_pool(&h);
    let mut fc = linear(params, "fc", pooled, tap);
    relu_inplace(&mut fc);
    linear(params, "head", fc, tap)
}

fn mobile_forward(
    cfg: &CnnConfig,
    params: &BTreeMap<String, Tensor>,
    x: &Tensor,
    tap: &mut Tap,
) -> Tensor {
    let mut h = conv2d(params, "stem", x, 3, 2, 1, tap);
    relu_inplace(&mut h);
    let mut cin = cfg.width;
    for i in 0..3 {
        let cout = cfg.width * (1 << i);
        let nm = format!("dsb{i}");
        let stride = if i > 0 { 2 } else { 1 };
        h = dwconv2d(params, &format!("{nm}/dw"), &h, 3, stride, 1, tap);
        relu_inplace(&mut h);
        let (bsz, oh, ow) = (h.shape()[0], h.shape()[1], h.shape()[2]);
        let flat = h.reshape(&[bsz * oh * ow, cin]);
        let mut pw = linear(params, &format!("{nm}/pw"), flat, tap);
        relu_inplace(&mut pw);
        h = pw.reshape(&[bsz, oh, ow, cout]);
        cin = cout;
    }
    let pooled = global_avg_pool(&h);
    linear(params, "head", pooled, tap)
}
