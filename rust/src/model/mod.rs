//! In-memory model graph + native forward pass.
//!
//! The native forward is an operation-for-operation mirror of the JAX
//! models in python/compile/nets/ (same im2col patch order, same GELU
//! closed form, same LayerNorm epsilon). It serves three purposes:
//!
//! 1. cross-checking the PJRT artifacts (parity tests assert the two
//!    paths agree to float tolerance on the real checkpoints);
//! 2. a fast evaluation engine for the bench sweeps (no per-batch PJRT
//!    dispatch overhead at these tiny model sizes);
//! 3. calibration-statistics capture via `Tap::Stats`, mirroring the
//!    `calib_stats` artifact.
//!
//! Both engines are exposed behind `eval::Evaluator`; the CLI's
//! `--engine {native,pjrt}` flips between them.

mod cnn;
mod vit;

pub use cnn::{cnn_forward, cnn_stages};
pub use vit::{vit_forward, vit_stages};

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::manifest::{Manifest, ModelConfig, ModelInfo};
use crate::quant::actq::ActQuant;
use crate::quant::GramSet;
use crate::tensor::Tensor;
use crate::tensorstore;

/// Per-layer calibration statistics captured by a `Stats` tap.
#[derive(Debug, Clone)]
pub struct LayerStats {
    pub gram: GramSet,
    pub min: f32,
    pub max: f32,
    /// Number of feature rows accumulated (for averaging diagnostics).
    pub rows: usize,
}

/// Layer-execution override: lets an external runtime take over whole
/// quantizable layers during a forward pass. The integer serving
/// runtime (`serve::QuantizedModel`) implements this to run `x@W + b`
/// as an i8 GEMM and depthwise convs through the grouped i8 kernel,
/// without ever materializing f32 weights; layers it does not own
/// (kept-FP skip layers) fall back to the normal f32 path after
/// `tap_input` has had a chance to rewrite their input.
pub trait LayerExec: Sync {
    /// Fully execute the named linear layer on `x` [rows, m], returning
    /// `y = x@W + b` [rows, n] — or None to fall back to the f32 path.
    fn exec_linear(&self, name: &str, x: &Tensor) -> Option<Tensor>;

    /// Fully execute the named grouped (depthwise) layer on its grouped
    /// patches `x3` [rows, groups, kk], returning `y` [rows, groups]
    /// (per-group conv + bias) — or None to fall back to the f32 path.
    /// Default: fall back.
    fn exec_grouped(&self, _name: &str, _x3: &Tensor) -> Option<Tensor> {
        None
    }

    /// Observe/rewrite the input of a layer this executor does *not* own
    /// (e.g. fake-quantize it so fallback layers match a W/A-quantized
    /// reference). Default: pass through.
    fn tap_input(&self, _name: &str, x: Tensor) -> Tensor {
        x
    }
}

/// Instrumentation at every quantizable layer input, mirroring
/// python/compile/nets/common.py::Tap.
pub enum Tap<'a> {
    /// Plain forward.
    None,
    /// Record (G = XᵀX, min, max) per layer.
    Stats(&'a mut BTreeMap<String, LayerStats>),
    /// Fake-quantize layer inputs (full W/A quantization).
    ActQ(&'a BTreeMap<String, ActQuant>),
    /// Route layers through an execution override (integer serving).
    Exec(&'a dyn LayerExec),
}

impl Tap<'_> {
    /// Observe/rewrite a 2-D layer input [rows, m].
    pub fn tap2(&mut self, name: &str, x: Tensor) -> Tensor {
        match self {
            Tap::None => x,
            Tap::Stats(map) => {
                accumulate(map, name, GramSet::from_features(&x), &x);
                x
            }
            Tap::ActQ(params) => apply_actq(params, name, x),
            Tap::Exec(e) => e.tap_input(name, x),
        }
    }

    /// Observe/rewrite a grouped (depthwise) input [rows, groups, kk].
    pub fn tap_grouped(&mut self, name: &str, x: Tensor) -> Tensor {
        match self {
            Tap::None => x,
            Tap::Stats(map) => {
                accumulate(map, name, GramSet::from_grouped_features(&x), &x);
                x
            }
            Tap::ActQ(params) => apply_actq(params, name, x),
            Tap::Exec(e) => e.tap_input(name, x),
        }
    }

    /// Give an execution override the chance to run the whole linear
    /// layer; None on every non-Exec tap.
    pub fn exec_linear(&mut self, name: &str, x: &Tensor) -> Option<Tensor> {
        match self {
            Tap::Exec(e) => e.exec_linear(name, x),
            _ => None,
        }
    }

    /// Give an execution override the chance to run the whole grouped
    /// (depthwise) layer; None on every non-Exec tap.
    pub fn exec_grouped(&mut self, name: &str, x3: &Tensor) -> Option<Tensor> {
        match self {
            Tap::Exec(e) => e.exec_grouped(name, x3),
            _ => None,
        }
    }
}

fn accumulate(
    map: &mut BTreeMap<String, LayerStats>,
    name: &str,
    gram: GramSet,
    x: &Tensor,
) {
    let (mn, mx) = (x.min(), x.max());
    let rows = x.shape()[0];
    match map.get_mut(name) {
        Some(st) => {
            st.gram.accumulate(&gram);
            st.min = st.min.min(mn);
            st.max = st.max.max(mx);
            st.rows += rows;
        }
        None => {
            map.insert(name.to_string(), LayerStats { gram, min: mn, max: mx, rows });
        }
    }
}

fn apply_actq(params: &BTreeMap<String, ActQuant>, name: &str, mut x: Tensor) -> Tensor {
    if let Some(aq) = params.get(name) {
        aq.apply_tensor(&mut x);
    }
    x
}

/// One step of a model's forward pass: a named, boxed transform
/// `h -> h'` over the activation tensor. The per-architecture stage
/// builders ([`cnn_stages`], [`vit_stages`]) cut each network at its
/// natural layer boundaries (stem / residual block / transformer block /
/// head), and [`Model::forward`] is *defined* as the sequential fold of
/// the plan — so the pipelined executor in `serve/batcher.rs`, which
/// runs different stages of different batches concurrently, is
/// bit-identical to the single-threaded forward by construction: both
/// run the exact same closures in the exact same order per batch.
pub struct Stage {
    name: String,
    f: Box<dyn Fn(&BTreeMap<String, Tensor>, Tensor, &mut Tap) -> Tensor + Send + Sync>,
}

impl Stage {
    pub(crate) fn new(
        name: impl Into<String>,
        f: impl Fn(&BTreeMap<String, Tensor>, Tensor, &mut Tap) -> Tensor + Send + Sync + 'static,
    ) -> Stage {
        Stage { name: name.into(), f: Box::new(f) }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Run this stage: consumes the activation, returns the next one.
    pub fn run(&self, params: &BTreeMap<String, Tensor>, h: Tensor, tap: &mut Tap) -> Tensor {
        (self.f)(params, h, tap)
    }
}

impl std::fmt::Debug for Stage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Stage({})", self.name)
    }
}

/// A loaded model: manifest metadata + named parameter tensors.
#[derive(Debug, Clone)]
pub struct Model {
    pub info: ModelInfo,
    pub params: BTreeMap<String, Tensor>,
}

impl Model {
    /// Load a model's checkpoint through the manifest.
    pub fn load(manifest: &Manifest, name: &str) -> Result<Model> {
        let info = manifest.model(name)?.clone();
        let params = tensorstore::read_tensors(&manifest.path(&info.checkpoint))
            .with_context(|| format!("loading checkpoint for {name}"))?;
        // validate against the canonical parameter list
        for p in &info.params {
            if !params.contains_key(p) {
                anyhow::bail!("checkpoint missing parameter '{p}'");
            }
        }
        Ok(Model { info, params })
    }

    pub fn param(&self, name: &str) -> &Tensor {
        &self.params[name]
    }

    /// Layer weight (W) of a quantizable layer.
    pub fn weight(&self, layer: &str) -> &Tensor {
        &self.params[&format!("{layer}/W")]
    }

    /// Replace a layer's weight (after quantization).
    pub fn set_weight(&mut self, layer: &str, w: Tensor) {
        let key = format!("{layer}/W");
        let old = self.params.get(&key).expect("unknown layer");
        assert_eq!(old.shape(), w.shape(), "weight shape mismatch for {layer}");
        self.params.insert(key, w);
    }

    /// Parameters in canonical (manifest) order — the PJRT input order.
    pub fn params_in_order(&self) -> Vec<&Tensor> {
        self.info.params.iter().map(|k| &self.params[k]).collect()
    }

    /// The forward pass as an ordered list of named stages. Building a
    /// plan is cheap (a few boxed closures); the serving tier builds it
    /// once per loaded model and reuses it across requests.
    pub fn stage_plan(&self) -> Vec<Stage> {
        match &self.info.config {
            ModelConfig::ViT(cfg) => vit_stages(cfg),
            ModelConfig::Cnn(cfg) => cnn_stages(cfg),
        }
    }

    /// Native forward: x [b, img, img, 3] -> logits [b, classes].
    /// Defined as the fold of [`Model::stage_plan`] — the single source
    /// of truth the pipelined serving executor shares.
    pub fn forward(&self, x: &Tensor, tap: &mut Tap) -> Tensor {
        let mut h = x.clone();
        for stage in self.stage_plan() {
            h = stage.run(&self.params, h, tap);
        }
        h
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.params.values().map(|t| t.len()).sum()
    }

    /// Quantizable weight count (what the bit-width applies to).
    pub fn num_quant_weights(&self) -> usize {
        self.info.quant_layers.iter().map(|l| l.m * l.n).sum()
    }
}

/// Linear layer: y = tap(x) @ W + b (mirrors nets/common.py::linear).
/// An `Exec` tap may take the whole layer over (integer serving); the
/// f32 parameters are only touched on the fallback path, so models
/// served through an override need no `{name}/W` entry for owned layers.
pub fn linear(
    params: &BTreeMap<String, Tensor>,
    name: &str,
    x: Tensor,
    tap: &mut Tap,
) -> Tensor {
    let x = tap.tap2(name, x);
    if let Some(y) = tap.exec_linear(name, &x) {
        return y;
    }
    let w = params
        .get(&format!("{name}/W"))
        .unwrap_or_else(|| panic!("missing {name}/W"));
    let b = params
        .get(&format!("{name}/b"))
        .unwrap_or_else(|| panic!("missing {name}/b"));
    let mut y = crate::tensor::matmul(&x, w);
    crate::tensor::ops::add_bias(&mut y, b.data());
    y
}

/// Convolution as im2col + linear (mirrors nets/common.py::conv2d).
pub fn conv2d(
    params: &BTreeMap<String, Tensor>,
    name: &str,
    x: &Tensor,
    k: usize,
    stride: usize,
    pad: usize,
    tap: &mut Tap,
) -> Tensor {
    let b = x.shape()[0];
    let (patches, oh, ow) = crate::tensor::im2col(x, k, stride, pad);
    let y = linear(params, name, patches, tap);
    let n = y.cols();
    y.reshape(&[b, oh, ow, n])
}

/// Depthwise convolution (mirrors nets/common.py::dwconv2d):
/// weight [k*k, c], per-channel filters over grouped patches. An `Exec`
/// tap may take the whole layer over (grouped integer serving); like
/// [`linear`], the f32 parameters are only touched on the fallback
/// path, so override-owned layers need no `{name}/W` entry.
pub fn dwconv2d(
    params: &BTreeMap<String, Tensor>,
    name: &str,
    x: &Tensor,
    k: usize,
    stride: usize,
    pad: usize,
    tap: &mut Tap,
) -> Tensor {
    let b = x.shape()[0];
    let c = x.shape()[3];
    let (x3, oh, ow) = crate::tensor::im2col_grouped(x, k, stride, pad);
    let x3 = tap.tap_grouped(name, x3);
    if let Some(y) = tap.exec_grouped(name, &x3) {
        return y.reshape(&[b, oh, ow, c]);
    }
    let w = params
        .get(&format!("{name}/W")) // [kk, c]
        .unwrap_or_else(|| panic!("missing {name}/W"));
    let bias = params
        .get(&format!("{name}/b"))
        .unwrap_or_else(|| panic!("missing {name}/b"));
    let kk = k * k;
    let rows = b * oh * ow;
    let mut out = Tensor::zeros(&[rows, c]);
    for r in 0..rows {
        let xr = &x3.data()[r * c * kk..(r + 1) * c * kk];
        let orow = &mut out.data_mut()[r * c..(r + 1) * c];
        for ch in 0..c {
            let xc = &xr[ch * kk..(ch + 1) * kk];
            let mut s = 0.0f32;
            for p in 0..kk {
                s += xc[p] * w.at2(p, ch);
            }
            orow[ch] = s + bias.data()[ch];
        }
    }
    out.reshape(&[b, oh, ow, c])
}

/// Fetch LayerNorm affine params (g, b).
pub fn ln_params<'p>(
    params: &'p BTreeMap<String, Tensor>,
    name: &str,
) -> (&'p [f32], &'p [f32]) {
    (
        params[&format!("{name}/g")].data(),
        params[&format!("{name}/b")].data(),
    )
}

/// Collect calibration statistics by running `images` through the model
/// natively in batches.
pub fn collect_stats_native(
    model: &Model,
    images: &Tensor,
    batch: usize,
) -> Result<BTreeMap<String, LayerStats>> {
    let n = images.shape()[0];
    let img_elems: usize = images.shape()[1..].iter().product();
    let mut stats = BTreeMap::new();
    let mut i = 0;
    while i < n {
        let hi = (i + batch).min(n);
        let chunk = Tensor::new(
            &[hi - i, images.shape()[1], images.shape()[2], images.shape()[3]],
            images.data()[i * img_elems..hi * img_elems].to_vec(),
        );
        let mut tap = Tap::Stats(&mut stats);
        let _ = model.forward(&chunk, &mut tap);
        i = hi;
    }
    // sanity: every quantizable layer was visited
    for l in &model.info.quant_layers {
        if !stats.contains_key(&l.name) {
            return Err(anyhow!("layer '{}' not visited by forward", l.name));
        }
    }
    Ok(stats)
}
