//! Native ViT forward — operation-for-operation mirror of
//! python/compile/nets/vit.py (including Swin-style shifted windows),
//! expressed as a stage plan (see [`super::Stage`]). `vit_forward` is
//! the sequential fold of the plan; the pipelined serving executor runs
//! the same plan stage-by-stage across batches.

use std::collections::BTreeMap;

use crate::manifest::ViTConfig;
use crate::tensor::ops::{gelu_inplace, layer_norm, mean_axis1, shift_tokens, softmax_lastdim};
use crate::tensor::{im2col, matmul_into, Tensor};

use super::{linear, ln_params, Stage, Tap};

/// x [b, img, img, 3] -> logits [b, classes].
pub fn vit_forward(
    cfg: &ViTConfig,
    params: &BTreeMap<String, Tensor>,
    x: &Tensor,
    tap: &mut Tap,
) -> Tensor {
    let mut h = x.clone();
    for stage in vit_stages(cfg) {
        h = stage.run(params, h, tap);
    }
    h
}

/// The ViT forward cut at its natural boundaries: patch embedding, one
/// stage per transformer block, head. Stage order and the ops inside
/// each stage are exactly the pre-refactor statement order, so the fold
/// is operation-for-operation identical.
pub fn vit_stages(cfg: &ViTConfig) -> Vec<Stage> {
    let cfg = *cfg;
    let grid = cfg.img / cfg.patch;
    let t = grid * grid;
    let mut stages = vec![Stage::new("embed", move |params, x, tap| {
        let b = x.shape()[0];
        let (patches, oh, ow) = im2col(&x, cfg.patch, cfg.patch, 0);
        debug_assert_eq!(oh * ow, t);
        let mut h = linear(params, "embed/proj", patches, tap); // [b*t, dim]
        let pos = &params["embed/pos"]; // [t, dim]
        for bt in 0..b * t {
            let ti = bt % t;
            let hrow = &mut h.data_mut()[bt * cfg.dim..(bt + 1) * cfg.dim];
            for (hv, pv) in hrow.iter_mut().zip(pos.row(ti)) {
                *hv += pv;
            }
        }
        h.reshape(&[b, t, cfg.dim])
    })];
    for i in 0..cfg.depth {
        let nm = format!("blk{i}");
        stages.push(Stage::new(nm.clone(), move |params, mut h, tap| {
            let b = h.shape()[0];
            // -- attention sublayer --
            let mut a_in = h.clone();
            let (g, be) = ln_params(params, &format!("{nm}/ln1"));
            layer_norm(&mut a_in, g, be);
            let a = if cfg.window > 0 {
                let shift = if i % 2 == 1 { cfg.window / 2 } else { 0 };
                let mut a = if shift > 0 {
                    shift_tokens(&a_in, grid, shift as isize)
                } else {
                    a_in
                };
                a = window_partition(&a, grid, cfg.window);
                a = attention(&cfg, params, &nm, &a, tap);
                a = window_merge(&a, b, grid, cfg.window);
                if shift > 0 {
                    a = shift_tokens(&a, grid, -(shift as isize));
                }
                a
            } else {
                attention(&cfg, params, &nm, &a_in, tap)
            };
            h.add_assign(&a);
            // -- MLP sublayer --
            let mut m_in = h.clone();
            let (g, be) = ln_params(params, &format!("{nm}/ln2"));
            layer_norm(&mut m_in, g, be);
            let m_in = m_in.reshape(&[b * t, cfg.dim]);
            let mut mlp = linear(params, &format!("{nm}/fc1"), m_in, tap);
            gelu_inplace(&mut mlp);
            let mlp = linear(params, &format!("{nm}/fc2"), mlp, tap).reshape(&[b, t, cfg.dim]);
            h.add_assign(&mlp);
            h
        }));
    }
    stages.push(Stage::new("head", |params, mut h, tap| {
        let (g, be) = ln_params(params, "norm");
        layer_norm(&mut h, g, be);
        let pooled = mean_axis1(&h);
        linear(params, "head", pooled, tap)
    }));
    stages
}

/// Multi-head self-attention on x [b, t, d] (global within each "batch"
/// element — window attention passes window-batched tokens).
fn attention(
    cfg: &ViTConfig,
    params: &BTreeMap<String, Tensor>,
    name: &str,
    x: &Tensor,
    tap: &mut Tap,
) -> Tensor {
    let (b, t, d) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let hd = cfg.dim / cfg.heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let qkv = linear(
        params,
        &format!("{name}/qkv"),
        x.clone().reshape(&[b * t, d]),
        tap,
    ); // [b*t, 3d]
    // split into per-head q, k, v: qkv[bt, 3, heads, hd]. All scratch is
    // preallocated once and reused across (batch, head) — the per-head
    // Tensor allocations were a measurable cost on the native eval path
    // (EXPERIMENTS.md §Perf iteration #5).
    let mut out = Tensor::zeros(&[b, t, d]);
    let qkvd = qkv.data();
    let mut q = vec![0.0f32; t * hd];
    let mut kt = vec![0.0f32; hd * t]; // k transposed: [hd, t]
    let mut v = vec![0.0f32; t * hd];
    let mut att = Tensor::zeros(&[t, t]);
    let mut o = vec![0.0f32; t * hd];
    for bi in 0..b {
        for hi in 0..cfg.heads {
            for ti in 0..t {
                let base = (bi * t + ti) * 3 * d;
                let qoff = base + hi * hd;
                let koff = base + d + hi * hd;
                let voff = base + 2 * d + hi * hd;
                q[ti * hd..(ti + 1) * hd].copy_from_slice(&qkvd[qoff..qoff + hd]);
                v[ti * hd..(ti + 1) * hd].copy_from_slice(&qkvd[voff..voff + hd]);
                for e in 0..hd {
                    kt[e * t + ti] = qkvd[koff + e];
                }
            }
            // att = softmax(q kᵀ * scale) [t, t]
            att.data_mut().fill(0.0);
            matmul_into(&q, &kt, att.data_mut(), t, hd, t);
            for x in att.data_mut() {
                *x *= scale;
            }
            softmax_lastdim(&mut att);
            o.fill(0.0);
            matmul_into(att.data(), &v, &mut o, t, t, hd);
            for ti in 0..t {
                let dst = &mut out.data_mut()[((bi * t + ti) * d + hi * hd)..][..hd];
                dst.copy_from_slice(&o[ti * hd..(ti + 1) * hd]);
            }
        }
    }
    let proj = linear(
        params,
        &format!("{name}/proj"),
        out.reshape(&[b * t, d]),
        tap,
    );
    proj.reshape(&[b, t, d])
}

/// [b, g*g, d] -> [b*(g/w)², w*w, d]  (mirrors vit.py::_window_partition).
fn window_partition(x: &Tensor, g: usize, w: usize) -> Tensor {
    let (b, t, d) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    debug_assert_eq!(t, g * g);
    let nw = g / w;
    let mut out = Tensor::zeros(&[b * nw * nw, w * w, d]);
    for bi in 0..b {
        for wy in 0..nw {
            for wx in 0..nw {
                let widx = (bi * nw + wy) * nw + wx;
                for iy in 0..w {
                    for ix in 0..w {
                        let src_tok = (wy * w + iy) * g + (wx * w + ix);
                        let src = &x.data()[(bi * t + src_tok) * d..][..d];
                        let dst_tok = iy * w + ix;
                        let dst =
                            &mut out.data_mut()[(widx * w * w + dst_tok) * d..][..d];
                        dst.copy_from_slice(src);
                    }
                }
            }
        }
    }
    out
}

/// Inverse of `window_partition`.
fn window_merge(x: &Tensor, b: usize, g: usize, w: usize) -> Tensor {
    let d = x.shape()[2];
    let nw = g / w;
    let mut out = Tensor::zeros(&[b, g * g, d]);
    for bi in 0..b {
        for wy in 0..nw {
            for wx in 0..nw {
                let widx = (bi * nw + wy) * nw + wx;
                for iy in 0..w {
                    for ix in 0..w {
                        let dst_tok = (wy * w + iy) * g + (wx * w + ix);
                        let src_tok = iy * w + ix;
                        let src = &x.data()[(widx * w * w + src_tok) * d..][..d];
                        let dst = &mut out.data_mut()[(bi * g * g + dst_tok) * d..][..d];
                        dst.copy_from_slice(src);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn window_partition_roundtrip() {
        let mut rng = Rng::new(1);
        let (b, g, w, d) = (2, 4, 2, 3);
        let x = Tensor::new(&[b, g * g, d], rng.normal_vec(b * g * g * d));
        let p = window_partition(&x, g, w);
        assert_eq!(p.shape(), &[b * 4, w * w, d]);
        let m = window_merge(&p, b, g, w);
        assert_eq!(m, x);
    }

    #[test]
    fn window_partition_layout() {
        // g=4, w=2: token grid indices, single batch & channel
        let x = Tensor::new(&[1, 16, 1], (0..16).map(|i| i as f32).collect());
        let p = window_partition(&x, 4, 2);
        // window (0,0) holds tokens 0,1,4,5
        assert_eq!(&p.data()[0..4], &[0., 1., 4., 5.]);
        // window (0,1) holds tokens 2,3,6,7
        assert_eq!(&p.data()[4..8], &[2., 3., 6., 7.]);
        // window (1,1) holds tokens 10,11,14,15
        assert_eq!(&p.data()[12..16], &[10., 11., 14., 15.]);
    }
}
