//! Calibration manager: turns raw calibration images into the per-layer
//! sufficient statistics every quantizer consumes.
//!
//! Two capture engines, cross-checked by the integration tests:
//!
//! * **pjrt**   — runs the AOT `calib_stats` artifact (L2 graph, which
//!   computes G = XᵀX *inside* XLA so raw activations never cross the
//!   runtime boundary) in batches and accumulates;
//! * **native** — runs the Rust mirror forward with a `Stats` tap.
//!
//! The dataset itself (SynthImageNet calib/val splits) lives in one .cts
//! file referenced by the manifest.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::manifest::Manifest;
use crate::model::{collect_stats_native, LayerStats, Model};
use crate::quant::GramSet;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::tensorstore;

/// Which execution engine to use for calibration & evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Native,
    Pjrt,
    /// The integer serving runtime (`serve::QuantizedModel`): real i8
    /// GEMMs over packed codes. Evaluation-only — calibration always
    /// runs in f32 (statistics of the *unquantized* network are what
    /// the quantizers need), so for calibration this aliases `Native`.
    Int8,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "native" => Some(EngineKind::Native),
            "pjrt" => Some(EngineKind::Pjrt),
            "int8" | "i8" => Some(EngineKind::Int8),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Pjrt => "pjrt",
            EngineKind::Int8 => "int8",
        }
    }
}

/// The calibration + validation dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub calib_images: Tensor,
    pub calib_labels: Vec<i32>,
    pub val_images: Tensor,
    pub val_labels: Vec<i32>,
}

impl Dataset {
    pub fn load(manifest: &Manifest) -> Result<Dataset> {
        let store = tensorstore::read_store(&manifest.path(&manifest.data))
            .context("loading dataset")?;
        let get_t = |k: &str| -> Result<Tensor> {
            Ok(store
                .get(k)
                .ok_or_else(|| anyhow!("dataset missing '{k}'"))?
                .tensor()?
                .clone())
        };
        let get_i = |k: &str| -> Result<Vec<i32>> {
            Ok(store
                .get(k)
                .ok_or_else(|| anyhow!("dataset missing '{k}'"))?
                .ints()?
                .to_vec())
        };
        Ok(Dataset {
            calib_images: get_t("calib/images")?,
            calib_labels: get_i("calib/labels")?,
            val_images: get_t("val/images")?,
            val_labels: get_i("val/labels")?,
        })
    }

    /// First `n` calibration images (paper Tab. 6 sweeps this).
    pub fn calib_subset(&self, n: usize) -> Tensor {
        let total = self.calib_images.shape()[0];
        let n = n.min(total);
        let elems: usize = self.calib_images.shape()[1..].iter().product();
        let mut shape = self.calib_images.shape().to_vec();
        shape[0] = n;
        Tensor::new(&shape, self.calib_images.data()[..n * elems].to_vec())
    }

    /// Data-free calibration stand-in (DFQ/ZeroQ context): Gaussian
    /// noise matched to the real calibration set's mean/std. The
    /// ablation bench measures how much COMQ actually depends on *real*
    /// calibration data versus merely well-scaled inputs.
    pub fn gaussian_calib(&self, n: usize, seed: u64) -> Tensor {
        let elems: usize = self.calib_images.shape()[1..].iter().product();
        let d = self.calib_images.data();
        let mean = d.iter().map(|&x| x as f64).sum::<f64>() / d.len() as f64;
        let var = d.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / d.len() as f64;
        let (mean, std) = (mean as f32, (var.sqrt()) as f32);
        let mut rng = crate::util::Rng::new(seed);
        let mut shape = self.calib_images.shape().to_vec();
        shape[0] = n;
        let data = (0..n * elems).map(|_| mean + std * rng.normal()).collect();
        Tensor::new(&shape, data)
    }
}

/// Collect per-layer calibration statistics.
pub fn collect_stats(
    manifest: &Manifest,
    model: &Model,
    images: &Tensor,
    engine: EngineKind,
) -> Result<BTreeMap<String, LayerStats>> {
    match engine {
        // Int8 is a serving engine; calibration statistics come from the
        // f32 network either way.
        EngineKind::Native | EngineKind::Int8 => {
            collect_stats_native(model, images, manifest.batch)
        }
        EngineKind::Pjrt => collect_stats_pjrt(manifest, model, images),
    }
}

/// PJRT path: run the `calib_stats` artifact per batch; outputs are
/// 3 per layer (G, min, max) in manifest layer order. The batch dimension
/// is baked into the artifact, so the last partial batch is zero-padded
/// and its padding rows contribute zero to G (zero images produce zero
/// patch rows for every layer input... they do NOT — biases/LN make
/// nonzero activations). We therefore drop a partial final batch instead
/// of padding; calibration sizes are multiples of the AOT batch in
/// practice (128..2048 vs batch 64).
pub fn collect_stats_pjrt(
    manifest: &Manifest,
    model: &Model,
    images: &Tensor,
) -> Result<BTreeMap<String, LayerStats>> {
    let engine = Engine::global()?;
    let art = model
        .info
        .artifacts
        .get("calib_stats")
        .ok_or_else(|| anyhow!("model has no calib_stats artifact"))?;
    let path = manifest.path(art);
    let b = manifest.batch;
    let n = images.shape()[0];
    if n < b {
        bail!("need at least {b} calibration images, got {n}");
    }
    let img_elems: usize = images.shape()[1..].iter().product();
    let layers = &model.info.quant_layers;
    let mut stats: BTreeMap<String, LayerStats> = BTreeMap::new();
    let params = model.params_in_order();
    let mut i = 0;
    while i + b <= n {
        let chunk = Tensor::new(
            &[b, images.shape()[1], images.shape()[2], images.shape()[3]],
            images.data()[i * img_elems..(i + b) * img_elems].to_vec(),
        );
        let mut inputs: Vec<&Tensor> = params.clone();
        inputs.push(&chunk);
        let outs = engine.run(&path, &inputs)?;
        // +1: the anchor output that pins head params into the signature
        if outs.len() != 3 * layers.len() + 1 {
            bail!(
                "calib_stats returned {} outputs, expected {}",
                outs.len(),
                3 * layers.len() + 1
            );
        }
        for (li, l) in layers.iter().enumerate() {
            let g = outs[3 * li].clone();
            let mn = outs[3 * li + 1].data()[0];
            let mx = outs[3 * li + 2].data()[0];
            let gram = if l.grouped {
                // [groups, kk, kk] stacked
                let (c, kk) = (g.shape()[0], g.shape()[1]);
                let mut groups = Vec::with_capacity(c);
                for ch in 0..c {
                    groups.push(Tensor::new(
                        &[kk, kk],
                        g.data()[ch * kk * kk..(ch + 1) * kk * kk].to_vec(),
                    ));
                }
                GramSet::Grouped(groups)
            } else {
                GramSet::Shared(g)
            };
            match stats.get_mut(&l.name) {
                Some(st) => {
                    st.gram.accumulate(&gram);
                    st.min = st.min.min(mn);
                    st.max = st.max.max(mx);
                    st.rows += b;
                }
                None => {
                    stats.insert(
                        l.name.clone(),
                        LayerStats { gram, min: mn, max: mx, rows: b },
                    );
                }
            }
        }
        i += b;
    }
    Ok(stats)
}
