//! PJRT runtime: loads the AOT-compiled HLO-text artifacts and executes
//! them on the CPU PJRT client. This is the only place the `xla` crate is
//! touched; everything above works in `Tensor`s.
//!
//! HLO *text* is the interchange format (python emits it via
//! `mlir_module_to_xla_computation(...).as_hlo_text()`): jax ≥ 0.5 emits
//! serialized protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids. See
//! /opt/xla-example/README.md.
//!
//! Executables are compiled once per artifact path and cached; the
//! compile cache is the runtime analogue of a serving system's model
//! registry.
//!
//! Builds without the `xla_extension` shared library use the in-tree
//! [`stub`] in its place (same API surface; `Engine::global()` returns
//! a "not vendored" error and the pipeline falls back to the native
//! engine). Swap the alias below for the real crate to re-enable PJRT.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};
use once_cell::sync::OnceCell;

use crate::tensor::Tensor;

pub mod stub;
use stub as xla;

/// Lazily-initialized process-wide PJRT engine.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// The xla crate wraps thread-safe C++ objects (PJRT is internally
// synchronized); the raw pointers just aren't marked. Executions from
// multiple coordinator threads are serialized per-executable by PJRT.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

static ENGINE: OnceCell<Engine> = OnceCell::new();

impl Engine {
    /// The process-wide engine (CPU PJRT client).
    pub fn global() -> Result<&'static Engine> {
        ENGINE.get_or_try_init(|| {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
            crate::log_info!(
                "PJRT client: platform={} devices={}",
                client.platform_name(),
                client.device_count()
            );
            Ok(Engine { client, cache: Mutex::new(HashMap::new()) })
        })
    }

    /// Compile (or fetch from cache) the HLO-text artifact at `path`.
    pub fn load(&self, path: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(path.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute an artifact with `Tensor` inputs; returns all tuple outputs.
    /// (All our graphs are lowered with `return_tuple=True`.)
    pub fn run(&self, path: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.load(path)?;
        self.run_exe(&exe, inputs).with_context(|| format!("executing {path}"))
    }

    /// Execute an already-loaded executable.
    pub fn run_exe(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| tensor_to_literal(t))
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("PJRT execute: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow!("untupling result: {e:?}"))?;
        parts.into_iter().map(|l| literal_to_tensor(&l)).collect()
    }
}

/// Tensor (row-major f32) -> xla::Literal of the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let flat = xla::Literal::vec1(t.data());
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    flat.reshape(&dims).map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// xla::Literal (f32) -> Tensor.
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec (dtype {:?}): {e:?}", shape.ty()))?;
    let dims = if dims.is_empty() { vec![1] } else { dims };
    Ok(Tensor::new(&dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> Option<std::path::PathBuf> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        root.join("manifest.json").exists().then_some(root)
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn sweep_artifact_runs() {
        // Smallest end-to-end PJRT check: run a COMQ sweep artifact and
        // verify shapes + cache behaviour. (Numerical parity with the rust
        // engine is covered by the integration tests.)
        let Some(root) = artifacts_root() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let m = crate::manifest::Manifest::load(&root).unwrap();
        let Some(sw) = m.sweeps.first() else { return };
        let eng = Engine::global().unwrap();
        let g = Tensor::zeros(&[sw.m, sw.m]);
        let w = Tensor::zeros(&[sw.m, sw.n]);
        let q = Tensor::zeros(&[sw.m, sw.n]);
        let d = Tensor::full(&[sw.n], 1.0);
        let lo = Tensor::full(&[sw.n], 0.0);
        let hi = Tensor::full(&[sw.n], 15.0);
        let outs = eng.run(&m.path(&sw.path), &[&g, &w, &q, &d, &lo, &hi]).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].shape(), &[sw.m, sw.n]);
        assert_eq!(outs[1].shape(), &[sw.n]);
        let before = eng.cache_len();
        let _ = eng.run(&m.path(&sw.path), &[&g, &w, &q, &d, &lo, &hi]).unwrap();
        assert_eq!(eng.cache_len(), before, "second run must hit the cache");
    }
}
