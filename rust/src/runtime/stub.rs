//! In-tree stand-in for the `xla` crate's PJRT surface.
//!
//! The PJRT runtime is an *optional* execution engine: the native
//! Gram-domain quantizer and the i8 serving runtime never touch it.
//! Vendored builds of this crate don't carry the `xla_extension`
//! shared library, so instead of a hard link-time dependency the
//! handful of types `runtime::Engine` needs are mirrored here.
//!
//! Shape of the stub:
//!
//! * `Literal` is **real** — host-side f32 tensor interchange has no
//!   PJRT dependency, so `tensor_to_literal`/`literal_to_tensor` (and
//!   their round-trip test) work unchanged;
//! * everything that requires a live PJRT client (`PjRtClient`,
//!   `PjRtLoadedExecutable`, `PjRtBuffer`, `HloModuleProto`,
//!   `XlaComputation`) is an *uninhabited* enum: the only constructors
//!   (`PjRtClient::cpu`, `HloModuleProto::from_text_file`) return
//!   `Err`, so every downstream method body is a provably-unreachable
//!   `match *self {}`. Callers see a clean runtime error
//!   ("PJRT runtime not vendored"), not a crash, and the pipeline's
//!   `pjrt-kernel` path falls back to the native engine per layer.
//!
//! When a real `xla` crate is linked in, delete the `use stub as xla`
//! alias in `runtime/mod.rs`; the call sites match its 0.5-era API.

/// Error type mirroring `xla::Error` closely enough for the `{e:?}`
/// formatting at the call sites.
pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn not_vendored() -> Error {
    Error(
        "PJRT runtime not vendored in this build (xla_extension is absent); \
         use the native engine (--quant-engine native, the default)"
            .into(),
    )
}

/// Element types `Literal` can report. The stub only ever holds f32.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Shape of an array literal: dimensions plus element type.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Marker for element types `Literal::to_vec` can extract. Sealed to
/// f32 — the only dtype the runtime moves across the boundary.
pub trait NativeType: Sized {
    fn extract(data: &[f32]) -> Vec<Self>;
}

impl NativeType for f32 {
    fn extract(data: &[f32]) -> Vec<f32> {
        data.to_vec()
    }
}

/// Host-side tensor interchange value. Real (not stubbed): it's just
/// an f32 buffer with a shape, and keeping it functional keeps the
/// Tensor↔Literal conversions testable without PJRT.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Same data, new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch ({} vs {})",
                self.dims,
                dims,
                self.data.len(),
                want
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: ElementType::F32 })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Ok(T::extract(&self.data))
    }

    /// Tuple literals only come out of PJRT executions, which the stub
    /// cannot perform — so this is always an error here.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(not_vendored())
    }
}

/// PJRT client handle. Uninhabited: `cpu()` is the only constructor
/// and it always fails in the stub.
pub enum PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(not_vendored())
    }
    pub fn platform_name(&self) -> String {
        match *self {}
    }
    pub fn device_count(&self) -> usize {
        match *self {}
    }
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        match *self {}
    }
}

/// Compiled-and-loaded executable handle (uninhabited in the stub).
pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        match *self {}
    }
}

/// Device buffer handle (uninhabited in the stub).
pub enum PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        match *self {}
    }
}

/// Parsed HLO module (uninhabited: parsing needs the XLA text parser).
pub enum HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(not_vendored())
    }
}

/// XLA computation wrapper (uninhabited in the stub).
pub enum XlaComputation {}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match *proto {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_is_functional_without_pjrt() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err(), "element count mismatch must fail");
        assert!(r.to_tuple().is_err(), "stub never produces tuple literals");
    }

    #[test]
    fn client_reports_not_vendored() {
        let err = PjRtClient::cpu().err().expect("stub client must not construct");
        let msg = format!("{err:?}");
        assert!(msg.contains("not vendored"), "got: {msg}");
    }
}
