//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the single source of truth tying the build-time python
//! world to the runtime rust world: model configs, canonical parameter
//! order for positional PJRT inputs, quantizable layer shapes, checkpoint
//! and HLO artifact paths, and the FP reference accuracy.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

/// One quantizable layer's shape metadata.
#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub name: String,
    /// Input features (rows of W; k*k for grouped/depthwise layers).
    pub m: usize,
    /// Output channels (columns of W).
    pub n: usize,
    /// Depthwise layer: per-column Gram, weight [kk, n].
    pub grouped: bool,
}

/// ViT-family architecture hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct ViTConfig {
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub mlp: usize,
    pub patch: usize,
    /// 0 = global attention; >0 = Swin-style windows of this side length.
    pub window: usize,
    pub img: usize,
    pub classes: usize,
}

/// CNN-family architecture hyperparameters.
#[derive(Debug, Clone)]
pub struct CnnConfig {
    pub kind: String, // resnet | plain | mobile
    pub width: usize,
    pub blocks: usize,
    pub img: usize,
    pub classes: usize,
}

#[derive(Debug, Clone)]
pub enum ModelConfig {
    ViT(ViTConfig),
    Cnn(CnnConfig),
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub config: ModelConfig,
    /// Canonical positional parameter order for PJRT graphs.
    pub params: Vec<String>,
    pub quant_layers: Vec<LayerInfo>,
    pub checkpoint: String,
    pub fp_top1: f64,
    /// Artifact kind -> relative HLO path ("forward", "calib_stats",
    /// "forward_actq4", "forward_actq8").
    pub artifacts: BTreeMap<String, String>,
}

/// A lowered COMQ sweep kernel artifact (L1 Pallas) for one layer shape.
#[derive(Debug, Clone)]
pub struct SweepInfo {
    pub m: usize,
    pub n: usize,
    pub per_channel: bool,
    pub path: String,
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub batch: usize,
    pub classes: usize,
    pub img: usize,
    pub data: String,
    pub models: BTreeMap<String, ModelInfo>,
    pub sweeps: Vec<SweepInfo>,
}

impl Manifest {
    /// Load from `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let j = Json::parse_file(&path.to_string_lossy())
            .with_context(|| "did you run `make artifacts`?")?;
        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models")?.obj()? {
            models.insert(name.clone(), parse_model(name, mj)?);
        }
        let mut sweeps = Vec::new();
        for sj in j.get("sweeps")?.arr()? {
            sweeps.push(SweepInfo {
                m: sj.get("m")?.usize()?,
                n: sj.get("n")?.usize()?,
                per_channel: sj.get("per_channel")?.boolean()?,
                path: sj.get("path")?.str()?.to_string(),
            });
        }
        Ok(Manifest {
            root,
            batch: j.get("batch")?.usize()?,
            classes: j.get("classes")?.usize()?,
            img: j.get("img")?.usize()?,
            data: j.get("data")?.str()?.to_string(),
            models,
            sweeps,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model '{name}' (have: {:?})", self.models.keys()))
    }

    /// Absolute path of a manifest-relative artifact path.
    pub fn path(&self, rel: &str) -> String {
        self.root.join(rel).to_string_lossy().to_string()
    }

    /// Find the sweep artifact for an exact layer shape, if lowered.
    pub fn sweep_for(&self, m: usize, n: usize, per_channel: bool) -> Option<&SweepInfo> {
        self.sweeps
            .iter()
            .find(|s| s.m == m && s.n == n && s.per_channel == per_channel)
    }
}

fn parse_model(name: &str, mj: &Json) -> Result<ModelInfo> {
    let family = mj.get("family")?.str()?.to_string();
    let cj = mj.get("config")?;
    let config = match family.as_str() {
        "vit" => ModelConfig::ViT(ViTConfig {
            dim: cj.get("dim")?.usize()?,
            depth: cj.get("depth")?.usize()?,
            heads: cj.get("heads")?.usize()?,
            mlp: cj.get("mlp")?.usize()?,
            patch: cj.get("patch")?.usize()?,
            window: cj.get("window")?.usize()?,
            img: cj.get("img")?.usize()?,
            classes: cj.get("classes")?.usize()?,
        }),
        "cnn" => ModelConfig::Cnn(CnnConfig {
            kind: cj.get("kind")?.str()?.to_string(),
            width: cj.get("width")?.usize()?,
            blocks: cj.get("blocks")?.usize()?,
            img: cj.get("img")?.usize()?,
            classes: cj.get("classes")?.usize()?,
        }),
        f => anyhow::bail!("unknown model family '{f}'"),
    };
    let params = mj
        .get("params")?
        .arr()?
        .iter()
        .map(|p| p.str().map(str::to_string))
        .collect::<Result<Vec<_>>>()?;
    let mut quant_layers = Vec::new();
    for lj in mj.get("quant_layers")?.arr()? {
        quant_layers.push(LayerInfo {
            name: lj.get("name")?.str()?.to_string(),
            m: lj.get("m")?.usize()?,
            n: lj.get("n")?.usize()?,
            grouped: lj.get("grouped")?.boolean()?,
        });
    }
    let mut artifacts = BTreeMap::new();
    for (k, v) in mj.get("artifacts")?.obj()? {
        artifacts.insert(k.clone(), v.str()?.to_string());
    }
    Ok(ModelInfo {
        name: name.to_string(),
        config,
        params,
        quant_layers,
        checkpoint: mj.get("checkpoint")?.str()?.to_string(),
        fp_top1: mj.get("fp_top1")?.num()?,
        artifacts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration test against the real artifacts (skipped when absent).
    #[test]
    fn loads_real_manifest() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&root).unwrap();
        assert!(m.models.contains_key("vit_s"));
        assert!(m.batch > 0);
        let vit = m.model("vit_s").unwrap();
        assert!(!vit.params.is_empty());
        assert!(!vit.quant_layers.is_empty());
        assert!(vit.fp_top1 > 0.5);
        // every artifact file exists
        for rel in vit.artifacts.values() {
            assert!(
                std::path::Path::new(&m.path(rel)).exists(),
                "missing artifact {rel}"
            );
        }
        // sweeps exist for vit_s layer shapes
        for l in &vit.quant_layers {
            if !l.grouped {
                assert!(m.sweep_for(l.m, l.n, true).is_some(), "no sweep for {l:?}");
            }
        }
        assert!(m.model("bogus").is_err());
    }
}
