//! Dynamic micro-batching request queue: single-image requests are
//! coalesced into batches up to `max_batch`, bounded by a latency
//! deadline measured from the oldest pending request. The classic
//! serving trade — batch-1 latency vs GEMM efficiency — made explicit:
//! under load the queue fills to `max_batch` before the deadline and the
//! i8 GEMM runs at full tilt; at low rate the deadline fires and a
//! request never waits more than `max_delay` for company.
//!
//! Executor threads both coalesce and run the forward (no separate
//! dispatcher), so with `executors > 1` the next batch assembles while
//! the previous one is still in the GEMM. Replies travel over
//! per-request channels, so batch composition never affects who gets
//! which logits.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::obs::metrics::with_labels;
use crate::obs::{Counter, Gauge, Histogram, SpanSet, Stage};
use crate::serve::QuantizedModel;
use crate::tensor::Tensor;

/// Micro-batcher tuning.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Largest batch a single forward will see.
    pub max_batch: usize,
    /// Longest the oldest request may wait for the batch to fill.
    pub max_delay: Duration,
    /// Executor threads (0 = derive from the shared COMQ_THREADS
    /// parallelism knob, see `util::effective_threads`). Each executor
    /// runs whole batches; the GEMM inside parallelizes further over the
    /// worker pool.
    pub executors: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch: 32, max_delay: Duration::from_millis(2), executors: 1 }
    }
}

struct Pending {
    data: Vec<f32>,
    arrived: Instant,
    tx: mpsc::Sender<Vec<f32>>,
}

/// The micro-batcher's telemetry handles for one model. Stage
/// histograms are recorded only for *answered* requests (a panicked
/// batch records nothing), so all five stages always carry the same
/// count and their sums stay coherent with the end-to-end totals.
pub struct ServeObs {
    /// queue_wait / coalesce / exec / epilogue / total, per request.
    pub spans: SpanSet,
    /// Requests currently waiting in the queue (decremented when an
    /// executor drains them into a batch).
    pub queue_depth: Arc<Gauge>,
    /// Coalesced batch sizes (unitless histogram).
    pub batch_size: Arc<Histogram>,
    /// Requests submitted.
    pub requests: Arc<Counter>,
    /// Batches whose coalesce window closed on the deadline rather than
    /// on a full batch.
    pub deadline_miss: Arc<Counter>,
    /// Batch forwards that panicked (their requests were dropped).
    pub panics: Arc<Counter>,
}

impl ServeObs {
    fn new(model: &str) -> ServeObs {
        let reg = crate::obs::registry();
        let l = |name: &str| with_labels(name, &[("model", model)]);
        ServeObs {
            spans: SpanSet::for_model(model),
            queue_depth: reg.gauge(&l("comq_serve_queue_depth")),
            batch_size: reg.histogram(&l("comq_serve_batch_size")),
            requests: reg.counter(&l("comq_serve_requests_total")),
            deadline_miss: reg.counter(&l("comq_serve_deadline_miss_total")),
            panics: reg.counter(&l("comq_serve_executor_panics_total")),
        }
    }
}

struct Shared {
    model: Arc<QuantizedModel>,
    side: usize,
    max_batch: usize,
    max_delay: Duration,
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    shutdown: AtomicBool,
    batches: AtomicUsize,
    served: AtomicUsize,
    /// Present only when telemetry was on when the server started.
    obs: Option<ServeObs>,
}

/// Cumulative queue counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeStats {
    /// Forward passes executed.
    pub batches: usize,
    /// Requests answered.
    pub served: usize,
}

/// A running micro-batched server over one quantized model.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start executor threads for `model`. Inputs are single images
    /// flattened to `img·img·3` f32s (the model's manifest geometry).
    pub fn start(model: Arc<QuantizedModel>, cfg: BatchConfig) -> Server {
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        let executors = if cfg.executors == 0 {
            // one batch in flight per ~4 pool threads keeps the GEMM fed
            // without oversubscribing it
            (crate::util::effective_threads() / 4).clamp(1, 4)
        } else {
            cfg.executors.min(crate::util::effective_threads())
        };
        let obs = crate::obs::enabled().then(|| ServeObs::new(&model.info().name));
        let shared = Arc::new(Shared {
            side: model.input_side(),
            max_batch: cfg.max_batch,
            max_delay: cfg.max_delay,
            model,
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            batches: AtomicUsize::new(0),
            served: AtomicUsize::new(0),
            obs,
        });
        let workers = (0..executors)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("comq-serve-{i}"))
                    .spawn(move || executor_loop(&sh))
                    .expect("spawning serve executor")
            })
            .collect();
        Server { shared, workers }
    }

    /// Enqueue one image; the receiver yields its logits row. Dropping
    /// the receiver abandons the request (the batch still runs).
    pub fn submit(&self, image: Vec<f32>) -> mpsc::Receiver<Vec<f32>> {
        let elems = self.shared.side * self.shared.side * 3;
        assert_eq!(image.len(), elems, "image must be img*img*3 f32s");
        let (tx, rx) = mpsc::channel();
        if let Some(o) = &self.shared.obs {
            o.requests.inc();
            o.queue_depth.inc();
        }
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Pending { data: image, arrived: Instant::now(), tx });
        }
        self.shared.cv.notify_one();
        rx
    }

    /// Blocking single-request inference. Errors if the server shut
    /// down first or the batch forward panicked (the executor survives
    /// a panic; only the affected batch's requests fail).
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(image)
            .recv()
            .map_err(|_| anyhow!("request dropped: server shut down or batch forward panicked"))
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            batches: self.shared.batches.load(Ordering::Relaxed),
            served: self.shared.served.load(Ordering::Relaxed),
        }
    }

    /// This server's telemetry handles (the same histograms the global
    /// registry exports), when `COMQ_OBS` was on at start.
    pub fn obs(&self) -> Option<&ServeObs> {
        self.shared.obs.as_ref()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn executor_loop(sh: &Shared) {
    let elems = sh.side * sh.side * 3;
    loop {
        // coalesce: wait for work, then until full / deadline / shutdown.
        // `missed` marks a window closed by the deadline rather than by
        // a full batch (shutdown drains don't count as misses).
        let (batch, missed): (Vec<Pending>, bool) = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if q.is_empty() {
                    if sh.shutdown.load(Ordering::Acquire) {
                        return;
                    }
                    // bounded wait so shutdown can't be missed
                    q = sh.cv.wait_timeout(q, Duration::from_millis(20)).unwrap().0;
                    continue;
                }
                let deadline = q.front().unwrap().arrived + sh.max_delay;
                let now = Instant::now();
                let full = q.len() >= sh.max_batch;
                if full || now >= deadline || sh.shutdown.load(Ordering::Acquire) {
                    let take = q.len().min(sh.max_batch);
                    break (q.drain(..take).collect(), !full && now >= deadline);
                }
                q = sh.cv.wait_timeout(q, deadline - now).unwrap().0;
            }
        };
        let b = batch.len();
        // Stamp the batch's stage boundaries only when telemetry is on.
        // Arrival times are copied out up front because the send loop
        // consumes the batch before the epilogue boundary is known.
        let t_drained = sh.obs.as_ref().map(|o| {
            o.queue_depth.add(-(b as i64));
            o.batch_size.record(b as u64);
            if missed {
                o.deadline_miss.inc();
            }
            Instant::now()
        });
        let arrivals: Vec<Instant> =
            if sh.obs.is_some() { batch.iter().map(|p| p.arrived).collect() } else { Vec::new() };
        let mut data = Vec::with_capacity(b * elems);
        for p in &batch {
            data.extend_from_slice(&p.data);
        }
        let t_built = t_drained.map(|_| Instant::now());
        // a panicking forward must not kill the executor — the queue
        // would fill forever behind a Server that still looks healthy.
        // Catch it, drop this batch's senders (their receivers observe
        // RecvError), and keep serving.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sh.model.forward(&Tensor::new(&[b, sh.side, sh.side, 3], data))
        }));
        match result {
            Ok(logits) => {
                let t_done = t_built.map(|_| Instant::now());
                let classes = logits.cols();
                for (i, p) in batch.into_iter().enumerate() {
                    // a dropped receiver is fine — the rest of the batch stands
                    let _ = p.tx.send(logits.data()[i * classes..(i + 1) * classes].to_vec());
                }
                sh.served.fetch_add(b, Ordering::Relaxed);
                // Record spans only for answered requests, all at once,
                // so every stage histogram carries the same count and
                // per-stage sums stay coherent with the totals.
                if let (Some(o), Some(ta), Some(tb), Some(td)) =
                    (&sh.obs, t_drained, t_built, t_done)
                {
                    let ts = Instant::now();
                    let ns = |d: std::time::Duration| d.as_nanos() as u64;
                    let n = b as u64;
                    o.spans.record_n(Stage::Coalesce, ns(tb.saturating_duration_since(ta)), n);
                    o.spans.record_n(Stage::Exec, ns(td.saturating_duration_since(tb)), n);
                    o.spans.record_n(Stage::Epilogue, ns(ts.saturating_duration_since(td)), n);
                    for a in &arrivals {
                        o.spans
                            .record(Stage::QueueWait, ns(ta.saturating_duration_since(*a)));
                        o.spans.record(Stage::Total, ns(ts.saturating_duration_since(*a)));
                    }
                }
            }
            Err(_) => {
                if let Some(o) = &sh.obs {
                    o.panics.inc();
                }
                crate::log_warn!(
                    "serve executor: batch forward panicked; {b} request(s) dropped"
                );
                drop(batch);
            }
        }
        sh.batches.fetch_add(1, Ordering::Relaxed);
    }
}
