//! Dynamic micro-batching request queue: single-image requests are
//! coalesced into batches up to `max_batch`, bounded by a latency
//! deadline measured from the oldest pending request. The classic
//! serving trade — batch-1 latency vs GEMM efficiency — made explicit:
//! under load the queue fills to `max_batch` before the deadline and the
//! i8 GEMM runs at full tilt; at low rate the deadline fires and a
//! request never waits more than `max_delay` for company.
//!
//! Executor threads both coalesce and run the forward (no separate
//! dispatcher), so with `executors > 1` the next batch assembles while
//! the previous one is still in the GEMM. Replies travel through a
//! per-request [`Responder`] (an mpsc channel for in-process callers, a
//! completion callback for the network tier), so batch composition
//! never affects who gets which logits.
//!
//! ## Robustness contract (PR 7)
//!
//! * **Per-request deadlines.** [`Server::submit_deadline`] carries an
//!   absolute deadline into the queue: an already-expired request is
//!   shed at submit, a request that expires while queued is shed at
//!   drain time — both answer `Err(DeadlineExceeded)` instead of
//!   burning a GEMM slot — and a pending deadline tightens the coalesce
//!   window so a tight-budget request is not held for company it cannot
//!   afford. Sheds count in `comq_serve_shed_total{model,reason}`.
//! * **Every request is answered.** A [`Responder`] that is dropped
//!   unanswered (a panic unwound through the executor) replies
//!   `Err(ExecutorPanicked)` from its `Drop` — no caller ever hangs on
//!   a reply that will not come.
//! * **Executors respawn.** A panic that escapes the per-batch guard
//!   (e.g. `COMQ_FAULT=panic:exec`) unwinds to a supervisor that counts
//!   it and re-enters the loop, so a poisoned request cannot
//!   permanently shrink exec capacity.
//! * **Shutdown is immediate.** The shutdown flag is flipped under the
//!   queue lock before the condvar broadcast, so an executor can never
//!   check the flag, miss the notify, and sleep — idle executors wake
//!   at once (the old code polled on a 20 ms timeout to paper over
//!   exactly this lost-wakeup race). Queued requests are still drained
//!   and answered before the executors exit.
//!
//! ## Pipelined execution (PR 10)
//!
//! With [`BatchConfig::pipeline`] on (CLI: `COMQ_PIPELINE=off|on|auto`)
//! the forward is cut along the model's stage plan
//! ([`QuantizedModel::stages`]) into contiguous *lanes*, each owned by
//! one thread: a head thread coalesces batches exactly like the classic
//! executor, then hands each batch down the lane chain, so batch A's
//! dense GEMM overlaps batch B's depthwise stage instead of serializing
//! behind one executor loop. Bit-identity is by construction — every
//! lane runs the same stage closures, in the same order per batch, that
//! the sequential forward folds over; only *which thread* runs a stage
//! changes. Lane queues are bounded (backpressure reaches the coalescer,
//! which is where the classic path applies it implicitly), shutdown
//! cascades a `Quit` marker down the chain after the last drained batch,
//! and a panicking stage drops the batch's [`Responder`]s, which answer
//! `Err(ExecutorPanicked)` with their terminal stamps already armed.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::obs::metrics::with_labels;
use crate::obs::recorder;
use crate::obs::trace::{self, TraceCtx};
use crate::obs::{Counter, Gauge, Histogram, SpanSet, Stage};
use crate::serve::net::fault;
use crate::serve::QuantizedModel;
use crate::tensor::Tensor;

/// Micro-batcher tuning.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Largest batch a single forward will see.
    pub max_batch: usize,
    /// Longest the oldest request may wait for the batch to fill.
    pub max_delay: Duration,
    /// Executor threads (0 = derive from the shared COMQ_THREADS
    /// parallelism knob, see `util::effective_threads`). Each executor
    /// runs whole batches; the GEMM inside parallelizes further over the
    /// worker pool. Ignored when `pipeline` is on — the pipeline has
    /// exactly one coalescing head plus its stage lanes.
    pub executors: usize,
    /// Run the forward as a pipeline of stage lanes (see the module
    /// docs). Off by default: every embedded caller keeps the classic
    /// single-loop executor unless it opts in; the `comq serve` CLI
    /// derives it from `COMQ_PIPELINE` via [`pipeline_from_env`].
    pub pipeline: bool,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_batch: 32,
            max_delay: Duration::from_millis(2),
            executors: 1,
            pipeline: false,
        }
    }
}

/// Resolve `COMQ_PIPELINE` for the serving CLI: `on`/`1` forces the
/// pipelined executor, `off`/`0` forces the classic loop, `auto` (or
/// unset) enables it exactly when the process has parallelism to spend
/// (`COMQ_THREADS=1` therefore reproduces the classic single-thread
/// behavior with no env gymnastics). Library callers who construct a
/// [`BatchConfig`] directly are unaffected.
pub fn pipeline_from_env() -> bool {
    let auto = crate::util::effective_threads() > 1;
    match std::env::var("COMQ_PIPELINE").ok().as_deref().map(str::trim) {
        Some("on") | Some("1") => true,
        Some("off") | Some("0") => false,
        None | Some("") | Some("auto") => auto,
        Some(other) => {
            crate::warn_once!("COMQ_PIPELINE='{other}' not off|on|auto; using auto");
            auto
        }
    }
}

/// Why a request was answered with an error instead of logits. The
/// wire protocol maps each variant onto a typed error frame
/// (`serve::net::frame::ErrorReason`), so clients can tell "back off"
/// from "give up".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// The request's deadline passed before the forward ran (shed at
    /// submit or at drain — either way no GEMM slot was spent on it).
    DeadlineExceeded,
    /// Admission control or queue-depth load shedding rejected the
    /// request up front; the client should back off and retry.
    Overloaded,
    /// The executor panicked with this request in flight.
    ExecutorPanicked,
    /// The server is draining and no longer accepts new requests.
    Shutdown,
}

impl ServeError {
    /// Stable label, used as the `reason` metric label and in error
    /// frames.
    pub fn name(&self) -> &'static str {
        match self {
            ServeError::DeadlineExceeded => "deadline",
            ServeError::Overloaded => "overload",
            ServeError::ExecutorPanicked => "panic",
            ServeError::Shutdown => "shutdown",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            ServeError::Overloaded => write!(f, "server overloaded, request shed"),
            ServeError::ExecutorPanicked => write!(f, "executor panicked on this batch"),
            ServeError::Shutdown => write!(f, "server shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a request resolves to: logits or a typed shed/failure reason.
pub type ServeResult = std::result::Result<Vec<f32>, ServeError>;

/// One request's reply path. Guarantees delivery: if the responder is
/// dropped unanswered (a panic unwound through the executor with the
/// batch in scope), `Drop` answers `Err(ExecutorPanicked)` so no caller
/// waits forever. The network tier leans on this — its per-connection
/// in-flight accounting is balanced inside the callback, so a lost
/// reply would wedge the drain.
pub struct Responder {
    f: Option<Box<dyn FnOnce(ServeResult) + Send + 'static>>,
    /// Terminal-stamp state for the drop path: the model's stage
    /// histograms plus the request's arrival, armed by the executor at
    /// drain time. A request answered from `Drop` used to vanish from
    /// the stage histograms entirely (the panic unwound before any
    /// boundary was stamped) — now the drop stamps a terminal mark
    /// *before* the error reply goes out, so `ExecutorPanicked` replies
    /// are visible in the latency percentiles. The whole elapsed time
    /// lands in `exec` (the stage the request died in; the drain
    /// boundary is lost in the unwind) with the other stages stamped 0,
    /// so the per-stage sums still telescope exactly to `total`.
    terminal: Option<(SpanSet, Instant)>,
}

impl Responder {
    pub fn new<F: FnOnce(ServeResult) + Send + 'static>(f: F) -> Responder {
        Responder { f: Some(Box::new(f)), terminal: None }
    }

    /// Arm the drop-path terminal stamp (executor, at drain time).
    fn arm_terminal(&mut self, spans: &SpanSet, arrived: Instant) {
        self.terminal = Some((spans.clone(), arrived));
    }

    /// Answer the request (consumes the responder). The normal path —
    /// the executor stamps this request's stages itself, so the
    /// terminal mark is disarmed.
    pub fn reply(mut self, r: ServeResult) {
        self.terminal = None;
        if let Some(f) = self.f.take() {
            f(r);
        }
    }
}

impl Drop for Responder {
    fn drop(&mut self) {
        if let Some(f) = self.f.take() {
            if let Some((spans, arrived)) = self.terminal.take() {
                let total =
                    Instant::now().saturating_duration_since(arrived).as_nanos() as u64;
                spans.record(Stage::QueueWait, 0);
                spans.record(Stage::Coalesce, 0);
                spans.record(Stage::Exec, total);
                spans.record(Stage::Epilogue, 0);
                spans.record(Stage::Total, total);
            }
            f(Err(ServeError::ExecutorPanicked));
        }
    }
}

struct Pending {
    data: Vec<f32>,
    arrived: Instant,
    /// Absolute per-request deadline; `None` = wait as long as it takes.
    deadline: Option<Instant>,
    /// End-to-end trace context, when the request is traced.
    trace: Option<TraceCtx>,
    respond: Responder,
}

/// The micro-batcher's telemetry handles for one model. Stage
/// histograms are recorded for every request that reaches an executor —
/// answered ones batch-wide on the normal path, panicked ones via the
/// [`Responder`] terminal mark — so all five stages carry coherent
/// counts and their sums telescope to the end-to-end totals.
pub struct ServeObs {
    /// queue_wait / coalesce / exec / epilogue / total, per request.
    pub spans: SpanSet,
    /// Requests currently waiting in the queue (decremented when an
    /// executor drains them into a batch).
    pub queue_depth: Arc<Gauge>,
    /// Coalesced batch sizes (unitless histogram; expired requests shed
    /// at drain are not part of the executed batch).
    pub batch_size: Arc<Histogram>,
    /// Requests submitted (including ones later shed).
    pub requests: Arc<Counter>,
    /// Batches whose coalesce window closed on a deadline rather than
    /// on a full batch.
    pub deadline_miss: Arc<Counter>,
    /// Executor panics — batch forwards that panicked plus panics that
    /// escaped to the respawn supervisor.
    pub panics: Arc<Counter>,
    /// Executor respawns after an escaped panic
    /// (`comq_serve_respawns_total{model}`, mirrors
    /// [`ServeStats::respawns`] into the registry export).
    pub respawns: Arc<Counter>,
    /// Requests shed before execution, deadline reason
    /// (`comq_serve_shed_total{model,reason="deadline"}`).
    pub shed_deadline: Arc<Counter>,
    /// Requests shed by admission control / queue-depth load shedding
    /// (`comq_serve_shed_total{model,reason="overload"}`, incremented by
    /// the network tier via [`Server::note_overload_shed`]).
    pub shed_overload: Arc<Counter>,
    /// Busy-lane count sampled at every pipeline dispatch
    /// (`comq_serve_pipeline_occupancy{model}`) — a full pipeline
    /// records `lanes` every time, an under-fed one records 1s. Empty
    /// unless the pipelined executor is on.
    pub pipe_occupancy: Arc<Histogram>,
}

impl ServeObs {
    fn new(model: &str) -> ServeObs {
        let reg = crate::obs::registry();
        let l = |name: &str| with_labels(name, &[("model", model)]);
        let shed = |reason: &str| {
            reg.counter(&with_labels(
                "comq_serve_shed_total",
                &[("model", model), ("reason", reason)],
            ))
        };
        ServeObs {
            spans: SpanSet::for_model(model),
            queue_depth: reg.gauge(&l("comq_serve_queue_depth")),
            batch_size: reg.histogram(&l("comq_serve_batch_size")),
            requests: reg.counter(&l("comq_serve_requests_total")),
            deadline_miss: reg.counter(&l("comq_serve_deadline_miss_total")),
            panics: reg.counter(&l("comq_serve_executor_panics_total")),
            respawns: reg.counter(&l("comq_serve_respawns_total")),
            shed_deadline: shed("deadline"),
            shed_overload: shed("overload"),
            pipe_occupancy: reg.histogram(&l("comq_serve_pipeline_occupancy")),
        }
    }
}

struct Shared {
    model: Arc<QuantizedModel>,
    side: usize,
    max_batch: usize,
    max_delay: Duration,
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    shutdown: AtomicBool,
    batches: AtomicUsize,
    served: AtomicUsize,
    /// Always-on queue depth (the obs gauge mirrors it when telemetry
    /// is on) — load shedding must work under `COMQ_OBS=off` too.
    depth: AtomicUsize,
    shed_deadline: AtomicUsize,
    shed_overload: AtomicUsize,
    /// Executor respawns after a panic escaped the per-batch guard.
    respawns: AtomicUsize,
    /// Present only when telemetry was on when the server started.
    obs: Option<ServeObs>,
}

impl Shared {
    fn note_deadline_shed(&self, n: usize) {
        self.shed_deadline.fetch_add(n, Ordering::Relaxed);
        if let Some(o) = &self.obs {
            o.shed_deadline.add(n as u64);
        }
    }
}

/// Most stage lanes a pipelined server will spawn — beyond this the
/// per-lane batches are too thin to cover the hand-off cost.
const MAX_LANES: usize = 8;

/// Bound on each lane's inbox. Small on purpose: once every lane holds
/// `LANE_CAP` batches the head blocks in [`PipeShared::send_work`], so
/// backpressure reaches the coalescer — the same place the classic path
/// applies it implicitly by running the forward on the coalescing
/// thread.
const LANE_CAP: usize = 4;

/// A coalesced batch traveling the lane chain: the activation plus
/// everything the epilogue needs (reply paths, trace ids, span
/// instants). The head moves each request's input bytes into the batch
/// tensor and leaves `Pending::data` empty, so an in-flight batch is
/// resident once, not twice.
struct StageBatch {
    /// Current activation; `take`n by each lane for the forward slice.
    h: Option<Tensor>,
    pending: Vec<Pending>,
    /// (trace id, arrival) per traced request.
    traced: Vec<(u64, Instant)>,
    /// Arrival instants (obs only — queue_wait/total spans).
    arrivals: Vec<Instant>,
    /// Requests in the batch.
    b: usize,
    t_drained: Option<Instant>,
    t_built: Option<Instant>,
}

enum LaneMsg {
    Work(Box<StageBatch>),
    Quit,
}

#[derive(Default)]
struct LaneQ {
    q: Mutex<VecDeque<LaneMsg>>,
    cv: Condvar,
}

/// The lane chain: one bounded inbox per lane plus the stage split.
struct PipeShared {
    lanes: Vec<LaneQ>,
    /// Half-open stage range each lane executes (`bounds[i] = (lo, hi)`,
    /// contiguous, covering the whole plan).
    bounds: Vec<(usize, usize)>,
    /// Lanes currently executing a slice (feeds the occupancy histogram).
    busy: AtomicUsize,
}

impl PipeShared {
    /// Enqueue a batch for `lane`, blocking while its inbox is full —
    /// the head's backpressure path.
    fn send_work(&self, lane: usize, sb: Box<StageBatch>) {
        let l = &self.lanes[lane];
        let mut q = l.q.lock().unwrap();
        while q.len() >= LANE_CAP {
            q = l.cv.wait(q).unwrap();
        }
        q.push_back(LaneMsg::Work(sb));
        drop(q);
        l.cv.notify_all();
    }

    /// Enqueue the shutdown marker unconditionally (it must never block
    /// behind the cap, or a full pipeline could deadlock the drain).
    fn send_quit(&self, lane: usize) {
        self.lanes[lane].q.lock().unwrap().push_back(LaneMsg::Quit);
        self.lanes[lane].cv.notify_all();
    }

    fn recv(&self, lane: usize) -> LaneMsg {
        let l = &self.lanes[lane];
        let mut q = l.q.lock().unwrap();
        loop {
            if let Some(m) = q.pop_front() {
                drop(q);
                // a slot freed: the blocked sender (head or upstream
                // lane) shares this condvar
                l.cv.notify_all();
                return m;
            }
            q = l.cv.wait(q).unwrap();
        }
    }
}

/// Cumulative queue counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeStats {
    /// Forward passes executed.
    pub batches: usize,
    /// Requests answered with logits.
    pub served: usize,
    /// Requests shed because their deadline passed before exec.
    pub shed_deadline: usize,
    /// Requests shed by admission control / queue-depth shedding
    /// (counted here when the network tier reports them).
    pub shed_overload: usize,
    /// Executor respawns after an escaped panic.
    pub respawns: usize,
}

/// A running micro-batched server over one quantized model.
pub struct Server {
    shared: Arc<Shared>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Server {
    /// Start executor threads for `model`. Inputs are single images
    /// flattened to `img·img·3` f32s (the model's manifest geometry).
    pub fn start(model: Arc<QuantizedModel>, cfg: BatchConfig) -> Server {
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        let executors = if cfg.executors == 0 {
            // one batch in flight per ~4 pool threads keeps the GEMM fed
            // without oversubscribing it
            (crate::util::effective_threads() / 4).clamp(1, 4)
        } else {
            cfg.executors.min(crate::util::effective_threads())
        };
        let obs = crate::obs::enabled().then(|| ServeObs::new(&model.info().name));
        let shared = Arc::new(Shared {
            side: model.input_side(),
            max_batch: cfg.max_batch,
            max_delay: cfg.max_delay,
            model,
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            batches: AtomicUsize::new(0),
            served: AtomicUsize::new(0),
            depth: AtomicUsize::new(0),
            shed_deadline: AtomicUsize::new(0),
            shed_overload: AtomicUsize::new(0),
            respawns: AtomicUsize::new(0),
            obs,
        });
        // Pipeline sizing: one lane per stage up to the parallelism
        // budget; fewer than two lanes is just the classic loop with
        // extra hand-offs, so fall back.
        let n_stages = shared.model.stages().len();
        let lanes = if cfg.pipeline {
            n_stages.min(crate::util::effective_threads()).min(MAX_LANES)
        } else {
            0
        };
        let workers = if lanes >= 2 {
            let bounds = (0..lanes)
                .map(|i| (i * n_stages / lanes, (i + 1) * n_stages / lanes))
                .collect();
            let ps = Arc::new(PipeShared {
                lanes: (0..lanes).map(|_| LaneQ::default()).collect(),
                bounds,
                busy: AtomicUsize::new(0),
            });
            let mut ws = Vec::with_capacity(lanes + 1);
            let (sh, p) = (shared.clone(), ps.clone());
            ws.push(
                std::thread::Builder::new()
                    .name("comq-serve-head".into())
                    .spawn(move || supervise(&sh, || pipeline_head_loop(&sh, &p)))
                    .expect("spawning pipeline head"),
            );
            for i in 0..lanes {
                let (sh, p) = (shared.clone(), ps.clone());
                ws.push(
                    std::thread::Builder::new()
                        .name(format!("comq-lane-{i}"))
                        .spawn(move || supervise(&sh, || lane_loop(&sh, &p, i)))
                        .expect("spawning pipeline lane"),
                );
            }
            ws
        } else {
            (0..executors)
                .map(|i| {
                    let sh = shared.clone();
                    std::thread::Builder::new()
                        .name(format!("comq-serve-{i}"))
                        .spawn(move || supervise(&sh, || executor_loop(&sh)))
                        .expect("spawning serve executor")
                })
                .collect()
        };
        Server { shared, workers: Mutex::new(workers) }
    }

    /// Enqueue one image with no deadline; the receiver yields its
    /// logits or a typed [`ServeError`]. Dropping the receiver abandons
    /// the request (the batch still runs).
    pub fn submit(&self, image: Vec<f32>) -> mpsc::Receiver<ServeResult> {
        self.submit_deadline(image, None)
    }

    /// Enqueue one image with an absolute deadline. If the deadline has
    /// already passed the request is shed immediately; if it passes
    /// while queued the request is shed at drain time — either way the
    /// receiver yields `Err(DeadlineExceeded)` and no GEMM slot is
    /// spent.
    pub fn submit_deadline(
        &self,
        image: Vec<f32>,
        deadline: Option<Instant>,
    ) -> mpsc::Receiver<ServeResult> {
        let (tx, rx) = mpsc::channel();
        self.submit_with(
            image,
            deadline,
            Responder::new(move |r| {
                let _ = tx.send(r);
            }),
        );
        rx
    }

    /// Enqueue one image, answering through `respond` — the zero-thread
    /// completion path the network tier uses (the executor invokes the
    /// callback after the forward; no per-request waiter blocks on a
    /// channel).
    pub fn submit_with(&self, image: Vec<f32>, deadline: Option<Instant>, respond: Responder) {
        self.submit_traced(image, deadline, None, respond);
    }

    /// [`Server::submit_with`] plus an end-to-end trace context: the id
    /// rides in the queue entry so the executor can cut per-stage and
    /// per-layer events for exactly this request.
    pub fn submit_traced(
        &self,
        image: Vec<f32>,
        deadline: Option<Instant>,
        trace: Option<TraceCtx>,
        respond: Responder,
    ) {
        let elems = self.shared.side * self.shared.side * 3;
        assert_eq!(image.len(), elems, "image must be img*img*3 f32s");
        if let Some(o) = &self.shared.obs {
            o.requests.inc();
        }
        if self.shared.shutdown.load(Ordering::Acquire) {
            respond.reply(Err(ServeError::Shutdown));
            return;
        }
        // pre-queue shed: an already-expired request never takes a slot
        if let Some(d) = deadline {
            if Instant::now() >= d {
                self.shared.note_deadline_shed(1);
                respond.reply(Err(ServeError::DeadlineExceeded));
                return;
            }
        }
        self.shared.depth.fetch_add(1, Ordering::Relaxed);
        if let Some(o) = &self.shared.obs {
            o.queue_depth.inc();
        }
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Pending { data: image, arrived: Instant::now(), deadline, trace, respond });
        }
        self.shared.cv.notify_one();
    }

    /// Blocking single-request inference. Errors carry the typed shed
    /// reason when the request was shed rather than executed.
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        match self.submit(image).recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(anyhow!(e)),
            Err(_) => Err(anyhow!("request dropped: server shut down")),
        }
    }

    /// Requests currently queued (always live, independent of
    /// `COMQ_OBS` — the load-shedding check in the network tier reads
    /// this).
    pub fn queue_depth(&self) -> usize {
        self.shared.depth.load(Ordering::Relaxed)
    }

    /// Record an admission-control / queue-depth shed against this
    /// model's counters (the shed itself happens in the network tier,
    /// before the request reaches the queue).
    pub fn note_overload_shed(&self) {
        self.shared.shed_overload.fetch_add(1, Ordering::Relaxed);
        if let Some(o) = &self.shared.obs {
            o.shed_overload.inc();
        }
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            batches: self.shared.batches.load(Ordering::Relaxed),
            served: self.shared.served.load(Ordering::Relaxed),
            shed_deadline: self.shared.shed_deadline.load(Ordering::Relaxed),
            shed_overload: self.shared.shed_overload.load(Ordering::Relaxed),
            respawns: self.shared.respawns.load(Ordering::Relaxed),
        }
    }

    /// The model this server executes.
    pub fn model(&self) -> &Arc<QuantizedModel> {
        &self.shared.model
    }

    /// This server's telemetry handles (the same histograms the global
    /// registry exports), when `COMQ_OBS` was on at start.
    pub fn obs(&self) -> Option<&ServeObs> {
        self.shared.obs.as_ref()
    }

    /// Graceful drain: stop accepting, answer everything queued, join
    /// the executors. Idempotent; `Drop` calls it. The shutdown flag is
    /// flipped *under the queue lock* before the broadcast so an
    /// executor that just found the queue empty cannot miss the wakeup
    /// and sleep through the drain (the executors block on a plain
    /// `Condvar::wait` — a lost notify here would hang forever, which
    /// is exactly what the shutdown-latency test would catch).
    pub fn shutdown(&self) {
        {
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
        }
        self.shared.cv.notify_all();
        let mut workers = self.workers.lock().unwrap();
        for w in workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Run an executor/head/lane loop, respawning it (in place, same OS
/// thread) when a panic escapes the per-batch guard — a single poisoned
/// request or an injected `COMQ_FAULT=panic:exec` must not permanently
/// shrink exec capacity (for a pipeline lane, an unrespawned panic
/// would wedge the whole chain). In-flight requests of the poisoned
/// iteration are answered `Err(ExecutorPanicked)` by their
/// [`Responder`] drops during the unwind.
fn supervise<F: Fn()>(sh: &Shared, run: F) {
    loop {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(&run)) {
            Ok(()) => return, // clean shutdown
            Err(_) => {
                sh.respawns.fetch_add(1, Ordering::Relaxed);
                if let Some(o) = &sh.obs {
                    o.panics.inc();
                    o.respawns.inc();
                }
                recorder::note(recorder::RecKind::Respawn, &sh.model.info().name);
                crate::log_warn!("serve executor: panic escaped the batch guard; respawning");
                // the black box shows what led up to the panic
                recorder::dump("executor respawn");
                // loop re-enters executor_loop: a shutdown in progress
                // still drains and returns cleanly from there
            }
        }
    }
}

/// Coalesce the next batch out of the shared queue — the one drain path
/// both the classic executor and the pipeline head run. Blocks for
/// work; closes the window on full / deadline / shutdown; decrements
/// the depth accounting; arms the drop-path terminal stamps; runs the
/// injected exec fault; sheds requests whose deadline passed while
/// queued. Returns the executable batch (possibly empty when everything
/// drained had expired) — `None` means shutdown with an empty queue and
/// the caller exits.
fn next_batch(sh: &Shared) -> Option<Vec<Pending>> {
    // coalesce: wait for work, then until full / deadline / shutdown.
    // The window is the oldest request's batching deadline tightened
    // by any queued per-request deadline (a tight-budget request
    // must not be held for company it cannot afford). `missed` marks
    // a window closed by a deadline rather than by a full batch
    // (shutdown drains don't count as misses).
    let (batch, missed): (Vec<Pending>, bool) = {
        let mut q = sh.queue.lock().unwrap();
        loop {
            if q.is_empty() {
                if sh.shutdown.load(Ordering::Acquire) {
                    return None;
                }
                // no timeout needed: push and shutdown both happen
                // under this mutex before their notify, so the
                // wakeup cannot be lost
                q = sh.cv.wait(q).unwrap();
                continue;
            }
            let window = coalesce_window(&q, sh.max_delay, sh.max_batch);
            let now = Instant::now();
            let full = q.len() >= sh.max_batch;
            if full || now >= window || sh.shutdown.load(Ordering::Acquire) {
                let take = q.len().min(sh.max_batch);
                break (q.drain(..take).collect(), !full && now >= window);
            }
            q = sh.cv.wait_timeout(q, window - now).unwrap().0;
        }
    };
    let mut batch = batch;
    let drained = batch.len();
    sh.depth.fetch_sub(drained, Ordering::Relaxed);
    if let Some(o) = &sh.obs {
        o.queue_depth.add(-(drained as i64));
        if missed {
            o.deadline_miss.inc();
        }
        // arm the drop-path terminal stamp before anything can
        // panic: a request answered by Responder::drop during an
        // unwind still lands in the stage histograms
        for p in &mut batch {
            p.respond.arm_terminal(&o.spans, p.arrived);
        }
    }
    // injected fault: a panic here escapes the per-batch guard below
    // and exercises the supervisor respawn (the batch's responders
    // answer ExecutorPanicked from their drops during the unwind)
    fault::maybe_panic(fault::Site::Exec);
    // pre-exec shed: anything whose deadline passed while queued is
    // answered DeadlineExceeded instead of burning a GEMM slot
    let now = Instant::now();
    let (batch, expired): (Vec<Pending>, Vec<Pending>) =
        batch.into_iter().partition(|p| p.deadline.map_or(true, |d| now < d));
    if !expired.is_empty() {
        sh.note_deadline_shed(expired.len());
        for p in expired {
            if let Some(c) = p.trace {
                // the traced view of a drain-time shed: the span
                // covers the whole doomed wait
                trace::event(c.id, "shed:deadline", p.arrived, now);
            }
            p.respond.reply(Err(ServeError::DeadlineExceeded));
        }
    }
    Some(batch)
}

/// Turn a drained batch into a [`StageBatch`]: stamp the stage
/// boundaries when telemetry is on or any request is traced — spans and
/// trace events are cut from the *same* instants, so a trace's stages
/// telescope exactly against the histogram sums — and concatenate the
/// request images into the batch tensor (moving, not copying: each
/// `Pending` is left with an empty data vec).
fn build_stage_batch(sh: &Shared, mut batch: Vec<Pending>) -> Box<StageBatch> {
    let b = batch.len();
    let traced: Vec<(u64, Instant)> = if trace::enabled() {
        batch.iter().filter_map(|p| p.trace.map(|c| (c.id, p.arrived))).collect()
    } else {
        Vec::new()
    };
    let need_t = sh.obs.is_some() || !traced.is_empty();
    if let Some(o) = &sh.obs {
        o.batch_size.record(b as u64);
    }
    let t_drained = need_t.then(Instant::now);
    let arrivals: Vec<Instant> =
        if sh.obs.is_some() { batch.iter().map(|p| p.arrived).collect() } else { Vec::new() };
    let elems = sh.side * sh.side * 3;
    let mut data = Vec::with_capacity(b * elems);
    for p in &mut batch {
        data.extend_from_slice(&p.data);
        p.data = Vec::new();
    }
    let t_built = need_t.then(Instant::now);
    Box::new(StageBatch {
        h: Some(Tensor::new(&[b, sh.side, sh.side, 3], data)),
        pending: batch,
        traced,
        arrivals,
        b,
        t_drained,
        t_built,
    })
}

fn ns(d: Duration) -> u64 {
    d.as_nanos() as u64
}

/// The epilogue: reply logits, count the batch, and stamp spans/trace
/// events against the boundaries carried in the [`StageBatch`]. Runs on
/// the classic executor after its forward, or on the *last* lane of the
/// pipeline — either way `Exec` spans `t_built → now`, so on the
/// pipelined path it covers the whole lane traversal (hand-off queueing
/// included), which is the honest per-request exec time.
fn finish_batch(sh: &Shared, sb: Box<StageBatch>, logits: &Tensor) {
    let sb = *sb;
    let b = sb.b;
    let need_t = sh.obs.is_some() || !sb.traced.is_empty();
    let t_done = need_t.then(Instant::now);
    let classes = logits.cols();
    for (i, p) in sb.pending.into_iter().enumerate() {
        // a dropped receiver is fine — the rest of the batch stands
        p.respond.reply(Ok(logits.data()[i * classes..(i + 1) * classes].to_vec()));
    }
    sh.served.fetch_add(b, Ordering::Relaxed);
    // epilogue closes here for spans and traces alike
    let t_sent = need_t.then(Instant::now);
    // Record spans for the whole answered batch at once, so every stage
    // histogram carries the same count and per-stage sums stay coherent
    // with the totals.
    if let (Some(o), Some(ta), Some(tb), Some(td), Some(ts)) =
        (&sh.obs, sb.t_drained, sb.t_built, t_done, t_sent)
    {
        let n = b as u64;
        o.spans.record_n(Stage::Coalesce, ns(tb.saturating_duration_since(ta)), n);
        o.spans.record_n(Stage::Exec, ns(td.saturating_duration_since(tb)), n);
        o.spans.record_n(Stage::Epilogue, ns(ts.saturating_duration_since(td)), n);
        for a in &sb.arrivals {
            o.spans.record(Stage::QueueWait, ns(ta.saturating_duration_since(*a)));
            o.spans.record(Stage::Total, ns(ts.saturating_duration_since(*a)));
        }
    }
    // the traced view of the same boundaries: four contiguous spans per
    // request, queue_wait → epilogue, telescoping exactly to
    // arrival → t_sent
    if let (Some(ta), Some(tb), Some(td), Some(ts)) = (sb.t_drained, sb.t_built, t_done, t_sent)
    {
        for (id, arrived) in &sb.traced {
            trace::event(*id, "queue_wait", *arrived, ta);
            trace::event(*id, "coalesce", ta, tb);
            trace::event(*id, "exec", tb, td);
            trace::event(*id, "epilogue", td, ts);
        }
    }
    sh.batches.fetch_add(1, Ordering::Relaxed);
}

/// The failure epilogue for a batch whose forward panicked (classic
/// executor or any pipeline lane): stamp the stages that really
/// happened — the epilogue never did (0), and the sums still telescope:
/// queue_wait+coalesce+exec = total — then answer every request
/// `ExecutorPanicked`.
fn fail_batch(sh: &Shared, sb: Box<StageBatch>) {
    let sb = *sb;
    let b = sb.b;
    let need_t = sh.obs.is_some() || !sb.traced.is_empty();
    let t_done = need_t.then(Instant::now);
    if let Some(o) = &sh.obs {
        o.panics.inc();
    }
    crate::log_warn!(
        "serve executor: batch forward panicked; {b} request(s) answered with error"
    );
    if let (Some(o), Some(ta), Some(tb), Some(td)) = (&sh.obs, sb.t_drained, sb.t_built, t_done)
    {
        let n = b as u64;
        o.spans.record_n(Stage::Coalesce, ns(tb.saturating_duration_since(ta)), n);
        o.spans.record_n(Stage::Exec, ns(td.saturating_duration_since(tb)), n);
        o.spans.record_n(Stage::Epilogue, 0, n);
        for a in &sb.arrivals {
            o.spans.record(Stage::QueueWait, ns(ta.saturating_duration_since(*a)));
            o.spans.record(Stage::Total, ns(td.saturating_duration_since(*a)));
        }
    }
    if let (Some(ta), Some(tb), Some(td)) = (sb.t_drained, sb.t_built, t_done) {
        for (id, arrived) in &sb.traced {
            trace::event(*id, "queue_wait", *arrived, ta);
            trace::event(*id, "coalesce", ta, tb);
            trace::event(*id, "exec_panic", tb, td);
        }
    }
    for p in sb.pending {
        p.respond.reply(Err(ServeError::ExecutorPanicked));
    }
    sh.batches.fetch_add(1, Ordering::Relaxed);
}

/// The classic executor: coalesce, run the whole stage plan, reply.
fn executor_loop(sh: &Shared) {
    let n_stages = sh.model.stages().len();
    loop {
        let Some(batch) = next_batch(sh) else { return };
        if batch.is_empty() {
            continue; // whole batch expired — nothing to execute
        }
        // injected fault: stretch the exec stage (overload / deadline
        // tests drive the shed paths with this)
        if let Some(d) = fault::slow_for(fault::Site::Exec) {
            std::thread::sleep(d);
        }
        let mut sb = build_stage_batch(sh, batch);
        // carry the traced ids into the per-layer exec hooks via the
        // executor thread (the layer has no other route back to its
        // requests)
        let ids: Vec<u64> = sb.traced.iter().map(|(id, _)| *id).collect();
        if !ids.is_empty() {
            trace::set_batch(&ids);
        }
        let h = sb.h.take().expect("fresh batch tensor");
        let items = sb.b as u64;
        // a panicking forward must not kill the executor — the queue
        // would fill forever behind a Server that still looks healthy.
        // Catch it, answer this batch's requests ExecutorPanicked, and
        // keep serving.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sh.model.forward_stages(0, n_stages, h, items)
        }));
        if !ids.is_empty() {
            trace::clear_batch();
        }
        match result {
            Ok(logits) => finish_batch(sh, sb, &logits),
            Err(_) => fail_batch(sh, sb),
        }
    }
}

/// The pipeline's coalescing head: same drain path as the classic
/// executor, but each built batch is handed to lane 0 instead of being
/// executed in place. On shutdown (queue fully drained) it starts the
/// `Quit` cascade down the lane chain.
fn pipeline_head_loop(sh: &Shared, ps: &PipeShared) {
    loop {
        let Some(batch) = next_batch(sh) else {
            ps.send_quit(0);
            return;
        };
        if batch.is_empty() {
            continue;
        }
        if let Some(d) = fault::slow_for(fault::Site::Exec) {
            std::thread::sleep(d);
        }
        ps.send_work(0, build_stage_batch(sh, batch));
    }
}

/// One pipeline lane: pull a batch, run this lane's stage slice with
/// the trace batch-context set on *this* thread (the per-layer exec
/// hooks read it thread-locally), pass the batch on — or, on the last
/// lane, run the shared epilogue. `Quit` is forwarded after all queued
/// work (FIFO), so shutdown still answers everything.
fn lane_loop(sh: &Shared, ps: &PipeShared, lane: usize) {
    let (lo, hi) = ps.bounds[lane];
    let last = lane + 1 == ps.lanes.len();
    let lane_nanos = sh.obs.as_ref().map(|_| {
        crate::obs::registry().histogram(&with_labels(
            "comq_serve_lane_seconds",
            &[("model", &sh.model.info().name), ("lane", &lane.to_string())],
        ))
    });
    loop {
        let mut sb = match ps.recv(lane) {
            LaneMsg::Quit => {
                if !last {
                    ps.send_quit(lane + 1);
                }
                return;
            }
            LaneMsg::Work(sb) => sb,
        };
        let busy = ps.busy.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(o) = &sh.obs {
            o.pipe_occupancy.record(busy as u64);
        }
        let ids: Vec<u64> = sb.traced.iter().map(|(id, _)| *id).collect();
        if !ids.is_empty() {
            trace::set_batch(&ids);
        }
        let t0 = Instant::now();
        let h = sb.h.take().expect("upstream lane left the activation");
        let items = sb.b as u64;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sh.model.forward_stages(lo, hi, h, items)
        }));
        let elapsed = t0.elapsed();
        if !ids.is_empty() {
            trace::clear_batch();
        }
        if let Some(hist) = &lane_nanos {
            hist.record(ns(elapsed));
        }
        ps.busy.fetch_sub(1, Ordering::Relaxed);
        match result {
            Ok(h) => {
                // the traced view of this lane's slice of the exec span
                for (id, _) in &sb.traced {
                    trace::event(*id, &format!("pipe:lane{lane}"), t0, t0 + elapsed);
                }
                if last {
                    finish_batch(sh, sb, &h);
                } else {
                    sb.h = Some(h);
                    ps.send_work(lane + 1, sb);
                }
            }
            Err(_) => fail_batch(sh, sb),
        }
    }
}

/// Earliest instant at which the pending batch must drain: the oldest
/// request's batching window, tightened by any per-request deadline in
/// the first `max_batch` entries (only those drain into this batch).
fn coalesce_window(q: &VecDeque<Pending>, max_delay: Duration, max_batch: usize) -> Instant {
    let mut window = q.front().expect("non-empty queue").arrived + max_delay;
    for p in q.iter().take(max_batch) {
        if let Some(d) = p.deadline {
            if d < window {
                window = d;
            }
        }
    }
    window
}
