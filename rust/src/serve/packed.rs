//! One-time weight prep: expand a `.cqm` layer's b-bit bitstream into
//! the K4-interleaved centered-i8 panel the serving GEMM streams, plus
//! the per-column integer sums and grid scalars its epilogue folds in.
//!
//! This is the only place codes are expanded, and they expand to i8 —
//! never to f32. An 8-bit panel is 4× smaller than the f32 weight
//! matrix, a 4-bit-sourced panel still 4× (codes widen to i8 for the
//! multiplier), so the serving working set stays a quarter of what
//! `eval::forward_native` touches per layer. The layout (k interleaved
//! in groups of 4 — see `serve::gemm::pack_panel_k4` and `util::simd`)
//! is kernel-independent: a panel packed here once serves the scalar,
//! AVX2 and VNNI kernels alike, so flipping `COMQ_KERNEL` at runtime
//! never forces a re-prep.

use anyhow::{bail, Result};

use crate::deploy::PackedLayer;
use crate::quant::actq::ActQuant;
use crate::serve::gemm::{gemm_i8_fused, pack_panel_k4, EpilogueCoeffs, QuantizedActs};
use crate::tensor::Tensor;

/// A layer's weights prepped for integer execution.
pub struct Int8Panel {
    /// Input features (the GEMM k extent).
    pub m: usize,
    /// Output columns.
    pub n: usize,
    /// Source code width.
    pub bits: u32,
    /// K4-interleaved strip-packed centered codes `u − 2^(bits−1)`
    /// (see gemm.rs).
    panel: Vec<i8>,
    /// Per-column sum of centered codes.
    csum: Vec<i32>,
    /// Per-column scale δ_j.
    delta: Vec<f32>,
    /// Per-column zero point z_j.
    zero: Vec<f32>,
}

impl Int8Panel {
    /// Decode the bitstream once (through the shared `grid` decoder,
    /// minus the f32 detour) and pack it.
    pub fn from_packed(pl: &PackedLayer) -> Result<Int8Panel> {
        if pl.bits < 1 || pl.bits > 8 {
            bail!("layer '{}': {} bits not servable as i8", pl.name, pl.bits);
        }
        let (m, n, bits) = (pl.m, pl.n, pl.bits as usize);
        if pl.delta.len() != n || pl.zero.len() != n {
            bail!("layer '{}': grid vectors don't match n={n}", pl.name);
        }
        if pl.codes.len() != (m * n * bits).div_ceil(8) {
            bail!("layer '{}': bitstream length {} for [{m}, {n}]@{bits}b", pl.name, pl.codes.len());
        }
        if m >= crate::serve::gemm::MAX_K {
            // fail at build time, not with the GEMM's assert mid-request
            bail!("layer '{}': m={m} exceeds the i32-accumulator bound ({})", pl.name, crate::serve::gemm::MAX_K);
        }
        let center = 1i32 << (bits - 1);
        let mut s = vec![0i8; m * n];
        let mut csum = vec![0i32; n];
        crate::quant::grid::for_each_code(&pl.codes, pl.bits, m * n, |idx, u| {
            let c = u as i32 - center;
            s[idx] = c as i8;
            csum[idx % n] += c;
        });
        Ok(Int8Panel {
            m,
            n,
            bits: pl.bits,
            panel: pack_panel_k4(&s, m, n),
            csum,
            delta: pl.delta.clone(),
            zero: pl.zero.clone(),
        })
    }

    pub(crate) fn panel(&self) -> &[i8] {
        &self.panel
    }

    /// `y = x@W (+ bias)` through the integer path: quantize `x` on the
    /// given activation grid, run the i8 GEMM, dequantize in the
    /// epilogue. The standalone form of an `Int8Layer` forward, exposed
    /// for benches and layer-level parity tests.
    pub fn matmul_i8(&self, x: &Tensor, aq: ActQuant, bias: Option<&[f32]>) -> Tensor {
        let rows = x.rows();
        assert_eq!(x.cols(), self.m, "input width vs layer m");
        let acts = QuantizedActs::quantize(x, aq);
        let co = self.coeffs(&acts.aq, bias);
        let mut out = Tensor::zeros(&[rows, self.n]);
        gemm_i8_fused(&acts, &self.panel, self.n, self.bits, &co, out.data_mut());
        out
    }

    /// Per-call epilogue coefficients for one activation grid. All
    /// inputs are exact integers (zero points are round()ed), so the f64
    /// arithmetic here is exact and the only rounding in the whole layer
    /// is the final f32 store. The activation offset is just `z_a` —
    /// the codes the GEMM consumes are the *unsigned* grid codes, so no
    /// activation centering needs undoing (the weight centering `c_w`
    /// still folds into `zc`/`fixed`).
    pub fn coeffs(&self, aq: &ActQuant, bias: Option<&[f32]>) -> EpilogueCoeffs {
        let cw = (1i64 << (self.bits - 1)) as f64;
        let a_off = aq.zero as f64;
        let sa = aq.scale as f64;
        let m = self.m as f64;
        let n = self.n;
        let mut scale = Vec::with_capacity(n);
        let mut zc = Vec::with_capacity(n);
        let mut fixed = Vec::with_capacity(n);
        let mut bv = Vec::with_capacity(n);
        for j in 0..n {
            let zcj = cw + self.zero[j] as f64;
            scale.push(sa * self.delta[j] as f64);
            zc.push(zcj);
            fixed.push(a_off * (self.csum[j] as f64 + m * zcj));
            bv.push(bias.map(|b| b[j] as f64).unwrap_or(0.0));
        }
        EpilogueCoeffs { scale, zc, fixed, bias: bv }
    }

    /// Serving-resident bytes (panel + column sums + grid scalars).
    pub fn resident_bytes(&self) -> usize {
        self.panel.len() + 4 * self.csum.len() + 8 * self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grid::LayerQuant;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn random_packed(rng: &mut Rng, m: usize, n: usize, bits: u32) -> (PackedLayer, LayerQuant) {
        let levels = (1u64 << bits) as usize;
        let zero: Vec<f32> = (0..n).map(|_| (rng.below(9) as f32) - 4.0).collect();
        let delta: Vec<f32> = (0..n).map(|_| rng.range_f32(0.02, 0.3)).collect();
        let mut q = Tensor::zeros(&[m, n]);
        for idx in 0..m * n {
            q.data_mut()[idx] = zero[idx % n] + rng.below(levels) as f32;
        }
        let lq = LayerQuant { q, delta, zero };
        let pl = PackedLayer::from_quant("t", &lq, bits);
        (pl, lq)
    }

    #[test]
    fn decode_agrees_with_unpack_codes() {
        let mut rng = Rng::new(21);
        for &bits in &[2u32, 3, 4, 8] {
            let (m, n) = (13, 7); // 91 codes — bitstream tail not word-aligned
            let (pl, lq) = random_packed(&mut rng, m, n, bits);
            let panel = Int8Panel::from_packed(&pl).unwrap();
            let center = (1i32 << (bits - 1)) as f32;
            // uncentered codes recovered from the K4-interleaved strips
            // must match the f32 unpack:
            // panel[strip][kk/4][l][kk%4] = s[kk][strip*NR+l]
            let nr = crate::tensor::NR;
            let k4 = crate::util::simd::K4;
            let kg = m.div_ceil(k4);
            for kk in 0..m {
                let (g, t) = (kk / k4, kk % k4);
                for j in 0..n {
                    let (strip, l) = (j / nr, j % nr);
                    let s = panel.panel()[strip * kg * nr * k4 + (g * nr + l) * k4 + t] as f32;
                    let u = lq.q.at2(kk, j) - lq.zero[j]; // unsigned code
                    assert_eq!(s + center, u, "bits={bits} ({kk},{j})");
                }
            }
            // column sums
            for j in 0..n {
                let want: i32 = (0..m)
                    .map(|kk| (lq.q.at2(kk, j) - lq.zero[j]) as i32 - (1i32 << (bits - 1)))
                    .sum();
                assert_eq!(panel.csum[j], want, "bits={bits} col {j}");
            }
            assert!(panel.resident_bytes() < 4 * m * n + 12 * n);
        }
    }

    #[test]
    fn rejects_malformed_layers() {
        let mut rng = Rng::new(22);
        let (mut pl, _) = random_packed(&mut rng, 4, 4, 4);
        pl.bits = 9;
        assert!(Int8Panel::from_packed(&pl).is_err());
        pl.bits = 4;
        pl.codes.pop();
        assert!(Int8Panel::from_packed(&pl).is_err());
    }
}
