//! One-time weight prep: expand a `.cqm` layer's b-bit bitstream into
//! the K4-interleaved centered-i8 panel the serving GEMM streams, plus
//! the per-column integer sums and grid scalars its epilogue folds in.
//!
//! This is the only place codes are expanded, and they expand to i8 —
//! never to f32. An 8-bit panel is 4× smaller than the f32 weight
//! matrix, a 4-bit-sourced panel still 4× (codes widen to i8 for the
//! multiplier), so the serving working set stays a quarter of what
//! `eval::forward_native` touches per layer. The layout (k interleaved
//! in groups of 4 — see `serve::gemm::pack_panel_k4` and `util::simd`)
//! is kernel-independent: a panel packed here once serves the scalar,
//! AVX2 and VNNI kernels alike, so flipping `COMQ_KERNEL` at runtime
//! never forces a re-prep.

use anyhow::{bail, Result};

use crate::deploy::PackedLayer;
use crate::quant::actq::ActQuant;
use crate::serve::gemm::{
    dwconv_i8_fused, gemm_i8_fused, gemm_i8_fused_sharded, pack_panel_k4, EpilogueCoeffs,
    GroupedQuantizedActs, PanelShard, QuantizedActs,
};
use crate::tensor::{Tensor, NR};
use crate::util::simd::K4;
use crate::util::{pool, topo};

/// A layer's weights prepped for integer execution.
pub struct Int8Panel {
    /// Input features (the GEMM k extent).
    pub m: usize,
    /// Output columns.
    pub n: usize,
    /// Source code width.
    pub bits: u32,
    /// K4-interleaved strip-packed centered codes `u − 2^(bits−1)`
    /// (see gemm.rs).
    panel: Vec<i8>,
    /// Per-column sum of centered codes.
    csum: Vec<i32>,
    /// Per-column scale δ_j.
    delta: Vec<f32>,
    /// Per-column zero point z_j.
    zero: Vec<f32>,
    /// Per-NUMA-node strip shards (empty on single-node layouts — the
    /// common case, where `panel` alone serves). Shard `i` holds a
    /// contiguous strip range first-touched on node `i`; the full
    /// contiguous `panel` stays authoritative for tests, the grouped
    /// path, and any future flat consumer.
    shards: Vec<PanelShard>,
}

impl Int8Panel {
    /// Decode the bitstream once (through the shared `grid` decoder,
    /// minus the f32 detour) and pack it.
    pub fn from_packed(pl: &PackedLayer) -> Result<Int8Panel> {
        if pl.bits < 1 || pl.bits > 8 {
            bail!("layer '{}': {} bits not servable as i8", pl.name, pl.bits);
        }
        let (m, n, bits) = (pl.m, pl.n, pl.bits as usize);
        if pl.delta.len() != n || pl.zero.len() != n {
            bail!("layer '{}': grid vectors don't match n={n}", pl.name);
        }
        if pl.codes.len() != (m * n * bits).div_ceil(8) {
            bail!("layer '{}': bitstream length {} for [{m}, {n}]@{bits}b", pl.name, pl.codes.len());
        }
        if m >= crate::serve::gemm::MAX_K {
            // fail at build time, not with the GEMM's assert mid-request
            bail!("layer '{}': m={m} exceeds the i32-accumulator bound ({})", pl.name, crate::serve::gemm::MAX_K);
        }
        let center = 1i32 << (bits - 1);
        let mut s = vec![0i8; m * n];
        let mut csum = vec![0i32; n];
        crate::quant::grid::for_each_code(&pl.codes, pl.bits, m * n, |idx, u| {
            let c = u as i32 - center;
            s[idx] = c as i8;
            csum[idx % n] += c;
        });
        let panel = pack_panel_k4(&s, m, n);
        let shards = build_shards(&panel, m, n);
        Ok(Int8Panel {
            m,
            n,
            bits: pl.bits,
            panel,
            csum,
            delta: pl.delta.clone(),
            zero: pl.zero.clone(),
            shards,
        })
    }

    pub(crate) fn panel(&self) -> &[i8] {
        &self.panel
    }

    /// Number of per-node shards (0 = flat single-node layout).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The serving GEMM over this panel: the NUMA-sharded entry when
    /// per-node shards exist, the flat entry otherwise. Outputs are
    /// bit-identical either way (exact integer accumulation over the
    /// same bytes in the same per-tile order); only memory locality
    /// differs. This is the one entry `Int8Layer::forward` calls.
    pub fn gemm(&self, acts: &QuantizedActs, co: &EpilogueCoeffs, out: &mut [f32]) {
        if self.shards.is_empty() {
            gemm_i8_fused(acts, &self.panel, self.n, self.bits, co, out);
        } else {
            gemm_i8_fused_sharded(acts, &self.shards, self.n, self.bits, co, out);
        }
    }

    /// `y = x@W (+ bias)` through the integer path: quantize `x` on the
    /// given activation grid, run the i8 GEMM, dequantize in the
    /// epilogue. The standalone form of an `Int8Layer` forward, exposed
    /// for benches and layer-level parity tests.
    pub fn matmul_i8(&self, x: &Tensor, aq: ActQuant, bias: Option<&[f32]>) -> Tensor {
        let rows = x.rows();
        assert_eq!(x.cols(), self.m, "input width vs layer m");
        let acts = QuantizedActs::quantize(x, aq);
        let co = self.coeffs(&acts.aq, bias);
        let mut out = Tensor::zeros(&[rows, self.n]);
        self.gemm(&acts, &co, out.data_mut());
        out
    }

    /// Per-call epilogue coefficients for one activation grid. All
    /// inputs are exact integers (zero points are round()ed), so the f64
    /// arithmetic here is exact and the only rounding in the whole layer
    /// is the final f32 store. The activation offset is just `z_a` —
    /// the codes the GEMM consumes are the *unsigned* grid codes, so no
    /// activation centering needs undoing (the weight centering `c_w`
    /// still folds into `zc`/`fixed`).
    pub fn coeffs(&self, aq: &ActQuant, bias: Option<&[f32]>) -> EpilogueCoeffs {
        let cw = (1i64 << (self.bits - 1)) as f64;
        let a_off = aq.zero as f64;
        let sa = aq.scale as f64;
        let m = self.m as f64;
        let n = self.n;
        let mut scale = Vec::with_capacity(n);
        let mut zc = Vec::with_capacity(n);
        let mut fixed = Vec::with_capacity(n);
        let mut bv = Vec::with_capacity(n);
        for j in 0..n {
            let zcj = cw + self.zero[j] as f64;
            scale.push(sa * self.delta[j] as f64);
            zc.push(zcj);
            fixed.push(a_off * (self.csum[j] as f64 + m * zcj));
            bv.push(bias.map(|b| b[j] as f64).unwrap_or(0.0));
        }
        EpilogueCoeffs { scale, zc, fixed, bias: bv }
    }

    /// Serving-resident bytes (panel + per-node shard copies + column
    /// sums + grid scalars). Shards are honest residency: a 2-node
    /// layout holds the panel bytes twice over (once flat, once split).
    pub fn resident_bytes(&self) -> usize {
        let shard_bytes: usize = self.shards.iter().map(|s| s.bytes.len()).sum();
        self.panel.len() + shard_bytes + 4 * self.csum.len() + 8 * self.n
    }
}

/// Split a packed panel's column strips into per-node contiguous shards
/// when `util::topo` reports a multi-node layout. Each shard's byte
/// copy is allocated inside a task hinted to its node, so first-touch
/// places the pages node-locally. Returns empty (no shards, flat
/// serving) on single-node layouts or panels too narrow to split.
fn build_shards(panel: &[i8], m: usize, n: usize) -> Vec<PanelShard> {
    let nodes = topo::nodes();
    let n_strips = n.div_ceil(NR);
    if nodes <= 1 || n_strips < 2 {
        return Vec::new();
    }
    let strip_len = m.div_ceil(K4) * NR * K4;
    let nodes = nodes.min(n_strips);
    let per = n_strips.div_ceil(nodes);
    let ranges: Vec<std::ops::Range<usize>> = (0..nodes)
        .map(|i| (i * per).min(n_strips)..((i + 1) * per).min(n_strips))
        .filter(|r| !r.is_empty())
        .collect();
    let slots: Vec<std::sync::Mutex<Option<PanelShard>>> =
        ranges.iter().map(|_| std::sync::Mutex::new(None)).collect();
    // One whole-shard task per node (min_per_task ≥ shard len keeps the
    // range unsplit): the to_vec() below is the first touch.
    pool::parallel_sharded(&ranges, n_strips, |si, r| {
        let bytes = panel[r.start * strip_len..r.end * strip_len].to_vec();
        *slots[si].lock().unwrap() = Some(PanelShard { strips: r, bytes });
    });
    slots.into_iter().map(|s| s.into_inner().unwrap().expect("shard task ran")).collect()
}

/// A grouped (depthwise) layer's weights prepped for integer execution:
/// the `.cqm` layer is [k·k, c] with one weight column per group, and
/// the panel is the same per-group k·k-column strip layout as the dense
/// prep — `pack_panel_k4` over [kk, c] — with the per-column code sums
/// the grouped epilogue folds in. The prep is shared with [`Int8Panel`]
/// (one decode path, one layout); only execution differs: the grouped
/// kernel dots each strip lane against its *own* activation patch
/// (`serve::gemm::dwconv_i8_fused`) instead of broadcasting one
/// activation row across the strip.
pub struct GroupedPanel {
    inner: Int8Panel,
}

impl GroupedPanel {
    /// Decode and pack a grouped `.cqm` layer (m = k·k patch length,
    /// n = groups). Same one-time prep and validation as the dense path.
    pub fn from_packed(pl: &PackedLayer) -> Result<GroupedPanel> {
        Ok(GroupedPanel { inner: Int8Panel::from_packed(pl)? })
    }

    /// Patch length per group (k·k).
    pub fn kk(&self) -> usize {
        self.inner.m
    }

    /// Number of groups (channels).
    pub fn channels(&self) -> usize {
        self.inner.n
    }

    pub fn bits(&self) -> u32 {
        self.inner.bits
    }

    pub(crate) fn panel(&self) -> &[i8] {
        self.inner.panel()
    }

    /// Per-group epilogue coefficients — the dense derivation with
    /// `m = k·k` (see [`Int8Panel::coeffs`]); the per-row activation sum
    /// it pairs with becomes per-(row, group) at execution time.
    pub fn coeffs(&self, aq: &ActQuant, bias: Option<&[f32]>) -> EpilogueCoeffs {
        self.inner.coeffs(aq, bias)
    }

    /// Depthwise conv over grouped patches x3 [rows, c, kk] entirely on
    /// the integer path: quantize+pack the patches on the given grid,
    /// run the grouped kernel, dequantize in the epilogue. Returns
    /// [rows, c]. The standalone form of a grouped layer forward,
    /// exposed for benches and layer-level parity tests.
    pub fn conv_i8(&self, x3: &Tensor, aq: ActQuant, bias: Option<&[f32]>) -> Tensor {
        assert_eq!(x3.ndim(), 3, "grouped input must be [rows, c, kk]");
        let (rows, c, kk) = (x3.shape()[0], x3.shape()[1], x3.shape()[2]);
        assert_eq!(c, self.channels(), "input groups vs layer channels");
        assert_eq!(kk, self.kk(), "patch length vs layer k·k");
        let acts = GroupedQuantizedActs::quantize(x3, aq);
        let co = self.coeffs(&acts.aq, bias);
        let mut out = Tensor::zeros(&[rows, c]);
        dwconv_i8_fused(&acts, self.panel(), c, self.bits(), &co, out.data_mut());
        out
    }

    /// Serving-resident bytes (panel + column sums + grid scalars).
    pub fn resident_bytes(&self) -> usize {
        self.inner.resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grid::LayerQuant;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn random_packed(rng: &mut Rng, m: usize, n: usize, bits: u32) -> (PackedLayer, LayerQuant) {
        let levels = (1u64 << bits) as usize;
        let zero: Vec<f32> = (0..n).map(|_| (rng.below(9) as f32) - 4.0).collect();
        let delta: Vec<f32> = (0..n).map(|_| rng.range_f32(0.02, 0.3)).collect();
        let mut q = Tensor::zeros(&[m, n]);
        for idx in 0..m * n {
            q.data_mut()[idx] = zero[idx % n] + rng.below(levels) as f32;
        }
        let lq = LayerQuant { q, delta, zero };
        let pl = PackedLayer::from_quant("t", &lq, bits);
        (pl, lq)
    }

    #[test]
    fn decode_agrees_with_unpack_codes() {
        let mut rng = Rng::new(21);
        for &bits in &[2u32, 3, 4, 8] {
            let (m, n) = (13, 7); // 91 codes — bitstream tail not word-aligned
            let (pl, lq) = random_packed(&mut rng, m, n, bits);
            let panel = Int8Panel::from_packed(&pl).unwrap();
            let center = (1i32 << (bits - 1)) as f32;
            // uncentered codes recovered from the K4-interleaved strips
            // must match the f32 unpack:
            // panel[strip][kk/4][l][kk%4] = s[kk][strip*NR+l]
            let nr = crate::tensor::NR;
            let k4 = crate::util::simd::K4;
            let kg = m.div_ceil(k4);
            for kk in 0..m {
                let (g, t) = (kk / k4, kk % k4);
                for j in 0..n {
                    let (strip, l) = (j / nr, j % nr);
                    let s = panel.panel()[strip * kg * nr * k4 + (g * nr + l) * k4 + t] as f32;
                    let u = lq.q.at2(kk, j) - lq.zero[j]; // unsigned code
                    assert_eq!(s + center, u, "bits={bits} ({kk},{j})");
                }
            }
            // column sums
            for j in 0..n {
                let want: i32 = (0..m)
                    .map(|kk| (lq.q.at2(kk, j) - lq.zero[j]) as i32 - (1i32 << (bits - 1)))
                    .sum();
                assert_eq!(panel.csum[j], want, "bits={bits} col {j}");
            }
            assert!(panel.resident_bytes() < 4 * m * n + 12 * n);
        }
    }

    #[test]
    fn grouped_panel_shares_the_dense_prep() {
        let mut rng = Rng::new(23);
        for &bits in &[2u32, 4, 8] {
            let (kk, c) = (9, 21); // kk % 4 ≠ 0, c % NR ≠ 0
            let (pl, lq) = random_packed(&mut rng, kk, c, bits);
            let gp = GroupedPanel::from_packed(&pl).unwrap();
            let dense = Int8Panel::from_packed(&pl).unwrap();
            assert_eq!((gp.kk(), gp.channels(), gp.bits()), (kk, c, bits));
            assert_eq!(gp.panel(), dense.panel(), "bits={bits}: one prep, one layout");
            assert_eq!(gp.resident_bytes(), dense.resident_bytes());
            // integer conv of a single patch row matches the dequantized
            // f32 dot per group
            let mut x3 = Tensor::zeros(&[2, c, kk]);
            for v in x3.data_mut() {
                *v = rng.range_f32(-1.0, 1.0);
            }
            let aq = crate::quant::actq::ActQuant::from_range(-1.0, 1.0, 8, 1.0);
            let y = gp.conv_i8(&x3, aq, None);
            let wq = lq.dequant(); // [kk, c]
            for r in 0..2 {
                for j in 0..c {
                    let mut want = 0.0f64;
                    for p in 0..kk {
                        want +=
                            aq.apply(x3.data()[(r * c + j) * kk + p]) as f64 * wq.at2(p, j) as f64;
                    }
                    let got = y.at2(r, j) as f64;
                    assert!(
                        (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                        "bits={bits} r={r} j={j}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn rejects_malformed_layers() {
        let mut rng = Rng::new(22);
        let (mut pl, _) = random_packed(&mut rng, 4, 4, 4);
        pl.bits = 9;
        assert!(Int8Panel::from_packed(&pl).is_err());
        pl.bits = 4;
        pl.codes.pop();
        assert!(Int8Panel::from_packed(&pl).is_err());
    }
}
