//! `QuantizedModel`: a `.cqm` checkpoint prepped for integer execution,
//! plus the process-wide load-once registry (the serving analogue of
//! `runtime::Engine`'s compile cache).
//!
//! Quantizable linear layers run through the i8 GEMM via the
//! `model::LayerExec` override — their f32 weights are never
//! materialized. Depthwise (grouped) layers and layers kept in full
//! precision fall back to the f32 forward; when an activation grid is
//! known their inputs are fake-quantized so the whole network matches
//! the W/A-quantized reference bit-for-argmax.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Result};

use crate::deploy::{self, PackedLayer};
use crate::manifest::{Manifest, ModelConfig, ModelInfo};
use crate::model::{LayerExec, Model, Tap};
use crate::quant::actq::ActQuant;
use crate::serve::gemm::{gemm_i8_fused, EpilogueCoeffs, QuantizedActs};
use crate::serve::packed::Int8Panel;
use crate::tensor::Tensor;

/// Activation bits assumed when a checkpoint carries no calibrated
/// activation grid (dynamic per-batch quantization).
pub const DEFAULT_ACT_BITS: u32 = 8;

/// Where each layer's activation grid comes from at serve time.
#[derive(Debug, Clone)]
pub enum ActSource {
    /// Calibrated (scale, zero) per layer, stored in the checkpoint or
    /// handed over by the pipeline — required for exact parity with the
    /// fake-quant reference.
    Static { bits: u32, by_layer: BTreeMap<String, ActQuant> },
    /// Derive the grid from each batch's (min, max) — standard dynamic
    /// quantization for checkpoints without calibrated scales.
    Dynamic { bits: u32 },
}

impl ActSource {
    pub fn bits(&self) -> u32 {
        match self {
            ActSource::Static { bits, .. } | ActSource::Dynamic { bits } => *bits,
        }
    }
}

/// One i8-served layer: prepped panel + bias; with a static activation
/// grid the per-column epilogue coefficients are derived once at build
/// time instead of on every request.
pub struct Int8Layer {
    panel: Int8Panel,
    bias: Option<Vec<f32>>,
    static_co: Option<(ActQuant, EpilogueCoeffs)>,
}

impl Int8Layer {
    /// y = x@W + b entirely in integer arithmetic (x [rows, m]),
    /// through whichever SIMD kernel `util::simd::Kernel::active`
    /// dispatches for this call (the K4 panel layout is
    /// kernel-independent, so `COMQ_KERNEL` can change between
    /// requests without re-prepping). `aq` is only consulted on the
    /// dynamic path; the static path uses the grid the coefficients
    /// were built from.
    fn forward(&self, x: &Tensor, aq: ActQuant) -> Tensor {
        match &self.static_co {
            Some((saq, co)) => {
                let acts = QuantizedActs::quantize(x, *saq);
                let mut out = Tensor::zeros(&[x.rows(), self.panel.n]);
                gemm_i8_fused(
                    &acts,
                    self.panel.panel(),
                    self.panel.n,
                    self.panel.bits,
                    co,
                    out.data_mut(),
                );
                out
            }
            None => self.panel.matmul_i8(x, aq, self.bias.as_deref()),
        }
    }
}

/// A packed checkpoint ready to serve.
pub struct QuantizedModel {
    /// Architecture + every parameter that still runs in f32 (biases,
    /// norms, depthwise weights, kept-FP layers). Has NO `{l}/W` entry
    /// for i8-served layers.
    base: Model,
    int8: BTreeMap<String, Int8Layer>,
    act: ActSource,
    weight_bits: u32,
    quantizable: BTreeSet<String>,
}

impl QuantizedModel {
    /// Build from in-memory parts. `params` must hold every non-packed
    /// parameter (the pipeline passes the dequantized model's map; the
    /// loader passes the checkpoint's `fp/` entries). Packed weights of
    /// non-grouped layers are prepped to i8 and their f32 `{l}/W`
    /// entries dropped; grouped layers are dequantized into `params`.
    pub fn from_parts(
        info: ModelInfo,
        mut params: BTreeMap<String, Tensor>,
        packed: &[PackedLayer],
        act: ActSource,
    ) -> Result<QuantizedModel> {
        // fail at build time, not with an assert mid-request
        if act.bits() < 1 || act.bits() > 8 {
            bail!("activation bits {} not servable as i8 (need 1..=8)", act.bits());
        }
        let grouped: BTreeSet<&str> = info
            .quant_layers
            .iter()
            .filter(|l| l.grouped)
            .map(|l| l.name.as_str())
            .collect();
        let known: BTreeSet<&str> = info.quant_layers.iter().map(|l| l.name.as_str()).collect();
        let mut int8 = BTreeMap::new();
        let mut weight_bits = 0;
        for pl in packed {
            if !known.contains(pl.name.as_str()) {
                bail!("packed layer '{}' not in model '{}'", pl.name, info.name);
            }
            weight_bits = weight_bits.max(pl.bits);
            if grouped.contains(pl.name.as_str()) {
                // depthwise runs f32 (k·k×c weights — memory-trivial)
                params.entry(format!("{}/W", pl.name)).or_insert_with(|| pl.dequant());
            } else {
                let panel = Int8Panel::from_packed(pl)?;
                let bias = params.get(&format!("{}/b", pl.name)).map(|t| t.data().to_vec());
                let static_co = match &act {
                    ActSource::Static { by_layer, .. } => by_layer
                        .get(&pl.name)
                        .map(|aq| (*aq, panel.coeffs(aq, bias.as_deref()))),
                    ActSource::Dynamic { .. } => None,
                };
                int8.insert(pl.name.clone(), Int8Layer { panel, bias, static_co });
                params.remove(&format!("{}/W", pl.name));
            }
        }
        // completeness: every canonical parameter is either present in
        // f32 or covered by an i8 panel
        for p in &info.params {
            if !params.contains_key(p) {
                let covered =
                    p.strip_suffix("/W").map(|l| int8.contains_key(l)).unwrap_or(false);
                if !covered {
                    bail!("missing parameter '{p}' (neither packed nor FP)");
                }
            }
        }
        let quantizable = info.quant_layers.iter().map(|l| l.name.clone()).collect();
        Ok(QuantizedModel {
            base: Model { info, params },
            int8,
            act,
            weight_bits,
            quantizable,
        })
    }

    /// Load a `.cqm` checkpoint for serving (manifest supplies the
    /// architecture). Falls back to dynamic activation quantization when
    /// the checkpoint stores no calibrated grid.
    pub fn load(manifest: &Manifest, model_name: &str, path: &str) -> Result<QuantizedModel> {
        let ck = deploy::read_packed(path)?;
        let info = manifest.model(model_name)?.clone();
        let act = match ck.act {
            Some(a) => ActSource::Static { bits: a.bits, by_layer: a.by_layer },
            None => ActSource::Dynamic { bits: DEFAULT_ACT_BITS },
        };
        QuantizedModel::from_parts(info, ck.fp, &ck.layers, act)
    }

    /// Integer forward: x [b, img, img, 3] -> logits [b, classes].
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let mut tap = Tap::Exec(self);
        self.base.forward(x, &mut tap)
    }

    pub fn info(&self) -> &ModelInfo {
        &self.base.info
    }

    pub fn classes(&self) -> usize {
        match &self.base.info.config {
            ModelConfig::ViT(c) => c.classes,
            ModelConfig::Cnn(c) => c.classes,
        }
    }

    pub fn input_side(&self) -> usize {
        match &self.base.info.config {
            ModelConfig::ViT(c) => c.img,
            ModelConfig::Cnn(c) => c.img,
        }
    }

    /// Number of layers served through the i8 GEMM.
    pub fn int8_layers(&self) -> usize {
        self.int8.len()
    }

    pub fn weight_bits(&self) -> u32 {
        self.weight_bits
    }

    pub fn act_source(&self) -> &ActSource {
        &self.act
    }

    /// Serving-resident bytes of the i8 panels (the f32 weights these
    /// replace would be `4·m·n` each).
    pub fn resident_bytes(&self) -> usize {
        self.int8.values().map(|l| l.panel.resident_bytes()).sum()
    }

    fn act_for(&self, name: &str, x: &Tensor) -> ActQuant {
        match &self.act {
            ActSource::Static { bits, by_layer } => by_layer
                .get(name)
                .copied()
                .unwrap_or_else(|| ActQuant::from_tensor(x, *bits)),
            ActSource::Dynamic { bits } => ActQuant::from_tensor(x, *bits),
        }
    }
}

impl LayerExec for QuantizedModel {
    fn exec_linear(&self, name: &str, x: &Tensor) -> Option<Tensor> {
        let layer = self.int8.get(name)?;
        Some(layer.forward(x, self.act_for(name, x)))
    }

    fn tap_input(&self, name: &str, x: Tensor) -> Tensor {
        // i8-owned layers quantize internally; non-quantizable layers
        // pass through; quantizable fallbacks (depthwise, kept-FP) get
        // fake-quantized so the network matches the W/A reference.
        if self.int8.contains_key(name) || !self.quantizable.contains(name) {
            return x;
        }
        let aq = self.act_for(name, &x);
        let mut x = x;
        aq.apply_tensor(&mut x);
        x
    }
}

// ---------------------------------------------------------------------------
// Registry: load each checkpoint once per process
// ---------------------------------------------------------------------------

static REGISTRY: OnceLock<Mutex<HashMap<String, Arc<QuantizedModel>>>> = OnceLock::new();

fn registry() -> &'static Mutex<HashMap<String, Arc<QuantizedModel>>> {
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Load a checkpoint through the process-wide registry: the decode +
/// panel prep runs once per (model, path); every later caller gets the
/// same `Arc`. The serving analogue of `runtime::Engine`'s compile
/// cache.
pub fn load_cached(
    manifest: &Manifest,
    model_name: &str,
    path: &str,
) -> Result<Arc<QuantizedModel>> {
    let key = format!("{model_name}@{path}");
    if let Some(m) = registry().lock().unwrap().get(&key) {
        return Ok(m.clone());
    }
    // prep outside the lock (it can be slow); a racing double-load is
    // benign — first insert wins
    let qm = Arc::new(QuantizedModel::load(manifest, model_name, path)?);
    let mut reg = registry().lock().unwrap();
    Ok(reg.entry(key).or_insert(qm).clone())
}

/// Checkpoints currently cached (diagnostics / tests).
pub fn registry_len() -> usize {
    REGISTRY.get().map(|r| r.lock().unwrap().len()).unwrap_or(0)
}
