//! `QuantizedModel`: a `.cqm` checkpoint prepped for integer execution,
//! plus the process-wide load-once registry (the serving analogue of
//! `runtime::Engine`'s compile cache).
//!
//! Quantizable layers run through integer execution via the
//! `model::LayerExec` override — their f32 weights are never
//! materialized. Dense linears go through the i8 GEMM; depthwise
//! (grouped) layers go through the grouped per-lane kernel
//! (`serve::gemm::dwconv_i8_fused`), so a MobileNet-style CNN is served
//! with no f32 weight anywhere. Only layers kept in full precision
//! (skip-layers) fall back to the f32 forward; when an activation grid
//! is known their inputs are fake-quantized so the whole network
//! matches the W/A-quantized reference bit-for-argmax.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::deploy::{self, PackedLayer};
use crate::manifest::{Manifest, ModelConfig, ModelInfo};
use crate::model::{LayerExec, Model, Stage, Tap};
use crate::obs::metrics::with_labels;
use crate::obs::recorder::{self, RecKind};
use crate::obs::{span, trace, Counter, Histogram};
use crate::quant::actq::ActQuant;
use crate::serve::gemm::{
    dwconv_i8_fused, EpilogueCoeffs, GroupedQuantizedActs, QuantizedActs,
};
use crate::serve::packed::{GroupedPanel, Int8Panel};
use crate::tensor::Tensor;
use crate::tensorstore::Integrity;

/// Activation bits assumed when a checkpoint carries no calibrated
/// activation grid (dynamic per-batch quantization).
pub const DEFAULT_ACT_BITS: u32 = 8;

/// Where each layer's activation grid comes from at serve time.
#[derive(Debug, Clone)]
pub enum ActSource {
    /// Calibrated (scale, zero) per layer, stored in the checkpoint or
    /// handed over by the pipeline — required for exact parity with the
    /// fake-quant reference.
    Static { bits: u32, by_layer: BTreeMap<String, ActQuant> },
    /// Derive the grid from each batch's (min, max) — standard dynamic
    /// quantization for checkpoints without calibrated scales.
    Dynamic { bits: u32 },
}

impl ActSource {
    pub fn bits(&self) -> u32 {
        match self {
            ActSource::Static { bits, .. } | ActSource::Dynamic { bits } => *bits,
        }
    }
}

/// One i8-served layer: prepped panel + bias; with a static activation
/// grid the per-column epilogue coefficients are derived once at build
/// time instead of on every request.
pub struct Int8Layer {
    panel: Int8Panel,
    bias: Option<Vec<f32>>,
    static_co: Option<(ActQuant, EpilogueCoeffs)>,
}

impl Int8Layer {
    /// y = x@W + b entirely in integer arithmetic (x [rows, m]),
    /// through whichever SIMD kernel `util::simd::Kernel::active`
    /// dispatches for this call (the K4 panel layout is
    /// kernel-independent, so `COMQ_KERNEL` can change between
    /// requests without re-prepping). `aq` is only consulted on the
    /// dynamic path; the static path uses the grid the coefficients
    /// were built from.
    fn forward(&self, x: &Tensor, aq: ActQuant) -> Tensor {
        match &self.static_co {
            Some((saq, co)) => {
                let acts = QuantizedActs::quantize(x, *saq);
                let mut out = Tensor::zeros(&[x.rows(), self.panel.n]);
                // `Int8Panel::gemm` dispatches flat vs NUMA-sharded;
                // both reduce identically, so the grid the coefficients
                // were built from is the only thing that matters here.
                self.panel.gemm(&acts, co, out.data_mut());
                out
            }
            None => self.panel.matmul_i8(x, aq, self.bias.as_deref()),
        }
    }
}

/// One grouped (depthwise) layer served integer: prepped grouped panel
/// + bias, with the same static-grid coefficient caching as
/// [`Int8Layer`].
pub struct GroupedInt8Layer {
    panel: GroupedPanel,
    bias: Option<Vec<f32>>,
    static_co: Option<(ActQuant, EpilogueCoeffs)>,
}

impl GroupedInt8Layer {
    /// Per-group conv + bias over grouped patches x3 [rows, c, kk],
    /// entirely in integer arithmetic. Same dispatch/static-grid
    /// contract as [`Int8Layer::forward`].
    fn forward(&self, x3: &Tensor, aq: ActQuant) -> Tensor {
        match &self.static_co {
            Some((saq, co)) => {
                let acts = GroupedQuantizedActs::quantize(x3, *saq);
                let c = self.panel.channels();
                let mut out = Tensor::zeros(&[x3.shape()[0], c]);
                dwconv_i8_fused(&acts, self.panel.panel(), c, self.panel.bits(), co, out.data_mut());
                out
            }
            None => self.panel.conv_i8(x3, aq, self.bias.as_deref()),
        }
    }
}

/// Per-layer execution telemetry for one model: exec counters (in
/// *images* — a batch of b counts b per layer, so "layers × requests"
/// holds regardless of coalescing) and per-call exec-time histograms,
/// plus model-wide fallback and image counters. Built only when
/// `COMQ_OBS` is on at load time; `None` costs nothing per request.
pub struct ModelObs {
    layers: BTreeMap<String, LayerObs>,
    fallback: Arc<Counter>,
    images: Arc<Counter>,
}

struct LayerObs {
    execs: Arc<Counter>,
    nanos: Arc<Histogram>,
}

impl ModelObs {
    fn new(
        model: &str,
        dense: &BTreeMap<String, Int8Layer>,
        grouped: &BTreeMap<String, GroupedInt8Layer>,
    ) -> ModelObs {
        let reg = crate::obs::registry();
        let mut layers = BTreeMap::new();
        let mut add = |name: &str, kind: &str| {
            let labels = [("model", model), ("layer", name), ("kind", kind)];
            layers.insert(
                name.to_string(),
                LayerObs {
                    execs: reg.counter(&with_labels("comq_serve_layer_exec_total", &labels)),
                    nanos: reg
                        .histogram(&with_labels("comq_serve_layer_exec_seconds", &labels)),
                },
            );
        };
        for name in dense.keys() {
            add(name, "dense");
        }
        for name in grouped.keys() {
            add(name, "grouped");
        }
        ModelObs {
            layers,
            fallback: reg.counter(&with_labels("comq_serve_fallback_total", &[("model", model)])),
            images: reg.counter(&with_labels("comq_serve_images_total", &[("model", model)])),
        }
    }

    /// Images executed through `layer` (0 for unknown layers).
    pub fn layer_execs(&self, layer: &str) -> u64 {
        self.layers.get(layer).map(|l| l.execs.get()).unwrap_or(0)
    }

    /// Integer-served layer names with telemetry attached.
    pub fn layer_names(&self) -> impl Iterator<Item = &str> {
        self.layers.keys().map(String::as_str)
    }

    /// Forward calls that hit a quantizable layer with no integer panel
    /// (the f32 fallback path).
    pub fn fallbacks(&self) -> u64 {
        self.fallback.get()
    }

    /// Total images through [`QuantizedModel::forward`].
    pub fn images(&self) -> u64 {
        self.images.get()
    }
}

/// A packed checkpoint ready to serve.
pub struct QuantizedModel {
    /// Architecture + every parameter that still runs in f32 (biases,
    /// norms, kept-FP layers). Has NO `{l}/W` entry for any
    /// integer-served layer, dense or grouped.
    base: Model,
    /// The stage plan [`QuantizedModel::forward`] folds over — built
    /// once at load so the pipelined executor (which runs stage slices
    /// of different batches concurrently) shares the exact closures the
    /// sequential forward runs.
    plan: Vec<Stage>,
    int8: BTreeMap<String, Int8Layer>,
    grouped: BTreeMap<String, GroupedInt8Layer>,
    act: ActSource,
    /// (min, max) source code width across packed layers —
    /// mixed-precision checkpoints carry per-layer widths, so a single
    /// number would misreport them. (0, 0) when nothing is packed.
    weight_bits: (u32, u32),
    quantizable: BTreeSet<String>,
    /// Present only when telemetry was on at build time.
    obs: Option<ModelObs>,
    /// Whether the source checkpoint's bytes were CRC-verified.
    /// In-memory builds ([`QuantizedModel::from_parts`] from the
    /// pipeline) are trusted and report `Verified`.
    integrity: Integrity,
}

impl QuantizedModel {
    /// Build from in-memory parts. `params` must hold every non-packed
    /// parameter (the pipeline passes the dequantized model's map; the
    /// loader passes the checkpoint's `fp/` entries). Packed weights —
    /// dense and grouped alike — are prepped to i8 panels; the packed
    /// codes are authoritative, so any caller-supplied f32 `{l}/W`
    /// entry for a packed layer is dropped (a stale tensor in `params`
    /// must never shadow the checkpoint's codes).
    pub fn from_parts(
        info: ModelInfo,
        mut params: BTreeMap<String, Tensor>,
        packed: &[PackedLayer],
        act: ActSource,
    ) -> Result<QuantizedModel> {
        // fail at build time, not with an assert mid-request
        if act.bits() < 1 || act.bits() > 8 {
            bail!("activation bits {} not servable as i8 (need 1..=8)", act.bits());
        }
        let grouped_names: BTreeSet<&str> = info
            .quant_layers
            .iter()
            .filter(|l| l.grouped)
            .map(|l| l.name.as_str())
            .collect();
        let known: BTreeSet<&str> = info.quant_layers.iter().map(|l| l.name.as_str()).collect();
        let mut int8 = BTreeMap::new();
        let mut grouped = BTreeMap::new();
        let mut weight_bits: Option<(u32, u32)> = None;
        for pl in packed {
            if !known.contains(pl.name.as_str()) {
                bail!("packed layer '{}' not in model '{}'", pl.name, info.name);
            }
            weight_bits = Some(match weight_bits {
                None => (pl.bits, pl.bits),
                Some((lo, hi)) => (lo.min(pl.bits), hi.max(pl.bits)),
            });
            let bias = params.get(&format!("{}/b", pl.name)).map(|t| t.data().to_vec());
            let static_aq = match &act {
                ActSource::Static { by_layer, .. } => by_layer.get(&pl.name).copied(),
                ActSource::Dynamic { .. } => None,
            };
            if grouped_names.contains(pl.name.as_str()) {
                let panel = GroupedPanel::from_packed(pl)?;
                let static_co =
                    static_aq.map(|aq| (aq, panel.coeffs(&aq, bias.as_deref())));
                grouped.insert(pl.name.clone(), GroupedInt8Layer { panel, bias, static_co });
            } else {
                let panel = Int8Panel::from_packed(pl)?;
                let static_co =
                    static_aq.map(|aq| (aq, panel.coeffs(&aq, bias.as_deref())));
                int8.insert(pl.name.clone(), Int8Layer { panel, bias, static_co });
            }
            // the packed codes are authoritative: a stale f32 weight in
            // `params` must neither be served nor linger in memory
            params.remove(&format!("{}/W", pl.name));
        }
        // completeness: every canonical parameter is either present in
        // f32 or covered by an integer panel
        for p in &info.params {
            if !params.contains_key(p) {
                let covered = p
                    .strip_suffix("/W")
                    .map(|l| int8.contains_key(l) || grouped.contains_key(l))
                    .unwrap_or(false);
                if !covered {
                    bail!("missing parameter '{p}' (neither packed nor FP)");
                }
            }
        }
        let quantizable = info.quant_layers.iter().map(|l| l.name.clone()).collect();
        let obs = crate::obs::enabled().then(|| {
            let m = ModelObs::new(&info.name, &int8, &grouped);
            let resident: usize = int8.values().map(|l| l.panel.resident_bytes()).sum::<usize>()
                + grouped.values().map(|l| l.panel.resident_bytes()).sum::<usize>();
            crate::obs::registry()
                .gauge(&with_labels("comq_serve_resident_bytes", &[("model", &info.name)]))
                .set(resident as i64);
            m
        });
        let base = Model { info, params };
        let plan = base.stage_plan();
        Ok(QuantizedModel {
            base,
            plan,
            int8,
            grouped,
            act,
            weight_bits: weight_bits.unwrap_or((0, 0)),
            quantizable,
            obs,
            integrity: Integrity::Verified,
        })
    }

    /// Load a `.cqm` checkpoint for serving (manifest supplies the
    /// architecture). Falls back to dynamic activation quantization when
    /// the checkpoint stores no calibrated grid.
    pub fn load(manifest: &Manifest, model_name: &str, path: &str) -> Result<QuantizedModel> {
        Self::load_with_info(manifest.model(model_name)?.clone(), path)
    }

    /// [`QuantizedModel::load`] without the manifest round-trip — the
    /// hot-swap path already holds the `ModelInfo` of the serving model
    /// and must not depend on the manifest still being on disk.
    pub fn load_with_info(info: ModelInfo, path: &str) -> Result<QuantizedModel> {
        let ck = deploy::read_packed(path)?;
        let act = match ck.act {
            Some(a) => ActSource::Static { bits: a.bits, by_layer: a.by_layer },
            None => ActSource::Dynamic { bits: DEFAULT_ACT_BITS },
        };
        let mut qm = QuantizedModel::from_parts(info, ck.fp, &ck.layers, act)?;
        qm.integrity = ck.integrity;
        Ok(qm)
    }

    /// Integer forward: x [b, img, img, 3] -> logits [b, classes].
    /// Defined as the full-plan case of [`QuantizedModel::forward_stages`],
    /// so the sequential and pipelined paths run the same code.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_stages(0, self.plan.len(), x.clone(), x.shape()[0] as u64)
    }

    /// The cached stage plan (for the pipelined executor, which needs
    /// the stage count to size its lanes).
    pub fn stages(&self) -> &[Stage] {
        &self.plan
    }

    /// Run stages `lo..hi` of the plan with the integer execution tap
    /// attached. `items` is the in-flight batch size in *requests* —
    /// re-stamped on every slice because pipeline lanes are distinct
    /// threads and [`span::set_items`] is thread-local. Images are
    /// counted once per batch, on the slice that starts the plan.
    pub fn forward_stages(&self, lo: usize, hi: usize, h: Tensor, items: u64) -> Tensor {
        if self.obs.is_some() || trace::batch_active() {
            // carry the batch size down to the per-layer exec hooks —
            // at that depth the row count is patches, not requests
            span::set_items(items);
        }
        if lo == 0 {
            if let Some(o) = &self.obs {
                o.images.add(items);
            }
        }
        let mut tap = Tap::Exec(self);
        let mut h = h;
        for st in &self.plan[lo..hi] {
            h = st.run(&self.base.params, h, &mut tap);
        }
        h
    }

    /// Per-layer telemetry, when `COMQ_OBS` was on at build time.
    pub fn obs(&self) -> Option<&ModelObs> {
        self.obs.as_ref()
    }

    pub fn info(&self) -> &ModelInfo {
        &self.base.info
    }

    pub fn classes(&self) -> usize {
        match &self.base.info.config {
            ModelConfig::ViT(c) => c.classes,
            ModelConfig::Cnn(c) => c.classes,
        }
    }

    pub fn input_side(&self) -> usize {
        match &self.base.info.config {
            ModelConfig::ViT(c) => c.img,
            ModelConfig::Cnn(c) => c.img,
        }
    }

    /// Number of layers served through integer execution (dense i8
    /// GEMM + grouped depthwise kernel).
    pub fn int8_layers(&self) -> usize {
        self.int8.len() + self.grouped.len()
    }

    /// Of those, the grouped (depthwise) layers.
    pub fn grouped_layers(&self) -> usize {
        self.grouped.len()
    }

    /// (min, max) source code width across the packed layers — equal
    /// for a uniform checkpoint, a genuine range for mixed precision.
    /// (0, 0) when nothing is packed.
    pub fn weight_bits_range(&self) -> (u32, u32) {
        self.weight_bits
    }

    /// Display form of the width: "4" for uniform, "2..8" for mixed.
    pub fn weight_bits_label(&self) -> String {
        let (lo, hi) = self.weight_bits;
        if lo == hi {
            format!("{lo}")
        } else {
            format!("{lo}..{hi}")
        }
    }

    pub fn act_source(&self) -> &ActSource {
        &self.act
    }

    /// Whether the source checkpoint's bytes were CRC-verified (v2
    /// footer) or loaded from an unverifiable v1 file.
    pub fn integrity(&self) -> Integrity {
        self.integrity
    }

    /// Whether a layer still holds an f32 `{layer}/W` entry (diagnostic
    /// for the no-f32-materialization guarantee of integer-served
    /// layers).
    pub fn fp_weight_materialized(&self, layer: &str) -> bool {
        self.base.params.contains_key(&format!("{layer}/W"))
    }

    /// Serving-resident bytes of the integer panels (the f32 weights
    /// these replace would be `4·m·n` each).
    pub fn resident_bytes(&self) -> usize {
        self.int8.values().map(|l| l.panel.resident_bytes()).sum::<usize>()
            + self.grouped.values().map(|l| l.panel.resident_bytes()).sum::<usize>()
    }

    fn act_for(&self, name: &str, x: &Tensor) -> ActQuant {
        match &self.act {
            ActSource::Static { bits, by_layer } => by_layer
                .get(name)
                .copied()
                .unwrap_or_else(|| ActQuant::from_tensor(x, *bits)),
            ActSource::Dynamic { bits } => ActQuant::from_tensor(x, *bits),
        }
    }
}

impl QuantizedModel {
    /// Count a quantizable layer falling back to the f32 path (kept-FP
    /// skip layers); non-quantizable layers never had a panel to miss.
    fn note_fallback(&self, name: &str) {
        if let Some(o) = &self.obs {
            if self.quantizable.contains(name) {
                o.fallback.inc();
            }
        }
    }

    /// Run one integer layer, timing it when telemetry is attached or
    /// the executing batch is traced — the trace's per-layer events use
    /// the same start/elapsed pair as the histograms. Exec counters are
    /// weighted by the in-flight batch size ([`span::items`]) so they
    /// count images, not forward calls; `kind` becomes the trace
    /// event's layer-kind attribute.
    fn timed<F: FnOnce() -> Tensor>(&self, name: &str, kind: &'static str, f: F) -> Tensor {
        let lo = self.obs.as_ref().and_then(|o| o.layers.get(name));
        match (lo, trace::batch_active()) {
            (None, false) => f(),
            (lo, _) => {
                let t = Instant::now();
                let y = f();
                let elapsed = t.elapsed();
                if let Some(lo) = lo {
                    lo.nanos.record(elapsed.as_nanos() as u64);
                    lo.execs.add(span::items());
                }
                trace::layer_event(name, kind, span::items(), t, elapsed);
                y
            }
        }
    }
}

impl LayerExec for QuantizedModel {
    fn exec_linear(&self, name: &str, x: &Tensor) -> Option<Tensor> {
        let Some(layer) = self.int8.get(name) else {
            self.note_fallback(name);
            return None;
        };
        Some(self.timed(name, "dense", || layer.forward(x, self.act_for(name, x))))
    }

    fn exec_grouped(&self, name: &str, x3: &Tensor) -> Option<Tensor> {
        let Some(layer) = self.grouped.get(name) else {
            self.note_fallback(name);
            return None;
        };
        Some(self.timed(name, "grouped", || layer.forward(x3, self.act_for(name, x3))))
    }

    fn tap_input(&self, name: &str, x: Tensor) -> Tensor {
        // integer-owned layers (dense and grouped) quantize internally;
        // non-quantizable layers pass through; quantizable fallbacks
        // (kept-FP skip layers) get fake-quantized so the network
        // matches the W/A reference.
        if self.int8.contains_key(name)
            || self.grouped.contains_key(name)
            || !self.quantizable.contains(name)
        {
            return x;
        }
        let aq = self.act_for(name, &x);
        let mut x = x;
        aq.apply_tensor(&mut x);
        x
    }
}

// ---------------------------------------------------------------------------
// Registry v2: load-once, byte-budgeted, LRU-evicting
// ---------------------------------------------------------------------------
//
// Keyed by `model@path`. Each key is either `Ready` (a loaded model +
// its resident bytes + an LRU stamp) or `Loading` (a gate the single
// loader resolves and every concurrent caller waits on — fixing the
// old check-unlock-decode-relock race where N first requests decoded
// the same checkpoint N times). A `COMQ_MODEL_BUDGET` byte cap (k/m/g
// suffixes; unset or 0 = unlimited) triggers LRU eviction of idle
// entries — an entry is idle when the registry holds the only `Arc`,
// so a model pinned by a serving epoch is never dropped mid-request.

struct LoadGate {
    /// `None` while the loader runs; the loader publishes `Ok(model)`
    /// or the load error (stringified — `anyhow::Error` isn't `Clone`)
    /// and every waiter shares it.
    done: Mutex<Option<Result<Arc<QuantizedModel>, String>>>,
    cv: Condvar,
}

struct RegEntry {
    model: Arc<QuantizedModel>,
    bytes: u64,
    last_used: u64,
}

enum Slot {
    Loading(Arc<LoadGate>),
    Ready(RegEntry),
}

static REGISTRY: OnceLock<Mutex<HashMap<String, Slot>>> = OnceLock::new();

fn registry() -> &'static Mutex<HashMap<String, Slot>> {
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Always-on lifecycle counters (plain atomics, like `NetStats`): the
/// reconciliation side the tests and the obs-gated metrics both check
/// against.
#[derive(Default)]
struct RegCounters {
    loads: AtomicU64,
    load_failures: AtomicU64,
    evictions: AtomicU64,
    swaps: AtomicU64,
}

fn counters() -> &'static RegCounters {
    static C: OnceLock<RegCounters> = OnceLock::new();
    C.get_or_init(RegCounters::default)
}

/// Snapshot of the registry's lifecycle counters + current residency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegistryStats {
    /// Successful checkpoint loads (decode + panel prep) ever.
    pub loads: u64,
    /// Loads that returned an error (every waiter shares one failure).
    pub load_failures: u64,
    /// Entries evicted (budget pressure or superseded by a swap).
    pub evictions: u64,
    /// Completed hot-swaps noted by the serving tier.
    pub swaps: u64,
    /// Resident panel bytes across `Ready` entries right now.
    pub resident_bytes: u64,
    /// Entries (ready + loading) right now.
    pub len: usize,
}

pub fn registry_stats() -> RegistryStats {
    let c = counters();
    let (resident, len) = match REGISTRY.get() {
        None => (0, 0),
        Some(r) => {
            let reg = r.lock().unwrap();
            let resident = reg
                .values()
                .map(|s| match s {
                    Slot::Ready(e) => e.bytes,
                    Slot::Loading(_) => 0,
                })
                .sum();
            (resident, reg.len())
        }
    };
    RegistryStats {
        loads: c.loads.load(Ordering::Relaxed),
        load_failures: c.load_failures.load(Ordering::Relaxed),
        evictions: c.evictions.load(Ordering::Relaxed),
        swaps: c.swaps.load(Ordering::Relaxed),
        resident_bytes: resident,
        len,
    }
}

fn lru_tick() -> u64 {
    static TICK: AtomicU64 = AtomicU64::new(0);
    TICK.fetch_add(1, Ordering::Relaxed) + 1
}

/// Registry byte budget: `u64::MAX` = unlimited. Read once from
/// `COMQ_MODEL_BUDGET`; tests override via [`set_budget`].
fn budget_cell() -> &'static AtomicU64 {
    static B: OnceLock<AtomicU64> = OnceLock::new();
    B.get_or_init(|| {
        let v = match std::env::var("COMQ_MODEL_BUDGET").ok().as_deref().map(str::trim) {
            None | Some("") => u64::MAX,
            Some(s) => match parse_model_budget(s) {
                Some(0) => u64::MAX,
                Some(b) => b,
                None => {
                    crate::warn_once!("COMQ_MODEL_BUDGET='{s}' unparseable, budget unlimited");
                    u64::MAX
                }
            },
        };
        AtomicU64::new(v)
    })
}

/// Parse a byte budget with optional k/m/g suffix (powers of 1024).
fn parse_model_budget(s: &str) -> Option<u64> {
    let t = s.trim().to_ascii_lowercase();
    let (digits, mult) = match t.as_bytes().last()? {
        b'k' => (&t[..t.len() - 1], 1u64 << 10),
        b'm' => (&t[..t.len() - 1], 1u64 << 20),
        b'g' => (&t[..t.len() - 1], 1u64 << 30),
        _ => (t.as_str(), 1),
    };
    digits.trim().parse::<u64>().ok()?.checked_mul(mult)
}

/// Override the registry byte budget (tests; `None` = unlimited).
pub fn set_budget(bytes: Option<u64>) {
    budget_cell().store(bytes.unwrap_or(u64::MAX), Ordering::Relaxed);
}

/// Load a checkpoint through the process-wide registry: the decode +
/// panel prep runs exactly once per (model, path) even under
/// concurrent first requests — one caller loads, the rest block on its
/// gate and share the result (or its error). The serving analogue of
/// `runtime::Engine`'s compile cache.
pub fn load_cached(
    manifest: &Manifest,
    model_name: &str,
    path: &str,
) -> Result<Arc<QuantizedModel>> {
    load_with_info(manifest.model(model_name)?.clone(), path)
}

/// [`load_cached`] for callers that already hold the `ModelInfo` (the
/// hot-swap path, which must not re-read the manifest).
pub fn load_with_info(info: ModelInfo, path: &str) -> Result<Arc<QuantizedModel>> {
    enum Next {
        Hit(Arc<QuantizedModel>),
        Wait(Arc<LoadGate>),
        Load,
    }
    let key = format!("{}@{path}", info.name);
    let next = {
        let mut reg = registry().lock().unwrap();
        let next = match reg.get_mut(&key) {
            Some(Slot::Ready(e)) => {
                e.last_used = lru_tick();
                Next::Hit(e.model.clone())
            }
            Some(Slot::Loading(g)) => Next::Wait(g.clone()),
            None => Next::Load,
        };
        if matches!(next, Next::Load) {
            let g = Arc::new(LoadGate { done: Mutex::new(None), cv: Condvar::new() });
            reg.insert(key.clone(), Slot::Loading(g));
        }
        next
    };
    match next {
        Next::Hit(m) => Ok(m),
        Next::Load => run_loader(&key, info, path),
        Next::Wait(gate) => {
            // another caller owns the load: wait for its published result
            let mut done = gate.done.lock().unwrap();
            while done.is_none() {
                done = gate.cv.wait(done).unwrap();
            }
            match done.as_ref().unwrap() {
                Ok(m) => Ok(m.clone()),
                Err(e) => bail!("loading {key}: {e}"),
            }
        }
    }
}

/// The single loader for a key: decode off-lock, publish the result to
/// the gate, transition the slot. A panicking decode publishes a
/// failure (so waiters don't hang) before resuming the panic.
fn run_loader(key: &str, info: ModelInfo, path: &str) -> Result<Arc<QuantizedModel>> {
    let model_label = info.name.clone();
    let path = path.to_string();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
        QuantizedModel::load_with_info(info, &path).map(Arc::new)
    }));
    let outcome: Result<Arc<QuantizedModel>> = match result {
        Ok(r) => r,
        Err(payload) => {
            finish_load(key, &model_label, Err("loader panicked".into()));
            std::panic::resume_unwind(payload);
        }
    };
    match outcome {
        Ok(m) => {
            finish_load(key, &model_label, Ok(m.clone()));
            Ok(m)
        }
        Err(e) => {
            finish_load(key, &model_label, Err(format!("{e:#}")));
            Err(e)
        }
    }
}

/// Transition a `Loading` slot to `Ready` (or remove it on failure),
/// bump the counters/metrics/recorder, enforce the byte budget, and
/// wake every waiter with the shared result.
fn finish_load(key: &str, model: &str, result: Result<Arc<QuantizedModel>, String>) {
    let gate = {
        let mut reg = registry().lock().unwrap();
        let gate = match reg.get(key) {
            Some(Slot::Loading(g)) => Some(g.clone()),
            _ => None,
        };
        match &result {
            Ok(m) => {
                counters().loads.fetch_add(1, Ordering::Relaxed);
                recorder::note(RecKind::Load, key);
                if crate::obs::enabled() {
                    crate::obs::registry()
                        .counter(&with_labels("comq_model_loads_total", &[("model", model)]))
                        .inc();
                }
                let bytes = m.resident_bytes() as u64;
                reg.insert(
                    key.to_string(),
                    Slot::Ready(RegEntry { model: m.clone(), bytes, last_used: lru_tick() }),
                );
                enforce_budget(&mut reg, Some(key));
            }
            Err(e) => {
                counters().load_failures.fetch_add(1, Ordering::Relaxed);
                if crate::obs::enabled() {
                    crate::obs::registry()
                        .counter(&with_labels(
                            "comq_model_load_failures_total",
                            &[("model", model)],
                        ))
                        .inc();
                }
                crate::log_warn!("registry: loading {key} failed: {e}");
                reg.remove(key);
            }
        }
        gate
    };
    if let Some(g) = gate {
        let mut done = g.done.lock().unwrap();
        *done = Some(result);
        g.cv.notify_all();
    }
}

/// Evict LRU idle entries until residency fits the budget. `keep`
/// (the just-loaded key) is never evicted, nor is any model some other
/// holder still pins (`Arc::strong_count > 1`) — dropping those would
/// free nothing and could rip a model out from under an epoch.
fn enforce_budget(reg: &mut HashMap<String, Slot>, keep: Option<&str>) {
    let budget = budget_cell().load(Ordering::Relaxed);
    if budget == u64::MAX {
        return;
    }
    loop {
        let resident: u64 = reg
            .values()
            .map(|s| match s {
                Slot::Ready(e) => e.bytes,
                Slot::Loading(_) => 0,
            })
            .sum();
        if resident <= budget {
            return;
        }
        let victim = reg
            .iter()
            .filter_map(|(k, s)| match s {
                Slot::Ready(e)
                    if Some(k.as_str()) != keep && Arc::strong_count(&e.model) == 1 =>
                {
                    Some((e.last_used, k.clone()))
                }
                _ => None,
            })
            .min();
        match victim {
            Some((_, k)) => evict_key(reg, &k, "budget"),
            None => {
                crate::warn_once!(
                    "COMQ_MODEL_BUDGET={budget} unmeetable: {resident} resident bytes are \
                     all pinned or loading"
                );
                return;
            }
        }
    }
}

fn evict_key(reg: &mut HashMap<String, Slot>, key: &str, reason: &str) {
    if let Some(Slot::Ready(_)) = reg.remove(key) {
        counters().evictions.fetch_add(1, Ordering::Relaxed);
        recorder::note(RecKind::Evict, &format!("{key} ({reason})"));
        if crate::obs::enabled() {
            let model = key.split('@').next().unwrap_or(key).to_string();
            crate::obs::registry()
                .counter(&with_labels(
                    "comq_model_evictions_total",
                    &[("model", &model), ("reason", reason)],
                ))
                .inc();
        }
        crate::log_info!("registry: evicted {key} ({reason})");
    }
}

/// Drop a retired checkpoint from the registry after a hot-swap
/// replaced it — counted as an eviction with reason `superseded`.
pub fn retire_cached(model_name: &str, path: &str) {
    let key = format!("{model_name}@{path}");
    let mut reg = registry().lock().unwrap();
    evict_key(&mut reg, &key, "superseded");
}

/// Count a completed hot-swap (the serving tier calls this once per
/// epoch flip, after the new model is live).
pub fn note_swap(model_name: &str, detail: &str) {
    counters().swaps.fetch_add(1, Ordering::Relaxed);
    recorder::note(RecKind::Swap, &format!("{model_name}: {detail}"));
    if crate::obs::enabled() {
        crate::obs::registry()
            .counter(&with_labels("comq_model_swaps_total", &[("model", model_name)]))
            .inc();
    }
}

/// Checkpoints currently cached, ready or loading (diagnostics/tests).
pub fn registry_len() -> usize {
    REGISTRY.get().map(|r| r.lock().unwrap().len()).unwrap_or(0)
}

/// Remove every idle entry (tests that assert budget/eviction behavior
/// need a clean slate; pinned entries stay, like under budget pressure).
pub fn registry_clear_idle() {
    if let Some(r) = REGISTRY.get() {
        let mut reg = r.lock().unwrap();
        let idle: Vec<String> = reg
            .iter()
            .filter_map(|(k, s)| match s {
                Slot::Ready(e) if Arc::strong_count(&e.model) == 1 => Some(k.clone()),
                _ => None,
            })
            .collect();
        for k in idle {
            // direct removal, not an eviction: tests resetting state
            // must not skew the eviction counters
            reg.remove(&k);
        }
    }
}
