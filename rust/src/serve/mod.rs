//! Integer serving runtime: execute packed `.cqm` checkpoints without
//! ever materializing f32 weights.
//!
//! The deployment story until now stopped at `deploy::load_packed`,
//! which unpacks the bit-codes *back to f32* and runs full-precision
//! matmuls — correct, but none of the compute/bandwidth win the codes
//! exist for. This subsystem is the other half:
//!
//! * `packed`  — one-time weight prep: b-bit bitstream → K4-interleaved
//!   strip-packed centered-i8 panel (the MR×NR blocking of
//!   `tensor/matmul.rs` with k in groups of 4, a quarter the bytes of
//!   f32) + per-column integer sums; grouped (depthwise) layers get the
//!   same prep as per-group k·k-column strips (`GroupedPanel`);
//! * `gemm`    — the `u8×i8→i32` register-tiled GEMM with the
//!   per-column `(δ, z)` weight dequant and `(scale, zero)` activation
//!   grid folded into the epilogue, parallelized over the persistent
//!   worker pool and executed by a runtime-dispatched SIMD micro-kernel
//!   (`util::simd`: AVX-512 VNNI `vpdpbusd` / AVX2 `vpmaddubsw` /
//!   scalar reference, forced via `COMQ_KERNEL=scalar|avx2|vnni`; all
//!   three produce bit-identical i32 accumulators); plus the grouped
//!   sibling `dwconv_i8_fused` over per-lane activation panels
//!   (`GroupedQuantizedActs`), same contract, same kernels;
//! * `model`   — `QuantizedModel` (routes quantizable linears through
//!   the GEMM and depthwise layers through the grouped kernel via
//!   `model::LayerExec` — no layer class is left on f32 weights) and
//!   the process-wide load-once registry, the serving analogue of
//!   `runtime::Engine`'s compile cache;
//! * `batcher` — a dynamic micro-batching request queue coalescing
//!   single requests into batches under a latency deadline, with
//!   per-request deadlines, typed shed errors and panic-respawning
//!   executors;
//! * `net`     — the TCP serving tier in front of the batcher: the
//!   COMQ wire protocol, deadline propagation, admission control and
//!   load shedding, graceful drain, and the `COMQ_FAULT` injection
//!   layer the robustness tests drive.
//!
//! The whole path is instrumented through `crate::obs` (per-request
//! stage spans, queue depth, batch-size distribution, per-layer exec
//! timing, kernel-tier dispatch counters), gated by `COMQ_OBS` —
//! see `obs` for the export formats and the off-is-free contract.
//!
//! Accuracy parity with the dequantized-f32 reference is routed through
//! `EngineKind::Int8` (see `eval::evaluate_int8` and the pipeline), and
//! asserted by rust/tests/serve_int8.rs.

pub mod batcher;
pub mod gemm;
pub mod model;
pub mod net;
pub mod packed;

pub use batcher::{
    pipeline_from_env, BatchConfig, Responder, ServeError, ServeObs, ServeResult, ServeStats,
    Server,
};
pub use net::{ModelEpoch, NetClient, NetConfig, NetServer};
pub use gemm::{
    dwconv_i8_fused, dwconv_i8_fused_with, gemm_i8_fused, gemm_i8_fused_sharded,
    gemm_i8_fused_with, EpilogueCoeffs, GroupedQuantizedActs, PanelShard, QuantizedActs,
};
pub use model::{
    load_cached, load_with_info, note_swap, registry_clear_idle, registry_len, registry_stats,
    retire_cached, set_budget, ActSource, ModelObs, QuantizedModel, RegistryStats,
    DEFAULT_ACT_BITS,
};
pub use packed::{GroupedPanel, Int8Panel};

pub use crate::util::simd::Kernel;
