//! A small blocking client for the COMQ wire protocol — enough for the
//! loopback integration tests, the open-loop load generator and the
//! CLI to drive a [`super::server::NetServer`] without any external
//! HTTP/RPC machinery.
//!
//! The client is deliberately synchronous (one thread, one socket) but
//! the protocol is pipelined: [`NetClient::send_infer`] returns the
//! request id immediately, any number may be outstanding, and
//! [`NetClient::recv`] yields replies in server completion order for
//! the caller to match by id. [`NetClient::infer`] wraps the pair for
//! the common one-at-a-time case.

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::obs::trace::{self, TraceCtx};
use crate::serve::net::frame::{self, ErrorReason, FrameError, FrameKind};

/// Why a client call failed.
#[derive(Debug)]
pub enum ClientError {
    /// Transport failure (connect, read, write, server hung up).
    Io(std::io::Error),
    /// The server's bytes do not parse as a frame (e.g. an injected
    /// `garbage_frame` corruption).
    Frame(FrameError),
    /// The server answered a typed error frame.
    Server { reason: ErrorReason, message: String },
    /// The server answered a frame kind that makes no sense here.
    Unexpected(FrameKind),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "transport error: {e}"),
            ClientError::Frame(e) => write!(f, "bad frame from server: {e}"),
            ClientError::Server { reason, message } => {
                write!(f, "server error ({}): {message}", reason.name())
            }
            ClientError::Unexpected(k) => write!(f, "unexpected frame kind {k:?} from server"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl From<FrameError> for ClientError {
    fn from(e: FrameError) -> Self {
        ClientError::Frame(e)
    }
}

/// One decoded server reply.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// `InferOk`: the logits for `request_id`. `epoch` is the weight
    /// generation that answered (servers stamp it on every reply;
    /// `None` from pre-epoch servers) — pin follow-ups to exactly
    /// these weights with `model@<epoch>`.
    Logits { request_id: u32, logits: Vec<f32>, epoch: Option<u64> },
    /// A typed error frame for `request_id` (protocol-level errors
    /// carry request id 0).
    Error { request_id: u32, reason: ErrorReason, message: String },
    /// `MetricsText`: the Prometheus exposition.
    Metrics { request_id: u32, text: String },
    /// `TraceJson`: the server's retained traces as Chrome trace-event
    /// JSON.
    Trace { request_id: u32, json: String },
    /// `SwapOk`: the hot-swap completed; the model flipped from
    /// `old_epoch` to `new_epoch`.
    SwapOk { request_id: u32, old_epoch: u64, new_epoch: u64 },
    /// `ModelsText`: the server's model/registry listing.
    Models { request_id: u32, text: String },
}

/// Blocking COMQ protocol client over one TCP connection.
pub struct NetClient {
    stream: TcpStream,
    rbuf: Vec<u8>,
    next_id: u32,
}

impl NetClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<NetClient, ClientError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(NetClient { stream, rbuf: Vec::new(), next_id: 1 })
    }

    /// Bound every subsequent `recv` (tests use this so an asserted
    /// "no reply" is a bounded wait, never a hang).
    pub fn set_read_timeout(&mut self, t: Option<Duration>) -> Result<(), ClientError> {
        self.stream.set_read_timeout(t)?;
        Ok(())
    }

    /// Send one inference request; returns its request id without
    /// waiting for the reply (pipelining). `budget` is the per-request
    /// latency deadline the server propagates into the batcher.
    ///
    /// When `COMQ_TRACE` is on a client-minted trace context rides
    /// along (a v2 frame); otherwise the wire stays bit-identical v1.
    pub fn send_infer(
        &mut self,
        model: &str,
        input: &[f32],
        budget: Option<Duration>,
    ) -> Result<u32, ClientError> {
        let ctx = if trace::enabled() { Some(trace::mint_client()) } else { None };
        self.send_infer_traced(model, input, budget, ctx)
    }

    /// [`send_infer`](Self::send_infer) with an explicit trace context
    /// (`None` forces an untraced v1 frame regardless of `COMQ_TRACE`).
    pub fn send_infer_traced(
        &mut self,
        model: &str,
        input: &[f32],
        budget: Option<Duration>,
        ctx: Option<TraceCtx>,
    ) -> Result<u32, ClientError> {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1).max(1);
        let deadline_us = budget.map_or(0, |b| b.as_micros().min(u64::MAX as u128) as u64);
        let bytes = frame::encode_infer_t(id, model, deadline_us, input, ctx);
        self.stream.write_all(&bytes)?;
        Ok(id)
    }

    /// Read the next reply frame (blocking, in server completion
    /// order).
    pub fn recv(&mut self) -> Result<Response, ClientError> {
        self.recv_with_trace().map(|(r, _)| r)
    }

    /// [`recv`](Self::recv) plus the trace context the server echoed on
    /// the reply frame (`None` on v1 replies — i.e. whenever the
    /// request did not carry one).
    pub fn recv_with_trace(&mut self) -> Result<(Response, Option<TraceCtx>), ClientError> {
        loop {
            match frame::decode(&self.rbuf)? {
                Some((f, used)) => {
                    self.rbuf.drain(..used);
                    let ctx = f.trace;
                    let resp = match f.kind {
                        FrameKind::InferOk => {
                            // the reply's model field is "@<epoch>"
                            // from epoch-aware servers, empty otherwise
                            let (_, epoch) = frame::split_model_pin(&f.model);
                            Response::Logits {
                                request_id: f.request_id,
                                logits: f.payload_f32()?,
                                epoch,
                            }
                        }
                        FrameKind::Error => {
                            let (reason, message) = f.error_reason()?;
                            Response::Error { request_id: f.request_id, reason, message }
                        }
                        FrameKind::MetricsText => Response::Metrics {
                            request_id: f.request_id,
                            text: String::from_utf8_lossy(&f.payload).into_owned(),
                        },
                        FrameKind::TraceJson => Response::Trace {
                            request_id: f.request_id,
                            json: String::from_utf8_lossy(&f.payload).into_owned(),
                        },
                        FrameKind::SwapOk => {
                            let (old_epoch, new_epoch) = frame::swap_ok_epochs(&f.payload)?;
                            Response::SwapOk { request_id: f.request_id, old_epoch, new_epoch }
                        }
                        FrameKind::ModelsText => Response::Models {
                            request_id: f.request_id,
                            text: String::from_utf8_lossy(&f.payload).into_owned(),
                        },
                        other => return Err(ClientError::Unexpected(other)),
                    };
                    return Ok((resp, ctx));
                }
                None => {
                    let mut buf = [0u8; 16384];
                    let n = self.stream.read(&mut buf)?;
                    if n == 0 {
                        return Err(ClientError::Io(std::io::Error::new(
                            std::io::ErrorKind::UnexpectedEof,
                            "server closed the connection",
                        )));
                    }
                    self.rbuf.extend_from_slice(&buf[..n]);
                }
            }
        }
    }

    /// One-shot inference: send, then wait for this request's reply. A
    /// typed error frame becomes [`ClientError::Server`].
    pub fn infer_deadline(
        &mut self,
        model: &str,
        input: &[f32],
        budget: Option<Duration>,
    ) -> Result<Vec<f32>, ClientError> {
        let id = self.send_infer(model, input, budget)?;
        loop {
            match self.recv()? {
                Response::Logits { request_id, logits, .. } if request_id == id => {
                    return Ok(logits)
                }
                Response::Error { request_id, reason, message }
                    if request_id == id || request_id == 0 =>
                {
                    return Err(ClientError::Server { reason, message })
                }
                // stale reply to an abandoned earlier request — skip
                _ => continue,
            }
        }
    }

    /// One-shot inference with no deadline.
    pub fn infer(&mut self, model: &str, input: &[f32]) -> Result<Vec<f32>, ClientError> {
        self.infer_deadline(model, input, None)
    }

    /// Fetch the server's retained traces as Chrome trace-event JSON.
    pub fn trace_dump(&mut self) -> Result<String, ClientError> {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1).max(1);
        self.stream.write_all(&frame::encode_trace_dump(id))?;
        loop {
            match self.recv()? {
                Response::Trace { request_id, json } if request_id == id => return Ok(json),
                Response::Error { request_id, reason, message }
                    if request_id == id || request_id == 0 =>
                {
                    return Err(ClientError::Server { reason, message })
                }
                _ => continue,
            }
        }
    }

    /// Fetch the server's Prometheus metrics over the same transport.
    pub fn metrics(&mut self) -> Result<String, ClientError> {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1).max(1);
        self.stream.write_all(&frame::encode_metrics_req(id))?;
        loop {
            match self.recv()? {
                Response::Metrics { request_id, text } if request_id == id => return Ok(text),
                Response::Error { request_id, reason, message }
                    if request_id == id || request_id == 0 =>
                {
                    return Err(ClientError::Server { reason, message })
                }
                _ => continue,
            }
        }
    }

    /// Hot-swap `model` to the checkpoint at `path` on the server.
    /// Blocks until the swap completes (the server loads the new
    /// weights off its event loop; in-flight inference keeps being
    /// answered throughout). Returns `(old_epoch, new_epoch)`.
    pub fn swap(&mut self, model: &str, path: &str) -> Result<(u64, u64), ClientError> {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1).max(1);
        self.stream.write_all(&frame::encode_swap_req(id, model, path))?;
        loop {
            match self.recv()? {
                Response::SwapOk { request_id, old_epoch, new_epoch } if request_id == id => {
                    return Ok((old_epoch, new_epoch))
                }
                Response::Error { request_id, reason, message }
                    if request_id == id || request_id == 0 =>
                {
                    return Err(ClientError::Server { reason, message })
                }
                _ => continue,
            }
        }
    }

    /// Fetch the server's model/registry listing (one line per model:
    /// epoch, bit-width, integrity, residency; plus registry totals).
    pub fn models(&mut self) -> Result<String, ClientError> {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1).max(1);
        self.stream.write_all(&frame::encode_models_req(id))?;
        loop {
            match self.recv()? {
                Response::Models { request_id, text } if request_id == id => return Ok(text),
                Response::Error { request_id, reason, message }
                    if request_id == id || request_id == 0 =>
                {
                    return Err(ClientError::Server { reason, message })
                }
                _ => continue,
            }
        }
    }
}
