//! Per-model admission control: a concurrency token bucket plus
//! queue-depth load shedding.
//!
//! Two independent gates, both checked *before* a request touches the
//! micro-batcher:
//!
//! * **In-flight tokens** — at most `max_inflight` requests per model
//!   between admission and reply. The permit is RAII: the network tier
//!   moves it into the completion callback, so however the request ends
//!   (logits, shed, executor panic, client gone) the token comes back.
//! * **Queue depth** — if the batcher's live queue is already at
//!   `max_queue`, the request is shed even if a token is free: depth is
//!   the leading indicator that p99 is about to blow (the same signal
//!   the `comq_serve_queue_depth` gauge exports; the check reads the
//!   batcher's always-on atomic so shedding works under
//!   `COMQ_OBS=off`).
//!
//! Shed requests answer a typed `Overloaded` frame — the client backs
//! off; the server does the cheap thing instead of queueing work it
//! will miss deadlines on. Explicit shed beats implicit collapse.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Admission tuning, per model.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Requests allowed between admission and reply.
    pub max_inflight: usize,
    /// Batcher queue depth at or above which new requests are shed.
    pub max_queue: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { max_inflight: 128, max_queue: 256 }
    }
}

/// The token bucket. Cheap: one atomic per try/release.
pub struct Admission {
    available: AtomicUsize,
    cfg: AdmissionConfig,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig) -> Arc<Admission> {
        assert!(cfg.max_inflight >= 1, "max_inflight must be >= 1");
        Arc::new(Admission { available: AtomicUsize::new(cfg.max_inflight), cfg })
    }

    /// Try to take an in-flight token. `None` = shed (Overloaded).
    pub fn try_acquire(self: &Arc<Admission>) -> Option<Permit> {
        let mut cur = self.available.load(Ordering::Relaxed);
        loop {
            if cur == 0 {
                return None;
            }
            match self.available.compare_exchange_weak(
                cur,
                cur - 1,
                Ordering::Acquire,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(Permit { bucket: self.clone() }),
                Err(now) => cur = now,
            }
        }
    }

    /// Whether queue depth `depth` means new work should be shed.
    pub fn queue_is_full(&self, depth: usize) -> bool {
        depth >= self.cfg.max_queue
    }

    /// Tokens currently free (diagnostics / tests).
    pub fn available(&self) -> usize {
        self.available.load(Ordering::Relaxed)
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }
}

/// RAII in-flight token; dropping it returns the token to the bucket.
pub struct Permit {
    bucket: Arc<Admission>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.bucket.available.fetch_add(1, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_are_bounded_and_returned() {
        let a = Admission::new(AdmissionConfig { max_inflight: 2, max_queue: 4 });
        let p1 = a.try_acquire().expect("token 1");
        let p2 = a.try_acquire().expect("token 2");
        assert!(a.try_acquire().is_none(), "bucket must be empty at max_inflight");
        assert_eq!(a.available(), 0);
        drop(p1);
        assert_eq!(a.available(), 1);
        let p3 = a.try_acquire().expect("token back after release");
        drop(p2);
        drop(p3);
        assert_eq!(a.available(), 2);
    }

    #[test]
    fn queue_threshold_is_inclusive() {
        let a = Admission::new(AdmissionConfig { max_inflight: 1, max_queue: 3 });
        assert!(!a.queue_is_full(0));
        assert!(!a.queue_is_full(2));
        assert!(a.queue_is_full(3));
        assert!(a.queue_is_full(4));
    }

    #[test]
    fn permits_survive_threads() {
        let a = Admission::new(AdmissionConfig { max_inflight: 4, max_queue: 8 });
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let a = a.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        if let Some(p) = a.try_acquire() {
                            std::hint::black_box(&p);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.available(), 4, "every permit must come home");
    }
}
