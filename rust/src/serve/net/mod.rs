//! `serve::net` — the hardened TCP front door for the int8 serving
//! runtime (PR 7).
//!
//! Layers, bottom up:
//!
//! * [`frame`]     — the dependency-free length-prefixed wire format
//!   (magic, version, request id, per-request deadline budget, model
//!   id, tensor payload; typed error frames). Incremental decode, hard
//!   size caps enforced from declared lengths.
//! * [`fault`]     — the `COMQ_FAULT` injection layer (`panic:<site>`,
//!   `slow:<ms>`, `drop_conn:<p>`, `garbage_frame`, each with an
//!   optional exact firing budget) that the robustness tests drive.
//! * [`admission`] — per-model concurrency token bucket + queue-depth
//!   load shedding, checked before a request touches the batcher.
//! * [`epoll`]     — (Linux) thin RAII wrapper over the epoll + pipe
//!   syscalls; no `libc` crate in the vendor set, so the symbols are
//!   declared directly.
//! * [`server`]    — [`NetServer`]: the event loop (epoll, or a
//!   portable connection-thread fallback) feeding the per-model
//!   micro-batchers, with deadline propagation, admission control,
//!   graceful drain and per-frame panic containment.
//! * [`client`]    — a small blocking client speaking the same frames
//!   (used by the loopback tests, the load generator and the CLI).
//!
//! The serving semantics (what is shed when, which errors close the
//! connection, the fault matrix) are documented in `EXPERIMENTS.md`
//! §Robustness.

pub mod admission;
pub mod client;
#[cfg(target_os = "linux")]
pub mod epoll;
pub mod fault;
pub mod frame;
pub mod server;

pub use admission::{Admission, AdmissionConfig, Permit};
pub use client::{ClientError, NetClient, Response};
pub use frame::{
    ErrorReason, Frame, FrameKind, MAX_MODEL_ID, MAX_PAYLOAD, WIRE_VERSION, WIRE_VERSION_MIN,
};
pub use server::{ModelEpoch, NetConfig, NetServer, NetStats};
