//! The COMQ wire format: a dependency-free length-prefixed binary
//! framing, little-endian throughout.
//!
//! ```text
//! offset  size  field
//! 0       4     magic        0x434F4D51 ("COMQ" big-endian bytes, read LE)
//! 4       1     version      1 or 2 (see below)
//! 5       1     kind         FrameKind discriminant
//! 6       4     request_id   client-chosen, echoed in the reply
//! 10      8     deadline_us  per-request latency budget in µs (0 = none)
//! 18      2     model_len    bytes of UTF-8 model id that follow
//! 20      4     payload_len  bytes of payload that follow the model id
//! --- version 2 only: 9-byte trace extension ---
//! 24      8     trace_id     64-bit end-to-end trace id
//! 32      1     trace_flags  TraceCtx flags byte
//! --- then, at 24 (v1) / 33 (v2): ---
//! +0      m     model id
//! +m      p     payload
//! ```
//!
//! **Version 2 = version 1 + an optional trace context.** A frame
//! carries the 9-byte `{trace_id, flags}` extension iff its version
//! byte says 2; encoders emit version 1 whenever no context is attached
//! (so a tracing-aware client talking to anything still produces
//! byte-identical v1 frames when tracing is off), and the server
//! decodes both versions — old clients' v1 frames still work, their
//! requests get server-minted ids, and replies carry the extension only
//! when the request did (a v1 client is never sent a v2 frame).
//!
//! Payloads by kind: `Infer` carries `payload_len/4` f32 inputs (LE);
//! `InferOk` carries the logits the same way; `Error` carries one
//! [`ErrorReason`] byte plus a UTF-8 message; `MetricsReq` is empty and
//! `MetricsText` carries the Prometheus text exposition — the PR 6
//! telemetry surfaces over the same transport as inference; `TraceDump`
//! is empty and `TraceJson` carries the retained traces of the PR 8
//! flight recorder as Chrome trace-event JSON; `SwapReq` carries the
//! new checkpoint path (UTF-8) in the payload with the model field
//! naming the model to swap, answered by `SwapOk` (`[old_epoch,
//! new_epoch]` as two LE u64s); `ModelsReq` is empty and `ModelsText`
//! carries a human-readable listing of the serving models.
//!
//! An `Infer` model field may carry an epoch pin (`name@<epoch>`,
//! see [`split_model_pin`]); `InferOk` replies echo the answering
//! epoch as `@<epoch>` in their model field, which pre-epoch clients
//! already ignore.
//!
//! Request ids make the protocol pipelined: a client may have many
//! requests outstanding on one connection and match replies by id (the
//! micro-batcher completes them in batch order, not submit order).
//!
//! Decoding is incremental: [`decode`] returns `Ok(None)` while the
//! accumulated bytes are still a prefix of a valid frame, so both event
//! loops just append reads to a buffer and poll it. Every decode error
//! is typed ([`FrameError`]) and maps onto the [`ErrorReason`] the
//! server answers with before closing the connection — a malformed
//! client costs its own connection, never the process.

use std::time::Duration;

use crate::obs::trace::TraceCtx;

/// First four bytes of every frame, "COMQ" as a LE u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"COMQ");

/// Current protocol version (v2 = v1 + the optional trace extension).
pub const WIRE_VERSION: u8 = 2;

/// Oldest version this build still decodes.
pub const WIRE_VERSION_MIN: u8 = 1;

/// Fixed header size in bytes (through `payload_len`) for a v1 frame.
pub const HEADER_LEN: usize = 24;

/// Bytes the v2 trace extension adds after the fixed header:
/// trace_id (u64) + flags (u8).
pub const TRACE_EXT_LEN: usize = 9;

/// Header length for a given wire version.
fn header_len(version: u8) -> usize {
    if version >= 2 { HEADER_LEN + TRACE_EXT_LEN } else { HEADER_LEN }
}

/// Hard cap on a frame's payload: a batch-1 image for any plausible
/// model fits well under this, and it bounds the per-connection buffer
/// a hostile client can make the server hold.
pub const MAX_PAYLOAD: usize = 1 << 24; // 16 MiB

/// Hard cap on the model-id length.
pub const MAX_MODEL_ID: usize = 256;

/// Frame discriminant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Client → server: run one image through `model`.
    Infer = 1,
    /// Server → client: the logits for `request_id`.
    InferOk = 2,
    /// Server → client: typed failure for `request_id`.
    Error = 3,
    /// Client → server: dump the metrics registry.
    MetricsReq = 4,
    /// Server → client: Prometheus text exposition.
    MetricsText = 5,
    /// Client → server: dump the retained traces.
    TraceDump = 6,
    /// Server → client: Chrome trace-event JSON.
    TraceJson = 7,
    /// Client → server (admin): hot-swap `model` to the checkpoint
    /// whose path is the UTF-8 payload.
    SwapReq = 8,
    /// Server → client: swap done; payload is `[old_epoch u64,
    /// new_epoch u64]` LE.
    SwapOk = 9,
    /// Client → server (admin): list the serving models.
    ModelsReq = 10,
    /// Server → client: human-readable model listing (UTF-8).
    ModelsText = 11,
}

impl FrameKind {
    fn from_u8(v: u8) -> Option<FrameKind> {
        match v {
            1 => Some(FrameKind::Infer),
            2 => Some(FrameKind::InferOk),
            3 => Some(FrameKind::Error),
            4 => Some(FrameKind::MetricsReq),
            5 => Some(FrameKind::MetricsText),
            6 => Some(FrameKind::TraceDump),
            7 => Some(FrameKind::TraceJson),
            8 => Some(FrameKind::SwapReq),
            9 => Some(FrameKind::SwapOk),
            10 => Some(FrameKind::ModelsReq),
            11 => Some(FrameKind::ModelsText),
            _ => None,
        }
    }
}

/// Why the server answered an [`FrameKind::Error`] frame. The
/// connection-fatal reasons (everything through `UnknownModel`) also
/// close the connection; the shed reasons (`DeadlineExceeded`,
/// `Overloaded`, `Shutdown`) answer only the one request, and a client
/// seeing `Overloaded` should back off before retrying.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorReason {
    BadMagic = 1,
    UnsupportedVersion = 2,
    Malformed = 3,
    Oversized = 4,
    UnknownModel = 5,
    /// Payload length is not a whole number of f32s or does not match
    /// the model's input geometry.
    BadPayload = 6,
    DeadlineExceeded = 7,
    Overloaded = 8,
    ExecutorPanicked = 9,
    Shutdown = 10,
    Internal = 11,
    /// The model id names a served model, but no epoch can answer right
    /// now — evicted, mid-load, failed verification, or the request
    /// pinned a retired epoch. Distinct from [`ErrorReason::UnknownModel`]
    /// (which is connection-fatal: the client asked for something this
    /// server never serves); `ModelUnavailable` is per-request and worth
    /// retrying after a backoff or without the stale pin.
    ModelUnavailable = 12,
}

impl ErrorReason {
    pub fn from_u8(v: u8) -> Option<ErrorReason> {
        use ErrorReason::*;
        match v {
            1 => Some(BadMagic),
            2 => Some(UnsupportedVersion),
            3 => Some(Malformed),
            4 => Some(Oversized),
            5 => Some(UnknownModel),
            6 => Some(BadPayload),
            7 => Some(DeadlineExceeded),
            8 => Some(Overloaded),
            9 => Some(ExecutorPanicked),
            10 => Some(Shutdown),
            11 => Some(Internal),
            12 => Some(ModelUnavailable),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        use ErrorReason::*;
        match self {
            BadMagic => "bad_magic",
            UnsupportedVersion => "unsupported_version",
            Malformed => "malformed",
            Oversized => "oversized",
            UnknownModel => "unknown_model",
            BadPayload => "bad_payload",
            DeadlineExceeded => "deadline_exceeded",
            Overloaded => "overloaded",
            ExecutorPanicked => "executor_panicked",
            Shutdown => "shutdown",
            Internal => "internal",
            ModelUnavailable => "model_unavailable",
        }
    }

    /// Whether the server closes the connection after answering this —
    /// protocol damage is connection-fatal, per-request sheds are not.
    pub fn closes_connection(&self) -> bool {
        use ErrorReason::*;
        matches!(
            self,
            BadMagic | UnsupportedVersion | Malformed | Oversized | UnknownModel | BadPayload
        )
    }
}

impl From<crate::serve::ServeError> for ErrorReason {
    fn from(e: crate::serve::ServeError) -> ErrorReason {
        use crate::serve::ServeError as S;
        match e {
            S::DeadlineExceeded => ErrorReason::DeadlineExceeded,
            S::Overloaded => ErrorReason::Overloaded,
            S::ExecutorPanicked => ErrorReason::ExecutorPanicked,
            S::Shutdown => ErrorReason::Shutdown,
        }
    }
}

/// A fully decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub request_id: u32,
    /// Latency budget in µs from the wire (`0` = no deadline).
    pub deadline_us: u64,
    pub model: String,
    pub payload: Vec<u8>,
    /// End-to-end trace context — `Some` iff the frame was a version-2
    /// frame carrying the 9-byte extension.
    pub trace: Option<TraceCtx>,
}

impl Frame {
    /// The deadline budget as a duration, if one was set.
    pub fn budget(&self) -> Option<Duration> {
        (self.deadline_us > 0).then(|| Duration::from_micros(self.deadline_us))
    }

    /// Interpret the payload as LE f32s (inference inputs / logits).
    pub fn payload_f32(&self) -> Result<Vec<f32>, FrameError> {
        if self.payload.len() % 4 != 0 {
            return Err(FrameError::Malformed("payload not a whole number of f32s"));
        }
        Ok(self
            .payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Split an `Error` frame payload into (reason, message).
    pub fn error_reason(&self) -> Result<(ErrorReason, String), FrameError> {
        let Some((&code, msg)) = self.payload.split_first() else {
            return Err(FrameError::Malformed("error frame without reason byte"));
        };
        let reason = ErrorReason::from_u8(code)
            .ok_or(FrameError::Malformed("unknown error reason code"))?;
        Ok((reason, String::from_utf8_lossy(msg).into_owned()))
    }
}

/// Typed decode failure. `Truncated` alone is recoverable (more bytes
/// may arrive); everything else is connection-fatal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameError {
    /// Not an error while the peer may still send more bytes; becomes
    /// one when the stream ends mid-frame.
    Truncated,
    BadMagic,
    UnsupportedVersion(u8),
    UnknownKind(u8),
    Oversized(usize),
    Malformed(&'static str),
}

impl FrameError {
    /// The wire reason the server answers with for this decode failure.
    pub fn reason(&self) -> ErrorReason {
        match self {
            FrameError::Truncated | FrameError::Malformed(_) => ErrorReason::Malformed,
            FrameError::BadMagic => ErrorReason::BadMagic,
            FrameError::UnsupportedVersion(_) | FrameError::UnknownKind(_) => {
                ErrorReason::UnsupportedVersion
            }
            FrameError::Oversized(_) => ErrorReason::Oversized,
        }
    }
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Truncated => write!(f, "stream ended mid-frame"),
            FrameError::BadMagic => write!(f, "bad magic (not a COMQ frame)"),
            FrameError::UnsupportedVersion(v) => {
                write!(f, "unsupported wire version {v} (this server speaks {WIRE_VERSION})")
            }
            FrameError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            FrameError::Oversized(n) => {
                write!(f, "declared payload {n} bytes exceeds the {MAX_PAYLOAD} cap")
            }
            FrameError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

impl std::error::Error for FrameError {}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn get_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}
fn get_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Encode a frame. The version byte follows the trace field: no
/// context → version 1 (byte-identical to the pre-trace wire), context
/// → version 2 with the 9-byte extension. Panics if model id or payload
/// exceed the wire caps — server-side frames are always under them and
/// the client validates before calling.
pub fn encode(frame: &Frame) -> Vec<u8> {
    assert!(frame.model.len() <= MAX_MODEL_ID, "model id too long for the wire");
    assert!(frame.payload.len() <= MAX_PAYLOAD, "payload too large for the wire");
    let version = if frame.trace.is_some() { 2 } else { 1 };
    let mut out =
        Vec::with_capacity(header_len(version) + frame.model.len() + frame.payload.len());
    put_u32(&mut out, MAGIC);
    out.push(version);
    out.push(frame.kind as u8);
    put_u32(&mut out, frame.request_id);
    put_u64(&mut out, frame.deadline_us);
    put_u16(&mut out, frame.model.len() as u16);
    put_u32(&mut out, frame.payload.len() as u32);
    if let Some(ctx) = frame.trace {
        put_u64(&mut out, ctx.id);
        out.push(ctx.flags);
    }
    out.extend_from_slice(frame.model.as_bytes());
    out.extend_from_slice(&frame.payload);
    out
}

/// Convenience encoders for the frames the server sends. The `_t`
/// variants attach a trace context (emitting a version-2 frame); the
/// plain names keep their pre-trace signatures and emit version 1.
pub fn encode_infer(request_id: u32, model: &str, deadline_us: u64, input: &[f32]) -> Vec<u8> {
    encode_infer_t(request_id, model, deadline_us, input, None)
}

pub fn encode_infer_t(
    request_id: u32,
    model: &str,
    deadline_us: u64,
    input: &[f32],
    trace: Option<TraceCtx>,
) -> Vec<u8> {
    let mut payload = Vec::with_capacity(input.len() * 4);
    for v in input {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    encode(&Frame {
        kind: FrameKind::Infer,
        request_id,
        deadline_us,
        model: model.to_string(),
        payload,
        trace,
    })
}

pub fn encode_infer_ok(request_id: u32, logits: &[f32]) -> Vec<u8> {
    encode_infer_ok_t(request_id, logits, None)
}

pub fn encode_infer_ok_t(request_id: u32, logits: &[f32], trace: Option<TraceCtx>) -> Vec<u8> {
    encode_infer_ok_pinned(request_id, logits, trace, None)
}

/// `InferOk` carrying the serving epoch that produced the logits in
/// the (otherwise unused) model field, as `@<epoch>` — old clients
/// ignore the field, epoch-aware ones surface the pin. `None` keeps
/// the pre-swap bytes bit-identical.
pub fn encode_infer_ok_pinned(
    request_id: u32,
    logits: &[f32],
    trace: Option<TraceCtx>,
    epoch: Option<u64>,
) -> Vec<u8> {
    let mut payload = Vec::with_capacity(logits.len() * 4);
    for v in logits {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    encode(&Frame {
        kind: FrameKind::InferOk,
        request_id,
        deadline_us: 0,
        model: epoch.map(|e| format!("@{e}")).unwrap_or_default(),
        payload,
        trace,
    })
}

pub fn encode_error(request_id: u32, reason: ErrorReason, msg: &str) -> Vec<u8> {
    encode_error_t(request_id, reason, msg, None)
}

pub fn encode_error_t(
    request_id: u32,
    reason: ErrorReason,
    msg: &str,
    trace: Option<TraceCtx>,
) -> Vec<u8> {
    let mut payload = Vec::with_capacity(1 + msg.len());
    payload.push(reason as u8);
    payload.extend_from_slice(msg.as_bytes());
    encode(&Frame {
        kind: FrameKind::Error,
        request_id,
        deadline_us: 0,
        model: String::new(),
        payload,
        trace,
    })
}

pub fn encode_metrics_req(request_id: u32) -> Vec<u8> {
    encode(&Frame {
        kind: FrameKind::MetricsReq,
        request_id,
        deadline_us: 0,
        model: String::new(),
        payload: Vec::new(),
        trace: None,
    })
}

pub fn encode_metrics_text(request_id: u32, text: &str) -> Vec<u8> {
    encode(&Frame {
        kind: FrameKind::MetricsText,
        request_id,
        deadline_us: 0,
        model: String::new(),
        payload: text.as_bytes().to_vec(),
        trace: None,
    })
}

pub fn encode_trace_dump(request_id: u32) -> Vec<u8> {
    encode(&Frame {
        kind: FrameKind::TraceDump,
        request_id,
        deadline_us: 0,
        model: String::new(),
        payload: Vec::new(),
        trace: None,
    })
}

pub fn encode_trace_json(request_id: u32, json: &str) -> Vec<u8> {
    encode(&Frame {
        kind: FrameKind::TraceJson,
        request_id,
        deadline_us: 0,
        model: String::new(),
        payload: json.as_bytes().to_vec(),
        trace: None,
    })
}

pub fn encode_swap_req(request_id: u32, model: &str, path: &str) -> Vec<u8> {
    encode(&Frame {
        kind: FrameKind::SwapReq,
        request_id,
        deadline_us: 0,
        model: model.to_string(),
        payload: path.as_bytes().to_vec(),
        trace: None,
    })
}

pub fn encode_swap_ok(request_id: u32, old_epoch: u64, new_epoch: u64) -> Vec<u8> {
    let mut payload = Vec::with_capacity(16);
    payload.extend_from_slice(&old_epoch.to_le_bytes());
    payload.extend_from_slice(&new_epoch.to_le_bytes());
    encode(&Frame {
        kind: FrameKind::SwapOk,
        request_id,
        deadline_us: 0,
        model: String::new(),
        payload,
        trace: None,
    })
}

pub fn encode_models_req(request_id: u32) -> Vec<u8> {
    encode(&Frame {
        kind: FrameKind::ModelsReq,
        request_id,
        deadline_us: 0,
        model: String::new(),
        payload: Vec::new(),
        trace: None,
    })
}

pub fn encode_models_text(request_id: u32, text: &str) -> Vec<u8> {
    encode(&Frame {
        kind: FrameKind::ModelsText,
        request_id,
        deadline_us: 0,
        model: String::new(),
        payload: text.as_bytes().to_vec(),
        trace: None,
    })
}

/// Split a `SwapOk` payload into `(old_epoch, new_epoch)`.
pub fn swap_ok_epochs(payload: &[u8]) -> Result<(u64, u64), FrameError> {
    if payload.len() != 16 {
        return Err(FrameError::Malformed("SwapOk payload must be 16 bytes"));
    }
    Ok((get_u64(&payload[..8]), get_u64(&payload[8..])))
}

/// Split a request's model field into `(name, epoch pin)`: a trailing
/// `@<integer>` is a version pin; everything else is a bare name.
/// Splitting at the *last* `@` keeps names containing `@` unambiguous
/// as long as the final segment is numeric.
pub fn split_model_pin(model: &str) -> (&str, Option<u64>) {
    match model.rsplit_once('@') {
        Some((name, e)) if !e.is_empty() => match e.parse::<u64>() {
            Ok(epoch) => (name, Some(epoch)),
            Err(_) => (model, None),
        },
        _ => (model, None),
    }
}

/// Incremental decode: `Ok(Some((frame, consumed)))` when `buf` starts
/// with a complete frame, `Ok(None)` when it is a (possibly empty)
/// prefix of one, `Err` when it can never become a valid frame. Size
/// caps are enforced from the *declared* lengths, before the bytes
/// arrive, so an oversized frame is rejected without buffering it.
pub fn decode(buf: &[u8]) -> Result<Option<(Frame, usize)>, FrameError> {
    // reject garbage from the earliest byte that proves it
    if !buf.is_empty() {
        let upto = buf.len().min(4);
        if buf[..upto] != MAGIC.to_le_bytes()[..upto] {
            return Err(FrameError::BadMagic);
        }
    }
    if buf.len() >= 5 && !(WIRE_VERSION_MIN..=WIRE_VERSION).contains(&buf[4]) {
        return Err(FrameError::UnsupportedVersion(buf[4]));
    }
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    let version = buf[4];
    let hlen = header_len(version);
    if buf.len() < hlen {
        return Ok(None);
    }
    let kind = FrameKind::from_u8(buf[5]).ok_or(FrameError::UnknownKind(buf[5]))?;
    let request_id = get_u32(&buf[6..10]);
    let deadline_us = get_u64(&buf[10..18]);
    let model_len = u16::from_le_bytes([buf[18], buf[19]]) as usize;
    let payload_len = get_u32(&buf[20..24]) as usize;
    if model_len > MAX_MODEL_ID {
        return Err(FrameError::Malformed("model id exceeds the wire cap"));
    }
    if payload_len > MAX_PAYLOAD {
        return Err(FrameError::Oversized(payload_len));
    }
    let trace = (version >= 2)
        .then(|| TraceCtx { id: get_u64(&buf[HEADER_LEN..HEADER_LEN + 8]), flags: buf[HEADER_LEN + 8] });
    let total = hlen + model_len + payload_len;
    if buf.len() < total {
        return Ok(None);
    }
    let model = std::str::from_utf8(&buf[hlen..hlen + model_len])
        .map_err(|_| FrameError::Malformed("model id is not UTF-8"))?
        .to_string();
    let payload = buf[hlen + model_len..total].to_vec();
    Ok(Some((Frame { kind, request_id, deadline_us, model, payload, trace }, total)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_frame_round_trips() {
        let bytes = encode_infer(42, "tiny_plain", 1500, &[1.0, -2.5, 0.0]);
        let (f, used) = decode(&bytes).unwrap().unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(f.kind, FrameKind::Infer);
        assert_eq!(f.request_id, 42);
        assert_eq!(f.deadline_us, 1500);
        assert_eq!(f.budget(), Some(Duration::from_micros(1500)));
        assert_eq!(f.model, "tiny_plain");
        assert_eq!(f.payload_f32().unwrap(), vec![1.0, -2.5, 0.0]);
    }

    #[test]
    fn error_frame_round_trips() {
        let bytes = encode_error(7, ErrorReason::Overloaded, "queue full");
        let (f, _) = decode(&bytes).unwrap().unwrap();
        assert_eq!(f.kind, FrameKind::Error);
        let (reason, msg) = f.error_reason().unwrap();
        assert_eq!(reason, ErrorReason::Overloaded);
        assert_eq!(msg, "queue full");
        assert!(!reason.closes_connection());
        assert!(ErrorReason::Oversized.closes_connection());
    }

    #[test]
    fn metrics_frames_round_trip() {
        let (req, _) = decode(&encode_metrics_req(1)).unwrap().unwrap();
        assert_eq!(req.kind, FrameKind::MetricsReq);
        let (txt, _) = decode(&encode_metrics_text(1, "comq_up 1\n")).unwrap().unwrap();
        assert_eq!(txt.kind, FrameKind::MetricsText);
        assert_eq!(txt.payload, b"comq_up 1\n");
    }

    #[test]
    fn incremental_decode_needs_more_then_completes() {
        let bytes = encode_infer(9, "m", 0, &[3.5; 8]);
        for cut in 0..bytes.len() {
            assert_eq!(decode(&bytes[..cut]).unwrap(), None, "prefix of {cut} bytes");
        }
        // two frames back to back: first decodes with its exact length
        let mut two = bytes.clone();
        two.extend_from_slice(&bytes);
        let (f, used) = decode(&two).unwrap().unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(f.request_id, 9);
        let (f2, _) = decode(&two[used..]).unwrap().unwrap();
        assert_eq!(f2.request_id, 9);
    }

    #[test]
    fn garbage_rejected_from_first_divergent_byte() {
        assert_eq!(decode(b"GET / HTTP/1.1\r\n"), Err(FrameError::BadMagic));
        // even a single wrong byte is enough
        assert_eq!(decode(b"X"), Err(FrameError::BadMagic));
        // a correct prefix of the magic is still "need more"
        assert_eq!(decode(b"CO").unwrap(), None);
    }

    #[test]
    fn version_and_kind_are_checked() {
        let mut bytes = encode_metrics_req(0);
        bytes[4] = 9;
        assert_eq!(decode(&bytes), Err(FrameError::UnsupportedVersion(9)));
        assert_eq!(FrameError::UnsupportedVersion(9).reason(), ErrorReason::UnsupportedVersion);
        let mut bytes = encode_metrics_req(0);
        bytes[5] = 200;
        assert_eq!(decode(&bytes), Err(FrameError::UnknownKind(200)));
    }

    #[test]
    fn oversized_rejected_from_declared_length() {
        let mut bytes = encode_metrics_req(0);
        // declare a payload over the cap without sending it
        bytes[20..24].copy_from_slice(&((MAX_PAYLOAD as u32) + 1).to_le_bytes());
        match decode(&bytes) {
            Err(FrameError::Oversized(n)) => assert_eq!(n, MAX_PAYLOAD + 1),
            other => panic!("expected Oversized, got {other:?}"),
        }
        assert_eq!(FrameError::Oversized(0).reason(), ErrorReason::Oversized);
    }

    #[test]
    fn payload_f32_rejects_ragged_lengths() {
        let mut f = Frame {
            kind: FrameKind::Infer,
            request_id: 0,
            deadline_us: 0,
            model: "m".into(),
            payload: vec![0u8; 6],
            trace: None,
        };
        assert!(f.payload_f32().is_err());
        f.payload = vec![0u8; 8];
        assert_eq!(f.payload_f32().unwrap(), vec![0.0, 0.0]);
    }

    #[test]
    fn untraced_frames_stay_version_1_bit_identical() {
        // a tracing-aware build must keep emitting the pre-trace wire
        // for untraced frames: version byte 1, 24-byte header
        let bytes = encode_infer(3, "m", 0, &[1.0]);
        assert_eq!(bytes[4], 1);
        assert_eq!(bytes.len(), HEADER_LEN + 1 + 4);
        let (f, _) = decode(&bytes).unwrap().unwrap();
        assert_eq!(f.trace, None);
    }

    #[test]
    fn traced_frame_round_trips_version_2() {
        let ctx = TraceCtx { id: 0xABCD_EF01_2345_6789, flags: 1 };
        let bytes = encode_infer_t(42, "tiny_plain", 1500, &[1.0, -2.5], Some(ctx));
        assert_eq!(bytes[4], 2);
        assert_eq!(bytes.len(), HEADER_LEN + TRACE_EXT_LEN + 10 + 8);
        let (f, used) = decode(&bytes).unwrap().unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(f.trace, Some(ctx));
        assert_eq!(f.model, "tiny_plain");
        assert_eq!(f.payload_f32().unwrap(), vec![1.0, -2.5]);
        // the reply-side encoders carry the context back the same way
        let (ok, _) = decode(&encode_infer_ok_t(42, &[0.5], Some(ctx))).unwrap().unwrap();
        assert_eq!(ok.trace, Some(ctx));
        let (err, _) =
            decode(&encode_error_t(42, ErrorReason::Overloaded, "q", Some(ctx))).unwrap().unwrap();
        assert_eq!(err.trace, Some(ctx));
    }

    #[test]
    fn v2_incremental_decode_needs_more_then_completes() {
        let ctx = TraceCtx { id: 7, flags: 0 };
        let bytes = encode_infer_t(9, "m", 0, &[3.5; 8], Some(ctx));
        for cut in 0..bytes.len() {
            assert_eq!(decode(&bytes[..cut]).unwrap(), None, "prefix of {cut} bytes");
        }
        let (f, used) = decode(&bytes).unwrap().unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(f.trace, Some(ctx));
    }

    #[test]
    fn version_3_rejected_version_1_still_decodes() {
        let mut bytes = encode_metrics_req(0);
        assert_eq!(bytes[4], 1, "untraced frames are v1");
        assert!(decode(&bytes).unwrap().is_some(), "v1 must keep decoding");
        bytes[4] = 3;
        assert_eq!(decode(&bytes), Err(FrameError::UnsupportedVersion(3)));
    }

    #[test]
    fn trace_frames_round_trip() {
        let (req, _) = decode(&encode_trace_dump(5)).unwrap().unwrap();
        assert_eq!(req.kind, FrameKind::TraceDump);
        assert!(req.payload.is_empty());
        let json = r#"{"traceEvents":[]}"#;
        let (resp, _) = decode(&encode_trace_json(5, json)).unwrap().unwrap();
        assert_eq!(resp.kind, FrameKind::TraceJson);
        assert_eq!(resp.payload, json.as_bytes());
        assert_eq!(resp.request_id, 5);
    }

    #[test]
    fn reason_codes_round_trip() {
        for code in 1..=12u8 {
            let r = ErrorReason::from_u8(code).unwrap();
            assert_eq!(r as u8, code, "{}", r.name());
        }
        assert_eq!(ErrorReason::from_u8(0), None);
        assert_eq!(ErrorReason::from_u8(13), None);
    }

    #[test]
    fn model_unavailable_is_per_request() {
        // the whole point of the reason: a retryable failure, unlike
        // UnknownModel which is connection-fatal
        assert!(!ErrorReason::ModelUnavailable.closes_connection());
        assert!(ErrorReason::UnknownModel.closes_connection());
    }

    #[test]
    fn swap_frames_round_trip() {
        let (req, _) = decode(&encode_swap_req(3, "tiny", "/ckpt/new.cqm")).unwrap().unwrap();
        assert_eq!(req.kind, FrameKind::SwapReq);
        assert_eq!(req.model, "tiny");
        assert_eq!(req.payload, b"/ckpt/new.cqm");
        let (ok, _) = decode(&encode_swap_ok(3, 1, 2)).unwrap().unwrap();
        assert_eq!(ok.kind, FrameKind::SwapOk);
        assert_eq!(swap_ok_epochs(&ok.payload).unwrap(), (1, 2));
        assert!(swap_ok_epochs(&[0u8; 7]).is_err());
    }

    #[test]
    fn models_frames_round_trip() {
        let (req, _) = decode(&encode_models_req(4)).unwrap().unwrap();
        assert_eq!(req.kind, FrameKind::ModelsReq);
        assert!(req.payload.is_empty());
        let (txt, _) = decode(&encode_models_text(4, "tiny epoch=2\n")).unwrap().unwrap();
        assert_eq!(txt.kind, FrameKind::ModelsText);
        assert_eq!(txt.payload, b"tiny epoch=2\n");
    }

    #[test]
    fn model_pin_parsing() {
        assert_eq!(split_model_pin("tiny"), ("tiny", None));
        assert_eq!(split_model_pin("tiny@3"), ("tiny", Some(3)));
        assert_eq!(split_model_pin("tiny@"), ("tiny@", None));
        assert_eq!(split_model_pin("tiny@next"), ("tiny@next", None));
        assert_eq!(split_model_pin("a@b@7"), ("a@b", Some(7)));
        assert_eq!(split_model_pin(""), ("", None));
    }

    #[test]
    fn pinned_infer_ok_carries_epoch_and_stays_v1() {
        let bytes = encode_infer_ok_pinned(8, &[0.5, 1.5], None, Some(4));
        assert_eq!(bytes[4], 1, "pin must not force the v2 extension");
        let (f, _) = decode(&bytes).unwrap().unwrap();
        assert_eq!(f.model, "@4");
        assert_eq!(split_model_pin(&f.model), ("", Some(4)));
        // un-pinned replies stay byte-identical to the pre-epoch wire
        assert_eq!(encode_infer_ok_pinned(8, &[0.5], None, None), encode_infer_ok(8, &[0.5]));
    }
}
