//! The network front door: a TCP server speaking the COMQ wire format
//! ([`super::frame`]) in front of the micro-batcher.
//!
//! ## Architecture
//!
//! One event-loop thread owns every connection (epoll on Linux via the
//! [`super::epoll`] wrapper; a portable thread-per-connection loop
//! elsewhere, also selectable with [`NetConfig::force_fallback`] so the
//! portable path stays tested on Linux). Inference never runs on the
//! loop thread: a decoded `Infer` frame is admitted, stamped with its
//! absolute deadline, and submitted to the per-model [`Server`] with a
//! completion callback that encodes the reply frame and hands it back
//! to the transport (completion queue + wake pipe for epoll, a direct
//! locked write for the fallback). Request ids make the connection
//! pipelined: replies go out in completion order and the client matches
//! them by id.
//!
//! ## Robustness contract
//!
//! * **Deadline propagation** — the frame's `deadline_us` budget
//!   becomes an absolute deadline at decode time and rides into the
//!   batcher, which tightens the coalesce window and sheds expired
//!   requests before the GEMM (`Err(DeadlineExceeded)` → a typed error
//!   frame).
//! * **Admission + load shedding** — per-model in-flight tokens and a
//!   live queue-depth check ([`super::admission`]) run *before* the
//!   queue; a shed answers an `Overloaded` frame on an otherwise
//!   healthy connection and counts in
//!   `comq_serve_shed_total{model,reason="overload"}`.
//! * **Protocol damage is connection-fatal, sheds are not** — a frame
//!   that can never parse answers a typed error with request id 0 and
//!   closes that one connection; other connections and the model
//!   registry are untouched.
//! * **Graceful drain** — [`NetServer::shutdown`] stops accepting,
//!   answers everything already submitted (bounded by
//!   [`NetConfig::drain_timeout`]), flushes, then joins the loop and
//!   the batcher executors.
//! * **Hot-swap without drops** — a `SwapReq` loads the new checkpoint
//!   off the event loop while the old epoch keeps serving, flips the
//!   model's current [`ModelEpoch`] atomically, then drains the old
//!   epoch so every request it admitted is answered from the weights
//!   it was admitted under. Replies carry the answering epoch; a
//!   client that pins `model@<epoch>` gets a retryable
//!   `ModelUnavailable` error once that epoch is retired, never
//!   silently different weights.
//! * **Fault containment** — a panic while handling a frame
//!   (`COMQ_FAULT=panic:conn`) is caught per-frame; the client gets an
//!   `Internal` error frame and loses only its own connection.

use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::manifest::ModelInfo;
use crate::obs::metrics::with_labels;
use crate::obs::recorder::{self, RecKind};
use crate::obs::trace;
use crate::obs::{Counter, Gauge};
use crate::serve::model;
use crate::serve::net::admission::{Admission, AdmissionConfig};
use crate::serve::net::fault;
use crate::serve::net::frame::{self, ErrorReason, Frame, FrameKind};
use crate::serve::{BatchConfig, QuantizedModel, Responder, Server};

/// Hard cap on one connection's pending write backlog; a client that
/// stops reading past this point is treated as gone rather than letting
/// it pin server memory.
const MAX_WBUF: usize = 1 << 26; // 64 MiB

/// Network tier tuning.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Micro-batcher tuning for every served model.
    pub batch: BatchConfig,
    /// Per-model admission control.
    pub admission: AdmissionConfig,
    /// How long [`NetServer::shutdown`] waits for in-flight requests to
    /// be answered and flushed before giving up on the stragglers.
    pub drain_timeout: Duration,
    /// Use the portable connection-thread loop even where epoll is
    /// available (tests exercise both transports on Linux).
    pub force_fallback: bool,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            batch: BatchConfig::default(),
            admission: AdmissionConfig::default(),
            drain_timeout: Duration::from_secs(5),
            force_fallback: false,
        }
    }
}

/// Cumulative network-tier counters (always on, independent of
/// `COMQ_OBS` — the integration tests reconcile these against injected
/// fault counts exactly).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetStats {
    /// Connections accepted (including fault-dropped ones).
    pub connections: usize,
    /// Connections closed right after accept by `COMQ_FAULT=drop_conn`.
    pub dropped_conns: usize,
    /// Frames dispatched (any kind).
    pub frames: usize,
    /// Error frames sent.
    pub error_frames: usize,
    /// Requests currently between admission and reply.
    pub inflight: usize,
    /// Bytes read from / written to clients.
    pub rx_bytes: usize,
    pub tx_bytes: usize,
}

#[derive(Default)]
struct Counters {
    connections: AtomicUsize,
    dropped_conns: AtomicUsize,
    frames: AtomicUsize,
    error_frames: AtomicUsize,
    rx_bytes: AtomicUsize,
    tx_bytes: AtomicUsize,
}

/// Registry handles for the exported `comq_net_*` metrics (present only
/// when `COMQ_OBS` was on at bind time; the always-on [`Counters`]
/// carry the same numbers for tests and `stats()`).
struct NetObs {
    connections: Arc<Counter>,
    open: Arc<Gauge>,
    frames: Arc<Counter>,
    rx_bytes: Arc<Counter>,
    tx_bytes: Arc<Counter>,
    /// Connections closed right after accept (fault-injected) —
    /// mirrors [`NetStats::dropped_conns`] into the registry export.
    dropped: Arc<Counter>,
    /// Requests between admission and reply — mirrors
    /// [`NetStats::inflight`].
    inflight: Arc<Gauge>,
}

impl NetObs {
    fn new() -> NetObs {
        let reg = crate::obs::registry();
        NetObs {
            connections: reg.counter("comq_net_connections_total"),
            open: reg.gauge("comq_net_open_connections"),
            frames: reg.counter("comq_net_frames_total"),
            rx_bytes: reg.counter("comq_net_rx_bytes_total"),
            tx_bytes: reg.counter("comq_net_tx_bytes_total"),
            dropped: reg.counter("comq_net_dropped_conns_total"),
            inflight: reg.gauge("comq_net_inflight"),
        }
    }

    /// Per-reason error-frame counter, created on demand (errors are
    /// rare; the registry lookup is off the hot path).
    fn error(&self, reason: ErrorReason) {
        crate::obs::registry()
            .counter(&with_labels("comq_net_error_frames_total", &[("reason", reason.name())]))
            .inc();
    }
}

/// One live generation of a served model: a micro-batcher bound to one
/// set of weights, tagged with the epoch clients may pin
/// (`model@<epoch>` on the wire). A hot-swap builds the next
/// `ModelEpoch` off-path, atomically flips the entry's `current` Arc,
/// then drains this one — every request it admitted is answered from
/// the weights the client saw at admission time.
///
/// `Deref`s to the inner [`Server`], so handles returned by
/// [`NetServer::model_server`] keep their `.stats()` /
/// `.queue_depth()` call shape.
pub struct ModelEpoch {
    /// Monotonic per-model generation; the first bind is epoch 1.
    pub epoch: u64,
    /// f32 elements one image must carry (`side·side·3`).
    elems: usize,
    /// Registry key path this epoch was loaded from (`None` for models
    /// handed to [`NetServer::bind`] as already-built Arcs). Retired
    /// from the registry as `superseded` when a swap replaces it.
    source: Option<String>,
    /// One-line description for the `comq models` listing, captured at
    /// build time (the batcher owns the model afterwards).
    desc: String,
    server: Server,
}

impl std::ops::Deref for ModelEpoch {
    type Target = Server;

    fn deref(&self) -> &Server {
        &self.server
    }
}

impl ModelEpoch {
    fn build(
        epoch: u64,
        qm: Arc<QuantizedModel>,
        source: Option<String>,
        batch: BatchConfig,
    ) -> ModelEpoch {
        let side = qm.input_side();
        let desc = format!(
            "bits={} act={} integrity={} resident={}B",
            qm.weight_bits_label(),
            qm.act_source().bits(),
            qm.integrity().name(),
            qm.resident_bytes()
        );
        ModelEpoch {
            epoch,
            elems: side * side * 3,
            source,
            desc,
            server: Server::start(qm, batch),
        }
    }
}

struct ModelEntry {
    /// Architecture/config identity reused to decode swapped-in
    /// checkpoints — a swap replaces weights, never the architecture.
    info: ModelInfo,
    current: Mutex<Arc<ModelEpoch>>,
    /// Shared across epochs on purpose: a swap must not reset the
    /// in-flight token bucket underneath admitted requests.
    admission: Arc<Admission>,
    batch: BatchConfig,
    next_epoch: AtomicU64,
}

impl ModelEntry {
    fn current(&self) -> Arc<ModelEpoch> {
        self.current.lock().unwrap().clone()
    }
}

/// State shared between the listener loop, connection handlers and
/// completion callbacks.
struct Inner {
    models: BTreeMap<String, ModelEntry>,
    draining: AtomicBool,
    /// Requests between admission and reply, across all models.
    inflight: AtomicUsize,
    drain_timeout: Duration,
    counters: Counters,
    obs: Option<NetObs>,
}

impl Inner {
    fn note_accept(&self, kept: bool) {
        self.counters.connections.fetch_add(1, Ordering::Relaxed);
        if let Some(o) = &self.obs {
            o.connections.inc();
            if kept {
                o.open.inc();
            }
        }
    }

    fn note_dropped_conn(&self) {
        self.counters.dropped_conns.fetch_add(1, Ordering::Relaxed);
        if let Some(o) = &self.obs {
            o.dropped.inc();
        }
        recorder::note(RecKind::DropConn, "accept-time drop (injected fault)");
    }

    fn note_conn_closed(&self) {
        if let Some(o) = &self.obs {
            o.open.dec();
        }
    }

    fn note_rx(&self, n: usize) {
        self.counters.rx_bytes.fetch_add(n, Ordering::Relaxed);
        if let Some(o) = &self.obs {
            o.rx_bytes.add(n as u64);
        }
    }

    fn note_tx(&self, n: usize) {
        self.counters.tx_bytes.fetch_add(n, Ordering::Relaxed);
        if let Some(o) = &self.obs {
            o.tx_bytes.add(n as u64);
        }
    }

    fn note_frame(&self) {
        self.counters.frames.fetch_add(1, Ordering::Relaxed);
        if let Some(o) = &self.obs {
            o.frames.inc();
        }
    }

    fn note_error(&self, reason: ErrorReason) {
        self.counters.error_frames.fetch_add(1, Ordering::Relaxed);
        if let Some(o) = &self.obs {
            o.error(reason);
        }
        // every error frame lands in the flight recorder as exactly one
        // note, so recorder counts reconcile against `error_frames`
        recorder::note(rec_kind(reason), reason.name());
    }
}

/// The flight-recorder kind one error frame records as: typed sheds are
/// `Shed`, executor panics are `Panic`, protocol/validation failures
/// are `ErrorFrame`. The partition is total, so
/// `count(Shed) + count(Panic) + count(ErrorFrame)` equals the
/// [`NetStats::error_frames`] counter for a run traced end to end.
fn rec_kind(reason: ErrorReason) -> RecKind {
    match reason {
        ErrorReason::DeadlineExceeded | ErrorReason::Overloaded | ErrorReason::Shutdown => {
            RecKind::Shed
        }
        ErrorReason::ExecutorPanicked => RecKind::Panic,
        _ => RecKind::ErrorFrame,
    }
}

/// Build (and count) an error frame.
fn error_reply(inner: &Inner, request_id: u32, reason: ErrorReason, msg: &str) -> Vec<u8> {
    inner.note_error(reason);
    frame::encode_error(request_id, reason, msg)
}

/// [`error_reply`] with a trace echo: `echo` is the request's wire
/// context when it carried one (a v1 client is never sent a v2 frame).
fn error_reply_t(
    inner: &Inner,
    request_id: u32,
    reason: ErrorReason,
    msg: &str,
    echo: Option<trace::TraceCtx>,
) -> Vec<u8> {
    inner.note_error(reason);
    frame::encode_error_t(request_id, reason, msg, echo)
}

/// What handling one frame produced.
enum Handled {
    /// Send these bytes now; `close` ends the connection after the
    /// flush (protocol damage is connection-fatal).
    Reply { bytes: Vec<u8>, close: bool },
    /// Submitted to the batcher; the completion callback owns the
    /// reply.
    Async,
}

/// Handle one decoded frame. Transport-agnostic: `complete` delivers
/// the encoded reply of an async (batched) request back to whichever
/// loop owns the connection. Callers wrap this in `catch_unwind` — an
/// injected `panic:conn` must cost one connection, not the process.
fn dispatch(
    inner: &Arc<Inner>,
    f: Frame,
    complete: Box<dyn FnOnce(Vec<u8>) + Send + 'static>,
) -> Handled {
    fault::maybe_panic(fault::Site::Conn);
    inner.note_frame();
    // request ingress timestamp: the root of the traced span tree. A
    // wire context is *ignored* when tracing is off, so `COMQ_TRACE=off`
    // keeps every buffer empty whatever clients send.
    let t_in = trace::enabled().then(Instant::now);
    let rid = f.request_id;
    match f.kind {
        FrameKind::MetricsReq => {
            let text = crate::obs::registry().to_prometheus();
            Handled::Reply { bytes: frame::encode_metrics_text(rid, &text), close: false }
        }
        FrameKind::TraceDump => {
            let json = trace::export_chrome();
            Handled::Reply { bytes: frame::encode_trace_json(rid, &json), close: false }
        }
        FrameKind::Infer => {
            // the traced identity of this request: the wire context, or
            // a server-minted id for old (v1) clients; replies echo the
            // context only when the request carried one on the wire
            let ctx = t_in.map(|_| f.trace.unwrap_or_else(trace::mint_server));
            let echo = f.trace.and(ctx);
            // a pre-admission failure still produces a (tiny) trace:
            // one error span plus a retained-as-error completion
            let fail = |reason: ErrorReason, msg: &str, close: bool| -> Handled {
                if let (Some(c), Some(t0)) = (ctx, t_in) {
                    let now = Instant::now();
                    trace::event(c.id, format!("error:{}", reason.name()), t0, now);
                    trace::finish(
                        c.id,
                        now.saturating_duration_since(t0).as_nanos() as u64,
                        reason.name(),
                    );
                }
                Handled::Reply { bytes: error_reply_t(inner, rid, reason, msg, echo), close }
            };
            // `model@<epoch>` pins the request to one weight
            // generation; a bare name takes whatever is current
            let (mname, pin) = frame::split_model_pin(&f.model);
            let Some(entry) = inner.models.get(mname) else {
                let msg = format!("unknown model '{mname}'");
                return fail(ErrorReason::UnknownModel, &msg, true);
            };
            // hold the epoch lock through the submit: a concurrent
            // swap can only flip before this pin check or after the
            // request is safely in the old epoch's queue (which the
            // swap then drains and answers) — never in between. No
            // admitted request ever lands on a dead batcher.
            let cur = entry.current.lock().unwrap();
            if let Some(p) = pin {
                if p != cur.epoch {
                    let msg = format!(
                        "model '{mname}' epoch {p} retired; current is {}",
                        cur.epoch
                    );
                    return fail(ErrorReason::ModelUnavailable, &msg, false);
                }
            }
            let input = match f.payload_f32() {
                Ok(v) => v,
                Err(e) => return fail(ErrorReason::BadPayload, &e.to_string(), true),
            };
            if input.len() != cur.elems {
                let msg = format!(
                    "payload carries {} f32s; model '{mname}' wants {}",
                    input.len(),
                    cur.elems
                );
                return fail(ErrorReason::BadPayload, &msg, true);
            }
            if inner.draining.load(Ordering::Acquire) {
                return fail(ErrorReason::Shutdown, "server is draining", false);
            }
            // admission: queue depth first (leading indicator), then the
            // in-flight token bucket; a shed answers Overloaded on an
            // otherwise healthy connection
            if entry.admission.queue_is_full(cur.server.queue_depth()) {
                cur.server.note_overload_shed();
                return fail(ErrorReason::Overloaded, "queue full, back off", false);
            }
            let Some(permit) = entry.admission.try_acquire() else {
                cur.server.note_overload_shed();
                return fail(
                    ErrorReason::Overloaded,
                    "too many requests in flight, back off",
                    false,
                );
            };
            let deadline = f.budget().map(|b| Instant::now() + b);
            inner.inflight.fetch_add(1, Ordering::AcqRel);
            if let Some(o) = &inner.obs {
                o.inflight.inc();
            }
            if let (Some(c), Some(t0)) = (ctx, t_in) {
                trace::event(c.id, "admission", t0, Instant::now());
            }
            recorder::note(RecKind::Admit, mname);
            let inner2 = inner.clone();
            // replies carry the answering epoch (`@<n>` in the model
            // field) so clients can pin follow-ups to these weights
            let epoch = cur.epoch;
            cur.server.submit_traced(
                input,
                deadline,
                ctx,
                Responder::new(move |res| {
                    let t_wb = ctx.map(|_| Instant::now());
                    let mut bytes = match &res {
                        Ok(logits) => {
                            frame::encode_infer_ok_pinned(rid, logits, echo, Some(epoch))
                        }
                        Err(e) => {
                            let reason: ErrorReason = (*e).into();
                            inner2.note_error(reason);
                            frame::encode_error_t(rid, reason, &e.to_string(), echo)
                        }
                    };
                    if fault::garbage_reply() {
                        bytes[0] ^= 0xAA; // corrupt the magic, as injected
                    }
                    // deliver before decrementing: the drain loop exits
                    // on inflight==0 and must find these bytes queued
                    complete(bytes);
                    inner2.inflight.fetch_sub(1, Ordering::AcqRel);
                    if let Some(o) = &inner2.obs {
                        o.inflight.dec();
                    }
                    drop(permit);
                    // close the span tree: write-back, then the root
                    // request span, then the retention decision
                    if let (Some(c), Some(t0), Some(tw)) = (ctx, t_in, t_wb) {
                        let now = Instant::now();
                        trace::event(c.id, "write_back", tw, now);
                        trace::event(c.id, "request", t0, now);
                        let outcome = match &res {
                            Ok(_) => "ok",
                            Err(e) => e.name(),
                        };
                        trace::finish(
                            c.id,
                            now.saturating_duration_since(t0).as_nanos() as u64,
                            outcome,
                        );
                    }
                }),
            );
            Handled::Async
        }
        FrameKind::ModelsReq => {
            let mut text = String::new();
            for (name, e) in &inner.models {
                let cur = e.current();
                text.push_str(&format!("{name}\tepoch={}\t{}\n", cur.epoch, cur.desc));
            }
            let st = model::registry_stats();
            text.push_str(&format!(
                "registry\tentries={}\tresident={}B\tloads={}\tload_failures={}\tswaps={}\t\
                 evictions={}\n",
                st.len, st.resident_bytes, st.loads, st.load_failures, st.swaps, st.evictions
            ));
            Handled::Reply { bytes: frame::encode_models_text(rid, &text), close: false }
        }
        FrameKind::SwapReq => {
            if inner.draining.load(Ordering::Acquire) {
                return Handled::Reply {
                    bytes: error_reply(inner, rid, ErrorReason::Shutdown, "server is draining"),
                    close: false,
                };
            }
            let name = f.model;
            let path = match String::from_utf8(f.payload) {
                Ok(p) if !p.trim().is_empty() => p,
                _ => {
                    return Handled::Reply {
                        bytes: error_reply(
                            inner,
                            rid,
                            ErrorReason::Malformed,
                            "SwapReq payload must be a utf-8 checkpoint path",
                        ),
                        close: true,
                    }
                }
            };
            if !inner.models.contains_key(&name) {
                let msg = format!("unknown model '{name}'");
                return Handled::Reply {
                    bytes: error_reply(inner, rid, ErrorReason::UnknownModel, &msg),
                    close: true,
                };
            }
            // the load + flip runs on its own thread: decode + panel
            // prep can take arbitrarily long (COMQ_FAULT=slow_load) and
            // must never stall the event loop. The reply rides the
            // normal completion path, so it mirrors an async infer's
            // in-flight accounting and the drain loop waits for it.
            inner.inflight.fetch_add(1, Ordering::AcqRel);
            if let Some(o) = &inner.obs {
                o.inflight.inc();
            }
            let inner2 = inner.clone();
            let spawned = std::thread::Builder::new().name("comq-swap".into()).spawn(move || {
                let done = catch_unwind(AssertUnwindSafe(|| swap_model(&inner2, &name, &path)));
                let bytes = match done {
                    Ok(Ok((old, new))) => frame::encode_swap_ok(rid, old, new),
                    Ok(Err(msg)) => {
                        error_reply(&inner2, rid, ErrorReason::ModelUnavailable, &msg)
                    }
                    Err(_) => error_reply(
                        &inner2,
                        rid,
                        ErrorReason::Internal,
                        "panic during hot-swap; old model still serving",
                    ),
                };
                complete(bytes);
                inner2.inflight.fetch_sub(1, Ordering::AcqRel);
                if let Some(o) = &inner2.obs {
                    o.inflight.dec();
                }
            });
            match spawned {
                Ok(_) => Handled::Async,
                Err(_) => {
                    inner.inflight.fetch_sub(1, Ordering::AcqRel);
                    if let Some(o) = &inner.obs {
                        o.inflight.dec();
                    }
                    Handled::Reply {
                        bytes: error_reply(
                            inner,
                            rid,
                            ErrorReason::Internal,
                            "cannot spawn the swap thread",
                        ),
                        close: false,
                    }
                }
            }
        }
        FrameKind::InferOk
        | FrameKind::Error
        | FrameKind::MetricsText
        | FrameKind::TraceJson
        | FrameKind::SwapOk
        | FrameKind::ModelsText => Handled::Reply {
            bytes: error_reply(
                inner,
                rid,
                ErrorReason::Malformed,
                "client sent a server-only frame kind",
            ),
            close: true,
        },
    }
}

/// The hot-swap itself: load `path` through the model registry (the
/// old epoch keeps serving during the decode + panel prep), start a
/// fresh batcher, flip the entry's `current` Arc, then drain the old
/// epoch — everything it admitted is answered from the old weights, so
/// a swap under live traffic drops nothing. The retired epoch's
/// registry entry is evicted as `superseded`.
fn swap_model(inner: &Inner, name: &str, path: &str) -> Result<(u64, u64), String> {
    let entry =
        inner.models.get(name).ok_or_else(|| format!("unknown model '{name}'"))?;
    // a swap must pick up the bytes on disk *now* (the common case is
    // re-quantizing in place), so any cached entry for this exact
    // key is retired before the load rather than short-circuiting it
    model::retire_cached(name, path);
    let qm = model::load_with_info(entry.info.clone(), path)
        .map_err(|e| format!("loading '{path}': {e:#}"))?;
    let epoch = entry.next_epoch.fetch_add(1, Ordering::Relaxed);
    let fresh =
        Arc::new(ModelEpoch::build(epoch, qm, Some(path.to_string()), entry.batch.clone()));
    let old = std::mem::replace(&mut *entry.current.lock().unwrap(), fresh);
    // drain-and-answer: joins the old epoch's executors after every
    // queued request replies from the weights it was admitted under
    old.server.shutdown();
    if let Some(src) = &old.source {
        model::retire_cached(name, src);
    }
    model::note_swap(name, &format!("epoch {} -> {epoch} ({path})", old.epoch));
    Ok((old.epoch, epoch))
}

/// Result of feeding buffered bytes through decode + dispatch.
struct Pumped {
    /// Immediate replies (errors, metrics) to queue for writing.
    replies: Vec<Vec<u8>>,
    /// Frames submitted to the batcher by this pump.
    started: usize,
    /// The connection must close once `replies` flush.
    close: bool,
}

/// Decode and dispatch every complete frame in `rbuf`. `eof` marks the
/// read side closed: leftover bytes then mean the stream ended
/// mid-frame (a typed error), and the connection winds down either way.
fn pump_frames(
    inner: &Arc<Inner>,
    rbuf: &mut Vec<u8>,
    eof: bool,
    mut mk_complete: impl FnMut() -> Box<dyn FnOnce(Vec<u8>) + Send + 'static>,
) -> Pumped {
    let mut out = Pumped { replies: Vec::new(), started: 0, close: false };
    let mut consumed = 0usize;
    loop {
        match frame::decode(&rbuf[consumed..]) {
            Ok(Some((f, used))) => {
                consumed += used;
                match catch_unwind(AssertUnwindSafe(|| dispatch(inner, f, mk_complete()))) {
                    Ok(Handled::Reply { bytes, close }) => {
                        out.replies.push(bytes);
                        out.close |= close;
                    }
                    Ok(Handled::Async) => out.started += 1,
                    Err(_) => {
                        crate::log_warn!(
                            "net: panic while handling a frame; closing that connection"
                        );
                        out.replies.push(error_reply(
                            inner,
                            0,
                            ErrorReason::Internal,
                            "internal error while handling frame",
                        ));
                        out.close = true;
                    }
                }
                if out.close {
                    break;
                }
            }
            Ok(None) => break,
            Err(e) => {
                out.replies.push(error_reply(inner, 0, e.reason(), &e.to_string()));
                out.close = true;
                break;
            }
        }
    }
    rbuf.drain(..consumed);
    if eof && !out.close {
        if !rbuf.is_empty() {
            out.replies.push(error_reply(
                inner,
                0,
                ErrorReason::Malformed,
                "stream ended mid-frame",
            ));
        }
        out.close = true;
    }
    if out.close {
        rbuf.clear();
    }
    out
}

// ---------------------------------------------------------------------------
// epoll transport (Linux)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod ep {
    use super::*;
    use crate::serve::net::epoll::{
        Epoll, EpollEvent, Wakeup, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP,
    };
    use std::os::unix::io::AsRawFd;

    const TOK_LISTENER: u64 = 0;
    const TOK_WAKE: u64 = 1;

    /// Encoded replies completed off-loop, keyed by connection id, plus
    /// the pipe that wakes `epoll_wait` to drain them. Callbacks may
    /// outlive the loop (a drain that timed out); they just enqueue
    /// into an Arc nobody reads again.
    pub(super) struct Completions {
        q: Mutex<Vec<(u64, Vec<u8>)>>,
        pub(super) wake: Wakeup,
    }

    impl Completions {
        pub(super) fn new(wake: Wakeup) -> Completions {
            Completions { q: Mutex::new(Vec::new()), wake }
        }

        fn push(&self, id: u64, bytes: Vec<u8>) {
            self.q.lock().unwrap().push((id, bytes));
            self.wake.wake();
        }

        fn take(&self) -> Vec<(u64, Vec<u8>)> {
            std::mem::take(&mut self.q.lock().unwrap())
        }

        fn is_empty(&self) -> bool {
            self.q.lock().unwrap().is_empty()
        }
    }

    struct Conn {
        stream: TcpStream,
        rbuf: Vec<u8>,
        wbuf: Vec<u8>,
        wpos: usize,
        /// Batched requests outstanding on this connection.
        inflight: usize,
        /// No more frames will be dispatched (EOF or protocol damage);
        /// wind down once replies flush and in-flight requests answer.
        read_done: bool,
        /// Socket unusable (reset / write failure / backlog cap):
        /// drop the connection without further ceremony.
        peer_gone: bool,
        /// Event mask currently registered with epoll.
        interest: u32,
    }

    impl Conn {
        fn new(stream: TcpStream) -> Conn {
            Conn {
                stream,
                rbuf: Vec::new(),
                wbuf: Vec::new(),
                wpos: 0,
                inflight: 0,
                read_done: false,
                peer_gone: false,
                interest: EPOLLIN | EPOLLRDHUP,
            }
        }

        fn wbuf_empty(&self) -> bool {
            self.wpos >= self.wbuf.len()
        }

        fn queue(&mut self, bytes: Vec<u8>) {
            if self.peer_gone {
                return;
            }
            if self.wbuf.len() - self.wpos + bytes.len() > MAX_WBUF {
                self.peer_gone = true; // reader stopped reading; cut it loose
                return;
            }
            self.wbuf.extend_from_slice(&bytes);
        }

        /// Write as much of the backlog as the socket takes.
        fn pump_write(&mut self, inner: &Inner) {
            while !self.wbuf_empty() && !self.peer_gone {
                match self.stream.write(&self.wbuf[self.wpos..]) {
                    Ok(0) => self.peer_gone = true,
                    Ok(n) => {
                        inner.note_tx(n);
                        self.wpos += n;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => self.peer_gone = true,
                }
            }
            if self.wbuf_empty() {
                self.wbuf.clear();
                self.wpos = 0;
            }
        }

        fn desired_interest(&self) -> u32 {
            let mut want = 0;
            if !self.read_done {
                want |= EPOLLIN | EPOLLRDHUP;
            }
            if !self.wbuf_empty() {
                want |= EPOLLOUT;
            }
            want
        }
    }

    fn accept_ready(
        inner: &Arc<Inner>,
        listener: &TcpListener,
        epoll: &Epoll,
        conns: &mut HashMap<u64, Conn>,
        next_id: &mut u64,
    ) {
        loop {
            match listener.accept() {
                Ok((s, _)) => {
                    if fault::should_drop_conn() {
                        inner.note_accept(false);
                        inner.note_dropped_conn();
                        continue; // drop(s): injected accept-time failure
                    }
                    if inner.draining.load(Ordering::Acquire) {
                        inner.note_accept(false);
                        continue;
                    }
                    if s.set_nonblocking(true).is_err() {
                        inner.note_accept(false);
                        continue;
                    }
                    let _ = s.set_nodelay(true);
                    let id = *next_id;
                    *next_id += 1;
                    if epoll.add(s.as_raw_fd(), EPOLLIN | EPOLLRDHUP, id).is_err() {
                        inner.note_accept(false);
                        continue;
                    }
                    inner.note_accept(true);
                    conns.insert(id, Conn::new(s));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn pump_read(inner: &Arc<Inner>, completions: &Arc<Completions>, id: u64, c: &mut Conn) {
        let mut eof = false;
        let mut buf = [0u8; 16384];
        loop {
            match c.stream.read(&mut buf) {
                Ok(0) => {
                    eof = true;
                    break;
                }
                Ok(n) => {
                    inner.note_rx(n);
                    c.rbuf.extend_from_slice(&buf[..n]);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    c.peer_gone = true;
                    return;
                }
            }
        }
        if c.read_done {
            c.rbuf.clear(); // protocol-dead: discard anything further
            return;
        }
        let cq = completions;
        let out = pump_frames(inner, &mut c.rbuf, eof, || {
            let cq = cq.clone();
            Box::new(move |bytes| cq.push(id, bytes))
        });
        c.inflight += out.started;
        for r in out.replies {
            c.queue(r);
        }
        if out.close || eof {
            c.read_done = true;
        }
        c.pump_write(inner);
    }

    pub(super) fn run(
        inner: Arc<Inner>,
        listener: TcpListener,
        epoll: Epoll,
        completions: Arc<Completions>,
    ) {
        if listener.set_nonblocking(true).is_err() {
            crate::log_warn!("net: cannot make the listener non-blocking; serving stops");
            return;
        }
        if epoll.add(listener.as_raw_fd(), EPOLLIN, TOK_LISTENER).is_err()
            || epoll.add(completions.wake.read_fd(), EPOLLIN, TOK_WAKE).is_err()
        {
            crate::log_warn!("net: epoll registration failed; serving stops");
            return;
        }
        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut next_id: u64 = 2;
        let mut evs = [EpollEvent::zero(); 64];
        let mut accepting = true;
        let mut drain_until: Option<Instant> = None;
        loop {
            let draining = inner.draining.load(Ordering::Acquire);
            if draining && accepting {
                // stop accepting: deregister and close the listen socket
                // so new connects are refused, not silently queued
                let _ = epoll.del(listener.as_raw_fd());
                accepting = false;
                drain_until = Some(Instant::now() + inner.drain_timeout);
            }
            let timeout = if draining { 25 } else { -1 };
            let n = match epoll.wait(&mut evs, timeout) {
                Ok(n) => n,
                Err(_) => 0,
            };
            for ev in evs.iter().take(n) {
                // copy fields out: the struct is packed on x86-64
                let (bits, tok) = (ev.events, ev.data);
                match tok {
                    TOK_LISTENER => {
                        if accepting {
                            accept_ready(&inner, &listener, &epoll, &mut conns, &mut next_id);
                        }
                    }
                    TOK_WAKE => completions.wake.drain(),
                    id => {
                        if let Some(c) = conns.get_mut(&id) {
                            if bits & (EPOLLIN | EPOLLRDHUP | EPOLLERR | EPOLLHUP) != 0 {
                                pump_read(&inner, &completions, id, c);
                            }
                            if bits & EPOLLOUT != 0 {
                                c.pump_write(&inner);
                            }
                        }
                    }
                }
            }
            // replies completed off-loop since the last pass
            for (id, bytes) in completions.take() {
                if let Some(c) = conns.get_mut(&id) {
                    c.inflight = c.inflight.saturating_sub(1);
                    c.queue(bytes);
                    c.pump_write(&inner);
                }
                // a vanished connection already dropped its replies;
                // global accounting happened in the callback
            }
            // re-register interest; reap finished connections
            let mut dead: Vec<u64> = Vec::new();
            for (id, c) in conns.iter_mut() {
                if c.peer_gone || (c.read_done && c.wbuf_empty() && c.inflight == 0) {
                    dead.push(*id);
                    continue;
                }
                let want = c.desired_interest();
                if want != c.interest && epoll.modify(c.stream.as_raw_fd(), want, *id).is_ok() {
                    c.interest = want;
                }
            }
            for id in dead {
                if let Some(c) = conns.remove(&id) {
                    let _ = epoll.del(c.stream.as_raw_fd());
                    inner.note_conn_closed();
                }
            }
            if draining {
                // order matters: load inflight before checking the
                // completion queue — a completion enqueues its reply
                // *before* decrementing, so inflight==0 + empty queue
                // means every reply is in a wbuf (or its conn is gone)
                let quiesced = inner.inflight.load(Ordering::Acquire) == 0
                    && completions.is_empty()
                    && conns.values().all(|c| c.peer_gone || c.wbuf_empty());
                let expired = drain_until.map_or(false, |d| Instant::now() >= d);
                if quiesced || expired {
                    if expired && !quiesced {
                        crate::log_warn!(
                            "net: drain timed out with {} request(s) in flight",
                            inner.inflight.load(Ordering::Relaxed)
                        );
                    }
                    break;
                }
            }
        }
        for (_, _c) in conns.drain() {
            inner.note_conn_closed();
        }
    }
}

// ---------------------------------------------------------------------------
// portable fallback transport (any platform; tested on Linux too)
// ---------------------------------------------------------------------------

/// Join handles of live connection threads (fallback transport).
struct FallbackState {
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

fn fallback_accept_loop(inner: Arc<Inner>, listener: TcpListener, st: Arc<FallbackState>) {
    if listener.set_nonblocking(true).is_err() {
        crate::log_warn!("net: cannot make the listener non-blocking; serving stops");
        return;
    }
    loop {
        if inner.draining.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((s, _)) => {
                if fault::should_drop_conn() {
                    inner.note_accept(false);
                    inner.note_dropped_conn();
                    continue;
                }
                inner.note_accept(true);
                let inner2 = inner.clone();
                let h = std::thread::Builder::new()
                    .name("comq-net-conn".into())
                    .spawn(move || fallback_conn_loop(inner2, s));
                match h {
                    Ok(h) => st.handles.lock().unwrap().push(h),
                    Err(_) => inner.note_conn_closed(),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn fallback_conn_loop(inner: Arc<Inner>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    // the read timeout doubles as the drain poll interval
    let _ = stream.set_read_timeout(Some(Duration::from_millis(25)));
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => {
            inner.note_conn_closed();
            return;
        }
    };
    // signed so a completion landing before this thread applies its
    // `started` increment dips below zero instead of underflowing
    let inflight: Arc<(Mutex<i64>, Condvar)> = Arc::new((Mutex::new(0), Condvar::new()));
    let mut reader = stream;
    let mut rbuf: Vec<u8> = Vec::new();
    let mut buf = [0u8; 16384];
    loop {
        if inner.draining.load(Ordering::Acquire) {
            break;
        }
        let eof = match reader.read(&mut buf) {
            Ok(0) => true,
            Ok(n) => {
                inner.note_rx(n);
                rbuf.extend_from_slice(&buf[..n]);
                false
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(_) => break,
        };
        let out = pump_frames(&inner, &mut rbuf, eof, || {
            let writer = writer.clone();
            let inflight = inflight.clone();
            let inner = inner.clone();
            Box::new(move |bytes: Vec<u8>| {
                {
                    let mut w = writer.lock().unwrap();
                    if w.write_all(&bytes).is_ok() {
                        inner.note_tx(bytes.len());
                        let _ = w.flush();
                    }
                }
                let (m, cv) = &*inflight;
                *m.lock().unwrap() -= 1;
                cv.notify_all();
            })
        });
        if out.started > 0 {
            *inflight.0.lock().unwrap() += out.started as i64;
        }
        if !out.replies.is_empty() {
            let mut w = writer.lock().unwrap();
            for r in &out.replies {
                if w.write_all(r).is_ok() {
                    inner.note_tx(r.len());
                }
            }
            let _ = w.flush();
        }
        if out.close || eof {
            break;
        }
    }
    // answer everything this connection submitted before closing
    // (bounded: a wedged executor must not pin the thread forever)
    let deadline = Instant::now() + inner.drain_timeout;
    let (m, cv) = &*inflight;
    let mut n = m.lock().unwrap();
    while *n > 0 {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        n = cv.wait_timeout(n, deadline - now).unwrap().0;
    }
    drop(n);
    inner.note_conn_closed();
}

// ---------------------------------------------------------------------------
// the server handle
// ---------------------------------------------------------------------------

enum LoopKind {
    #[cfg(target_os = "linux")]
    Epoll(Arc<ep::Completions>),
    Fallback(Arc<FallbackState>),
}

/// A running TCP serving tier: one listener, one event loop, one
/// micro-batched [`Server`] + [`Admission`] gate per model.
pub struct NetServer {
    inner: Arc<Inner>,
    local: SocketAddr,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    kind: LoopKind,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// serve `models` by name. On Linux this runs an epoll event loop;
    /// elsewhere (or with [`NetConfig::force_fallback`], or if epoll
    /// setup fails) a portable connection-thread loop.
    pub fn bind(
        addr: &str,
        models: Vec<(String, Arc<QuantizedModel>)>,
        cfg: NetConfig,
    ) -> Result<NetServer> {
        if models.is_empty() {
            return Err(anyhow!("need at least one model to serve"));
        }
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("binding {addr}: {e}"))?;
        let local = listener.local_addr().map_err(|e| anyhow!("local_addr: {e}"))?;
        let mut map = BTreeMap::new();
        for (name, model) in models {
            let info = model.info().clone();
            let entry = ModelEntry {
                info,
                current: Mutex::new(Arc::new(ModelEpoch::build(
                    1,
                    model,
                    None,
                    cfg.batch.clone(),
                ))),
                admission: Admission::new(cfg.admission.clone()),
                batch: cfg.batch.clone(),
                next_epoch: AtomicU64::new(2),
            };
            map.insert(name, entry);
        }
        let inner = Arc::new(Inner {
            models: map,
            draining: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            drain_timeout: cfg.drain_timeout,
            counters: Counters::default(),
            obs: crate::obs::enabled().then(NetObs::new),
        });
        #[cfg(target_os = "linux")]
        {
            use crate::serve::net::epoll::{Epoll, Wakeup};
            if !cfg.force_fallback {
                match (Epoll::new(), Wakeup::new()) {
                    (Ok(epoll), Ok(wake)) => {
                        let completions = Arc::new(ep::Completions::new(wake));
                        let (i2, c2) = (inner.clone(), completions.clone());
                        let thread = std::thread::Builder::new()
                            .name("comq-net".into())
                            .spawn(move || ep::run(i2, listener, epoll, c2))
                            .map_err(|e| anyhow!("spawning the net loop: {e}"))?;
                        crate::log_info!("net: serving on {local} (epoll)");
                        return Ok(NetServer {
                            inner,
                            local,
                            thread: Mutex::new(Some(thread)),
                            kind: LoopKind::Epoll(completions),
                        });
                    }
                    _ => crate::log_warn!(
                        "net: epoll unavailable; using the portable connection-thread loop"
                    ),
                }
            }
        }
        let st = Arc::new(FallbackState { handles: Mutex::new(Vec::new()) });
        let (i2, s2) = (inner.clone(), st.clone());
        let thread = std::thread::Builder::new()
            .name("comq-net".into())
            .spawn(move || fallback_accept_loop(i2, listener, s2))
            .map_err(|e| anyhow!("spawning the net loop: {e}"))?;
        crate::log_info!("net: serving on {local} (connection threads)");
        Ok(NetServer { inner, local, thread: Mutex::new(Some(thread)), kind: LoopKind::Fallback(st) })
    }

    /// The bound address (with the real port when `:0` was requested).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// The live epoch serving `name`: its epoch number plus (via
    /// `Deref`) the micro-batcher — tests reconcile its stats and
    /// queue depth against wire-level behavior. The handle stays valid
    /// across a hot-swap; it keeps pointing at the epoch it captured.
    pub fn model_server(&self, name: &str) -> Option<Arc<ModelEpoch>> {
        self.inner.models.get(name).map(|e| e.current())
    }

    /// Hot-swap `name` to the checkpoint at `path` in-process — the
    /// wire `SwapReq` runs exactly this, off the event loop. Returns
    /// `(old_epoch, new_epoch)`; on error the old epoch keeps serving.
    pub fn swap_model(&self, name: &str, path: &str) -> Result<(u64, u64)> {
        swap_model(&self.inner, name, path).map_err(|e| anyhow!(e))
    }

    /// The admission gate behind `name`.
    pub fn admission(&self, name: &str) -> Option<&Arc<Admission>> {
        self.inner.models.get(name).map(|e| &e.admission)
    }

    /// Point-in-time network-tier counters.
    pub fn stats(&self) -> NetStats {
        let c = &self.inner.counters;
        NetStats {
            connections: c.connections.load(Ordering::Relaxed),
            dropped_conns: c.dropped_conns.load(Ordering::Relaxed),
            frames: c.frames.load(Ordering::Relaxed),
            error_frames: c.error_frames.load(Ordering::Relaxed),
            inflight: self.inner.inflight.load(Ordering::Relaxed),
            rx_bytes: c.rx_bytes.load(Ordering::Relaxed),
            tx_bytes: c.tx_bytes.load(Ordering::Relaxed),
        }
    }

    /// Graceful drain: stop accepting, answer every request already
    /// admitted (bounded by the drain timeout), flush replies, join the
    /// event loop and every batcher executor. Idempotent; `Drop` calls
    /// it.
    pub fn shutdown(&self) {
        let first = !self.inner.draining.swap(true, Ordering::AcqRel);
        if first {
            recorder::note(RecKind::Drain, "net server draining");
        }
        match &self.kind {
            #[cfg(target_os = "linux")]
            LoopKind::Epoll(c) => c.wake.wake(),
            LoopKind::Fallback(_) => {}
        }
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
        if let LoopKind::Fallback(st) = &self.kind {
            for h in st.handles.lock().unwrap().drain(..) {
                let _ = h.join();
            }
        }
        for e in self.inner.models.values() {
            e.current().server.shutdown();
        }
        // black-box readout: a drain that saw incidents (error frames,
        // sheds, panics, respawns, dropped conns) dumps the last-N ring
        // so the post-mortem shows what led up to them; a clean drain
        // stays quiet
        if first {
            let incidents = [
                RecKind::ErrorFrame,
                RecKind::Shed,
                RecKind::Respawn,
                RecKind::Panic,
                RecKind::DropConn,
            ]
            .iter()
            .map(|k| recorder::count(*k))
            .sum::<u64>();
            if incidents > 0 {
                recorder::dump("drain");
            }
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}
