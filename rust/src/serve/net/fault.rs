//! `COMQ_FAULT` — deterministic fault injection for the serving tier,
//! so containment is *tested*, not asserted.
//!
//! The spec is a comma-separated list of faults:
//!
//! ```text
//! panic:<site>[:<n>]     panic at a site (exec | forward | conn), n times
//! slow:<ms>[:<n>]        stretch the exec stage by <ms> milliseconds
//! drop_conn:<p>[:<n>]    close 1-in-round(1/p) connections after accept
//! garbage_frame[:<n>]    corrupt the magic of an outgoing reply frame
//! io_err[:<stage>][:<n>] fail a checkpoint-save I/O op; stage is one of
//!                        create | write | sync | rename (omitted = any)
//! corrupt_load:<off>[:<n>]  XOR one byte of checkpoint bytes at <off>
//!                        (clamped to the file) after read, before parse
//! slow_load:<ms>[:<n>]   stretch a checkpoint load by <ms> milliseconds
//! ```
//!
//! `[:<n>]` is a **budget**: the fault fires exactly `n` times then
//! disarms, which is what lets the integration tests assert that shed
//! and panic counters match the injected counts *exactly*. Without a
//! budget the fault fires on every hit.
//!
//! Like `COMQ_OBS`, the spec is read from the environment once and
//! cached; tests and embedders flip it with [`set_spec`] / [`clear`]
//! (tests in one binary run concurrently, so fault-sensitive tests
//! serialize on a lock and never touch the process environment). Every
//! injection site counts its firings ([`fired`]), giving tests the
//! exact number to reconcile counters against.
//!
//! `drop_conn` is deterministic, not random: with probability `p` it
//! closes every `round(1/p)`-th connection the process accepts, so a
//! test that opens 10 connections under `drop_conn:0.5` knows exactly
//! 5 die.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// Where a fault can fire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    /// In the batcher's executor loop, outside the per-batch panic
    /// guard — a `panic` here exercises the respawn supervisor; `slow`
    /// here stretches the exec stage.
    Exec,
    /// Inside the model forward (under the per-batch guard) — a `panic`
    /// here fails one batch but not the executor.
    Forward,
    /// In the network connection handler, while processing a frame.
    Conn,
}

impl Site {
    pub fn name(&self) -> &'static str {
        match self {
            Site::Exec => "exec",
            Site::Forward => "forward",
            Site::Conn => "conn",
        }
    }

    fn parse(s: &str) -> Option<Site> {
        match s {
            "exec" => Some(Site::Exec),
            "forward" => Some(Site::Forward),
            "conn" => Some(Site::Conn),
            _ => None,
        }
    }
}

/// Which I/O operation of the atomic checkpoint save an `io_err` fault
/// fails. The four stages are exactly the four syscalls of the
/// temp-file + fsync + rename sequence in `tensorstore::write_store` —
/// the kill-point tests iterate all of them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoStage {
    Create,
    Write,
    Sync,
    Rename,
}

impl IoStage {
    pub fn name(&self) -> &'static str {
        match self {
            IoStage::Create => "create",
            IoStage::Write => "write",
            IoStage::Sync => "sync",
            IoStage::Rename => "rename",
        }
    }

    fn parse(s: &str) -> Option<IoStage> {
        match s {
            "create" => Some(IoStage::Create),
            "write" => Some(IoStage::Write),
            "sync" => Some(IoStage::Sync),
            "rename" => Some(IoStage::Rename),
            _ => None,
        }
    }
}

/// One armed fault.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    Panic(Site),
    /// Sleep this long at the exec site.
    Slow(Duration),
    /// Close 1-in-`period` accepted connections.
    DropConn { period: u64 },
    /// Corrupt the magic of an outgoing reply frame.
    GarbageFrame,
    /// Fail a checkpoint-save I/O operation (`None` = any stage).
    IoErr { stage: Option<IoStage> },
    /// XOR one byte of checkpoint bytes at this offset after read.
    CorruptLoad { off: usize },
    /// Sleep this long at the start of a checkpoint load.
    SlowLoad(Duration),
}

/// An armed fault: kind + firing budget + fired count. Opaque outside
/// this module; [`parse`] hands a batch of them to [`set_spec`].
#[derive(Debug)]
pub struct Fault {
    kind: FaultKind,
    /// Remaining firings; `None` = unlimited.
    budget: Option<AtomicU64>,
    fired: AtomicU64,
}

impl Fault {
    /// Consume one firing if armed and in budget.
    fn take(&self) -> bool {
        match &self.budget {
            None => {
                self.fired.fetch_add(1, Ordering::Relaxed);
                true
            }
            Some(b) => {
                // CAS loop: never take the budget below zero under races
                let mut cur = b.load(Ordering::Relaxed);
                loop {
                    if cur == 0 {
                        return false;
                    }
                    match b.compare_exchange_weak(
                        cur,
                        cur - 1,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => {
                            self.fired.fetch_add(1, Ordering::Relaxed);
                            return true;
                        }
                        Err(now) => cur = now,
                    }
                }
            }
        }
    }
}

#[derive(Default)]
struct State {
    faults: Vec<Fault>,
    /// Monotone accepted-connection counter driving `drop_conn`.
    conns: AtomicU64,
}

fn state() -> &'static Mutex<State> {
    static S: OnceLock<Mutex<State>> = OnceLock::new();
    S.get_or_init(|| {
        let faults = match std::env::var("COMQ_FAULT").ok().as_deref().map(str::trim) {
            None | Some("") => Vec::new(),
            Some(spec) => match parse(spec) {
                Ok(fs) => {
                    crate::log_warn!("COMQ_FAULT armed: {spec} (fault injection is for tests)");
                    fs
                }
                Err(e) => {
                    crate::warn_once!("COMQ_FAULT ignored: {e}");
                    Vec::new()
                }
            },
        };
        Mutex::new(State { faults, conns: AtomicU64::new(0) })
    })
}

/// Parse a fault spec into its armed faults. Pure — unit-testable and
/// reused by [`set_spec`] and the env init.
pub fn parse(spec: &str) -> Result<Vec<Fault>, String> {
    let mut out = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let mut fields = part.split(':');
        let kind = fields.next().unwrap_or("");
        let rest: Vec<&str> = fields.collect();
        let (kind, budget) = match kind {
            "panic" => {
                let site = rest
                    .first()
                    .and_then(|s| Site::parse(s))
                    .ok_or_else(|| format!("panic needs a site (exec|forward|conn): '{part}'"))?;
                (FaultKind::Panic(site), parse_budget(rest.get(1))?)
            }
            "slow" => {
                let ms: u64 = rest
                    .first()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| format!("slow needs milliseconds: '{part}'"))?;
                (FaultKind::Slow(Duration::from_millis(ms)), parse_budget(rest.get(1))?)
            }
            "drop_conn" => {
                let p: f64 = rest
                    .first()
                    .and_then(|s| s.parse().ok())
                    .filter(|p| *p > 0.0 && *p <= 1.0)
                    .ok_or_else(|| format!("drop_conn needs a probability in (0, 1]: '{part}'"))?;
                let period = (1.0 / p).round().max(1.0) as u64;
                (FaultKind::DropConn { period }, parse_budget(rest.get(1))?)
            }
            "garbage_frame" => (FaultKind::GarbageFrame, parse_budget(rest.first())?),
            "io_err" => match rest.first() {
                // the stage is optional, so a numeric first field is the
                // budget: `io_err:1` = any stage, fire once
                None => (FaultKind::IoErr { stage: None }, None),
                Some(s) => match IoStage::parse(s) {
                    Some(st) => (FaultKind::IoErr { stage: Some(st) }, parse_budget(rest.get(1))?),
                    None => (FaultKind::IoErr { stage: None }, parse_budget(rest.first())?),
                },
            },
            "corrupt_load" => {
                let off: usize = rest
                    .first()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| format!("corrupt_load needs a byte offset: '{part}'"))?;
                (FaultKind::CorruptLoad { off }, parse_budget(rest.get(1))?)
            }
            "slow_load" => {
                let ms: u64 = rest
                    .first()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| format!("slow_load needs milliseconds: '{part}'"))?;
                (FaultKind::SlowLoad(Duration::from_millis(ms)), parse_budget(rest.get(1))?)
            }
            other => return Err(format!("unknown fault kind '{other}' in '{part}'")),
        };
        out.push(Fault { kind, budget: budget.map(AtomicU64::new), fired: AtomicU64::new(0) });
    }
    if out.is_empty() {
        return Err(format!("no faults in spec '{spec}'"));
    }
    Ok(out)
}

fn parse_budget(field: Option<&&str>) -> Result<Option<u64>, String> {
    match field {
        None => Ok(None),
        Some(s) => s.parse().map(Some).map_err(|_| format!("bad fault budget '{s}'")),
    }
}

/// Arm a new fault spec, replacing whatever was armed (tests).
pub fn set_spec(spec: &str) -> Result<(), String> {
    let faults = parse(spec)?;
    state().lock().unwrap().faults = faults;
    Ok(())
}

/// Disarm all faults (tests call this before and after fault runs).
pub fn clear() {
    state().lock().unwrap().faults.clear();
}

/// Total firings of faults matching `pred` since they were armed.
fn fired_where<F: Fn(&FaultKind) -> bool>(pred: F) -> u64 {
    let st = state().lock().unwrap();
    st.faults
        .iter()
        .filter(|f| pred(&f.kind))
        .map(|f| f.fired.load(Ordering::Relaxed))
        .sum()
}

/// Firings of `panic:<site>` faults.
pub fn fired_panics(site: Site) -> u64 {
    fired_where(|k| matches!(k, FaultKind::Panic(s) if *s == site))
}

/// Firings of `slow` faults.
pub fn fired_slow() -> u64 {
    fired_where(|k| matches!(k, FaultKind::Slow(_)))
}

/// Firings of `drop_conn` faults.
pub fn fired_drops() -> u64 {
    fired_where(|k| matches!(k, FaultKind::DropConn { .. }))
}

/// Firings of `io_err` faults.
pub fn fired_io_errors() -> u64 {
    fired_where(|k| matches!(k, FaultKind::IoErr { .. }))
}

/// Firings of `corrupt_load` faults.
pub fn fired_corrupt_loads() -> u64 {
    fired_where(|k| matches!(k, FaultKind::CorruptLoad { .. }))
}

/// Firings of `slow_load` faults.
pub fn fired_slow_loads() -> u64 {
    fired_where(|k| matches!(k, FaultKind::SlowLoad(_)))
}

/// Panic at `site` if a matching fault is armed and in budget.
/// The panic message names the injection so escaped ones are
/// recognizable in logs.
pub fn maybe_panic(site: Site) {
    let hit = {
        let st = state().lock().unwrap();
        st.faults
            .iter()
            .any(|f| matches!(f.kind, FaultKind::Panic(s) if s == site) && f.take())
    };
    if hit {
        panic!("COMQ_FAULT injected panic at site '{}'", site.name());
    }
}

/// The injected exec-stage delay, if a `slow` fault is armed and in
/// budget. (`site` is accepted for symmetry; only `Exec` slows today.)
pub fn slow_for(site: Site) -> Option<Duration> {
    if site != Site::Exec {
        return None;
    }
    let st = state().lock().unwrap();
    st.faults.iter().find_map(|f| match f.kind {
        FaultKind::Slow(d) if f.take() => Some(d),
        _ => None,
    })
}

/// Whether the connection being accepted should be dropped. Counts
/// *all* accepted connections (the period is deterministic), fires on
/// every `period`-th one.
pub fn should_drop_conn() -> bool {
    let st = state().lock().unwrap();
    let n = st.conns.fetch_add(1, Ordering::Relaxed) + 1;
    st.faults.iter().any(|f| {
        matches!(f.kind, FaultKind::DropConn { period } if n % period == 0) && f.take()
    })
}

/// Whether the reply frame about to be written should be corrupted.
pub fn garbage_reply() -> bool {
    let st = state().lock().unwrap();
    st.faults.iter().any(|f| matches!(f.kind, FaultKind::GarbageFrame) && f.take())
}

/// Whether the checkpoint-save I/O operation at `stage` should fail.
/// A stage-less `io_err` matches every stage (first boundary wins).
pub fn io_error_at(stage: IoStage) -> bool {
    let st = state().lock().unwrap();
    st.faults.iter().any(|f| {
        matches!(f.kind, FaultKind::IoErr { stage: s } if s.is_none() || s == Some(stage))
            && f.take()
    })
}

/// Byte offset to corrupt in checkpoint bytes about to be parsed, if a
/// `corrupt_load` fault is armed and in budget.
pub fn corrupt_load() -> Option<usize> {
    let st = state().lock().unwrap();
    st.faults.iter().find_map(|f| match f.kind {
        FaultKind::CorruptLoad { off } if f.take() => Some(off),
        _ => None,
    })
}

/// The injected checkpoint-load delay, if a `slow_load` fault is armed
/// and in budget.
pub fn slow_load() -> Option<Duration> {
    let st = state().lock().unwrap();
    st.faults.iter().find_map(|f| match f.kind {
        FaultKind::SlowLoad(d) if f.take() => Some(d),
        _ => None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing_matrix() {
        let fs = parse("panic:exec:3, slow:50, drop_conn:0.25:2, garbage_frame:1").unwrap();
        assert_eq!(fs.len(), 4);
        assert_eq!(fs[0].kind, FaultKind::Panic(Site::Exec));
        assert_eq!(fs[0].budget.as_ref().unwrap().load(Ordering::Relaxed), 3);
        assert_eq!(fs[1].kind, FaultKind::Slow(Duration::from_millis(50)));
        assert!(fs[1].budget.is_none());
        assert_eq!(fs[2].kind, FaultKind::DropConn { period: 4 });
        assert_eq!(fs[3].kind, FaultKind::GarbageFrame);
        assert_eq!(fs[3].budget.as_ref().unwrap().load(Ordering::Relaxed), 1);
    }

    #[test]
    fn spec_errors_are_typed() {
        assert!(parse("").is_err());
        assert!(parse("panic").is_err());
        assert!(parse("panic:gpu").is_err());
        assert!(parse("slow:abc").is_err());
        assert!(parse("drop_conn:0").is_err());
        assert!(parse("drop_conn:1.5").is_err());
        assert!(parse("explode:now").is_err());
        assert!(parse("panic:exec:many").is_err());
        assert!(parse("corrupt_load").is_err());
        assert!(parse("corrupt_load:deep").is_err());
        assert!(parse("slow_load:soon").is_err());
        assert!(parse("io_err:fsync").is_err()); // not a stage, not a budget
    }

    #[test]
    fn lifecycle_spec_parsing() {
        let fs =
            parse("io_err, io_err:rename:2, io_err:1, corrupt_load:64:1, slow_load:20").unwrap();
        assert_eq!(fs.len(), 5);
        assert_eq!(fs[0].kind, FaultKind::IoErr { stage: None });
        assert!(fs[0].budget.is_none());
        assert_eq!(fs[1].kind, FaultKind::IoErr { stage: Some(IoStage::Rename) });
        assert_eq!(fs[1].budget.as_ref().unwrap().load(Ordering::Relaxed), 2);
        // a numeric first field on io_err is the budget, not a stage
        assert_eq!(fs[2].kind, FaultKind::IoErr { stage: None });
        assert_eq!(fs[2].budget.as_ref().unwrap().load(Ordering::Relaxed), 1);
        assert_eq!(fs[3].kind, FaultKind::CorruptLoad { off: 64 });
        assert_eq!(fs[3].budget.as_ref().unwrap().load(Ordering::Relaxed), 1);
        assert_eq!(fs[4].kind, FaultKind::SlowLoad(Duration::from_millis(20)));
        assert!(fs[4].budget.is_none());
    }

    #[test]
    fn io_err_stage_matching() {
        let f = Fault {
            kind: FaultKind::IoErr { stage: Some(IoStage::Sync) },
            budget: Some(AtomicU64::new(1)),
            fired: AtomicU64::new(0),
        };
        // staged fault only matches its own stage
        let matches = |stage: IoStage| {
            matches!(f.kind, FaultKind::IoErr { stage: s } if s.is_none() || s == Some(stage))
        };
        assert!(!matches(IoStage::Create));
        assert!(!matches(IoStage::Rename));
        assert!(matches(IoStage::Sync));
    }

    #[test]
    fn budget_disarms_exactly() {
        let f = Fault {
            kind: FaultKind::GarbageFrame,
            budget: Some(AtomicU64::new(2)),
            fired: AtomicU64::new(0),
        };
        assert!(f.take());
        assert!(f.take());
        assert!(!f.take());
        assert!(!f.take());
        assert_eq!(f.fired.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn unlimited_fault_keeps_firing() {
        let f = Fault { kind: FaultKind::GarbageFrame, budget: None, fired: AtomicU64::new(0) };
        for _ in 0..5 {
            assert!(f.take());
        }
        assert_eq!(f.fired.load(Ordering::Relaxed), 5);
    }
}
