//! Thin epoll + pipe syscall wrapper (Linux only). The offline vendor
//! set has no `libc`/`mio`, so the handful of symbols the event loop
//! needs are declared here directly against the C library `std`
//! already links. Everything is wrapped in safe RAII types; raw fds
//! never leak past this module.

use std::io;
use std::os::raw::{c_int, c_void};
use std::os::unix::io::RawFd;

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLL_CLOEXEC: c_int = 0o2000000;
const O_CLOEXEC: c_int = 0o2000000;
const O_NONBLOCK: c_int = 0o4000;

/// Kernel ABI: packed on x86-64, natural alignment elsewhere.
#[derive(Clone, Copy)]
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

impl EpollEvent {
    pub const fn zero() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
        -> c_int;
    fn pipe2(fds: *mut c_int, flags: c_int) -> c_int;
    fn close(fd: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
}

fn cvt(ret: c_int) -> io::Result<c_int> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// An epoll instance. Interest is registered per-fd with a caller
/// token returned in the event's `data`.
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        cvt(unsafe { epoll_ctl(self.fd, op, fd, &mut ev) }).map(|_| ())
    }

    pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    pub fn modify(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    pub fn del(&self, fd: RawFd) -> io::Result<()> {
        // the event argument is ignored for DEL on modern kernels but
        // must be non-null on pre-2.6.9 ones; pass one unconditionally
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for events. `timeout_ms < 0` blocks indefinitely. EINTR is
    /// retried internally so callers never see spurious zero-waits as
    /// errors.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let n = unsafe {
                epoll_wait(self.fd, events.as_mut_ptr(), events.len() as c_int, timeout_ms)
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

/// A self-pipe for waking `epoll_wait` from other threads (the batcher
/// executors complete requests on their own threads; the event loop
/// must wake to write the replies out). Both ends are non-blocking: a
/// full pipe just means a wake is already pending.
pub struct Wakeup {
    r: RawFd,
    w: RawFd,
}

impl Wakeup {
    pub fn new() -> io::Result<Wakeup> {
        let mut fds = [0 as c_int; 2];
        cvt(unsafe { pipe2(fds.as_mut_ptr(), O_CLOEXEC | O_NONBLOCK) })?;
        Ok(Wakeup { r: fds[0], w: fds[1] })
    }

    /// The read end, for epoll registration.
    pub fn read_fd(&self) -> RawFd {
        self.r
    }

    /// Wake the event loop. Callable from any thread; errors (pipe
    /// already full = wake already pending) are intentionally ignored.
    pub fn wake(&self) {
        let b = [1u8];
        unsafe { write(self.w, b.as_ptr() as *const c_void, 1) };
    }

    /// Drain pending wake bytes after the loop observed readability.
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            let n = unsafe { read(self.r, buf.as_mut_ptr() as *mut c_void, buf.len()) };
            if n <= 0 {
                return; // empty (EAGAIN) or closed — either way drained
            }
        }
    }
}

// raw fds are plain ints; the pipe syscalls are thread-safe
unsafe impl Send for Wakeup {}
unsafe impl Sync for Wakeup {}

impl Drop for Wakeup {
    fn drop(&mut self) {
        unsafe {
            close(self.r);
            close(self.w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn wakeup_pipe_wakes_epoll() {
        let ep = Epoll::new().unwrap();
        let wk = Wakeup::new().unwrap();
        ep.add(wk.read_fd(), EPOLLIN, 7).unwrap();
        let mut evs = [EpollEvent::zero(); 4];
        // nothing pending: a zero-timeout wait returns no events
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
        wk.wake();
        wk.wake(); // coalesces; still just one readable event
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert_eq!(n, 1);
        let (events, data) = (evs[0].events, evs[0].data);
        assert_ne!(events & EPOLLIN, 0);
        assert_eq!(data, 7);
        wk.drain();
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0, "drained pipe must be quiet");
    }

    #[test]
    fn epoll_sees_tcp_readability_with_tokens() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let ep = Epoll::new().unwrap();
        ep.add(server.as_raw_fd(), EPOLLIN | EPOLLRDHUP, 42).unwrap();
        let mut evs = [EpollEvent::zero(); 4];
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);

        client.write_all(b"ping").unwrap();
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!({ evs[0].data }, 42);
        let mut s = server;
        let mut buf = [0u8; 8];
        assert_eq!(s.read(&mut buf).unwrap(), 4);

        // interest can be rewritten and removed
        ep.modify(s.as_raw_fd(), EPOLLIN | EPOLLOUT, 43).unwrap();
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert!(n >= 1, "socket must be writable");
        ep.del(s.as_raw_fd()).unwrap();
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
        drop(client);
    }
}
