//! The integer serving GEMM: `u8 × i8 → i32` with the dequantization
//! epilogue fused into the accumulator drain, over runtime-dispatched
//! SIMD micro-kernels (`util::simd`).
//!
//! Activations carry their *uncentered* unsigned codes `qa ∈ [0, 2^ab)`
//! and weights their *centered* codes `u − 2^(b−1) ∈ i8` — the operand
//! signedness `vpmaddubsw`/`vpdpbusd` demand (unsigned × signed). The
//! products stay well inside i32: |qa·s| ≤ 2^8·2^7 = 2^15, so the k
//! extent would need to reach 2^16 to overflow — far beyond any layer
//! here, rejected at weight prep and asserted again below. All offsets
//! are exact integers, so the epilogue reconstructs the exact
//! uncentered integer sum
//!
//! ```text
//! Σ_i (qa_i + z_a)(u_ij + z_j)
//!   = dot_ij + (c_w + z_j)·rowsum_i + z_a·(colsum_j + m·(c_w + z_j))
//! ```
//!
//! in f64 (every term an integer < 2^53) and scales once by
//! `δ_a · δ_j`, giving bit-faithful agreement with the fake-quant f32
//! reference up to a single final rounding. `rowsum` (of the unsigned
//! codes) comes free during activation quantization; `colsum` (of the
//! centered weight codes) is precomputed at weight prep. `c_w = 2^(b−1)`
//! is the weight centering, folded here so the panel can stay signed.
//!
//! The kernel reuses the MR×NR register tiling of `tensor/matmul.rs`
//! with the B strips K4-interleaved (k in groups of 4 adjacent bytes —
//! the layout `vpdpbusd` and `vpmaddubsw` consume; one group row is 64
//! bytes, a single cache line). One panel layout serves every kernel,
//! so a model prepped under one `COMQ_KERNEL` can be re-benched under
//! another without re-packing. An i8 strip is still a quarter the f32
//! bytes, which is the whole bandwidth win on batch-1 serving; the same
//! persistent-pool parallelism splits over row blocks when the batch
//! can feed the pool and over column strips when it can't (batch-1).
//!
//! Grouped (depthwise) layers run through [`dwconv_i8_fused`]: each
//! output channel convolves its own k·k patch, so the activation side
//! is packed into the same K4-interleaved strip layout as the weight
//! panel ([`GroupedQuantizedActs`]) and the kernel loads per-lane quads
//! instead of broadcasting one (`util::simd::dot_i8_grouped`). The
//! epilogue identity above holds per group with `m = k·k` and the
//! per-row code sum replaced by a per-(row, group) sum.

use crate::quant::actq::ActQuant;
use crate::tensor::{Tensor, MR, NR};
use crate::util::pool::{parallel_ranges, parallel_sharded, SendPtr};
use crate::util::simd::{self, Kernel, K4};

/// At this k extent the worst-case i32 sum hits exactly 2^31
/// (2^16 · 2^15) and overflows, so the guard is strict. Weight prep
/// (`Int8Panel::from_packed`) rejects such layers at build time; the
/// assert below is the backstop for direct kernel callers. (Half the
/// old centered-i8 bound: the unsigned activation operand doubled the
/// per-product magnitude.)
pub(crate) const MAX_K: usize = 1 << 16;
const MIN_OPS_PER_THREAD: usize = 1 << 20;

/// Below this many elements, activation quantization runs inline — the
/// per-element cost is a few ns, so small batches can't amortize a pool
/// hand-off.
const QUANT_MIN_ELEMS_PER_THREAD: usize = 1 << 14;

/// A batch of activations quantized to uncentered u8 codes, plus the
/// per-row code sums the epilogue needs.
pub struct QuantizedActs {
    /// Unsigned codes `qa ∈ [0, 2^bits)`, row-major [rows, stride] with
    /// `stride = m` rounded up to the K4 group width; the pad bytes are
    /// zero (and the matching panel k-pad is zero, so padded products
    /// vanish from every kernel identically).
    pub codes: Vec<u8>,
    /// Per-row sum of the unsigned codes.
    pub rsum: Vec<i32>,
    pub rows: usize,
    /// True k extent (columns of the source input).
    pub m: usize,
    /// Row stride of `codes` in bytes: `m.div_ceil(4) * 4`.
    pub stride: usize,
    pub aq: ActQuant,
}

impl QuantizedActs {
    /// Quantize a 2-D input [rows, m] with the given activation grid.
    /// Rows are split over the persistent pool above a size threshold —
    /// each row writes a disjoint `codes` stripe and `rsum` slot, the
    /// pool's `SendPtr` contract — so batch serving no longer pays a
    /// serial pre-GEMM quantization tax.
    pub fn quantize(x: &Tensor, aq: ActQuant) -> QuantizedActs {
        assert!(aq.bits >= 1 && aq.bits <= 8, "activation bits {} not in 1..=8", aq.bits);
        let (rows, m) = (x.rows(), x.cols());
        let stride = m.div_ceil(K4) * K4;
        let mut codes = vec![0u8; rows * stride];
        let mut rsum = vec![0i32; rows];
        let cptr = SendPtr::new(codes.as_mut_ptr());
        let rptr = SendPtr::new(rsum.as_mut_ptr());
        let min_rows = (QUANT_MIN_ELEMS_PER_THREAD / m.max(1)).max(1);
        parallel_ranges(rows, min_rows, |_, rr| {
            for r in rr {
                // disjoint per-row stripes; pad bytes stay zero
                let crow = unsafe { std::slice::from_raw_parts_mut(cptr.ptr().add(r * stride), m) };
                let mut acc = 0i32;
                for (c, &v) in crow.iter_mut().zip(x.row(r)) {
                    let q = aq.code(v) as i32;
                    *c = q as u8;
                    acc += q;
                }
                unsafe { *rptr.ptr().add(r) = acc };
            }
        });
        QuantizedActs { codes, rsum, rows, m, stride, aq }
    }
}

/// A grouped (depthwise) batch of activation patches quantized to
/// uncentered u8 codes, packed into the **same K4-interleaved strip
/// layout as the weight panel** so the grouped kernel can load per-lane
/// quads (see `util::simd::dot_i8_grouped`), plus the per-(row, group)
/// code sums its epilogue needs.
pub struct GroupedQuantizedActs {
    /// Unsigned codes in per-row panels `[rows][n_strips][kg][NR][4]`:
    /// `codes[r·stride + s·kg·NR·4 + (g·NR + l)·4 + t]` is the code of
    /// patch element `4g + t` of group `s·NR + l`. Pad lanes (groups
    /// past `c`) and pad k positions (past `kk`) stay zero, matching
    /// the panel's zero padding so padded products vanish.
    pub codes: Vec<u8>,
    /// Per-(row, group) sum of the unsigned codes, `[rows · c]` —
    /// unlike the dense path the activation sum differs per output
    /// column, because each group convolves its own patch.
    pub gsum: Vec<i32>,
    pub rows: usize,
    /// Number of groups (channels).
    pub c: usize,
    /// Patch length per group (k·k for a k×k depthwise kernel).
    pub kk: usize,
    /// Row stride of `codes` in bytes: `c.div_ceil(NR)·kk.div_ceil(4)·NR·4`.
    pub stride: usize,
    pub aq: ActQuant,
}

impl GroupedQuantizedActs {
    /// Quantize grouped patches x3 [rows, c, kk] (the `im2col_grouped`
    /// layout) with the given activation grid. Rows split over the
    /// persistent pool above the same size threshold as the dense path;
    /// each row writes a disjoint `codes` panel and `gsum` stripe.
    pub fn quantize(x3: &Tensor, aq: ActQuant) -> GroupedQuantizedActs {
        assert!(aq.bits >= 1 && aq.bits <= 8, "activation bits {} not in 1..=8", aq.bits);
        assert_eq!(x3.ndim(), 3, "grouped input must be [rows, c, kk], got {:?}", x3.shape());
        let (rows, c, kk) = (x3.shape()[0], x3.shape()[1], x3.shape()[2]);
        let kg = kk.div_ceil(K4);
        let strip_len = kg * NR * K4;
        let stride = c.div_ceil(NR) * strip_len;
        let mut codes = vec![0u8; rows * stride];
        let mut gsum = vec![0i32; rows * c];
        let xd = x3.data();
        let cptr = SendPtr::new(codes.as_mut_ptr());
        let gptr = SendPtr::new(gsum.as_mut_ptr());
        let min_rows = (QUANT_MIN_ELEMS_PER_THREAD / (c * kk).max(1)).max(1);
        parallel_ranges(rows, min_rows, |_, rr| {
            for r in rr {
                // disjoint per-row stripes; pad bytes stay zero
                let crow =
                    unsafe { std::slice::from_raw_parts_mut(cptr.ptr().add(r * stride), stride) };
                let grow = unsafe { std::slice::from_raw_parts_mut(gptr.ptr().add(r * c), c) };
                let src = &xd[r * c * kk..(r + 1) * c * kk];
                for (ch, (gs, patch)) in grow.iter_mut().zip(src.chunks_exact(kk)).enumerate() {
                    let (s, l) = (ch / NR, ch % NR);
                    let mut acc = 0i32;
                    for (p, &v) in patch.iter().enumerate() {
                        let q = aq.code(v) as i32;
                        acc += q;
                        let (g, t) = (p / K4, p % K4);
                        crow[s * strip_len + (g * NR + l) * K4 + t] = q as u8;
                    }
                    *gs = acc;
                }
            }
        });
        GroupedQuantizedActs { codes, gsum, rows, c, kk, stride, aq }
    }
}

/// Per-column epilogue coefficients for one (layer, activation-grid)
/// pair; see [`crate::serve::Int8Panel::coeffs`] for the derivation.
pub struct EpilogueCoeffs {
    /// δ_a · δ_j — the only non-integer factor.
    pub scale: Vec<f64>,
    /// c_w + z_j — multiplies the per-row unsigned code sum.
    pub zc: Vec<f64>,
    /// z_a·(colsum_j + m·(c_w + z_j)) — the row-independent term.
    pub fixed: Vec<f64>,
    /// Layer bias, added after scaling.
    pub bias: Vec<f64>,
}

/// Pack centered codes [k, n] row-major into K4-interleaved column
/// strips of width NR: within strip `s`, group `g` holds the `NR × 4`
/// bytes `panel[(g·NR + l)·4 + t] = s[(4g + t)·n + (s·NR + l)]`,
/// zero-padded in both the last strip and the last k group. Done once
/// at weight prep; the layout feeds every kernel (see `util::simd`).
pub fn pack_panel_k4(s: &[i8], k: usize, n: usize) -> Vec<i8> {
    assert_eq!(s.len(), k * n);
    let n_strips = n.div_ceil(NR);
    let kg = k.div_ceil(K4);
    let mut panel = vec![0i8; n_strips * kg * NR * K4];
    for strip in 0..n_strips {
        let j0 = strip * NR;
        let cols = NR.min(n - j0);
        let base = strip * kg * NR * K4;
        for kk in 0..k {
            let (g, t) = (kk / K4, kk % K4);
            let src = &s[kk * n + j0..kk * n + j0 + cols];
            for (l, &v) in src.iter().enumerate() {
                panel[base + (g * NR + l) * K4 + t] = v;
            }
        }
    }
    panel
}

/// y[r][j] = scale_j·(dot_rj + zc_j·rsum_r + fixed_j) + bias_j over a
/// K4-packed i8 weight panel, with the micro-kernel chosen by
/// [`Kernel::active`] (CPU detection + `COMQ_KERNEL` override). `wbits`
/// is the panel's source code width — it sizes the AVX2 saturation
/// guard. `out` [rows, n] is fully overwritten.
pub fn gemm_i8_fused(
    a: &QuantizedActs,
    panel: &[i8],
    n: usize,
    wbits: u32,
    co: &EpilogueCoeffs,
    out: &mut [f32],
) {
    gemm_i8_fused_with(Kernel::active(), a, panel, n, wbits, co, out)
}

/// [`gemm_i8_fused`] with the kernel forced — the benching/testing
/// entry that bypasses detection and the env override.
pub fn gemm_i8_fused_with(
    kern: Kernel,
    a: &QuantizedActs,
    panel: &[i8],
    n: usize,
    wbits: u32,
    co: &EpilogueCoeffs,
    out: &mut [f32],
) {
    // resolve the defensive unsupported-kernel fallback once per call,
    // so every per-tile dispatch below takes its guarded arm
    let kern = if kern.supported() { kern } else { Kernel::Scalar };
    if crate::obs::enabled() {
        crate::obs::metrics::kernel_counter(kern).inc();
    }
    let (rows, k) = (a.rows, a.m);
    assert!(k < MAX_K, "k={k} would overflow the i32 accumulator");
    assert_eq!(out.len(), rows * n);
    assert_eq!(co.scale.len(), n);
    assert_eq!(co.zc.len(), n);
    assert_eq!(co.fixed.len(), n);
    assert_eq!(co.bias.len(), n);
    if rows == 0 || n == 0 {
        return;
    }
    let kg = k.div_ceil(K4);
    let strip_len = kg * NR * K4;
    let n_strips = n.div_ceil(NR);
    assert_eq!(panel.len(), n_strips * strip_len, "panel not K4-packed for [{k}, {n}]");
    let wide = !simd::maddubs_safe(a.aq.bits, wbits);
    let row_blocks = rows.div_ceil(MR);
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    if row_blocks < crate::util::pool::num_threads() && n_strips > row_blocks {
        // few rows (the batch-1 serving case): a row split can't feed
        // the pool, so split the output columns instead — strips write
        // disjoint column ranges, which keeps the SendPtr contract
        let min_strips = (MIN_OPS_PER_THREAD / (2 * k * NR * rows).max(1)).max(1);
        parallel_ranges(n_strips, min_strips, |_, strips| {
            let out = unsafe { std::slice::from_raw_parts_mut(out_ptr.ptr(), rows * n) };
            for s in strips {
                let strip = &panel[s * strip_len..(s + 1) * strip_len];
                let j0 = s * NR;
                let cols = NR.min(n - j0);
                for blk in 0..row_blocks {
                    let i0 = blk * MR;
                    let rmax = MR.min(rows - i0);
                    micro_i8(kern, a, strip, kg, wide, out, i0, rmax, j0, cols, n, co);
                }
            }
        });
        return;
    }
    let min_blocks = (MIN_OPS_PER_THREAD / (2 * k * n * MR).max(1)).max(1);
    parallel_ranges(row_blocks, min_blocks, |_, blocks| {
        let out = unsafe { std::slice::from_raw_parts_mut(out_ptr.ptr(), rows * n) };
        // strip-outer order keeps one i8 strip (kg×64 bytes) hot across
        // this thread's row blocks, same as the f32 kernel
        for s in 0..n_strips {
            let strip = &panel[s * strip_len..(s + 1) * strip_len];
            let j0 = s * NR;
            let cols = NR.min(n - j0);
            for blk in blocks.clone() {
                let i0 = blk * MR;
                let rmax = MR.min(rows - i0);
                micro_i8(kern, a, strip, kg, wide, out, i0, rmax, j0, cols, n, co);
            }
        }
    });
}

/// One NUMA node's slice of a K4-packed weight panel: a contiguous
/// range of column strips with its own byte copy, allocated (and so
/// first-touched) by a pool task hinted to that node — which is what
/// places the pages in that node's local memory under first-touch NUMA
/// policy. Built by `Int8Panel` at weight prep when `util::topo`
/// reports a multi-node layout; shard `i` is consumed by node `i`'s
/// workers via [`crate::util::pool::parallel_sharded`].
pub struct PanelShard {
    /// Strip indices `[start, end)` of the full panel this shard holds.
    pub strips: std::ops::Range<usize>,
    /// `strips.len() * strip_len` panel bytes, node-local.
    pub bytes: Vec<i8>,
}

/// NUMA-sharded [`gemm_i8_fused`]: identical math over per-node panel
/// shards. Each shard's strips are dispatched as node-hinted tasks, so
/// the i8 panel bytes stream from node-local memory and every i32
/// accumulator (an MR×NR stack tile inside [`micro_i8`]) is node-local
/// by construction. Bit-identity with the flat entry is structural:
/// per-(strip, row-block) tiles see the exact same bytes in the exact
/// same K4 order regardless of which shard copy — or which thread —
/// serves them, and the integer accumulation is exact.
pub fn gemm_i8_fused_sharded(
    a: &QuantizedActs,
    shards: &[PanelShard],
    n: usize,
    wbits: u32,
    co: &EpilogueCoeffs,
    out: &mut [f32],
) {
    let kern = Kernel::active();
    let kern = if kern.supported() { kern } else { Kernel::Scalar };
    if crate::obs::enabled() {
        crate::obs::metrics::kernel_counter(kern).inc();
    }
    let (rows, k) = (a.rows, a.m);
    assert!(k < MAX_K, "k={k} would overflow the i32 accumulator");
    assert_eq!(out.len(), rows * n);
    assert_eq!(co.scale.len(), n);
    assert_eq!(co.zc.len(), n);
    assert_eq!(co.fixed.len(), n);
    assert_eq!(co.bias.len(), n);
    if rows == 0 || n == 0 {
        return;
    }
    let kg = k.div_ceil(K4);
    let strip_len = kg * NR * K4;
    let n_strips = n.div_ceil(NR);
    let covered: usize = shards.iter().map(|s| s.strips.len()).sum();
    assert_eq!(covered, n_strips, "shards must cover every strip exactly once");
    for s in shards {
        assert_eq!(s.bytes.len(), s.strips.len() * strip_len, "shard not K4-packed for [{k}, {n}]");
    }
    let wide = !simd::maddubs_safe(a.aq.bits, wbits);
    let row_blocks = rows.div_ceil(MR);
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    // Column-strip split in every regime: strips are what the shards
    // partition, and strips write disjoint output columns (the SendPtr
    // contract). Within a task: strip-outer / row-block-inner, the same
    // per-tile order as the flat entry.
    let min_strips = (MIN_OPS_PER_THREAD / (2 * k * NR * rows).max(1)).max(1);
    let ranges: Vec<std::ops::Range<usize>> = shards.iter().map(|s| s.strips.clone()).collect();
    parallel_sharded(&ranges, min_strips, |si, strips| {
        let sh = &shards[si];
        let out = unsafe { std::slice::from_raw_parts_mut(out_ptr.ptr(), rows * n) };
        for s in strips {
            let off = (s - sh.strips.start) * strip_len;
            let strip = &sh.bytes[off..off + strip_len];
            let j0 = s * NR;
            let cols = NR.min(n - j0);
            for blk in 0..row_blocks {
                let i0 = blk * MR;
                let rmax = MR.min(rows - i0);
                micro_i8(kern, a, strip, kg, wide, out, i0, rmax, j0, cols, n, co);
            }
        }
    });
}

/// Grouped (depthwise) counterpart of [`gemm_i8_fused`]:
/// `y[r][j] = scale_j·(dot_rj + zc_j·gsum_rj + fixed_j) + bias_j` over a
/// K4-packed grouped weight panel (`pack_panel_k4` of the [kk, c]
/// centered codes — the same one-time prep as the dense path), with the
/// per-lane kernel dispatched by [`Kernel::active`]. The epilogue is the
/// dense one with `m = kk` and the per-row code sum replaced by the
/// per-(row, group) sum. `out` [rows, c] is fully overwritten.
pub fn dwconv_i8_fused(
    a: &GroupedQuantizedActs,
    panel: &[i8],
    c: usize,
    wbits: u32,
    co: &EpilogueCoeffs,
    out: &mut [f32],
) {
    dwconv_i8_fused_with(Kernel::active(), a, panel, c, wbits, co, out)
}

/// [`dwconv_i8_fused`] with the kernel forced — the benching/testing
/// entry that bypasses detection and the env override.
pub fn dwconv_i8_fused_with(
    kern: Kernel,
    a: &GroupedQuantizedActs,
    panel: &[i8],
    c: usize,
    wbits: u32,
    co: &EpilogueCoeffs,
    out: &mut [f32],
) {
    let kern = if kern.supported() { kern } else { Kernel::Scalar };
    if crate::obs::enabled() {
        crate::obs::metrics::kernel_counter(kern).inc();
    }
    let (rows, kk) = (a.rows, a.kk);
    assert!(kk < MAX_K, "kk={kk} would overflow the i32 accumulator");
    assert_eq!(a.c, c, "activation groups vs layer channels");
    assert_eq!(out.len(), rows * c);
    assert_eq!(co.scale.len(), c);
    assert_eq!(co.zc.len(), c);
    assert_eq!(co.fixed.len(), c);
    assert_eq!(co.bias.len(), c);
    if rows == 0 || c == 0 {
        return;
    }
    let kg = kk.div_ceil(K4);
    let strip_len = kg * NR * K4;
    let n_strips = c.div_ceil(NR);
    assert_eq!(panel.len(), n_strips * strip_len, "panel not K4-packed for [{kk}, {c}]");
    assert_eq!(a.stride, n_strips * strip_len, "activation panel stride mismatch");
    let wide = !simd::maddubs_safe(a.aq.bits, wbits);
    let row_blocks = rows.div_ceil(MR);
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    // rows = b·oh·ow, so a row split feeds the pool on every realistic
    // depthwise call (even batch 1 has oh·ow rows); the whole weight
    // panel is a few k-groups × 64 bytes and stays L1-resident
    let min_blocks = (MIN_OPS_PER_THREAD / (2 * kk * c * MR).max(1)).max(1);
    parallel_ranges(row_blocks, min_blocks, |_, blocks| {
        let out = unsafe { std::slice::from_raw_parts_mut(out_ptr.ptr(), rows * c) };
        for blk in blocks {
            let i0 = blk * MR;
            let rmax = MR.min(rows - i0);
            for s in 0..n_strips {
                let strip = &panel[s * strip_len..(s + 1) * strip_len];
                let j0 = s * NR;
                let cols = NR.min(c - j0);
                let mut acc = [[0i32; NR]; MR];
                simd::dot_i8_grouped(
                    kern,
                    &a.codes[i0 * a.stride + s * strip_len..],
                    a.stride,
                    rmax,
                    strip,
                    kg,
                    wide,
                    &mut acc,
                );
                for (r, accr) in acc.iter().take(rmax).enumerate() {
                    let orow = &mut out[(i0 + r) * c + j0..(i0 + r) * c + j0 + cols];
                    for (l, (o, &d)) in orow.iter_mut().zip(&accr[..cols]).enumerate() {
                        let j = j0 + l;
                        let gs = a.gsum[(i0 + r) * c + j] as f64;
                        *o = (co.scale[j] * (d as f64 + co.zc[j] * gs + co.fixed[j])
                            + co.bias[j]) as f32;
                    }
                }
            }
        }
    });
}

/// One MR×NR tile: dispatched integer dot (`util::simd::dot_i8`) plus
/// the fused dequant drain. The drain is identical for every kernel, so
/// bit-identical accumulators give bit-identical f32 outputs.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_i8(
    kern: Kernel,
    a: &QuantizedActs,
    strip: &[i8],
    kg: usize,
    wide: bool,
    out: &mut [f32],
    i0: usize,
    rmax: usize,
    j0: usize,
    cols: usize,
    n: usize,
    co: &EpilogueCoeffs,
) {
    let mut acc = [[0i32; NR]; MR];
    simd::dot_i8(kern, &a.codes[i0 * a.stride..], a.stride, rmax, strip, kg, wide, &mut acc);
    for (r, accr) in acc.iter().take(rmax).enumerate() {
        let rs = a.rsum[i0 + r] as f64;
        let orow = &mut out[(i0 + r) * n + j0..(i0 + r) * n + j0 + cols];
        for (l, (o, &d)) in orow.iter_mut().zip(&accr[..cols]).enumerate() {
            let j = j0 + l;
            *o = (co.scale[j] * (d as f64 + co.zc[j] * rs + co.fixed[j]) + co.bias[j]) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn quantize_codes_and_rowsums() {
        let aq = ActQuant::from_range(-2.0, 2.0, 8, 1.0);
        let mut rng = Rng::new(5);
        let x = Tensor::new(&[3, 17], rng.normal_vec(51));
        let qa = QuantizedActs::quantize(&x, aq);
        assert_eq!(qa.stride, 20, "17 rounds up to the K4 group width");
        assert_eq!(qa.codes.len(), 3 * 20);
        for r in 0..3 {
            let row = &qa.codes[r * qa.stride..(r + 1) * qa.stride];
            let want: i32 = row.iter().map(|&c| c as i32).sum();
            assert_eq!(qa.rsum[r], want);
            // stored codes are the unsigned grid codes, pad is zero
            for (c, &v) in row.iter().zip(x.row(r)) {
                assert_eq!(*c as f32, aq.code(v));
            }
            assert!(row[17..].iter().all(|&c| c == 0), "pad bytes must stay zero");
        }
    }

    #[test]
    fn quantize_parallel_matches_inline() {
        // large enough to cross QUANT_MIN_ELEMS_PER_THREAD: the split
        // path must produce the same codes as the inline path
        let aq = ActQuant::from_range(-3.0, 3.0, 8, 1.0);
        let mut rng = Rng::new(9);
        let (rows, m) = (64, 1024);
        let x = Tensor::new(&[rows, m], rng.normal_vec(rows * m));
        let qa = QuantizedActs::quantize(&x, aq);
        for r in 0..rows {
            let row = &qa.codes[r * qa.stride..r * qa.stride + m];
            for (c, &v) in row.iter().zip(x.row(r)) {
                assert_eq!(*c as f32, aq.code(v));
            }
            assert_eq!(qa.rsum[r], row.iter().map(|&c| c as i32).sum::<i32>());
        }
    }

    #[test]
    fn k4_panel_layout() {
        let mut rng = Rng::new(6);
        for &(k, n) in &[(3usize, 5usize), (7, 16), (4, 33), (1, 1), (8, 16)] {
            let s: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let panel = pack_panel_k4(&s, k, n);
            let kg = k.div_ceil(K4);
            assert_eq!(panel.len(), n.div_ceil(NR) * kg * NR * K4, "({k},{n})");
            for kk in 0..kg * K4 {
                let (g, t) = (kk / K4, kk % K4);
                for j in 0..n.div_ceil(NR) * NR {
                    let (strip, l) = (j / NR, j % NR);
                    let got = panel[strip * kg * NR * K4 + (g * NR + l) * K4 + t];
                    let want = if kk < k && j < n { s[kk * n + j] } else { 0 };
                    assert_eq!(got, want, "({k},{n}) kk={kk} j={j}");
                }
            }
        }
    }

    /// Integer GEMM against a plain f64 loop over the *dequantized*
    /// values — the identity the whole serving path rests on.
    #[test]
    fn gemm_matches_dequantized_reference() {
        let mut rng = Rng::new(7);
        for &(rows, k, n) in &[(1usize, 8usize, 3usize), (4, 16, 16), (5, 33, 21), (9, 7, 40)] {
            let wbits = 4u32;
            let cw = 1i32 << (wbits - 1);
            // random centered weight codes + per-column grid
            let s: Vec<i8> = (0..k * n).map(|_| (rng.below(16) as i32 - cw) as i8).collect();
            let delta: Vec<f32> = (0..n).map(|_| rng.range_f32(0.01, 0.2)).collect();
            let zero: Vec<f32> = (0..n).map(|_| (rng.below(9) as f32) - 8.0).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let x = Tensor::new(&[rows, k], rng.normal_vec(rows * k));
            let aq = ActQuant::from_range(x.min(), x.max(), 8, 1.0);
            let acts = QuantizedActs::quantize(&x, aq);

            // epilogue coefficients straight from the derivation
            let za = aq.zero as f64;
            let mut csum = vec![0i64; n];
            for (idx, &v) in s.iter().enumerate() {
                csum[idx % n] += v as i64;
            }
            let co = EpilogueCoeffs {
                scale: delta.iter().map(|&d| aq.scale as f64 * d as f64).collect(),
                zc: zero.iter().map(|&z| cw as f64 + z as f64).collect(),
                fixed: (0..n)
                    .map(|j| za * (csum[j] as f64 + k as f64 * (cw as f64 + zero[j] as f64)))
                    .collect(),
                bias: bias.iter().map(|&b| b as f64).collect(),
            };
            let panel = pack_panel_k4(&s, k, n);
            let mut y = vec![0.0f32; rows * n];
            gemm_i8_fused(&acts, &panel, n, wbits, &co, &mut y);

            // reference: fake-quant x, dequantize w, f64 matmul
            for r in 0..rows {
                for j in 0..n {
                    let mut acc = bias[j] as f64;
                    for kk in 0..k {
                        let xh = aq.apply(x.at2(r, kk)) as f64;
                        let wq = ((s[kk * n + j] as i32 + cw) as f32 + zero[j]) * delta[j];
                        acc += xh * wq as f64;
                    }
                    let got = y[r * n + j] as f64;
                    let tol = 1e-3 * acc.abs().max(1.0);
                    assert!((got - acc).abs() <= tol, "({rows},{k},{n}) r={r} j={j}: {got} vs {acc}");
                }
            }
        }
    }

    #[test]
    fn grouped_quantize_layout_and_sums() {
        let aq = ActQuant::from_range(-2.0, 2.0, 8, 1.0);
        let mut rng = Rng::new(11);
        let (rows, c, kk) = (3usize, 21usize, 9usize); // c % NR ≠ 0, kk % 4 ≠ 0
        let x3 = Tensor::new(&[rows, c, kk], rng.normal_vec(rows * c * kk));
        let qa = GroupedQuantizedActs::quantize(&x3, aq);
        let kg = kk.div_ceil(K4);
        let strip_len = kg * NR * K4;
        assert_eq!(qa.stride, c.div_ceil(NR) * strip_len);
        assert_eq!(qa.codes.len(), rows * qa.stride);
        for r in 0..rows {
            let panel = &qa.codes[r * qa.stride..(r + 1) * qa.stride];
            let mut seen = vec![false; qa.stride];
            for ch in 0..c {
                let (s, l) = (ch / NR, ch % NR);
                let mut sum = 0i32;
                for p in 0..kk {
                    let (g, t) = (p / K4, p % K4);
                    let idx = s * strip_len + (g * NR + l) * K4 + t;
                    seen[idx] = true;
                    let got = panel[idx] as f32;
                    assert_eq!(got, aq.code(x3.data()[(r * c + ch) * kk + p]), "r={r} ch={ch} p={p}");
                    sum += panel[idx] as i32;
                }
                assert_eq!(qa.gsum[r * c + ch], sum, "r={r} ch={ch}");
            }
            // everything not covered by a (group, patch) pair is padding
            for (idx, &v) in panel.iter().enumerate() {
                if !seen[idx] {
                    assert_eq!(v, 0, "pad byte {idx} must stay zero");
                }
            }
        }
    }

    /// Grouped integer conv against a plain f64 loop over the
    /// *dequantized* values — the depthwise analogue of
    /// `gemm_matches_dequantized_reference`.
    #[test]
    fn dwconv_matches_dequantized_reference() {
        let mut rng = Rng::new(12);
        for &(rows, kk, c) in &[(1usize, 1usize, 1usize), (4, 9, 8), (5, 9, 21), (7, 4, 40)] {
            let wbits = 4u32;
            let cw = 1i32 << (wbits - 1);
            // random centered weight codes [kk, c] + per-channel grid
            let s: Vec<i8> = (0..kk * c).map(|_| (rng.below(16) as i32 - cw) as i8).collect();
            let delta: Vec<f32> = (0..c).map(|_| rng.range_f32(0.01, 0.2)).collect();
            let zero: Vec<f32> = (0..c).map(|_| (rng.below(9) as f32) - 8.0).collect();
            let bias: Vec<f32> = (0..c).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let x3 = Tensor::new(&[rows, c, kk], rng.normal_vec(rows * c * kk));
            let aq = ActQuant::from_range(x3.min(), x3.max(), 8, 1.0);
            let acts = GroupedQuantizedActs::quantize(&x3, aq);

            let za = aq.zero as f64;
            let mut csum = vec![0i64; c];
            for (idx, &v) in s.iter().enumerate() {
                csum[idx % c] += v as i64;
            }
            let co = EpilogueCoeffs {
                scale: delta.iter().map(|&d| aq.scale as f64 * d as f64).collect(),
                zc: zero.iter().map(|&z| cw as f64 + z as f64).collect(),
                fixed: (0..c)
                    .map(|j| za * (csum[j] as f64 + kk as f64 * (cw as f64 + zero[j] as f64)))
                    .collect(),
                bias: bias.iter().map(|&b| b as f64).collect(),
            };
            let panel = pack_panel_k4(&s, kk, c);
            let mut y = vec![0.0f32; rows * c];
            dwconv_i8_fused(&acts, &panel, c, wbits, &co, &mut y);

            // reference: fake-quant patches, dequantize w, f64 dot
            for r in 0..rows {
                for j in 0..c {
                    let mut acc = bias[j] as f64;
                    for p in 0..kk {
                        let xh = aq.apply(x3.data()[(r * c + j) * kk + p]) as f64;
                        let wq = ((s[p * c + j] as i32 + cw) as f32 + zero[j]) * delta[j];
                        acc += xh * wq as f64;
                    }
                    let got = y[r * c + j] as f64;
                    let tol = 1e-3 * acc.abs().max(1.0);
                    assert!((got - acc).abs() <= tol, "({rows},{kk},{c}) r={r} j={j}: {got} vs {acc}");
                }
            }
        }
    }

    /// Sharded GEMM must be bit-identical to the flat entry: same
    /// bytes, same per-tile order, exact integer accumulation — the
    /// contract that lets NUMA sharding ride under the parity tests.
    #[test]
    fn sharded_gemm_bit_identical_to_flat() {
        let mut rng = Rng::new(13);
        for &(rows, k, n) in &[(1usize, 8usize, 48usize), (5, 33, 40), (9, 16, 64)] {
            let wbits = 4u32;
            let cw = 1i32 << (wbits - 1);
            let s: Vec<i8> = (0..k * n).map(|_| (rng.below(16) as i32 - cw) as i8).collect();
            let x = Tensor::new(&[rows, k], rng.normal_vec(rows * k));
            let aq = ActQuant::from_range(x.min(), x.max(), 8, 1.0);
            let acts = QuantizedActs::quantize(&x, aq);
            let co = EpilogueCoeffs {
                scale: (0..n).map(|_| rng.range_f32(0.01, 0.2) as f64).collect(),
                zc: (0..n).map(|_| rng.below(17) as f64 - 8.0).collect(),
                fixed: (0..n).map(|_| rng.below(100) as f64).collect(),
                bias: (0..n).map(|_| rng.range_f32(-1.0, 1.0) as f64).collect(),
            };
            let panel = pack_panel_k4(&s, k, n);
            let mut flat = vec![0.0f32; rows * n];
            gemm_i8_fused(&acts, &panel, n, wbits, &co, &mut flat);

            // split the strips into 1, 2 and 3 hand-built shards
            let kg = k.div_ceil(K4);
            let strip_len = kg * NR * K4;
            let n_strips = n.div_ceil(NR);
            for parts in 1..=3usize.min(n_strips) {
                let per = n_strips.div_ceil(parts);
                let shards: Vec<PanelShard> = (0..parts)
                    .map(|i| {
                        let r = (i * per).min(n_strips)..((i + 1) * per).min(n_strips);
                        let bytes = panel[r.start * strip_len..r.end * strip_len].to_vec();
                        PanelShard { strips: r, bytes }
                    })
                    .filter(|sh| !sh.strips.is_empty())
                    .collect();
                let mut sharded = vec![0.0f32; rows * n];
                gemm_i8_fused_sharded(&acts, &shards, n, wbits, &co, &mut sharded);
                assert!(
                    flat.iter().zip(&sharded).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "({rows},{k},{n}) parts={parts}: sharded GEMM diverged from flat"
                );
            }
        }
    }

    #[test]
    fn empty_batch_is_noop() {
        let aq = ActQuant::from_range(0.0, 1.0, 8, 1.0);
        let acts = QuantizedActs::quantize(&Tensor::zeros(&[0, 4]), aq);
        let co = EpilogueCoeffs {
            scale: vec![1.0; 2],
            zc: vec![0.0; 2],
            fixed: vec![0.0; 2],
            bias: vec![0.0; 2],
        };
        let panel = pack_panel_k4(&[0i8; 8], 4, 2);
        gemm_i8_fused(&acts, &panel, 2, 4, &co, &mut []);
    }
}
