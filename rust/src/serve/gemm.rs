//! The integer serving GEMM: `u8 × i8 → i32` with the dequantization
//! epilogue fused into the accumulator drain, over runtime-dispatched
//! SIMD micro-kernels (`util::simd`).
//!
//! Activations carry their *uncentered* unsigned codes `qa ∈ [0, 2^ab)`
//! and weights their *centered* codes `u − 2^(b−1) ∈ i8` — the operand
//! signedness `vpmaddubsw`/`vpdpbusd` demand (unsigned × signed). The
//! products stay well inside i32: |qa·s| ≤ 2^8·2^7 = 2^15, so the k
//! extent would need to reach 2^16 to overflow — far beyond any layer
//! here, rejected at weight prep and asserted again below. All offsets
//! are exact integers, so the epilogue reconstructs the exact
//! uncentered integer sum
//!
//! ```text
//! Σ_i (qa_i + z_a)(u_ij + z_j)
//!   = dot_ij + (c_w + z_j)·rowsum_i + z_a·(colsum_j + m·(c_w + z_j))
//! ```
//!
//! in f64 (every term an integer < 2^53) and scales once by
//! `δ_a · δ_j`, giving bit-faithful agreement with the fake-quant f32
//! reference up to a single final rounding. `rowsum` (of the unsigned
//! codes) comes free during activation quantization; `colsum` (of the
//! centered weight codes) is precomputed at weight prep. `c_w = 2^(b−1)`
//! is the weight centering, folded here so the panel can stay signed.
//!
//! The kernel reuses the MR×NR register tiling of `tensor/matmul.rs`
//! with the B strips K4-interleaved (k in groups of 4 adjacent bytes —
//! the layout `vpdpbusd` and `vpmaddubsw` consume; one group row is 64
//! bytes, a single cache line). One panel layout serves every kernel,
//! so a model prepped under one `COMQ_KERNEL` can be re-benched under
//! another without re-packing. An i8 strip is still a quarter the f32
//! bytes, which is the whole bandwidth win on batch-1 serving; the same
//! persistent-pool parallelism splits over row blocks when the batch
//! can feed the pool and over column strips when it can't (batch-1).

use crate::quant::actq::ActQuant;
use crate::tensor::{Tensor, MR, NR};
use crate::util::pool::{parallel_ranges, SendPtr};
use crate::util::simd::{self, Kernel, K4};

/// At this k extent the worst-case i32 sum hits exactly 2^31
/// (2^16 · 2^15) and overflows, so the guard is strict. Weight prep
/// (`Int8Panel::from_packed`) rejects such layers at build time; the
/// assert below is the backstop for direct kernel callers. (Half the
/// old centered-i8 bound: the unsigned activation operand doubled the
/// per-product magnitude.)
pub(crate) const MAX_K: usize = 1 << 16;
const MIN_OPS_PER_THREAD: usize = 1 << 20;

/// Below this many elements, activation quantization runs inline — the
/// per-element cost is a few ns, so small batches can't amortize a pool
/// hand-off.
const QUANT_MIN_ELEMS_PER_THREAD: usize = 1 << 14;

/// A batch of activations quantized to uncentered u8 codes, plus the
/// per-row code sums the epilogue needs.
pub struct QuantizedActs {
    /// Unsigned codes `qa ∈ [0, 2^bits)`, row-major [rows, stride] with
    /// `stride = m` rounded up to the K4 group width; the pad bytes are
    /// zero (and the matching panel k-pad is zero, so padded products
    /// vanish from every kernel identically).
    pub codes: Vec<u8>,
    /// Per-row sum of the unsigned codes.
    pub rsum: Vec<i32>,
    pub rows: usize,
    /// True k extent (columns of the source input).
    pub m: usize,
    /// Row stride of `codes` in bytes: `m.div_ceil(4) * 4`.
    pub stride: usize,
    pub aq: ActQuant,
}

impl QuantizedActs {
    /// Quantize a 2-D input [rows, m] with the given activation grid.
    /// Rows are split over the persistent pool above a size threshold —
    /// each row writes a disjoint `codes` stripe and `rsum` slot, the
    /// pool's `SendPtr` contract — so batch serving no longer pays a
    /// serial pre-GEMM quantization tax.
    pub fn quantize(x: &Tensor, aq: ActQuant) -> QuantizedActs {
        assert!(aq.bits >= 1 && aq.bits <= 8, "activation bits {} not in 1..=8", aq.bits);
        let (rows, m) = (x.rows(), x.cols());
        let stride = m.div_ceil(K4) * K4;
        let mut codes = vec![0u8; rows * stride];
        let mut rsum = vec![0i32; rows];
        let cptr = SendPtr::new(codes.as_mut_ptr());
        let rptr = SendPtr::new(rsum.as_mut_ptr());
        let min_rows = (QUANT_MIN_ELEMS_PER_THREAD / m.max(1)).max(1);
        parallel_ranges(rows, min_rows, |_, rr| {
            for r in rr {
                // disjoint per-row stripes; pad bytes stay zero
                let crow = unsafe { std::slice::from_raw_parts_mut(cptr.ptr().add(r * stride), m) };
                let mut acc = 0i32;
                for (c, &v) in crow.iter_mut().zip(x.row(r)) {
                    let q = aq.code(v) as i32;
                    *c = q as u8;
                    acc += q;
                }
                unsafe { *rptr.ptr().add(r) = acc };
            }
        });
        QuantizedActs { codes, rsum, rows, m, stride, aq }
    }
}

/// Per-column epilogue coefficients for one (layer, activation-grid)
/// pair; see [`crate::serve::Int8Panel::coeffs`] for the derivation.
pub struct EpilogueCoeffs {
    /// δ_a · δ_j — the only non-integer factor.
    pub scale: Vec<f64>,
    /// c_w + z_j — multiplies the per-row unsigned code sum.
    pub zc: Vec<f64>,
    /// z_a·(colsum_j + m·(c_w + z_j)) — the row-independent term.
    pub fixed: Vec<f64>,
    /// Layer bias, added after scaling.
    pub bias: Vec<f64>,
}

/// Pack centered codes [k, n] row-major into K4-interleaved column
/// strips of width NR: within strip `s`, group `g` holds the `NR × 4`
/// bytes `panel[(g·NR + l)·4 + t] = s[(4g + t)·n + (s·NR + l)]`,
/// zero-padded in both the last strip and the last k group. Done once
/// at weight prep; the layout feeds every kernel (see `util::simd`).
pub fn pack_panel_k4(s: &[i8], k: usize, n: usize) -> Vec<i8> {
    assert_eq!(s.len(), k * n);
    let n_strips = n.div_ceil(NR);
    let kg = k.div_ceil(K4);
    let mut panel = vec![0i8; n_strips * kg * NR * K4];
    for strip in 0..n_strips {
        let j0 = strip * NR;
        let cols = NR.min(n - j0);
        let base = strip * kg * NR * K4;
        for kk in 0..k {
            let (g, t) = (kk / K4, kk % K4);
            let src = &s[kk * n + j0..kk * n + j0 + cols];
            for (l, &v) in src.iter().enumerate() {
                panel[base + (g * NR + l) * K4 + t] = v;
            }
        }
    }
    panel
}

/// y[r][j] = scale_j·(dot_rj + zc_j·rsum_r + fixed_j) + bias_j over a
/// K4-packed i8 weight panel, with the micro-kernel chosen by
/// [`Kernel::active`] (CPU detection + `COMQ_KERNEL` override). `wbits`
/// is the panel's source code width — it sizes the AVX2 saturation
/// guard. `out` [rows, n] is fully overwritten.
pub fn gemm_i8_fused(
    a: &QuantizedActs,
    panel: &[i8],
    n: usize,
    wbits: u32,
    co: &EpilogueCoeffs,
    out: &mut [f32],
) {
    gemm_i8_fused_with(Kernel::active(), a, panel, n, wbits, co, out)
}

/// [`gemm_i8_fused`] with the kernel forced — the benching/testing
/// entry that bypasses detection and the env override.
pub fn gemm_i8_fused_with(
    kern: Kernel,
    a: &QuantizedActs,
    panel: &[i8],
    n: usize,
    wbits: u32,
    co: &EpilogueCoeffs,
    out: &mut [f32],
) {
    // resolve the defensive unsupported-kernel fallback once per call,
    // so every per-tile dispatch below takes its guarded arm
    let kern = if kern.supported() { kern } else { Kernel::Scalar };
    let (rows, k) = (a.rows, a.m);
    assert!(k < MAX_K, "k={k} would overflow the i32 accumulator");
    assert_eq!(out.len(), rows * n);
    assert_eq!(co.scale.len(), n);
    assert_eq!(co.zc.len(), n);
    assert_eq!(co.fixed.len(), n);
    assert_eq!(co.bias.len(), n);
    if rows == 0 || n == 0 {
        return;
    }
    let kg = k.div_ceil(K4);
    let strip_len = kg * NR * K4;
    let n_strips = n.div_ceil(NR);
    assert_eq!(panel.len(), n_strips * strip_len, "panel not K4-packed for [{k}, {n}]");
    let wide = !simd::maddubs_safe(a.aq.bits, wbits);
    let row_blocks = rows.div_ceil(MR);
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    if row_blocks < crate::util::pool::num_threads() && n_strips > row_blocks {
        // few rows (the batch-1 serving case): a row split can't feed
        // the pool, so split the output columns instead — strips write
        // disjoint column ranges, which keeps the SendPtr contract
        let min_strips = (MIN_OPS_PER_THREAD / (2 * k * NR * rows).max(1)).max(1);
        parallel_ranges(n_strips, min_strips, |_, strips| {
            let out = unsafe { std::slice::from_raw_parts_mut(out_ptr.ptr(), rows * n) };
            for s in strips {
                let strip = &panel[s * strip_len..(s + 1) * strip_len];
                let j0 = s * NR;
                let cols = NR.min(n - j0);
                for blk in 0..row_blocks {
                    let i0 = blk * MR;
                    let rmax = MR.min(rows - i0);
                    micro_i8(kern, a, strip, kg, wide, out, i0, rmax, j0, cols, n, co);
                }
            }
        });
        return;
    }
    let min_blocks = (MIN_OPS_PER_THREAD / (2 * k * n * MR).max(1)).max(1);
    parallel_ranges(row_blocks, min_blocks, |_, blocks| {
        let out = unsafe { std::slice::from_raw_parts_mut(out_ptr.ptr(), rows * n) };
        // strip-outer order keeps one i8 strip (kg×64 bytes) hot across
        // this thread's row blocks, same as the f32 kernel
        for s in 0..n_strips {
            let strip = &panel[s * strip_len..(s + 1) * strip_len];
            let j0 = s * NR;
            let cols = NR.min(n - j0);
            for blk in blocks.clone() {
                let i0 = blk * MR;
                let rmax = MR.min(rows - i0);
                micro_i8(kern, a, strip, kg, wide, out, i0, rmax, j0, cols, n, co);
            }
        }
    });
}

/// One MR×NR tile: dispatched integer dot (`util::simd::dot_i8`) plus
/// the fused dequant drain. The drain is identical for every kernel, so
/// bit-identical accumulators give bit-identical f32 outputs.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_i8(
    kern: Kernel,
    a: &QuantizedActs,
    strip: &[i8],
    kg: usize,
    wide: bool,
    out: &mut [f32],
    i0: usize,
    rmax: usize,
    j0: usize,
    cols: usize,
    n: usize,
    co: &EpilogueCoeffs,
) {
    let mut acc = [[0i32; NR]; MR];
    simd::dot_i8(kern, &a.codes[i0 * a.stride..], a.stride, rmax, strip, kg, wide, &mut acc);
    for (r, accr) in acc.iter().take(rmax).enumerate() {
        let rs = a.rsum[i0 + r] as f64;
        let orow = &mut out[(i0 + r) * n + j0..(i0 + r) * n + j0 + cols];
        for (l, (o, &d)) in orow.iter_mut().zip(&accr[..cols]).enumerate() {
            let j = j0 + l;
            *o = (co.scale[j] * (d as f64 + co.zc[j] * rs + co.fixed[j]) + co.bias[j]) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn quantize_codes_and_rowsums() {
        let aq = ActQuant::from_range(-2.0, 2.0, 8, 1.0);
        let mut rng = Rng::new(5);
        let x = Tensor::new(&[3, 17], rng.normal_vec(51));
        let qa = QuantizedActs::quantize(&x, aq);
        assert_eq!(qa.stride, 20, "17 rounds up to the K4 group width");
        assert_eq!(qa.codes.len(), 3 * 20);
        for r in 0..3 {
            let row = &qa.codes[r * qa.stride..(r + 1) * qa.stride];
            let want: i32 = row.iter().map(|&c| c as i32).sum();
            assert_eq!(qa.rsum[r], want);
            // stored codes are the unsigned grid codes, pad is zero
            for (c, &v) in row.iter().zip(x.row(r)) {
                assert_eq!(*c as f32, aq.code(v));
            }
            assert!(row[17..].iter().all(|&c| c == 0), "pad bytes must stay zero");
        }
    }

    #[test]
    fn quantize_parallel_matches_inline() {
        // large enough to cross QUANT_MIN_ELEMS_PER_THREAD: the split
        // path must produce the same codes as the inline path
        let aq = ActQuant::from_range(-3.0, 3.0, 8, 1.0);
        let mut rng = Rng::new(9);
        let (rows, m) = (64, 1024);
        let x = Tensor::new(&[rows, m], rng.normal_vec(rows * m));
        let qa = QuantizedActs::quantize(&x, aq);
        for r in 0..rows {
            let row = &qa.codes[r * qa.stride..r * qa.stride + m];
            for (c, &v) in row.iter().zip(x.row(r)) {
                assert_eq!(*c as f32, aq.code(v));
            }
            assert_eq!(qa.rsum[r], row.iter().map(|&c| c as i32).sum::<i32>());
        }
    }

    #[test]
    fn k4_panel_layout() {
        let mut rng = Rng::new(6);
        for &(k, n) in &[(3usize, 5usize), (7, 16), (4, 33), (1, 1), (8, 16)] {
            let s: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let panel = pack_panel_k4(&s, k, n);
            let kg = k.div_ceil(K4);
            assert_eq!(panel.len(), n.div_ceil(NR) * kg * NR * K4, "({k},{n})");
            for kk in 0..kg * K4 {
                let (g, t) = (kk / K4, kk % K4);
                for j in 0..n.div_ceil(NR) * NR {
                    let (strip, l) = (j / NR, j % NR);
                    let got = panel[strip * kg * NR * K4 + (g * NR + l) * K4 + t];
                    let want = if kk < k && j < n { s[kk * n + j] } else { 0 };
                    assert_eq!(got, want, "({k},{n}) kk={kk} j={j}");
                }
            }
        }
    }

    /// Integer GEMM against a plain f64 loop over the *dequantized*
    /// values — the identity the whole serving path rests on.
    #[test]
    fn gemm_matches_dequantized_reference() {
        let mut rng = Rng::new(7);
        for &(rows, k, n) in &[(1usize, 8usize, 3usize), (4, 16, 16), (5, 33, 21), (9, 7, 40)] {
            let wbits = 4u32;
            let cw = 1i32 << (wbits - 1);
            // random centered weight codes + per-column grid
            let s: Vec<i8> = (0..k * n).map(|_| (rng.below(16) as i32 - cw) as i8).collect();
            let delta: Vec<f32> = (0..n).map(|_| rng.range_f32(0.01, 0.2)).collect();
            let zero: Vec<f32> = (0..n).map(|_| (rng.below(9) as f32) - 8.0).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let x = Tensor::new(&[rows, k], rng.normal_vec(rows * k));
            let aq = ActQuant::from_range(x.min(), x.max(), 8, 1.0);
            let acts = QuantizedActs::quantize(&x, aq);

            // epilogue coefficients straight from the derivation
            let za = aq.zero as f64;
            let mut csum = vec![0i64; n];
            for (idx, &v) in s.iter().enumerate() {
                csum[idx % n] += v as i64;
            }
            let co = EpilogueCoeffs {
                scale: delta.iter().map(|&d| aq.scale as f64 * d as f64).collect(),
                zc: zero.iter().map(|&z| cw as f64 + z as f64).collect(),
                fixed: (0..n)
                    .map(|j| za * (csum[j] as f64 + k as f64 * (cw as f64 + zero[j] as f64)))
                    .collect(),
                bias: bias.iter().map(|&b| b as f64).collect(),
            };
            let panel = pack_panel_k4(&s, k, n);
            let mut y = vec![0.0f32; rows * n];
            gemm_i8_fused(&acts, &panel, n, wbits, &co, &mut y);

            // reference: fake-quant x, dequantize w, f64 matmul
            for r in 0..rows {
                for j in 0..n {
                    let mut acc = bias[j] as f64;
                    for kk in 0..k {
                        let xh = aq.apply(x.at2(r, kk)) as f64;
                        let wq = ((s[kk * n + j] as i32 + cw) as f32 + zero[j]) * delta[j];
                        acc += xh * wq as f64;
                    }
                    let got = y[r * n + j] as f64;
                    let tol = 1e-3 * acc.abs().max(1.0);
                    assert!((got - acc).abs() <= tol, "({rows},{k},{n}) r={r} j={j}: {got} vs {acc}");
                }
            }
        }
    }

    #[test]
    fn empty_batch_is_noop() {
        let aq = ActQuant::from_range(0.0, 1.0, 8, 1.0);
        let acts = QuantizedActs::quantize(&Tensor::zeros(&[0, 4]), aq);
        let co = EpilogueCoeffs {
            scale: vec![1.0; 2],
            zc: vec![0.0; 2],
            fixed: vec![0.0; 2],
            bias: vec![0.0; 2],
        };
        let panel = pack_panel_k4(&[0i8; 8], 4, 2);
        gemm_i8_fused(&acts, &panel, 2, 4, &co, &mut []);
    }
}
