//! The integer serving GEMM: `i8 × i8 → i32` with the dequantization
//! epilogue fused into the accumulator drain.
//!
//! Both operands are *centered* codes: activations store `qa − 2^(ab−1)`
//! and weights store `u − 2^(b−1)`, so every value fits i8 for any bit
//! width ≤ 8 and the products stay well inside i32 (|a·w| ≤ 2^14; the
//! k extent would need to reach 2^17 to overflow, far beyond any layer
//! here — asserted anyway). The centering offsets are exact integers, so
//! the epilogue can reconstruct the *exact* uncentered integer sum
//!
//! ```text
//! Σ_i (qa_i + z_a)(u_ij + z_j)
//!   = dot_ij + (c_w + z_j)·rowsum_i + (c_a + z_a)·colsum_j
//!     + m·(c_a + z_a)·(c_w + z_j)
//! ```
//!
//! in f64 (all terms are integers < 2^53) and scale once by
//! `δ_a · δ_j`, giving bit-faithful agreement with the fake-quant f32
//! reference up to a single final rounding. `rowsum` comes free during
//! activation quantization; `colsum` is precomputed at weight prep.
//!
//! The kernel reuses the MR×NR register tiling of `tensor/matmul.rs`
//! (same strip-packed B layout, i8 instead of f32 — one B strip is a
//! quarter the bytes, which is the whole bandwidth win on batch-1
//! serving) and the same persistent-pool parallelism, splitting over
//! row blocks when the batch can feed the pool and over column strips
//! when it can't (batch-1).

use crate::quant::actq::ActQuant;
use crate::tensor::{Tensor, MR, NR};
use crate::util::pool::{parallel_ranges, SendPtr};

/// At this k extent the worst-case i32 sum hits exactly 2^31 (2^17 ·
/// 2^14) and overflows, so the guard is strict. Weight prep
/// (`Int8Panel::from_packed`) rejects such layers at build time; the
/// assert below is the backstop for direct kernel callers.
pub(crate) const MAX_K: usize = 1 << 17;
const MIN_OPS_PER_THREAD: usize = 1 << 20;

/// A batch of activations quantized to centered i8 codes, plus the
/// per-row code sums the epilogue needs.
pub struct QuantizedActs {
    /// Centered codes `qa − 2^(bits−1)`, row-major [rows, m].
    pub codes: Vec<i8>,
    /// Per-row sum of centered codes.
    pub rsum: Vec<i32>,
    pub rows: usize,
    pub m: usize,
    pub aq: ActQuant,
}

impl QuantizedActs {
    /// Quantize a 2-D input [rows, m] with the given activation grid.
    pub fn quantize(x: &Tensor, aq: ActQuant) -> QuantizedActs {
        assert!(aq.bits >= 1 && aq.bits <= 8, "activation bits {} not in 1..=8", aq.bits);
        let (rows, m) = (x.rows(), x.cols());
        let center = (1i32 << (aq.bits - 1)) as f32;
        let mut codes = vec![0i8; rows * m];
        let mut rsum = vec![0i32; rows];
        for (r, (crow, rs)) in codes.chunks_exact_mut(m).zip(&mut rsum).enumerate() {
            let xrow = x.row(r);
            let mut acc = 0i32;
            for (c, &v) in crow.iter_mut().zip(xrow) {
                let s = (aq.code(v) - center) as i32;
                *c = s as i8;
                acc += s;
            }
            *rs = acc;
        }
        QuantizedActs { codes, rsum, rows, m, aq }
    }
}

/// Per-column epilogue coefficients for one (layer, activation-grid)
/// pair; see [`crate::serve::Int8Panel::coeffs`] for the derivation.
pub struct EpilogueCoeffs {
    /// δ_a · δ_j — the only non-integer factor.
    pub scale: Vec<f64>,
    /// c_w + z_j — multiplies the per-row code sum.
    pub zc: Vec<f64>,
    /// (c_a + z_a)·(colsum_j + m·(c_w + z_j)) — the row-independent term.
    pub fixed: Vec<f64>,
    /// Layer bias, added after scaling.
    pub bias: Vec<f64>,
}

/// Pack centered codes [k, n] row-major into column strips of width NR,
/// k-contiguous and zero-padded on the last strip — the i8 twin of
/// `tensor::matmul::pack_b`, done once at weight prep.
pub(crate) fn pack_panel_i8(s: &[i8], k: usize, n: usize) -> Vec<i8> {
    assert_eq!(s.len(), k * n);
    let n_strips = n.div_ceil(NR);
    let mut panel = vec![0i8; n_strips * k * NR];
    for strip in 0..n_strips {
        let j0 = strip * NR;
        let cols = NR.min(n - j0);
        for kk in 0..k {
            let src = &s[kk * n + j0..kk * n + j0 + cols];
            panel[strip * k * NR + kk * NR..strip * k * NR + kk * NR + cols].copy_from_slice(src);
        }
    }
    panel
}

/// y[r][j] = scale_j·(dot_rj + zc_j·rsum_r + fixed_j) + bias_j over a
/// strip-packed i8 weight panel. `out` [rows, n] is fully overwritten.
pub fn gemm_i8_fused(
    a: &QuantizedActs,
    panel: &[i8],
    n: usize,
    co: &EpilogueCoeffs,
    out: &mut [f32],
) {
    let (rows, k) = (a.rows, a.m);
    assert!(k < MAX_K, "k={k} would overflow the i32 accumulator");
    assert_eq!(out.len(), rows * n);
    assert_eq!(co.scale.len(), n);
    assert_eq!(co.zc.len(), n);
    assert_eq!(co.fixed.len(), n);
    assert_eq!(co.bias.len(), n);
    if rows == 0 || n == 0 {
        return;
    }
    let n_strips = n.div_ceil(NR);
    assert_eq!(panel.len(), n_strips * k * NR, "panel not packed for [{k}, {n}]");
    let row_blocks = rows.div_ceil(MR);
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    if row_blocks < crate::util::pool::num_threads() && n_strips > row_blocks {
        // few rows (the batch-1 serving case): a row split can't feed
        // the pool, so split the output columns instead — strips write
        // disjoint column ranges, which keeps the SendPtr contract
        let min_strips = (MIN_OPS_PER_THREAD / (2 * k * NR * rows).max(1)).max(1);
        parallel_ranges(n_strips, min_strips, |_, strips| {
            let out = unsafe { std::slice::from_raw_parts_mut(out_ptr.ptr(), rows * n) };
            for s in strips {
                let strip = &panel[s * k * NR..(s + 1) * k * NR];
                let j0 = s * NR;
                let cols = NR.min(n - j0);
                for blk in 0..row_blocks {
                    let i0 = blk * MR;
                    let rmax = MR.min(rows - i0);
                    micro_i8(a, strip, out, i0, rmax, j0, cols, k, n, co);
                }
            }
        });
        return;
    }
    let min_blocks = (MIN_OPS_PER_THREAD / (2 * k * n * MR).max(1)).max(1);
    parallel_ranges(row_blocks, min_blocks, |_, blocks| {
        let out = unsafe { std::slice::from_raw_parts_mut(out_ptr.ptr(), rows * n) };
        // strip-outer order keeps one i8 strip (k×NR bytes) hot across
        // this thread's row blocks, same as the f32 kernel
        for s in 0..n_strips {
            let strip = &panel[s * k * NR..(s + 1) * k * NR];
            let j0 = s * NR;
            let cols = NR.min(n - j0);
            for blk in blocks.clone() {
                let i0 = blk * MR;
                let rmax = MR.min(rows - i0);
                micro_i8(a, strip, out, i0, rmax, j0, cols, k, n, co);
            }
        }
    });
}

/// MR×NR i8 micro-kernel with fused dequant drain.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_i8(
    a: &QuantizedActs,
    strip: &[i8],
    out: &mut [f32],
    i0: usize,
    rmax: usize,
    j0: usize,
    cols: usize,
    k: usize,
    n: usize,
    co: &EpilogueCoeffs,
) {
    let codes = &a.codes;
    let mut acc = [[0i32; NR]; MR];
    for kk in 0..k {
        let brow = &strip[kk * NR..kk * NR + NR];
        for (r, accr) in acc.iter_mut().take(rmax).enumerate() {
            let av = codes[(i0 + r) * k + kk] as i32;
            for l in 0..NR {
                accr[l] += av * brow[l] as i32;
            }
        }
    }
    for (r, accr) in acc.iter().take(rmax).enumerate() {
        let rs = a.rsum[i0 + r] as f64;
        let orow = &mut out[(i0 + r) * n + j0..(i0 + r) * n + j0 + cols];
        for (l, (o, &d)) in orow.iter_mut().zip(&accr[..cols]).enumerate() {
            let j = j0 + l;
            *o = (co.scale[j] * (d as f64 + co.zc[j] * rs + co.fixed[j]) + co.bias[j]) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn quantize_codes_and_rowsums() {
        let aq = ActQuant::from_range(-2.0, 2.0, 8, 1.0);
        let mut rng = Rng::new(5);
        let x = Tensor::new(&[3, 17], rng.normal_vec(51));
        let qa = QuantizedActs::quantize(&x, aq);
        assert_eq!(qa.codes.len(), 51);
        for r in 0..3 {
            let want: i32 = qa.codes[r * 17..(r + 1) * 17].iter().map(|&c| c as i32).sum();
            assert_eq!(qa.rsum[r], want);
            // centered code + center reproduces the unsigned code
            for (c, &v) in qa.codes[r * 17..(r + 1) * 17].iter().zip(x.row(r)) {
                assert_eq!((*c as i32 + 128) as f32, aq.code(v));
            }
        }
    }

    #[test]
    fn panel_layout_matches_pack_b() {
        // pack the same values through the f32 packer and compare
        let mut rng = Rng::new(6);
        for &(k, n) in &[(3usize, 5usize), (7, 16), (4, 33), (1, 1)] {
            let s: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let sf: Vec<f32> = s.iter().map(|&v| v as f32).collect();
            let pi = pack_panel_i8(&s, k, n);
            let pf = crate::tensor::pack_b(&sf, k, n);
            assert_eq!(pi.len(), pf.len(), "({k},{n})");
            for (a, b) in pi.iter().zip(&pf) {
                assert_eq!(*a as f32, *b, "({k},{n})");
            }
        }
    }

    /// Integer GEMM against a plain f64 loop over the *dequantized*
    /// values — the identity the whole serving path rests on.
    #[test]
    fn gemm_matches_dequantized_reference() {
        let mut rng = Rng::new(7);
        for &(rows, k, n) in &[(1usize, 8usize, 3usize), (4, 16, 16), (5, 33, 21), (9, 7, 40)] {
            let wbits = 4u32;
            let cw = 1i32 << (wbits - 1);
            // random centered weight codes + per-column grid
            let s: Vec<i8> = (0..k * n).map(|_| (rng.below(16) as i32 - cw) as i8).collect();
            let delta: Vec<f32> = (0..n).map(|_| rng.range_f32(0.01, 0.2)).collect();
            let zero: Vec<f32> = (0..n).map(|_| (rng.below(9) as f32) - 8.0).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let x = Tensor::new(&[rows, k], rng.normal_vec(rows * k));
            let aq = ActQuant::from_range(x.min(), x.max(), 8, 1.0);
            let acts = QuantizedActs::quantize(&x, aq);

            // epilogue coefficients straight from the derivation
            let ca = 128.0f64 + aq.zero as f64;
            let mut csum = vec![0i64; n];
            for (idx, &v) in s.iter().enumerate() {
                csum[idx % n] += v as i64;
            }
            let co = EpilogueCoeffs {
                scale: delta.iter().map(|&d| aq.scale as f64 * d as f64).collect(),
                zc: zero.iter().map(|&z| cw as f64 + z as f64).collect(),
                fixed: (0..n)
                    .map(|j| ca * (csum[j] as f64 + k as f64 * (cw as f64 + zero[j] as f64)))
                    .collect(),
                bias: bias.iter().map(|&b| b as f64).collect(),
            };
            let panel = pack_panel_i8(&s, k, n);
            let mut y = vec![0.0f32; rows * n];
            gemm_i8_fused(&acts, &panel, n, &co, &mut y);

            // reference: fake-quant x, dequantize w, f64 matmul
            for r in 0..rows {
                for j in 0..n {
                    let mut acc = bias[j] as f64;
                    for kk in 0..k {
                        let xh = aq.apply(x.at2(r, kk)) as f64;
                        let wq = ((s[kk * n + j] as i32 + cw) as f32 + zero[j]) * delta[j];
                        acc += xh * wq as f64;
                    }
                    let got = y[r * n + j] as f64;
                    let tol = 1e-3 * acc.abs().max(1.0);
                    assert!((got - acc).abs() <= tol, "({rows},{k},{n}) r={r} j={j}: {got} vs {acc}");
                }
            }
        }
    }

    #[test]
    fn empty_batch_is_noop() {
        let aq = ActQuant::from_range(0.0, 1.0, 8, 1.0);
        let acts = QuantizedActs::quantize(&Tensor::zeros(&[0, 4]), aq);
        let co = EpilogueCoeffs {
            scale: vec![1.0; 2],
            zc: vec![0.0; 2],
            fixed: vec![0.0; 2],
            bias: vec![0.0; 2],
        };
        let panel = pack_panel_i8(&[0i8; 8], 4, 2);
        gemm_i8_fused(&acts, &panel, 2, &co, &mut []);
    }
}
