//! Evaluation harness: top-1 / top-5 accuracy of (quantized) models on
//! the validation split, through either execution engine.

use anyhow::{anyhow, bail, Result};

use crate::calib::EngineKind;
use crate::manifest::Manifest;
use crate::model::{Model, Tap};
use crate::quant::actq::ActQuant;
use crate::runtime::Engine;
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Accuracy {
    pub top1: f64,
    pub top5: f64,
    pub n: usize,
}

/// Activation-quantization mode for evaluation.
#[derive(Debug, Clone)]
pub enum ActMode {
    /// Full-precision activations (weight-only tables).
    Fp,
    /// Fake-quantize every quantizable layer input with these params
    /// (manifest layer order).
    Quant { bits: u32, params: Vec<ActQuant> },
}

/// Evaluate a model on (images, labels).
pub fn evaluate(
    manifest: &Manifest,
    model: &Model,
    images: &Tensor,
    labels: &[i32],
    engine: EngineKind,
    act: &ActMode,
) -> Result<Accuracy> {
    let logits = match engine {
        EngineKind::Native => forward_native(manifest, model, images, act)?,
        EngineKind::Pjrt => forward_pjrt(manifest, model, images, act)?,
        EngineKind::Int8 => bail!(
            "the int8 engine executes packed codes, which a dequantized f32 \
             Model no longer carries — build a serve::QuantizedModel (from \
             a .cqm via serve::load_cached, or from pipeline parts) and use \
             eval::evaluate_int8; `comq quantize --engine int8` and \
             `comq run-packed --engine int8` do this routing"
        ),
    };
    score(&logits, labels)
}

/// Integer-runtime forward over all images (batched to bound memory) —
/// the serving path's accuracy instrument.
pub fn forward_int8(
    qm: &crate::serve::QuantizedModel,
    images: &Tensor,
    batch: usize,
) -> Result<Tensor> {
    let n = images.shape()[0];
    let classes = qm.classes();
    let img_elems: usize = images.shape()[1..].iter().product();
    let mut logits = Tensor::zeros(&[n, classes]);
    let mut i = 0;
    while i < n {
        let hi = (i + batch).min(n);
        let chunk = Tensor::new(
            &[hi - i, images.shape()[1], images.shape()[2], images.shape()[3]],
            images.data()[i * img_elems..hi * img_elems].to_vec(),
        );
        let out = qm.forward(&chunk);
        logits.data_mut()[i * classes..hi * classes].copy_from_slice(out.data());
        i = hi;
    }
    Ok(logits)
}

/// Top-1/top-5 of a packed checkpoint served through the i8 GEMM path.
pub fn evaluate_int8(
    qm: &crate::serve::QuantizedModel,
    images: &Tensor,
    labels: &[i32],
    batch: usize,
) -> Result<Accuracy> {
    let logits = forward_int8(qm, images, batch)?;
    score(&logits, labels)
}

/// Native engine forward over all images (batched to bound memory).
fn forward_native(
    manifest: &Manifest,
    model: &Model,
    images: &Tensor,
    act: &ActMode,
) -> Result<Tensor> {
    let n = images.shape()[0];
    let b = manifest.batch;
    let classes = manifest.classes;
    let img_elems: usize = images.shape()[1..].iter().product();
    let mut logits = Tensor::zeros(&[n, classes]);
    let actq_map = build_actq_map(model, act);
    let mut i = 0;
    while i < n {
        let hi = (i + b).min(n);
        let chunk = Tensor::new(
            &[hi - i, images.shape()[1], images.shape()[2], images.shape()[3]],
            images.data()[i * img_elems..hi * img_elems].to_vec(),
        );
        let out = match &actq_map {
            Some(map) => model.forward(&chunk, &mut Tap::ActQ(map)),
            None => model.forward(&chunk, &mut Tap::None),
        };
        logits.data_mut()[i * classes..hi * classes].copy_from_slice(out.data());
        i = hi;
    }
    Ok(logits)
}

fn build_actq_map(
    model: &Model,
    act: &ActMode,
) -> Option<std::collections::BTreeMap<String, ActQuant>> {
    match act {
        ActMode::Fp => None,
        ActMode::Quant { params, .. } => {
            let mut map = std::collections::BTreeMap::new();
            for (l, aq) in model.info.quant_layers.iter().zip(params) {
                map.insert(l.name.clone(), *aq);
            }
            Some(map)
        }
    }
}

/// PJRT engine forward: the `forward` artifact (or `forward_actq{bits}`)
/// with parameters fed positionally. The artifact batch is fixed; the
/// last partial batch is padded and the padded rows discarded.
fn forward_pjrt(
    manifest: &Manifest,
    model: &Model,
    images: &Tensor,
    act: &ActMode,
) -> Result<Tensor> {
    let engine = Engine::global()?;
    let (art_key, act_rows) = match act {
        ActMode::Fp => ("forward".to_string(), None),
        ActMode::Quant { bits, params } => {
            let key = format!("forward_actq{bits}");
            let rows: Vec<f32> = params.iter().flat_map(|a| a.as_row()).collect();
            (key, Some(Tensor::new(&[params.len(), 2], rows)))
        }
    };
    let art = model
        .info
        .artifacts
        .get(&art_key)
        .ok_or_else(|| anyhow!("model has no '{art_key}' artifact"))?;
    let path = manifest.path(art);
    let b = manifest.batch;
    let n = images.shape()[0];
    let classes = manifest.classes;
    let img_elems: usize = images.shape()[1..].iter().product();
    let params = model.params_in_order();
    let mut logits = Tensor::zeros(&[n, classes]);
    let mut i = 0;
    while i < n {
        let hi = (i + b).min(n);
        // pad the final partial batch with zeros
        let mut chunk_data = images.data()[i * img_elems..hi * img_elems].to_vec();
        chunk_data.resize(b * img_elems, 0.0);
        let chunk = Tensor::new(
            &[b, images.shape()[1], images.shape()[2], images.shape()[3]],
            chunk_data,
        );
        let mut inputs: Vec<&Tensor> = params.clone();
        if let Some(ar) = &act_rows {
            inputs.push(ar);
        }
        inputs.push(&chunk);
        let outs = engine.run(&path, &inputs)?;
        let out = &outs[0];
        if out.cols() != classes {
            bail!("forward artifact returned {} classes, expected {classes}", out.cols());
        }
        logits.data_mut()[i * classes..hi * classes]
            .copy_from_slice(&out.data()[..(hi - i) * classes]);
        i = hi;
    }
    Ok(logits)
}

/// Top-1 / top-5 from logits.
pub fn score(logits: &Tensor, labels: &[i32]) -> Result<Accuracy> {
    let n = logits.rows();
    if n != labels.len() {
        bail!("logits rows {n} vs labels {}", labels.len());
    }
    let c = logits.cols();
    let k = 5.min(c);
    let mut top1 = 0usize;
    let mut top5 = 0usize;
    for (i, &lbl) in labels.iter().enumerate() {
        let row = logits.row(i);
        let lbl = lbl as usize;
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if argmax == lbl {
            top1 += 1;
        }
        // top-5: count entries strictly greater than label's score
        let lscore = row[lbl];
        let better = row.iter().filter(|&&v| v > lscore).count();
        if better < k {
            top5 += 1;
        }
    }
    Ok(Accuracy { top1: top1 as f64 / n as f64, top5: top5 as f64 / n as f64, n })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_perfect_and_zero() {
        // 3 classes, identity logits
        let logits = Tensor::new(&[3, 3], vec![9., 0., 0., 0., 9., 0., 0., 0., 9.]);
        let acc = score(&logits, &[0, 1, 2]).unwrap();
        assert_eq!(acc.top1, 1.0);
        assert_eq!(acc.top5, 1.0);
        let acc2 = score(&logits, &[1, 2, 0]).unwrap();
        assert_eq!(acc2.top1, 0.0);
        assert_eq!(acc2.top5, 1.0); // only 3 classes, all within top-5
    }

    #[test]
    fn top5_counts_rank() {
        // 8 classes; label ranked 6th -> top1 no, top5 no
        let mut row = vec![0.0f32; 8];
        for (i, v) in row.iter_mut().enumerate() {
            *v = (8 - i) as f32;
        }
        // label 5 has score 3; entries greater: 5 -> not top5
        let logits = Tensor::new(&[1, 8], row);
        let acc = score(&logits, &[5]).unwrap();
        assert_eq!(acc.top1, 0.0);
        assert_eq!(acc.top5, 0.0);
        let acc2 = score(&logits, &[4]).unwrap();
        assert_eq!(acc2.top5, 1.0);
    }

    #[test]
    fn mismatched_lengths_error() {
        let logits = Tensor::zeros(&[2, 3]);
        assert!(score(&logits, &[0]).is_err());
    }
}
