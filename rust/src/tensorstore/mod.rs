//! CTS ("Comq Tensor Store") reader/writer — the python→rust interchange
//! format for checkpoints, calibration and validation data.
//!
//! The v1 body mirrors python/compile/export.py byte-for-byte:
//!
//! ```text
//! magic  b"CTS1"
//! u32    tensor count                       (little-endian throughout)
//! per tensor:
//!     u16  name length, then utf-8 name bytes
//!     u8   dtype   (0 = f32, 1 = i32)
//!     u8   ndim
//!     u32  dims[ndim]
//!     raw  data (C-contiguous)
//! ```
//!
//! # v2 integrity footer
//!
//! Files written by this module append a footer after the v1 body:
//!
//! ```text
//! magic  b"CQI2"
//! u32    entry count n      (must equal the body's tensor count)
//! u32    entry_crc[n]       CRC32 (IEEE) of each entry's record bytes
//!                           (name length through data), in file order
//! u32    file_crc           CRC32 of every byte before this field
//!                           (body + footer magic + n + entry CRCs)
//! u32    entry count n      (trailing copy, for end-first discovery)
//! magic  b"CQI2"
//! ```
//!
//! Compatibility rules:
//!
//! * **v1 files still load** (python's `write_cts` has no footer): a
//!   file not ending in the footer magic parses as a bare v1 body and
//!   is flagged [`Integrity::Unverified`].
//! * **python still reads v2 files**: `read_cts` consumes exactly
//!   `count` records and ignores trailing bytes, so the footer is
//!   invisible to it.
//! * A file that *does* end in the footer magic must carry a fully
//!   valid footer — a torn or corrupt footer is a typed error, never a
//!   silent downgrade to unverified. (A v1 file whose last four bytes
//!   coincide with the magic is misclassified with probability 2⁻³²;
//!   we accept that.)
//!
//! [`write_store`] is crash-safe: the full byte image (body + footer)
//! is serialized in memory, written to a temp file in the destination
//! directory, fsynced, then atomically renamed over the target. A kill
//! at any point leaves either the intact old file or a temp file the
//! loader never looks at — never a truncated-but-parseable checkpoint.
//! The `COMQ_FAULT` sites `io_err[:<stage>]`, `corrupt_load:<off>` and
//! `slow_load:<ms>` let tests drive every failure boundary.

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Context, Result};

use crate::serve::net::fault::{self, IoStage};
use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"CTS1";
const FOOTER_MAGIC: &[u8; 4] = b"CQI2";
/// Fixed footer overhead: leading magic + n + file_crc + trailing n +
/// trailing magic (the entry CRCs add 4 bytes each).
const FOOTER_FIXED: usize = 20;

/// One stored tensor: f32 payloads become `Tensor`; i32 payloads (labels)
/// are kept as raw vectors.
#[derive(Debug, Clone, PartialEq)]
pub enum Entry {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Entry {
    pub fn tensor(&self) -> Result<&Tensor> {
        match self {
            Entry::F32(t) => Ok(t),
            Entry::I32 { .. } => bail!("entry is i32, expected f32 tensor"),
        }
    }

    pub fn ints(&self) -> Result<&[i32]> {
        match self {
            Entry::I32 { data, .. } => Ok(data),
            Entry::F32(_) => bail!("entry is f32, expected i32"),
        }
    }
}

/// An ordered name -> tensor map.
pub type Store = BTreeMap<String, Entry>;

/// Whether a loaded store's bytes were checksum-verified.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Integrity {
    /// v2 footer present; every entry CRC and the whole-file CRC match.
    Verified,
    /// v1 file (no footer) — parsed structurally, but bit flips in the
    /// payload are undetectable.
    Unverified,
}

impl Integrity {
    pub fn name(&self) -> &'static str {
        match self {
            Integrity::Verified => "verified",
            Integrity::Unverified => "unverified",
        }
    }
}

/// A parsed store plus what we know about its integrity.
#[derive(Debug)]
pub struct LoadedStore {
    pub store: Store,
    pub integrity: Integrity,
}

/// CRC32 (IEEE 802.3, reflected, poly 0xEDB88320) — the checksum both
/// footer fields use. Hand-rolled: no crates in the vendor set.
pub fn crc32(bytes: &[u8]) -> u32 {
    const TABLE: [u32; 256] = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut n = 0;
    while n < 256 {
        let mut c = n as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[n] = c;
        n += 1;
    }
    table
}

pub fn read_store(path: &str) -> Result<Store> {
    Ok(read_store_checked(path)?.store)
}

/// Read + verify a store, reporting whether its bytes were covered by
/// a v2 footer. The `slow_load` / `corrupt_load` fault sites fire here
/// — every checkpoint load in the crate funnels through this function.
pub fn read_store_checked(path: &str) -> Result<LoadedStore> {
    if let Some(d) = fault::slow_load() {
        std::thread::sleep(d);
    }
    let mut bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    if let Some(off) = fault::corrupt_load() {
        if !bytes.is_empty() {
            let i = off.min(bytes.len() - 1);
            bytes[i] ^= 0xFF;
        }
    }
    parse_store_checked(&bytes).with_context(|| format!("parsing {path}"))
}

pub fn parse_store(bytes: &[u8]) -> Result<Store> {
    Ok(parse_store_checked(bytes)?.store)
}

pub fn parse_store_checked(bytes: &[u8]) -> Result<LoadedStore> {
    match split_footer(bytes)? {
        Some((body, entry_crcs)) => {
            let (store, spans) = parse_body(body)?;
            if spans.len() != entry_crcs.len() {
                bail!(
                    "integrity: footer lists {} entries but the body has {}",
                    entry_crcs.len(),
                    spans.len()
                );
            }
            for (i, (&(start, end), &want)) in spans.iter().zip(&entry_crcs).enumerate() {
                let got = crc32(&body[start..end]);
                if got != want {
                    bail!(
                        "integrity: entry #{i} CRC mismatch \
                         (stored {want:#010x}, computed {got:#010x})"
                    );
                }
            }
            Ok(LoadedStore { store, integrity: Integrity::Verified })
        }
        None => {
            let (store, _) = parse_body(bytes)?;
            Ok(LoadedStore { store, integrity: Integrity::Unverified })
        }
    }
}

/// If `bytes` end in a v2 footer, verify the whole-file CRC and return
/// the body slice + per-entry CRCs. `Ok(None)` means a v1 file; any
/// footer defect once the trailing magic matched is an error.
fn split_footer(bytes: &[u8]) -> Result<Option<(&[u8], Vec<u32>)>> {
    let len = bytes.len();
    if len < 8 || &bytes[len - 4..] != FOOTER_MAGIC {
        return Ok(None);
    }
    let n = u32::from_le_bytes(bytes[len - 8..len - 4].try_into().unwrap()) as usize;
    let footer_len = n
        .checked_mul(4)
        .and_then(|c| c.checked_add(FOOTER_FIXED))
        .ok_or_else(|| anyhow!("integrity: absurd footer entry count {n}"))?;
    if footer_len > len {
        bail!("integrity: footer claims {n} entries but the file is only {len} bytes");
    }
    let foot = &bytes[len - footer_len..];
    if &foot[..4] != FOOTER_MAGIC {
        bail!("integrity: trailing footer magic without a leading one (torn footer?)");
    }
    let n_lead = u32::from_le_bytes(foot[4..8].try_into().unwrap()) as usize;
    if n_lead != n {
        bail!("integrity: footer entry counts disagree ({n_lead} leading vs {n} trailing)");
    }
    let stored = u32::from_le_bytes(bytes[len - 12..len - 8].try_into().unwrap());
    let got = crc32(&bytes[..len - 12]);
    if got != stored {
        bail!("integrity: whole-file CRC mismatch (stored {stored:#010x}, computed {got:#010x})");
    }
    let entry_crcs = foot[8..8 + 4 * n]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Some((&bytes[..len - footer_len], entry_crcs)))
}

/// Parse a v1 body, recording each entry's byte span (start of the
/// name-length field through the end of its data) for CRC checking.
/// Every length is validated before use — malformed input is a typed
/// error, never a panic or an unbounded allocation.
fn parse_body(bytes: &[u8]) -> Result<(Store, Vec<(usize, usize)>)> {
    let mut r = Cursor { b: bytes, i: 0 };
    if r.take(4)? != MAGIC {
        bail!("bad magic");
    }
    let count = r.u32()? as usize;
    let mut out = Store::new();
    let mut spans = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        let start = r.i;
        let nlen = r.u16()? as usize;
        let name = std::str::from_utf8(r.take(nlen)?)
            .map_err(|e| anyhow!("bad tensor name: {e}"))?
            .to_string();
        let dtype = r.u8()?;
        let ndim = r.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()? as usize);
        }
        let mut numel: usize = 1;
        for &d in &shape {
            numel = numel
                .checked_mul(d)
                .ok_or_else(|| anyhow!("tensor '{name}': shape overflows usize"))?;
        }
        let numel = numel.max(1);
        let nbytes = numel
            .checked_mul(4)
            .ok_or_else(|| anyhow!("tensor '{name}': byte size overflows usize"))?;
        let entry = match dtype {
            0 => {
                // take() bounds-checks against the file before the
                // allocation, so numel can never exceed the byte count
                let raw = r.take(nbytes)?;
                let mut data = vec![0.0f32; numel];
                for (i, c) in raw.chunks_exact(4).enumerate() {
                    data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
                let shp = if shape.is_empty() { vec![1] } else { shape };
                Entry::F32(Tensor::new(&shp, data))
            }
            1 => {
                let raw = r.take(nbytes)?;
                let mut data = vec![0i32; numel];
                for (i, c) in raw.chunks_exact(4).enumerate() {
                    data[i] = i32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
                Entry::I32 { shape, data }
            }
            d => bail!("unknown dtype {d} for '{name}'"),
        };
        spans.push((start, r.i));
        if out.insert(name.clone(), entry).is_some() {
            bail!("duplicate tensor '{name}'");
        }
    }
    if r.i != bytes.len() {
        bail!("{} trailing bytes", bytes.len() - r.i);
    }
    Ok((out, spans))
}

/// Serialize a store to its full v2 byte image: v1 body + integrity
/// footer. Entry CRCs are computed over exactly the spans
/// [`parse_body`] records on the way back in.
pub fn serialize_store(store: &Store) -> Vec<u8> {
    let mut b: Vec<u8> = Vec::new();
    b.extend_from_slice(MAGIC);
    b.extend_from_slice(&(store.len() as u32).to_le_bytes());
    let mut entry_crcs = Vec::with_capacity(store.len());
    for (name, entry) in store {
        let start = b.len();
        let nb = name.as_bytes();
        b.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        b.extend_from_slice(nb);
        match entry {
            Entry::F32(t) => {
                b.push(0u8);
                b.push(t.ndim() as u8);
                for &d in t.shape() {
                    b.extend_from_slice(&(d as u32).to_le_bytes());
                }
                for &x in t.data() {
                    b.extend_from_slice(&x.to_le_bytes());
                }
            }
            Entry::I32 { shape, data } => {
                b.push(1u8);
                b.push(shape.len() as u8);
                for &d in shape {
                    b.extend_from_slice(&(d as u32).to_le_bytes());
                }
                for &x in data {
                    b.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        entry_crcs.push(crc32(&b[start..]));
    }
    b.extend_from_slice(FOOTER_MAGIC);
    b.extend_from_slice(&(store.len() as u32).to_le_bytes());
    for c in &entry_crcs {
        b.extend_from_slice(&c.to_le_bytes());
    }
    let file_crc = crc32(&b);
    b.extend_from_slice(&file_crc.to_le_bytes());
    b.extend_from_slice(&(store.len() as u32).to_le_bytes());
    b.extend_from_slice(FOOTER_MAGIC);
    b
}

/// Crash-safe write: serialize in memory, write a temp file in the
/// destination directory, fsync, rename over the target, then
/// best-effort fsync the directory so the rename itself is durable.
/// On any failure the temp file is removed and the old file (if any)
/// is untouched.
pub fn write_store(path: &str, store: &Store) -> Result<()> {
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    let bytes = serialize_store(store);
    let tmp = format!(
        "{path}.tmp.{}.{}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    );
    let result = write_atomic(path, &tmp, &bytes);
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

fn write_atomic(path: &str, tmp: &str, bytes: &[u8]) -> Result<()> {
    if fault::io_error_at(IoStage::Create) {
        bail!("injected io_err at create ({tmp})");
    }
    let mut f = std::fs::File::create(tmp).with_context(|| format!("creating {tmp}"))?;
    if fault::io_error_at(IoStage::Write) {
        bail!("injected io_err at write ({tmp})");
    }
    f.write_all(bytes).with_context(|| format!("writing {tmp}"))?;
    if fault::io_error_at(IoStage::Sync) {
        bail!("injected io_err at sync ({tmp})");
    }
    f.sync_all().with_context(|| format!("syncing {tmp}"))?;
    drop(f);
    if fault::io_error_at(IoStage::Rename) {
        bail!("injected io_err at rename ({tmp} -> {path})");
    }
    std::fs::rename(tmp, path).with_context(|| format!("renaming {tmp} -> {path}"))?;
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked_add: a near-usize::MAX n must not wrap past the bound
        let end = self
            .i
            .checked_add(n)
            .ok_or_else(|| anyhow!("length overflow at byte {}", self.i))?;
        if end > self.b.len() {
            bail!("truncated file at byte {} (wanted {n} more)", self.i);
        }
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
}

/// Read a store but keep only f32 tensors (checkpoint convenience).
pub fn read_tensors(path: &str) -> Result<BTreeMap<String, Tensor>> {
    let store = read_store(path)?;
    let mut out = BTreeMap::new();
    for (k, v) in store {
        if let Entry::F32(t) = v {
            out.insert(k, t);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> String {
        let dir = std::env::temp_dir().join("comq_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().to_string()
    }

    fn sample() -> Store {
        let mut s = Store::new();
        s.insert("a/W".into(), Entry::F32(Tensor::new(&[2, 3], vec![1., -2., 3., 0.5, 0., 9.])));
        s.insert(
            "labels".into(),
            Entry::I32 { shape: vec![4], data: vec![1, 2, 3, -7] },
        );
        s
    }

    #[test]
    fn roundtrip() {
        let s = sample();
        let p = tmpfile("roundtrip.cts");
        write_store(&p, &s).unwrap();
        let r = read_store(&p).unwrap();
        assert_eq!(r, s);
    }

    #[test]
    fn v2_files_verify() {
        let s = sample();
        let p = tmpfile("verified.cts");
        write_store(&p, &s).unwrap();
        let loaded = read_store_checked(&p).unwrap();
        assert_eq!(loaded.integrity, Integrity::Verified);
        assert_eq!(loaded.store, s);
    }

    #[test]
    fn v1_files_load_unverified() {
        // serialize, then strip the footer: a v1 file as python writes it
        let s = sample();
        let bytes = serialize_store(&s);
        let footer_len = FOOTER_FIXED + 4 * s.len();
        let v1 = &bytes[..bytes.len() - footer_len];
        let loaded = parse_store_checked(v1).unwrap();
        assert_eq!(loaded.integrity, Integrity::Unverified);
        assert_eq!(loaded.store, s);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_store(b"NOPE\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut s = Store::new();
        s.insert("t".into(), Entry::F32(Tensor::new(&[8], vec![0.0; 8])));
        let p = tmpfile("trunc.cts");
        write_store(&p, &s).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        for cut in [3, 8, 12, bytes.len() - 1] {
            assert!(parse_store(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(parse_store(&extra).is_err());
    }

    #[test]
    fn corruption_is_detected_everywhere() {
        // flip one byte at every offset of a small v2 file: every flip
        // must be a typed error (the footer CRCs leave no blind spots)
        let bytes = serialize_store(&sample());
        for off in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[off] ^= 0xFF;
            assert!(
                parse_store_checked(&bad).is_err(),
                "flip at byte {off} went undetected"
            );
        }
    }

    #[test]
    fn torn_footer_is_an_error_not_a_downgrade() {
        // keep the trailing magic but corrupt the leading one: a file
        // that advertises v2 with a broken footer must not silently
        // load as unverified v1
        let bytes = serialize_store(&sample());
        let footer_start = bytes.len() - (FOOTER_FIXED + 4 * 2);
        let mut bad = bytes.clone();
        bad[footer_start] ^= 0xFF; // leading "CQI2" -> garbage
        let err = parse_store_checked(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("integrity"), "{err:#}");
    }

    #[test]
    fn python_written_fixture() {
        // Byte layout written by hand matching export.py
        let mut b: Vec<u8> = b"CTS1".to_vec();
        b.extend(1u32.to_le_bytes());
        b.extend(1u16.to_le_bytes());
        b.extend(b"x");
        b.push(0); // f32
        b.push(1); // ndim 1
        b.extend(2u32.to_le_bytes());
        b.extend(1.5f32.to_le_bytes());
        b.extend((-0.25f32).to_le_bytes());
        let s = parse_store(&b).unwrap();
        let t = s["x"].tensor().unwrap();
        assert_eq!(t.data(), &[1.5, -0.25]);
        assert_eq!(parse_store_checked(&b).unwrap().integrity, Integrity::Unverified);
    }

    #[test]
    fn crc32_known_vectors() {
        // the standard IEEE check value plus the empty string
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn failed_write_leaves_old_file_intact() {
        // no fault needed: target a path whose parent doesn't exist so
        // File::create fails, and check nothing appeared
        let p = tmpfile("no_such_dir/out.cts");
        assert!(write_store(&p, &sample()).is_err());
        assert!(!std::path::Path::new(&p).exists());
    }
}
