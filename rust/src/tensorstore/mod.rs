//! CTS ("Comq Tensor Store") reader/writer — the python→rust interchange
//! format for checkpoints, calibration and validation data.
//!
//! Mirrors python/compile/export.py byte-for-byte:
//!
//! ```text
//! magic  b"CTS1"
//! u32    tensor count                       (little-endian throughout)
//! per tensor:
//!     u16  name length, then utf-8 name bytes
//!     u8   dtype   (0 = f32, 1 = i32)
//!     u8   ndim
//!     u32  dims[ndim]
//!     raw  data (C-contiguous)
//! ```

use std::collections::BTreeMap;
use std::io::Write;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"CTS1";

/// One stored tensor: f32 payloads become `Tensor`; i32 payloads (labels)
/// are kept as raw vectors.
#[derive(Debug, Clone, PartialEq)]
pub enum Entry {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Entry {
    pub fn tensor(&self) -> Result<&Tensor> {
        match self {
            Entry::F32(t) => Ok(t),
            Entry::I32 { .. } => bail!("entry is i32, expected f32 tensor"),
        }
    }

    pub fn ints(&self) -> Result<&[i32]> {
        match self {
            Entry::I32 { data, .. } => Ok(data),
            Entry::F32(_) => bail!("entry is f32, expected i32"),
        }
    }
}

/// An ordered name -> tensor map.
pub type Store = BTreeMap<String, Entry>;

pub fn read_store(path: &str) -> Result<Store> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    parse_store(&bytes).with_context(|| format!("parsing {path}"))
}

pub fn parse_store(bytes: &[u8]) -> Result<Store> {
    let mut r = Cursor { b: bytes, i: 0 };
    if r.take(4)? != MAGIC {
        bail!("bad magic");
    }
    let count = r.u32()? as usize;
    let mut out = Store::new();
    for _ in 0..count {
        let nlen = r.u16()? as usize;
        let name = std::str::from_utf8(r.take(nlen)?)
            .map_err(|e| anyhow!("bad tensor name: {e}"))?
            .to_string();
        let dtype = r.u8()?;
        let ndim = r.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()? as usize);
        }
        let numel: usize = shape.iter().product::<usize>().max(1);
        let entry = match dtype {
            0 => {
                let raw = r.take(numel * 4)?;
                let mut data = vec![0.0f32; numel];
                for (i, c) in raw.chunks_exact(4).enumerate() {
                    data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
                let shp = if shape.is_empty() { vec![1] } else { shape };
                Entry::F32(Tensor::new(&shp, data))
            }
            1 => {
                let raw = r.take(numel * 4)?;
                let mut data = vec![0i32; numel];
                for (i, c) in raw.chunks_exact(4).enumerate() {
                    data[i] = i32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
                Entry::I32 { shape, data }
            }
            d => bail!("unknown dtype {d} for '{name}'"),
        };
        if out.insert(name.clone(), entry).is_some() {
            bail!("duplicate tensor '{name}'");
        }
    }
    if r.i != bytes.len() {
        bail!("{} trailing bytes", bytes.len() - r.i);
    }
    Ok(out)
}

pub fn write_store(path: &str, store: &Store) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {path}"))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&(store.len() as u32).to_le_bytes())?;
    for (name, entry) in store {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        match entry {
            Entry::F32(t) => {
                f.write_all(&[0u8, t.ndim() as u8])?;
                for &d in t.shape() {
                    f.write_all(&(d as u32).to_le_bytes())?;
                }
                for &x in t.data() {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            Entry::I32 { shape, data } => {
                f.write_all(&[1u8, shape.len() as u8])?;
                for &d in shape {
                    f.write_all(&(d as u32).to_le_bytes())?;
                }
                for &x in data {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    f.flush()?;
    Ok(())
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated file at byte {} (wanted {n} more)", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
}

/// Read a store but keep only f32 tensors (checkpoint convenience).
pub fn read_tensors(path: &str) -> Result<BTreeMap<String, Tensor>> {
    let store = read_store(path)?;
    let mut out = BTreeMap::new();
    for (k, v) in store {
        if let Entry::F32(t) = v {
            out.insert(k, t);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> String {
        let dir = std::env::temp_dir().join("comq_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().to_string()
    }

    #[test]
    fn roundtrip() {
        let mut s = Store::new();
        s.insert("a/W".into(), Entry::F32(Tensor::new(&[2, 3], vec![1., -2., 3., 0.5, 0., 9.])));
        s.insert(
            "labels".into(),
            Entry::I32 { shape: vec![4], data: vec![1, 2, 3, -7] },
        );
        let p = tmpfile("roundtrip.cts");
        write_store(&p, &s).unwrap();
        let r = read_store(&p).unwrap();
        assert_eq!(r, s);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_store(b"NOPE\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut s = Store::new();
        s.insert("t".into(), Entry::F32(Tensor::new(&[8], vec![0.0; 8])));
        let p = tmpfile("trunc.cts");
        write_store(&p, &s).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        for cut in [3, 8, 12, bytes.len() - 1] {
            assert!(parse_store(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(parse_store(&extra).is_err());
    }

    #[test]
    fn python_written_fixture() {
        // Byte layout written by hand matching export.py
        let mut b: Vec<u8> = b"CTS1".to_vec();
        b.extend(1u32.to_le_bytes());
        b.extend(1u16.to_le_bytes());
        b.extend(b"x");
        b.push(0); // f32
        b.push(1); // ndim 1
        b.extend(2u32.to_le_bytes());
        b.extend(1.5f32.to_le_bytes());
        b.extend((-0.25f32).to_le_bytes());
        let s = parse_store(&b).unwrap();
        let t = s["x"].tensor().unwrap();
        assert_eq!(t.data(), &[1.5, -0.25]);
    }
}
