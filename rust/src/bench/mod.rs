//! In-tree micro/macro benchmark harness (criterion is not in the
//! offline vendor set). Used by every `rust/benches/*` binary.
//!
//! Two facilities:
//! * `time_it` — warmup + repeated timing with mean/std/p50/p95;
//! * `Table`   — aligned table printing matching the paper's table rows,
//!   plus JSON dumping so EXPERIMENTS.md entries are regenerable.

pub mod suite;

use crate::util::{stats, Json, Timer};

/// Timing summary in seconds.
#[derive(Debug, Clone)]
pub struct Timing {
    pub mean: f64,
    pub std: f64,
    pub p50: f64,
    pub p95: f64,
    pub iters: usize,
}

impl Timing {
    pub fn fmt_ms(&self) -> String {
        format!("{:.3} ms ± {:.3} (p95 {:.3})", self.mean * 1e3, self.std * 1e3, self.p95 * 1e3)
    }
}

fn summarize(mut samples: Vec<f64>) -> Timing {
    // one sort feeds every percentile (mean/std are order-free)
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing {
        mean: stats::mean(&samples),
        std: stats::std_dev(&samples),
        p50: stats::quantile_sorted(&samples, 0.5),
        p95: stats::quantile_sorted(&samples, 0.95),
        iters: samples.len(),
    }
}

/// Time `f` with `warmup` unrecorded runs then `iters` recorded ones.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    summarize(samples)
}

/// Adaptive variant: runs until `min_secs` of samples or `max_iters`.
pub fn time_budget<F: FnMut()>(min_secs: f64, max_iters: usize, mut f: F) -> Timing {
    f(); // warmup
    let mut samples = Vec::new();
    let total = Timer::start();
    while total.secs() < min_secs && samples.len() < max_iters {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    summarize(samples)
}

/// A paper-style results table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj_from(vec![
            ("title", Json::Str(self.title.clone())),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Dump to bench_results/<slug>.json for EXPERIMENTS.md regeneration.
    pub fn save_json(&self, slug: &str) {
        let dir = std::path::Path::new("bench_results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{slug}.json"));
        let _ = std::fs::write(&path, self.to_json().to_string_pretty(1));
        println!("[saved {}]", path.display());
    }
}

/// A whole bench run as one machine-readable artifact. `bench_results/`
/// holds per-table snapshots of whatever ran last; a `Report` instead
/// collects every table of a run and lands at a *stable, committed* path
/// — `BENCH_<slug>.json` at the repo root — so the perf trajectory in
/// EXPERIMENTS.md §Perf stays diffable across PRs.
pub struct Report {
    pub slug: String,
    tables: Vec<Json>,
}

impl Report {
    pub fn new(slug: &str) -> Report {
        Report { slug: slug.to_string(), tables: Vec::new() }
    }

    /// Record a finished table (call after the last `row`).
    pub fn add(&mut self, table: &Table) {
        self.tables.push(table.to_json());
    }

    pub fn to_json(&self) -> Json {
        Json::obj_from(vec![
            ("bench", Json::Str(self.slug.clone())),
            ("tables", Json::Arr(self.tables.clone())),
        ])
    }

    /// Write `BENCH_<slug>.json` at the repo root (one level above the
    /// crate manifest), the stable path EXPERIMENTS.md points at.
    pub fn write_repo_root(&self) -> std::io::Result<std::path::PathBuf> {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join(format!("BENCH_{}.json", self.slug));
        std::fs::write(&path, self.to_json().to_string_pretty(1))?;
        println!("[saved {}]", path.display());
        Ok(path)
    }
}

/// Format an accuracy as the tables do.
pub fn pct(x: f64) -> String {
    if x.is_nan() {
        "-".into()
    } else {
        format!("{:.2}", x * 100.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_runs() {
        let t = time_it(1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert_eq!(t.iters, 5);
        assert!(t.mean >= 0.0);
        assert!(t.p95 >= t.p50);
    }

    #[test]
    fn budget_stops() {
        let t = time_budget(0.01, 3, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(t.iters <= 3);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.9234), "92.34");
        assert_eq!(pct(f64::NAN), "-");
    }

    #[test]
    fn report_round_trips_through_json() {
        let mut t = Table::new("engines", &["shape", "ns"]);
        t.row(vec!["(4096,192,384)".into(), "9.2".into()]);
        let mut rep = Report::new("micro_hotpath");
        rep.add(&t);
        let j = Json::parse(&rep.to_json().to_string_pretty(1)).unwrap();
        assert_eq!(j.get("bench").unwrap().str().unwrap(), "micro_hotpath");
        let tables = j.get("tables").unwrap().arr().unwrap();
        assert_eq!(tables.len(), 1);
        assert_eq!(tables[0].get("title").unwrap().str().unwrap(), "engines");
        let rows = tables[0].get("rows").unwrap().arr().unwrap();
        assert_eq!(rows[0].arr().unwrap()[1].str().unwrap(), "9.2");
    }
}
