//! Shared scaffolding for the paper-table bench binaries
//! (rust/benches/tab*.rs, fig3_layer_errors.rs).

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

use anyhow::Result;

use crate::calib::{collect_stats, Dataset, EngineKind};
use crate::coordinator::{quantize_model_with_stats, PipelineOptions, QuantReport};
use crate::manifest::Manifest;
use crate::model::{LayerStats, Model};
use crate::quant::grid::Scheme;
use crate::quant::{OrderKind, QuantConfig};

type StatsMap = BTreeMap<String, LayerStats>;

/// Everything a table bench needs, loaded once. Calibration statistics
/// are cached per (model, calib size) — the table sweeps reuse one
/// calibration pass across every method/bit configuration, exactly as a
/// real deployment pipeline would.
pub struct Suite {
    pub manifest: Manifest,
    pub dataset: Dataset,
    stats_cache: RefCell<HashMap<(String, usize), (Rc<StatsMap>, f64)>>,
}

impl Suite {
    /// Loads artifacts/ relative to the crate root; panics with a clear
    /// message when `make artifacts` has not been run (benches are not
    /// skip-silent — a bench with no data is a failure).
    pub fn load() -> Result<Suite> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        anyhow::ensure!(
            root.join("manifest.json").exists(),
            "artifacts missing — run `make artifacts` first"
        );
        let manifest = Manifest::load(&root)?;
        let dataset = Dataset::load(&manifest)?;
        Ok(Suite { manifest, dataset, stats_cache: RefCell::new(HashMap::new()) })
    }

    pub fn model(&self, name: &str) -> Result<Model> {
        Model::load(&self.manifest, name)
    }

    /// Calibration statistics for (model, size), computed once (PJRT).
    pub fn stats(&self, model: &Model, calib_size: usize) -> Result<(Rc<StatsMap>, f64)> {
        let key = (model.info.name.clone(), calib_size);
        if let Some(hit) = self.stats_cache.borrow().get(&key) {
            return Ok(hit.clone());
        }
        let t = crate::util::Timer::start();
        let imgs = self.dataset.calib_subset(calib_size);
        let stats = collect_stats(&self.manifest, model, &imgs, EngineKind::Pjrt)?;
        let entry = (Rc::new(stats), t.secs());
        self.stats_cache.borrow_mut().insert(key, entry.clone());
        Ok(entry)
    }

    /// One full pipeline run with the common knobs.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        model: &Model,
        method: &str,
        bits: u32,
        scheme: Scheme,
        order: OrderKind,
        lam: f32,
        calib_size: usize,
        act_bits: Option<u32>,
    ) -> Result<QuantReport> {
        let opts = PipelineOptions {
            method: method.into(),
            engine: EngineKind::Pjrt,
            calib_size,
            act_bits,
            qcfg: QuantConfig { bits, scheme, order, iters: 3, lam },
            ..Default::default()
        };
        let (stats, calib_secs) = self.stats(model, calib_size)?;
        let (_qm, report) = quantize_model_with_stats(
            &self.manifest,
            model,
            &self.dataset,
            &opts,
            &stats,
            calib_secs,
        )?;
        Ok(report)
    }

    /// Default λ used by the tables at each bit-width (Tab. 10: λ<1 at 2-bit).
    pub fn default_lam(bits: u32) -> f32 {
        if bits <= 2 {
            0.8
        } else {
            1.0
        }
    }
}
