//! Minimal property-based testing harness (the `proptest` crate is not in
//! the offline vendor set). Provides seeded generators and a `forall`
//! runner with failure-case reporting + naive shrinking of the size
//! parameter.
//!
//! Usage (see rust/tests/prop_quant.rs):
//! ```ignore
//! forall(100, 0xC0MQ, |g| {
//!     let m = g.usize_in(1, 64);
//!     let w = g.tensor(&[m, g.usize_in(1, 32)], 1.0);
//!     ... assert invariants ...
//! });
//! ```

use crate::tensor::Tensor;
use crate::util::Rng;

/// A seeded generator handed to every property case.
pub struct Gen {
    pub rng: Rng,
    /// Case index (0..cases); properties can use it to scale sizes.
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len())]
    }

    /// Random normal tensor scaled by `sigma`.
    pub fn tensor(&mut self, shape: &[usize], sigma: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, self.rng.normal_vec(n).into_iter().map(|v| v * sigma).collect())
    }

    /// Tensor with occasional large outliers (PTQ stress shape).
    pub fn tensor_with_outliers(&mut self, shape: &[usize], sigma: f32, p_out: f32) -> Tensor {
        let mut t = self.tensor(shape, sigma);
        for v in t.data_mut() {
            if self.rng.f32() < p_out {
                *v *= 10.0;
            }
        }
        t
    }

    /// Random shared-Gram calibration for an [m, n] layer: returns
    /// (W, GramSet::Shared) with `b` calibration rows. Occasionally
    /// (p=1/8) zeroes a feature column so the EPS_DIAG dead-feature path
    /// is exercised by default.
    pub fn shared_layer(&mut self, b: usize, m: usize, n: usize) -> (Tensor, crate::quant::GramSet) {
        let mut x = self.tensor(&[b, m], 1.0);
        if m > 1 && self.rng.below(8) == 0 {
            let dead = self.rng.below(m);
            for r in 0..b {
                x.data_mut()[r * m + dead] = 0.0;
            }
        }
        let w = self.tensor_with_outliers(&[m, n], 0.5, 0.05);
        (w, crate::quant::GramSet::from_features(&x))
    }

    /// Random grouped (depthwise) calibration: returns (W [k, c],
    /// GramSet::Grouped) from features [rows, c, k].
    pub fn grouped_layer(&mut self, rows: usize, c: usize, k: usize) -> (Tensor, crate::quant::GramSet) {
        let x3 = self.tensor(&[rows, c, k], 1.0);
        let w = self.tensor(&[k, c], 0.4);
        (w, crate::quant::GramSet::from_grouped_features(&x3))
    }
}

/// Run `prop` over `cases` seeded cases; panics with the failing case
/// index + seed so the case is replayable.
pub fn forall<F: Fn(&mut Gen)>(cases: usize, seed: u64, prop: F) {
    for case in 0..cases {
        let mut g = Gen { rng: Rng::new(seed.wrapping_add(case as u64 * 0x9e37)), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes() {
        forall(50, 1, |g| {
            let n = g.usize_in(1, 10);
            assert!(n >= 1 && n <= 10);
            let t = g.tensor(&[n, 2], 1.0);
            assert_eq!(t.len(), n * 2);
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn forall_reports_failure() {
        forall(10, 2, |g| {
            let n = g.usize_in(0, 9);
            assert!(n < 5, "n too big: {n}");
        });
    }

    #[test]
    fn deterministic_cases() {
        use std::sync::Mutex;
        let v1 = Mutex::new(Vec::new());
        let v2 = Mutex::new(Vec::new());
        forall(5, 3, |g| v1.lock().unwrap().push(g.usize_in(0, 1000)));
        forall(5, 3, |g| v2.lock().unwrap().push(g.usize_in(0, 1000)));
        // NB: closure side effects run in order; same seeds -> same values
        assert_eq!(*v1.lock().unwrap(), *v2.lock().unwrap());
    }

    #[test]
    fn layer_generators_shapes() {
        let mut g = Gen { rng: Rng::new(9), case: 0 };
        let (w, gram) = g.shared_layer(16, 6, 4);
        assert_eq!(w.shape(), &[6, 4]);
        assert_eq!(gram.m(), 6);
        let (wg, gg) = g.grouped_layer(12, 3, 5);
        assert_eq!(wg.shape(), &[5, 3]);
        assert_eq!(gg.m(), 5);
    }

    #[test]
    fn outlier_tensor_has_outliers() {
        let mut g = Gen { rng: Rng::new(7), case: 0 };
        let t = g.tensor_with_outliers(&[100, 10], 1.0, 0.1);
        let big = t.data().iter().filter(|v| v.abs() > 5.0).count();
        assert!(big > 10, "expected outliers, got {big}");
    }
}
