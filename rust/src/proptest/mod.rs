//! Minimal property-based testing harness (the `proptest` crate is not in
//! the offline vendor set). Provides seeded generators and a `forall`
//! runner with failure-case reporting + naive shrinking of the size
//! parameter.
//!
//! Usage (see rust/tests/prop_quant.rs):
//! ```ignore
//! forall(100, 0xC0MQ, |g| {
//!     let m = g.usize_in(1, 64);
//!     let w = g.tensor(&[m, g.usize_in(1, 32)], 1.0);
//!     ... assert invariants ...
//! });
//! ```

use crate::tensor::Tensor;
use crate::util::Rng;

/// Build a tiny self-contained "plain" CNN (see `model/cnn.rs`) plus a
/// matching in-memory manifest — for tests and benches that must run
/// without the AOT artifact set. `conv0` is deliberately sized so
/// `m·n = 189` is odd: for every bit width ≤ 8 its code count does not
/// pack to whole 32-bit words, exercising the bitstream tail path.
pub fn tiny_plain_cnn(seed: u64) -> (crate::manifest::Manifest, crate::model::Model) {
    use crate::manifest::{CnnConfig, LayerInfo, Manifest, ModelConfig, ModelInfo};
    use std::collections::BTreeMap;

    let (img, classes) = (8usize, 10usize);
    // (name, input features m, output channels n) along plain_forward
    let spec: &[(&str, usize, usize)] = &[
        ("conv0", 27, 7),
        ("conv1", 63, 8),
        ("conv2", 72, 16),
        ("conv3", 144, 16),
        ("conv4", 144, 16),
        ("fc", 16, 24),
        ("head", 24, classes),
    ];
    let mut rng = Rng::new(seed);
    let mut params = BTreeMap::new();
    let mut names = Vec::new();
    let mut quant_layers = Vec::new();
    for &(name, m, n) in spec {
        let sc = 1.5 / (m as f32).sqrt();
        params.insert(
            format!("{name}/W"),
            Tensor::new(&[m, n], rng.normal_vec(m * n).into_iter().map(|v| v * sc).collect()),
        );
        params.insert(
            format!("{name}/b"),
            Tensor::new(&[n], rng.normal_vec(n).into_iter().map(|v| v * 0.1).collect()),
        );
        names.push(format!("{name}/W"));
        names.push(format!("{name}/b"));
        quant_layers.push(LayerInfo { name: name.to_string(), m, n, grouped: false });
    }
    let info = ModelInfo {
        name: "tiny_plain".into(),
        config: ModelConfig::Cnn(CnnConfig {
            kind: "plain".into(),
            width: 7,
            blocks: 0,
            img,
            classes,
        }),
        params: names,
        quant_layers,
        checkpoint: String::new(),
        fp_top1: 0.0,
        artifacts: BTreeMap::new(),
    };
    let manifest = Manifest {
        root: std::path::PathBuf::from("."),
        batch: 16,
        classes,
        img,
        data: String::new(),
        models: BTreeMap::from([("tiny_plain".to_string(), info.clone())]),
        sweeps: Vec::new(),
    };
    (manifest, crate::model::Model { info, params })
}

/// Build a tiny self-contained "mobile" CNN (depthwise-separable blocks,
/// see `model/cnn.rs::mobile_forward`) plus a matching in-memory
/// manifest — the grouped-layer counterpart of [`tiny_plain_cnn`] for
/// tests and benches of the integer depthwise path. Channel counts are
/// chosen to cover both a partial (c=8 < NR) and a full (c=16) panel
/// strip, and the last depthwise block reduces to a 1×1 spatial output
/// (the oh·ow = 1 edge).
pub fn tiny_mobile_cnn(seed: u64) -> (crate::manifest::Manifest, crate::model::Model) {
    use crate::manifest::{CnnConfig, LayerInfo, Manifest, ModelConfig, ModelInfo};
    use std::collections::BTreeMap;

    let (img, classes, width) = (8usize, 10usize, 8usize);
    // (name, input features m, output channels n, grouped) along
    // mobile_forward: stem k3 s2 → 4×4, dsb0 (dw s1, pw 8→8),
    // dsb1 (dw s2 → 2×2, pw 8→16), dsb2 (dw s2 → 1×1, pw 16→32), head
    let spec: &[(&str, usize, usize, bool)] = &[
        ("stem", 27, width, false),
        ("dsb0/dw", 9, 8, true),
        ("dsb0/pw", 8, 8, false),
        ("dsb1/dw", 9, 8, true),
        ("dsb1/pw", 8, 16, false),
        ("dsb2/dw", 9, 16, true),
        ("dsb2/pw", 16, 32, false),
        ("head", 32, classes, false),
    ];
    let mut rng = Rng::new(seed);
    let mut params = BTreeMap::new();
    let mut names = Vec::new();
    let mut quant_layers = Vec::new();
    for &(name, m, n, grouped) in spec {
        let sc = 1.5 / (m as f32).sqrt();
        params.insert(
            format!("{name}/W"),
            Tensor::new(&[m, n], rng.normal_vec(m * n).into_iter().map(|v| v * sc).collect()),
        );
        params.insert(
            format!("{name}/b"),
            Tensor::new(&[n], rng.normal_vec(n).into_iter().map(|v| v * 0.1).collect()),
        );
        names.push(format!("{name}/W"));
        names.push(format!("{name}/b"));
        quant_layers.push(LayerInfo { name: name.to_string(), m, n, grouped });
    }
    let info = ModelInfo {
        name: "tiny_mobile".into(),
        config: ModelConfig::Cnn(CnnConfig {
            kind: "mobile".into(),
            width,
            blocks: 0,
            img,
            classes,
        }),
        params: names,
        quant_layers,
        checkpoint: String::new(),
        fp_top1: 0.0,
        artifacts: BTreeMap::new(),
    };
    let manifest = Manifest {
        root: std::path::PathBuf::from("."),
        batch: 16,
        classes,
        img,
        data: String::new(),
        models: BTreeMap::from([("tiny_mobile".to_string(), info.clone())]),
        sweeps: Vec::new(),
    };
    (manifest, crate::model::Model { info, params })
}

/// A seeded generator handed to every property case.
pub struct Gen {
    pub rng: Rng,
    /// Case index (0..cases); properties can use it to scale sizes.
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len())]
    }

    /// Random normal tensor scaled by `sigma`.
    pub fn tensor(&mut self, shape: &[usize], sigma: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, self.rng.normal_vec(n).into_iter().map(|v| v * sigma).collect())
    }

    /// Tensor with occasional large outliers (PTQ stress shape).
    pub fn tensor_with_outliers(&mut self, shape: &[usize], sigma: f32, p_out: f32) -> Tensor {
        let mut t = self.tensor(shape, sigma);
        for v in t.data_mut() {
            if self.rng.f32() < p_out {
                *v *= 10.0;
            }
        }
        t
    }

    /// Random shared-Gram calibration for an [m, n] layer: returns
    /// (W, GramSet::Shared) with `b` calibration rows. Occasionally
    /// (p=1/8) zeroes a feature column so the EPS_DIAG dead-feature path
    /// is exercised by default.
    pub fn shared_layer(&mut self, b: usize, m: usize, n: usize) -> (Tensor, crate::quant::GramSet) {
        let mut x = self.tensor(&[b, m], 1.0);
        if m > 1 && self.rng.below(8) == 0 {
            let dead = self.rng.below(m);
            for r in 0..b {
                x.data_mut()[r * m + dead] = 0.0;
            }
        }
        let w = self.tensor_with_outliers(&[m, n], 0.5, 0.05);
        (w, crate::quant::GramSet::from_features(&x))
    }

    /// Random grouped (depthwise) calibration: returns (W [k, c],
    /// GramSet::Grouped) from features [rows, c, k].
    pub fn grouped_layer(&mut self, rows: usize, c: usize, k: usize) -> (Tensor, crate::quant::GramSet) {
        let x3 = self.tensor(&[rows, c, k], 1.0);
        let w = self.tensor(&[k, c], 0.4);
        (w, crate::quant::GramSet::from_grouped_features(&x3))
    }
}

/// COMQ-quantize every layer of a (synthetic) model from real
/// calibration statistics — the shared fixture step behind the serve
/// parity tests and the `serve_latency` bench, kept in one place so the
/// two can't drift apart. Returns (packed layers, calibrated activation
/// grid, dequantized reference model).
#[allow(clippy::type_complexity)]
pub fn quantize_all_layers(
    manifest: &crate::manifest::Manifest,
    model: &crate::model::Model,
    bits: u32,
    act_bits: u32,
    calib: &Tensor,
) -> anyhow::Result<(
    Vec<crate::deploy::PackedLayer>,
    crate::deploy::PackedAct,
    crate::model::Model,
)> {
    use crate::deploy::{PackedAct, PackedLayer};
    use crate::quant::actq::ActQuant;
    use crate::quant::{comq_gram, QuantConfig};

    let stats = crate::model::collect_stats_native(model, calib, manifest.batch)?;
    let cfg = QuantConfig { bits, ..Default::default() };
    let mut qmodel = model.clone();
    let mut packed = Vec::new();
    let mut by_layer = std::collections::BTreeMap::new();
    for l in &model.info.quant_layers {
        let st = &stats[&l.name];
        let lq = comq_gram(&st.gram, model.weight(&l.name), &cfg);
        qmodel.set_weight(&l.name, lq.dequant());
        packed.push(PackedLayer::from_quant(&l.name, &lq, bits));
        by_layer.insert(l.name.clone(), ActQuant::from_range(st.min, st.max, act_bits, 0.95));
    }
    Ok((packed, PackedAct { bits: act_bits, by_layer }, qmodel))
}

/// Run `prop` over `cases` seeded cases; panics with the failing case
/// index + seed so the case is replayable.
pub fn forall<F: Fn(&mut Gen)>(cases: usize, seed: u64, prop: F) {
    for case in 0..cases {
        let mut g = Gen { rng: Rng::new(seed.wrapping_add(case as u64 * 0x9e37)), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes() {
        forall(50, 1, |g| {
            let n = g.usize_in(1, 10);
            assert!(n >= 1 && n <= 10);
            let t = g.tensor(&[n, 2], 1.0);
            assert_eq!(t.len(), n * 2);
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn forall_reports_failure() {
        forall(10, 2, |g| {
            let n = g.usize_in(0, 9);
            assert!(n < 5, "n too big: {n}");
        });
    }

    #[test]
    fn deterministic_cases() {
        use std::sync::Mutex;
        let v1 = Mutex::new(Vec::new());
        let v2 = Mutex::new(Vec::new());
        forall(5, 3, |g| v1.lock().unwrap().push(g.usize_in(0, 1000)));
        forall(5, 3, |g| v2.lock().unwrap().push(g.usize_in(0, 1000)));
        // NB: closure side effects run in order; same seeds -> same values
        assert_eq!(*v1.lock().unwrap(), *v2.lock().unwrap());
    }

    #[test]
    fn layer_generators_shapes() {
        let mut g = Gen { rng: Rng::new(9), case: 0 };
        let (w, gram) = g.shared_layer(16, 6, 4);
        assert_eq!(w.shape(), &[6, 4]);
        assert_eq!(gram.m(), 6);
        let (wg, gg) = g.grouped_layer(12, 3, 5);
        assert_eq!(wg.shape(), &[5, 3]);
        assert_eq!(gg.m(), 5);
    }

    #[test]
    fn tiny_plain_cnn_is_consistent() {
        let (manifest, model) = tiny_plain_cnn(1);
        let mut g = Gen { rng: Rng::new(2), case: 0 };
        let x = g.tensor(&[3, manifest.img, manifest.img, 3], 1.0);
        let y = model.forward(&x, &mut crate::model::Tap::None);
        assert_eq!(y.shape(), &[3, manifest.classes]);
        for l in &model.info.quant_layers {
            assert_eq!(model.weight(&l.name).shape(), &[l.m, l.n], "{}", l.name);
        }
        // the bitstream-edge guarantee the serve tests rely on
        let conv0 = &model.info.quant_layers[0];
        assert_eq!((conv0.m * conv0.n) % 2, 1, "conv0 must have an odd code count");
        assert!(manifest.model("tiny_plain").is_ok());
    }

    #[test]
    fn tiny_mobile_cnn_is_consistent() {
        let (manifest, model) = tiny_mobile_cnn(5);
        let mut g = Gen { rng: Rng::new(6), case: 0 };
        let x = g.tensor(&[2, manifest.img, manifest.img, 3], 1.0);
        let y = model.forward(&x, &mut crate::model::Tap::None);
        assert_eq!(y.shape(), &[2, manifest.classes]);
        for l in &model.info.quant_layers {
            assert_eq!(model.weight(&l.name).shape(), &[l.m, l.n], "{}", l.name);
        }
        let dw: Vec<_> = model.info.quant_layers.iter().filter(|l| l.grouped).collect();
        assert_eq!(dw.len(), 3, "three depthwise blocks");
        assert!(dw.iter().all(|l| l.m == 9), "3×3 depthwise patches");
        // the strip edges the grouped serve tests rely on: one partial
        // strip (c < NR) and one full strip (c == NR)
        assert!(dw.iter().any(|l| l.n < crate::tensor::NR));
        assert!(dw.iter().any(|l| l.n == crate::tensor::NR));
    }

    #[test]
    fn outlier_tensor_has_outliers() {
        let mut g = Gen { rng: Rng::new(7), case: 0 };
        let t = g.tensor_with_outliers(&[100, 10], 1.0, 0.1);
        let big = t.data().iter().filter(|v| v.abs() > 5.0).count();
        assert!(big > 10, "expected outliers, got {big}");
    }
}
