//! Deployment: packed quantized checkpoints (.cqm).
//!
//! After PTQ the coordinator holds a dequantized `Model` (f32 weights on
//! grid points — fine for simulated-quantization evaluation). For
//! deployment the codes themselves are the artifact: this module packs
//! each quantized layer to its b-bit offset-binary bitstream plus the
//! per-column (δ, z) vectors and every non-quantized parameter in f32,
//! all inside a single CTS container with a small JSON header entry:
//!
//! ```text
//! __meta__            i32[3]  = [version, bits, n_layers]
//! __model__           f32 utf8-bytes? -> stored in header json instead
//! q/<layer>/codes     i32[ceil(m*n*b/32)]  packed little-endian bits
//! q/<layer>/delta     f32[n]
//! q/<layer>/zero      f32[n]
//! fp/<name>           f32[...] every parameter not covered by a packed layer
//! __act__             i32[1]  activation bits (optional)
//! aq/<layer>          f32[2]  calibrated activation (scale, zero) (optional)
//! ```
//!
//! Loading reconstructs a `Model` byte-exactly equal (in W_q) to the one
//! that was saved — asserted by tests — so accuracy of a served packed
//! model is identical to the pipeline's report. The integer serving
//! runtime (`serve::QuantizedModel`) instead consumes the raw
//! [`read_packed`] view and never dequantizes; the optional `__act__` /
//! `aq/` entries carry the calibrated activation grid it needs for
//! static (calibration-exact) activation quantization. Readers that
//! don't know those entries skip them, so the format version is
//! unchanged.
//!
//! # Integrity and crash safety (container v2)
//!
//! `.cqm` is a CTS container, so it inherits the tensorstore v2
//! integrity footer (see `tensorstore` module doc for the byte layout):
//! per-entry CRC32s plus a whole-file CRC appended after the v1 body.
//! Saves go through `tensorstore::write_store`'s temp-file + fsync +
//! atomic-rename path, so a crash mid-save can never leave a
//! truncated-but-parseable checkpoint — the loader sees either the old
//! intact file or a typed integrity error. v1 files (python-written, or
//! pre-footer) still load but are flagged
//! [`tensorstore::Integrity::Unverified`], surfaced on
//! [`PackedCheckpoint::integrity`] and warned about at load time.
//! [`read_packed`] itself is hardened against arbitrary bytes: every
//! header index, shape field, codes length and (δ, z) length is
//! validated with a typed error naming the offending key — malformed
//! input never panics or over-allocates.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::manifest::Manifest;
use crate::model::Model;
use crate::quant::actq::ActQuant;
use crate::quant::grid::LayerQuant;
use crate::tensor::Tensor;
use crate::tensorstore::{self, Entry, Integrity, Store};

pub const VERSION: i32 = 1;

/// One packed layer ready for serialization.
pub struct PackedLayer {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub bits: u32,
    pub codes: Vec<u8>,
    pub delta: Vec<f32>,
    pub zero: Vec<f32>,
}

impl PackedLayer {
    pub fn from_quant(name: &str, lq: &LayerQuant, bits: u32) -> PackedLayer {
        PackedLayer {
            name: name.to_string(),
            m: lq.q.rows(),
            n: lq.q.cols(),
            bits,
            codes: lq.pack_codes(bits),
            delta: lq.delta.clone(),
            zero: lq.zero.clone(),
        }
    }

    /// Reconstruct the dequantized weight W_q [m, n].
    pub fn dequant(&self) -> Tensor {
        let q = LayerQuant::unpack_codes(&self.codes, self.bits, self.m, self.n, &self.zero);
        let lq = LayerQuant { q, delta: self.delta.clone(), zero: self.zero.clone() };
        lq.dequant()
    }

    /// Packed size in bytes (codes + scales + zero points).
    pub fn packed_bytes(&self) -> usize {
        self.codes.len() + 8 * self.n
    }
}

/// Calibrated activation grid stored alongside the weight codes so the
/// integer runtime can serve with calibration-exact activation scales.
#[derive(Debug, Clone)]
pub struct PackedAct {
    pub bits: u32,
    pub by_layer: BTreeMap<String, ActQuant>,
}

/// Raw view of a `.cqm` file: what is actually on disk, before any
/// dequantization. `load_packed` turns this into an f32 `Model`; the
/// serving runtime preps it to i8 panels directly.
pub struct PackedCheckpoint {
    /// Header bit-width (layers may override per-layer, e.g. mixed
    /// precision).
    pub bits: u32,
    pub layers: Vec<PackedLayer>,
    /// Every parameter stored in f32.
    pub fp: BTreeMap<String, Tensor>,
    pub act: Option<PackedAct>,
    /// Whether the container bytes were CRC-verified (v2 footer) or
    /// merely structurally parsed (v1 file).
    pub integrity: Integrity,
}

/// Save a quantized model: `layers` are the packed quantized layers; all
/// other parameters of `model` are stored in f32.
pub fn save_packed(
    path: &str,
    model: &Model,
    layers: &[PackedLayer],
    bits: u32,
) -> Result<()> {
    save_packed_with_act(path, model, layers, bits, None)
}

/// [`save_packed`] plus the calibrated activation grid (when the run
/// quantized activations too) so the checkpoint is servable with static
/// scales.
pub fn save_packed_with_act(
    path: &str,
    model: &Model,
    layers: &[PackedLayer],
    bits: u32,
    act: Option<&PackedAct>,
) -> Result<()> {
    let mut store = Store::new();
    if let Some(a) = act {
        store.insert(
            "__act__".into(),
            Entry::I32 { shape: vec![1], data: vec![a.bits as i32] },
        );
        for (name, aq) in &a.by_layer {
            store.insert(
                format!("aq/{name}"),
                Entry::F32(Tensor::from_vec(vec![aq.scale, aq.zero])),
            );
        }
    }
    store.insert(
        "__meta__".into(),
        Entry::I32 { shape: vec![3], data: vec![VERSION, bits as i32, layers.len() as i32] },
    );
    let covered: std::collections::BTreeSet<String> =
        layers.iter().map(|l| format!("{}/W", l.name)).collect();
    for l in layers {
        // pad the byte stream to a whole number of i32 words
        let mut words = vec![0i32; l.codes.len().div_ceil(4)];
        for (i, b) in l.codes.iter().enumerate() {
            words[i / 4] |= (*b as i32 & 0xff) << (8 * (i % 4));
        }
        store.insert(
            format!("q/{}/codes", l.name),
            Entry::I32 { shape: vec![words.len()], data: words },
        );
        store.insert(
            format!("q/{}/shape", l.name),
            Entry::I32 { shape: vec![3], data: vec![l.m as i32, l.n as i32, l.bits as i32] },
        );
        store.insert(format!("q/{}/delta", l.name), Entry::F32(Tensor::from_vec(l.delta.clone())));
        store.insert(format!("q/{}/zero", l.name), Entry::F32(Tensor::from_vec(l.zero.clone())));
    }
    for (name, t) in &model.params {
        if !covered.contains(name) {
            store.insert(format!("fp/{name}"), Entry::F32(t.clone()));
        }
    }
    tensorstore::write_store(path, &store)
}

/// Parse a `.cqm` file into its raw on-disk parts — codes stay packed,
/// nothing is dequantized, no manifest needed. The serving runtime preps
/// i8 panels straight from this; [`load_packed`] builds an f32 `Model`
/// on top of it.
pub fn read_packed(path: &str) -> Result<PackedCheckpoint> {
    let loaded =
        tensorstore::read_store_checked(path).with_context(|| format!("loading {path}"))?;
    if loaded.integrity == Integrity::Unverified {
        crate::log_warn!("{path}: v1 checkpoint without integrity footer — loading unverified");
    }
    let store = loaded.store;
    let meta = store
        .get("__meta__")
        .ok_or_else(|| anyhow!("{path}: missing __meta__"))?
        .ints()?;
    if meta.len() != 3 {
        bail!("{path}: __meta__ must be i32[3], found {} values", meta.len());
    }
    if meta[0] != VERSION {
        bail!("{path}: unsupported version {}", meta[0]);
    }
    if meta[1] <= 0 {
        bail!("{path}: __meta__ bits {} out of range", meta[1]);
    }
    let bits = meta[1] as u32;
    let n_layers = meta[2];
    let mut fp = BTreeMap::new();
    let mut layers = Vec::new();
    let mut act_raw: Vec<(String, f32, f32)> = Vec::new();
    for (key, entry) in &store {
        if let Some(name) = key.strip_prefix("fp/") {
            fp.insert(name.to_string(), entry.tensor()?.clone());
        } else if let Some(name) = key.strip_prefix("q/").and_then(|r| r.strip_suffix("/shape")) {
            let sh = entry.ints()?;
            if sh.len() != 3 {
                bail!("{path}: '{key}' must be i32[3] = [m, n, bits], found {} values", sh.len());
            }
            if sh[0] < 0 || sh[1] < 0 || !(1..=32).contains(&sh[2]) {
                bail!("{path}: '{key}' has invalid [m, n, bits] = {sh:?}");
            }
            let (m, n, lbits) = (sh[0] as usize, sh[1] as usize, sh[2] as u32);
            let get = |suffix: &str| {
                store
                    .get(&format!("q/{name}/{suffix}"))
                    .ok_or_else(|| anyhow!("{path}: layer '{name}' missing {suffix}"))
            };
            let code_bytes = m
                .checked_mul(n)
                .and_then(|mn| mn.checked_mul(lbits as usize))
                .map(|b| b.div_ceil(8))
                .ok_or_else(|| anyhow!("{path}: '{key}' shape overflows usize"))?;
            let words = get("codes")?.ints()?;
            if words.len() * 4 < code_bytes {
                bail!(
                    "{path}: 'q/{name}/codes' holds {} bytes but shape {m}x{n}x{lbits}b \
                     needs {code_bytes}",
                    words.len() * 4
                );
            }
            let mut bytes = Vec::with_capacity(words.len() * 4);
            for w in words {
                bytes.extend_from_slice(&(*w as u32).to_le_bytes());
            }
            bytes.truncate(code_bytes);
            let delta = get("delta")?.tensor()?.data().to_vec();
            let zero = get("zero")?.tensor()?.data().to_vec();
            if delta.len() != n {
                bail!("{path}: 'q/{name}/delta' has {} values, expected n={n}", delta.len());
            }
            if zero.len() != n {
                bail!("{path}: 'q/{name}/zero' has {} values, expected n={n}", zero.len());
            }
            layers.push(PackedLayer {
                name: name.to_string(),
                m,
                n,
                bits: lbits,
                codes: bytes,
                delta,
                zero,
            });
        } else if let Some(name) = key.strip_prefix("aq/") {
            let row = entry.tensor()?.data();
            if row.len() != 2 {
                bail!("{path}: malformed activation entry '{key}'");
            }
            act_raw.push((name.to_string(), row[0], row[1]));
        }
    }
    if layers.len() != n_layers as usize {
        bail!("{path}: __meta__ declares {n_layers} packed layers, found {}", layers.len());
    }
    let act = match store.get("__act__") {
        Some(e) => {
            let av = e.ints()?;
            let abits = match av.first() {
                Some(&b) if (1..=32).contains(&b) => b as u32,
                _ => bail!("{path}: '__act__' must hold one bit-width in 1..=32, found {av:?}"),
            };
            let by_layer = act_raw
                .into_iter()
                .map(|(name, scale, zero)| (name, ActQuant { scale, zero, bits: abits }))
                .collect();
            Some(PackedAct { bits: abits, by_layer })
        }
        None => None,
    };
    Ok(PackedCheckpoint { bits, layers, fp, act, integrity: loaded.integrity })
}

/// Load a packed checkpoint into a ready-to-run `Model` (manifest
/// supplies the architecture; the checkpoint supplies the weights).
pub fn load_packed(manifest: &Manifest, model_name: &str, path: &str) -> Result<Model> {
    let ck = read_packed(path)?;
    let info = manifest.model(model_name)?.clone();
    let mut params = ck.fp;
    let by_name: BTreeMap<&str, &PackedLayer> =
        ck.layers.iter().map(|l| (l.name.as_str(), l)).collect();
    for l in &info.quant_layers {
        // layers without codes were kept FP (skip-layers) — already under fp/
        if let Some(pl) = by_name.get(l.name.as_str()) {
            params.insert(format!("{}/W", l.name), pl.dequant());
        }
    }
    // validate completeness
    for p in &info.params {
        if !params.contains_key(p) {
            bail!("{path}: missing parameter '{p}' after unpacking");
        }
    }
    Ok(Model { info, params })
}

/// Total packed footprint of a layer set vs its f32 footprint.
pub fn footprint(layers: &[PackedLayer]) -> (usize, usize) {
    let packed = layers.iter().map(|l| l.packed_bytes()).sum();
    let fp32 = layers.iter().map(|l| 4 * l.m * l.n).sum();
    (packed, fp32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{comq_gram, GramSet, QuantConfig};
    use crate::tensor::matmul_at_a;
    use crate::util::Rng;

    #[test]
    fn packed_layer_roundtrip() {
        let mut rng = Rng::new(40);
        let x = Tensor::new(&[64, 20], rng.normal_vec(64 * 20));
        let w = Tensor::new(&[20, 12], rng.normal_vec(240));
        let gram = GramSet::Shared(matmul_at_a(&x));
        for bits in [2u32, 3, 4, 8] {
            let cfg = QuantConfig { bits, ..Default::default() };
            let lq = comq_gram(&gram, &w, &cfg);
            let pl = PackedLayer::from_quant("test", &lq, bits);
            let back = pl.dequant();
            assert_eq!(back, lq.dequant(), "bits={bits}");
            assert!(pl.packed_bytes() < 4 * 20 * 12, "bits={bits} not smaller than f32");
        }
    }

    #[test]
    fn footprint_math() {
        let pl = PackedLayer {
            name: "x".into(),
            m: 16,
            n: 8,
            bits: 4,
            codes: vec![0u8; 64],
            delta: vec![0.1; 8],
            zero: vec![0.0; 8],
        };
        let (packed, fp32) = footprint(&[pl]);
        assert_eq!(fp32, 512);
        assert_eq!(packed, 64 + 64);
    }
}
