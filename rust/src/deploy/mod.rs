//! Deployment: packed quantized checkpoints (.cqm).
//!
//! After PTQ the coordinator holds a dequantized `Model` (f32 weights on
//! grid points — fine for simulated-quantization evaluation). For
//! deployment the codes themselves are the artifact: this module packs
//! each quantized layer to its b-bit offset-binary bitstream plus the
//! per-column (δ, z) vectors and every non-quantized parameter in f32,
//! all inside a single CTS container with a small JSON header entry:
//!
//! ```text
//! __meta__            i32[3]  = [version, bits, n_layers]
//! __model__           f32 utf8-bytes? -> stored in header json instead
//! q/<layer>/codes     i32[ceil(m*n*b/32)]  packed little-endian bits
//! q/<layer>/delta     f32[n]
//! q/<layer>/zero      f32[n]
//! fp/<name>           f32[...] every parameter not covered by a packed layer
//! ```
//!
//! Loading reconstructs a `Model` byte-exactly equal (in W_q) to the one
//! that was saved — asserted by tests — so accuracy of a served packed
//! model is identical to the pipeline's report.

use anyhow::{anyhow, bail, Context, Result};

use crate::manifest::Manifest;
use crate::model::Model;
use crate::quant::grid::LayerQuant;
use crate::tensor::Tensor;
use crate::tensorstore::{self, Entry, Store};

pub const VERSION: i32 = 1;

/// One packed layer ready for serialization.
pub struct PackedLayer {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub bits: u32,
    pub codes: Vec<u8>,
    pub delta: Vec<f32>,
    pub zero: Vec<f32>,
}

impl PackedLayer {
    pub fn from_quant(name: &str, lq: &LayerQuant, bits: u32) -> PackedLayer {
        PackedLayer {
            name: name.to_string(),
            m: lq.q.rows(),
            n: lq.q.cols(),
            bits,
            codes: lq.pack_codes(bits),
            delta: lq.delta.clone(),
            zero: lq.zero.clone(),
        }
    }

    /// Reconstruct the dequantized weight W_q [m, n].
    pub fn dequant(&self) -> Tensor {
        let q = LayerQuant::unpack_codes(&self.codes, self.bits, self.m, self.n, &self.zero);
        let lq = LayerQuant { q, delta: self.delta.clone(), zero: self.zero.clone() };
        lq.dequant()
    }

    /// Packed size in bytes (codes + scales + zero points).
    pub fn packed_bytes(&self) -> usize {
        self.codes.len() + 8 * self.n
    }
}

/// Save a quantized model: `layers` are the packed quantized layers; all
/// other parameters of `model` are stored in f32.
pub fn save_packed(
    path: &str,
    model: &Model,
    layers: &[PackedLayer],
    bits: u32,
) -> Result<()> {
    let mut store = Store::new();
    store.insert(
        "__meta__".into(),
        Entry::I32 { shape: vec![3], data: vec![VERSION, bits as i32, layers.len() as i32] },
    );
    let covered: std::collections::BTreeSet<String> =
        layers.iter().map(|l| format!("{}/W", l.name)).collect();
    for l in layers {
        // pad the byte stream to a whole number of i32 words
        let mut words = vec![0i32; l.codes.len().div_ceil(4)];
        for (i, b) in l.codes.iter().enumerate() {
            words[i / 4] |= (*b as i32 & 0xff) << (8 * (i % 4));
        }
        store.insert(
            format!("q/{}/codes", l.name),
            Entry::I32 { shape: vec![words.len()], data: words },
        );
        store.insert(
            format!("q/{}/shape", l.name),
            Entry::I32 { shape: vec![3], data: vec![l.m as i32, l.n as i32, l.bits as i32] },
        );
        store.insert(format!("q/{}/delta", l.name), Entry::F32(Tensor::from_vec(l.delta.clone())));
        store.insert(format!("q/{}/zero", l.name), Entry::F32(Tensor::from_vec(l.zero.clone())));
    }
    for (name, t) in &model.params {
        if !covered.contains(name) {
            store.insert(format!("fp/{name}"), Entry::F32(t.clone()));
        }
    }
    tensorstore::write_store(path, &store)
}

/// Load a packed checkpoint into a ready-to-run `Model` (manifest
/// supplies the architecture; the checkpoint supplies the weights).
pub fn load_packed(manifest: &Manifest, model_name: &str, path: &str) -> Result<Model> {
    let store = tensorstore::read_store(path).with_context(|| format!("loading {path}"))?;
    let meta = store
        .get("__meta__")
        .ok_or_else(|| anyhow!("{path}: missing __meta__"))?
        .ints()?;
    if meta[0] != VERSION {
        bail!("{path}: unsupported version {}", meta[0]);
    }
    let info = manifest.model(model_name)?.clone();
    let mut params = std::collections::BTreeMap::new();
    for (key, entry) in &store {
        if let Some(name) = key.strip_prefix("fp/") {
            params.insert(name.to_string(), entry.tensor()?.clone());
        }
    }
    // unpack quantized layers
    for l in &info.quant_layers {
        let pre = format!("q/{}", l.name);
        let Some(shape) = store.get(&format!("{pre}/shape")) else {
            continue; // layer kept FP (skip-layers) — already under fp/
        };
        let sh = shape.ints()?;
        let (m, n, bits) = (sh[0] as usize, sh[1] as usize, sh[2] as u32);
        let words = store[&format!("{pre}/codes")].ints()?;
        let mut bytes = Vec::with_capacity(words.len() * 4);
        for w in words {
            bytes.extend_from_slice(&(*w as u32).to_le_bytes());
        }
        bytes.truncate((m * n * bits as usize).div_ceil(8));
        let delta = store[&format!("{pre}/delta")].tensor()?.data().to_vec();
        let zero = store[&format!("{pre}/zero")].tensor()?.data().to_vec();
        let pl = PackedLayer { name: l.name.clone(), m, n, bits, codes: bytes, delta, zero };
        params.insert(format!("{}/W", l.name), pl.dequant());
    }
    // validate completeness
    for p in &info.params {
        if !params.contains_key(p) {
            bail!("{path}: missing parameter '{p}' after unpacking");
        }
    }
    Ok(Model { info, params })
}

/// Total packed footprint of a layer set vs its f32 footprint.
pub fn footprint(layers: &[PackedLayer]) -> (usize, usize) {
    let packed = layers.iter().map(|l| l.packed_bytes()).sum();
    let fp32 = layers.iter().map(|l| 4 * l.m * l.n).sum();
    (packed, fp32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{comq_gram, GramSet, QuantConfig};
    use crate::tensor::matmul_at_a;
    use crate::util::Rng;

    #[test]
    fn packed_layer_roundtrip() {
        let mut rng = Rng::new(40);
        let x = Tensor::new(&[64, 20], rng.normal_vec(64 * 20));
        let w = Tensor::new(&[20, 12], rng.normal_vec(240));
        let gram = GramSet::Shared(matmul_at_a(&x));
        for bits in [2u32, 3, 4, 8] {
            let cfg = QuantConfig { bits, ..Default::default() };
            let lq = comq_gram(&gram, &w, &cfg);
            let pl = PackedLayer::from_quant("test", &lq, bits);
            let back = pl.dequant();
            assert_eq!(back, lq.dequant(), "bits={bits}");
            assert!(pl.packed_bytes() < 4 * 20 * 12, "bits={bits} not smaller than f32");
        }
    }

    #[test]
    fn footprint_math() {
        let pl = PackedLayer {
            name: "x".into(),
            m: 16,
            n: 8,
            bits: 4,
            codes: vec![0u8; 64],
            delta: vec![0.1; 8],
            zero: vec![0.0; 8],
        };
        let (packed, fp32) = footprint(&[pl]);
        assert_eq!(fp32, 512);
        assert_eq!(packed, 64 + 64);
    }
}
