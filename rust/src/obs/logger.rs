//! Leveled stderr logger behind the `COMQ_LOG` gate.
//!
//! Earlier PRs each grew their own warn path: warn-once `eprintln!`s in
//! `util::comq_threads` / `util::simd::Kernel::active`, an ad-hoc
//! `env_logger_lite` in the CLI, and a bare `eprintln!` on the batcher
//! panic path. They all route through here now, so one env var controls
//! verbosity everywhere:
//!
//! * `COMQ_LOG=quiet` — nothing, not even warnings;
//! * `COMQ_LOG=warn`  — misconfiguration warnings only;
//! * `COMQ_LOG=info`  — plus the CLI's progress lines (the default, which
//!   preserves the CLI's previous behavior);
//! * `COMQ_LOG=debug` — plus per-layer debug detail (`trace` accepted as
//!   an alias).
//!
//! Use via the crate-root macros: `crate::log_warn!` / `log_info!` /
//! `log_debug!`, and `crate::warn_once!` for the fire-exactly-once
//! misconfiguration warnings. Like `COMQ_OBS` the level is read from the
//! environment once and cached; [`set_level`] overrides it.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity, from `COMQ_LOG`. Ordered: a message is emitted when
/// its level is ≤ the configured level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    Quiet = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl LogLevel {
    pub fn name(&self) -> &'static str {
        match self {
            LogLevel::Quiet => "quiet",
            LogLevel::Warn => "warn",
            LogLevel::Info => "info",
            LogLevel::Debug => "debug",
        }
    }
}

/// Parsed `COMQ_LOG` policy: `Ok(None)` = unset/blank → default (info),
/// `Ok(Some(l))` = explicit level, `Err(raw)` = unknown value. Pure for
/// unit-testability (tests in this crate run concurrently, so they must
/// not flip the real environment).
fn parse_log_level(raw: Option<&str>) -> Result<Option<LogLevel>, String> {
    match raw.map(str::trim) {
        None | Some("") => Ok(None),
        Some("quiet") => Ok(Some(LogLevel::Quiet)),
        Some("warn") => Ok(Some(LogLevel::Warn)),
        Some("info") => Ok(Some(LogLevel::Info)),
        Some("debug") | Some("trace") => Ok(Some(LogLevel::Debug)),
        Some(other) => Err(other.to_string()),
    }
}

const LEVEL_UNINIT: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNINIT);

/// The configured log level (cached after the first read).
#[inline]
pub fn log_level() -> LogLevel {
    match LEVEL.load(Ordering::Relaxed) {
        0 => LogLevel::Quiet,
        1 => LogLevel::Warn,
        2 => LogLevel::Info,
        3 => LogLevel::Debug,
        _ => init_level(),
    }
}

/// Whether a message at level `l` would be emitted.
#[inline]
pub fn log_enabled(l: LogLevel) -> bool {
    l != LogLevel::Quiet && l <= log_level()
}

#[cold]
fn init_level() -> LogLevel {
    let lv = match parse_log_level(std::env::var("COMQ_LOG").ok().as_deref()) {
        Ok(v) => v.unwrap_or(LogLevel::Info),
        Err(bad) => {
            // Can't use warn_once! here (it would recurse into the
            // uninitialized gate); the default level emits warnings, so
            // a bare stamped line is fine for this one bootstrap case.
            LEVEL.store(LogLevel::Info as u8, Ordering::Relaxed);
            eprintln!("[warn] COMQ_LOG={bad}: expected quiet|warn|info|debug, using info");
            return LogLevel::Info;
        }
    };
    LEVEL.store(lv as u8, Ordering::Relaxed);
    lv
}

/// Override the log level (tests, embedders).
pub fn set_level(l: LogLevel) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Emit a pre-checked message. Called by the macros after the
/// `log_enabled` check so formatting cost is only paid when the line is
/// actually printed.
pub fn emit(l: LogLevel, args: std::fmt::Arguments<'_>) {
    eprintln!("[{}] {}", l.name(), args);
}

/// Warn about a misconfiguration (macro-visible shorthand).
#[macro_export]
macro_rules! log_warn {
    ($($a:tt)*) => {
        if $crate::obs::logger::log_enabled($crate::obs::logger::LogLevel::Warn) {
            $crate::obs::logger::emit($crate::obs::logger::LogLevel::Warn, format_args!($($a)*));
        }
    };
}

/// Progress line (default-visible, like the CLI's old `log::info!`).
#[macro_export]
macro_rules! log_info {
    ($($a:tt)*) => {
        if $crate::obs::logger::log_enabled($crate::obs::logger::LogLevel::Info) {
            $crate::obs::logger::emit($crate::obs::logger::LogLevel::Info, format_args!($($a)*));
        }
    };
}

/// Per-layer / per-item detail, off by default.
#[macro_export]
macro_rules! log_debug {
    ($($a:tt)*) => {
        if $crate::obs::logger::log_enabled($crate::obs::logger::LogLevel::Debug) {
            $crate::obs::logger::emit($crate::obs::logger::LogLevel::Debug, format_args!($($a)*));
        }
    };
}

/// Warn exactly once per call site for the lifetime of the process (the
/// contract the old scattered `static Once + eprintln!` sites had).
/// Note: if `COMQ_LOG=quiet` the single chance is consumed silently —
/// same as before, when there was no way to silence these at all.
#[macro_export]
macro_rules! warn_once {
    ($($a:tt)*) => {{
        static ONCE: std::sync::Once = std::sync::Once::new();
        ONCE.call_once(|| {
            $crate::log_warn!($($a)*);
        });
    }};
}

#[cfg(test)]
mod tests {
    use super::{parse_log_level, LogLevel};

    #[test]
    fn log_level_parsing_rules() {
        assert_eq!(parse_log_level(None), Ok(None));
        assert_eq!(parse_log_level(Some("")), Ok(None));
        assert_eq!(parse_log_level(Some("quiet")), Ok(Some(LogLevel::Quiet)));
        assert_eq!(parse_log_level(Some("warn")), Ok(Some(LogLevel::Warn)));
        assert_eq!(parse_log_level(Some(" info ")), Ok(Some(LogLevel::Info)));
        assert_eq!(parse_log_level(Some("debug")), Ok(Some(LogLevel::Debug)));
        // back-compat alias from the old env_logger_lite
        assert_eq!(parse_log_level(Some("trace")), Ok(Some(LogLevel::Debug)));
        assert_eq!(parse_log_level(Some("loud")), Err("loud".to_string()));
    }

    #[test]
    fn level_gating_is_ordered() {
        // Pure check on the ordering used by log_enabled; the cached
        // global is exercised by the integration test (tests/serve_obs.rs)
        // to avoid cross-test races on process-wide state.
        assert!(LogLevel::Warn <= LogLevel::Info);
        assert!(LogLevel::Debug > LogLevel::Info);
        assert_eq!(LogLevel::Quiet.name(), "quiet");
    }
}
