//! `recorder` — the serving tier's flight recorder (crash black box).
//!
//! A bounded ring of the last [`CAP`] notable control-plane events —
//! admissions, typed sheds, error frames, executor panics and respawns,
//! dropped connections, drains, model lifecycle (checkpoint loads,
//! hot-swaps, evictions) — each with a monotonic timestamp on the
//! trace epoch. When something goes wrong (executor panic, drain, a
//! `COMQ_FAULT`-injected failure) the ring is [`dump`]ed to the log so
//! the post-mortem shows *what led up to it*, not just final counter
//! values.
//!
//! Two representations on purpose:
//!
//! * the **ring** holds the last N events with detail strings — it
//!   overwrites, so it answers "what just happened";
//! * the **per-kind counts** are monotonic atomics that never reset on
//!   overwrite — they answer "how many, ever", and are what tests
//!   reconcile counter-for-counter against `NetStats` (every error
//!   frame the net tier counts must appear here as exactly one
//!   `Shed`/`Panic`/`ErrorFrame` note).
//!
//! Gated on the same `COMQ_TRACE` switch as [`super::trace`]: off means
//! every `note` is a branch-predicted no-op and the ring stays empty.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use super::trace;
use crate::{log_info, log_warn};

/// Ring capacity — the "last N events" a dump shows.
pub const CAP: usize = 256;

/// What kind of control-plane event a note records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecKind {
    /// Request admitted past admission control into the batcher.
    Admit = 0,
    /// Per-request error frame for a protocol/validation failure
    /// (bad payload, unknown model, bad kind...).
    ErrorFrame = 1,
    /// Typed shed: deadline exceeded, overloaded, shutting down.
    Shed = 2,
    /// Executor thread respawned after a panic.
    Respawn = 3,
    /// Executor panic answered by `ExecutorPanicked` error frames.
    Panic = 4,
    /// Connection dropped (fault-injected or accept-time).
    DropConn = 5,
    /// Server drain began.
    Drain = 6,
    /// Checkpoint decoded + prepped into the model registry.
    Load = 7,
    /// Hot-swap completed: a model flipped to a new epoch.
    Swap = 8,
    /// Registry entry evicted (budget pressure or superseded by swap).
    Evict = 9,
}

const KINDS: usize = 10;

impl RecKind {
    pub fn name(&self) -> &'static str {
        match self {
            RecKind::Admit => "admit",
            RecKind::ErrorFrame => "error_frame",
            RecKind::Shed => "shed",
            RecKind::Respawn => "respawn",
            RecKind::Panic => "panic",
            RecKind::DropConn => "drop_conn",
            RecKind::Drain => "drain",
            RecKind::Load => "load",
            RecKind::Swap => "swap",
            RecKind::Evict => "evict",
        }
    }
}

/// One recorded event: kind, detail, monotonic ns on the trace epoch.
#[derive(Debug, Clone)]
pub struct RecEvent {
    pub at_ns: u64,
    pub kind: RecKind,
    pub detail: String,
}

struct Recorder {
    ring: Mutex<VecDeque<RecEvent>>,
    counts: [AtomicU64; KINDS],
}

fn recorder() -> &'static Recorder {
    static R: OnceLock<Recorder> = OnceLock::new();
    R.get_or_init(|| Recorder {
        ring: Mutex::new(VecDeque::with_capacity(CAP)),
        counts: std::array::from_fn(|_| AtomicU64::new(0)),
    })
}

/// Record one event. No-op when `COMQ_TRACE` is off.
#[inline]
pub fn note(kind: RecKind, detail: &str) {
    if !trace::enabled() {
        return;
    }
    let r = recorder();
    r.counts[kind as usize].fetch_add(1, Ordering::Relaxed);
    let mut ring = r.ring.lock().unwrap();
    if ring.len() >= CAP {
        ring.pop_front();
    }
    ring.push_back(RecEvent { at_ns: trace::now_ns(), kind, detail: to_detail(detail) });
}

fn to_detail(d: &str) -> String {
    // cap pathological details so the ring's memory stays bounded
    if d.len() <= 128 {
        return d.to_string();
    }
    let mut cut = 127;
    while cut > 0 && !d.is_char_boundary(cut) {
        cut -= 1;
    }
    format!("{}…", &d[..cut])
}

/// Monotonic total of events of one kind (never reset by ring
/// overwrite — the reconciliation side of the recorder).
pub fn count(kind: RecKind) -> u64 {
    recorder().counts[kind as usize].load(Ordering::Relaxed)
}

/// Events currently held in the ring.
pub fn len() -> usize {
    recorder().ring.lock().unwrap().len()
}

/// The last `n` events, oldest first.
pub fn last(n: usize) -> Vec<RecEvent> {
    let ring = recorder().ring.lock().unwrap();
    ring.iter().skip(ring.len().saturating_sub(n)).cloned().collect()
}

/// Dump the ring to the log — the black-box readout. Called on
/// executor respawn and server drain; embedders may call it from their
/// own panic hooks. No-op when tracing is off or nothing was recorded.
pub fn dump(reason: &str) {
    if !trace::enabled() {
        return;
    }
    let events = last(CAP);
    if events.is_empty() {
        return;
    }
    let r = recorder();
    let totals: Vec<String> = ALL_KINDS
        .iter()
        .filter_map(|k| {
            let c = r.counts[*k as usize].load(Ordering::Relaxed);
            (c > 0).then(|| format!("{}={c}", k.name()))
        })
        .collect();
    log_warn!(
        "flight recorder dump ({reason}): last {} events, totals [{}]",
        events.len(),
        totals.join(" ")
    );
    for e in &events {
        log_info!("  +{:>12.3}ms {:<11} {}", e.at_ns as f64 / 1e6, e.kind.name(), e.detail);
    }
}

const ALL_KINDS: [RecKind; KINDS] = [
    RecKind::Admit,
    RecKind::ErrorFrame,
    RecKind::Shed,
    RecKind::Respawn,
    RecKind::Panic,
    RecKind::DropConn,
    RecKind::Drain,
    RecKind::Load,
    RecKind::Swap,
    RecKind::Evict,
];

/// Clear the ring and zero every count (tests).
pub fn reset() {
    let r = recorder();
    r.ring.lock().unwrap().clear();
    for c in &r.counts {
        c.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceMode;
    use std::sync::Mutex as StdMutex;

    /// Recorder state is process-global; serialize and reset.
    static LOCK: StdMutex<()> = StdMutex::new(());

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn off_mode_records_nothing() {
        let _g = guard();
        trace::set_mode(TraceMode::Off);
        reset();
        note(RecKind::Admit, "m");
        assert_eq!(len(), 0);
        assert_eq!(count(RecKind::Admit), 0);
    }

    #[test]
    fn counts_survive_ring_overwrite() {
        let _g = guard();
        trace::set_mode(TraceMode::All);
        reset();
        for i in 0..(CAP + 10) {
            note(RecKind::Shed, &format!("req {i}"));
        }
        assert_eq!(len(), CAP, "ring must cap at {CAP}");
        assert_eq!(count(RecKind::Shed), (CAP + 10) as u64, "counts must not reset");
        // the ring holds the *last* CAP events
        let tail = last(2);
        assert_eq!(tail[1].detail, format!("req {}", CAP + 9));
        assert!(tail[0].at_ns <= tail[1].at_ns, "timestamps must be monotonic");
        trace::set_mode(TraceMode::Off);
        reset();
    }

    #[test]
    fn last_n_and_detail_cap() {
        let _g = guard();
        trace::set_mode(TraceMode::All);
        reset();
        note(RecKind::Panic, &"x".repeat(500));
        note(RecKind::Respawn, "model-a");
        assert_eq!(len(), 2);
        let evs = last(10);
        assert_eq!(evs.len(), 2);
        assert!(evs[0].detail.len() <= 132, "detail must be capped");
        assert_eq!(evs[1].kind, RecKind::Respawn);
        dump("unit test"); // smoke: must not panic on a populated ring
        trace::set_mode(TraceMode::Off);
        reset();
    }
}
