//! Per-request serving spans.
//!
//! Each request through the micro-batcher is stamped at four stage
//! boundaries — submit → coalesce-start (queue wait), tensor assembly
//! (coalesce), model forward (exec), reply delivery (epilogue) — and
//! the durations aggregate into per-model per-stage histograms named
//! `comq_serve_stage_seconds{model=...,stage=...}` plus a `total`
//! histogram of submit→reply latency. Stages are recorded batch-wide
//! with [`SpanSet::record_n`] (every request in a batch shares the
//! coalesce/exec/epilogue durations), so per-stage sums stay coherent
//! with the per-request totals — the invariant the integration test
//! asserts.
//!
//! The [`items`] thread-local carries the current batch size from
//! `QuantizedModel::forward` down into the per-layer exec hooks, so
//! layer exec counters count *images*, not forward calls (a grouped
//! conv sees b·oh·ow rows per call — request count is not recoverable
//! from the tensor shape at that depth).

use std::cell::Cell;
use std::sync::Arc;
use std::time::Instant;

use super::hist::Histogram;
use super::metrics::{registry, with_labels};

/// A pipeline stage of one serving request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Submit → executor picks the request out of the queue.
    QueueWait,
    /// Queue drain → input tensor assembled.
    Coalesce,
    /// Model forward (all layers).
    Exec,
    /// Forward done → reply handed to the requester.
    Epilogue,
    /// Submit → reply (end-to-end, per request).
    Total,
}

/// All stages, in pipeline order.
pub const STAGES: [Stage; 5] =
    [Stage::QueueWait, Stage::Coalesce, Stage::Exec, Stage::Epilogue, Stage::Total];

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::Coalesce => "coalesce",
            Stage::Exec => "exec",
            Stage::Epilogue => "epilogue",
            Stage::Total => "total",
        }
    }

    fn idx(&self) -> usize {
        match self {
            Stage::QueueWait => 0,
            Stage::Coalesce => 1,
            Stage::Exec => 2,
            Stage::Epilogue => 3,
            Stage::Total => 4,
        }
    }
}

/// The per-stage histograms of one model's serving path.
#[derive(Clone)]
pub struct SpanSet {
    hists: [Arc<Histogram>; 5],
}

impl SpanSet {
    /// Build (or re-attach to) the per-stage histograms for `model`.
    pub fn for_model(model: &str) -> SpanSet {
        let mk = |stage: Stage| {
            registry().histogram(&with_labels(
                "comq_serve_stage_seconds",
                &[("model", model), ("stage", stage.name())],
            ))
        };
        SpanSet {
            hists: [
                mk(Stage::QueueWait),
                mk(Stage::Coalesce),
                mk(Stage::Exec),
                mk(Stage::Epilogue),
                mk(Stage::Total),
            ],
        }
    }

    /// Record one duration (nanoseconds) for `stage`.
    #[inline]
    pub fn record(&self, stage: Stage, nanos: u64) {
        self.hists[stage.idx()].record(nanos);
    }

    /// Record the same duration once per request in a batch of `n`.
    #[inline]
    pub fn record_n(&self, stage: Stage, nanos: u64, n: u64) {
        self.hists[stage.idx()].record_n(nanos, n);
    }

    /// The underlying histogram (snapshot access for tests/benches).
    pub fn hist(&self, stage: Stage) -> &Histogram {
        &self.hists[stage.idx()]
    }
}

/// Incremental span: mark successive stage boundaries, each `mark`
/// recording the time since the previous one.
pub struct Span {
    set: SpanSet,
    last: Instant,
    weight: u64,
}

impl Span {
    /// Start a span at an explicit instant (the batcher timestamps
    /// arrival while holding the queue lock, before the span exists).
    pub fn start_at(set: &SpanSet, at: Instant, weight: u64) -> Span {
        Span { set: set.clone(), last: at, weight }
    }

    /// Close the current stage: record now−last into `stage` (weighted
    /// by the batch size) and advance the boundary.
    pub fn mark(&mut self, stage: Stage) {
        let now = Instant::now();
        let ns = now.saturating_duration_since(self.last).as_nanos() as u64;
        self.set.record_n(stage, ns, self.weight);
        self.last = now;
    }
}

thread_local! {
    static ITEMS: Cell<u64> = const { Cell::new(1) };
}

/// Set the number of requests (images) in the batch the current thread
/// is executing; read back by per-layer exec hooks via [`items`].
pub fn set_items(n: u64) {
    ITEMS.with(|c| c.set(n.max(1)));
}

/// The current thread's in-flight batch size (1 outside a forward).
pub fn items() -> u64 {
    ITEMS.with(|c| c.get())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_and_order() {
        assert_eq!(STAGES.len(), 5);
        let names: Vec<_> = STAGES.iter().map(|s| s.name()).collect();
        assert_eq!(names, ["queue_wait", "coalesce", "exec", "epilogue", "total"]);
        for (i, s) in STAGES.iter().enumerate() {
            assert_eq!(s.idx(), i);
        }
    }

    #[test]
    fn span_marks_accumulate_per_stage() {
        crate::obs::set_level(crate::obs::ObsLevel::On);
        let set = SpanSet::for_model("span-unit-test");
        let mut span = Span::start_at(&set, Instant::now(), 3);
        span.mark(Stage::QueueWait);
        span.mark(Stage::Exec);
        assert_eq!(set.hist(Stage::QueueWait).count(), 3);
        assert_eq!(set.hist(Stage::Exec).count(), 3);
        assert_eq!(set.hist(Stage::Coalesce).count(), 0);
    }

    #[test]
    fn items_is_thread_local() {
        set_items(8);
        assert_eq!(items(), 8);
        std::thread::spawn(|| assert_eq!(items(), 1)).join().unwrap();
        set_items(0); // clamps to 1 — a zero weight would drop samples
        assert_eq!(items(), 1);
    }
}
