//! Log-linear fixed-bucket latency histogram (HDR-style).
//!
//! Values are non-negative integers — the serving spans record
//! **nanoseconds** as `u64`. The bucket layout is log-linear with
//! 2^[`SUB_BITS`] = 64 linear sub-buckets per power-of-two octave:
//!
//! * values `< 64` land in exact unit buckets (small counts like batch
//!   sizes are represented exactly);
//! * larger values keep their top 1+6 significant bits, so the relative
//!   bucket width is ≤ 1/64 ≈ 1.56 % and the midpoint estimate returned
//!   by snapshots is within ~0.8 % of the true value;
//! * values ≥ 2^[`MAX_EXP`] ns (≈ 73 min) saturate into the top bucket.
//!
//! `record` is lock-free (one relaxed `fetch_add` on the bucket plus
//! count/sum/min/max updates) and internally gated on `obs::enabled()`,
//! so call sites don't need their own guard. Quantiles are computed on
//! [`snapshot`](Histogram::snapshot) by rank-walking the buckets; tests
//! cross-check them against `util::stats::quantile` on the raw samples.

use std::sync::atomic::{AtomicU64, Ordering};

/// log2 of the number of linear sub-buckets per octave.
pub const SUB_BITS: u32 = 6;
const SUB: u64 = 1 << SUB_BITS; // 64
/// Values at or above 2^MAX_EXP saturate into the last bucket.
pub const MAX_EXP: u32 = 42;
/// 64 exact unit buckets + (MAX_EXP − SUB_BITS) octaves × 64 sub-buckets.
pub const N_BUCKETS: usize = SUB as usize + (MAX_EXP - SUB_BITS) as usize * SUB as usize;

/// Index of the bucket holding `v`.
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let v = v.min((1u64 << MAX_EXP) - 1);
    let msb = 63 - v.leading_zeros(); // ≥ SUB_BITS here
    let sub = (v >> (msb - SUB_BITS)) & (SUB - 1);
    SUB as usize + ((msb - SUB_BITS) as usize) * SUB as usize + sub as usize
}

/// Midpoint of the value range covered by bucket `idx` (the estimate
/// reported for every sample that landed there).
fn bucket_value(idx: usize) -> u64 {
    if idx < SUB as usize {
        return idx as u64;
    }
    let rel = idx - SUB as usize;
    let exp = SUB_BITS + (rel / SUB as usize) as u32; // msb of values in this octave
    let sub = (rel % SUB as usize) as u64;
    let width = 1u64 << (exp - SUB_BITS);
    ((SUB + sub) << (exp - SUB_BITS)) + width / 2
}

/// Lock-free log-linear histogram. Cheap to record into from any
/// thread; all aggregate reads go through [`snapshot`](Self::snapshot).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample. No-op when `COMQ_OBS=off`.
    #[inline]
    pub fn record(&self, v: u64) {
        self.record_n(v, 1);
    }

    /// Record `n` samples of the same value (the batcher records one
    /// coalesce/exec duration for every request in the batch).
    pub fn record_n(&self, v: u64, n: u64) {
        if n == 0 || !crate::obs::enabled() {
            return;
        }
        self.buckets[bucket_index(v)].fetch_add(n, Ordering::Relaxed);
        self.count.fetch_add(n, Ordering::Relaxed);
        self.sum.fetch_add(v.saturating_mul(n), Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Exact number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact sum of recorded sample values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Consistent read of the whole histogram. Not atomic across
    /// concurrent recorders, but each field is monotone so a snapshot
    /// taken after all recording threads have quiesced is exact.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let count = self.count.load(Ordering::Relaxed);
        let min = self.min.load(Ordering::Relaxed);
        HistogramSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: if count == 0 { 0 } else { min },
            max: self.max.load(Ordering::Relaxed),
            buckets: self
                .buckets
                .iter()
                .enumerate()
                .filter_map(|(i, b)| {
                    let c = b.load(Ordering::Relaxed);
                    (c > 0).then_some((i as u32, c))
                })
                .collect(),
        }
    }
}

/// Point-in-time copy of a [`Histogram`]: exact count/sum/min/max plus
/// the non-empty buckets, with quantile estimation.
#[derive(Debug, Clone)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    /// (bucket index, sample count), ascending, non-empty buckets only.
    pub buckets: Vec<(u32, u64)>,
}

impl HistogramSnapshot {
    /// Quantile estimate (nearest-rank over buckets, midpoint within a
    /// bucket, clamped to the exact observed [min, max]). 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for &(idx, c) in &self.buckets {
            seen += c;
            if seen >= rank {
                return bucket_value(idx as usize).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    /// Exact mean (sum/count), 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats;

    /// Recording is gated on the process-wide COMQ_OBS level; these
    /// unit tests exercise the recording path itself, so they force it
    /// on (telemetry is observation-only, so this cannot perturb any
    /// concurrently-running parity test). The off-path contract is
    /// asserted in tests/serve_obs.rs, a separate test binary.
    fn force_on() {
        crate::obs::set_level(crate::obs::ObsLevel::On);
    }

    #[test]
    fn small_values_are_exact() {
        force_on();
        let h = Histogram::new();
        for v in 0..64u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 64);
        assert_eq!(s.sum, (0..64).sum::<u64>());
        assert_eq!(s.min, 0);
        assert_eq!(s.max, 63);
        // every unit bucket holds exactly its own value
        for &(idx, c) in &s.buckets {
            assert_eq!(c, 1);
            assert_eq!(bucket_value(idx as usize), idx as u64);
        }
    }

    #[test]
    fn bucket_relative_error_bound() {
        // For every representable magnitude, the midpoint estimate is
        // within half a bucket width of the sample → ≤ 1/128 rel error.
        let mut v = 64u64;
        while v < (1 << MAX_EXP) {
            for probe in [v, v + v / 128, v + v / 65] {
                let est = bucket_value(bucket_index(probe));
                let err = (est as f64 - probe as f64).abs() / probe as f64;
                assert!(err <= 1.0 / 128.0 + 1e-12, "v={probe} est={est} err={err}");
            }
            v = v.saturating_mul(3) / 2 + 1;
        }
    }

    #[test]
    fn quantiles_match_stats_quantile() {
        // Cross-check against util::stats::quantile on the raw samples
        // (the tentpole's stated accuracy contract: ~2 % relative).
        force_on();
        let mut rng = Rng::new(0xC0310);
        let mut samples: Vec<u64> = Vec::new();
        let h = Histogram::new();
        for _ in 0..4000 {
            // log-uniform-ish spread over 1µs..10ms, like real latencies
            let e = 10.0 + 13.3 * rng.f32() as f64;
            let v = (2f64.powf(e)) as u64;
            samples.push(v);
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, samples.len() as u64);
        let raw: Vec<f64> = samples.iter().map(|&v| v as f64).collect();
        for q in [0.5, 0.95, 0.99, 0.999] {
            let exact = stats::quantile(&raw, q);
            let est = s.quantile(q) as f64;
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.04, "q={q}: est={est} exact={exact} rel={rel}");
        }
        // percentiles are monotone
        assert!(s.p50() <= s.p95() && s.p95() <= s.p99() && s.p99() <= s.p999());
        assert!(s.p999() <= s.max && s.min <= s.p50());
    }

    #[test]
    fn record_n_equals_repeated_record() {
        force_on();
        let a = Histogram::new();
        let b = Histogram::new();
        for v in [3u64, 700, 123_456, 1 << 30] {
            a.record_n(v, 5);
            for _ in 0..5 {
                b.record(v);
            }
        }
        let (sa, sb) = (a.snapshot(), b.snapshot());
        assert_eq!(sa.count, sb.count);
        assert_eq!(sa.sum, sb.sum);
        assert_eq!(sa.buckets, sb.buckets);
    }

    #[test]
    fn empty_and_saturation() {
        force_on();
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!((s.count, s.sum, s.min, s.max), (0, 0, 0, 0));
        assert_eq!(s.quantile(0.5), 0);
        assert_eq!(s.mean(), 0.0);
        // huge values saturate into the top bucket instead of panicking
        h.record(u64::MAX);
        h.record(1 << 50);
        let s = h.snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.buckets.len(), 1);
        assert_eq!(s.buckets[0].0 as usize, N_BUCKETS - 1);
    }
}
