//! `trace` — end-to-end request tracing for the serving tier.
//!
//! Where `span` aggregates stage latencies into histograms (what is the
//! p99 of exec?), this module follows *one* request from the wire
//! through admission, the coalesce window, the per-layer int8 GEMMs and
//! back out (why was request 0x4f2a slow?). Each traced request leaves
//! a set of [`Event`]s — `request`, `admission`, `queue_wait`,
//! `coalesce`, `exec`, per-layer `layer:<name>` children with
//! `{layer, kind, kernel, batch}` attributes, `epilogue`, `write_back`
//! — cut from the **same `Instant`s** the `Stage` span marks use, so a
//! trace's stages telescope exactly to the histogram totals.
//!
//! ## The `COMQ_TRACE` gate
//!
//! `COMQ_TRACE=off|sample:<p>|all` (default `off`). Like `COMQ_OBS` the
//! value is read once and cached; recording sites check [`enabled`] — a
//! relaxed atomic load and compare — so `off` keeps every event append
//! a branch-predicted no-op, the buffers empty, and the bit-identity
//! contracts untouched (tracing is observation-only; nothing it records
//! feeds back into logits). Tests and embedders flip it with
//! [`set_mode`].
//!
//! Under `sample:<p>` **every** request is traced into the ring buffers
//! (events are cheap; whether a request turns out interesting is only
//! known at the end), and *retention* decides at completion which
//! traces survive for export:
//!
//! * every errored / shed / deadline-missed trace is kept,
//! * the slowest K per window of [`WINDOW`] completions are kept
//!   (K defaults to 8, see [`set_slow_k`]) — tail-based retention: a
//!   faster trace that was provisionally in the window's top-K is
//!   un-retained when a slower one bumps it, so the window converges to
//!   exactly its K slowest,
//! * of the rest, a deterministic `p`-fraction is kept (a hash of the
//!   trace id against `p` — no RNG, so a given id's fate is
//!   reproducible).
//!
//! `all` retains every completed trace. Either way the retained set is
//! capped at [`RETAIN_CAP`] traces (oldest evicted) and events for
//! unretained traces simply age out of the rings.
//!
//! ## Ring buffers
//!
//! Events land in per-thread rings: [`SHARDS`] fixed-capacity deques,
//! each thread pinned to one shard by the same round-robin id the
//! metric counters use. A shard's lock is therefore private to its
//! writer thread in steady state — the hot path never contends with
//! other request threads, only with the rare export/dump reader.
//!
//! ## Export
//!
//! [`export_chrome`] serializes the retained traces as Chrome
//! trace-event JSON (`chrome://tracing` / Perfetto): one synthetic
//! thread lane per trace, `"X"` complete events with µs timestamps on a
//! shared process-uptime timebase. The `TraceDump` wire frame and the
//! `comq trace <addr>` CLI subcommand fetch it remotely.

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Tracing policy, from `COMQ_TRACE`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceMode {
    /// Recording is a branch-predicted no-op; every buffer stays empty.
    Off,
    /// Trace every request; retain errors, the slowest K per window,
    /// and a deterministic `p`-fraction of the rest.
    Sample(f32),
    /// Trace every request and retain every completed trace (capped).
    All,
}

impl TraceMode {
    pub fn name(&self) -> String {
        match self {
            TraceMode::Off => "off".into(),
            TraceMode::Sample(p) => format!("sample:{p}"),
            TraceMode::All => "all".into(),
        }
    }
}

/// Parsed `COMQ_TRACE` policy: `Ok(None)` = unset/blank → default
/// (off), `Ok(Some(m))` = explicit mode, `Err(raw)` = unknown value —
/// the caller warns once and stays off. Pure so the rules are
/// unit-testable without touching the process environment.
pub fn parse_mode(raw: Option<&str>) -> Result<Option<TraceMode>, String> {
    match raw.map(str::trim) {
        None | Some("") => Ok(None),
        Some("off") => Ok(Some(TraceMode::Off)),
        Some("all") => Ok(Some(TraceMode::All)),
        Some(other) => match other.strip_prefix("sample:") {
            Some(p) => match p.trim().parse::<f32>() {
                Ok(p) if (0.0..=1.0).contains(&p) => Ok(Some(TraceMode::Sample(p))),
                _ => Err(other.to_string()),
            },
            None => Err(other.to_string()),
        },
    }
}

const MODE_OFF: u8 = 0;
const MODE_ALL: u8 = 1;
const MODE_SAMPLE: u8 = 2;
const MODE_UNINIT: u8 = u8::MAX;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNINIT);
static SAMPLE_BITS: AtomicU32 = AtomicU32::new(0);
static SLOW_K: AtomicUsize = AtomicUsize::new(DEFAULT_SLOW_K);

/// Default slowest-per-window retention count.
pub const DEFAULT_SLOW_K: usize = 8;
/// Completions per tail-retention window.
pub const WINDOW: u64 = 256;
/// Cap on retained traces (oldest evicted beyond this).
pub const RETAIN_CAP: usize = 256;
/// Per-shard event-ring capacity.
pub const RING_CAP: usize = 4096;
/// Number of per-thread event rings (matches `metrics::SHARDS`).
pub const SHARDS: usize = 16;

/// The current tracing mode (cached after the first read).
#[inline]
pub fn mode() -> TraceMode {
    match MODE.load(Ordering::Relaxed) {
        MODE_OFF => TraceMode::Off,
        MODE_ALL => TraceMode::All,
        MODE_SAMPLE => TraceMode::Sample(f32::from_bits(SAMPLE_BITS.load(Ordering::Relaxed))),
        _ => init_mode(),
    }
}

/// Whether tracing is on at all — the check every recording site makes
/// first.
#[inline]
pub fn enabled() -> bool {
    mode() != TraceMode::Off
}

#[cold]
fn init_mode() -> TraceMode {
    let m = match parse_mode(std::env::var("COMQ_TRACE").ok().as_deref()) {
        Ok(v) => v.unwrap_or(TraceMode::Off),
        Err(bad) => {
            crate::warn_once!("COMQ_TRACE={bad}: expected off|sample:<p>|all, tracing stays off");
            TraceMode::Off
        }
    };
    store_mode(m);
    m
}

fn store_mode(m: TraceMode) {
    // pin the shared timebase before any event can be recorded, so
    // every Instant a request carries is at or after the epoch
    let _ = epoch();
    match m {
        TraceMode::Off => MODE.store(MODE_OFF, Ordering::Relaxed),
        TraceMode::All => MODE.store(MODE_ALL, Ordering::Relaxed),
        TraceMode::Sample(p) => {
            SAMPLE_BITS.store(p.to_bits(), Ordering::Relaxed);
            MODE.store(MODE_SAMPLE, Ordering::Relaxed);
        }
    }
}

/// Override the tracing mode (tests, embedders).
pub fn set_mode(m: TraceMode) {
    store_mode(m);
}

/// Override the slowest-per-window retention count K (tests tune this
/// to assert exact retention).
pub fn set_slow_k(k: usize) {
    SLOW_K.store(k.max(1), Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// trace context + timebase
// ---------------------------------------------------------------------------

/// Bit set in [`TraceCtx::flags`] when the client asked for the trace
/// to be kept regardless of sampling (reserved; retention honors errors
/// and tails first).
pub const FLAG_SAMPLED: u8 = 1;

/// The context that travels with one traced request: the 64-bit trace
/// id (client-minted on the wire, or server-minted for old clients) and
/// a flags byte. 9 bytes on the wire (version-2 frames).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    pub id: u64,
    pub flags: u8,
}

/// High bit marks ids the server minted for clients that sent none
/// (version-1 frames) — keeps the two id spaces disjoint.
pub const SERVER_MINTED: u64 = 1 << 63;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Mint a server-side trace id (for requests that carried none).
pub fn mint_server() -> TraceCtx {
    TraceCtx { id: NEXT_ID.fetch_add(1, Ordering::Relaxed) | SERVER_MINTED, flags: 0 }
}

/// Mint a client-side trace id: pid in the high half, a process counter
/// in the low — unique across the client processes of one test run
/// without any RNG.
pub fn mint_client() -> TraceCtx {
    let id = ((std::process::id() as u64) << 32 | NEXT_ID.fetch_add(1, Ordering::Relaxed))
        & !SERVER_MINTED;
    TraceCtx { id, flags: FLAG_SAMPLED }
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The shared monotonic timebase every event timestamp is relative to.
/// Pinned when the gate first initializes (before any request exists).
fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the trace epoch.
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Map an `Instant` onto the shared timebase.
pub fn ns_of(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_nanos() as u64
}

// ---------------------------------------------------------------------------
// event rings
// ---------------------------------------------------------------------------

/// One recorded span of one traced request.
#[derive(Debug, Clone)]
pub struct Event {
    /// The trace this event belongs to.
    pub trace: u64,
    /// Span name (`request`, `admission`, `queue_wait`, `coalesce`,
    /// `exec`, `layer:<name>`, `epilogue`, `write_back`,
    /// `shed:<reason>`, `error:<reason>`, `exec_panic`).
    pub name: String,
    /// Start, ns since the trace epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Small attribute set rendered into the Chrome event's `args`.
    pub attrs: Vec<(&'static str, String)>,
}

struct Shard {
    ring: Mutex<VecDeque<Event>>,
}

fn shards() -> &'static [Shard; SHARDS] {
    static S: OnceLock<[Shard; SHARDS]> = OnceLock::new();
    S.get_or_init(|| std::array::from_fn(|_| Shard { ring: Mutex::new(VecDeque::new()) }))
}

/// Stable per-thread shard id — same trick as the metric counters: each
/// thread writes one ring, so its lock is uncontended in steady state.
fn shard_id() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: usize = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
    }
    SHARD.with(|s| *s)
}

fn push(ev: Event) {
    let shard = &shards()[shard_id()];
    let mut ring = shard.ring.lock().unwrap();
    if ring.len() >= RING_CAP {
        ring.pop_front();
    }
    ring.push_back(ev);
}

/// Record one span cut from two `Instant`s. No-op when tracing is off.
#[inline]
pub fn event(trace: u64, name: impl Into<String>, start: Instant, end: Instant) {
    if !enabled() {
        return;
    }
    event_ns(trace, name, ns_of(start), end.saturating_duration_since(start).as_nanos() as u64);
}

/// Record one span from raw epoch-relative nanoseconds.
#[inline]
pub fn event_ns(trace: u64, name: impl Into<String>, start_ns: u64, dur_ns: u64) {
    if !enabled() {
        return;
    }
    push(Event { trace, name: name.into(), start_ns, dur_ns, attrs: Vec::new() });
}

/// Record one span with attributes.
#[inline]
pub fn event_attrs(
    trace: u64,
    name: impl Into<String>,
    start: Instant,
    dur: Duration,
    attrs: Vec<(&'static str, String)>,
) {
    if !enabled() {
        return;
    }
    push(Event {
        trace,
        name: name.into(),
        start_ns: ns_of(start),
        dur_ns: dur.as_nanos() as u64,
        attrs,
    });
}

// ---------------------------------------------------------------------------
// per-batch thread-local: carries traced ids into the per-layer hooks
// ---------------------------------------------------------------------------

thread_local! {
    /// Trace ids of the batch the current thread is executing — set by
    /// the batcher around the model forward, read by the per-layer exec
    /// hooks (the layer has no other route back to its requests).
    static BATCH: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

/// Declare the traced ids of the batch about to run on this thread.
pub fn set_batch(ids: &[u64]) {
    BATCH.with(|b| {
        let mut b = b.borrow_mut();
        b.clear();
        b.extend_from_slice(ids);
    });
}

/// Clear the per-thread batch trace set (after the forward).
pub fn clear_batch() {
    BATCH.with(|b| b.borrow_mut().clear());
}

/// Whether the current thread is executing a traced batch.
#[inline]
pub fn batch_active() -> bool {
    enabled() && BATCH.with(|b| !b.borrow().is_empty())
}

/// Record one per-layer exec span for every traced request in the
/// current batch, with the `{layer, kind, kernel, batch}` attributes.
/// The event is duplicated per traced id so each request's lane shows
/// its own layer breakdown (the work itself ran once, batch-wide).
pub fn layer_event(layer: &str, kind: &'static str, batch: u64, start: Instant, dur: Duration) {
    if !batch_active() {
        return;
    }
    let kernel = crate::util::simd::Kernel::active().name();
    BATCH.with(|b| {
        for &id in b.borrow().iter() {
            event_attrs(
                id,
                format!("layer:{layer}"),
                start,
                dur,
                vec![
                    ("layer", layer.to_string()),
                    ("kind", kind.to_string()),
                    ("kernel", kernel.to_string()),
                    ("batch", batch.to_string()),
                ],
            );
        }
    });
}

// ---------------------------------------------------------------------------
// tail-based retention
// ---------------------------------------------------------------------------

/// Why a trace was retained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Why {
    /// Errored / shed / deadline-missed — always kept.
    Error,
    /// Among the slowest K of its window.
    Slow,
    /// Won the deterministic `sample:<p>` draw.
    Sampled,
    /// `COMQ_TRACE=all` keeps everything.
    All,
}

/// Completion record of one retained trace.
#[derive(Debug, Clone, Copy)]
pub struct TraceMeta {
    pub total_ns: u64,
    /// `"ok"` or the error/shed reason name.
    pub outcome: &'static str,
    pub why: Why,
    /// Completion order (export sorts lanes by it).
    pub seq: u64,
}

#[derive(Default)]
struct Retention {
    meta: BTreeMap<u64, TraceMeta>,
    /// Retention order, for cap eviction.
    order: VecDeque<u64>,
    /// Current window's slowest-K candidates: (total_ns, id).
    slow: Vec<(u64, u64)>,
    completions: u64,
}

fn retention() -> &'static Mutex<Retention> {
    static R: OnceLock<Mutex<Retention>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Retention::default()))
}

/// splitmix64 — the deterministic per-id sampling draw.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

fn sample_keep(id: u64, p: f32) -> bool {
    // top 53 bits → a uniform fraction in [0, 1); strict < makes p=0
    // keep nothing and p=1 keep everything
    ((mix(id) >> 11) as f64 / (1u64 << 53) as f64) < p as f64
}

/// Mark a traced request complete and decide whether its trace is
/// retained for export. `outcome` is `"ok"` or the error/shed reason
/// name (anything non-ok is always retained).
pub fn finish(trace: u64, total_ns: u64, outcome: &'static str) {
    let m = mode();
    if m == TraceMode::Off {
        return;
    }
    let mut r = retention().lock().unwrap();
    r.completions += 1;
    let seq = r.completions;
    let why = if m == TraceMode::All {
        Some(Why::All)
    } else if outcome != "ok" {
        Some(Why::Error)
    } else {
        let k = SLOW_K.load(Ordering::Relaxed);
        if r.slow.len() < k {
            r.slow.push((total_ns, trace));
            Some(Why::Slow)
        } else {
            // bump the window's provisional minimum if this one is
            // slower; the bumped trace leaves the retained set (unless
            // something else retained it), so the window converges to
            // exactly its K slowest
            let (imin, &(tmin, idmin)) = r
                .slow
                .iter()
                .enumerate()
                .min_by_key(|(_, (t, _))| *t)
                .expect("non-empty slow window");
            if total_ns > tmin {
                r.slow[imin] = (total_ns, trace);
                if r.meta.get(&idmin).is_some_and(|m| m.why == Why::Slow) {
                    r.meta.remove(&idmin);
                    r.order.retain(|&id| id != idmin);
                }
                Some(Why::Slow)
            } else {
                match m {
                    TraceMode::Sample(p) if sample_keep(trace, p) => Some(Why::Sampled),
                    _ => None,
                }
            }
        }
    };
    if let Some(why) = why {
        if r.meta.insert(trace, TraceMeta { total_ns, outcome, why, seq }).is_none() {
            r.order.push_back(trace);
        }
        while r.meta.len() > RETAIN_CAP {
            if let Some(old) = r.order.pop_front() {
                r.meta.remove(&old);
            } else {
                break;
            }
        }
    }
    // rotate the tail window after WINDOW completions
    if r.completions % WINDOW == 0 {
        r.slow.clear();
    }
}

/// The retained traces, oldest-completion first.
pub fn retained() -> Vec<(u64, TraceMeta)> {
    let r = retention().lock().unwrap();
    let mut v: Vec<(u64, TraceMeta)> = r.meta.iter().map(|(id, m)| (*id, *m)).collect();
    v.sort_by_key(|(_, m)| m.seq);
    v
}

/// Total events currently buffered across all rings (tests assert the
/// off-mode emptiness contract with this).
pub fn events_buffered() -> usize {
    shards().iter().map(|s| s.ring.lock().unwrap().len()).sum()
}

/// Events of one trace, start-sorted (tests).
pub fn events_of(trace: u64) -> Vec<Event> {
    let mut v: Vec<Event> = shards()
        .iter()
        .flat_map(|s| s.ring.lock().unwrap().iter().filter(|e| e.trace == trace).cloned().collect::<Vec<_>>())
        .collect();
    v.sort_by_key(|e| e.start_ns);
    v
}

/// Drop every buffered event and the whole retained set (tests; also
/// useful for embedders starting a fresh capture window).
pub fn reset() {
    for s in shards().iter() {
        s.ring.lock().unwrap().clear();
    }
    let mut r = retention().lock().unwrap();
    r.meta.clear();
    r.order.clear();
    r.slow.clear();
    r.completions = 0;
}

// ---------------------------------------------------------------------------
// Chrome trace-event export
// ---------------------------------------------------------------------------

/// Serialize the retained traces as Chrome trace-event JSON (open in
/// `chrome://tracing` or Perfetto). One synthetic thread lane per
/// trace, `"X"` complete events, µs timestamps on the shared
/// process-uptime timebase. Non-destructive — the buffers keep
/// accumulating.
pub fn export_chrome() -> String {
    let kept = retained();
    let lane: BTreeMap<u64, usize> =
        kept.iter().enumerate().map(|(i, (id, _))| (*id, i + 1)).collect();
    let mut events: Vec<Json> = Vec::new();
    for (i, (id, meta)) in kept.iter().enumerate() {
        let tid = (i + 1) as f64;
        let label = format!(
            "req {:#018x} ({}, {:.1} µs)",
            id,
            meta.outcome,
            meta.total_ns as f64 / 1e3
        );
        events.push(Json::obj_from(vec![
            ("ph", Json::Str("M".into())),
            ("name", Json::Str("thread_name".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid)),
            ("args", Json::obj_from(vec![("name", Json::Str(label))])),
        ]));
    }
    // one pass over the rings, then group by retained trace
    let mut all: Vec<Event> = Vec::new();
    for s in shards().iter() {
        let ring = s.ring.lock().unwrap();
        all.extend(ring.iter().filter(|e| lane.contains_key(&e.trace)).cloned());
    }
    all.sort_by_key(|e| (e.trace, e.start_ns));
    for e in &all {
        let mut args: Vec<(&str, Json)> = vec![(
            "trace_id",
            Json::Str(format!("{:#018x}", e.trace)),
        )];
        for (k, v) in &e.attrs {
            args.push((k, Json::Str(v.clone())));
        }
        events.push(Json::obj_from(vec![
            ("ph", Json::Str("X".into())),
            ("name", Json::Str(e.name.clone())),
            ("ts", Json::Num(e.start_ns as f64 / 1e3)),
            ("dur", Json::Num(e.dur_ns as f64 / 1e3)),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(lane[&e.trace] as f64)),
            ("args", Json::obj_from(args)),
        ]));
    }
    Json::obj_from(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
    .to_string_pretty(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Retention state is process-global; these tests serialize on one
    /// lock and reset around themselves.
    static LOCK: Mutex<()> = Mutex::new(());

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn mode_parsing_rules() {
        assert_eq!(parse_mode(None), Ok(None));
        assert_eq!(parse_mode(Some("")), Ok(None));
        assert_eq!(parse_mode(Some("off")), Ok(Some(TraceMode::Off)));
        assert_eq!(parse_mode(Some("all")), Ok(Some(TraceMode::All)));
        assert_eq!(parse_mode(Some(" sample:0.25 ")), Ok(Some(TraceMode::Sample(0.25))));
        assert_eq!(parse_mode(Some("sample:1")), Ok(Some(TraceMode::Sample(1.0))));
        assert!(parse_mode(Some("sample:2")).is_err());
        assert!(parse_mode(Some("sample:")).is_err());
        assert!(parse_mode(Some("on")).is_err());
    }

    #[test]
    fn sampling_draw_is_deterministic_and_bounded() {
        for id in [1u64, 42, 0xDEAD_BEEF, u64::MAX] {
            assert!(!sample_keep(id, 0.0), "p=0 must keep nothing");
            assert!(sample_keep(id, 1.0), "p=1 must keep everything");
            assert_eq!(sample_keep(id, 0.5), sample_keep(id, 0.5), "draw must be stable");
        }
        // the draw is roughly fair (splitmix64 over 4k ids)
        let kept = (0..4096u64).filter(|&i| sample_keep(mix(i), 0.5)).count();
        assert!((1500..2600).contains(&kept), "p=0.5 kept {kept}/4096");
    }

    #[test]
    fn minted_id_spaces_are_disjoint() {
        let s = mint_server();
        let c = mint_client();
        assert_ne!(s.id & SERVER_MINTED, 0);
        assert_eq!(c.id & SERVER_MINTED, 0);
        assert_ne!(mint_server().id, s.id);
    }

    #[test]
    fn off_mode_records_and_retains_nothing() {
        let _g = guard();
        set_mode(TraceMode::Off);
        reset();
        event_ns(7, "request", 0, 100);
        finish(7, 100, "ok");
        assert_eq!(events_buffered(), 0);
        assert!(retained().is_empty());
    }

    #[test]
    fn tail_retention_converges_to_slowest_k() {
        let _g = guard();
        set_mode(TraceMode::Sample(0.0));
        set_slow_k(3);
        reset();
        // 20 fast completions interleaved with 3 slow ones; the window
        // must converge to exactly the slow three, un-retaining the
        // provisional fast entries that filled it first
        for i in 0..10u64 {
            finish(100 + i, 1_000 + i, "ok");
        }
        for s in 0..3u64 {
            finish(900 + s, 40_000_000 + s, "ok");
        }
        for i in 10..20u64 {
            finish(100 + i, 1_000 + i, "ok");
        }
        let kept = retained();
        let ids: Vec<u64> = kept.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids.len(), 3, "exactly the K slowest must survive: {ids:?}");
        for s in 0..3u64 {
            assert!(ids.contains(&(900 + s)), "slow trace {} must be retained", 900 + s);
        }
        set_slow_k(DEFAULT_SLOW_K);
        reset();
    }

    #[test]
    fn errors_always_retained_and_all_keeps_everything() {
        let _g = guard();
        set_mode(TraceMode::Sample(0.0));
        set_slow_k(1);
        reset();
        finish(1, 50_000, "ok"); // window seed
        finish(2, 10, "overload"); // error: kept despite being fast
        finish(3, 10, "ok"); // fast, p=0: dropped
        let kept: Vec<u64> = retained().iter().map(|(id, _)| *id).collect();
        assert!(kept.contains(&2), "errored trace must be retained");
        assert!(!kept.contains(&3));
        set_mode(TraceMode::All);
        reset();
        finish(10, 5, "ok");
        finish(11, 5, "ok");
        assert_eq!(retained().len(), 2, "all-mode must retain every completion");
        set_slow_k(DEFAULT_SLOW_K);
        set_mode(TraceMode::Off);
        reset();
    }

    #[test]
    fn export_is_valid_chrome_trace_json() {
        let _g = guard();
        set_mode(TraceMode::All);
        reset();
        event_ns(42, "request", 1_000, 9_000);
        event_ns(42, "exec", 3_000, 4_000);
        finish(42, 9_000, "ok");
        let json = export_chrome();
        let parsed = Json::parse(&json).expect("export must parse");
        let evs = parsed.get("traceEvents").unwrap().arr().unwrap();
        // one metadata lane event + two spans
        assert_eq!(evs.len(), 3, "{json}");
        let x: Vec<&Json> =
            evs.iter().filter(|e| e.get("ph").unwrap().str().unwrap() == "X").collect();
        assert_eq!(x.len(), 2);
        assert_eq!(x[0].get("ts").unwrap().num().unwrap(), 1.0); // µs
        assert_eq!(x[1].get("dur").unwrap().num().unwrap(), 4.0);
        set_mode(TraceMode::Off);
        reset();
    }

    #[test]
    fn batch_thread_local_scopes_layer_events() {
        let _g = guard();
        set_mode(TraceMode::All);
        reset();
        assert!(!batch_active());
        set_batch(&[5, 6]);
        assert!(batch_active());
        let t = Instant::now();
        layer_event("conv1", "dense", 2, t, Duration::from_micros(10));
        clear_batch();
        assert!(!batch_active());
        // one event per traced id, each carrying the attribute set
        assert_eq!(events_of(5).len(), 1);
        assert_eq!(events_of(6).len(), 1);
        let ev = &events_of(5)[0];
        assert_eq!(ev.name, "layer:conv1");
        assert!(ev.attrs.iter().any(|(k, v)| *k == "kind" && v == "dense"));
        assert!(ev.attrs.iter().any(|(k, v)| *k == "batch" && v == "2"));
        set_mode(TraceMode::Off);
        reset();
    }
}
