//! Quantizer-side telemetry: per-layer sweep statistics.
//!
//! The coordinate-descent sweep (`quant::workspace`) runs deep inside a
//! worker-pool job with no channel back to the coordinator other than
//! its return value — which is pinned by bit-identity tests and cannot
//! grow fields. So the sweep stashes its telemetry in a thread-local
//! and the coordinator (`coordinator::pipeline`), which runs the
//! quantizer on the *same* thread, takes it immediately after the call.
//! The stash is observation-only: nothing in it feeds back into codes
//! or scales.
//!
//! Wall time per layer additionally lands in the registry histogram
//! `comq_quant_layer_seconds` (with `comq_quant_layers_total`), so a
//! long quantization run can be watched over the same Prometheus/JSON
//! export as serving.

use std::cell::RefCell;

use super::metrics::registry;

/// Telemetry from one layer's coordinate-descent sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepTelemetry {
    /// Reconstruction-error trajectory: ‖X(W_q − W)‖² after each full
    /// pass over the coordinates. Only populated under `COMQ_OBS=trace`
    /// (costs one extra Gram product per layer); empty at `on`.
    pub passes: Vec<f64>,
    /// Total coordinate updates performed (passes × rows × columns).
    pub updates: u64,
    /// Whether the greedy order collapsed to a single shared
    /// permutation (uniform) or used a per-column order table.
    pub order_uniform: bool,
}

thread_local! {
    static STASH: RefCell<Option<SweepTelemetry>> = const { RefCell::new(None) };
}

/// Stash this thread's sweep telemetry (called by the sweep engine;
/// no-op when telemetry is off).
pub fn put_sweep(t: SweepTelemetry) {
    if crate::obs::enabled() {
        STASH.with(|s| *s.borrow_mut() = Some(t));
    }
}

/// Take (and clear) this thread's stashed sweep telemetry.
pub fn take_sweep() -> Option<SweepTelemetry> {
    STASH.with(|s| s.borrow_mut().take())
}

/// Record one quantized layer's wall time into the registry.
pub fn record_layer(secs: f64) {
    if !crate::obs::enabled() {
        return;
    }
    registry()
        .histogram("comq_quant_layer_seconds")
        .record((secs * 1e9) as u64);
    registry().counter("comq_quant_layers_total").inc();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stash_roundtrip_and_clear() {
        crate::obs::set_level(crate::obs::ObsLevel::On);
        assert_eq!(take_sweep(), None);
        let t = SweepTelemetry { passes: vec![4.0, 1.0, 0.5], updates: 300, order_uniform: true };
        put_sweep(t.clone());
        assert_eq!(take_sweep(), Some(t));
        // take clears — a second take sees nothing (stale-stash guard)
        assert_eq!(take_sweep(), None);
    }

    #[test]
    fn stash_is_thread_local() {
        crate::obs::set_level(crate::obs::ObsLevel::On);
        put_sweep(SweepTelemetry { passes: vec![], updates: 1, order_uniform: false });
        std::thread::spawn(|| assert_eq!(take_sweep(), None)).join().unwrap();
        assert!(take_sweep().is_some());
    }
}
