//! `obs` — in-tree, dependency-free observability.
//!
//! Everything the runtime measures about itself funnels through here:
//!
//! * [`logger`]  — the leveled logger behind the `COMQ_LOG` gate and the
//!   crate-root `log_warn!` / `log_info!` / `log_debug!` / `warn_once!`
//!   macros (the one place warnings are formatted; the scattered
//!   warn-once `eprintln!`s of earlier PRs route through it now);
//! * [`hist`]    — log-linear fixed-bucket latency histograms
//!   (HDR-style: lock-free atomic record, ≤ ~1.6 % relative bucket
//!   error, exact count/sum/min/max, p50/p95/p99/p999 on snapshot);
//! * [`metrics`] — the process-wide [`MetricsRegistry`] of named
//!   counters (sharded, cache-line-padded), gauges and histograms, with
//!   Prometheus text and JSON (`util::json`) export;
//! * [`span`]    — the per-request serving span: submit → queue-wait →
//!   batch-coalesce → exec → epilogue, aggregated into per-model
//!   per-stage histograms;
//! * [`quant`]   — quantizer-side sweep telemetry (per-pass
//!   reconstruction-error trajectory, order stats, coordinate-update
//!   counts), stashed by the sweep engine and surfaced through
//!   `coordinator::report`;
//! * [`trace`]   — end-to-end request tracing behind the separate
//!   `COMQ_TRACE=off|sample:<p>|all` gate: per-request span trees cut
//!   from the same instants as the `span` stage marks, tail-based
//!   retention (errors + slowest-K + deterministic sample), Chrome
//!   trace-event export;
//! * [`recorder`] — the flight recorder: a bounded ring of the last N
//!   control-plane events (admissions, sheds, panics, respawns, drops,
//!   drains) dumped to the log on executor respawn or drain, with
//!   monotonic per-kind totals for counter reconciliation.
//!
//! ## The `COMQ_OBS` gate
//!
//! `COMQ_OBS=off|on|trace` (default `on`). Recording sites check
//! [`enabled`] — a single relaxed atomic load and compare, so `off`
//! turns every counter bump and histogram record into a
//! branch-predicted no-op and the kernel-parity bit-identity contracts
//! are untouched (telemetry is observation-only everywhere; nothing it
//! computes feeds back into codes, scales or logits). `trace`
//! additionally enables the per-pass reconstruction-error trajectory in
//! the sweep engine, which costs one extra Gram product per layer.
//!
//! Unlike `COMQ_KERNEL`/`COMQ_THREADS`, the level is read from the
//! environment **once** and cached — recording sites are too hot for an
//! env lookup. Embedders and tests flip it with [`set_level`].
//!
//! Granularity rule: counters and histograms live at request/layer
//! granularity only — never inside kernel inner loops (`micro_i8`,
//! `dot_i8`, the sweep coordinate loop).

pub mod hist;
pub mod logger;
pub mod metrics;
pub mod quant;
pub mod recorder;
pub mod span;
pub mod trace;

pub use hist::{Histogram, HistogramSnapshot};
pub use logger::LogLevel;
pub use metrics::{registry, Counter, Gauge, MetricsRegistry, Snapshot};
pub use span::{Span, SpanSet, Stage};
pub use trace::{TraceCtx, TraceMode};

use std::sync::atomic::{AtomicU8, Ordering};

/// Telemetry level, from `COMQ_OBS`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ObsLevel {
    /// Recording is a branch-predicted no-op; the registry stays empty.
    Off = 0,
    /// Counters, gauges, histograms and spans (the default).
    On = 1,
    /// `On` plus the expensive extras (per-pass error trajectories).
    Trace = 2,
}

impl ObsLevel {
    pub fn name(&self) -> &'static str {
        match self {
            ObsLevel::Off => "off",
            ObsLevel::On => "on",
            ObsLevel::Trace => "trace",
        }
    }
}

/// Parsed `COMQ_OBS` policy: `Ok(None)` = unset/blank → default,
/// `Ok(Some(l))` = explicit level, `Err(raw)` = unknown value — the
/// caller warns once and stays on the default. Pure so the rules are
/// unit-testable without touching the process environment.
fn parse_level(raw: Option<&str>) -> Result<Option<ObsLevel>, String> {
    match raw.map(str::trim) {
        None | Some("") => Ok(None),
        Some("off") => Ok(Some(ObsLevel::Off)),
        Some("on") => Ok(Some(ObsLevel::On)),
        Some("trace") => Ok(Some(ObsLevel::Trace)),
        Some(other) => Err(other.to_string()),
    }
}

const LEVEL_UNINIT: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNINIT);

/// The current telemetry level (cached after the first read).
#[inline]
pub fn level() -> ObsLevel {
    match LEVEL.load(Ordering::Relaxed) {
        0 => ObsLevel::Off,
        1 => ObsLevel::On,
        2 => ObsLevel::Trace,
        _ => init_level(),
    }
}

/// Whether recording is on at all — the hot-path check every counter
/// bump and histogram record makes first.
#[inline]
pub fn enabled() -> bool {
    level() != ObsLevel::Off
}

/// Whether the expensive extras are on.
#[inline]
pub fn tracing() -> bool {
    level() == ObsLevel::Trace
}

#[cold]
fn init_level() -> ObsLevel {
    let lv = match parse_level(std::env::var("COMQ_OBS").ok().as_deref()) {
        Ok(v) => v.unwrap_or(ObsLevel::On),
        Err(bad) => {
            crate::warn_once!("COMQ_OBS={bad}: expected off|on|trace, telemetry stays on");
            ObsLevel::On
        }
    };
    LEVEL.store(lv as u8, Ordering::Relaxed);
    lv
}

/// Override the telemetry level (tests, embedders). Metrics created
/// while the level was `Off` stay detached from the registry — flip the
/// level before building servers/models whose telemetry should export.
pub fn set_level(l: ObsLevel) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::{parse_level, ObsLevel};

    #[test]
    fn level_parsing_rules() {
        assert_eq!(parse_level(None), Ok(None));
        assert_eq!(parse_level(Some("")), Ok(None));
        assert_eq!(parse_level(Some("  ")), Ok(None));
        assert_eq!(parse_level(Some("off")), Ok(Some(ObsLevel::Off)));
        assert_eq!(parse_level(Some("on")), Ok(Some(ObsLevel::On)));
        assert_eq!(parse_level(Some(" trace ")), Ok(Some(ObsLevel::Trace)));
        assert_eq!(parse_level(Some("verbose")), Err("verbose".to_string()));
    }

    #[test]
    fn level_ordering() {
        assert!(ObsLevel::Off < ObsLevel::On);
        assert!(ObsLevel::On < ObsLevel::Trace);
        assert_eq!(ObsLevel::Trace.name(), "trace");
    }
}
