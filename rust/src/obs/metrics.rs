//! Process-wide metrics registry: sharded counters, gauges, histograms,
//! and the Prometheus / JSON exporters.
//!
//! ## Hot-path cost model
//!
//! [`Counter`] spreads increments across [`SHARDS`] cache-line-padded
//! atomic cells indexed by a per-thread shard id, so the pool's worker
//! threads and the batcher executors never contend on one line; reads
//! sum the shards. [`Gauge`] is the same with signed cells (queue depth
//! goes down as well as up). Both gate on `obs::enabled()` internally —
//! under `COMQ_OBS=off` every bump is a relaxed load, a compare, and a
//! predicted-not-taken branch.
//!
//! ## Naming
//!
//! Metric names follow Prometheus conventions: `comq_` prefix,
//! `_total` suffix on counters, `_seconds` suffix on duration
//! histograms. Histograms record **nanoseconds**; the exporters divide
//! by 1e9 exactly when the base name ends in `_seconds`, so unitless
//! histograms (batch size) pass through raw. Labels are embedded in the
//! name with [`with_labels`] — the registry key *is* the full exposition
//! string, so two call sites asking for the same name+labels share one
//! underlying metric (that is how per-request spans aggregate).
//!
//! ## `off` means empty
//!
//! When telemetry is off at creation time, [`MetricsRegistry::counter`]
//! & co. hand back a *detached* instance that is never registered:
//! recording into it is already a no-op, and the exported snapshot
//! stays empty — the acceptance contract for `COMQ_OBS=off`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::hist::{Histogram, HistogramSnapshot};
use crate::util::json::Json;
use crate::util::simd::Kernel;

/// Number of per-thread shards in counters/gauges. 16 covers the pool's
/// worker cap (`effective_threads()` ≤ 16) plus the batcher executors
/// with only benign collisions beyond that.
pub const SHARDS: usize = 16;

#[repr(align(64))]
struct PadU64(AtomicU64);

#[repr(align(64))]
struct PadI64(AtomicI64);

/// Stable per-thread shard id in [0, SHARDS).
#[inline]
fn shard_id() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: usize = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
    }
    SHARD.with(|s| *s)
}

/// Monotone counter, sharded per thread.
pub struct Counter {
    shards: [PadU64; SHARDS],
}

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

impl Counter {
    pub fn new() -> Self {
        // const-item trick: arrays of non-Copy values need a const initializer
        const Z: PadU64 = PadU64(AtomicU64::new(0));
        Counter { shards: [Z; SHARDS] }
    }

    /// Add `n`. No-op when `COMQ_OBS=off`.
    #[inline]
    pub fn add(&self, n: u64) {
        if crate::obs::enabled() {
            self.shards[shard_id()].0.fetch_add(n, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Sum across shards.
    pub fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// Signed gauge (queue depth, worker count, resident bytes), sharded
/// per thread for the inc/dec paths.
pub struct Gauge {
    shards: [PadI64; SHARDS],
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

impl Gauge {
    pub fn new() -> Self {
        const Z: PadI64 = PadI64(AtomicI64::new(0));
        Gauge { shards: [Z; SHARDS] }
    }

    /// Add `n` (may be negative). No-op when `COMQ_OBS=off`.
    #[inline]
    pub fn add(&self, n: i64) {
        if crate::obs::enabled() {
            self.shards[shard_id()].0.fetch_add(n, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn dec(&self) {
        self.add(-1);
    }

    /// Overwrite the value. Not linearizable against concurrent
    /// `add`s — use for set-once/quiescent values (resident bytes,
    /// worker count), not for anything inc/dec'd concurrently.
    pub fn set(&self, v: i64) {
        if !crate::obs::enabled() {
            return;
        }
        for s in &self.shards[1..] {
            s.0.store(0, Ordering::Relaxed);
        }
        self.shards[0].0.store(v, Ordering::Relaxed);
    }

    /// Sum across shards.
    pub fn get(&self) -> i64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// Build a full exposition name: `name{k1="v1",k2="v2"}`. Values are
/// escaped per the Prometheus text format (`\` and `"`).
pub fn with_labels(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut out = String::with_capacity(name.len() + 16 * labels.len());
    out.push_str(name);
    out.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        for c in v.chars() {
            match c {
                '\\' => out.push_str("\\\\"),
                '"' => out.push_str("\\\""),
                '\n' => out.push_str("\\n"),
                _ => out.push(c),
            }
        }
        out.push('"');
    }
    out.push('}');
    out
}

/// Process-wide registry of named metrics. One global instance behind
/// [`registry`]; separate instances exist only in tests.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create the counter `name`. Detached (never exported) when
    /// telemetry is off at call time.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        if !crate::obs::enabled() {
            return Arc::new(Counter::new());
        }
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_insert_with(|| Arc::new(Counter::new())).clone()
    }

    /// Get-or-create the gauge `name`; detached when telemetry is off.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        if !crate::obs::enabled() {
            return Arc::new(Gauge::new());
        }
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string()).or_insert_with(|| Arc::new(Gauge::new())).clone()
    }

    /// Get-or-create the histogram `name`; detached when telemetry is off.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        if !crate::obs::enabled() {
            return Arc::new(Histogram::new());
        }
        let mut m = self.hists.lock().unwrap();
        m.entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new())).clone()
    }

    /// Point-in-time copy of every registered metric.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            counters: self
                .counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: self
                .gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            hists: self
                .hists
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }

    /// Prometheus text exposition of the current snapshot.
    pub fn to_prometheus(&self) -> String {
        self.snapshot().to_prometheus()
    }

    /// JSON exposition of the current snapshot.
    pub fn to_json(&self) -> Json {
        self.snapshot().to_json()
    }

    /// Drop every registered metric (test isolation). Live `Arc`s held
    /// by servers/models keep recording but stop exporting.
    pub fn reset(&self) {
        self.counters.lock().unwrap().clear();
        self.gauges.lock().unwrap().clear();
        self.hists.lock().unwrap().clear();
    }
}

/// The process-wide registry.
pub fn registry() -> &'static MetricsRegistry {
    static REG: OnceLock<MetricsRegistry> = OnceLock::new();
    REG.get_or_init(MetricsRegistry::new)
}

/// Per-kernel-tier GEMM dispatch counters
/// (`comq_serve_gemm_calls_total{kernel=...}`), cached so the serving
/// GEMM entry points pay one array index per call instead of a registry
/// lock. Caller gates on `obs::enabled()`.
pub fn kernel_counter(k: Kernel) -> &'static Counter {
    static KC: OnceLock<[Arc<Counter>; 3]> = OnceLock::new();
    let all = KC.get_or_init(|| {
        let mk = |tag: &str| {
            registry().counter(&with_labels("comq_serve_gemm_calls_total", &[("kernel", tag)]))
        };
        [mk("scalar"), mk("avx2"), mk("vnni")]
    });
    match k {
        Kernel::Scalar => &all[0],
        Kernel::Avx2 => &all[1],
        Kernel::Vnni => &all[2],
    }
}

/// Point-in-time copy of the whole registry, with both exporters.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub hists: BTreeMap<String, HistogramSnapshot>,
}

/// Whether a full exposition name's *base* (before any `{labels}`)
/// carries the `_seconds` unit suffix — those histograms recorded
/// nanoseconds and export scaled by 1e-9.
fn is_seconds(name: &str) -> bool {
    name.split('{').next().unwrap_or(name).ends_with("_seconds")
}

/// Split `name{labels}` into (`name`, `Some("labels")` without braces).
fn split_labels(name: &str) -> (&str, Option<&str>) {
    match name.find('{') {
        Some(i) => (&name[..i], Some(name[i + 1..].trim_end_matches('}'))),
        None => (name, None),
    }
}

/// Append `suffix` and/or an extra label to a full exposition name:
/// `decorate("h{a="b"}", "_sum", None)` → `h_sum{a="b"}`.
fn decorate(name: &str, suffix: &str, extra_label: Option<&str>) -> String {
    let (base, labels) = split_labels(name);
    let mut out = String::with_capacity(name.len() + suffix.len() + 24);
    out.push_str(base);
    out.push_str(suffix);
    let combined = match (labels, extra_label) {
        (Some(l), Some(e)) => Some(format!("{l},{e}")),
        (Some(l), None) => Some(l.to_string()),
        (None, Some(e)) => Some(e.to_string()),
        (None, None) => None,
    };
    if let Some(c) = combined {
        out.push('{');
        out.push_str(&c);
        out.push('}');
    }
    out
}

impl Snapshot {
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    /// Prometheus text format: counters and gauges as plain samples,
    /// histograms as summaries (`quantile` label + `_sum`/`_count`).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, h) in &self.hists {
            let scale = if is_seconds(name) { 1e-9 } else { 1.0 };
            for (q, label) in
                [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99"), (0.999, "0.999")]
            {
                let line = decorate(name, "", Some(&format!("quantile=\"{label}\"")));
                out.push_str(&format!("{line} {}\n", h.quantile(q) as f64 * scale));
            }
            out.push_str(&format!("{} {}\n", decorate(name, "_sum", None), h.sum as f64 * scale));
            out.push_str(&format!("{} {}\n", decorate(name, "_count", None), h.count));
        }
        out
    }

    /// JSON exposition via `util::json` — counters and gauges as number
    /// maps, histograms as `{count, mean, min, max, p50, p95, p99,
    /// p999, sum}` objects (durations in seconds).
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect(),
        );
        let gauges = Json::Obj(
            self.gauges.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect(),
        );
        let hists = Json::Obj(
            self.hists
                .iter()
                .map(|(k, h)| {
                    let scale = if is_seconds(k) { 1e-9 } else { 1.0 };
                    let obj = Json::obj_from(vec![
                        ("count", Json::Num(h.count as f64)),
                        ("mean", Json::Num(h.mean() * scale)),
                        ("min", Json::Num(h.min as f64 * scale)),
                        ("max", Json::Num(h.max as f64 * scale)),
                        ("p50", Json::Num(h.p50() as f64 * scale)),
                        ("p95", Json::Num(h.p95() as f64 * scale)),
                        ("p99", Json::Num(h.p99() as f64 * scale)),
                        ("p999", Json::Num(h.p999() as f64 * scale)),
                        ("sum", Json::Num(h.sum as f64 * scale)),
                    ]);
                    (k.clone(), obj)
                })
                .collect(),
        );
        Json::obj_from(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", hists),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn force_on() {
        crate::obs::set_level(crate::obs::ObsLevel::On);
    }

    #[test]
    fn counter_and_gauge_shard_correctly() {
        force_on();
        let c = Arc::new(Counter::new());
        let g = Arc::new(Gauge::new());
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let (c, g) = (c.clone(), g.clone());
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                        g.inc();
                    }
                    for _ in 0..250 {
                        g.dec();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
        assert_eq!(g.get(), 8 * 750);
        g.set(-3);
        assert_eq!(g.get(), -3);
    }

    #[test]
    fn registry_shares_by_name() {
        force_on();
        let reg = MetricsRegistry::new();
        let a = reg.counter("x_total");
        let b = reg.counter("x_total");
        a.inc();
        b.inc();
        assert_eq!(reg.snapshot().counters["x_total"], 2);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn label_escaping() {
        assert_eq!(with_labels("m", &[]), "m");
        assert_eq!(
            with_labels("m", &[("model", "a\"b\\c"), ("stage", "exec")]),
            "m{model=\"a\\\"b\\\\c\",stage=\"exec\"}"
        );
    }

    #[test]
    fn prometheus_and_json_exposition() {
        force_on();
        let reg = MetricsRegistry::new();
        reg.counter("comq_requests_total").add(7);
        reg.gauge("comq_queue_depth").set(2);
        // a _seconds histogram records ns, exports seconds
        let h = reg.histogram(&with_labels("comq_stage_seconds", &[("stage", "exec")]));
        h.record_n(1_000_000_000, 4); // 4 × 1s
        // a unitless histogram passes through raw
        let b = reg.histogram("comq_batch_size");
        b.record(16);

        let text = reg.to_prometheus();
        assert!(text.contains("comq_requests_total 7\n"), "{text}");
        assert!(text.contains("comq_queue_depth 2\n"), "{text}");
        assert!(
            text.contains("comq_stage_seconds{stage=\"exec\",quantile=\"0.99\"}"),
            "{text}"
        );
        assert!(text.contains("comq_stage_seconds_sum{stage=\"exec\"} 4\n"), "{text}");
        assert!(text.contains("comq_stage_seconds_count{stage=\"exec\"} 4\n"), "{text}");
        assert!(text.contains("comq_batch_size{quantile=\"0.5\"} 16\n"), "{text}");

        let j = reg.to_json();
        let hs = j.get("histograms").unwrap();
        let exec = hs.get("comq_stage_seconds{stage=\"exec\"}").unwrap();
        assert_eq!(exec.get("count").unwrap().num().unwrap(), 4.0);
        assert_eq!(exec.get("sum").unwrap().num().unwrap(), 4.0); // seconds
        let bs = hs.get("comq_batch_size").unwrap();
        assert_eq!(bs.get("max").unwrap().num().unwrap(), 16.0); // raw
        // round-trips through the in-tree parser
        let parsed = Json::parse(&j.to_string_pretty(1)).unwrap();
        assert_eq!(
            parsed.get("counters").unwrap().get("comq_requests_total").unwrap().num().unwrap(),
            7.0
        );
    }

    #[test]
    fn decorate_suffix_placement() {
        assert_eq!(decorate("h", "_sum", None), "h_sum");
        assert_eq!(decorate("h{a=\"b\"}", "_sum", None), "h_sum{a=\"b\"}");
        assert_eq!(decorate("h{a=\"b\"}", "", Some("q=\"1\"")), "h{a=\"b\",q=\"1\"}");
        assert_eq!(decorate("h", "", Some("q=\"1\"")), "h{q=\"1\"}");
        assert!(is_seconds("x_seconds{stage=\"exec\"}"));
        assert!(!is_seconds("x_total"));
    }
}
