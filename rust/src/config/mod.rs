//! Run configuration: CLI flags layered over optional TOML-lite files.
//!
//! The TOML subset (hand-rolled; no external crates available) supports
//! `[sections]`, `key = value` with string/int/float/bool values, and
//! `#` comments — enough for reproducible run configs like
//! examples/configs/*.toml.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::calib::EngineKind;
use crate::coordinator::{PipelineOptions, QuantEngine};
use crate::quant::grid::Scheme;
use crate::quant::{OrderKind, QuantConfig};

/// Parsed TOML-lite document: section -> key -> raw value.
#[derive(Debug, Clone, Default)]
pub struct Toml {
    pub sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Toml {
    pub fn parse(src: &str) -> Result<Toml> {
        let mut doc = Toml::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section '{raw}'", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                doc.sections.entry(section.clone()).or_default();
            } else if let Some(eq) = line.find('=') {
                let key = line[..eq].trim().to_string();
                let mut val = line[eq + 1..].trim().to_string();
                if (val.starts_with('"') && val.ends_with('"') && val.len() >= 2)
                    || (val.starts_with('\'') && val.ends_with('\'') && val.len() >= 2)
                {
                    val = val[1..val.len() - 1].to_string();
                }
                if key.is_empty() {
                    bail!("line {}: empty key", lineno + 1);
                }
                doc.sections.entry(section.clone()).or_default().insert(key, val);
            } else {
                bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
            }
        }
        Ok(doc)
    }

    pub fn parse_file(path: &str) -> Result<Toml> {
        Self::parse(&std::fs::read_to_string(path).map_err(|e| anyhow!("reading {path}: {e}"))?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // naive: '#' outside quotes ends the line
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' | '\'' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Everything a `comq quantize` run needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub artifacts: String,
    pub model: String,
    pub opts: PipelineOptions,
    pub report_path: Option<String>,
    pub save_path: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts: "artifacts".into(),
            model: "vit_s".into(),
            opts: PipelineOptions::default(),
            report_path: None,
            save_path: None,
        }
    }
}

impl RunConfig {
    /// Layer a TOML-lite file (sections [run] and [quant]) over defaults.
    pub fn apply_toml(&mut self, doc: &Toml) -> Result<()> {
        if let Some(v) = doc.get("run", "artifacts") {
            self.artifacts = v.into();
        }
        if let Some(v) = doc.get("run", "model") {
            self.model = v.into();
        }
        if let Some(v) = doc.get("run", "engine") {
            self.opts.engine =
                EngineKind::parse(v).ok_or_else(|| anyhow!("bad engine '{v}'"))?;
        }
        if let Some(v) = doc.get("run", "quant_engine") {
            self.opts.quant_engine =
                QuantEngine::parse(v).ok_or_else(|| anyhow!("bad quant_engine '{v}'"))?;
        }
        if let Some(v) = doc.get("run", "calib_size") {
            self.opts.calib_size = v.parse()?;
        }
        if let Some(v) = doc.get("run", "workers") {
            self.opts.workers = v.parse()?;
        }
        if let Some(v) = doc.get("run", "report") {
            self.report_path = Some(v.into());
        }
        if let Some(v) = doc.get("quant", "method") {
            self.opts.method = v.into();
        }
        if let Some(v) = doc.get("quant", "bits") {
            self.opts.qcfg.bits = v.parse()?;
        }
        if let Some(v) = doc.get("quant", "scheme") {
            self.opts.qcfg.scheme =
                Scheme::parse(v).ok_or_else(|| anyhow!("bad scheme '{v}'"))?;
        }
        if let Some(v) = doc.get("quant", "order") {
            self.opts.qcfg.order =
                OrderKind::parse(v).ok_or_else(|| anyhow!("bad order '{v}'"))?;
        }
        if let Some(v) = doc.get("quant", "iters") {
            self.opts.qcfg.iters = v.parse()?;
        }
        if let Some(v) = doc.get("quant", "lam") {
            self.opts.qcfg.lam = v.parse()?;
        }
        if let Some(v) = doc.get("quant", "act_bits") {
            self.opts.act_bits = Some(v.parse()?);
        }
        if let Some(v) = doc.get("quant", "act_clip") {
            self.opts.act_clip = v.parse()?;
        }
        if let Some(v) = doc.get("quant", "skip_layers") {
            self.opts.skip_layers = v.split(',').map(|s| s.trim().to_string()).collect();
        }
        Ok(())
    }

    /// Build a QuantConfig override quickly (tests & benches).
    pub fn qcfg(&self) -> &QuantConfig {
        &self.opts.qcfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_basics() {
        let doc = Toml::parse(
            r#"
# comment
[run]
model = "vit_s"     # inline comment
calib_size = 512

[quant]
method = 'comq'
bits = 3
lam = 0.71
"#,
        )
        .unwrap();
        assert_eq!(doc.get("run", "model"), Some("vit_s"));
        assert_eq!(doc.get("run", "calib_size"), Some("512"));
        assert_eq!(doc.get("quant", "lam"), Some("0.71"));
        assert_eq!(doc.get("quant", "missing"), None);
    }

    #[test]
    fn toml_rejects_garbage() {
        assert!(Toml::parse("[unclosed").is_err());
        assert!(Toml::parse("novalue").is_err());
        assert!(Toml::parse("= 3").is_err());
    }

    #[test]
    fn layered_config() {
        let mut rc = RunConfig::default();
        let doc = Toml::parse(
            r#"
[run]
model = "resnet_lite"
engine = "native"
workers = 4
[quant]
method = "obq"
bits = 2
scheme = "per-layer"
order = "cyclic"
act_bits = 4
skip_layers = "head, embed/proj"
"#,
        )
        .unwrap();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.model, "resnet_lite");
        assert_eq!(rc.opts.method, "obq");
        assert_eq!(rc.opts.qcfg.bits, 2);
        assert_eq!(rc.opts.qcfg.scheme, Scheme::PerLayer);
        assert_eq!(rc.opts.qcfg.order, OrderKind::Cyclic);
        assert_eq!(rc.opts.act_bits, Some(4));
        assert_eq!(rc.opts.workers, 4);
        assert_eq!(rc.opts.skip_layers, vec!["head".to_string(), "embed/proj".to_string()]);
    }

    #[test]
    fn bad_enum_values_error() {
        let mut rc = RunConfig::default();
        let doc = Toml::parse("[quant]\nscheme = \"per-banana\"").unwrap();
        assert!(rc.apply_toml(&doc).is_err());
    }
}
