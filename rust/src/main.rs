//! `comq` — CLI for the COMQ post-training-quantization coordinator.
//!
//! ```text
//! comq models [--artifacts DIR]
//! comq eval     --model M [--engine native|pjrt]
//! comq quantize --model M --method comq --bits 4 --scheme per-channel
//!               [--order greedy|cyclic] [--iters K] [--lam F]
//!               [--engine native|pjrt] [--quant-engine native|pjrt-kernel]
//!               [--calib-size N] [--act-bits B] [--workers W]
//!               [--config FILE.toml] [--report OUT.json]
//! comq serve    --model M --packed FILE.cqm [--addr HOST:PORT]
//!               [--max-batch N] [--max-delay-ms MS]
//!               [--max-inflight N] [--max-queue N]
//! comq swap     --model M --packed FILE.cqm [ADDR]
//! comq models   --addr ADDR        (remote listing: epochs, registry)
//! comq metrics  [ADDR] [--raw]
//! comq trace    [ADDR] [--out FILE]
//! ```
//!
//! Argument parsing is hand-rolled (no clap in the offline vendor set).

use anyhow::{anyhow, bail, Result};

use comq::calib::{Dataset, EngineKind};
use comq::config::{RunConfig, Toml};
use comq::coordinator::QuantEngine;
use comq::manifest::Manifest;
use comq::model::Model;
use comq::quant::grid::Scheme;
use comq::quant::{OrderKind, QUANTIZER_NAMES};


fn main() {
    // logging goes through comq::obs::logger (COMQ_LOG=quiet|warn|info|debug,
    // default info) — no logger setup needed, the gate is read on first use
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

fn parse_args(argv: &[String]) -> Result<Args> {
    let mut positional = Vec::new();
    let mut flags = std::collections::BTreeMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 1;
            } else {
                flags.insert(name.to_string(), "true".to_string());
            }
        } else {
            positional.push(a.clone());
        }
        i += 1;
    }
    Ok(Args { positional, flags })
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = parse_args(&argv)?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "models" => cmd_models(&args),
        "eval" => cmd_eval(&args),
        "quantize" => cmd_quantize(&args),
        "run-packed" => cmd_run_packed(&args),
        "serve" => cmd_serve(&args),
        "swap" => cmd_swap(&args),
        "metrics" => cmd_metrics(&args),
        "trace" => cmd_trace(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        c => bail!("unknown command '{c}' (try `comq help`)"),
    }
}

fn print_help() {
    println!(
        "comq — backpropagation-free post-training quantization (COMQ, Zhang et al. 2024)

USAGE:
  comq models   [--artifacts DIR]
  comq eval     --model NAME [--engine native|pjrt] [--artifacts DIR]
  comq quantize --model NAME [options]
  comq run-packed --model NAME --packed FILE.cqm [--engine native|pjrt|int8]
                  int8 = serve through the integer runtime (i8 GEMM)
  comq serve --model NAME --packed FILE.cqm [--addr HOST:PORT]
             TCP serving tier over the int8 micro-batcher (COMQ wire
             protocol; Ctrl-C drains in flight and exits). Options:
             --max-batch N / --max-delay-ms MS   micro-batcher window
             --max-inflight N / --max-queue N    admission + shedding
             --drain-timeout-ms MS               shutdown drain bound
  comq swap --model NAME --packed FILE.cqm [ADDR]
             hot-swap a running server's model to a new checkpoint:
             the new weights load off-path, in-flight requests finish
             on the old epoch, nothing is dropped
  comq models --addr ADDR   list a running server's models (epoch,
             bits, integrity, residency) and its registry counters
             (without --addr: the local artifact listing below)
  comq metrics [ADDR]   fetch a running server's metrics and pretty-print
             counters, gauges and histogram quantiles (default addr
             127.0.0.1:7943); --raw dumps the Prometheus text as-is
  comq trace [ADDR]     fetch a running server's retained request traces
             (COMQ_TRACE must be on server-side) as Chrome trace-event
             JSON; --out FILE (default comq_trace.json), load in
             chrome://tracing or https://ui.perfetto.dev
  comq inspect --model NAME [--calib-size N]   calibration diagnostics

QUANTIZE OPTIONS:
  --method M         {}  (default comq)
  --bits B           weight bits, default 4
  --scheme S         per-channel | per-layer   (default per-channel)
  --order O          greedy | greedy-shared | cyclic (default greedy)
  --iters K          COMQ sweeps, default 3
  --lam F            per-channel init shrink, default 1.0
  --act-bits B       also fake-quantize activations (4 or 8)
  --act-clip F       activation range clip ratio, default 0.95
  --calib-size N     calibration images, default 1024
  --engine E         eval/calibration engine: native | pjrt | int8
                     (default native; int8 scores the packed codes through
                     the integer serving runtime)
  --quant-engine E   sweep engine: native | pjrt-kernel (default native)
  --workers N        parallel layer jobs, default 1
  --skip-layers L    comma-separated layer names to keep FP
  --mixed-budget B   mixed-precision mode: allocate per-layer bits under
                     an average budget of B bits/weight (extension)
  --config FILE      TOML config (CLI flags override)
  --report FILE      write the JSON run report here
  --save FILE.cqm    write the packed (bit-stream) quantized checkpoint
  --artifacts DIR    artifact root (default ./artifacts)",
        QUANTIZER_NAMES.join(" | ")
    );
}

fn build_config(args: &Args) -> Result<RunConfig> {
    let mut rc = RunConfig::default();
    if let Some(cfg) = args.flags.get("config") {
        rc.apply_toml(&Toml::parse_file(cfg)?)?;
    }
    let f = &args.flags;
    if let Some(v) = f.get("artifacts") {
        rc.artifacts = v.clone();
    }
    if let Some(v) = f.get("model") {
        rc.model = v.clone();
    }
    if let Some(v) = f.get("method") {
        rc.opts.method = v.clone();
    }
    if let Some(v) = f.get("bits") {
        rc.opts.qcfg.bits = v.parse()?;
    }
    if let Some(v) = f.get("scheme") {
        rc.opts.qcfg.scheme = Scheme::parse(v).ok_or_else(|| anyhow!("bad --scheme '{v}'"))?;
    }
    if let Some(v) = f.get("order") {
        rc.opts.qcfg.order = OrderKind::parse(v).ok_or_else(|| anyhow!("bad --order '{v}'"))?;
    }
    if let Some(v) = f.get("iters") {
        rc.opts.qcfg.iters = v.parse()?;
    }
    if let Some(v) = f.get("lam") {
        rc.opts.qcfg.lam = v.parse()?;
    }
    if let Some(v) = f.get("act-bits") {
        rc.opts.act_bits = Some(v.parse()?);
    }
    if let Some(v) = f.get("act-clip") {
        rc.opts.act_clip = v.parse()?;
    }
    if let Some(v) = f.get("calib-size") {
        rc.opts.calib_size = v.parse()?;
    }
    if let Some(v) = f.get("engine") {
        rc.opts.engine = EngineKind::parse(v).ok_or_else(|| anyhow!("bad --engine '{v}'"))?;
    }
    if let Some(v) = f.get("quant-engine") {
        rc.opts.quant_engine =
            QuantEngine::parse(v).ok_or_else(|| anyhow!("bad --quant-engine '{v}'"))?;
    }
    if let Some(v) = f.get("workers") {
        rc.opts.workers = v.parse()?;
    }
    if let Some(v) = f.get("skip-layers") {
        rc.opts.skip_layers = v.split(',').map(|s| s.trim().to_string()).collect();
    }
    if let Some(v) = f.get("report") {
        rc.report_path = Some(v.clone());
    }
    if let Some(v) = f.get("save") {
        rc.save_path = Some(v.clone());
    }
    Ok(rc)
}

fn cmd_models(args: &Args) -> Result<()> {
    // `--addr` asks a running server instead of the local manifest:
    // one line per served model (epoch, bits, integrity, residency)
    // plus the model registry's lifecycle counters
    if let Some(addr) = args.flags.get("addr") {
        let mut client = comq::serve::NetClient::connect(addr.as_str())
            .map_err(|e| anyhow!("connect {addr}: {e}"))?;
        let text = client.models().map_err(|e| anyhow!("models fetch: {e}"))?;
        print!("{text}");
        return Ok(());
    }
    let rc = build_config(args)?;
    let manifest = Manifest::load(&rc.artifacts)?;
    println!(
        "{:<16} {:<7} {:>8} {:>8} {:>7}  artifacts",
        "model", "family", "params", "q-wts", "fp-top1"
    );
    for (name, info) in &manifest.models {
        let model = Model::load(&manifest, name)?;
        println!(
            "{:<16} {:<7} {:>8} {:>8} {:>6.2}%  {}",
            name,
            match info.config {
                comq::manifest::ModelConfig::ViT(_) => "vit",
                comq::manifest::ModelConfig::Cnn(_) => "cnn",
            },
            model.num_params(),
            model.num_quant_weights(),
            info.fp_top1 * 100.0,
            info.artifacts.keys().cloned().collect::<Vec<_>>().join(",")
        );
    }
    println!("\nsweep kernels: {} shapes", manifest.sweeps.len());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rc = build_config(args)?;
    let manifest = Manifest::load(&rc.artifacts)?;
    let model = Model::load(&manifest, &rc.model)?;
    let dataset = Dataset::load(&manifest)?;
    let t = comq::util::Timer::start();
    let acc = comq::coordinator::pipeline::eval_fp(&manifest, &model, &dataset, rc.opts.engine)?;
    println!(
        "{}: top1={:.2}% top5={:.2}% (n={}, engine={}, {:.2}s; manifest fp_top1={:.2}%)",
        rc.model,
        acc.top1 * 100.0,
        acc.top5 * 100.0,
        acc.n,
        rc.opts.engine.name(),
        t.secs(),
        model.info.fp_top1 * 100.0,
    );
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let rc = build_config(args)?;
    let manifest = Manifest::load(&rc.artifacts)?;
    let model = Model::load(&manifest, &rc.model)?;
    let dataset = Dataset::load(&manifest)?;
    if let Some(budget) = args.flags.get("mixed-budget") {
        return cmd_quantize_mixed(&rc, &manifest, &model, &dataset, budget.parse()?);
    }
    comq::log_info!(
        "quantizing {} with {} ({}W{}, {}, {})",
        rc.model,
        rc.opts.method,
        rc.opts.qcfg.bits,
        rc.opts.act_bits.map(|b| format!("A{b}")).unwrap_or_else(|| "A32".into()),
        rc.opts.qcfg.scheme.name(),
        rc.opts.qcfg.order.name()
    );
    let out = comq::coordinator::quantize_model_packed(&manifest, &model, &dataset, &rc.opts)?;
    let report = out.report;
    println!("{}", report.summary());
    if let Some(path) = &rc.save_path {
        comq::deploy::save_packed_with_act(
            path,
            &out.model,
            &out.packed,
            rc.opts.qcfg.bits,
            out.act.as_ref(),
        )?;
        let (packed, fp32) = comq::deploy::footprint(&out.packed);
        comq::log_info!(
            "packed checkpoint written to {path} ({:.1} KiB quantized weights vs {:.1} KiB f32{})",
            packed as f64 / 1024.0,
            fp32 as f64 / 1024.0,
            if out.act.is_some() { ", + activation grid for int8 serving" } else { "" }
        );
    }
    for l in &report.layers {
        comq::log_debug!(
            "  {:<16} [{:>4}x{:<4}] err={:.4e} (rtn {:.4e}) {:.3}s",
            l.name,
            l.m,
            l.n,
            l.err,
            l.err_rtn,
            l.secs
        );
    }
    if let Some(path) = &rc.report_path {
        report.save(path)?;
        comq::log_info!("report written to {path}");
    }
    Ok(())
}

/// Mixed-precision mode (paper future-work extension): allocate per-layer
/// bit-widths under an average-bits budget, then quantize + evaluate.
fn cmd_quantize_mixed(
    rc: &RunConfig,
    manifest: &Manifest,
    model: &Model,
    dataset: &Dataset,
    budget: f64,
) -> Result<()> {
    use comq::coordinator::mixed_precision_quantize;
    let imgs = dataset.calib_subset(rc.opts.calib_size);
    let stats = comq::calib::collect_stats(manifest, model, &imgs, rc.opts.engine)?;
    let t = comq::util::Timer::start();
    let (qmodel, rep) =
        mixed_precision_quantize(manifest, model, &stats, &rc.opts.qcfg, budget)?;
    let quant_secs = t.secs();
    let acc = comq::eval::evaluate(
        manifest,
        &qmodel,
        &dataset.val_images,
        &dataset.val_labels,
        rc.opts.engine,
        &comq::eval::ActMode::Fp,
    )?;
    println!(
        "{} mixed-precision: budget {:.2} bits -> achieved {:.3} bits, top1={:.2}% (fp {:.2}%), err={:.4e}, quant={:.2}s",
        rc.model,
        rep.budget_bits,
        rep.achieved_bits,
        acc.top1 * 100.0,
        model.info.fp_top1 * 100.0,
        rep.total_err,
        quant_secs,
    );
    for l in &rep.layers {
        println!("  {:<16} {} bits ({} weights, err {:.3e})", l.name, l.bits, l.weights, l.err);
    }
    Ok(())
}

/// Load a packed (.cqm) checkpoint and evaluate it — the deployment path.
/// `--engine int8` serves the codes through the integer runtime (i8 GEMM,
/// no f32 weights); native/pjrt dequantize and run the f32 graph.
fn cmd_run_packed(args: &Args) -> Result<()> {
    let rc = build_config(args)?;
    let packed_path = args
        .flags
        .get("packed")
        .ok_or_else(|| anyhow!("run-packed needs --packed FILE.cqm"))?;
    let manifest = Manifest::load(&rc.artifacts)?;
    let dataset = Dataset::load(&manifest)?;
    let t = comq::util::Timer::start();
    let acc = if rc.opts.engine == EngineKind::Int8 {
        let qm = comq::serve::load_cached(&manifest, &rc.model, packed_path)?;
        comq::log_info!(
            "serving {} via int8 runtime: {} i8 layers ({} grouped), {:.1} KiB resident (W{}A{})",
            rc.model,
            qm.int8_layers(),
            qm.grouped_layers(),
            qm.resident_bytes() as f64 / 1024.0,
            qm.weight_bits_label(),
            qm.act_source().bits(),
        );
        comq::eval::evaluate_int8(&qm, &dataset.val_images, &dataset.val_labels, manifest.batch)?
    } else {
        let model = comq::deploy::load_packed(&manifest, &rc.model, packed_path)?;
        comq::eval::evaluate(
            &manifest,
            &model,
            &dataset.val_images,
            &dataset.val_labels,
            rc.opts.engine,
            &comq::eval::ActMode::Fp,
        )?
    };
    println!(
        "{} (packed {packed_path}, engine {}): top1={:.2}% top5={:.2}% (n={}, {:.2}s)",
        rc.model,
        rc.opts.engine.name(),
        acc.top1 * 100.0,
        acc.top5 * 100.0,
        acc.n,
        t.secs()
    );
    Ok(())
}

/// TCP serving: load a packed checkpoint into the int8 runtime and put
/// the hardened network front door (wire protocol, deadlines, admission
/// control, load shedding) in front of its micro-batcher. Runs until
/// SIGINT/SIGTERM, then drains gracefully.
fn cmd_serve(args: &Args) -> Result<()> {
    use std::time::Duration;
    let rc = build_config(args)?;
    let packed_path =
        args.flags.get("packed").ok_or_else(|| anyhow!("serve needs --packed FILE.cqm"))?;
    let manifest = Manifest::load(&rc.artifacts)?;
    let qm = comq::serve::load_cached(&manifest, &rc.model, packed_path)?;
    let f = &args.flags;
    let addr = f.get("addr").map(String::as_str).unwrap_or("127.0.0.1:7943");
    let mut cfg = comq::serve::NetConfig::default();
    // pipelined stage execution is a deployment decision, not a client
    // one: resolved from COMQ_PIPELINE (off|on|auto) at startup
    cfg.batch.pipeline = comq::serve::pipeline_from_env();
    if let Some(v) = f.get("max-batch") {
        cfg.batch.max_batch = v.parse()?;
    }
    if let Some(v) = f.get("max-delay-ms") {
        cfg.batch.max_delay = Duration::from_millis(v.parse()?);
    }
    if let Some(v) = f.get("max-inflight") {
        cfg.admission.max_inflight = v.parse()?;
    }
    if let Some(v) = f.get("max-queue") {
        cfg.admission.max_queue = v.parse()?;
    }
    if let Some(v) = f.get("drain-timeout-ms") {
        cfg.drain_timeout = Duration::from_millis(v.parse()?);
    }
    let server = comq::serve::NetServer::bind(addr, vec![(rc.model.clone(), qm)], cfg)?;
    println!(
        "serving {} on {} — COMQ wire protocol v{} (Ctrl-C drains and exits)",
        rc.model,
        server.local_addr(),
        comq::serve::net::WIRE_VERSION,
    );
    wait_for_interrupt();
    println!("draining in-flight requests…");
    server.shutdown();
    let net = server.stats();
    let batch = server.model_server(&rc.model).map(|s| s.stats());
    println!(
        "drained: {} connections, {} frames, {} error frames, {} rx / {} tx bytes",
        net.connections, net.frames, net.error_frames, net.rx_bytes, net.tx_bytes
    );
    if let Some(b) = batch {
        println!(
            "batcher: {} served in {} batches, shed {} (deadline) + {} (overload), {} respawns",
            b.served, b.batches, b.shed_deadline, b.shed_overload, b.respawns
        );
    }
    Ok(())
}

/// Hot-swap a running server's model to a new packed checkpoint over
/// the wire. The server loads + preps the new weights off its event
/// loop, answers every in-flight request from the old epoch, then
/// flips — the reply reports both epochs once the swap is live.
fn cmd_swap(args: &Args) -> Result<()> {
    let model =
        args.flags.get("model").ok_or_else(|| anyhow!("swap needs --model NAME"))?;
    let packed =
        args.flags.get("packed").ok_or_else(|| anyhow!("swap needs --packed FILE.cqm"))?;
    let addr = client_addr(args);
    let mut client = comq::serve::NetClient::connect(addr)
        .map_err(|e| anyhow!("connect {addr}: {e}"))?;
    let (old_epoch, new_epoch) =
        client.swap(model, packed).map_err(|e| anyhow!("swap: {e}"))?;
    println!(
        "{model}: epoch {old_epoch} -> {new_epoch} ({packed}) — swap complete, old epoch drained"
    );
    Ok(())
}

/// Positional `ADDR` for the client-side subcommands (`metrics`,
/// `trace`), defaulting to the `serve` default.
fn client_addr(args: &Args) -> &str {
    args.positional.get(1).map(String::as_str).unwrap_or("127.0.0.1:7943")
}

/// Fetch a running server's metrics over the wire and pretty-print them
/// client-side: plain counters/gauges as-is, histogram summaries
/// regrouped so each series shows its quantiles on one line.
fn cmd_metrics(args: &Args) -> Result<()> {
    let addr = client_addr(args);
    let mut client = comq::serve::NetClient::connect(addr)
        .map_err(|e| anyhow!("connect {addr}: {e}"))?;
    let text = client.metrics().map_err(|e| anyhow!("metrics fetch: {e}"))?;
    if args.flags.contains_key("raw") {
        print!("{text}");
        return Ok(());
    }

    // The exposition is `name{labels} value` lines; histograms appear as
    // summaries — four `quantile="..."` samples plus `_sum`/`_count`.
    // Regroup by series key (name+labels minus the quantile label).
    use std::collections::BTreeMap;
    let mut scalars: Vec<(String, f64)> = Vec::new();
    let mut hists: BTreeMap<String, BTreeMap<String, f64>> = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((name, value)) = line.rsplit_once(' ') else { continue };
        let Ok(value) = value.parse::<f64>() else { continue };
        if name.contains("quantile=\"") {
            // split name{l1,l2,quantile="q"} into the series key (name +
            // remaining labels) and the quantile itself
            let (base, labels) = match name.find('{') {
                Some(i) => (&name[..i], name[i + 1..].trim_end_matches('}')),
                None => (name, ""),
            };
            let mut q = String::new();
            let rest: Vec<&str> = labels
                .split(',')
                .filter(|l| match l.strip_prefix("quantile=\"") {
                    Some(v) => {
                        q = v.trim_end_matches('"').to_string();
                        false
                    }
                    None => true,
                })
                .collect();
            let key = if rest.is_empty() {
                base.to_string()
            } else {
                format!("{base}{{{}}}", rest.join(","))
            };
            let field = format!("p{}", q.strip_prefix("0.").unwrap_or(&q));
            hists.entry(key).or_default().insert(field, value);
        } else if let Some(base) = series_base(name, "_sum") {
            hists.entry(base).or_default().insert("sum".into(), value);
        } else if let Some(base) = series_base(name, "_count") {
            hists.entry(base).or_default().insert("count".into(), value);
        } else {
            scalars.push((name.to_string(), value));
        }
    }

    if !scalars.is_empty() {
        println!("counters / gauges:");
        for (name, v) in &scalars {
            println!("  {name:<56} {v}");
        }
    }
    if !hists.is_empty() {
        println!("histograms:");
        for (name, fields) in &hists {
            let secs = name.split('{').next().unwrap_or(name).ends_with("_seconds");
            let fmt = |k: &str| {
                fields.get(k).map_or("-".to_string(), |&v| {
                    if secs {
                        format!("{:.3}ms", v * 1e3)
                    } else {
                        format!("{v:.1}")
                    }
                })
            };
            let count = fields.get("count").copied().unwrap_or(0.0);
            println!(
                "  {name}\n    p50 {:>10}  p95 {:>10}  p99 {:>10}  p999 {:>10}  count {}",
                fmt("p5"),
                fmt("p95"),
                fmt("p99"),
                fmt("p999"),
                count as u64,
            );
        }
    }
    Ok(())
}

/// `name` is `base_suffix{labels}` → `Some("base{labels}")`, else None.
fn series_base(name: &str, suffix: &str) -> Option<String> {
    let (head, labels) = match name.find('{') {
        Some(i) => (&name[..i], &name[i..]),
        None => (name, ""),
    };
    head.strip_suffix(suffix).map(|base| format!("{base}{labels}"))
}

/// Fetch a running server's retained traces (the flight-recorder /
/// tail-sampled span trees) as Chrome trace-event JSON and write them to
/// a file for chrome://tracing or Perfetto.
fn cmd_trace(args: &Args) -> Result<()> {
    let addr = client_addr(args);
    let out = args.flags.get("out").map(String::as_str).unwrap_or("comq_trace.json");
    let mut client = comq::serve::NetClient::connect(addr)
        .map_err(|e| anyhow!("connect {addr}: {e}"))?;
    let json = client.trace_dump().map_err(|e| anyhow!("trace fetch: {e}"))?;
    let (requests, events) = match comq::util::json::Json::parse(&json) {
        Ok(doc) => {
            let evs = doc.get("traceEvents").and_then(|e| e.arr()).map_or(0, |a| a.len());
            let reqs = doc
                .get("traceEvents")
                .and_then(|e| e.arr())
                .map(|a| {
                    a.iter()
                        .filter(|e| e.get("ph").and_then(|p| p.str()).ok() == Some("M"))
                        .count()
                })
                .unwrap_or(0);
            (reqs, evs)
        }
        Err(_) => (0, 0),
    };
    std::fs::write(out, &json)?;
    println!(
        "wrote {out}: {requests} retained request(s), {events} trace event(s) \
         ({} bytes) — open in chrome://tracing or https://ui.perfetto.dev",
        json.len()
    );
    if requests == 0 {
        println!("(no traces retained — is COMQ_TRACE set on the server?)");
    }
    Ok(())
}

/// Park the main thread until SIGINT/SIGTERM. The handler only flips an
/// atomic (async-signal-safe); the drain itself runs on this thread.
#[cfg(unix)]
fn wait_for_interrupt() {
    use std::sync::atomic::{AtomicBool, Ordering};
    static STOP: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_signal(_sig: i32) {
        STOP.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
    while !STOP.load(Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
}

#[cfg(not(unix))]
fn wait_for_interrupt() {
    // no portable signal story without deps: serve until the process is
    // killed (the OS reclaims the sockets)
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Calibration diagnostics: per-layer Gram conditioning, dead features,
/// activation ranges — what to look at before quantizing a new model.
fn cmd_inspect(args: &Args) -> Result<()> {
    let rc = build_config(args)?;
    let manifest = Manifest::load(&rc.artifacts)?;
    let model = Model::load(&manifest, &rc.model)?;
    let dataset = Dataset::load(&manifest)?;
    let imgs = dataset.calib_subset(rc.opts.calib_size);
    let stats = comq::calib::collect_stats(&manifest, &model, &imgs, rc.opts.engine)?;
    println!(
        "{:<16} {:>5} {:>5} {:>12} {:>12} {:>6} {:>18}",
        "layer", "m", "n", "tr(G)/m", "diag min", "dead", "act range"
    );
    for l in &model.info.quant_layers {
        let st = &stats[&l.name];
        // diagnostics over the (first) Gram
        let g = st.gram.for_col(0);
        let m = g.rows();
        let mut tr = 0.0f64;
        let mut dmin = f64::INFINITY;
        let mut dead = 0usize;
        for i in 0..m {
            let d = g.at2(i, i) as f64;
            tr += d;
            dmin = dmin.min(d);
            if d <= 1e-12 {
                dead += 1;
            }
        }
        println!(
            "{:<16} {:>5} {:>5} {:>12.4e} {:>12.4e} {:>6} [{:>7.2}, {:>7.2}]",
            l.name,
            l.m,
            l.n,
            tr / m as f64,
            dmin,
            dead,
            st.min,
            st.max
        );
    }
    Ok(())
}
